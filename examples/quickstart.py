"""Quickstart: the paper's headline result in ~40 lines.

DIANA-RR (Algorithm 3) vs the naive Q-RR (Algorithm 2) and the QSGD/DIANA
baselines on federated L2-regularized logistic regression (paper Sec. 3.1):
same Rand-k compressor, same communication budget — DIANA-RR converges to
the exact optimum, the others stall at their compression-variance floor.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.compression.ops import RandK
from repro.core.algorithms import (
    init_algorithm,
    make_epoch_fn,
    theoretical_stepsizes,
)
from repro.data.logreg import make_federated_logreg

problem = make_federated_logreg(m=20, n_batches=10, batch=10, d=100,
                                cond=100.0, seed=0, heterogeneous=True)
comp = RandK(fraction=0.02)  # the paper's k/d ~= 0.02
loss = problem.loss_fn()

# stepsize = theory x tuned multiplier (the paper's protocol, App. A.1;
# multipliers are the tuned values from EXPERIMENTS.md §Paper-validation)
MULT = {"qsgd": 8.0, "q_rr": 8.0, "diana": 32.0, "diana_rr": 128.0}

print(f"{'method':>10s} | {'f(x)-f* after 1500 epochs':>24s}")
for name in ("qsgd", "q_rr", "diana", "diana_rr"):
    th = theoretical_stepsizes(name, l_max=problem.l_max, mu=problem.mu,
                               omega=comp.omega(problem.d), m=problem.m,
                               n=problem.n)
    spec, epoch = make_epoch_fn(name, loss, comp,
                                gamma=th["gamma"] * MULT[name],
                                alpha=th.get("alpha"))
    state = init_algorithm(spec, {"w": jnp.zeros((problem.d,))}, problem.m,
                           problem.n)
    epoch = jax.jit(epoch)
    key = jax.random.PRNGKey(0)
    for e in range(1500):
        key, k = jax.random.split(key)
        state = epoch(state, problem.data, k)
    print(f"{name:>10s} | {problem.suboptimality(state.params['w']):24.3e}")
