"""End-to-end driver: train a ~100M-param LM with the production DIANA-RR
compressed-gradient wire on a (data=4, model=2) mesh of 8 host devices.

This is deliverable (b)'s end-to-end example: real mesh, real shard_map
train step (per-client grads -> Rand-block compression -> sparse all-reduce
-> DIANA shift update -> SGD), random-reshuffling data pipeline, loss
falling on a learnable synthetic token stream.

    PYTHONPATH=src python examples/train_lm_diana_rr.py --preset tiny --steps 60
    PYTHONPATH=src python examples/train_lm_diana_rr.py --preset 100m --steps 300
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dist import CompressedAggregation
from repro.data.pipeline import make_batch_stream, shared_slots_for_step
from repro.data.reshuffle import ReshuffleSampler
from repro.data.tokens import synthetic_token_batches
from repro.launch import compat
from repro.launch import steps
from repro.launch.mesh import make_test_mesh, num_clients
from repro.models.config import ArchConfig

PRESETS = {
    # ~10M: CI-speed sanity run
    "tiny": dict(num_layers=4, d_model=256, num_heads=4, num_kv_heads=4,
                 d_ff=1024, vocab=2048),
    # ~100M-class model (the deliverable's end-to-end scale)
    "100m": dict(num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
                 d_ff=3072, vocab=8192),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=PRESETS, default="tiny")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)  # global; 2 per client
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--fraction", type=float, default=0.05)
    ap.add_argument("--agg", choices=("diana_rr", "diana", "q", "dense"),
                    default="diana_rr",
                    help="diana_rr is the paper's Algorithm 3 on the wire: "
                         "per-slot shift tables + the shared (rr_shared) "
                         "reshuffling order")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = ArchConfig(name=f"lm-{args.preset}", family="dense",
                     norm="rmsnorm", act="swiglu", **PRESETS[args.preset])
    mesh = make_test_mesh((4, 2), ("data", "model"))
    m = num_clients(mesh)
    n_batches = 8
    slotted = args.agg == "diana_rr"
    agg = CompressedAggregation(method=args.agg, wire="shared",
                                fraction=args.fraction,
                                n_slots=n_batches if slotted else 1,
                                shift_dtype=jnp.float32)
    jitted, abstract, shardings, batch_sh = steps.make_train_step(
        cfg, mesh, agg=agg, lr=args.lr, remat=False)

    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(abstract.params))
    print(f"model: {n_params/1e6:.1f}M params | clients={m} | agg={args.agg} "
          f"(k/d={args.fraction}) | mesh=(data=4, model=2)")

    # random-reshuffling data pipeline (the paper's 'RR' — a data-pipeline
    # property). DIANA-RR uses the SHARED per-epoch order so every client
    # sits on the same shift-table slot each round (DESIGN.md §3.8).
    data = synthetic_token_batches(
        vocab=cfg.vocab, seq_len=args.seq, batch=args.batch // m,
        num_batches=n_batches, num_clients=m, seed=0)
    sampler = ReshuffleSampler(m, n_batches,
                               mode="rr_shared" if slotted else "rr", seed=1)

    with compat.set_mesh(mesh):
        state = jax.device_put(
            steps.init_train_state(jax.random.key(0), cfg, agg, m), shardings)
        key = jax.random.key(1)
        t0 = time.time()
        first = last = None
        # epoch-indexed RR stream: client-major rows, prefetch+device_put
        # overlapped with the running step (data.pipeline, DESIGN.md §3.7)
        stream = make_batch_stream(
            {"tokens": data}, sampler,
            put=lambda b: jax.device_put(b, batch_sh(b)))
        with stream:
            for t, batch in zip(range(args.steps), stream):
                if slotted:
                    slots = jnp.asarray(shared_slots_for_step(
                        sampler, t, n_slots=agg.n_slots))
                    state, metrics = jitted(state, batch, key, slots)
                else:
                    state, metrics = jitted(state, batch, key)
                if t % args.log_every == 0 or t == args.steps - 1:
                    loss = float(metrics["loss"])
                    first = first if first is not None else loss
                    last = loss
                    print(f"step {t:4d} | loss {loss:7.4f} | "
                          f"gnorm {float(metrics['grad_norm']):8.3f} | "
                          f"{(time.time()-t0)/(t+1):5.2f}s/step", flush=True)
    print(f"loss: {first:.4f} -> {last:.4f} "
          f"({'DECREASED' if last < first - 0.05 else 'no significant change'})")


if __name__ == "__main__":
    main()
