"""Batched decode serving on a (data, model) mesh: prefill a prompt batch,
then stream tokens through the sharded serve_step (KV cache donated
in-place each step).

    PYTHONPATH=src python examples/serve_decode.py --arch starcoder2-15b --tokens 32

Uses the REDUCED config of the chosen architecture so the example runs on
CPU; the full config is exercised (lower+compile) by launch/dryrun.py.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config, reduced
from repro.launch import compat
from repro.launch import steps
from repro.launch.mesh import make_test_mesh
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="starcoder2-15b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch), seq=max(64, args.prompt_len * 2))
    mesh = make_test_mesh((4, 2), ("data", "model"))
    key = jax.random.key(0)
    params = T.init_params(key, cfg)
    cache_len = args.prompt_len + args.tokens + 8

    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (args.batch, cfg.vision_patches, cfg.d_model), cfg.dtype)
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            key, (args.batch, cfg.encoder_seq, cfg.d_model), cfg.dtype)

    serve, lower_args = steps.make_serve_step(cfg, mesh)
    with compat.set_mesh(mesh):
        logits, cache = T.prefill(params, batch, cfg, cache_len=cache_len)
        jitted, (psh, csh, tsh) = lower_args(
            jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params),
            jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), cache),
            jax.ShapeDtypeStruct((args.batch, 1), jnp.int32),
        )
        params = jax.device_put(params, psh)
        cache = jax.device_put(cache, csh)
        tok = jnp.argmax(logits[:, :, :cfg.vocab], -1).astype(jnp.int32)
        out = [tok]
        t0 = time.time()
        for i in range(args.tokens):
            logits, cache = jitted(params, cache, jax.device_put(tok, tsh),
                                   jnp.int32(args.prompt_len + i))
            tok = jnp.argmax(logits[:, :, :cfg.vocab], -1).astype(jnp.int32)
            out.append(tok)
        dt = (time.time() - t0) / args.tokens
    gen = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name} (reduced) | batch={args.batch} | "
          f"{dt*1e3:.1f} ms/token on CPU")
    print("generated token ids (first request):", gen[0].tolist())
    assert bool(jnp.all((gen >= 0) & (gen < cfg.vocab)))
    print("OK: all generated ids in-vocab; cache ring/state advanced "
          f"{args.tokens} steps")


if __name__ == "__main__":
    main()
