"""Paper Figure 1 reproduction: all four proposed methods vs all baselines
on heterogeneous federated logistic regression, with the paper's tuning
protocol (theory stepsize x tuned multiplier) and honest uplink-bit
accounting.

    PYTHONPATH=src python examples/federated_logreg.py [--epochs 800] [--quick]

Prints one CSV row per (method): final suboptimality + bits uplinked, the
two axes of the paper's plots. Expected ordering (paper Sec. 3):
  exp1:  diana_rr << diana < qsgd ~ q_rr
  exp2:  diana_nastya << q_nastya ~ fedcom ~ fedpaq
"""
import argparse

from benchmarks.experiments import communication_table, experiment1, experiment2


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=800)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()

    rows = []
    rows += experiment1(epochs=args.epochs, quick=args.quick)
    rows += experiment2(epochs=args.epochs, quick=args.quick)
    rows += communication_table(epochs=min(args.epochs, 400))
    print("name,us_per_epoch_or_bits,final_suboptimality")
    for r in rows:
        print(",".join(str(x) for x in r))


if __name__ == "__main__":
    main()
