"""MoE dispatch: ragged_dot path vs dense-einsum oracle + routing invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models.moe import init_moe, moe_ffn, moe_ffn_ref


@pytest.fixture(scope="module")
def moe_setup():
    cfg = reduced(get_config("qwen2-moe-a2.7b"))
    params = init_moe(jax.random.key(0), cfg)
    return cfg, params


@pytest.mark.parametrize("b,s", [(1, 1), (2, 16), (3, 33)])
def test_ragged_matches_dense_oracle(moe_setup, b, s):
    cfg, params = moe_setup
    x = jax.random.normal(jax.random.key(1), (b, s, cfg.d_model), cfg.dtype)
    got = moe_ffn(params, x, cfg)
    want = moe_ffn_ref(params, x, cfg)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=0.15, rtol=0.1)


def test_dbrx_family_no_shared_expert():
    cfg = reduced(get_config("dbrx-132b"))
    params = init_moe(jax.random.key(2), cfg)
    assert "shared" not in params
    x = jax.random.normal(jax.random.key(3), (2, 8, cfg.d_model), cfg.dtype)
    got = moe_ffn(params, x, cfg)
    want = moe_ffn_ref(params, x, cfg)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=0.15, rtol=0.1)


def test_load_balance_aux_bounds(moe_setup):
    """Switch aux loss is >= 1 (perfectly balanced) and finite."""
    cfg, params = moe_setup
    x = jax.random.normal(jax.random.key(4), (4, 64, cfg.d_model), cfg.dtype)
    _, aux = moe_ffn(params, x, cfg, return_aux=True)
    assert float(aux) >= 0.99  # e * sum(f_e * p_e) >= 1 at balance
    assert bool(jnp.isfinite(aux))


def test_grad_flows_to_routed_experts_only_when_routed(moe_setup):
    """Experts that received zero tokens get zero gradient through dispatch
    (router gradient may still be nonzero) — dropless semantics."""
    cfg, params = moe_setup
    x = jax.random.normal(jax.random.key(5), (1, 2, cfg.d_model), cfg.dtype)

    def loss(p):
        return jnp.sum(jnp.square(moe_ffn(p, x, cfg).astype(jnp.float32)))

    g = jax.grad(loss)(params)
    # 2 tokens * top-2 = at most 4 routed experts; >= num_experts-4 get no grad
    per_expert = jnp.sum(jnp.abs(g["w_down"].astype(jnp.float32)), axis=(1, 2))
    assert int(jnp.sum(per_expert == 0)) >= cfg.num_experts - 4
