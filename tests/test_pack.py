"""Bit-packed wire slab kernels (kernels/pack.py vs the ref.py oracle).

The packed wire's contract (DESIGN.md §3.13) splits into two halves:

  transport   the packed BYTES are bitwise identical between the pallas
              kernels and the jnp reference — the lattice is integer math,
              so there is no tolerance to hide behind. Scales are one f32
              division and may differ by an ulp across compilation contexts
              (XLA reciprocal-multiply vs true divide), so they compare at
              the repo's standard oracle tolerance.
  decode      v = (b - L) * scale is the ONLY dequantization formula; both
              the f32-transport quantized wire and the packed wire
              round-trip through it, which is what makes packed8 transport
              bit-match the f32 wire at equal levels (test_pod_wire.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.pack import pack_slab, unpack_reduce, unpack_slab
from repro.kernels.randk import BLOCK_ROWS


def _slab(rows, d, seed, scale=3.0):
    key = jax.random.key(seed)
    vals = jax.random.normal(key, (rows, d), jnp.float32) * scale
    u = jax.random.uniform(jax.random.key(seed + 1), (rows, d))
    return vals, u


# ---------------------------------------------------------------------------
# pallas vs reference: bytes bitwise, scales at oracle tolerance
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nibble", [False, True])
@pytest.mark.parametrize("rows,d", [(8, 128), (16, 64), (13, 5), (64, 32)])
def test_pack_matches_ref(rows, d, nibble):
    levels = 7 if nibble else 127
    vals, u = _slab(rows, d, seed=rows * d)
    p, s = pack_slab(vals, u, levels=levels, nibble=nibble)
    pr, sr = ref.pack_slab_ref(vals, u, levels=levels, nibble=nibble,
                               block_rows=BLOCK_ROWS)
    assert p.dtype == jnp.uint8 and pr.dtype == jnp.uint8
    assert np.array_equal(np.asarray(p), np.asarray(pr))  # bitwise
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr),
                               rtol=1e-6, atol=0)


@pytest.mark.parametrize("nibble", [False, True])
@pytest.mark.parametrize("rows,d", [(8, 128), (13, 5), (24, 16)])
def test_unpack_matches_ref(rows, d, nibble):
    levels = 7 if nibble else 127
    vals, u = _slab(rows, d, seed=3 + rows)
    p, s = pack_slab(vals, u, levels=levels, nibble=nibble)
    got = unpack_slab(p, s, levels=levels, n_rows=rows, nibble=nibble)
    want = ref.unpack_slab_ref(p, s, levels=levels, n_rows=rows,
                               nibble=nibble)
    assert got.shape == (rows, d)
    # same bytes, same scales -> same decode, bitwise
    assert np.array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# round-trip properties of the lattice
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nibble,levels", [(False, 127), (False, 3),
                                           (True, 7), (True, 2)])
def test_roundtrip_decode_is_exact_lattice(nibble, levels):
    """Decoding reproduces exactly (q - L) * scale for the integer lattice
    the quantizer chose — recomputed independently in numpy float64."""
    rows, d = 16, 32
    vals, u = _slab(rows, d, seed=11)
    p, s = pack_slab(vals, u, levels=levels, nibble=nibble)
    got = np.asarray(unpack_slab(p, s, levels=levels, n_rows=rows,
                                 nibble=nibble))
    # independent byte decode
    b = np.asarray(p).astype(np.int64)
    if nibble:
        prows = b.shape[0]
        b = np.stack([b % 16, b // 16], axis=1).reshape(prows * 2, d)
    assert (b >= 0).all() and (b <= 2 * levels).all()
    want = (b.astype(np.float32) - np.float32(levels)) * np.asarray(s)[:rows]
    assert np.array_equal(got, want[:rows])


@pytest.mark.parametrize("rows", [1, 5, 9, 13])
def test_padding_tail_decodes_to_zero(rows):
    """Rows pad to a BLOCK_ROWS multiple; padding quantizes to the zero
    byte (b = L), so a full-width decode puts exact zeros in the tail and
    the n_rows trim loses nothing."""
    d = 16
    vals, u = _slab(rows, d, seed=rows)
    p, s = pack_slab(vals, u, levels=127)
    kp = s.shape[0]
    assert kp == rows + (-rows) % BLOCK_ROWS
    full = np.asarray(unpack_slab(p, s, levels=127, n_rows=kp))
    assert (full[rows:] == 0).all()
    got = unpack_slab(p, s, levels=127, n_rows=rows)
    assert got.shape == (rows, d)
    assert np.array_equal(np.asarray(got), full[:rows])


def test_nibble_dequant_identity_at_shared_levels():
    """At L = 7 the nibble lane carries the same lattice as the full byte:
    pack(nibble=True) must decode bitwise-identically to pack(nibble=False)
    at the same levels — the packing is transport, not quantization."""
    rows, d = 16, 32
    vals, u = _slab(rows, d, seed=21)
    p8, s8 = pack_slab(vals, u, levels=7, nibble=False)
    p4, s4 = pack_slab(vals, u, levels=7, nibble=True)
    assert p4.shape == (p8.shape[0] // 2, d)  # two rows per byte
    assert np.array_equal(np.asarray(s8), np.asarray(s4))
    v8 = unpack_slab(p8, s8, levels=7, n_rows=rows, nibble=False)
    v4 = unpack_slab(p4, s4, levels=7, n_rows=rows, nibble=True)
    assert np.array_equal(np.asarray(v8), np.asarray(v4))


def test_quantizer_unbiased():
    """E[decode(pack(x))] = x over the rounding uniforms (Assumption 1 for
    the wire quantizer; omega is set by levels, not by the transport)."""
    rows, d, levels, reps = 8, 16, 7, 4000
    vals = jax.random.normal(jax.random.key(0), (rows, d), jnp.float32)

    def one(key):
        u = jax.random.uniform(key, (rows, d))
        p, s = pack_slab(vals, u, levels=levels)
        return unpack_slab(p, s, levels=levels, n_rows=rows)

    outs = jax.lax.map(one, jax.random.split(jax.random.key(1), reps))
    err = np.asarray(jnp.mean(outs, axis=0) - vals)
    # per-entry MC std <= scale_r/(2 sqrt(reps)); scale_r = amax_r / levels
    amax = np.abs(np.asarray(vals)).max(axis=1, keepdims=True)
    tol = 3.0 * amax / levels / (2 * np.sqrt(reps))
    assert (np.abs(err) < tol + 1e-6).all(), np.abs(err / amax).max()


# ---------------------------------------------------------------------------
# fused unpack-reduce (the receive half of the packed collective)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nibble", [False, True])
@pytest.mark.parametrize("ranks", [2, 4, 8])
def test_unpack_reduce_matches_ref(ranks, nibble):
    levels = 7 if nibble else 127
    rows, d = 16, 32
    packed, scales = [], []
    for r in range(ranks):
        vals, u = _slab(rows, d, seed=100 + r)
        p, s = pack_slab(vals, u, levels=levels, nibble=nibble)
        packed.append(p)
        scales.append(s)
    packed = jnp.stack(packed)
    scales = jnp.stack(scales)
    got = unpack_reduce(packed, scales, levels=levels, n_rows=rows,
                        nibble=nibble)
    want = ref.unpack_reduce_ref(packed, scales, levels=levels, n_rows=rows,
                                 nibble=nibble)
    assert got.shape == (rows, d)
    assert np.array_equal(np.asarray(got), np.asarray(want))  # same schedule


def test_unpack_reduce_is_mean_of_decodes():
    """The fused kernel equals the mean of individually decoded slabs on
    power-of-two rank counts (rank-order sum, exact /R division) — the
    property that lets the packed wire stand in for lax.pmean."""
    ranks, rows, d, levels = 4, 16, 32, 127
    packed, scales = [], []
    for r in range(ranks):
        vals, u = _slab(rows, d, seed=200 + r)
        p, s = pack_slab(vals, u, levels=levels)
        packed.append(p)
        scales.append(s)
    fused = unpack_reduce(jnp.stack(packed), jnp.stack(scales),
                          levels=levels, n_rows=rows)
    acc = unpack_slab(packed[0], scales[0], levels=levels, n_rows=rows)
    for r in range(1, ranks):
        acc = acc + unpack_slab(packed[r], scales[r], levels=levels,
                                n_rows=rows)
    assert np.array_equal(np.asarray(fused), np.asarray(acc / float(ranks)))


def test_unpack_reduce_weighted_scales_fold():
    """Elastic weights fold into the scale sideband: reducing with scales
    w_r * s_r equals the weighted mean of decodes for exact (0/1) weights —
    a dropped rank contributes exact zeros."""
    ranks, rows, d, levels = 4, 8, 16, 127
    weights = [1.0, 0.0, 1.0, 1.0]
    packed, scales = [], []
    for r in range(ranks):
        vals, u = _slab(rows, d, seed=300 + r)
        p, s = pack_slab(vals, u, levels=levels)
        packed.append(p)
        scales.append(s * weights[r])
    fused = np.asarray(unpack_reduce(jnp.stack(packed), jnp.stack(scales),
                                     levels=levels, n_rows=rows))
    acc = np.zeros((rows, d), np.float32)
    for r in (0, 2, 3):
        acc += np.asarray(unpack_slab(packed[r], scales[r], levels=levels,
                                      n_rows=rows))
    assert np.array_equal(fused, acc / np.float32(ranks))
