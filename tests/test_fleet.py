"""fleet/ — partial participation at population scale (DESIGN.md §3.9).

Covers the cohort-RR walk, the sharded client-state store's gather/scatter
contract (DIANA single shifts AND DIANA-RR slot tables), the per-cohort
stream view, the simulator fleet driver, and the production acceptance
criteria: a cohort == population cohort-RR fleet run bit-matches today's
full-participation wire trajectory (params, shift tables, bits) for
`diana` and `diana_rr` on the 1-pod and 2-pod meshes, and fleet `--resume`
is bit-deterministic.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import CohortStream, make_batch_stream
from repro.data.reshuffle import ReshuffleSampler
from repro.fleet import CohortSampler, ClientStateStore, FleetRunner

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 8, reason="needs 8 forced host devices"
)


# ---------------------------------------------------------------------------
# CohortSampler: the client-level RR walk
# ---------------------------------------------------------------------------

def test_cohort_rr_visits_every_client_once_per_fleet_epoch():
    """C=10, m=4: cohorts straddle the fleet-epoch boundary mid-round
    (round 2 takes the last 2 clients of epoch 0 and the first 2 of epoch
    1), yet after any whole number of fleet epochs every client has
    participated exactly that many times."""
    cs = CohortSampler(10, 4, seed=3)
    counts = np.zeros(10, np.int64)
    for r in range(5):  # 5 rounds * 4 = 20 slots = exactly 2 fleet epochs
        cohort = cs.cohort_for_round(r)
        assert cohort.shape == (4,)
        assert (np.diff(cohort) > 0).all(), "sorted, distinct"
        counts[cohort] += 1
    assert (counts == 2).all(), counts
    # the straddling round really mixes two epochs' (effective) orders
    e0, e1 = cs.effective_order(0), cs.effective_order(1)
    straddle = cs.cohort_for_round(2)
    assert set(straddle) == set(e0[8:]) | set(e1[:2])
    # ... and each effective order is still a full permutation (exactly
    # once per epoch even with the head deconflicted against e0's tail)
    assert sorted(e1.tolist()) == list(range(10))
    # closed-form participation counts == replayed counts, mid-epoch too
    for r in range(6):
        replay = np.zeros(10, np.int64)
        for q in range(r):
            replay[cs.cohort_for_round(q)] += 1
        assert np.array_equal(cs.participation_counts(r), replay), r


def test_cohort_straddle_deconfliction():
    """Adjacent epochs' raw permutations are independent, so a straddling
    cohort could draw the same client from epoch e's tail and epoch e+1's
    head — ill-defined for the store scatter. Regression: seed 0 on
    (C=10, m=4) puts client 1 in both; the effective order moves it out of
    the straddling round's reach while keeping exactly-once coverage.
    Sweeps seeds/shapes, and checks cold-cache random access (a resumed
    run's first lookup) matches the sequential walk."""
    raw = CohortSampler(10, 4, seed=0)
    # round 2 takes epoch 0's last 2 slots + epoch 1's first 2: the raw
    # draws collide there (this is the seed the bug reproduced with)
    assert np.intersect1d(raw.epoch_order(0)[8:],
                          raw.epoch_order(1)[:2]).size > 0
    assert (np.diff(raw.cohort_for_round(2)) > 0).all()
    for seed in range(8):
        for C, m in ((10, 4), (7, 3), (13, 5), (9, 2)):
            cs = CohortSampler(C, m, seed=seed)
            rounds = [cs.cohort_for_round(r) for r in range(3 * C // m + 2)]
            for r, co in enumerate(rounds):
                assert (np.diff(co) > 0).all(), (seed, C, m, r, co)
            for e in range(3):
                assert sorted(cs.effective_order(e).tolist()) == \
                    list(range(C)), (seed, C, m, e)
            cold = CohortSampler(C, m, seed=seed)
            r = len(rounds) - 1
            assert np.array_equal(cold.cohort_for_round(r), rounds[r])


def test_cohort_sampler_idempotent_and_stateless():
    cs = CohortSampler(12, 4, seed=7)
    a = [cs.cohort_for_round(r) for r in range(4)]
    b = [CohortSampler(12, 4, seed=7).cohort_for_round(r) for r in range(4)]
    for x, y in zip(a, b):
        assert np.array_equal(x, y)
    assert cs.cursor(0) == (0, 0)
    assert cs.cursor(3) == (1, 0)
    assert cs.cursor(4) == (1, 4)


def test_with_replacement_mode_distinct_within_round():
    cs = CohortSampler(20, 6, mode="with_replacement", seed=1)
    seen = []
    for r in range(10):
        c = cs.cohort_for_round(r)
        assert (np.diff(c) > 0).all(), "distinct ids (scatter well-defined)"
        seen.append(tuple(c))
    assert len(set(seen)) > 1, "i.i.d. across rounds"
    assert np.array_equal(cs.cohort_for_round(3), seen[3])
    # replayed counts drive the resume path for the i.i.d. baseline
    replay = np.zeros(20, np.int64)
    for r in range(7):
        replay[cs.cohort_for_round(r)] += 1
    assert np.array_equal(cs.participation_counts(7), replay)


def test_cohort_sampler_validation():
    with pytest.raises(ValueError):
        CohortSampler(4, 8)
    with pytest.raises(ValueError):
        CohortSampler(4, 2, mode="bogus")


# ---------------------------------------------------------------------------
# ClientStateStore: sharded gather/scatter round-trip
# ---------------------------------------------------------------------------

def _params():
    return {"w": jnp.zeros((3, 5), jnp.float32), "b": jnp.zeros((4,))}


@pytest.mark.parametrize("rule_name,lead", [("single", ()), ("per_slot", (2,))])
def test_store_gather_scatter_roundtrip(rule_name, lead):
    """Gather -> mutate -> scatter -> re-gather is the identity on the
    cohort rows and a no-op on everyone else, across shard boundaries
    (shard_size=3 splits an 11-client population into 4 shards), for both
    the DIANA single-shift and the DIANA-RR slot-table layouts."""
    from repro.core.rules import get_rule

    store = ClientStateStore.create(_params(), 11, get_rule(rule_name),
                                    n_slots=2, shard_size=3)
    cohort = np.array([0, 2, 5, 10])  # hits shards 0, 0, 1, 3
    got = store.gather(cohort)
    assert got["w"].shape == (4,) + lead + (3, 5)
    assert got["b"].shape == (4,) + lead + (4,)
    rng = np.random.default_rng(0)
    upd = jax.tree.map(
        lambda x: rng.normal(size=x.shape).astype(np.float32), got)
    store.scatter(cohort, upd)
    back = store.gather(cohort)
    for k in upd:
        assert np.array_equal(back[k], upd[k]), k
    rest = np.array([1, 3, 4, 6, 7, 8, 9])
    for leaf in jax.tree.leaves(store.gather(rest)):
        assert np.abs(leaf).max() == 0, "untouched clients stay zero"
    # cursors + bit counters ride the same cohort addressing
    store.advance(cohort, 2)
    store.add_bits(cohort, 640.0)
    assert store.cursors(cohort).tolist() == [2] * 4
    assert store.cursors(rest).tolist() == [0] * 7
    assert store.bits[cohort].tolist() == [640.0] * 4


def test_store_memmap_backing(tmp_path):
    from repro.core.rules import get_rule

    store = ClientStateStore.create(_params(), 9, get_rule("single"),
                                    shard_size=4, path=str(tmp_path))
    assert store.num_shards == 3
    cohort = np.array([3, 4, 8])
    upd = store.gather(cohort)
    upd = jax.tree.map(lambda x: x + 1.25, upd)
    store.scatter(cohort, upd)
    got = store.gather(cohort)
    assert np.array_equal(got["w"], upd["w"])
    assert len(list(tmp_path.iterdir())) == 2 * 3  # 2 leaves x 3 shards


def test_store_rejects_bad_cohorts():
    from repro.core.rules import get_rule

    store = ClientStateStore.create(_params(), 8, get_rule("single"),
                                    shard_size=4)
    with pytest.raises(ValueError, match="strictly increasing"):
        store.gather(np.array([2, 1]))
    with pytest.raises(ValueError, match="strictly increasing"):
        store.gather(np.array([1, 1, 2]))
    with pytest.raises(ValueError, match="outside"):
        store.gather(np.array([1, 8]))
    got = store.gather(np.array([0, 1]))
    with pytest.raises(ValueError, match="cohort slice"):
        store.scatter(np.array([0, 1, 2]), got)


# ---------------------------------------------------------------------------
# CohortStream: the per-cohort view of the population stream
# ---------------------------------------------------------------------------

def test_cohort_stream_full_participation_matches_batch_stream():
    """cohort == population under cohort-RR: every round samples every
    client in ascending order, so the emitted batches are bitwise the
    full-participation BatchStream's — the stream half of the fleet
    bit-match invariant. Runs across a data-epoch boundary."""
    m, n, b = 4, 3, 2
    data = {"x": np.arange(m * n * b * 5, dtype=np.float32).reshape(
        m, n, b, 5)}
    sampler = ReshuffleSampler(m, n, mode="rr", seed=1)
    with CohortStream(data, sampler, CohortSampler(m, m, seed=0),
                      local_steps=2) as cstream, \
            make_batch_stream(data, sampler, local_steps=2,
                              prefetch=False) as bstream:
        for t in range(2 * n):
            fr = next(cstream)
            assert fr.round == t
            assert np.array_equal(fr.cohort, np.arange(m))
            assert np.array_equal(fr.batch["x"], next(bstream)["x"]), t


def test_cohort_stream_partial_rows_follow_per_client_cursors():
    """Partial participation: a sampled client's rows come from ITS next RR
    position (clients advance only when sampled), modalities stay aligned,
    and a stream rebuilt at `start_round` replays identically."""
    C, n, b, m = 6, 3, 2, 2
    rng = np.random.default_rng(0)
    data = {"x": rng.normal(size=(C, n, b, 4)).astype(np.float32),
            "y": rng.normal(size=(C, n, b)).astype(np.float32)}
    sampler = ReshuffleSampler(C, n, mode="rr", seed=4)
    cohorts = CohortSampler(C, m, seed=9)
    counts = np.zeros(C, np.int64)
    rounds = []
    with CohortStream(data, sampler, cohorts, prefetch=False) as stream:
        for t in range(8):
            fr = next(stream)
            rounds.append(fr)
            for i, c in enumerate(fr.cohort):
                e, pos = divmod(counts[c], n)
                want = sampler.epoch_order(e)[c, pos]
                assert fr.cols[i, 0] == want, (t, c)
                assert np.array_equal(fr.batch["x"][i * b:(i + 1) * b],
                                      data["x"][c, want])
                assert np.array_equal(fr.batch["y"][i * b:(i + 1) * b],
                                      data["y"][c, want])
            counts[fr.cohort] += 1
    with CohortStream(data, sampler, cohorts, prefetch=False,
                      start_round=5) as resumed:
        for t in range(5, 8):
            fr = next(resumed)
            assert np.array_equal(fr.cohort, rounds[t].cohort)
            assert np.array_equal(fr.batch["x"], rounds[t].batch["x"]), t


def test_cohort_stream_prefetch_matches_sync():
    C, n, b, m = 5, 3, 1, 2
    data = {"x": np.arange(C * n * b * 2, dtype=np.float32).reshape(
        C, n, b, 2)}
    sampler = ReshuffleSampler(C, n, seed=2)
    args = (data, sampler, CohortSampler(C, m, seed=1))
    with CohortStream(*args, prefetch=True) as pre, \
            CohortStream(*args, prefetch=False) as sync:
        for _ in range(7):
            a, s = next(pre), next(sync)
            assert a.round == s.round
            assert np.array_equal(a.batch["x"], s.batch["x"])


# ---------------------------------------------------------------------------
# simulator fleet driver (core.algorithms.run_fleet_rounds)
# ---------------------------------------------------------------------------

def _logreg(m, seed=0):
    from repro.data.logreg import make_federated_logreg

    return make_federated_logreg(m=m, n_batches=4, batch=5, d=32, cond=50.0,
                                 seed=seed)


@pytest.mark.parametrize("name", ["q_rr", "diana", "diana_rr"])
def test_run_fleet_rounds_full_participation_matches_epoch_driver(name):
    """cohort == population, exact compression: n fleet rounds ARE one
    `_nonlocal_epoch` scan — params agree with `run_epochs` to float
    noise, and the store's shift tables equal FedState.shifts."""
    from repro.compression.ops import RandK
    from repro.core.algorithms import (
        ALGORITHMS, init_algorithm, make_epoch_fn, run_fleet_rounds)
    from repro.core.rules import get_rule
    from repro.data.pipeline import run_epochs

    prob = _logreg(m=6)
    loss = prob.loss_fn()
    params0 = {"w": jnp.zeros((prob.d,))}
    spec = ALGORITHMS[name]
    rule = get_rule(spec.shift_mode)
    sampler = ReshuffleSampler(prob.m, prob.n, mode="rr_once", seed=2)
    store = ClientStateStore.create(params0, prob.m, rule, n_slots=prob.n,
                                    shard_size=4)
    pf, info = run_fleet_rounds(
        name, loss, RandK(fraction=1.0), gamma=0.05, params=params0,
        data=prob.data, sampler=sampler, store=store,
        cohort_sampler=CohortSampler(prob.m, prob.m, seed=1),
        rounds=2 * prob.n, key=jax.random.PRNGKey(0))
    _, epoch = make_epoch_fn(name, loss, RandK(fraction=1.0), gamma=0.05)
    st = init_algorithm(spec, params0, prob.m, prob.n)
    st = run_epochs(epoch, st, prob.data, sampler, epochs=2,
                    key=jax.random.PRNGKey(0))
    np.testing.assert_allclose(np.asarray(pf["w"]),
                               np.asarray(st.params["w"]), atol=1e-6)
    if rule.has_shifts:
        got = store.gather(np.arange(prob.m))
        np.testing.assert_allclose(np.asarray(got["w"]),
                                   np.asarray(st.shifts["w"]), atol=1e-6)
    assert info["rounds"] == 2 * prob.n
    assert np.array_equal(store.cursor, np.full(prob.m, 2 * prob.n))


def test_run_fleet_rounds_partial_participation_converges():
    """DIANA with a 3-of-12 cohort on heterogeneous logreg: suboptimality
    drops by >10x, bits are charged per participation, and the store's
    cursors equal the closed-form cohort walk."""
    from repro.compression.ops import RandK
    from repro.core.algorithms import run_fleet_rounds
    from repro.core.rules import get_rule

    prob = _logreg(m=12, seed=1)
    params0 = {"w": jnp.zeros((prob.d,))}
    store = ClientStateStore.create(params0, 12, get_rule("single"),
                                    shard_size=5)
    cohorts = CohortSampler(12, 3, seed=7)
    sub0 = prob.suboptimality(params0["w"])
    p, info = run_fleet_rounds(
        "diana", prob.loss_fn(), RandK(fraction=0.5), gamma=0.05,
        params=params0, data=prob.data,
        sampler=ReshuffleSampler(12, 4, mode="rr", seed=3), store=store,
        cohort_sampler=cohorts, rounds=200, key=jax.random.PRNGKey(5))
    assert prob.suboptimality(p["w"]) < 0.1 * sub0
    assert np.array_equal(store.cursor, cohorts.participation_counts(200))
    assert store.bits.sum() == pytest.approx(info["bits"])
    # per-client accounting: bits proportional to participations
    assert np.array_equal(store.bits > 0, store.cursor > 0)


def test_run_fleet_rounds_rejects_local_family():
    from repro.core.algorithms import make_round_fn

    with pytest.raises(ValueError, match="local-family"):
        make_round_fn("q_nastya", lambda p, b: 0.0, gamma=0.1)


# ---------------------------------------------------------------------------
# production acceptance: cohort == population bit-matches the flat wire
# ---------------------------------------------------------------------------

def _tiny_cfg():
    from repro.configs import get_config, reduced

    cfg = reduced(get_config("stablelm-1.6b"), seq=8)
    return dataclasses.replace(cfg, dtype=jnp.float32)


def _fleet_setup(mesh, method, *, n=3):
    from repro.core.dist import CompressedAggregation
    from repro.launch import steps
    from repro.launch.mesh import num_clients

    cfg = _tiny_cfg()
    m = num_clients(mesh)
    slotted = method == "diana_rr"
    agg = CompressedAggregation(method=method, wire="shared", fraction=0.5,
                                n_slots=n if slotted else 1,
                                shift_dtype=jnp.float32)
    jitted, abstract, shardings, batch_sh = steps.make_train_step(
        cfg, mesh, agg=agg, lr=0.05, remat=False, seq_shard=False)
    return cfg, m, agg, jitted, abstract, shardings, batch_sh


def _population_tokens(cfg, C, n, b, seq, seed=0):
    from repro.data.tokens import synthetic_token_batches

    return {"tokens": np.asarray(synthetic_token_batches(
        vocab=cfg.vocab, seq_len=seq, batch=b, num_batches=n,
        num_clients=C, seed=seed))}


@needs_mesh
@pytest.mark.parametrize("method", ["diana", "diana_rr"])
@pytest.mark.parametrize("mesh_name", ["mesh_4x2", "mesh_2x2x2"])
def test_fleet_full_cohort_bit_matches_flat_wire(method, mesh_name, request):
    """THE acceptance criterion: with C == mesh clients and cohort-RR, the
    fleet path (host store + per-round gather/scatter through
    `with_cohort_shifts`) walks a bitwise-identical trajectory to today's
    full-participation loop — params AND shift tables — on the 1-pod and
    2-pod meshes, and charges exactly the static per-round uplink bits."""
    from repro.core.rules import WIRE_RULES
    from repro.data.pipeline import shared_slots_for_step
    from repro.launch import compat, steps

    mesh = request.getfixturevalue(mesh_name)
    n, b, seq, total = 3, 1, 8, 4
    cfg, m, agg, jitted, abstract, shardings, batch_sh = _fleet_setup(
        mesh, method, n=n)
    data = _population_tokens(cfg, m, n, b, seq)
    mode = "rr_shared" if method == "diana_rr" else "rr"
    key = jax.random.key(4)

    with compat.set_mesh(mesh):
        # A: today's full-participation pipeline-fed loop
        state = jax.device_put(
            steps.init_train_state(jax.random.key(0), cfg, agg, m,
                                   mesh=mesh), shardings)
        sampler = ReshuffleSampler(m, n, mode=mode, seed=1)
        with make_batch_stream(
                data, sampler,
                put=lambda bt: jax.device_put(bt, batch_sh(bt))) as stream:
            for t in range(total):
                if method == "diana_rr":
                    slots = jnp.asarray(shared_slots_for_step(
                        sampler, t, n_slots=agg.n_slots))
                    state, _ = jitted(state, next(stream), key, slots)
                else:
                    state, _ = jitted(state, next(stream), key)
        ref = jax.device_get(state)

        # B: the fleet path with cohort == population
        state2 = jax.device_put(
            steps.init_train_state(jax.random.key(0), cfg, agg, m,
                                   mesh=mesh), shardings)
        store = ClientStateStore.create(
            abstract.params, m, WIRE_RULES[method], n_slots=agg.n_slots,
            dtype=np.float32, shard_size=3)
        with FleetRunner(jitted, abstract, shardings, batch_sh, agg=agg,
                         mesh=mesh, data=data,
                         sampler=ReshuffleSampler(m, n, mode=mode, seed=1),
                         cohorts=CohortSampler(m, m, seed=9),
                         store=store) as runner:
            state2 = runner.run(state2, key, total)
            bits_per_client = runner.checkpoint_meta()[
                "bits_per_client_round"]
        flt = jax.device_get(state2)

    for (pa, a), (_, bb) in zip(
            jax.tree_util.tree_leaves_with_path(ref.params),
            jax.tree_util.tree_leaves_with_path(flt.params)):
        assert np.asarray(a).tobytes() == np.asarray(bb).tobytes(), pa
    got = store.gather(np.arange(m))
    for (pa, a), (_, bb) in zip(
            jax.tree_util.tree_leaves_with_path(ref.shifts),
            jax.tree_util.tree_leaves_with_path(got)):
        assert np.asarray(a).tobytes() == np.asarray(bb).tobytes(), pa
    assert bits_per_client > 0
    assert (store.bits == total * bits_per_client).all()
    assert (store.cursor == total).all()


@needs_mesh
def test_fleet_resume_determinism(mesh_4x2, tmp_path):
    """Fleet --resume: checkpoint (TrainState + store + fleet cursor) cut
    mid-fleet-epoch at round 3 of a C=10/m=4 walk, restore into a fresh
    store, continue — metrics, params, store shifts, cursors, and bit
    counters all bit-match the uninterrupted run."""
    from repro.checkpoint import (
        load_meta, restore_fleet_checkpoint, save_fleet_checkpoint)
    from repro.core.rules import WIRE_RULES
    from repro.launch import compat, steps

    mesh = mesh_4x2
    C, n, b, seq, total, cut = 10, 3, 1, 8, 6, 3
    cfg, m, agg, jitted, abstract, shardings, batch_sh = _fleet_setup(
        mesh, "diana", n=n)
    data = _population_tokens(cfg, C, n, b, seq)
    mk_store = lambda: ClientStateStore.create(
        abstract.params, C, WIRE_RULES["diana"], dtype=np.float32,
        shard_size=4)
    mk_runner = lambda start, store: FleetRunner(
        jitted, abstract, shardings, batch_sh, agg=agg, mesh=mesh,
        data=data, sampler=ReshuffleSampler(C, n, mode="rr", seed=1),
        cohorts=CohortSampler(C, m, seed=9), store=store, start_round=start)
    key = jax.random.key(4)
    path = str(tmp_path / "fleet.ckpt")

    with compat.set_mesh(mesh):
        state = jax.device_put(
            steps.init_train_state(jax.random.key(0), cfg, agg, m,
                                   mesh=mesh), shardings)
        store = mk_store()
        runner = mk_runner(0, store)
        losses_a = []

        def snap(t, st, metrics):
            losses_a.append(np.asarray(metrics["loss"]).tobytes())
            if t + 1 == cut:
                save_fleet_checkpoint(path, jax.device_get(st), store,
                                      step=t + 1,
                                      meta={"fleet":
                                            runner.checkpoint_meta()})

        with runner:
            state = runner.run(state, key, total, callback=snap)
        ref, ref_store = jax.device_get(state), store

        fm = load_meta(path)["meta"]["fleet"]
        assert fm["round"] == cut
        assert fm["epoch_position"] != 0, "cut must land mid-fleet-epoch"
        store_b = mk_store()
        state_b = restore_fleet_checkpoint(path, abstract, shardings,
                                           store_b)
        losses_b = []
        with mk_runner(fm["round"], store_b) as runner_b:
            state_b = runner_b.run(
                state_b, key, total - cut,
                callback=lambda t, st, mx: losses_b.append(
                    np.asarray(mx["loss"]).tobytes()))
        flt = jax.device_get(state_b)

    assert losses_b == losses_a[cut:]
    for (pa, a), (_, bb) in zip(
            jax.tree_util.tree_leaves_with_path(ref.params),
            jax.tree_util.tree_leaves_with_path(flt.params)):
        assert np.asarray(a).tobytes() == np.asarray(bb).tobytes(), pa
    everyone = np.arange(C)
    for (pa, a), (_, bb) in zip(
            jax.tree_util.tree_leaves_with_path(ref_store.gather(everyone)),
            jax.tree_util.tree_leaves_with_path(store_b.gather(everyone))):
        assert np.array_equal(a, bb), pa
    assert np.array_equal(ref_store.cursor, store_b.cursor)
    assert np.array_equal(ref_store.bits, store_b.bits)


@needs_mesh
def test_fleet_partial_participation_trains_and_isolates_state(mesh_4x2):
    """C=12 > m=4 on the production wire: the run trains (finite losses),
    only sampled clients' store rows move, device shift tables stay
    O(cohort), and a wrong-cursor store is rejected at resume."""
    from repro.launch import compat, steps

    mesh = mesh_4x2
    C, n, b, seq, total = 12, 3, 1, 8, 2  # 2 of 3 cohorts per fleet epoch
    cfg, m, agg, jitted, abstract, shardings, batch_sh = _fleet_setup(
        mesh, "diana", n=n)
    data = _population_tokens(cfg, C, n, b, seq)
    from repro.core.rules import WIRE_RULES

    store = ClientStateStore.create(abstract.params, C,
                                    WIRE_RULES["diana"], dtype=np.float32,
                                    shard_size=5)
    cohorts = CohortSampler(C, m, seed=3)
    sampler = ReshuffleSampler(C, n, mode="rr", seed=1)
    with compat.set_mesh(mesh):
        state = jax.device_put(
            steps.init_train_state(jax.random.key(0), cfg, agg, m,
                                   mesh=mesh), shardings)
        losses = []
        with FleetRunner(jitted, abstract, shardings, batch_sh, agg=agg,
                         mesh=mesh, data=data, sampler=sampler,
                         cohorts=cohorts, store=store) as runner:
            state = runner.run(
                state, jax.random.key(2), total,
                callback=lambda t, st, mx: losses.append(
                    float(mx["loss"])))
    assert np.isfinite(losses).all()
    sampled = np.unique(np.concatenate(
        [cohorts.cohort_for_round(r) for r in range(total)]))
    unsampled = np.setdiff1d(np.arange(C), sampled)
    assert unsampled.size, "C=12/m=4/2 rounds must leave clients unsampled"
    for leaf in jax.tree.leaves(store.gather(unsampled)):
        assert np.abs(leaf).max() == 0
    touched = store.gather(sampled)
    assert any(np.abs(l).max() > 0 for l in jax.tree.leaves(touched))
    assert np.array_equal(store.cursor > 0, np.isin(np.arange(C), sampled))
    # device shift tables are cohort-sized, not population-sized
    for leaf in jax.tree.leaves(abstract.shifts):
        assert leaf.shape[0] == m
    # a store whose cursors disagree with the walk is rejected at resume
    store.advance(np.array([0]), 1)
    with pytest.raises(ValueError, match="disagree with the cohort walk"):
        FleetRunner(jitted, abstract, shardings, batch_sh, agg=agg,
                    mesh=mesh, data=data, sampler=sampler, cohorts=cohorts,
                    store=store, start_round=total)


@needs_mesh
def test_fleet_slotted_gates(mesh_4x2):
    """diana_rr fleet configs that break the shared-slot contract are
    rejected up front: i.i.d. cohorts, a population not divisible by the
    cohort (straddling cohorts mix data positions), and non-shared
    sampler orders (DESIGN.md §3.9)."""
    from repro.core.rules import WIRE_RULES
    from repro.launch import compat

    mesh = mesh_4x2
    n = 3
    cfg, m, agg, jitted, abstract, shardings, batch_sh = _fleet_setup(
        mesh, "diana_rr", n=n)
    mk = lambda C, cmode, smode, ls=1: FleetRunner(
        jitted, abstract, shardings, batch_sh, agg=agg, mesh=mesh,
        data=_population_tokens(cfg, C, n, 1, 8),
        sampler=ReshuffleSampler(C, n, mode=smode, seed=1),
        cohorts=CohortSampler(C, m, mode=cmode, seed=2),
        store=ClientStateStore.create(abstract.params, C,
                                      WIRE_RULES["diana_rr"], n_slots=n,
                                      dtype=np.float32), local_steps=ls)
    with compat.set_mesh(mesh):
        with pytest.raises(ValueError, match="shared-slot"):
            mk(8, "with_replacement", "rr_shared")
        with pytest.raises(ValueError, match="divisible"):
            mk(10, "rr", "rr_shared")
        with pytest.raises(ValueError, match="rr_shared"):
            mk(8, "rr", "rr")
        # flat-mesh NASTYA: per-client shifts land in pod_shifts, which
        # the store does not round-trip — rejected before the slot gates
        with pytest.raises(ValueError, match="pod_shifts"):
            mk(8, "rr", "rr_shared", ls=2)
        runner = mk(8, "rr", "rr_shared")  # valid: 8 % 4 == 0
        runner.close()
