"""fleet/ — partial participation at population scale (DESIGN.md §3.9).

Covers the cohort-RR walk, the sharded client-state store's gather/scatter
contract (DIANA single shifts AND DIANA-RR slot tables), the per-cohort
stream view, the simulator fleet driver, and the production acceptance
criteria: a cohort == population cohort-RR fleet run bit-matches today's
full-participation wire trajectory (params, shift tables, bits) for
`diana` and `diana_rr` on the 1-pod and 2-pod meshes, and fleet `--resume`
is bit-deterministic.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import CohortStream, make_batch_stream
from repro.data.reshuffle import ReshuffleSampler
from repro.fleet import (AsyncFleetRunner, AsyncPlanner, ChaosConfig,
                         CohortSampler, ClientStateStore, FaultyStore,
                         FleetRunner, TransientStoreError)

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 8, reason="needs 8 forced host devices"
)


# ---------------------------------------------------------------------------
# CohortSampler: the client-level RR walk
# ---------------------------------------------------------------------------

def test_cohort_rr_visits_every_client_once_per_fleet_epoch():
    """C=10, m=4: cohorts straddle the fleet-epoch boundary mid-round
    (round 2 takes the last 2 clients of epoch 0 and the first 2 of epoch
    1), yet after any whole number of fleet epochs every client has
    participated exactly that many times."""
    cs = CohortSampler(10, 4, seed=3)
    counts = np.zeros(10, np.int64)
    for r in range(5):  # 5 rounds * 4 = 20 slots = exactly 2 fleet epochs
        cohort = cs.cohort_for_round(r)
        assert cohort.shape == (4,)
        assert (np.diff(cohort) > 0).all(), "sorted, distinct"
        counts[cohort] += 1
    assert (counts == 2).all(), counts
    # the straddling round really mixes two epochs' (effective) orders
    e0, e1 = cs.effective_order(0), cs.effective_order(1)
    straddle = cs.cohort_for_round(2)
    assert set(straddle) == set(e0[8:]) | set(e1[:2])
    # ... and each effective order is still a full permutation (exactly
    # once per epoch even with the head deconflicted against e0's tail)
    assert sorted(e1.tolist()) == list(range(10))
    # closed-form participation counts == replayed counts, mid-epoch too
    for r in range(6):
        replay = np.zeros(10, np.int64)
        for q in range(r):
            replay[cs.cohort_for_round(q)] += 1
        assert np.array_equal(cs.participation_counts(r), replay), r


def test_cohort_straddle_deconfliction():
    """Adjacent epochs' raw permutations are independent, so a straddling
    cohort could draw the same client from epoch e's tail and epoch e+1's
    head — ill-defined for the store scatter. Regression: seed 0 on
    (C=10, m=4) puts client 1 in both; the effective order moves it out of
    the straddling round's reach while keeping exactly-once coverage.
    Sweeps seeds/shapes, and checks cold-cache random access (a resumed
    run's first lookup) matches the sequential walk."""
    raw = CohortSampler(10, 4, seed=0)
    # round 2 takes epoch 0's last 2 slots + epoch 1's first 2: the raw
    # draws collide there (this is the seed the bug reproduced with)
    assert np.intersect1d(raw.epoch_order(0)[8:],
                          raw.epoch_order(1)[:2]).size > 0
    assert (np.diff(raw.cohort_for_round(2)) > 0).all()
    for seed in range(8):
        for C, m in ((10, 4), (7, 3), (13, 5), (9, 2)):
            cs = CohortSampler(C, m, seed=seed)
            rounds = [cs.cohort_for_round(r) for r in range(3 * C // m + 2)]
            for r, co in enumerate(rounds):
                assert (np.diff(co) > 0).all(), (seed, C, m, r, co)
            for e in range(3):
                assert sorted(cs.effective_order(e).tolist()) == \
                    list(range(C)), (seed, C, m, e)
            cold = CohortSampler(C, m, seed=seed)
            r = len(rounds) - 1
            assert np.array_equal(cold.cohort_for_round(r), rounds[r])


def test_cohort_sampler_idempotent_and_stateless():
    cs = CohortSampler(12, 4, seed=7)
    a = [cs.cohort_for_round(r) for r in range(4)]
    b = [CohortSampler(12, 4, seed=7).cohort_for_round(r) for r in range(4)]
    for x, y in zip(a, b):
        assert np.array_equal(x, y)
    assert cs.cursor(0) == (0, 0)
    assert cs.cursor(3) == (1, 0)
    assert cs.cursor(4) == (1, 4)


def test_with_replacement_mode_distinct_within_round():
    cs = CohortSampler(20, 6, mode="with_replacement", seed=1)
    seen = []
    for r in range(10):
        c = cs.cohort_for_round(r)
        assert (np.diff(c) > 0).all(), "distinct ids (scatter well-defined)"
        seen.append(tuple(c))
    assert len(set(seen)) > 1, "i.i.d. across rounds"
    assert np.array_equal(cs.cohort_for_round(3), seen[3])
    # replayed counts drive the resume path for the i.i.d. baseline
    replay = np.zeros(20, np.int64)
    for r in range(7):
        replay[cs.cohort_for_round(r)] += 1
    assert np.array_equal(cs.participation_counts(7), replay)


def test_cohort_sampler_validation():
    with pytest.raises(ValueError):
        CohortSampler(4, 8)
    with pytest.raises(ValueError):
        CohortSampler(4, 2, mode="bogus")


# ---------------------------------------------------------------------------
# ClientStateStore: sharded gather/scatter round-trip
# ---------------------------------------------------------------------------

def _params():
    return {"w": jnp.zeros((3, 5), jnp.float32), "b": jnp.zeros((4,))}


@pytest.mark.parametrize("rule_name,lead", [("single", ()), ("per_slot", (2,))])
def test_store_gather_scatter_roundtrip(rule_name, lead):
    """Gather -> mutate -> scatter -> re-gather is the identity on the
    cohort rows and a no-op on everyone else, across shard boundaries
    (shard_size=3 splits an 11-client population into 4 shards), for both
    the DIANA single-shift and the DIANA-RR slot-table layouts."""
    from repro.core.rules import get_rule

    store = ClientStateStore.create(_params(), 11, get_rule(rule_name),
                                    n_slots=2, shard_size=3)
    cohort = np.array([0, 2, 5, 10])  # hits shards 0, 0, 1, 3
    got = store.gather(cohort)
    assert got["w"].shape == (4,) + lead + (3, 5)
    assert got["b"].shape == (4,) + lead + (4,)
    rng = np.random.default_rng(0)
    upd = jax.tree.map(
        lambda x: rng.normal(size=x.shape).astype(np.float32), got)
    store.scatter(cohort, upd)
    back = store.gather(cohort)
    for k in upd:
        assert np.array_equal(back[k], upd[k]), k
    rest = np.array([1, 3, 4, 6, 7, 8, 9])
    for leaf in jax.tree.leaves(store.gather(rest)):
        assert np.abs(leaf).max() == 0, "untouched clients stay zero"
    # cursors + bit counters ride the same cohort addressing
    store.advance(cohort, 2)
    store.add_bits(cohort, 640.0)
    assert store.cursors(cohort).tolist() == [2] * 4
    assert store.cursors(rest).tolist() == [0] * 7
    assert store.bits[cohort].tolist() == [640.0] * 4


def test_store_memmap_backing(tmp_path):
    from repro.core.rules import get_rule

    store = ClientStateStore.create(_params(), 9, get_rule("single"),
                                    shard_size=4, path=str(tmp_path))
    assert store.num_shards == 3
    cohort = np.array([3, 4, 8])
    upd = store.gather(cohort)
    upd = jax.tree.map(lambda x: x + 1.25, upd)
    store.scatter(cohort, upd)
    got = store.gather(cohort)
    assert np.array_equal(got["w"], upd["w"])
    assert len(list(tmp_path.iterdir())) == 2 * 3  # 2 leaves x 3 shards


def test_store_unwritable_path_fails_readably(tmp_path):
    """--store-path pointing at a non-directory (or an unwritable mount)
    fails up front with an actionable message, not deep inside np.memmap."""
    from repro.core.rules import get_rule

    not_a_dir = tmp_path / "occupied"
    not_a_dir.write_bytes(b"x")
    with pytest.raises(OSError, match="not a writable directory"):
        ClientStateStore.create(_params(), 4, get_rule("single"),
                                path=str(not_a_dir))


def test_store_rejects_bad_cohorts():
    from repro.core.rules import get_rule

    store = ClientStateStore.create(_params(), 8, get_rule("single"),
                                    shard_size=4)
    with pytest.raises(ValueError, match="strictly increasing"):
        store.gather(np.array([2, 1]))
    with pytest.raises(ValueError, match="strictly increasing"):
        store.gather(np.array([1, 1, 2]))
    with pytest.raises(ValueError, match="outside"):
        store.gather(np.array([1, 8]))
    got = store.gather(np.array([0, 1]))
    with pytest.raises(ValueError, match="cohort slice"):
        store.scatter(np.array([0, 1, 2]), got)
    # regression: an UNSORTED cohort with out-of-range ids must get the
    # bounds error NAMING the bad ids, not a misleading sortedness
    # complaint (the old check looked only at cohort[0]/cohort[-1], which
    # both pass for e.g. [9, 2] — then blamed the ordering)
    with pytest.raises(ValueError, match=r"outside \[0, 8\): \[9\]"):
        store.gather(np.array([9, 2]))
    with pytest.raises(ValueError, match=r"\[-3, 11\]"):
        store.gather(np.array([-3, 11]))
    # many offenders: first 8 shown, the rest counted
    with pytest.raises(ValueError, match=r"\(\+2 more\)"):
        store.gather(np.arange(10) + 8)


# ---------------------------------------------------------------------------
# CohortStream: the per-cohort view of the population stream
# ---------------------------------------------------------------------------

def test_cohort_stream_full_participation_matches_batch_stream():
    """cohort == population under cohort-RR: every round samples every
    client in ascending order, so the emitted batches are bitwise the
    full-participation BatchStream's — the stream half of the fleet
    bit-match invariant. Runs across a data-epoch boundary."""
    m, n, b = 4, 3, 2
    data = {"x": np.arange(m * n * b * 5, dtype=np.float32).reshape(
        m, n, b, 5)}
    sampler = ReshuffleSampler(m, n, mode="rr", seed=1)
    with CohortStream(data, sampler, CohortSampler(m, m, seed=0),
                      local_steps=2) as cstream, \
            make_batch_stream(data, sampler, local_steps=2,
                              prefetch=False) as bstream:
        for t in range(2 * n):
            fr = next(cstream)
            assert fr.round == t
            assert np.array_equal(fr.cohort, np.arange(m))
            assert np.array_equal(fr.batch["x"], next(bstream)["x"]), t


def test_cohort_stream_partial_rows_follow_per_client_cursors():
    """Partial participation: a sampled client's rows come from ITS next RR
    position (clients advance only when sampled), modalities stay aligned,
    and a stream rebuilt at `start_round` replays identically."""
    C, n, b, m = 6, 3, 2, 2
    rng = np.random.default_rng(0)
    data = {"x": rng.normal(size=(C, n, b, 4)).astype(np.float32),
            "y": rng.normal(size=(C, n, b)).astype(np.float32)}
    sampler = ReshuffleSampler(C, n, mode="rr", seed=4)
    cohorts = CohortSampler(C, m, seed=9)
    counts = np.zeros(C, np.int64)
    rounds = []
    with CohortStream(data, sampler, cohorts, prefetch=False) as stream:
        for t in range(8):
            fr = next(stream)
            rounds.append(fr)
            for i, c in enumerate(fr.cohort):
                e, pos = divmod(counts[c], n)
                want = sampler.epoch_order(e)[c, pos]
                assert fr.cols[i, 0] == want, (t, c)
                assert np.array_equal(fr.batch["x"][i * b:(i + 1) * b],
                                      data["x"][c, want])
                assert np.array_equal(fr.batch["y"][i * b:(i + 1) * b],
                                      data["y"][c, want])
            counts[fr.cohort] += 1
    with CohortStream(data, sampler, cohorts, prefetch=False,
                      start_round=5) as resumed:
        for t in range(5, 8):
            fr = next(resumed)
            assert np.array_equal(fr.cohort, rounds[t].cohort)
            assert np.array_equal(fr.batch["x"], rounds[t].batch["x"]), t


def test_cohort_stream_prefetch_matches_sync():
    C, n, b, m = 5, 3, 1, 2
    data = {"x": np.arange(C * n * b * 2, dtype=np.float32).reshape(
        C, n, b, 2)}
    sampler = ReshuffleSampler(C, n, seed=2)
    args = (data, sampler, CohortSampler(C, m, seed=1))
    with CohortStream(*args, prefetch=True) as pre, \
            CohortStream(*args, prefetch=False) as sync:
        for _ in range(7):
            a, s = next(pre), next(sync)
            assert a.round == s.round
            assert np.array_equal(a.batch["x"], s.batch["x"])


# ---------------------------------------------------------------------------
# simulator fleet driver (core.algorithms.run_fleet_rounds)
# ---------------------------------------------------------------------------

def _logreg(m, seed=0):
    from repro.data.logreg import make_federated_logreg

    return make_federated_logreg(m=m, n_batches=4, batch=5, d=32, cond=50.0,
                                 seed=seed)


@pytest.mark.parametrize("name", ["q_rr", "diana", "diana_rr"])
def test_run_fleet_rounds_full_participation_matches_epoch_driver(name):
    """cohort == population, exact compression: n fleet rounds ARE one
    `_nonlocal_epoch` scan — params agree with `run_epochs` to float
    noise, and the store's shift tables equal FedState.shifts."""
    from repro.compression.ops import RandK
    from repro.core.algorithms import (
        ALGORITHMS, init_algorithm, make_epoch_fn, run_fleet_rounds)
    from repro.core.rules import get_rule
    from repro.data.pipeline import run_epochs

    prob = _logreg(m=6)
    loss = prob.loss_fn()
    params0 = {"w": jnp.zeros((prob.d,))}
    spec = ALGORITHMS[name]
    rule = get_rule(spec.shift_mode)
    sampler = ReshuffleSampler(prob.m, prob.n, mode="rr_once", seed=2)
    store = ClientStateStore.create(params0, prob.m, rule, n_slots=prob.n,
                                    shard_size=4)
    pf, info = run_fleet_rounds(
        name, loss, RandK(fraction=1.0), gamma=0.05, params=params0,
        data=prob.data, sampler=sampler, store=store,
        cohort_sampler=CohortSampler(prob.m, prob.m, seed=1),
        rounds=2 * prob.n, key=jax.random.PRNGKey(0))
    _, epoch = make_epoch_fn(name, loss, RandK(fraction=1.0), gamma=0.05)
    st = init_algorithm(spec, params0, prob.m, prob.n)
    st = run_epochs(epoch, st, prob.data, sampler, epochs=2,
                    key=jax.random.PRNGKey(0))
    np.testing.assert_allclose(np.asarray(pf["w"]),
                               np.asarray(st.params["w"]), atol=1e-6)
    if rule.has_shifts:
        got = store.gather(np.arange(prob.m))
        np.testing.assert_allclose(np.asarray(got["w"]),
                                   np.asarray(st.shifts["w"]), atol=1e-6)
    assert info["rounds"] == 2 * prob.n
    assert np.array_equal(store.cursor, np.full(prob.m, 2 * prob.n))


def test_run_fleet_rounds_partial_participation_converges():
    """DIANA with a 3-of-12 cohort on heterogeneous logreg: suboptimality
    drops by >10x, bits are charged per participation, and the store's
    cursors equal the closed-form cohort walk."""
    from repro.compression.ops import RandK
    from repro.core.algorithms import run_fleet_rounds
    from repro.core.rules import get_rule

    prob = _logreg(m=12, seed=1)
    params0 = {"w": jnp.zeros((prob.d,))}
    store = ClientStateStore.create(params0, 12, get_rule("single"),
                                    shard_size=5)
    cohorts = CohortSampler(12, 3, seed=7)
    sub0 = prob.suboptimality(params0["w"])
    p, info = run_fleet_rounds(
        "diana", prob.loss_fn(), RandK(fraction=0.5), gamma=0.05,
        params=params0, data=prob.data,
        sampler=ReshuffleSampler(12, 4, mode="rr", seed=3), store=store,
        cohort_sampler=cohorts, rounds=200, key=jax.random.PRNGKey(5))
    assert prob.suboptimality(p["w"]) < 0.1 * sub0
    assert np.array_equal(store.cursor, cohorts.participation_counts(200))
    assert store.bits.sum() == pytest.approx(info["bits"])
    # per-client accounting: bits proportional to participations
    assert np.array_equal(store.bits > 0, store.cursor > 0)


def test_run_fleet_rounds_rejects_local_family():
    from repro.core.algorithms import make_round_fn

    with pytest.raises(ValueError, match="local-family"):
        make_round_fn("q_nastya", lambda p, b: 0.0, gamma=0.1)


# ---------------------------------------------------------------------------
# production acceptance: cohort == population bit-matches the flat wire
# ---------------------------------------------------------------------------

def _tiny_cfg():
    from repro.configs import get_config, reduced

    cfg = reduced(get_config("stablelm-1.6b"), seq=8)
    return dataclasses.replace(cfg, dtype=jnp.float32)


def _fleet_setup(mesh, method, *, n=3, elastic=False, local_steps=1,
                 mean_scale=1.0):
    from repro.core.dist import CompressedAggregation
    from repro.launch import steps
    from repro.launch.mesh import num_clients

    cfg = _tiny_cfg()
    m = num_clients(mesh)
    slotted = method == "diana_rr"
    agg = CompressedAggregation(method=method, wire="shared", fraction=0.5,
                                n_slots=n if slotted else 1,
                                shift_dtype=jnp.float32,
                                mean_scale=mean_scale)
    jitted, abstract, shardings, batch_sh = steps.make_train_step(
        cfg, mesh, agg=agg, lr=0.05, remat=False, seq_shard=False,
        elastic=elastic, local_steps=local_steps)
    return cfg, m, agg, jitted, abstract, shardings, batch_sh


def _population_tokens(cfg, C, n, b, seq, seed=0):
    from repro.data.tokens import synthetic_token_batches

    return {"tokens": np.asarray(synthetic_token_batches(
        vocab=cfg.vocab, seq_len=seq, batch=b, num_batches=n,
        num_clients=C, seed=seed))}


@needs_mesh
@pytest.mark.parametrize("method", ["diana", "diana_rr"])
@pytest.mark.parametrize("mesh_name", ["mesh_4x2", "mesh_2x2x2"])
def test_fleet_full_cohort_bit_matches_flat_wire(method, mesh_name, request):
    """THE acceptance criterion: with C == mesh clients and cohort-RR, the
    fleet path (host store + per-round gather/scatter through
    `with_cohort_shifts`) walks a bitwise-identical trajectory to today's
    full-participation loop — params AND shift tables — on the 1-pod and
    2-pod meshes, and charges exactly the static per-round uplink bits."""
    from repro.core.rules import WIRE_RULES
    from repro.data.pipeline import shared_slots_for_step
    from repro.launch import compat, steps

    mesh = request.getfixturevalue(mesh_name)
    n, b, seq, total = 3, 1, 8, 4
    cfg, m, agg, jitted, abstract, shardings, batch_sh = _fleet_setup(
        mesh, method, n=n)
    data = _population_tokens(cfg, m, n, b, seq)
    mode = "rr_shared" if method == "diana_rr" else "rr"
    key = jax.random.key(4)

    with compat.set_mesh(mesh):
        # A: today's full-participation pipeline-fed loop
        state = jax.device_put(
            steps.init_train_state(jax.random.key(0), cfg, agg, m,
                                   mesh=mesh), shardings)
        sampler = ReshuffleSampler(m, n, mode=mode, seed=1)
        with make_batch_stream(
                data, sampler,
                put=lambda bt: jax.device_put(bt, batch_sh(bt))) as stream:
            for t in range(total):
                if method == "diana_rr":
                    slots = jnp.asarray(shared_slots_for_step(
                        sampler, t, n_slots=agg.n_slots))
                    state, _ = jitted(state, next(stream), key, slots)
                else:
                    state, _ = jitted(state, next(stream), key)
        ref = jax.device_get(state)

        # B: the fleet path with cohort == population
        state2 = jax.device_put(
            steps.init_train_state(jax.random.key(0), cfg, agg, m,
                                   mesh=mesh), shardings)
        store = ClientStateStore.create(
            abstract.params, m, WIRE_RULES[method], n_slots=agg.n_slots,
            dtype=np.float32, shard_size=3)
        with FleetRunner(jitted, abstract, shardings, batch_sh, agg=agg,
                         mesh=mesh, data=data,
                         sampler=ReshuffleSampler(m, n, mode=mode, seed=1),
                         cohorts=CohortSampler(m, m, seed=9),
                         store=store) as runner:
            state2 = runner.run(state2, key, total)
            bits_per_client = runner.checkpoint_meta()[
                "bits_per_client_round"]
        flt = jax.device_get(state2)

    for (pa, a), (_, bb) in zip(
            jax.tree_util.tree_leaves_with_path(ref.params),
            jax.tree_util.tree_leaves_with_path(flt.params)):
        assert np.asarray(a).tobytes() == np.asarray(bb).tobytes(), pa
    got = store.gather(np.arange(m))
    for (pa, a), (_, bb) in zip(
            jax.tree_util.tree_leaves_with_path(ref.shifts),
            jax.tree_util.tree_leaves_with_path(got)):
        assert np.asarray(a).tobytes() == np.asarray(bb).tobytes(), pa
    assert bits_per_client > 0
    assert (store.bits == total * bits_per_client).all()
    assert (store.cursor == total).all()


@needs_mesh
def test_fleet_resume_determinism(mesh_4x2, tmp_path):
    """Fleet --resume: checkpoint (TrainState + store + fleet cursor) cut
    mid-fleet-epoch at round 3 of a C=10/m=4 walk, restore into a fresh
    store, continue — metrics, params, store shifts, cursors, and bit
    counters all bit-match the uninterrupted run."""
    from repro.checkpoint import (
        load_meta, restore_fleet_checkpoint, save_fleet_checkpoint)
    from repro.core.rules import WIRE_RULES
    from repro.launch import compat, steps

    mesh = mesh_4x2
    C, n, b, seq, total, cut = 10, 3, 1, 8, 6, 3
    cfg, m, agg, jitted, abstract, shardings, batch_sh = _fleet_setup(
        mesh, "diana", n=n)
    data = _population_tokens(cfg, C, n, b, seq)
    mk_store = lambda: ClientStateStore.create(
        abstract.params, C, WIRE_RULES["diana"], dtype=np.float32,
        shard_size=4)
    mk_runner = lambda start, store: FleetRunner(
        jitted, abstract, shardings, batch_sh, agg=agg, mesh=mesh,
        data=data, sampler=ReshuffleSampler(C, n, mode="rr", seed=1),
        cohorts=CohortSampler(C, m, seed=9), store=store, start_round=start)
    key = jax.random.key(4)
    path = str(tmp_path / "fleet.ckpt")

    with compat.set_mesh(mesh):
        state = jax.device_put(
            steps.init_train_state(jax.random.key(0), cfg, agg, m,
                                   mesh=mesh), shardings)
        store = mk_store()
        runner = mk_runner(0, store)
        losses_a = []

        def snap(t, st, metrics):
            losses_a.append(np.asarray(metrics["loss"]).tobytes())
            if t + 1 == cut:
                save_fleet_checkpoint(path, jax.device_get(st), store,
                                      step=t + 1,
                                      meta={"fleet":
                                            runner.checkpoint_meta()})

        with runner:
            state = runner.run(state, key, total, callback=snap)
        ref, ref_store = jax.device_get(state), store

        fm = load_meta(path)["meta"]["fleet"]
        assert fm["round"] == cut
        assert fm["epoch_position"] != 0, "cut must land mid-fleet-epoch"
        store_b = mk_store()
        state_b = restore_fleet_checkpoint(path, abstract, shardings,
                                           store_b)
        losses_b = []
        with mk_runner(fm["round"], store_b) as runner_b:
            state_b = runner_b.run(
                state_b, key, total - cut,
                callback=lambda t, st, mx: losses_b.append(
                    np.asarray(mx["loss"]).tobytes()))
        flt = jax.device_get(state_b)

    assert losses_b == losses_a[cut:]
    for (pa, a), (_, bb) in zip(
            jax.tree_util.tree_leaves_with_path(ref.params),
            jax.tree_util.tree_leaves_with_path(flt.params)):
        assert np.asarray(a).tobytes() == np.asarray(bb).tobytes(), pa
    everyone = np.arange(C)
    for (pa, a), (_, bb) in zip(
            jax.tree_util.tree_leaves_with_path(ref_store.gather(everyone)),
            jax.tree_util.tree_leaves_with_path(store_b.gather(everyone))):
        assert np.array_equal(a, bb), pa
    assert np.array_equal(ref_store.cursor, store_b.cursor)
    assert np.array_equal(ref_store.bits, store_b.bits)


@needs_mesh
def test_fleet_partial_participation_trains_and_isolates_state(mesh_4x2):
    """C=12 > m=4 on the production wire: the run trains (finite losses),
    only sampled clients' store rows move, device shift tables stay
    O(cohort), and a wrong-cursor store is rejected at resume."""
    from repro.launch import compat, steps

    mesh = mesh_4x2
    C, n, b, seq, total = 12, 3, 1, 8, 2  # 2 of 3 cohorts per fleet epoch
    cfg, m, agg, jitted, abstract, shardings, batch_sh = _fleet_setup(
        mesh, "diana", n=n)
    data = _population_tokens(cfg, C, n, b, seq)
    from repro.core.rules import WIRE_RULES

    store = ClientStateStore.create(abstract.params, C,
                                    WIRE_RULES["diana"], dtype=np.float32,
                                    shard_size=5)
    cohorts = CohortSampler(C, m, seed=3)
    sampler = ReshuffleSampler(C, n, mode="rr", seed=1)
    with compat.set_mesh(mesh):
        state = jax.device_put(
            steps.init_train_state(jax.random.key(0), cfg, agg, m,
                                   mesh=mesh), shardings)
        losses = []
        with FleetRunner(jitted, abstract, shardings, batch_sh, agg=agg,
                         mesh=mesh, data=data, sampler=sampler,
                         cohorts=cohorts, store=store) as runner:
            state = runner.run(
                state, jax.random.key(2), total,
                callback=lambda t, st, mx: losses.append(
                    float(mx["loss"])))
    assert np.isfinite(losses).all()
    sampled = np.unique(np.concatenate(
        [cohorts.cohort_for_round(r) for r in range(total)]))
    unsampled = np.setdiff1d(np.arange(C), sampled)
    assert unsampled.size, "C=12/m=4/2 rounds must leave clients unsampled"
    for leaf in jax.tree.leaves(store.gather(unsampled)):
        assert np.abs(leaf).max() == 0
    touched = store.gather(sampled)
    assert any(np.abs(l).max() > 0 for l in jax.tree.leaves(touched))
    assert np.array_equal(store.cursor > 0, np.isin(np.arange(C), sampled))
    # device shift tables are cohort-sized, not population-sized
    for leaf in jax.tree.leaves(abstract.shifts):
        assert leaf.shape[0] == m
    # a store whose cursors disagree with the walk is rejected at resume,
    # and the error names the offending client ids (satellite: debuggable
    # cursor mismatches)
    store.advance(np.array([0]), 1)
    with pytest.raises(ValueError,
                       match=r"disagree with the cohort walk at round 2 "
                             r"for client ids \[0\]"):
        FleetRunner(jitted, abstract, shardings, batch_sh, agg=agg,
                    mesh=mesh, data=data, sampler=sampler, cohorts=cohorts,
                    store=store, start_round=total)


@needs_mesh
def test_fleet_slotted_gates(mesh_4x2):
    """diana_rr fleet configs that break the shared-slot contract are
    rejected up front: i.i.d. cohorts, a population not divisible by the
    cohort (straddling cohorts mix data positions), and non-shared
    sampler orders (DESIGN.md §3.9)."""
    from repro.core.rules import WIRE_RULES
    from repro.launch import compat

    mesh = mesh_4x2
    n = 3
    cfg, m, agg, jitted, abstract, shardings, batch_sh = _fleet_setup(
        mesh, "diana_rr", n=n)
    mk = lambda C, cmode, smode, ls=1: FleetRunner(
        jitted, abstract, shardings, batch_sh, agg=agg, mesh=mesh,
        data=_population_tokens(cfg, C, n, 1, 8),
        sampler=ReshuffleSampler(C, n, mode=smode, seed=1),
        cohorts=CohortSampler(C, m, mode=cmode, seed=2),
        store=ClientStateStore.create(abstract.params, C,
                                      WIRE_RULES["diana_rr"], n_slots=n,
                                      dtype=np.float32), local_steps=ls)
    with compat.set_mesh(mesh):
        with pytest.raises(ValueError, match="shared-slot"):
            mk(8, "with_replacement", "rr_shared")
        with pytest.raises(ValueError, match="divisible"):
            mk(10, "rr", "rr_shared")
        with pytest.raises(ValueError, match="rr_shared"):
            mk(8, "rr", "rr")
        # flat-mesh NASTYA collapses the outer slot tables to one row
        # (the inter-pod wire carries the slot-free epoch gradient), so a
        # 3-slot store no longer matches the wire's table layout
        with pytest.raises(ValueError, match="store n_slots=3"):
            mk(8, "rr", "rr_shared", ls=2)
        runner = mk(8, "rr", "rr_shared")  # valid: 8 % 4 == 0
        runner.close()


# ---------------------------------------------------------------------------
# chaos: deterministic fault injection + buffered-async round planning
# ---------------------------------------------------------------------------

def test_async_planner_clean_run_is_exactly_synchronous():
    """No chaos, buffer_k == m: everyone on time, weight EXACTLY 1.0 per
    rank (the elastic step's bitwise no-op), everyone completes/reports —
    and the plan is a pure function of (seed, round)."""
    p = AsyncPlanner(6)
    cohort = np.arange(6)
    for rnd in range(4):
        plan = p(rnd, cohort)
        assert (plan.weights == np.float32(1.0)).all()
        assert plan.completes.all() and plan.reported.all()
        assert np.isfinite(plan.deadline)
    q = AsyncPlanner(6)
    for rnd in range(4):
        a, b = p(rnd, cohort), q(rnd, cohort)
        assert np.array_equal(a.weights, b.weights)
        assert np.array_equal(a.completes, b.completes)
        assert np.array_equal(a.latency, b.latency)


def test_async_planner_k_of_m_late_policies():
    """buffer_k=2 of m=4 with stragglers: under 'drop' the late reports
    get weight 0 and never complete (but still burn uplink bits — reported
    stays True); under 'discount' everyone alive completes with a
    staleness-damped weight; both normalize so sum(weights) == m."""
    m = 4
    chaos = ChaosConfig(straggler=0.5, delay=2.0, seed=7)
    cohort = np.arange(m)
    drop = AsyncPlanner(m, buffer_k=2, late="drop", chaos=chaos)
    disc = AsyncPlanner(m, buffer_k=2, late="discount", discount=0.5,
                        chaos=chaos)
    saw_dropped_late = False
    for rnd in range(12):
        pd, pc = drop(rnd, cohort), disc(rnd, cohort)
        # same latency stream (same chaos seed), different fold-in policy
        assert np.array_equal(pd.latency, pc.latency)
        assert pd.deadline == pc.deadline
        assert np.array_equal(pd.completes, pd.weights > 0)
        assert pd.reported.all(), "no dropout: everyone transmits"
        assert pc.completes.all(), "discount folds every alive report in"
        saw_dropped_late |= bool((pd.reported & ~pd.completes).any())
        np.testing.assert_allclose(pd.weights.sum(), m, rtol=1e-6)
        np.testing.assert_allclose(pc.weights.sum(), m, rtol=1e-6)
        on_time = pc.latency <= pc.deadline
        assert (pc.weights[on_time] >= pc.weights.max() - 1e-6).all()
        late = pc.completes & ~on_time
        if late.any():
            assert (pc.weights[late] < pc.weights[on_time].min()).all(), \
                "stale reports fold in at a strictly smaller weight"
    assert saw_dropped_late, "12 rounds at straggler=0.5 must drop someone"


def test_async_planner_elastic_resize_pads_with_zero_weight():
    """resize(r)=2 on an m=4 step: ranks past the active count are padding
    — weight 0, never reported (no bits), never complete (no cursor
    advance), latency inf — so the compiled shape never changes."""
    p = AsyncPlanner(4, chaos=ChaosConfig(seed=1),
                     resize=lambda r: 2 if r % 2 == 0 else 4)
    plan = p(0, np.arange(4))
    assert (plan.weights[2:] == 0).all()
    assert not plan.reported[2:].any() and not plan.completes[2:].any()
    assert np.isinf(plan.latency[2:]).all()
    assert plan.completes[:2].all()
    np.testing.assert_allclose(plan.weights.sum(), 4, rtol=1e-6)
    grown = p(1, np.arange(4))
    assert grown.completes.all(), "odd rounds run the full cohort again"
    with pytest.raises(ValueError, match="outside"):
        AsyncPlanner(4, resize=lambda r: 0)(0, np.arange(4))


def test_async_planner_zero_alive_round():
    """dropout can darken the whole cohort: the plan reports an empty
    round (deadline inf, no weights) instead of dividing by zero — the
    driver skips the jitted launch entirely."""
    p = AsyncPlanner(4, chaos=ChaosConfig(dropout=0.9, seed=0))
    cohort = np.arange(4)
    rnd = next(r for r in range(64) if not p(r, cohort).reported.any())
    plan = p(rnd, cohort)
    assert plan.deadline == np.inf
    assert (plan.weights == 0).all() and not plan.completes.any()


def test_async_planner_may_defer_matrix_and_validation():
    """`may_defer` is the slotted-methods gate: anything that can finish a
    round without advancing a client's cursor trips it."""
    assert not AsyncPlanner(4).may_defer
    assert not AsyncPlanner(
        4, buffer_k=2, chaos=ChaosConfig(straggler=0.5)).may_defer
    assert AsyncPlanner(4, late="drop").may_defer
    assert AsyncPlanner(4, chaos=ChaosConfig(dropout=0.1)).may_defer
    assert AsyncPlanner(4, resize=lambda r: 4).may_defer
    with pytest.raises(ValueError, match="buffer_k"):
        AsyncPlanner(4, buffer_k=0)
    with pytest.raises(ValueError, match="buffer_k"):
        AsyncPlanner(4, buffer_k=5)
    with pytest.raises(ValueError, match="late"):
        AsyncPlanner(4, late="bogus")
    with pytest.raises(ValueError, match="discount"):
        AsyncPlanner(4, discount=0.0)
    with pytest.raises(ValueError, match="dropout"):
        ChaosConfig(dropout=1.0)
    with pytest.raises(ValueError, match="delay"):
        ChaosConfig(delay=-0.5)


def test_async_planner_on_time_metric_regression():
    """`on_time` must come from the plan (`alive & (latency <= deadline)`),
    NOT from thresholding the normalized weights: the m/sum(w) rescale
    exceeds 1.0 whenever any client is late or dark, so at late='discount'
    with discount=1.0 a small-staleness late report's weight crosses 1.0
    and the weight-threshold count claims a LATE client was on time."""
    m = 4
    planner = AsyncPlanner(
        m, buffer_k=2, late="discount", discount=1.0,
        chaos=ChaosConfig(dropout=0.3, straggler=0.5, delay=0.2, seed=7))
    cohort = np.arange(m)
    miscounted = []
    for r in range(100):
        plan = planner(r, cohort)
        # the plan's on_time is definitionally alive-and-within-deadline
        assert np.array_equal(plan.on_time,
                              ~np.isinf(plan.latency)
                              & (plan.latency <= plan.deadline))
        if int((plan.weights >= 1.0).sum()) != int(plan.on_time.sum()):
            miscounted.append(r)
            # every miscount is a LATE/dark-rescaled weight >= 1, never a
            # missing on-time client
            assert ((plan.weights >= 1.0) & ~plan.on_time).any()
    assert 4 in miscounted, "seed 7 round 4 is the pinned repro"
    assert len(miscounted) > 10, "the miscount is systematic, not a fluke"
    # clean synchronous round: all weights exactly 1.0 AND all on time —
    # the two counts agree, which is why the bug stayed invisible
    clean = AsyncPlanner(m)(0, cohort)
    assert clean.on_time.all() and (clean.weights == 1.0).all()
    # zero-alive rounds report nobody on time
    dead = AsyncPlanner(
        m, chaos=ChaosConfig(dropout=0.99, seed=1))
    for r in range(200):
        plan = dead(r, cohort)
        if not (~np.isinf(plan.latency)).any():
            assert not plan.on_time.any()
            break
    else:
        pytest.fail("dropout=0.99 over 200 rounds must kill one round")


def test_faulty_store_injects_cursor_and_bit_writes():
    """Chaos store-fail coverage includes `advance`/`add_bits` (the cursor
    and bit writes), not just gather/scatter: they draw from the SAME
    (seed, call-index) stream, injection happens BEFORE the op (a failed
    advance leaves cursors untouched), and `touch`/`as_tree` still
    delegate uninjected (prefetch warming and checkpoint reads must not
    perturb the I/O schedule)."""
    from repro.core.rules import get_rule

    store = ClientStateStore.create(_params(), 6, get_rule("single"),
                                    shard_size=3)
    chaos = ChaosConfig(store_fail=0.5, seed=3)
    cohort = np.array([0, 1])

    def pattern(fs, op, ops=30):
        out = []
        for _ in range(ops):
            try:
                op(fs)
                out.append(False)
            except TransientStoreError:
                out.append(True)
        return out

    pat_adv = pattern(FaultyStore(store, chaos), lambda fs: fs.advance(cohort, 1))
    assert any(pat_adv) and not all(pat_adv)
    # same call-index stream: add_bits at the same indices fails identically
    assert pattern(FaultyStore(store, chaos),
                   lambda fs: fs.add_bits(cohort, 8.0)) == pat_adv
    # inject-before-op atomicity: a failing advance never moved the cursor
    store.cursor[...] = 0
    store.bits[...] = 0.0
    fs = FaultyStore(store, chaos)
    applied = 0
    for _ in range(30):
        try:
            fs.advance(cohort, 1)
            applied += 1
        except TransientStoreError:
            assert store.cursor[cohort].min() == applied, \
                "a failed advance must not move the cursor"
    assert (store.cursor[cohort] == applied).all()
    # the fresh wrapper replays the same schedule: failures line up
    assert 30 - applied == sum(pat_adv)
    # uninjected delegation: warming + checkpoint reads never fault and
    # never consume a call index
    before = fs.injected_failures
    for _ in range(50):
        fs.touch(cohort)
        fs.as_tree()
    assert fs.injected_failures == before


def test_faulty_store_deterministic_and_atomic():
    """Injected store failures are a pure function of (seed, call index):
    a replay reproduces the exact failure schedule. Injection happens
    BEFORE the underlying op, so a failed scatter leaves the store
    untouched and the retry cannot double-apply."""
    from repro.core.rules import get_rule

    store = ClientStateStore.create(_params(), 6, get_rule("single"),
                                    shard_size=3)
    chaos = ChaosConfig(store_fail=0.5, seed=3)
    cohort = np.array([0, 1])

    def pattern(fs, ops=30):
        out = []
        for _ in range(ops):
            try:
                fs.gather(cohort)
                out.append(False)
            except TransientStoreError:
                out.append(True)
        return out

    fs = FaultyStore(store, chaos)
    pat = pattern(fs)
    assert any(pat) and not all(pat), "store_fail=0.5 over 30 calls"
    assert fs.injected_failures == sum(pat)
    assert pattern(FaultyStore(store, chaos)) == pat, "same seed, same faults"
    # atomicity: keep fs's call index rolling past the gather probes
    before = store.gather(cohort)
    upd = jax.tree.map(lambda x: x + 1.0, before)
    applied = False
    for _ in range(10):
        try:
            fs.scatter(cohort, upd)
            applied = True
            break
        except TransientStoreError:
            for k in before:
                assert np.array_equal(store.gather(cohort)[k], before[k]), \
                    "a failed scatter must not touch the store"
    assert applied, "bounded retries must eventually land at fail=0.5"
    for k in upd:
        assert np.array_equal(store.gather(cohort)[k], upd[k])
    # everything but gather/scatter delegates to the wrapped store
    assert fs.population == 6
    assert np.array_equal(fs.cursor, store.cursor)


def test_async_stream_exactly_once_rr_under_dropout():
    """THE exactly-once acceptance criterion, host-side: with seeded
    dropout + stragglers and late='drop', a client's cursor advances ONLY
    when its report completes — so a dropped client re-reads the SAME RR
    position next time it is sampled, every consumed position is the
    contiguous walk of its own epoch permutations, and every completed
    data epoch is a full permutation (>= 3 epochs per client). A stream
    rebuilt at `start_round` replays the planner over the skipped prefix
    and lands on identical cursors/batches."""
    C, n, b, m, total, restart = 8, 3, 1, 4, 48, 31
    rng = np.random.default_rng(0)
    data = {"x": rng.normal(size=(C, n, b, 2)).astype(np.float32)}
    sampler = ReshuffleSampler(C, n, mode="rr", seed=1)
    cohorts = CohortSampler(C, m, seed=2)
    planner = AsyncPlanner(m, buffer_k=3, late="drop",
                           chaos=ChaosConfig(dropout=0.25, straggler=0.3,
                                             delay=1.0, seed=13))
    counts = np.zeros(C, np.int64)
    consumed = [[] for _ in range(C)]
    deferrals = 0
    tail = []
    with CohortStream(data, sampler, cohorts, prefetch=False,
                      planner=planner) as stream:
        for t in range(total):
            fr = next(stream)
            assert fr.plan is not None
            for i, c in enumerate(fr.cohort):
                e, pos = divmod(counts[c], n)
                want = sampler.epoch_order(e)[c, pos]
                # sampled clients always read from their OWN cursor —
                # including clients about to be dropped, who will re-read
                # this very position next time
                assert fr.cols[i, 0] == want, (t, c)
                assert np.array_equal(fr.batch["x"][i * b:(i + 1) * b],
                                      data["x"][c, want])
                if fr.plan.completes[i]:
                    consumed[c].append(int(want))
            deferrals += int((~fr.plan.completes).sum())
            counts[fr.cohort[fr.plan.completes]] += 1
            if t >= restart:
                tail.append((fr.cohort.copy(), fr.batch["x"].copy()))
    assert deferrals > 0, "chaos at these rates must defer someone"
    assert counts.min() >= 3 * n, \
        f"every client needs >= 3 completed epochs, got {counts}"
    for c in range(C):
        assert len(consumed[c]) == counts[c]
        for e in range(counts[c] // n):  # every COMPLETED epoch
            assert sorted(consumed[c][e * n:(e + 1) * n]) == list(range(n)), \
                (c, e, consumed[c])
    # resume: replaying the planner over [0, restart) lands mid-chaos
    with CohortStream(data, sampler, cohorts, prefetch=False,
                      planner=planner, start_round=restart) as resumed:
        for cohort, x in tail:
            fr = next(resumed)
            assert np.array_equal(fr.cohort, cohort)
            assert np.array_equal(fr.batch["x"], x)


# ---------------------------------------------------------------------------
# production acceptance: buffered-async fleet on the compiled elastic step
# ---------------------------------------------------------------------------

@needs_mesh
@pytest.mark.parametrize("method", ["diana", "diana_rr"])
def test_async_clean_run_bit_matches_sync_fleet(method, mesh_4x2):
    """Chaos off + buffer_k == cohort size: the AsyncFleetRunner on the
    ELASTIC compiled step (weights vector all-1.0) walks a bitwise
    identical trajectory to the synchronous FleetRunner on the non-elastic
    step — params, store shift tables, bits, cursors — for both the
    single-shift and the per-slot wire."""
    from repro.core.rules import WIRE_RULES
    from repro.launch import compat, steps

    mesh = mesh_4x2
    n, b, seq, total = 3, 1, 8, 4
    mode = "rr_shared" if method == "diana_rr" else "rr"
    key = jax.random.key(4)

    def run(async_mode):
        cfg, m, agg, jitted, abstract, shardings, batch_sh = _fleet_setup(
            mesh, method, n=n, elastic=async_mode)
        data = _population_tokens(cfg, m, n, b, seq)
        store = ClientStateStore.create(
            abstract.params, m, WIRE_RULES[method], n_slots=agg.n_slots,
            dtype=np.float32, shard_size=3)
        cls = AsyncFleetRunner if async_mode else FleetRunner
        with compat.set_mesh(mesh):
            state = jax.device_put(
                steps.init_train_state(jax.random.key(0), cfg, agg, m,
                                       mesh=mesh), shardings)
            with cls(jitted, abstract, shardings, batch_sh, agg=agg,
                     mesh=mesh, data=data,
                     sampler=ReshuffleSampler(m, n, mode=mode, seed=1),
                     cohorts=CohortSampler(m, m, seed=9),
                     store=store) as runner:
                state = runner.run(state, key, total)
        return jax.device_get(state), store

    ref, ref_store = run(False)
    got, got_store = run(True)
    for (pa, a), (_, bb) in zip(
            jax.tree_util.tree_leaves_with_path(ref.params),
            jax.tree_util.tree_leaves_with_path(got.params)):
        assert np.asarray(a).tobytes() == np.asarray(bb).tobytes(), pa
    everyone = np.arange(ref_store.population)
    for (pa, a), (_, bb) in zip(
            jax.tree_util.tree_leaves_with_path(ref_store.gather(everyone)),
            jax.tree_util.tree_leaves_with_path(got_store.gather(everyone))):
        assert np.asarray(a).tobytes() == np.asarray(bb).tobytes(), pa
    assert np.array_equal(ref_store.bits, got_store.bits)
    assert np.array_equal(ref_store.cursor, got_store.cursor)


@needs_mesh
def test_async_fleet_resume_under_chaos_bit_exact(mesh_4x2, tmp_path):
    """Mid-walk fleet checkpoint UNDER chaos (dropout + stragglers +
    injected store failures with bounded retry) resumes bit-exactly: the
    rebuilt stream replays the planner over the skipped rounds, the
    FaultyStore wrapper re-arms, and metrics/params/store all match the
    uninterrupted run."""
    from repro.checkpoint import (
        load_meta, restore_fleet_checkpoint, save_fleet_checkpoint)
    from repro.core.rules import WIRE_RULES
    from repro.launch import compat, steps

    mesh = mesh_4x2
    C, n, b, seq, total, cut = 8, 3, 1, 8, 6, 3
    cfg, m, agg, jitted, abstract, shardings, batch_sh = _fleet_setup(
        mesh, "diana", n=n, elastic=True)
    data = _population_tokens(cfg, C, n, b, seq)
    chaos = ChaosConfig(dropout=0.2, straggler=0.4, delay=1.0,
                        store_fail=0.3, max_retries=3, seed=5)
    mk_store = lambda: ClientStateStore.create(
        abstract.params, C, WIRE_RULES["diana"], dtype=np.float32,
        shard_size=3)
    mk_runner = lambda start, store: AsyncFleetRunner(
        jitted, abstract, shardings, batch_sh, agg=agg, mesh=mesh,
        data=data, sampler=ReshuffleSampler(C, n, mode="rr", seed=1),
        cohorts=CohortSampler(C, m, seed=9), store=store, buffer_k=3,
        late="drop", chaos=chaos, start_round=start)
    key = jax.random.key(4)
    path = str(tmp_path / "fleet_async.ckpt")
    trace = lambda mx: (b"skip" if mx.get("skipped")
                        else np.asarray(mx["loss"]).tobytes())

    with compat.set_mesh(mesh):
        state = jax.device_put(
            steps.init_train_state(jax.random.key(0), cfg, agg, m,
                                   mesh=mesh), shardings)
        store = mk_store()
        runner = mk_runner(0, store)
        losses_a = []
        on_time_a = {}

        def snap(t, st, metrics):
            losses_a.append(trace(metrics))
            if "on_time" in metrics:
                on_time_a[t] = metrics["on_time"]
            if t + 1 == cut:
                save_fleet_checkpoint(path, jax.device_get(st), store,
                                      step=t + 1,
                                      meta={"fleet":
                                            runner.checkpoint_meta()})

        with runner:
            state = runner.run(state, key, total, callback=snap)
        ref, ref_store = jax.device_get(state), store

        fm = load_meta(path)["meta"]["fleet"]
        assert fm["round"] == cut
        assert fm["async"]["chaos"]["dropout"] == 0.2
        store_b = mk_store()
        state_b = restore_fleet_checkpoint(path, abstract, shardings,
                                           store_b)
        losses_b = []
        with mk_runner(fm["round"], store_b) as runner_b:
            state_b = runner_b.run(
                state_b, key, total - cut,
                callback=lambda t, st, mx: losses_b.append(trace(mx)))
        flt = jax.device_get(state_b)

    assert losses_b == losses_a[cut:]
    for (pa, a), (_, bb) in zip(
            jax.tree_util.tree_leaves_with_path(ref.params),
            jax.tree_util.tree_leaves_with_path(flt.params)):
        assert np.asarray(a).tobytes() == np.asarray(bb).tobytes(), pa
    everyone = np.arange(C)
    for (pa, a), (_, bb) in zip(
            jax.tree_util.tree_leaves_with_path(ref_store.gather(everyone)),
            jax.tree_util.tree_leaves_with_path(store_b.gather(everyone))):
        assert np.array_equal(a, bb), pa
    assert np.array_equal(ref_store.cursor, store_b.cursor)
    assert np.array_equal(ref_store.bits, store_b.bits)
    # under drop + dropout some clients must sit below the full walk
    assert ref_store.cursor.sum() < \
        CohortSampler(C, m, seed=9).participation_counts(total).sum()
    # with advance/add_bits inside the injected+retried I/O set, the
    # chaos run's cursors must STILL equal the closed-form planner replay
    # of the walk — an injected-but-unretried cursor write would drift
    cohorts = CohortSampler(C, m, seed=9)
    planner = AsyncPlanner(m, buffer_k=3, late="drop", chaos=chaos)
    replay = np.zeros(C, np.int64)
    for t in range(total):
        cohort = cohorts.cohort_for_round(t)
        plan = planner(t, cohort)
        replay[cohort[plan.completes]] += 1
        if t in on_time_a:
            # driver metric == plan truth (the weight-threshold count
            # overstated it whenever a late weight rescaled past 1.0)
            assert on_time_a[t] == int(plan.on_time.sum()), t
    assert np.array_equal(ref_store.cursor, replay)


@needs_mesh
def test_fleet_mean_scale_tracks_population_mean(mesh_4x2):
    """PR-5 carry-over (a): with `mean_scale = M/C` the device-resident
    mean shift integrates beta = (M/C) * alpha per round, which is exactly
    the population mean of the per-client store shifts — not the
    (C/M)-inflated cohort estimate the unscaled update would keep."""
    from repro.core.rules import WIRE_RULES
    from repro.launch import compat, steps

    mesh = mesh_4x2
    C, n, b, seq, total = 8, 3, 1, 8, 4  # 2 whole fleet epochs
    cfg, m, agg, jitted, abstract, shardings, batch_sh = _fleet_setup(
        mesh, "diana", n=n, mean_scale=0.5)  # m/C = 4/8
    data = _population_tokens(cfg, C, n, b, seq)
    store = ClientStateStore.create(abstract.params, C, WIRE_RULES["diana"],
                                    dtype=np.float32, shard_size=3)
    with compat.set_mesh(mesh):
        state = jax.device_put(
            steps.init_train_state(jax.random.key(0), cfg, agg, m,
                                   mesh=mesh), shardings)
        with FleetRunner(jitted, abstract, shardings, batch_sh, agg=agg,
                         mesh=mesh, data=data,
                         sampler=ReshuffleSampler(C, n, mode="rr", seed=1),
                         cohorts=CohortSampler(C, m, seed=3),
                         store=store) as runner:
            state = runner.run(state, jax.random.key(2), total)
    mean_shift = jax.device_get(state.mean_shift)
    got = store.gather(np.arange(C))
    moved = False
    for (pa, h_bar), (_, rows) in zip(
            jax.tree_util.tree_leaves_with_path(mean_shift),
            jax.tree_util.tree_leaves_with_path(got)):
        pop_mean = np.asarray(rows, np.float64).mean(axis=0)
        np.testing.assert_allclose(np.asarray(h_bar), pop_mean.astype(
            np.float32), atol=1e-5, err_msg=str(pa))
        moved |= bool(np.abs(np.asarray(h_bar)).max() > 0)
    assert moved, "4 rounds of DIANA must move the mean shift"


@needs_mesh
def test_fleet_flat_nastya_pod_shift_roundtrip(mesh_4x2):
    """PR-5 carry-over (b): flat-mesh NASTYA (local_steps > 1 maps every
    client onto its own pod) now RUNS as a fleet — the driver round-trips
    `TrainState.pod_shifts` through the store instead of rejecting the
    config. Sampled clients' rows move, cursors advance by local_steps per
    participation, and device tables stay O(cohort)."""
    from repro.core.rules import WIRE_RULES
    from repro.launch import compat, steps

    mesh = mesh_4x2
    C, n, b, seq, total, ls = 12, 4, 1, 8, 2, 2
    cfg, m, agg, jitted, abstract, shardings, batch_sh = _fleet_setup(
        mesh, "diana", n=n, local_steps=ls)
    assert abstract.shifts is None and abstract.pod_shifts is not None, \
        "flat NASTYA keeps per-client DIANA state in the pod tables"
    data = _population_tokens(cfg, C, n, b, seq)
    store = ClientStateStore.create(abstract.params, C, WIRE_RULES["diana"],
                                    dtype=np.float32, shard_size=3)
    cohorts = CohortSampler(C, m, seed=3)
    with compat.set_mesh(mesh):
        state = jax.device_put(
            steps.init_train_state(jax.random.key(0), cfg, agg, m,
                                   mesh=mesh, local_steps=ls), shardings)
        losses = []
        with FleetRunner(jitted, abstract, shardings, batch_sh, agg=agg,
                         mesh=mesh, data=data,
                         sampler=ReshuffleSampler(C, n, mode="rr", seed=1),
                         cohorts=cohorts, store=store,
                         local_steps=ls) as runner:
            state = runner.run(
                state, jax.random.key(2), total,
                callback=lambda t, st, mx: losses.append(
                    float(mx["loss"])))
    assert np.isfinite(losses).all() and len(losses) == total
    sampled = np.unique(np.concatenate(
        [cohorts.cohort_for_round(r) for r in range(total)]))
    unsampled = np.setdiff1d(np.arange(C), sampled)
    assert unsampled.size, "2 rounds of C=12/m=4 leave clients unsampled"
    touched = store.gather(sampled)
    assert any(np.abs(l).max() > 0 for l in jax.tree.leaves(touched)), \
        "pod_shifts must round-trip into the store"
    for leaf in jax.tree.leaves(store.gather(unsampled)):
        assert np.abs(leaf).max() == 0
    assert np.array_equal(store.cursor,
                          cohorts.participation_counts(total) * ls)
    for leaf in jax.tree.leaves(jax.device_get(state.pod_shifts)):
        assert leaf.shape[0] == m, "device tables stay cohort-sized"
