"""repro.telemetry — structured metrics, spans, and the zero-cost-when-off
event pipeline (DESIGN.md §3.14).

Covers the JSONL schema round-trip (torn-tail tolerance mirroring the
checkpoint reader), span nesting + Chrome trace export, the acceptance
criterion that a run with an active sink is BIT-IDENTICAL to one without
(params, shift tables, bits) for diana and diana_rr, the unified
sync/async participation schema (`completed`/`on_time`/`weight_sum`), the
chaos counters pinned against the deterministic planner schedule, and the
opt-in device-side compression diagnostics.
"""
import dataclasses
import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import telemetry
from repro.data.pipeline import make_batch_stream
from repro.data.reshuffle import ReshuffleSampler
from repro.fleet import (AsyncFleetRunner, AsyncPlanner, ChaosConfig,
                         CohortSampler, ClientStateStore, FleetRunner)

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 8, reason="needs 8 forced host devices"
)


# ---------------------------------------------------------------------------
# events: JSONL round-trip, torn tail, validation
# ---------------------------------------------------------------------------

def _emit_mix(sink):
    sink.run_meta({"arch": "tiny", "n_params": 7})
    with sink.span("outer", round=0):
        with sink.span("inner"):
            pass
    sink.counter("fleet.uplink_bits", np.float64(96.0), round=0)
    sink.counter("fleet.staleness_hist", [1, 0, 2])
    sink.round_metrics(0, {"loss": np.float32(1.5),
                           "grad_norm": jnp.float32(2.0),
                           "completed": 4})


def test_jsonl_round_trip_and_validation(tmp_path):
    """read_events is the inverse of the sink's writes, values land as
    plain JSON scalars (jax/np materialized on the writer thread), and
    every record passes schema validation."""
    path = str(tmp_path / "run.telemetry.jsonl")
    with telemetry.MetricsSink(path) as sink:
        _emit_mix(sink)
    events = telemetry.read_events(path)
    assert [e["kind"] for e in events] == [
        "run_meta", "span", "span", "counter", "counter", "round_metrics"]
    assert telemetry.validate_events(events) == []
    # spans record on EXIT, so inner lands first, one depth level down
    inner, outer = events[1], events[2]
    assert (inner["name"], inner["depth"]) == ("inner", 1)
    assert (outer["name"], outer["depth"]) == ("outer", 0)
    assert outer["dur"] >= inner["dur"] >= 0
    rm = events[5]
    assert rm["round"] == 0
    assert rm["metrics"]["loss"] == pytest.approx(1.5)
    assert isinstance(rm["metrics"]["loss"], float)  # materialized
    assert events[3]["value"] == pytest.approx(96.0)
    assert events[4]["value"] == [1, 0, 2]


def test_torn_tail_tolerated_interior_corruption_raises(tmp_path):
    """Like the checkpoint reader: a torn FINAL line (the crash case the
    buffered writer can leave) is dropped silently; damage anywhere else
    is out-of-band corruption and raises."""
    path = str(tmp_path / "run.telemetry.jsonl")
    with telemetry.MetricsSink(path) as sink:
        _emit_mix(sink)
    n = len(telemetry.read_events(path))
    with open(path, "a") as f:
        f.write('{"v": 1, "kind": "coun')  # torn mid-record
    assert len(telemetry.read_events(path)) == n
    lines = open(path).read().splitlines()
    lines[2] = lines[2][:10]
    bad = str(tmp_path / "corrupt.jsonl")
    with open(bad, "w") as f:
        f.write("\n".join(lines) + "\n")
    with pytest.raises(telemetry.TelemetryError):
        telemetry.read_events(bad)


def test_validate_flags_bad_records():
    assert telemetry.validate_events([{"v": 99, "kind": "span"}])
    assert telemetry.validate_events([{"v": 1, "kind": "nope", "ts": 0}])
    assert telemetry.validate_events(
        [{"v": 1, "kind": "counter", "ts": 0, "name": "x",
          "value": "not-a-number"}])
    assert telemetry.validate_events(
        [{"v": 1, "kind": "span", "ts": 0.0, "dur": -1.0, "name": "s",
          "tid": 1, "depth": 0}])


def test_module_helpers_are_noops_when_off():
    assert not telemetry.enabled()
    with telemetry.span("anything", round=3):
        pass
    telemetry.counter("x", 1)
    telemetry.round_metrics(0, {"loss": 1.0})
    telemetry.run_meta({})
    assert telemetry.active() is None


def test_session_installs_and_always_uninstalls():
    sink = telemetry.MetricsSink()
    with pytest.raises(RuntimeError, match="boom"):
        with telemetry.session(sink):
            assert telemetry.active() is sink
            raise RuntimeError("boom")
    assert telemetry.active() is None


def test_spans_from_worker_threads_get_their_own_tid_and_depth():
    with telemetry.MetricsSink() as sink:
        def worker():
            with sink.span("worker_phase"):
                pass

        with sink.span("main_phase"):
            t = threading.Thread(target=worker)
            t.start()
            t.join()
        spans = {e["name"]: e for e in sink.events()}
    assert spans["worker_phase"]["tid"] != spans["main_phase"]["tid"]
    # nesting depth is per-thread: the worker span is NOT inside main's
    assert spans["worker_phase"]["depth"] == 0
    assert spans["main_phase"]["depth"] == 0


# ---------------------------------------------------------------------------
# trace export
# ---------------------------------------------------------------------------

def test_trace_export_golden(tmp_path):
    """Chrome trace_event shape: leading process metadata, spans as
    complete "X" events in microseconds, numeric counters and round
    metrics as "C" tracks, run_meta as a global instant."""
    with telemetry.MetricsSink() as sink:
        _emit_mix(sink)
        events = sink.events()
    trace = telemetry.to_trace_events(events)
    assert trace[0] == {"ph": "M", "name": "process_name", "pid": 1,
                        "ts": 0, "args": {"name": "repro.telemetry"}}
    by_ph = {}
    for ev in trace[1:]:
        by_ph.setdefault(ev["ph"], []).append(ev)
    assert {e["name"] for e in by_ph["X"]} == {"outer", "inner"}
    for ev in by_ph["X"]:
        src = next(e for e in events if e.get("name") == ev["name"])
        assert ev["ts"] == pytest.approx(src["ts"] * 1e6)
        assert ev["dur"] == pytest.approx(src["dur"] * 1e6)
        assert ev["tid"] == src["tid"]
    # the list-valued staleness hist has no counter track; the scalar does
    c_names = {e["name"] for e in by_ph["C"]}
    assert c_names == {"fleet.uplink_bits", "metrics/loss",
                       "metrics/grad_norm", "metrics/completed"}
    assert len(by_ph["i"]) == 1

    out = str(tmp_path / "trace.json")
    n = telemetry.write_trace(events, out)
    loaded = json.load(open(out))
    assert loaded["displayTimeUnit"] == "ms"
    assert len(loaded["traceEvents"]) == n == len(trace)


def test_cli_validate_summary_trace(tmp_path, capsys):
    from repro.telemetry.__main__ import main as tmain

    path = str(tmp_path / "run.telemetry.jsonl")
    with telemetry.MetricsSink(path) as sink:
        _emit_mix(sink)
    out = str(tmp_path / "t.json")
    assert tmain([path, "--validate", "--summary", "--to-trace", out]) == 0
    text = capsys.readouterr().out
    assert "schema OK" in text and "span" in text
    assert json.load(open(out))["traceEvents"]
    # schema problems exit 1
    bad = str(tmp_path / "bad.jsonl")
    with open(bad, "w") as f:
        f.write('{"v": 1, "kind": "span", "ts": 0}\n')
    assert tmain([bad, "--validate"]) == 1
    # unreadable exits 2
    assert tmain([str(tmp_path / "missing.jsonl"), "--validate"]) == 2


# ---------------------------------------------------------------------------
# the acceptance criterion: telemetry-on bit-matches telemetry-off
# ---------------------------------------------------------------------------

def _tiny_cfg():
    from repro.configs import get_config, reduced

    cfg = reduced(get_config("stablelm-1.6b"), seq=8)
    return dataclasses.replace(cfg, dtype=jnp.float32)


def _fleet_setup(mesh, method, *, n=3, elastic=False):
    from repro.core.dist import CompressedAggregation
    from repro.launch import steps
    from repro.launch.mesh import num_clients

    cfg = _tiny_cfg()
    m = num_clients(mesh)
    slotted = method == "diana_rr"
    agg = CompressedAggregation(method=method, wire="shared", fraction=0.5,
                                n_slots=n if slotted else 1,
                                shift_dtype=jnp.float32,
                                mean_scale=m / (2 * m))
    jitted, abstract, shardings, batch_sh = steps.make_train_step(
        cfg, mesh, agg=agg, lr=0.05, remat=False, seq_shard=False,
        elastic=elastic)
    return cfg, m, agg, jitted, abstract, shardings, batch_sh


def _population_tokens(cfg, C, n, b, seq, seed=0):
    from repro.data.tokens import synthetic_token_batches

    return {"tokens": np.asarray(synthetic_token_batches(
        vocab=cfg.vocab, seq_len=seq, batch=b, num_batches=n,
        num_clients=C, seed=seed))}


def _run_fleet(mesh, method, setup, data, *, total, sink=None):
    """One C = 2m cohort-RR fleet walk; returns (final state, store,
    callback metrics) — with `sink` installed for the duration."""
    from repro.core.rules import WIRE_RULES
    from repro.launch import compat, steps

    cfg, m, agg, jitted, abstract, shardings, batch_sh = setup
    C = 2 * m
    mode = "rr_shared" if method == "diana_rr" else "rr"
    seen = []
    if sink is not None:
        telemetry.install(sink)
    try:
        with compat.set_mesh(mesh):
            state = jax.device_put(
                steps.init_train_state(jax.random.key(0), cfg, agg, m,
                                       mesh=mesh), shardings)
            store = ClientStateStore.create(
                abstract.params, C, WIRE_RULES[method], n_slots=agg.n_slots,
                dtype=np.float32, shard_size=3)
            with FleetRunner(
                    jitted, abstract, shardings, batch_sh, agg=agg,
                    mesh=mesh, data=data,
                    sampler=ReshuffleSampler(C, 3, mode=mode, seed=1),
                    cohorts=CohortSampler(C, m, seed=9),
                    store=store) as runner:
                state = runner.run(
                    state, jax.random.key(4), total,
                    callback=lambda t, s, mt: seen.append((t, mt)))
            return jax.device_get(state), store, seen
    finally:
        if sink is not None:
            telemetry.uninstall()


@needs_mesh
@pytest.mark.parametrize("method", ["diana", "diana_rr"])
def test_telemetry_on_bit_matches_off(method, mesh_4x2):
    """THE §3.14 acceptance criterion, host side: a fleet run with an
    active sink walks a byte-identical trajectory — params, store shift
    tables, bit counters — and the sink sees every phase span (including
    assemble from the prefetch worker's own thread) plus one round_metrics
    per round with the unified participation schema."""
    mesh = mesh_4x2
    setup = _fleet_setup(mesh, method)
    cfg, m = setup[0], setup[1]
    data = _population_tokens(cfg, 2 * m, 3, 1, 8)
    total = 3

    off_state, off_store, off_seen = _run_fleet(
        mesh, method, setup, data, total=total)
    sink = telemetry.MetricsSink()
    on_state, on_store, on_seen = _run_fleet(
        mesh, method, setup, data, total=total, sink=sink)

    for (pa, a), (_, b) in zip(
            jax.tree_util.tree_leaves_with_path(off_state.params),
            jax.tree_util.tree_leaves_with_path(on_state.params)):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes(), pa
    for (pa, a), (_, b) in zip(
            jax.tree_util.tree_leaves_with_path(
                off_store.gather(np.arange(2 * m))),
            jax.tree_util.tree_leaves_with_path(
                on_store.gather(np.arange(2 * m)))):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes(), pa
    assert np.array_equal(off_store.bits, on_store.bits)
    assert np.array_equal(off_store.cursor, on_store.cursor)

    events = sink.events()
    sink.close()
    assert telemetry.validate_events(events) == []
    spans = [e for e in events if e["kind"] == "span"]
    names = {e["name"] for e in spans}
    assert {"gather", "device_step", "scatter", "assemble"} <= names
    # prefetch assembly runs on the worker thread, phases on the caller's
    tids = {e["name"]: e["tid"] for e in spans}
    assert tids["assemble"] != tids["device_step"]
    rms = [e for e in events if e["kind"] == "round_metrics"]
    assert [e["round"] for e in rms] == list(range(total))
    # one static run_meta with the wire accounting
    (meta,) = [e for e in events if e["kind"] == "run_meta"]
    assert meta["meta"]["bits_per_client_round"] > 0
    assert set(meta["meta"]["wire_bytes_per_round"]) == {
        "intra_pod", "inter_pod", "dense"}
    # the sync path emits the SAME participation schema as async
    # (satellite: one schema across drivers)
    for (t, mt) in on_seen:
        assert mt["completed"] == mt["on_time"] == m
        assert mt["weight_sum"] == float(m)
    assert [mt for _, mt in off_seen][0].keys() == \
        [mt for _, mt in on_seen][0].keys()


# ---------------------------------------------------------------------------
# chaos counters pinned against the deterministic planner schedule
# ---------------------------------------------------------------------------

@needs_mesh
def test_async_chaos_counters_match_planner_replay(mesh_4x2):
    """Every chaos counter the async driver emits must equal the closed-
    form replay of its deterministic `AsyncPlanner`/`FaultyStore` schedule
    — and `weight_sum` must recover the RAW pre-normalization buffered
    mass (1 per on-time reporter + the staleness discounts), not the
    vacuous post-rescale sum (always m)."""
    from repro.core.rules import WIRE_RULES
    from repro.launch import compat, steps

    mesh = mesh_4x2
    method, total = "diana", 6
    setup = _fleet_setup(mesh, method, elastic=True)
    cfg, m, agg, jitted, abstract, shardings, batch_sh = setup
    C = 2 * m
    data = _population_tokens(cfg, C, 3, 1, 8)
    chaos = ChaosConfig(dropout=0.25, straggler=0.4, delay=1.0,
                        store_fail=0.15, max_retries=6, seed=5)
    discount = 0.5

    sink = telemetry.MetricsSink()
    telemetry.install(sink)
    seen = []
    try:
        with compat.set_mesh(mesh):
            state = jax.device_put(
                steps.init_train_state(jax.random.key(0), cfg, agg, m,
                                       mesh=mesh), shardings)
            store = ClientStateStore.create(
                abstract.params, C, WIRE_RULES[method], n_slots=1,
                dtype=np.float32, shard_size=3)
            with AsyncFleetRunner(
                    jitted, abstract, shardings, batch_sh, agg=agg,
                    mesh=mesh, data=data,
                    sampler=ReshuffleSampler(C, 3, seed=1),
                    cohorts=CohortSampler(C, m, seed=9), store=store,
                    buffer_k=2, discount=discount, chaos=chaos) as runner:
                runner.run(state, jax.random.key(4), total,
                           callback=lambda t, s, mt: seen.append(mt))
                injected = runner._store.injected_failures
                bits_per_client = runner.checkpoint_meta()[
                    "bits_per_client_round"]
    finally:
        telemetry.uninstall()
    events = sink.events()
    sink.close()
    assert telemetry.validate_events(events) == []

    def totals(name):
        return [e["value"] for e in events
                if e["kind"] == "counter" and e["name"] == name]

    # replay the planner: a pure function of (chaos seed, round)
    planner = AsyncPlanner(m, buffer_k=2, discount=discount, chaos=chaos)
    cohorts = CohortSampler(C, m, seed=9)
    exp_on, exp_late, exp_drop, exp_bits, exp_mass = [], [], [], [], []
    for r in range(total):
        plan = planner(r, cohorts.cohort_for_round(r))
        late = plan.reported & ~plan.on_time
        exp_on.append(int(plan.on_time.sum()))
        exp_late.append(int(late.sum()))
        exp_drop.append(int(m - plan.reported.sum()))
        exp_bits.append(int(plan.reported.sum()) * bits_per_client)
        exp_mass.append(float(plan.on_time.sum()) + float(np.sum(
            discount / (1.0 + plan.latency[late] - plan.deadline))))
    assert totals("fleet.on_time") == exp_on
    assert totals("fleet.late") == exp_late
    assert totals("fleet.dropped") == exp_drop
    assert totals("fleet.uplink_bits") == pytest.approx(exp_bits)
    assert totals("fleet.store_retry") == [1] * injected
    assert injected > 0, "chaos config never fired — test is vacuous"
    for hist, late_n in zip(totals("fleet.staleness_hist"), exp_late):
        assert sum(hist) == late_n
    assert sum(exp_late) > 0, "no late reporters — discount path untested"
    # per-round metrics carry the raw mass, not the normalized sum
    assert len(seen) == total
    for mt, mass, on in zip(seen, exp_mass, exp_on):
        assert mt["weight_sum"] == pytest.approx(mass)
        assert mt["on_time"] == on
        assert "completed" in mt and "deadline" in mt


# ---------------------------------------------------------------------------
# opt-in device-side compression diagnostics
# ---------------------------------------------------------------------------

@needs_mesh
def test_debug_metrics_opt_in(mesh_4x2):
    """debug_metrics=True carries finite ‖ḡ−D‖²/shift-norm scalars in the
    metrics pytree without perturbing the trajectory: params after two
    steps are bitwise identical to the default step's."""
    from repro.core.dist import CompressedAggregation
    from repro.launch import compat, steps
    from repro.launch.mesh import num_clients

    mesh = mesh_4x2
    cfg = _tiny_cfg()
    m = num_clients(mesh)
    agg = CompressedAggregation(method="diana", wire="shared", fraction=0.5,
                                shift_dtype=jnp.float32)

    def run(debug):
        jitted, abstract, shardings, batch_sh = steps.make_train_step(
            cfg, mesh, agg=agg, lr=0.05, remat=False, seq_shard=False,
            debug_metrics=debug)
        data = _population_tokens(cfg, m, 3, 1, 8)
        with compat.set_mesh(mesh):
            state = jax.device_put(
                steps.init_train_state(jax.random.key(0), cfg, agg, m,
                                       mesh=mesh), shardings)
            with make_batch_stream(
                    data, ReshuffleSampler(m, 3, seed=1),
                    put=lambda bt: jax.device_put(bt, batch_sh(bt))) as st:
                for _ in range(2):
                    state, metrics = jitted(state, next(st),
                                            jax.random.key(4))
            return jax.device_get(state), jax.device_get(metrics)

    base_state, base_metrics = run(False)
    dbg_state, dbg_metrics = run(True)
    assert set(base_metrics) == {"loss", "grad_norm"}
    extra = {"compression_err_sq", "direction_norm_sq", "shift_norm_sq",
             "mean_shift_norm_sq"}
    assert set(dbg_metrics) == {"loss", "grad_norm"} | extra
    for k in extra:
        v = float(dbg_metrics[k])
        assert np.isfinite(v) and v >= 0.0, (k, v)
    # compression is lossy here (rand-k at 0.5): the error norm is real
    assert float(dbg_metrics["compression_err_sq"]) > 0.0
    for (pa, a), (_, b) in zip(
            jax.tree_util.tree_leaves_with_path(base_state.params),
            jax.tree_util.tree_leaves_with_path(dbg_state.params)):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes(), pa
    assert float(base_metrics["loss"]) == float(dbg_metrics["loss"])


# ---------------------------------------------------------------------------
# console reporter
# ---------------------------------------------------------------------------

def test_console_reporter_cadence_and_skips(capsys):
    rep = telemetry.ConsoleReporter(unit="round", log_every=2, total=5)
    rep.start()
    for t in range(5):
        if t == 3:
            rep.report(t, {"skipped": True})
        else:
            rep.report(t, {"loss": 1.0, "grad_norm": 2.0, "completed": 3},
                       cohort=4)
    lines = capsys.readouterr().out.strip().splitlines()
    # t=0, t=2 (cadence), t=4 (last); t=1 suppressed, t=3 off-cadence
    assert len(lines) == 3
    assert all("done 3/4" in ln for ln in lines)
    assert "round     4" in lines[-1]
    rep2 = telemetry.ConsoleReporter(unit="round", log_every=1, total=4)
    rep2.start()
    rep2.report(0, {"skipped": True})
    assert "skipped" in capsys.readouterr().out
