"""Data pipeline: RR sampler semantics + synthetic token learnability."""
import numpy as np

from repro.data.reshuffle import ReshuffleSampler
from repro.data.tokens import lm_inputs_labels, synthetic_token_batches


def test_epoch_order_idempotent_all_modes():
    """The headline-bug regression at sampler level: epoch_order(e) must be
    a pure function of (seed, e) — the seed-era sampler mutated its RNG and
    returned a FRESH permutation on every call (`del epoch`)."""
    for mode in ("rr", "rr_once", "wr"):
        s = ReshuffleSampler(4, 8, mode=mode, seed=3)
        a, b = s.epoch_order(2), s.epoch_order(2)
        assert (a == b).all(), mode
        # and a twin sampler (fresh object, same seed) agrees — resumable
        t = ReshuffleSampler(4, 8, mode=mode, seed=3)
        assert (t.epoch_order(2) == a).all(), mode
        # interleaved queries don't perturb each other (no hidden state)
        s.epoch_order(7)
        assert (s.epoch_order(2) == a).all(), mode


def test_rr_fresh_permutation_every_epoch():
    s = ReshuffleSampler(4, 8, mode="rr", seed=0)
    e0, e1 = s.epoch_order(0), s.epoch_order(1)
    assert e0.shape == (4, 8)
    for m in range(4):
        assert sorted(e0[m]) == list(range(8))  # a permutation
    assert (e0 != e1).any()  # reshuffled


def test_rr_once_is_fixed():
    s = ReshuffleSampler(4, 8, mode="rr_once", seed=0)
    assert (s.epoch_order(0) == s.epoch_order(5)).all()


def test_wr_allows_repeats():
    s = ReshuffleSampler(2, 4, mode="wr", seed=0)
    orders = np.stack([s.epoch_order(e) for e in range(16)])
    # with replacement, some epoch must sample a duplicate batch index
    dupes = [len(set(row)) < 4 for e in orders for row in e]
    assert any(dupes)


def test_clients_get_independent_permutations():
    s = ReshuffleSampler(8, 16, mode="rr", seed=1)
    e = s.epoch_order(0)
    assert not all((e[0] == e[m]).all() for m in range(1, 8))


def test_synthetic_tokens_learnable_structure():
    """Successor structure: P(next = succ[cur]) ~ 0.7 >> 1/vocab."""
    toks = synthetic_token_batches(vocab=64, seq_len=128, batch=8,
                                   num_batches=2, num_clients=1, seed=0)
    x, y = lm_inputs_labels(toks)
    x, y = x.reshape(-1, 128), y.reshape(-1, 128)
    # estimate successor table from the first half, test on the second
    votes = {}
    for a, b in zip(x[:, :64].ravel(), y[:, :64].ravel()):
        votes.setdefault(int(a), {}).setdefault(int(b), 0)
        votes[int(a)][int(b)] += 1
    succ = {a: max(d, key=d.get) for a, d in votes.items()}
    hits = sum(succ.get(int(a)) == int(b)
               for a, b in zip(x[:, 64:].ravel(), y[:, 64:].ravel()))
    total = x[:, 64:].size
    assert hits / total > 0.5  # way above chance (1/64)
