"""NASTYA-aware data pipeline (data/pipeline.py, DESIGN.md §3.7).

Host-side stream semantics (RR coherence, modality alignment, uneven
clients, prefetch, cursor resume) plus the production-path regressions the
ISSUE pins down: a pipeline-fed train step whose 2-epoch run visits every
batch exactly once per epoch, resume determinism on the flat mesh and the
2-pod NASTYA mesh, and 1-pod vs flat bit-parity of the pipeline-fed run.

Mesh tests follow tests/test_pod_wire.py's style (tiny reduced configs,
remat=False, seq_shard=False, fully in-process on the 8 forced host
devices).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import (
    BatchStream,
    EpochIterator,
    make_batch_stream,
    normalize_client_data,
    run_epochs,
)
from repro.data.reshuffle import ReshuffleSampler


def _id_data(m, n, b=1):
    """Leaf whose value encodes its (client, slot) coordinates."""
    return (np.arange(m * n).reshape(m, n, 1)
            * np.ones((1, 1, b), np.int64)).astype(np.int64)


# ---------------------------------------------------------------------------
# host-side stream semantics
# ---------------------------------------------------------------------------

def test_epoch_iterator_straddles_boundary():
    s = ReshuffleSampler(2, 3, mode="rr", seed=5)
    it = EpochIterator(s, start=2)  # one micro-step before the boundary
    cols = it.take(2)  # [epoch0 col 2, epoch1 col 0]
    assert (cols[:, 0] == s.epoch_order(0)[:, 2]).all()
    assert (cols[:, 1] == s.epoch_order(1)[:, 0]).all()
    assert it.cursor == (1, 1)


@pytest.mark.parametrize("prefetch", [False, True])
def test_two_epoch_stream_visits_each_batch_once_per_epoch(prefetch):
    """The headline-bug regression on the production feed path: with
    local_steps=2 and an odd n (epoch boundary falls MID-STEP) every client
    must consume each of its batches exactly once per epoch, in the
    sampler's per-epoch order. The seed-era loop redrew a permutation per
    micro-batch — near-with-replacement — and fails this immediately."""
    m, n, ls, b = 3, 5, 2, 2
    s = ReshuffleSampler(m, n, mode="rr", seed=7)
    stream = make_batch_stream({"id": _id_data(m, n, b)}, s, local_steps=ls,
                               prefetch=prefetch)
    per_client = [[] for _ in range(m)]
    with stream:
        for _ in range(n):  # n steps * ls micro = 2 full epochs
            rows = next(stream)["id"].reshape(m, ls, b)
            assert (rows == rows[:, :, :1]).all()  # b rows of one batch
            for c in range(m):
                per_client[c].extend(int(x) - c * n for x in rows[c, :, 0])
    for c in range(m):
        epoch0, epoch1 = per_client[c][:n], per_client[c][n:]
        assert sorted(epoch0) == list(range(n)), (c, epoch0)
        assert sorted(epoch1) == list(range(n)), (c, epoch1)
        assert epoch0 == [int(x) for x in s.epoch_order(0)[c]]
        assert epoch1 == [int(x) for x in s.epoch_order(1)[c]]


def test_extras_follow_the_same_index_stream():
    """Modality alignment (the tile_extra regression): every leaf — tokens
    and stub extras alike — must be gathered by the same RR indices, so the
    local micro-steps get DIFFERENT extra rows, matching their tokens."""
    m, n, ls = 2, 4, 2
    s = ReshuffleSampler(m, n, mode="rr", seed=1)
    ids = _id_data(m, n)
    patches = _id_data(m, n).astype(np.float32) * 10.0
    stream = make_batch_stream({"tokens": ids}, s, local_steps=ls,
                               extras={"patches": patches}, prefetch=False)
    with stream:
        for _ in range(2 * n):
            batch = next(stream)
            np.testing.assert_array_equal(
                batch["patches"], batch["tokens"].astype(np.float32) * 10.0)
            # the ls micro-steps of one client are distinct batches, so the
            # extras must differ too (tile_extra repeated one row ls times)
            rows = batch["patches"].reshape(m, ls)
            assert (rows[:, 0] != rows[:, 1]).all()


def test_uneven_clients_drop_remainder_semantics():
    data = {"x": [np.arange(7).reshape(7, 1), np.arange(5).reshape(5, 1)]}
    views, n = normalize_client_data(data, 2, drop_remainder=True)
    assert n == 5
    with pytest.raises(ValueError, match="drop_remainder"):
        normalize_client_data(data, 2, drop_remainder=False)
    # a full epoch only ever touches batches [0, sampler.n)
    s = ReshuffleSampler(2, 5, mode="rr", seed=0)
    with make_batch_stream(data, s, prefetch=False) as stream:
        seen = {int(next(stream)["x"][0]) for _ in range(5)}
    assert seen <= set(range(5))
    # sampler bigger than the data is an error, not a silent wrap
    with pytest.raises(ValueError, match="usable batches"):
        make_batch_stream(data, ReshuffleSampler(2, 7, seed=0))


def test_prefetch_stream_matches_sync_stream():
    m, n, ls = 4, 6, 3
    data = {"x": np.random.default_rng(0).normal(size=(m, n, 2, 5))}
    a = make_batch_stream(data, ReshuffleSampler(m, n, seed=9),
                          local_steps=ls, prefetch=True)
    b = make_batch_stream(data, ReshuffleSampler(m, n, seed=9),
                          local_steps=ls, prefetch=False)
    with a, b:
        for _ in range(8):
            np.testing.assert_array_equal(next(a)["x"], next(b)["x"])


def test_put_runs_on_stream_and_cursor_ignores_prefetch():
    m, n = 2, 4
    calls = []
    stream = make_batch_stream(
        {"x": _id_data(m, n)}, ReshuffleSampler(m, n, seed=2),
        put=lambda batch: (calls.append(1), batch)[1], prefetch=True)
    with stream:
        assert stream.cursor == (0, 0)
        next(stream)
        # one batch consumed; the prefetched one must NOT advance the cursor
        assert stream.cursor == (0, 1)
        meta = stream.cursor_meta()
    assert meta["train_step"] == 1 and meta["sampler"]["seed"] == 2
    assert len(calls) >= 1


def test_closed_or_failed_stream_refuses_to_continue():
    """A closed stream, or one whose assemble/put failed, must raise rather
    than silently emit batches that no longer match its cursor."""
    m, n = 2, 4
    data = {"x": _id_data(m, n)}
    stream = make_batch_stream(data, ReshuffleSampler(m, n, seed=0),
                               prefetch=True)
    next(stream)
    stream.close()
    with pytest.raises(ValueError, match="closed"):
        next(stream)

    for prefetch in (True, False):
        boom = make_batch_stream(
            data, ReshuffleSampler(m, n, seed=0), prefetch=prefetch,
            put=lambda batch: (_ for _ in ()).throw(RuntimeError("transfer")))
        with pytest.raises(RuntimeError):
            next(boom)
        with pytest.raises(ValueError, match="closed"):
            next(boom)


def test_stream_resume_from_cursor_bit_matches():
    """Rebuilding the stream at a checkpointed cursor — mid-epoch included —
    replays the identical remainder of the stream."""
    m, n, ls = 3, 5, 2
    data = {"x": np.random.default_rng(3).normal(size=(m, n, 1, 4))}
    full = make_batch_stream(data, ReshuffleSampler(m, n, seed=11),
                             local_steps=ls, prefetch=False)
    with full:
        batches = [next(full)["x"] for _ in range(6)]
        assert full.cursor_meta()["step"] != 0  # landed mid-epoch
    resumed = make_batch_stream(data, ReshuffleSampler(m, n, seed=11),
                                local_steps=ls, start_step=2, prefetch=True)
    with resumed:
        for want in batches[2:]:
            np.testing.assert_array_equal(next(resumed)["x"], want)


# ---------------------------------------------------------------------------
# production path: pipeline-fed train step on the forced 8-device session
# ---------------------------------------------------------------------------

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 8, reason="needs 8 forced host devices")


def _tiny_cfg(seq=8):
    from repro.configs import get_config, reduced

    cfg = reduced(get_config("stablelm-1.6b"), seq=seq)
    return dataclasses.replace(cfg, dtype=jnp.float32)


def _setup_step(mesh, *, local_steps=1, eta=None, seq=8):
    from repro.core.dist import CompressedAggregation
    from repro.launch import steps
    from repro.launch.mesh import num_clients

    cfg = _tiny_cfg(seq)
    m = num_clients(mesh)
    agg = CompressedAggregation(method="diana", wire="shared", fraction=0.5,
                                shift_dtype=jnp.float32)
    jitted, abstract, shardings, batch_sh = steps.make_train_step(
        cfg, mesh, agg=agg, lr=0.05, eta=eta, local_steps=local_steps,
        remat=False, seq_shard=False)
    state = steps.init_train_state(jax.random.key(0), cfg, agg, m, mesh=mesh,
                                   local_steps=local_steps)
    return cfg, m, jitted, abstract, shardings, batch_sh, state


def _token_data(cfg, m, n, b, seq, seed=0):
    from repro.data.tokens import synthetic_token_batches

    return {"tokens": synthetic_token_batches(
        vocab=cfg.vocab, seq_len=seq, batch=b, num_batches=n,
        num_clients=m, seed=seed)}


def _run_resume_cycle(mesh, *, local_steps, eta, n_batches, tmp_path):
    """6 pipeline-fed steps with a checkpoint (state + cursor) snapped after
    step 3, then restore + rerun 4..6: trajectories must bit-match."""
    from repro.checkpoint import load_meta, restore_train_state, save_pytree
    from repro.launch import compat

    seq, b, total, cut = 8, 1, 6, 3
    cfg, m, jitted, abstract, shardings, batch_sh, state = _setup_step(
        mesh, local_steps=local_steps, eta=eta, seq=seq)
    data = _token_data(cfg, m, n_batches, b, seq)
    put = lambda batch: jax.device_put(batch, batch_sh(batch))
    key = jax.random.key(4)
    path = str(tmp_path / "mid.ckpt")

    with compat.set_mesh(mesh):
        state = jax.device_put(state, shardings)
        stream = make_batch_stream(
            data, ReshuffleSampler(m, n_batches, seed=1),
            local_steps=local_steps, put=put)
        metrics_a = []
        with stream:
            for t in range(total):
                state, metrics = jitted(state, stream.__next__(), key)
                metrics_a.append(jax.device_get(metrics))
                if t + 1 == cut:
                    save_pytree(path, jax.device_get(state),
                                step=int(state.step),
                                meta={"data_stream": stream.cursor_meta()})
        params_a = jax.device_get(state.params)

        cursor = load_meta(path)["meta"]["data_stream"]
        assert cursor["train_step"] == cut
        if local_steps * cut % n_batches:
            assert cursor["step"] != 0  # checkpoint truly lands mid-epoch
        state_b = restore_train_state(path, abstract, shardings)
        stream_b = make_batch_stream(
            data, ReshuffleSampler(m, n_batches, seed=1),
            local_steps=local_steps, put=put,
            start_step=cursor["train_step"])
        metrics_b = []
        with stream_b:
            for _ in range(cut, total):
                state_b, metrics = jitted(state_b, stream_b.__next__(), key)
                metrics_b.append(jax.device_get(metrics))
        params_b = jax.device_get(state_b.params)

    for got, want in zip(metrics_b, metrics_a[cut:]):
        for k in ("loss", "grad_norm"):
            assert np.asarray(got[k]).tobytes() == \
                np.asarray(want[k]).tobytes(), k
    for (pa, a), (_, b_) in zip(
            jax.tree_util.tree_leaves_with_path(params_a),
            jax.tree_util.tree_leaves_with_path(params_b)):
        assert np.asarray(a).tobytes() == np.asarray(b_).tobytes(), pa


@needs_mesh
def test_resume_determinism_flat_mesh(mesh_4x2, tmp_path):
    _run_resume_cycle(mesh_4x2, local_steps=1, eta=None, n_batches=4,
                      tmp_path=tmp_path)


@needs_mesh
def test_resume_determinism_2pod_nastya(mesh_2x2x2, tmp_path):
    """2 pods x 2 clients, local_steps=2 over n=3 batches: epoch boundaries
    fall mid-step and the checkpoint cut lands mid-epoch."""
    _run_resume_cycle(mesh_2x2x2, local_steps=2, eta=0.1, n_batches=3,
                      tmp_path=tmp_path)


@needs_mesh
def test_one_pod_pipeline_run_bit_matches_flat(mesh_4x2, mesh_1x4x2):
    """The acceptance-criteria parity: the SAME pipeline stream feeding the
    1-pod two-level step and the flat step produces bitwise-identical
    parameter trajectories (tests/test_pod_wire.py proves it for the wire;
    this proves it end-to-end through the pipeline-fed step)."""
    from repro.launch import compat

    seq, b, n, total = 8, 1, 4, 3
    results = {}
    for name, mesh in (("flat", mesh_4x2), ("one_pod", mesh_1x4x2)):
        cfg, m, jitted, _, shardings, batch_sh, state = _setup_step(
            mesh, seq=seq)
        data = _token_data(cfg, m, n, b, seq)
        with compat.set_mesh(mesh):
            state = jax.device_put(state, shardings)
            stream = make_batch_stream(
                data, ReshuffleSampler(m, n, seed=1),
                put=lambda batch: jax.device_put(batch, batch_sh(batch)))
            with stream:
                for _ in range(total):
                    state, _ = jitted(state, stream.__next__(),
                                      jax.random.key(4))
            results[name] = jax.device_get(state.params)
    for (pa, a), (_, b_) in zip(
            jax.tree_util.tree_leaves_with_path(results["flat"]),
            jax.tree_util.tree_leaves_with_path(results["one_pod"])):
        assert np.asarray(a).tobytes() == np.asarray(b_).tobytes(), pa


# ---------------------------------------------------------------------------
# simulator path: run_epochs through the same sampler
# ---------------------------------------------------------------------------

def test_simulator_run_epochs_resume_bit_matches():
    """core/algorithms epochs driven by the stateless sampler: restart from
    a mid-run state with start_epoch=e and the trajectory bit-matches."""
    from repro.compression.ops import RandK
    from repro.core.algorithms import ALGORITHMS, init_algorithm, make_epoch_fn
    from repro.data.logreg import make_federated_logreg

    prob = make_federated_logreg(m=4, n_batches=5, batch=4, d=16, cond=50.0,
                                 seed=2)
    spec, epoch = make_epoch_fn("diana_rr", prob.loss_fn(),
                                RandK(fraction=0.25), gamma=0.05, alpha=0.2)
    # Shuffle-Once, as the paper runs DIANA-RR (shift slots stay aligned)
    sampler = ReshuffleSampler(prob.m, prob.n, mode="rr_once", seed=13)
    s0 = init_algorithm(ALGORITHMS["diana_rr"],
                        {"w": jnp.zeros((prob.d,), jnp.float32)},
                        prob.m, prob.n)
    key = jax.random.PRNGKey(21)

    full = run_epochs(epoch, s0, prob.data, sampler, epochs=4, key=key)
    half = run_epochs(epoch, s0, prob.data, sampler, epochs=2, key=key)
    ckpt = jax.device_get(half)  # "save": a host snapshot of the FedState
    resumed = run_epochs(epoch, ckpt, prob.data, sampler, epochs=2, key=key,
                         start_epoch=2)
    for (pa, a), (_, b_) in zip(
            jax.tree_util.tree_leaves_with_path(full),
            jax.tree_util.tree_leaves_with_path(resumed)):
        assert np.asarray(a).tobytes() == np.asarray(b_).tobytes(), pa


def test_simulator_rr_once_order_reaches_per_slot_shifts():
    """With an rr_once sampler the SAME (M, n) order matrix is fed every
    epoch, so DIANA-RR's per-slot shifts align with fixed datapoints — the
    property the paper's Shuffle-Once variant needs. Verified by running two
    epochs and checking the per-slot shifts only ever update at the slots
    the fixed permutation visits (all of them) in the same order."""
    from repro.compression.ops import RandK
    from repro.core.algorithms import ALGORITHMS, init_algorithm, make_epoch_fn
    from repro.data.logreg import make_federated_logreg

    prob = make_federated_logreg(m=3, n_batches=4, batch=4, d=8, cond=50.0,
                                 seed=4)
    spec, epoch = make_epoch_fn("diana_rr", prob.loss_fn(),
                                RandK(fraction=1.0), gamma=0.01, alpha=1.0)
    sampler = ReshuffleSampler(prob.m, prob.n, mode="rr_once", seed=5)
    s0 = init_algorithm(ALGORITHMS["diana_rr"],
                        {"w": jnp.zeros((prob.d,), jnp.float32)},
                        prob.m, prob.n)
    s1 = run_epochs(epoch, s0, prob.data, sampler, epochs=1,
                    key=jax.random.PRNGKey(0))
    # alpha=1, k=d: after one epoch every slot's shift equals the gradient
    # that was computed at its slot — i.e. every slot got touched exactly once
    shifts = np.asarray(s1.shifts["w"])  # (M, n, d)
    assert (np.abs(shifts).sum(axis=-1) > 0).all()
