"""Checkpoint round-trips, including bf16 leaves and sharded restore."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointError, load_meta, load_pytree,
                              restore_train_state, save_pytree)
from repro.configs import get_config, reduced
from repro.core.dist import CompressedAggregation
from repro.launch import steps
from repro.launch.mesh import make_test_mesh, num_clients
from repro.models import transformer as T


def test_roundtrip_mixed_dtypes(tmp_path):
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"c": jnp.ones((5,), jnp.bfloat16) * 1.5,
              "d": jnp.zeros((), jnp.int32)},
    }
    p = str(tmp_path / "ck.msgpack")
    save_pytree(p, tree, step=7)
    got = load_pytree(p, jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_missing_leaf_raises(tmp_path):
    p = str(tmp_path / "ck.msgpack")
    save_pytree(p, {"a": jnp.ones(3)})
    with pytest.raises(KeyError):
        load_pytree(p, {"a": jnp.ones(3), "b": jnp.ones(2)})


def test_truncated_checkpoint_raises_checkpoint_error(tmp_path):
    """A checkpoint cut short mid-write (power loss around the atomic
    rename, a partial download) must surface as CheckpointError naming the
    file — not as a raw msgpack/json/numpy decode traceback."""
    tree = {"a": jnp.arange(64, dtype=jnp.float32),
            "b": jnp.ones((8, 8), jnp.bfloat16)}
    p = str(tmp_path / "ck.msgpack")
    save_pytree(p, tree, step=3)
    blob = open(p, "rb").read()
    like = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    # truncate at several depths: inside the buffers, inside the manifest,
    # and a nearly-empty file — every cut decodes to the same typed error
    for frac in (0.6, 0.25, 0.02):
        with open(p, "wb") as f:
            f.write(blob[:max(1, int(len(blob) * frac))])
        with pytest.raises(CheckpointError, match="truncated or corrupt"):
            load_pytree(p, like)
    # garbage that isn't msgpack at all: load_meta is the first resume
    # touchpoint and must fail readably too
    with open(p, "wb") as f:
        f.write(b"\x00not a checkpoint\xff" * 7)
    with pytest.raises(CheckpointError):
        load_meta(p)
    # an intact non-checkpoint msgpack map: readable "no manifest" error
    import msgpack

    with open(p, "wb") as f:
        f.write(msgpack.packb({"something": "else"}))
    with pytest.raises(CheckpointError, match="no manifest"):
        load_meta(p)


@pytest.mark.skipif(jax.device_count() < 8, reason="needs 8 host devices")
def test_sharded_train_state_restore(tmp_path):
    cfg = reduced(get_config("stablelm-1.6b"), seq=16)
    mesh = make_test_mesh((4, 2), ("data", "model"))
    agg = CompressedAggregation(method="diana", fraction=0.25,
                                shift_dtype=jnp.float32)
    state = steps.init_train_state(jax.random.key(0), cfg, agg,
                                   num_clients(mesh))
    _, abstract, shardings, _ = steps.make_train_step(cfg, mesh, agg=agg,
                                                      remat=False)
    p = str(tmp_path / "state.msgpack")
    save_pytree(p, state)
    restored = restore_train_state(p, abstract, shardings)
    for a, b in zip(jax.tree.leaves(state.params),
                    jax.tree.leaves(restored.params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
