"""Sharding rule units: divisibility fallbacks, cache specs, ZeRO-1 specs."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import sharding


def _leaf(path_names, shape):
    """Build (path, leaf) the way tree_map_with_path would."""
    path = tuple(jax.tree_util.DictKey(n) for n in path_names)
    return path, jax.ShapeDtypeStruct(shape, jnp.bfloat16)


def spec_of(names, shape, msize=16):
    path, leaf = _leaf(names, shape)
    return sharding._leaf_spec(path, leaf, msize)


def test_column_parallel_divisible():
    assert spec_of(["blocks", "mixer", "wq"], (24, 2048, 4096)) == \
        P(None, None, "model")


def test_column_parallel_indivisible_replicates():
    assert spec_of(["blocks", "mixer", "wq"], (24, 2048, 100)) == \
        P(None, None, None)


def test_row_parallel_fallback_to_last_axis():
    # hymba ln_attn (L, 25, 64): heads don't divide 16, head_dim does
    assert spec_of(["blocks", "mixer", "ln_attn"], (32, 25, 64)) == \
        P(None, None, "model")


def test_row_parallel_primary_axis():
    assert spec_of(["blocks", "mixer", "wo"], (24, 4096, 2048)) == \
        P(None, "model", None)


def test_vocab_parallel():
    assert spec_of(["embed"], (100352, 2048)) == P("model", None)


def test_norms_replicated():
    assert spec_of(["blocks", "ln1", "scale"], (24, 2048)) == P(None, None)
    assert spec_of(["blocks", "ffn", "router"], (24, 2048, 60)) == \
        P(None, None, None)


class _FakeMesh:
    def __init__(self, sizes):
        self.shape = sizes
        self.axis_names = tuple(sizes)


def test_cache_specs_batch_sharded():
    mesh = _FakeMesh({"data": 16, "model": 16})
    cache = {"k": jax.ShapeDtypeStruct((40, 128, 32768, 8, 128), jnp.bfloat16)}
    specs = sharding.cache_specs(cache, ("data",), mesh=mesh, n_clients=16)
    # batch over data; widest divisible axis (32768) over model
    assert specs["k"] == P(None, ("data",), "model", None, None)


def test_cache_specs_indivisible_widest_falls_through():
    mesh = _FakeMesh({"data": 16, "model": 16})
    # whisper cross cache: 1500 not divisible -> next-widest divisible axis
    # (head_dim 64) takes the model sharding
    cache = {"k": jax.ShapeDtypeStruct((24, 128, 1500, 16, 64), jnp.bfloat16)}
    specs = sharding.cache_specs(cache, ("data",), mesh=mesh, n_clients=16)
    assert specs["k"] == P(None, ("data",), None, None, "model")


def test_cache_specs_small_batch_joint_shard():
    mesh = _FakeMesh({"data": 16, "model": 16})
    # long_500k, B=1: widest axis sharded over (data, model) jointly
    cache = {"k": jax.ShapeDtypeStruct((40, 1, 4096, 4, 128), jnp.bfloat16)}
    specs = sharding.cache_specs(cache, ("data",), mesh=mesh, n_clients=16)
    assert specs["k"] == P(None, None, ("data", "model"), None, None)


def test_zero1_never_shards_layer_axis():
    mesh = _FakeMesh({"data": 16, "model": 16})
    params = {"blocks": {"ln1": {"scale": jax.ShapeDtypeStruct(
        (96, 8192), jnp.float32)}}}
    specs = sharding.zero1_specs(params, ("data",), mesh=mesh)
    # axis 0 is the scan axis: data must land on axis 1
    assert specs["blocks"]["ln1"]["scale"] == P(None, "data")
