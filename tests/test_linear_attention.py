"""Chunked linear attention vs the O(T) sequential oracle (RWKV6 + SSD)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.linear_attention import (
    LOG_DECAY_CLAMP,
    chunked_linear_attention,
    linear_attention_decode,
    reference_linear_attention,
)


def _inputs(key, b, s, h, dk, dv, *, scalar_decay):
    ks = jax.random.split(key, 4)
    r = jax.random.normal(ks[0], (b, s, h, dk)) * 0.5
    k = jax.random.normal(ks[1], (b, s, h, dk)) * 0.5
    v = jax.random.normal(ks[2], (b, s, h, dv)) * 0.5
    shape = (b, s, h) if scalar_decay else (b, s, h, dk)
    ld = -jnp.exp(jax.random.normal(ks[3], shape) * 0.5)  # in (-inf, 0)
    return r, k, v, ld


@pytest.mark.parametrize("inclusive", [True, False])
@pytest.mark.parametrize("scalar_decay", [True, False])
@pytest.mark.parametrize("s,chunk", [(32, 8), (64, 16), (48, 16)])
def test_chunked_matches_sequential(inclusive, scalar_decay, s, chunk):
    b, h, dk, dv = 2, 3, 8, 8
    r, k, v, ld = _inputs(jax.random.key(s), b, s, h, dk, dv,
                          scalar_decay=scalar_decay)
    bonus = None
    if not inclusive:
        bonus = jax.random.normal(jax.random.key(9), (h, dk)) * 0.3
    got, gstate = chunked_linear_attention(
        r, k, v, ld, bonus=bonus, inclusive=inclusive, chunk=chunk)
    want, wstate = reference_linear_attention(
        r, k, v, jnp.clip(ld, -LOG_DECAY_CLAMP, 0.0), bonus=bonus,
        inclusive=inclusive)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(gstate), np.asarray(wstate),
                               atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("inclusive", [True, False])
def test_prefill_then_decode_matches_full(inclusive):
    """State handoff: chunked prefill state + recurrent decode == full pass."""
    b, s, h, dk, dv = 1, 32, 2, 8, 8
    pre = 24  # prefill length (divisible by chunk); decode the rest
    r, k, v, ld = _inputs(jax.random.key(3), b, s, h, dk, dv,
                          scalar_decay=inclusive)
    full, _ = chunked_linear_attention(
        r, k, v, ld, inclusive=inclusive, chunk=8)
    _, state = chunked_linear_attention(
        r[:, :pre], k[:, :pre], v[:, :pre], ld[:, :pre],
        inclusive=inclusive, chunk=8)
    for t in range(pre, s):
        out, state = linear_attention_decode(
            r[:, t], k[:, t], v[:, t], ld[:, t], state, inclusive=inclusive)
        np.testing.assert_allclose(np.asarray(out), np.asarray(full[:, t]),
                                   atol=2e-4, rtol=2e-4)


@settings(max_examples=20, deadline=None)
@given(
    s=st.sampled_from([16, 32, 64]),
    chunk=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**30),
    inclusive=st.booleans(),
)
def test_property_chunk_invariance(s, chunk, seed, inclusive):
    """Output must not depend on the chunk decomposition (system invariant:
    chunking is an implementation detail, not semantics)."""
    b, h, dk, dv = 1, 2, 4, 4
    r, k, v, ld = _inputs(jax.random.key(seed), b, s, h, dk, dv,
                          scalar_decay=False)
    a, _ = chunked_linear_attention(r, k, v, ld, inclusive=inclusive, chunk=chunk)
    bfull, _ = chunked_linear_attention(r, k, v, ld, inclusive=inclusive, chunk=s)
    np.testing.assert_allclose(np.asarray(a), np.asarray(bfull),
                               atol=3e-4, rtol=3e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**30))
def test_property_zero_decay_is_cumulative_sum(seed):
    """With decay -> 0 (w=1) and inclusive scores, the state is a running
    sum of k v^T — a closed form the implementation must reproduce."""
    b, s, h, dk, dv = 1, 16, 1, 4, 4
    ks = jax.random.split(jax.random.key(seed), 3)
    r = jax.random.normal(ks[0], (b, s, h, dk))
    k = jax.random.normal(ks[1], (b, s, h, dk))
    v = jax.random.normal(ks[2], (b, s, h, dv))
    ld = jnp.zeros((b, s, h, dk)) - 1e-9
    got, _ = chunked_linear_attention(r, k, v, ld, inclusive=True, chunk=4)
    # closed form: out_t = r_t . sum_{s<=t} k_s v_s^T
    kv = jnp.einsum("bshk,bshv->bshkv", k, v)
    run = jnp.cumsum(kv, axis=1)
    want = jnp.einsum("bshk,bshkv->bshv", r, run)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)
