"""Config registry: exact assigned hyper-parameters + shape support matrix."""
import pytest

from repro.configs import (
    ARCH_NAMES,
    INPUT_SHAPES,
    all_configs,
    get_config,
    reduced,
    shape_supported,
)

# (layers, d_model, heads, kv, d_ff, vocab) exactly as assigned
ASSIGNED = {
    "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
    "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
    "rwkv6-7b": (32, 4096, 64, 64, 14336, 65536),
    "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
    "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
    "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
    "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
    "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
    "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
    "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
}


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_assigned_hparams_exact(name):
    cfg = get_config(name)
    assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.d_ff, cfg.vocab) == ASSIGNED[name]


def test_all_ten_archs_present():
    assert len(ARCH_NAMES) == 10
    assert set(ASSIGNED) == set(ARCH_NAMES)


def test_moe_routing_params():
    q = get_config("qwen2-moe-a2.7b")
    assert (q.num_experts, q.experts_per_token) == (60, 4)
    assert q.shared_expert_ff == 4 * 1408
    d = get_config("dbrx-132b")
    assert (d.num_experts, d.experts_per_token) == (16, 4)


def test_param_counts_in_expected_range():
    """Nameplate sizes within ~20% (sanity on the model definitions)."""
    expect = {
        "stablelm-1.6b": 1.6e9, "deepseek-67b": 67e9, "rwkv6-7b": 7e9,
        "hymba-1.5b": 1.5e9, "starcoder2-15b": 15e9, "qwen2-vl-2b": 2e9,
        "qwen2.5-32b": 32e9, "qwen2-moe-a2.7b": 14e9, "whisper-medium": 0.7e9,
        "dbrx-132b": 132e9,
    }
    for name, target in expect.items():
        n = get_config(name).param_count()
        assert 0.6 * target < n < 1.6 * target, f"{name}: {n:.3g} vs {target:.3g}"


def test_active_params_moe():
    d = get_config("dbrx-132b")
    assert d.active_param_count() < 0.45 * d.param_count()


def test_long_context_support_matrix():
    """long_500k runs exactly for the sub-quadratic archs (DESIGN.md)."""
    shape = INPUT_SHAPES["long_500k"]
    runnable = {n for n in ARCH_NAMES
                if shape_supported(get_config(n), shape)[0]}
    assert runnable == {"rwkv6-7b", "hymba-1.5b", "starcoder2-15b"}
    # every other shape runs for every arch
    for s in ("train_4k", "prefill_32k", "decode_32k"):
        for n in ARCH_NAMES:
            assert shape_supported(get_config(n), INPUT_SHAPES[s])[0]


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_reduced_within_smoke_budget(name):
    cfg = reduced(get_config(name))
    assert cfg.num_layers == 2
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4
    assert cfg.family == get_config(name).family


def test_vocab_padding():
    assert get_config("hymba-1.5b").padded_vocab() == 32016
    assert get_config("whisper-medium").padded_vocab() == 51872
    assert get_config("deepseek-67b").padded_vocab() == 102400  # already /16
