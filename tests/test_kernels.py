"""Per-kernel shape/dtype sweeps: pallas_call (interpret on CPU) vs ref.py,
plus backend-level parity (backend="reference" vs backend="pallas") and the
statistical guarantees (unbiasedness) of the sort-free Rand-k sampler.

Promoted from the ad-hoc parity prints in benchmarks/run.py `[kernels]`."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compression.backend import (
    CompressionBackend,
    tree_ravel_clients,
)
from repro.compression.ops import QSGDQuantizer, RandK
from repro.kernels import ops, ref
from repro.kernels.diana_shift import diana_shift_update
from repro.kernels.qsgd import TILE, qsgd_quantize
from repro.kernels.randk import randk_compress, randk_decompress, randk_mask

REF = CompressionBackend("reference")
PAL = CompressionBackend("pallas")


# ---------------------------------------------------------------------------
# qsgd
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_tiles", [1, 3, 16])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("levels", [4, 8, 16])
def test_qsgd_matches_ref(n_tiles, dtype, levels):
    key = jax.random.key(n_tiles * levels)
    x = (jax.random.normal(key, (n_tiles * TILE,)) * 3).astype(dtype)
    u = jax.random.uniform(jax.random.key(7), x.shape)
    got = qsgd_quantize(x, u, levels=levels)
    want = ref.qsgd_quantize_ref(x, u, levels=levels, tile=TILE)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=1e-2 if dtype == jnp.bfloat16 else 1e-6,
    )


def test_qsgd_unbiased():
    """E[Q(x)] = x conditional on tile scales (Assumption 1)."""
    key = jax.random.key(0)
    x = jax.random.normal(key, (TILE,))
    reps = 512
    us = jax.random.uniform(jax.random.key(1), (reps, TILE))
    outs = jax.vmap(lambda u: qsgd_quantize(x, u, levels=4))(us)
    err = jnp.mean(outs, axis=0) - x
    scale = float(jnp.max(jnp.abs(x)))
    # MC std of the mean ~ scale/(4*sqrt(reps)); allow 5 sigma
    assert float(jnp.max(jnp.abs(err))) < 5 * scale / (4 * np.sqrt(reps))


def test_qsgd_wrapper_padding():
    x = jax.random.normal(jax.random.key(2), (TILE + 13, 7))
    out = ops.qsgd(x, jax.random.key(3))
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))


# ---------------------------------------------------------------------------
# randk circular row-block gather/scatter
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_blocks,k_blocks", [(5, 1), (5, 2), (8, 8), (16, 3)])
@pytest.mark.parametrize("d", [16, 128])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_randk_roundtrip_all_starts(n_blocks, k_blocks, d, dtype):
    br = 8
    rows = (jax.random.normal(jax.random.key(0), (n_blocks * br, d)) * 2).astype(dtype)
    for start in range(n_blocks):  # includes every wrap position
        s = jnp.int32(start)
        got_v = randk_compress(rows, s, k_blocks=k_blocks, block_rows=br)
        want_v = ref.randk_compress_ref(rows, s, k_blocks=k_blocks, block_rows=br)
        np.testing.assert_allclose(np.asarray(got_v, np.float32),
                                   np.asarray(want_v, np.float32), rtol=1e-2)
        got_d = randk_decompress(got_v, s, n_rows=n_blocks * br, block_rows=br)
        want_d = ref.randk_decompress_ref(want_v, s, n_rows=n_blocks * br,
                                          block_rows=br)
        np.testing.assert_allclose(np.asarray(got_d, np.float32),
                                   np.asarray(want_d, np.float32), rtol=1e-2)


def test_randk_unbiased_over_starts():
    """Mean over all start blocks reconstructs the original rows exactly."""
    br, nb, d = 8, 6, 32
    rows = jax.random.normal(jax.random.key(1), (nb * br, d))
    acc = jnp.zeros_like(rows)
    for start in range(nb):
        v = randk_compress(rows, jnp.int32(start), k_blocks=2, block_rows=br)
        acc = acc + randk_decompress(v, jnp.int32(start), n_rows=nb * br,
                                     block_rows=br)
    np.testing.assert_allclose(np.asarray(acc / nb), np.asarray(rows), atol=1e-4)


# ---------------------------------------------------------------------------
# fused diana shift update
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [128, 128 * 600, 128 * 600 + 128])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_diana_shift_matches_ref(n, dtype):
    ks = jax.random.split(jax.random.key(4), 4)
    h, qo, mh, qm = (jax.random.normal(k, (n,)).astype(dtype) for k in ks)
    got = diana_shift_update(h, qo, mh, qm, alpha=0.11)
    want = ref.diana_shift_update_ref(h, qo, mh, qm, 0.11)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32),
                                   atol=5e-2 if dtype == jnp.bfloat16 else 1e-6)


def test_diana_shift_beta_second_stepsize():
    """The mean-shift update takes its own stepsize beta (fleets pass
    mean_scale*alpha, DESIGN.md §3.9): kernel matches reference for
    beta != alpha, and the beta=None default is bitwise the beta=alpha
    path — the no-rescale configs keep their exact trajectory."""
    n = 128 * 3
    ks = jax.random.split(jax.random.key(7), 4)
    h, qo, mh, qm = (jax.random.normal(k, (n,)) for k in ks)
    alpha, beta = 0.25, 0.0625  # beta = (M/C) * alpha at M/C = 1/4
    got = diana_shift_update(h, qo, mh, qm, alpha=alpha, beta=beta)
    want = ref.diana_shift_update_ref(h, qo, mh, qm, alpha, beta)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=1e-6)
    # only the mean-shift output moves with beta
    base = diana_shift_update(h, qo, mh, qm, alpha=alpha)
    assert np.array_equal(np.asarray(got[1]), np.asarray(base[1]))
    assert not np.array_equal(np.asarray(got[2]), np.asarray(base[2]))
    np.testing.assert_allclose(np.asarray(got[2]),
                               np.asarray(mh) + beta * np.asarray(qm),
                               atol=1e-6)
    for defaulted, explicit in zip(
            base, diana_shift_update(h, qo, mh, qm, alpha=alpha, beta=alpha)):
        assert np.asarray(defaulted).tobytes() == \
            np.asarray(explicit).tobytes()


def test_backend_parity_diana_shift_beta():
    ks = jax.random.split(jax.random.key(27), 4)
    trees = [jax.tree.map(lambda l, kk=kk: jax.random.normal(kk, l.shape), TREE)
             for kk in ks]
    got = PAL.tree_diana_shift(*trees, alpha=0.17, beta=0.03)
    want = REF.tree_diana_shift(*trees, alpha=0.17, beta=0.03)
    for gt, wt in zip(got, want):
        for a, b in zip(jax.tree.leaves(gt), jax.tree.leaves(wt)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_diana_shift_fixed_point():
    """At the DIANA fixed point (h == g, q == 0) the direction is H_t and
    shifts do not move — the Theorem 2 stationarity on the kernel path."""
    n = 256
    h = jax.random.normal(jax.random.key(5), (n,))
    zeros = jnp.zeros_like(h)
    direction, h2, mh2 = ops.diana_shift(h, zeros, h, zeros, alpha=0.5)
    np.testing.assert_allclose(np.asarray(direction), np.asarray(h), atol=1e-6)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h), atol=1e-6)
    np.testing.assert_allclose(np.asarray(mh2), np.asarray(h), atol=1e-6)


# ---------------------------------------------------------------------------
# fused dense Rand-k mask (simulator hot path)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d,dp", [(1024, 1024), (2500, 3072), (130, 1024)])
@pytest.mark.parametrize("k", [1, 13, 100])
def test_randk_mask_matches_ref(d, dp, k):
    k = min(k, d)
    m = 3
    x = jax.random.normal(jax.random.key(0), (m, dp))
    x = x * (jnp.arange(dp) < d)  # padding region zero, as callers guarantee
    starts = jnp.array([0, d - 1, d // 2], jnp.int32)
    got = randk_mask(x, starts, d=d, k=k)
    want = ref.randk_mask_ref(x, starts, d=d, k=k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)
    # exactly k real coordinates survive per client (a.s. for dense x)
    nnz = np.count_nonzero(np.asarray(got[:, :d]) != 0, axis=1)
    dense_rows = np.count_nonzero(np.asarray(x[:, :d]), axis=1) == d
    assert np.all(nnz[dense_rows] == k)


# ---------------------------------------------------------------------------
# backend-level parity: backend="reference" vs backend="pallas"
# ---------------------------------------------------------------------------

TREE = {
    "w": jax.random.normal(jax.random.key(11), (4, 37, 13)),
    "b": jax.random.normal(jax.random.key(12), (4, 129)),
}


@pytest.mark.parametrize("comp", [RandK(fraction=0.1), RandK(k=7),
                                  QSGDQuantizer(levels=8)],
                         ids=["randk_frac", "randk_k", "qsgd"])
def test_backend_parity_compress_clients(comp):
    key = jax.random.key(3)
    got = PAL.compress_clients(comp, key, TREE)
    want = REF.compress_clients(comp, key, TREE)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_backend_parity_diana_shift():
    ks = jax.random.split(jax.random.key(21), 4)
    trees = [jax.tree.map(lambda l, kk=kk: jax.random.normal(kk, l.shape), TREE)
             for kk in ks]
    got = PAL.tree_diana_shift(*trees, alpha=0.17)
    want = REF.tree_diana_shift(*trees, alpha=0.17)
    for gt, wt in zip(got, want):
        for a, b in zip(jax.tree.leaves(gt), jax.tree.leaves(wt)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_backend_parity_wire_roundtrip():
    rows = jax.random.normal(jax.random.key(31), (40, 16))
    for start in range(5):
        s = jnp.int32(start)
        vp = PAL.wire_compress(rows, s, k_blocks=2, block_rows=8)
        vr = REF.wire_compress(rows, s, k_blocks=2, block_rows=8)
        np.testing.assert_allclose(np.asarray(vp), np.asarray(vr), atol=1e-6)
        dp_ = PAL.wire_decompress(vp, s, n_rows=40, block_rows=8)
        dr = REF.wire_decompress(vr, s, n_rows=40, block_rows=8)
        np.testing.assert_allclose(np.asarray(dp_), np.asarray(dr), atol=1e-6)


def test_backend_unknown_name_raises():
    with pytest.raises(ValueError):
        CompressionBackend("cuda")


# ---------------------------------------------------------------------------
# statistical guarantees of the sort-free (circular-window) Rand-k
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("be", [REF, PAL], ids=["reference", "pallas"])
def test_sortfree_randk_unbiased(be):
    """E[Q(x)] = x over window starts (Assumption 1 for the backend path)."""
    comp = RandK(fraction=0.2)
    mat, _ = tree_ravel_clients(TREE)
    reps = 3000
    keys = jax.random.split(jax.random.key(41), reps)
    outs = jax.vmap(
        lambda k: tree_ravel_clients(be.compress_clients(comp, k, TREE))[0]
    )(keys)
    mean = jnp.mean(outs, axis=0)
    se = jnp.std(outs, axis=0) / np.sqrt(reps)
    viol = jnp.abs(mean - mat) > 6 * se + 1e-4
    assert int(viol.sum()) == 0


def test_sortfree_randk_omega_exact():
    """E||Q(x)-x||^2 = (d/k - 1)||x||^2 exactly — the window sampler keeps
    the Rand-k variance constant (marginal inclusion probability k/d)."""
    comp = RandK(k=8)
    d = 64
    x = jax.random.normal(jax.random.key(51), (d,))
    keys = jax.random.split(jax.random.key(52), 20000)
    qs = jax.vmap(lambda k: comp.compress(k, x))(keys)
    var = float(jnp.mean(jnp.sum((qs - x[None]) ** 2, axis=-1)))
    expect = (d / 8 - 1) * float(jnp.sum(x**2))
    assert abs(var - expect) / expect < 0.05


def test_sortfree_randk_window_is_contiguous():
    """The selected support is a circular window — the property that makes
    the sampler sort-free and the kernel gather block-contiguous."""
    comp = RandK(k=5)
    x = jnp.ones((12,))
    q = np.asarray(comp.compress(jax.random.key(61), x))
    (nz,) = np.nonzero(q)
    rolled = [(i - nz[0]) % 12 for i in nz]
    assert sorted(rolled) == list(range(5))
