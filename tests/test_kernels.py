"""Per-kernel shape/dtype sweeps: pallas_call (interpret on CPU) vs ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.diana_shift import diana_shift_update
from repro.kernels.qsgd import TILE, qsgd_quantize
from repro.kernels.randk import randk_compress, randk_decompress


# ---------------------------------------------------------------------------
# qsgd
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_tiles", [1, 3, 16])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("levels", [4, 8, 16])
def test_qsgd_matches_ref(n_tiles, dtype, levels):
    key = jax.random.key(n_tiles * levels)
    x = (jax.random.normal(key, (n_tiles * TILE,)) * 3).astype(dtype)
    u = jax.random.uniform(jax.random.key(7), x.shape)
    got = qsgd_quantize(x, u, levels=levels)
    want = ref.qsgd_quantize_ref(x, u, levels=levels, tile=TILE)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=1e-2 if dtype == jnp.bfloat16 else 1e-6,
    )


def test_qsgd_unbiased():
    """E[Q(x)] = x conditional on tile scales (Assumption 1)."""
    key = jax.random.key(0)
    x = jax.random.normal(key, (TILE,))
    reps = 512
    us = jax.random.uniform(jax.random.key(1), (reps, TILE))
    outs = jax.vmap(lambda u: qsgd_quantize(x, u, levels=4))(us)
    err = jnp.mean(outs, axis=0) - x
    scale = float(jnp.max(jnp.abs(x)))
    # MC std of the mean ~ scale/(4*sqrt(reps)); allow 5 sigma
    assert float(jnp.max(jnp.abs(err))) < 5 * scale / (4 * np.sqrt(reps))


def test_qsgd_wrapper_padding():
    x = jax.random.normal(jax.random.key(2), (TILE + 13, 7))
    out = ops.qsgd(x, jax.random.key(3))
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))


# ---------------------------------------------------------------------------
# randk circular row-block gather/scatter
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_blocks,k_blocks", [(5, 1), (5, 2), (8, 8), (16, 3)])
@pytest.mark.parametrize("d", [16, 128])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_randk_roundtrip_all_starts(n_blocks, k_blocks, d, dtype):
    br = 8
    rows = (jax.random.normal(jax.random.key(0), (n_blocks * br, d)) * 2).astype(dtype)
    for start in range(n_blocks):  # includes every wrap position
        s = jnp.int32(start)
        got_v = randk_compress(rows, s, k_blocks=k_blocks, block_rows=br)
        want_v = ref.randk_compress_ref(rows, s, k_blocks=k_blocks, block_rows=br)
        np.testing.assert_allclose(np.asarray(got_v, np.float32),
                                   np.asarray(want_v, np.float32), rtol=1e-2)
        got_d = randk_decompress(got_v, s, n_rows=n_blocks * br, block_rows=br)
        want_d = ref.randk_decompress_ref(want_v, s, n_rows=n_blocks * br,
                                          block_rows=br)
        np.testing.assert_allclose(np.asarray(got_d, np.float32),
                                   np.asarray(want_d, np.float32), rtol=1e-2)


def test_randk_unbiased_over_starts():
    """Mean over all start blocks reconstructs the original rows exactly."""
    br, nb, d = 8, 6, 32
    rows = jax.random.normal(jax.random.key(1), (nb * br, d))
    acc = jnp.zeros_like(rows)
    for start in range(nb):
        v = randk_compress(rows, jnp.int32(start), k_blocks=2, block_rows=br)
        acc = acc + randk_decompress(v, jnp.int32(start), n_rows=nb * br,
                                     block_rows=br)
    np.testing.assert_allclose(np.asarray(acc / nb), np.asarray(rows), atol=1e-4)


# ---------------------------------------------------------------------------
# fused diana shift update
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [128, 128 * 600, 128 * 600 + 128])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_diana_shift_matches_ref(n, dtype):
    ks = jax.random.split(jax.random.key(4), 4)
    h, qo, mh, qm = (jax.random.normal(k, (n,)).astype(dtype) for k in ks)
    got = diana_shift_update(h, qo, mh, qm, alpha=0.11)
    want = ref.diana_shift_update_ref(h, qo, mh, qm, 0.11)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32),
                                   atol=5e-2 if dtype == jnp.bfloat16 else 1e-6)


def test_diana_shift_fixed_point():
    """At the DIANA fixed point (h == g, q == 0) the direction is H_t and
    shifts do not move — the Theorem 2 stationarity on the kernel path."""
    n = 256
    h = jax.random.normal(jax.random.key(5), (n,))
    zeros = jnp.zeros_like(h)
    direction, h2, mh2 = ops.diana_shift(h, zeros, h, zeros, alpha=0.5)
    np.testing.assert_allclose(np.asarray(direction), np.asarray(h), atol=1e-6)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h), atol=1e-6)
    np.testing.assert_allclose(np.asarray(mh2), np.asarray(h), atol=1e-6)
