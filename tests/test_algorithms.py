"""Behavioural tests of the 13 federated drivers on a strongly-convex task.

These encode the paper's THEOREM-level claims as assertions:
  - every method decreases the objective (sanity);
  - DIANA-RR converges to the exact optimum with constant stepsize while
    Q-RR stalls at a compression-variance neighborhood (Thm 1 vs Thm 2);
  - DIANA-NASTYA beats Q-NASTYA the same way (Thm 3 vs Thm 4);
  - Q-RR and QSGD end up at comparable suboptimality (the paper's negative
    result, Sec. 2.1);
  - NASTYA with eta = gamma*n reproduces FedRR exactly (Corollary 3 remark);
  - shift layouts: DIANA 1/worker, DIANA-RR n/worker.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compression.ops import Identity, RandK
from repro.core.algorithms import ALGORITHMS, init_algorithm, make_epoch_fn
from repro.data.logreg import make_federated_logreg

PROBLEM = make_federated_logreg(m=8, n_batches=6, batch=6, d=16, cond=20.0, seed=3)
LOSS = PROBLEM.loss_fn()
P0 = {"w": jnp.zeros((PROBLEM.d,))}
COMP = RandK(fraction=0.25)


def run(name, epochs=150, gamma=None, eta=None, alpha=None, comp=None, seed=0):
    spec = ALGORITHMS[name]
    if comp is None:
        # error feedback needs a CONTRACTIVE compressor (Top-k); the unbiased
        # scaled Rand-k has omega > 1 variance and EF theory does not apply
        from repro.compression.ops import TopK
        comp = TopK(fraction=0.25) if spec.shift_mode == "ef" else COMP
    gamma = gamma if gamma is not None else 0.5 / PROBLEM.l_max
    if spec.family == "local":
        gamma = gamma / PROBLEM.n
        eta = eta if eta is not None else gamma * PROBLEM.n
    spec, epoch = make_epoch_fn(
        name, LOSS, comp if spec.default_compressed else Identity(),
        gamma=gamma, eta=eta, alpha=alpha,
    )
    st = init_algorithm(spec, P0, PROBLEM.m, PROBLEM.n)
    ep = jax.jit(epoch)
    key = jax.random.PRNGKey(seed)
    for _ in range(epochs):
        key, k = jax.random.split(key)
        st = ep(st, PROBLEM.data, k)
    return st


@pytest.mark.parametrize("name", sorted(ALGORITHMS))
def test_decreases_objective(name):
    st = run(name, epochs=30)
    f0 = PROBLEM.full_objective(np.zeros(PROBLEM.d))
    fT = PROBLEM.full_objective(np.asarray(st.params["w"]))
    assert np.isfinite(fT)
    assert fT < f0 - 0.1 * (f0 - PROBLEM.f_star)


def test_diana_rr_beats_q_rr():
    """Thm 2 vs Thm 1: DIANA-RR kills the O(gamma*omega) neighborhood."""
    sub_q = PROBLEM.suboptimality(run("q_rr", epochs=400).params["w"])
    sub_d = PROBLEM.suboptimality(run("diana_rr", epochs=400).params["w"])
    assert sub_d < sub_q / 100


def test_q_rr_matches_qsgd():
    """The paper's negative result: no RR benefit under naive compression."""
    sub_q_rr = PROBLEM.suboptimality(run("q_rr", epochs=120).params["w"])
    sub_qsgd = PROBLEM.suboptimality(run("qsgd", epochs=120).params["w"])
    ratio = sub_q_rr / sub_qsgd
    assert 0.2 < ratio < 5.0  # same order — neither dominates


def test_diana_nastya_beats_q_nastya():
    """Thm 3 vs Thm 4: with gamma -> 0 the only floor left in Q-NASTYA is the
    O(eta*omega/M) quantization term, which DIANA-NASTYA removes. We use a
    tiny local stepsize to suppress the (shared) client-drift term and a harsh
    compressor so the omega-term dominates."""
    harsh = RandK(fraction=0.1)  # omega = 9
    eta = 1.0 / PROBLEM.l_max
    gamma = eta / (20 * PROBLEM.n)
    sub_q = PROBLEM.suboptimality(
        run("q_nastya", epochs=800, gamma=gamma * PROBLEM.n, eta=eta, comp=harsh).params["w"]
    )
    sub_d = PROBLEM.suboptimality(
        run("diana_nastya", epochs=800, gamma=gamma * PROBLEM.n, eta=eta, comp=harsh).params["w"]
    )
    assert sub_d < sub_q / 5


def test_nastya_eta_gamma_n_is_fedrr():
    """With eta = gamma*n and identity compression NASTYA == FedRR exactly."""
    a = run("nastya", epochs=5, seed=11)
    b = run("fedrr", epochs=5, seed=11)
    np.testing.assert_allclose(np.asarray(a.params["w"]), np.asarray(b.params["w"]), rtol=1e-6)


def test_shift_layouts():
    m, n = PROBLEM.m, PROBLEM.n
    st = init_algorithm(ALGORITHMS["diana"], P0, m, n)
    assert st.shifts["w"].shape == (m, PROBLEM.d)
    st = init_algorithm(ALGORITHMS["diana_rr"], P0, m, n)
    assert st.shifts["w"].shape == (m, n, PROBLEM.d)
    st = init_algorithm(ALGORITHMS["q_rr"], P0, m, n)
    assert st.shifts is None


def test_rounds_and_bits_accounting():
    st_nl = run("q_rr", epochs=3)
    assert int(st_nl.rounds) == 3 * PROBLEM.n
    st_l = run("q_nastya", epochs=3, eta=0.1 / PROBLEM.l_max)
    assert int(st_l.rounds) == 3
    # compressed methods send fewer bits than uncompressed at equal rounds
    st_rr = run("rr", epochs=3)
    assert float(st_nl.bits) < float(st_rr.bits)


def test_rr_beats_sgd_late():
    """Classic RR advantage (no compression): smaller neighborhood."""
    sub_rr = PROBLEM.suboptimality(run("rr", epochs=200).params["w"])
    sub_sgd = PROBLEM.suboptimality(run("sgd", epochs=200).params["w"])
    assert sub_rr < sub_sgd


def test_diana_rr_neighborhood_scales_as_gamma_squared():
    """Thm 2: DIANA-RR's only residual term is 2*gamma^2*sigma_rad^2/mu —
    halving gamma should shrink the floor ~4x (vs the O(gamma) floor of
    Q-RR, Thm 1). We check the floor drops superlinearly in gamma and is
    itself tiny in absolute terms."""
    sub_g = PROBLEM.suboptimality(run("diana_rr", epochs=500, gamma=0.4 / PROBLEM.l_max).params["w"])
    sub_g2 = PROBLEM.suboptimality(run("diana_rr", epochs=1000, gamma=0.2 / PROBLEM.l_max).params["w"])
    assert sub_g < 1e-4          # deep convergence despite omega = 3
    assert sub_g2 < sub_g / 2.5  # superlinear shrinkage with gamma


def test_error_feedback_fixes_topk():
    """Beyond-paper: Top-k is biased — naked it stalls/diverges in the
    heterogeneous setting, with error feedback it converges (Stich et al.
    2018, the remedy the paper's related work points to)."""
    import jax
    import jax.numpy as jnp

    from repro.compression.ops import TopK
    from repro.core.algorithms import init_algorithm, make_epoch_fn
    from repro.data.logreg import make_federated_logreg

    problem = make_federated_logreg(m=10, n_batches=5, batch=10, d=40,
                                    cond=50.0, seed=3, heterogeneous=True)
    loss = problem.loss_fn()
    comp = TopK(fraction=0.1)
    gamma = 0.5 / problem.l_max

    def run(name, epochs=300):
        spec, epoch = make_epoch_fn(name, loss, comp, gamma=gamma, alpha=1.0)
        st = init_algorithm(spec, {"w": jnp.zeros((problem.d,))}, problem.m,
                            problem.n)
        ep = jax.jit(epoch)
        key = jax.random.PRNGKey(0)
        for e in range(epochs):
            key, k = jax.random.split(key)
            st = ep(st, problem.data, k)
        return problem.suboptimality(st.params["w"])

    ef = run("ef_topk_rr")
    naked = run("q_rr")  # same Top-k compressor, no error memory
    assert ef < 5e-3, f"EF Top-k failed to converge: {ef}"
    assert ef < naked * 0.5, (ef, naked)


def test_fedstate_bits_lo_default_matches_init_state_dtype():
    """FedState's NamedTuple default for bits_lo must be a strongly-typed
    f32 scalar like init_state builds — a bare Python 0.0 made tree maps
    over hand-built states promote (f64 leaves under numpy semantics)."""
    import numpy as np

    from repro.core.api import FedState, init_state

    hand = FedState(params={"w": jnp.zeros((2,))}, shifts=None,
                    server_h=None, rounds=jnp.zeros((), jnp.int32),
                    bits=jnp.zeros((), jnp.float32))
    ref = init_state({"w": jnp.zeros((2,))})
    assert np.asarray(hand.bits_lo).dtype == np.float32
    assert np.asarray(hand.bits_lo).shape == np.asarray(ref.bits_lo).shape
    summed = jax.tree.map(lambda a, b: jnp.add(a, b), hand, ref)
    assert summed.bits_lo.dtype == jnp.float32
