"""Per-architecture smoke tests (reduced configs) + decode consistency.

Every assigned arch: one forward/train step on CPU, asserting output shapes
and no NaNs (the (f) deliverable's smoke contract), plus prefill->decode
teacher-forcing consistency against the full forward pass.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, reduced
from repro.models import transformer as T

S, B = 32, 2
KEY = jax.random.key(0)


def make_batch(cfg, s=S, b=B, with_labels=True):
    n = s + 1 if with_labels else s
    batch = {"tokens": jax.random.randint(KEY, (b, n), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            KEY, (b, cfg.vision_patches, cfg.d_model), cfg.dtype)
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            KEY, (b, cfg.encoder_seq, cfg.d_model), cfg.dtype)
    return batch


@pytest.fixture(scope="module", params=ARCH_NAMES)
def arch(request):
    cfg = reduced(get_config(request.param), seq=S)
    params = T.init_params(KEY, cfg)
    return cfg, params


def test_train_step_shapes_and_finite(arch):
    cfg, params = arch
    batch = make_batch(cfg)
    loss, g = jax.jit(jax.value_and_grad(lambda p: T.loss_fn(p, batch, cfg)))(params)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{cfg.name}: loss not finite"
    # one SGD step keeps params finite
    new = jax.tree.map(lambda p, gi: p - 0.01 * gi.astype(p.dtype), params, g)
    for leaf in jax.tree.leaves(new):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))


def test_forward_logits_shape(arch):
    cfg, params = arch
    batch = make_batch(cfg)
    logits = T.forward(params, batch, cfg)
    assert logits.shape == (B, S, cfg.padded_vocab())
    # pad-vocab ids are masked
    if cfg.padded_vocab() > cfg.vocab:
        assert float(logits[..., cfg.vocab:].max()) < -1e29


def test_prefill_decode_matches_forward(arch):
    """Teacher-forced decode must reproduce the forward logits: prefill the
    first S/2 tokens, decode the rest one-by-one, compare at each position."""
    cfg, params = arch
    batch = make_batch(cfg, with_labels=False)
    full = T.forward(
        params, {**batch, "tokens": jnp.pad(batch["tokens"], ((0, 0), (0, 1)))},
        cfg,
    )  # logits for positions 0..S-1
    half = S // 2
    pre_batch = {**batch, "tokens": batch["tokens"][:, :half]}
    logits, cache = T.prefill(params, pre_batch, cfg, cache_len=S + 4)
    np.testing.assert_allclose(
        np.asarray(logits[:, 0], np.float32),
        np.asarray(full[:, half - 1], np.float32),
        atol=0.1, rtol=0.05,
    )
    decode = jax.jit(lambda c, t, p: T.decode_step(params, c, t, p, cfg))
    # MoE archs: bf16-vs-f32 prob rounding between the train and decode
    # attention paths can flip near-tie router decisions at a few positions,
    # which discretely changes those logits — tolerate sparse flips there.
    max_bad_frac = 0.25 if cfg.num_experts else 0.0
    bad = 0
    for i in range(half, S):
        tok = batch["tokens"][:, i:i + 1]
        logits, cache = decode(cache, tok, jnp.int32(i))
        diff = np.abs(np.asarray(logits[:, 0], np.float32)
                      - np.asarray(full[:, i], np.float32))
        tol = 0.1 + 0.05 * np.abs(np.asarray(full[:, i], np.float32))
        if (diff > tol).any():
            bad += 1
    n = S - half
    assert bad <= max_bad_frac * n, (
        f"{cfg.name}: decode diverges from forward at {bad}/{n} positions")


def test_sliding_window_ring_buffer(arch):
    """For SWA archs, decoding past the window must keep working (ring
    wrap) and stay finite."""
    cfg, params = arch
    if not cfg.sliding_window:
        pytest.skip("full-attention arch")
    w = cfg.sliding_window
    batch = make_batch(cfg, with_labels=False)
    pre = {**batch, "tokens": batch["tokens"][:, :4]}
    _, cache = T.prefill(params, pre, cfg, cache_len=S)
    decode = jax.jit(lambda c, t, p: T.decode_step(params, c, t, p, cfg))
    logits = None
    for i in range(4, 4 + 2 * w):  # decode well past the window
        tok = jnp.full((B, 1), (i * 7) % cfg.vocab, jnp.int32)
        logits, cache = decode(cache, tok, jnp.int32(i))
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_unroll_matches_scan(arch):
    """Python-loop layer traversal (dry-run probes) == lax.scan traversal."""
    cfg, params = arch
    batch = make_batch(cfg)
    a = T.loss_fn(params, batch, cfg, unroll=False)
    b = T.loss_fn(params, batch, cfg, unroll=True)
    np.testing.assert_allclose(float(a), float(b), rtol=2e-3)


def test_vlm_loss_masks_patch_positions():
    cfg = reduced(get_config("qwen2-vl-2b"), seq=S)
    params = T.init_params(KEY, cfg)
    batch = make_batch(cfg)
    # changing labels under the patch positions must not change the loss
    loss1 = T.loss_fn(params, batch, cfg)
    toks = batch["tokens"].at[:, 1:cfg.vision_patches].set(1)
    loss2 = T.loss_fn(params, {**batch, "tokens": toks}, cfg)
    np.testing.assert_allclose(float(loss1), float(loss2), rtol=1e-5)


def test_rwkv6_state_decode_long():
    """Attention-free decode has O(1) state: position can exceed any cache
    capacity (the long_500k contract)."""
    cfg = reduced(get_config("rwkv6-7b"), seq=S)
    params = T.init_params(KEY, cfg)
    batch = make_batch(cfg, with_labels=False)
    pre = {**batch, "tokens": batch["tokens"][:, :8]}
    _, cache = T.prefill(params, pre, cfg, cache_len=8)
    decode = jax.jit(lambda c, t, p: T.decode_step(params, c, t, p, cfg))
    logits, cache = decode(cache, batch["tokens"][:, :1], jnp.int32(500_000))
    assert bool(jnp.all(jnp.isfinite(logits)))
