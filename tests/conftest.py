"""Force 8 host devices for the test session.

The dist-layer tests need a small multi-device mesh. 8 devices keeps the
smoke tests fast on one CPU core. The 512-device production mesh is ONLY
created by launch/dryrun.py (per its own XLA_FLAGS header) — never here.

Also: `hypothesis` is an optional dependency. When it is absent (minimal CI
images) we install a stub that marks @given property tests as skipped so the
rest of each module still collects and runs.
"""
import os
import sys
import types

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import pytest


@pytest.fixture
def mesh_4x2():
    """Flat single-pod mesh: 4 clients ("data") x 2-way TP ("model")."""
    from repro.launch.mesh import make_test_mesh

    return make_test_mesh((4, 2), ("data", "model"))


@pytest.fixture
def mesh_2x2x2():
    """Two-pod mesh: 2 pods x 2 in-pod clients ("data") x 2-way TP — the
    smallest mesh that exercises BOTH levels of the hierarchical wire."""
    from repro.launch.mesh import make_test_mesh

    return make_test_mesh((2, 2, 2), ("pod", "data", "model"))


@pytest.fixture
def mesh_1x4x2():
    """Single-pod mesh WITH a pod axis (size 1): the two-level wire code
    path whose output must bit-match mesh_4x2's flat wire."""
    from repro.launch.mesh import make_test_mesh

    return make_test_mesh((1, 4, 2), ("pod", "data", "model"))

try:  # pragma: no cover - depends on the environment
    import hypothesis  # noqa: F401
except ImportError:  # build a skip-only stand-in
    import pytest

    def _given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    def _settings(*_a, **_k):
        return lambda fn: fn

    def _strategy(*_a, **_k):
        return None

    _st = types.ModuleType("hypothesis.strategies")
    for _name in ("integers", "floats", "sampled_from", "booleans", "lists",
                  "tuples", "just", "one_of"):
        setattr(_st, _name, _strategy)

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
