"""Force 8 host devices for the test session.

The dist-layer tests need a small multi-device mesh. 8 devices keeps the
smoke tests fast on one CPU core. The 512-device production mesh is ONLY
created by launch/dryrun.py (per its own XLA_FLAGS header) — never here.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
