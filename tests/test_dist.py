"""Production wire (core.dist): shard_map aggregation semantics.

Runs on 8 forced host devices (mesh 4x2 = data x model), set in conftest for
this module only via a subprocess-free trick: these tests are skipped unless
the session was started with at least 8 devices — `tests/conftest.py` forces
8 host devices for the whole test session (smoke tests use a mesh-free path,
so this is safe; the 512-device production mesh is ONLY in launch/dryrun.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.dist import CompressedAggregation

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8, reason="needs 8 forced host devices"
)

# version compat: jax.shard_map/AxisType landed after the 0.4.x pin
if hasattr(jax, "shard_map"):
    from jax.sharding import AxisType

    def _shard_map(f, mesh, in_specs, out_specs):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)

    def _mesh():
        return jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(AxisType.Auto,) * 2)
else:
    from jax.experimental.shard_map import shard_map as _exp_shard_map

    def _shard_map(f, mesh, in_specs, out_specs):
        return _exp_shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=False)

    def _mesh():
        return jax.make_mesh((4, 2), ("data", "model"))


GRADS = {
    "w": jnp.arange(4 * 64, dtype=jnp.float32).reshape(4, 64) / 100.0,
    "b": jnp.ones((4, 8), jnp.float32),
}
SPECS = {"w": P("data", "model"), "b": P("data", None)}
MEAN = jax.tree.map(lambda x: x.mean(0), GRADS)


def _run_rounds(agg, rounds):
    def body(g):
        g = jax.tree.map(lambda x: x[0], g)
        state = agg.init(g)
        key = jax.random.PRNGKey(0)

        def one(state, t):
            d, state = agg.aggregate(g, state, jax.random.fold_in(key, t))
            return state, d

        _, ds = jax.lax.scan(one, state, jnp.arange(rounds))
        d = jax.tree.map(lambda x: x[-1], ds)
        return jax.tree.map(lambda x: x[None], d)

    out = jax.jit(
        _shard_map(body, _mesh(), (SPECS,), SPECS)
    )(GRADS)
    return jax.tree.map(lambda x: x[0], out)


def test_dense_is_exact_mean():
    agg = CompressedAggregation(method="dense", client_axes=("data",))
    got = _run_rounds(agg, 1)
    for k in GRADS:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(MEAN[k]), rtol=1e-6)


def test_diana_shared_converges_to_exact_mean():
    """Fixed gradients: shifts absorb them; direction -> exact mean (Thm 2
    fixed-point logic on the production wire)."""
    agg = CompressedAggregation(method="diana", wire="shared", fraction=0.25,
                                client_axes=("data",), shift_dtype=jnp.float32)
    got = _run_rounds(agg, 200)
    for k in GRADS:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(MEAN[k]), atol=1e-5)


def test_diana_independent_converges():
    agg = CompressedAggregation(method="diana", wire="independent", fraction=0.5,
                                client_axes=("data",), shift_dtype=jnp.float32)
    got = _run_rounds(agg, 300)
    for k in GRADS:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(MEAN[k]), atol=5e-2)


def test_q_shared_unbiased():
    """Averaging many Q-rounds approaches the true mean (unbiasedness)."""
    agg = CompressedAggregation(method="q", wire="shared", fraction=0.25,
                                client_axes=("data",))

    def body(g):
        g = jax.tree.map(lambda x: x[0], g)
        key = jax.random.PRNGKey(0)

        def one(acc, t):
            d, _ = agg.aggregate(g, None, jax.random.fold_in(key, t))
            return jax.tree.map(jnp.add, acc, d), None

        acc, _ = jax.lax.scan(one, jax.tree.map(jnp.zeros_like, g), jnp.arange(2000))
        acc = jax.tree.map(lambda a: a / 2000.0, acc)
        return jax.tree.map(lambda x: x[None], acc)

    out = jax.jit(
        _shard_map(body, _mesh(), (SPECS,), SPECS)
    )(GRADS)
    got = jax.tree.map(lambda x: x[0], out)
    for k in GRADS:
        scale = float(jnp.abs(MEAN[k]).max())
        assert float(jnp.abs(got[k] - MEAN[k]).max()) < 0.15 * scale + 0.05


def test_shift_lr_default_matches_theory():
    agg = CompressedAggregation(fraction=0.02)
    assert abs(agg.shift_lr - 0.02) < 1e-9  # 1/(1+omega) = k/d
    agg2 = CompressedAggregation(fraction=0.25, alpha=0.1)
    assert agg2.shift_lr == 0.1
