"""repro.analysis: the invariant linter and the jaxpr wire census.

Layer 1 is tested against in-memory fixtures — including two regression
fixtures that reproduce, minimally, the silent bugs of PR 3 (a sampler that
`del`s its epoch argument) and PR 4 (plain-f32 bits accumulation) — each
caught by exactly one named rule. Layer 2 is tested by tracing the real
train steps on the shared conftest meshes and pinning the collective
census. Finally, the repo itself must lint clean against the EMPTY
checked-in baseline — the CI gate, as a test.
"""
import json
import textwrap
from pathlib import Path

import pytest

from repro.analysis import lint_paths, lint_source, rule_catalog
from repro.analysis.findings import apply_baseline, load_baseline

REPO = Path(__file__).resolve().parents[1]


def _lint(src: str, rel: str = "src/repro/somewhere/mod.py"):
    return lint_source(textwrap.dedent(src), rel)


def _rules(findings):
    return [f.rule for f in findings]


# -- layer 1: rng purity ------------------------------------------------------


def test_rng_unseeded_and_bare_int_seed_flagged():
    f = _lint("""
        import numpy as np
        a = np.random.default_rng()
        b = np.random.default_rng(seed)
    """)
    assert _rules(f) == ["rng-unstructured-seed", "rng-unstructured-seed"]


def test_rng_structured_tuple_passes_but_literal_salt_flagged():
    clean = _lint("""
        import numpy as np
        from repro.core import salts
        rng = np.random.default_rng((seed, salts.WR_COHORT_SALT, rnd))
    """)
    assert clean == []
    f = _lint("""
        import numpy as np
        rng = np.random.default_rng((seed, 0x5EED, rnd))
    """)
    assert _rules(f) == ["rng-literal-salt"]


def test_rng_bare_jax_key_and_global_numpy_flagged():
    f = _lint("""
        import jax
        import numpy as np
        k = jax.random.key(0)
        np.random.seed(3)
        x = np.random.rand(4)
    """)
    assert _rules(f) == ["rng-unstructured-seed"] * 3


def test_rng_fold_in_literal_and_salt_assignment_flagged():
    f = _lint("""
        import jax
        k2 = jax.random.fold_in(key, 7)
        MY_SALT = 0x1234
    """)
    assert sorted(_rules(f)) == ["rng-literal-salt", "rng-literal-salt"]


def test_rng_salts_module_itself_is_exempt():
    assert _lint("""
        POD_KEY_SALT = 0x70D5
    """, rel="src/repro/core/salts.py") == []


# -- layer 1: ignored arguments (the PR 3 regression) -------------------------

PR3_SAMPLER = """
    import numpy as np

    from repro.core import salts

    class Sampler:
        def __init__(self, seed, n):
            self.rng = np.random.default_rng((seed, salts.WR_COHORT_SALT))
            self.n = n

        def sample(self, epoch):
            del epoch  # looked harmless in review
            return self.rng.permutation(self.n)
"""


def test_pr3_del_epoch_sampler_caught_by_exactly_one_rule():
    """The PR 3 bug class: the signature promises epoch-indexed draws, the
    body advances a mutable rng instead — near-with-replacement sampling
    behind a without-replacement API."""
    f = _lint(PR3_SAMPLER)
    assert len(f) == 1 and f[0].rule == "ignored-argument"
    assert "epoch" in f[0].message


def test_ignored_argument_never_read_without_del():
    f = _lint("""
        def scale(x, gamma):
            return x * 2.0
    """)
    assert _rules(f) == ["ignored-argument"]
    assert "gamma" in f[0].message


def test_ignored_argument_exemptions():
    clean = _lint("""
        import abc

        def _private(unused):
            return 1

        def stub(x, y):
            ...

        class Proto:
            @abc.abstractmethod
            def step(self, epoch):
                raise NotImplementedError

        def outer(items):
            def inner(unused_inner):  # nested defs are not API surface
                return 0
            return [inner(i) for i in items]
    """)
    assert clean == []


# -- layer 1: bits accounting (the PR 4 regression) ---------------------------

PR4_ACCUMULATOR = """
    import jax.numpy as jnp

    def charge_round(state, per_round):
        new_bits = state.bits + jnp.float32(per_round)
        return state._replace(bits=new_bits)
"""


def test_pr4_plain_f32_bits_accumulation_caught_by_exactly_one_rule():
    """The PR 4 bug class: a plain f32 running total stalls once it crosses
    ~2^24 and the reported communication cost silently flatlines."""
    f = _lint(PR4_ACCUMULATOR)
    assert len(f) == 1 and f[0].rule == "bits-accounting"


def test_bits_augassign_flagged_and_api_module_exempt():
    f = _lint("""
        def g(bits, inc):
            bits += inc
            return bits
    """)
    assert "bits-accounting" in _rules(f)
    assert _lint("""
        def accumulate_bits(bits, bits_lo, inc):
            s = bits + inc
            return s, bits_lo - (s - bits)
    """, rel="src/repro/core/api.py") == []


def test_bits_lookalike_names_not_flagged():
    assert _lint("""
        def h(bits_per_round, x):
            return bits_per_round + x
    """) == []


# -- layer 1: kernel imports --------------------------------------------------


def test_kernel_import_flagged_outside_backend():
    f = _lint("""
        from repro.kernels.randk import BLOCK_ROWS
    """, rel="src/repro/core/dist.py")
    assert _rules(f) == ["kernel-import"]


def test_kernel_import_allowed_in_backend_and_kernels():
    src = "from repro.kernels.randk import BLOCK_ROWS\n"
    assert lint_source(src, "src/repro/compression/backend.py") == []
    assert lint_source(src, "src/repro/kernels/ops.py") == []


def test_pack_kernel_import_only_via_backend():
    """The new pack/unpack kernels obey the same boundary: reachable from
    the compression backend (and within repro/kernels/), a lint error
    anywhere else — callers must go through `wire_exchange`."""
    src = "from repro.kernels.pack import pack_slab\n"
    assert lint_source(src, "src/repro/compression/backend.py") == []
    assert lint_source(src, "src/repro/kernels/ref.py") == []
    f = _lint("""
        from repro.kernels.pack import pack_slab
    """, rel="src/repro/core/dist.py")
    assert _rules(f) == ["kernel-import"]


# -- layer 1: trace hazards ---------------------------------------------------


def test_trace_hazard_in_jitted_function():
    f = _lint("""
        import time
        import jax

        def step(x):
            t0 = time.time()
            return x * t0

        run = jax.jit(step)
    """)
    assert _rules(f) == ["trace-hazard"]


def test_trace_hazard_reaches_through_local_calls():
    f = _lint("""
        import time
        import jax

        def helper(x):
            return x + time.time()

        def step(x):
            return helper(x)

        run = jax.jit(step)
    """)
    assert _rules(f) == ["trace-hazard"]


def test_trace_hazard_untraced_function_is_fine():
    assert _lint("""
        import time

        def wall_clock():
            return time.time()
    """) == []


def test_trace_hazard_float_cast_heuristic():
    f = _lint("""
        import jax
        import jax.numpy as jnp

        @jax.jit
        def step(x):
            return float(jnp.sum(x))
    """)
    assert _rules(f) == ["trace-hazard"]
    # int() on host arithmetic (no jnp/jax/lax in the subtree) is fine
    assert _lint("""
        import jax

        @jax.jit
        def step(x, fraction, size):
            k = int(fraction * size)
            return x[:k]
    """) == []


# -- suppression semantics ----------------------------------------------------


def test_allow_with_rationale_suppresses():
    assert _lint("""
        import jax
        k = jax.random.key(0)  # analysis: allow[rng-unstructured-seed] test fixture key
    """) == []


def test_allow_without_rationale_is_a_finding():
    f = _lint("""
        import jax
        k = jax.random.key(0)  # analysis: allow[rng-unstructured-seed]
    """)
    assert sorted(_rules(f)) == ["allow-missing-rationale",
                                 "rng-unstructured-seed"]


def test_stale_allow_is_a_finding():
    f = _lint("""
        x = 1  # analysis: allow[bits-accounting] nothing here violates it
    """)
    assert _rules(f) == ["stale-allow"]


def test_comment_only_line_allow_covers_next_code_line():
    assert _lint("""
        import jax
        # analysis: allow[rng-unstructured-seed] fixture key; continuation
        # comments between the annotation and the code are fine
        k = jax.random.key(0)
    """) == []


def test_docstring_mention_is_not_an_annotation():
    f = _lint('''
        import jax

        def doc():
            """Write `# analysis: allow[rng-unstructured-seed] why` inline."""
            return jax.random.key(0)
    ''')
    assert _rules(f) == ["rng-unstructured-seed"]


def test_baseline_schema_and_staleness(tmp_path):
    p = tmp_path / "baseline.json"
    p.write_text(json.dumps({"suppressions": [
        {"rule": "kernel-import", "file": "src/x.py", "reason": "legacy"}]}))
    entries = load_baseline(p)
    out = apply_baseline([], entries, baseline_file=str(p))
    assert _rules(out) == ["stale-baseline"]

    p.write_text(json.dumps({"suppressions": [
        {"rule": "kernel-import", "file": "src/x.py"}]}))
    with pytest.raises(ValueError, match="reason"):
        load_baseline(p)
    p.write_text(json.dumps({"wrong": []}))
    with pytest.raises(ValueError, match="suppressions"):
        load_baseline(p)


def test_rule_catalog_covers_all_emitted_rules():
    cat = rule_catalog()
    for rule in ("rng-unstructured-seed", "rng-literal-salt",
                 "ignored-argument", "bits-accounting", "kernel-import",
                 "trace-hazard", "allow-missing-rationale", "stale-allow",
                 "stale-baseline", "syntax-error"):
        assert rule in cat, rule
    from repro.analysis import graph
    for rule in graph.RULES:
        assert rule not in cat  # census rules are layer-2, documented there


# -- the salt registry --------------------------------------------------------


def test_salt_registry_unique_and_complete():
    from repro.core import salts

    reg = salts.registered_salts()
    values = list(reg.values())
    assert len(values) == len(set(values)), "salt value collision"
    # the literals that used to be scattered across modules kept their
    # values (checkpoint/stream compatibility)
    assert reg["POD_KEY_SALT"] == 0x70D5
    assert reg["WR_COHORT_SALT"] == 0x5EED
    assert reg["CHAOS_DROP_SALT"] == 0xD42C
    assert reg["CHAOS_LATENCY_SALT"] == 0x1A7E
    assert reg["CHAOS_IO_SALT"] == 0x10FA
    assert reg["NASTYA_PERM_SALT"] == 1
    assert reg["NASTYA_LOCAL_SALT"] == 2


def test_salt_registry_rejects_collisions():
    from repro.core import salts

    with pytest.raises(ValueError, match="collides"):
        salts._register("TEST_COLLIDING_SALT", 0x70D5)
    with pytest.raises(ValueError, match="twice"):
        salts._register("POD_KEY_SALT", 0xFFFF1)
    assert "TEST_COLLIDING_SALT" not in salts.registered_salts()


def test_root_key_matches_manual_construction():
    import jax

    from repro.core import salts

    k = salts.root_key(7, salts.PARAMS_KEY_SALT)
    expect = jax.random.fold_in(jax.random.key(7), salts.PARAMS_KEY_SALT)
    assert jax.numpy.array_equal(jax.random.key_data(k),
                                 jax.random.key_data(expect))


# -- the repo itself lints clean (the CI gate, as a test) ---------------------


def test_repo_lints_clean_against_checked_in_baseline():
    findings = lint_paths([REPO / "src" / "repro"], repo_root=REPO)
    entries = load_baseline(REPO / "analysis_baseline.json")
    left = apply_baseline(findings, entries)
    assert left == [], "\n".join(str(f) for f in left)


def test_checked_in_baseline_is_empty():
    """The baseline is an escape hatch, not a dumping ground: the repo ships
    with zero suppressions, so any new finding fails CI loudly."""
    assert load_baseline(REPO / "analysis_baseline.json") == []


# -- layer 2: jaxpr census on the shared test meshes --------------------------


@pytest.fixture(scope="module")
def census_cfg():
    from repro.configs import get_config, reduced

    return reduced(get_config("stablelm-1.6b"), seq=16)


@pytest.mark.parametrize("method", ["q", "diana", "diana_rr", "ef"])
def test_census_psum_counts_flat_mesh(census_cfg, mesh_4x2, method):
    """Flat wire on the TP=2 mesh: exactly L psums, all over "data" — one
    per parameter leaf, nothing over "model" (GSPMD comms are invisible at
    jaxpr level; an explicit model-axis psum would be a stray collective)."""
    import jax

    from repro.analysis import graph

    traced, _, abstract, _ = graph._trace_step(census_cfg, mesh_4x2, method)
    levels = graph.collective_census(traced.jaxpr.jaxpr)
    L = len(jax.tree.leaves(abstract.params))
    assert set(levels) == {("data",)}
    assert levels[("data",)][0] == L


@pytest.mark.parametrize("method", ["q", "diana", "diana_rr", "ef"])
def test_census_psum_counts_two_pod_mesh(census_cfg, mesh_2x2x2, method):
    """Hierarchical wire: L psums over "data" (intra-pod) plus L over "pod"
    (inter-pod), and nothing else."""
    import jax

    from repro.analysis import graph

    traced, _, abstract, _ = graph._trace_step(census_cfg, mesh_2x2x2, method)
    levels = graph.collective_census(traced.jaxpr.jaxpr)
    L = len(jax.tree.leaves(abstract.params))
    assert set(levels) == {("data",), ("pod",)}
    assert levels[("data",)][0] == L
    assert levels[("pod",)][0] == L


@pytest.mark.parametrize("label,shape,axes", [
    ("flat", (4, 1), ("data", "model")),
    ("two_pod", (2, 2, 1), ("pod", "data", "model")),
])
def test_census_full_checks_clean_on_tp1(census_cfg, label, shape, axes):
    """The CLI's own census points (TP=1: exact byte equality against
    wire_bytes_per_round, donation audit, dtype audit) report nothing."""
    from repro.analysis import graph
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh(shape, axes)
    findings = []
    for method in graph.CENSUS_METHODS:
        findings.extend(graph.check_step(census_cfg, mesh, method, label))
    assert findings == [], "\n".join(str(f) for f in findings)


@pytest.mark.parametrize("method", ["q", "diana_rr"])
def test_census_packed_all_gather_counts_flat_mesh(census_cfg, mesh_4x2,
                                                   method):
    """Packed wire on the TP=2 mesh: the slab travels as all_gathers — TWO
    per leaf (bytes + scale sideband), all over "data" — and ZERO psums
    touch the wire axes (the packed wire replaces the collective, it does
    not add one)."""
    import jax

    from repro.analysis import graph

    traced, _, abstract, _ = graph._trace_step(census_cfg, mesh_4x2, method,
                                               wire_dtype="packed8")
    jxp = traced.jaxpr.jaxpr
    L = len(jax.tree.leaves(abstract.params))
    gathers = graph.collective_census(jxp, primitive="all_gather")
    assert set(gathers) == {("data",)}
    assert gathers[("data",)][0] == 2 * L
    psums = graph.collective_census(jxp, primitive="psum")
    assert ("data",) not in psums and ("pod",) not in psums


def test_census_packed_all_gather_counts_two_pod_mesh(census_cfg,
                                                      mesh_2x2x2):
    """Both wire levels packed: 2L all_gathers over "data" AND over "pod",
    no wire-axis psums anywhere."""
    import jax

    from repro.analysis import graph

    traced, _, abstract, _ = graph._trace_step(
        census_cfg, mesh_2x2x2, "diana_rr", wire_dtype="packed8")
    jxp = traced.jaxpr.jaxpr
    L = len(jax.tree.leaves(abstract.params))
    gathers = graph.collective_census(jxp, primitive="all_gather")
    assert set(gathers) == {("data",), ("pod",)}
    assert gathers[("data",)][0] == 2 * L
    assert gathers[("pod",)][0] == 2 * L
    psums = graph.collective_census(jxp, primitive="psum")
    assert ("data",) not in psums and ("pod",) not in psums


@pytest.mark.parametrize("wire_dtype", ["packed8", "packed4", "bf16"])
@pytest.mark.parametrize("label,shape,axes", [
    ("flat", (4, 1), ("data", "model")),
    ("two_pod", (2, 2, 1), ("pod", "data", "model")),
])
def test_census_full_checks_clean_packed_tp1(census_cfg, label, shape, axes,
                                             wire_dtype):
    """check_step's packed/bf16 points (TP=1: collective payload bytes ==
    the analytic packed accounting exactly, stray-primitive sweep) report
    nothing on either CLI mesh."""
    from repro.analysis import graph
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh(shape, axes)
    findings = graph.check_step(census_cfg, mesh, "diana", label,
                                wire_dtype=wire_dtype)
    assert findings == [], "\n".join(str(f) for f in findings)


def test_census_elastic_weights_are_live(census_cfg):
    from repro.analysis import graph
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh((4, 1), ("data", "model"))
    assert graph.check_elastic(census_cfg, mesh, "flat") == []


def test_census_telemetry_identity(census_cfg, mesh_4x2):
    """The zero-cost-when-off claim in compiled form: tracing the step
    with an active in-memory sink yields a byte-identical jaxpr (telemetry
    lives strictly host-side of the jit boundary), and the check itself
    never leaks an installed sink."""
    from repro import telemetry
    from repro.analysis import graph

    assert graph.check_telemetry_identity(census_cfg, mesh_4x2, "flat",
                                          method="diana_rr") == []
    assert telemetry.active() is None


def test_census_detects_a_broken_wire_model(census_cfg):
    """Sanity that the census would actually fire: feed check_step a wire
    whose analytic accounting we deliberately corrupt."""
    import dataclasses

    from repro.analysis import graph
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh((4, 1), ("data", "model"))
    real = graph._trace_step

    def corrupted(cfg, mesh_, method, **kw):
        traced, lowered, abstract, agg = real(cfg, mesh_, method, **kw)
        return traced, lowered, abstract, dataclasses.replace(
            agg, fraction=agg.fraction / 2)  # analytic model now disagrees

    graph._trace_step, saved = corrupted, graph._trace_step
    try:
        findings = graph.check_step(census_cfg, mesh, "diana", "flat")
    finally:
        graph._trace_step = saved
    assert any(f.rule == "census-collective-bytes" for f in findings)
