"""Unit tests of the shared shift-rule layer (repro.core.rules) and the
satellite fixes that ride with it: the Kahan bits accounting, the
`ef_topk_rr` theory stepsize, the simplified default-compressor condition,
and the shared-order sampler/slot helpers the per-slot wire consumes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compression.backend import CompressionBackend
from repro.core.api import accumulate_bits, init_state
from repro.core.rules import RULES, WIRE_RULES, get_rule

BACKENDS = [CompressionBackend("reference"), CompressionBackend("pallas")]


# ---------------------------------------------------------------------------
# layouts
# ---------------------------------------------------------------------------

def test_rule_registry():
    assert set(RULES) == {"none", "single", "per_slot", "ef"}
    assert set(WIRE_RULES) == {"dense", "q", "diana", "diana_rr", "ef"}
    with pytest.raises(ValueError):
        get_rule("banana")


def test_init_shift_layouts():
    params = {"w": jnp.zeros((5, 3)), "b": jnp.zeros((2,))}
    m, n = 4, 6
    assert get_rule("none").init_shifts(params, m, n_slots=n) is None
    single = get_rule("single").init_shifts(params, m, n_slots=n)
    assert single["w"].shape == (m, 5, 3)
    slot = get_rule("per_slot").init_shifts(params, m, n_slots=n)
    assert slot["w"].shape == (m, n, 5, 3) and slot["b"].shape == (m, n, 2)
    ef = get_rule("ef").init_shifts(params, m, n_slots=n)
    assert ef["w"].shape == (m, 5, 3)
    # wire layout: m=None drops the client axis (the mesh is the client axis)
    wire = get_rule("per_slot").init_shifts(params, None, n_slots=n,
                                            dtype=jnp.bfloat16)
    assert wire["w"].shape == (n, 5, 3) and wire["w"].dtype == jnp.bfloat16
    assert get_rule("single").init_shifts(params, None)["w"].shape == (5, 3)


# ---------------------------------------------------------------------------
# arithmetic: each rule's select/payload/update/scatter against hand math
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("be", BACKENDS, ids=lambda b: b.name)
def test_single_shift_round_matches_hand_math(be):
    rule = get_rule("single")
    rng = np.random.default_rng(0)
    h = {"w": jnp.asarray(rng.normal(size=(3, 8)), jnp.float32)}
    g = {"w": jnp.asarray(rng.normal(size=(3, 8)), jnp.float32)}
    alpha = 0.25
    sel = rule.select(h, None)
    p = rule.payload(g, sel, gamma=0.1)
    np.testing.assert_allclose(np.asarray(p["w"]),
                               np.asarray(g["w"] - h["w"]), rtol=1e-6)
    ghat, h_new, _ = rule.update(sel, p, sel, p, alpha=alpha, backend=be)
    np.testing.assert_allclose(np.asarray(ghat["w"]), np.asarray(g["w"]),
                               atol=1e-6)  # h + (g - h)
    np.testing.assert_allclose(np.asarray(h_new["w"]),
                               np.asarray(h["w"] + alpha * p["w"]), atol=1e-6)
    assert rule.scatter(h, None, h_new) is h_new


@pytest.mark.parametrize("be", BACKENDS, ids=lambda b: b.name)
def test_per_slot_round_touches_only_its_slot(be):
    rule = get_rule("per_slot")
    m, n, d = 3, 4, 8
    rng = np.random.default_rng(1)
    shifts = {"w": jnp.asarray(rng.normal(size=(m, n, d)), jnp.float32)}
    g = {"w": jnp.asarray(rng.normal(size=(m, d)), jnp.float32)}
    col = jnp.asarray([2, 0, 3])
    idx = (jnp.arange(m), col)
    alpha = 0.5
    h = rule.select(shifts, idx)
    assert h["w"].shape == (m, d)
    np.testing.assert_array_equal(np.asarray(h["w"][1]),
                                  np.asarray(shifts["w"][1, 0]))
    p = rule.payload(g, h)
    _, h_new, _ = rule.update(h, p, h, p, alpha=alpha, backend=be)
    out = rule.scatter(shifts, idx, h_new)
    got = np.asarray(out["w"])
    want = np.asarray(shifts["w"]).copy()
    for i, s in enumerate(np.asarray(col)):
        want[i, s] += alpha * np.asarray(p["w"][i])
    np.testing.assert_allclose(got, want, atol=1e-6)


def test_ef_rule_residual_and_direction():
    rule = get_rule("ef")
    be = BACKENDS[0]
    e = {"w": jnp.asarray([1.0, -2.0, 0.5])}
    g = {"w": jnp.asarray([0.5, 4.0, -1.0])}
    gamma = 0.2
    p = rule.payload(g, e, gamma=gamma)
    np.testing.assert_allclose(np.asarray(p["w"]),
                               gamma * np.asarray(g["w"]) + np.asarray(e["w"]))
    q = {"w": jnp.asarray([0.6, 0.0, -0.9])}  # a pretend compression of p
    d, e_new, _ = rule.update(e, q, None, q, alpha=0.0, gamma=gamma,
                              backend=be, payload=p)
    np.testing.assert_allclose(np.asarray(d["w"]), np.asarray(q["w"]) / gamma)
    np.testing.assert_allclose(np.asarray(e_new["w"]),
                               np.asarray(p["w"]) - np.asarray(q["w"]))
    assert rule.contractive  # the wire must NOT apply the d/k scaling


def test_local_family_direction_single_shift():
    rule = get_rule("single")
    be = BACKENDS[0]
    H = {"w": jnp.asarray([1.0, 2.0])}
    mq = {"w": jnp.asarray([0.5, -0.5])}
    d, H_new = rule.direction(H, mq, alpha=0.5, backend=be)
    np.testing.assert_allclose(np.asarray(d["w"]), [1.5, 1.5])
    np.testing.assert_allclose(np.asarray(H_new["w"]), [1.25, 1.75])
    # NoShift: pass-through server side
    d2, H2 = get_rule("none").direction(None, mq, alpha=0.5, backend=be)
    assert d2 is mq and H2 is None


def test_local_family_rejects_slot_and_ef_rules():
    from repro.core.algorithms import ALGORITHMS, make_epoch_fn
    import dataclasses

    spec = dataclasses.replace(ALGORITHMS["q_nastya"], shift_mode="per_slot")
    loss = lambda p, b: jnp.sum(p["w"] ** 2)
    from repro.core.algorithms import _local_epoch
    from repro.compression.backend import get_backend

    state = init_state({"w": jnp.zeros((3,))})
    data = {"x": jnp.zeros((2, 2, 1))}
    with pytest.raises(ValueError, match="local-family"):
        _local_epoch(spec, loss, None, 0.1, 0.1, 0.5, get_backend("reference"),
                     state, data, jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# satellite: Kahan bits accounting keeps counting past the f32 mantissa
# ---------------------------------------------------------------------------

def test_accumulate_bits_past_f32_mantissa():
    start = jnp.float32(2.0 ** 24)
    inc = jnp.float32(1.0)  # 2^24 + 1 is NOT representable in f32

    def naive(b, _):
        return b + inc, None

    def kahan(carry, _):
        return accumulate_bits(*carry, inc), None

    steps = 10_000
    stalled, _ = jax.lax.scan(lambda b, x: (b + inc, None), start,
                              None, length=steps)
    assert float(stalled) == 2.0 ** 24  # the seed bug: silently stuck

    (bits, lo), _ = jax.lax.scan(
        lambda c, x: (accumulate_bits(c[0], c[1], inc), None),
        (start, jnp.float32(0.0)), None, length=steps)
    total = float(bits) - float(lo)
    assert abs(total - (2.0 ** 24 + steps)) <= 4.0, total


def test_fedstate_bits_keep_incrementing_in_driver():
    from repro.core.algorithms import ALGORITHMS, init_algorithm, make_epoch_fn
    from repro.compression.ops import RandK
    from repro.data.logreg import make_federated_logreg

    prob = make_federated_logreg(m=4, n_batches=3, batch=4, d=8, cond=5.0,
                                 seed=0)
    spec, epoch = make_epoch_fn("q_rr", prob.loss_fn(), RandK(fraction=0.5),
                                gamma=0.01)
    st = init_algorithm(spec, {"w": jnp.zeros((prob.d,))}, prob.m, prob.n)
    st = st._replace(bits=jnp.float32(2.0 ** 27))  # deep into stall territory
    before = float(st.bits) - float(st.bits_lo)
    ep = jax.jit(epoch)
    for e in range(3):
        st = ep(st, prob.data, jax.random.PRNGKey(e))
    after = float(st.bits) - float(st.bits_lo)
    # q_rr sends m * n * bits(RandK) per epoch; must all land despite the
    # huge running total
    from repro.compression.ops import tree_compression_bits
    inc = 3 * prob.n * prob.m * tree_compression_bits(
        RandK(fraction=0.5), {"w": jnp.zeros((prob.d,))})
    assert abs((after - before) - inc) <= 8.0, (after - before, inc)


# ---------------------------------------------------------------------------
# satellite: theory stepsizes cover the beyond-paper EF method
# ---------------------------------------------------------------------------

def test_theoretical_stepsizes_ef_topk_rr():
    from repro.core.algorithms import theoretical_stepsizes

    out = theoretical_stepsizes("ef_topk_rr", l_max=10.0, mu=0.1, omega=9.0,
                                m=8, n=4)
    assert out["gamma"] == pytest.approx((1.0 / 10.0) / (2.0 * 10.0))
    # every named algorithm now has a theory default
    from repro.core.algorithms import ALGORITHMS
    for name in ALGORITHMS:
        got = theoretical_stepsizes(name, l_max=10.0, mu=0.1, omega=3.0,
                                    m=8, n=4)
        assert got["gamma"] > 0.0


# ---------------------------------------------------------------------------
# satellite: default-compressor condition (the dead branch is gone)
# ---------------------------------------------------------------------------

def test_make_epoch_fn_default_compressor():
    from repro.compression.ops import Identity, RandK
    from repro.core.algorithms import init_algorithm, make_epoch_fn

    loss = lambda p, b: jnp.mean((p["w"] - b["x"]) ** 2)
    data = {"x": jnp.ones((2, 2, 1))}
    # no compressor -> identity, even for default-compressed methods
    spec, epoch = make_epoch_fn("q_rr", loss, None, gamma=0.1)
    st = init_algorithm(spec, {"w": jnp.zeros(())}, 2, 2)
    st1 = jax.jit(epoch)(st, data, jax.random.PRNGKey(0))
    spec2, epoch2 = make_epoch_fn("rr", loss, None, gamma=0.1)
    st2 = jax.jit(epoch2)(init_algorithm(spec2, {"w": jnp.zeros(())}, 2, 2),
                          data, jax.random.PRNGKey(0))
    # identity-compressed q_rr IS rr
    np.testing.assert_allclose(np.asarray(st1.params["w"]),
                               np.asarray(st2.params["w"]), rtol=1e-7)


# ---------------------------------------------------------------------------
# shared-order sampler + the slot stream the per-slot wire consumes
# ---------------------------------------------------------------------------

def test_rr_shared_sampler_rows_agree():
    from repro.data.reshuffle import ReshuffleSampler

    s = ReshuffleSampler(5, 7, mode="rr_shared", seed=3)
    for e in range(3):
        order = s.epoch_order(e)
        assert (order == order[:1]).all()
        assert sorted(order[0].tolist()) == list(range(7))
    assert not np.array_equal(s.epoch_order(0)[0], s.epoch_order(1)[0])


def test_shared_slots_for_step_matches_stream_order():
    from repro.data.pipeline import (make_batch_stream, shared_slots_for_step,
                                     slots_for_step)
    from repro.data.reshuffle import ReshuffleSampler

    m, n, b, ls = 3, 4, 2, 2
    rng = np.random.default_rng(0)
    tokens = rng.integers(0, 100, size=(m, n, b, 5), dtype=np.int32)
    sampler = ReshuffleSampler(m, n, mode="rr_shared", seed=9)
    stream = make_batch_stream({"tokens": tokens}, sampler, local_steps=ls,
                               prefetch=False)
    with stream:
        for t in range(2 * n // ls):  # two epochs, boundary included
            batch = next(stream)
            slots = shared_slots_for_step(sampler, t, ls)
            want = np.concatenate(
                [tokens[c, slots[j]] for c in range(m) for j in range(ls)], 0)
            np.testing.assert_array_equal(batch["tokens"], want)
    # per-client helper agrees with the shared view
    np.testing.assert_array_equal(
        slots_for_step(sampler, 1, ls)[0], shared_slots_for_step(sampler, 1, ls))


def test_shared_slots_rejects_divergent_orders():
    from repro.data.pipeline import shared_slots_for_step
    from repro.data.reshuffle import ReshuffleSampler

    with pytest.raises(ValueError, match="shared order"):
        shared_slots_for_step(ReshuffleSampler(4, 6, mode="rr", seed=0), 0, 2)


def test_shared_slots_rejects_undersized_table():
    from repro.data.pipeline import shared_slots_for_step
    from repro.data.reshuffle import ReshuffleSampler

    s = ReshuffleSampler(4, 6, mode="rr_shared", seed=0)
    with pytest.raises(ValueError, match="n_slots"):
        shared_slots_for_step(s, 0, 2, n_slots=4)  # table smaller than n
    assert shared_slots_for_step(s, 0, 2, n_slots=6).shape == (2,)


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 forced host devices")
def test_diana_rr_default_n_slots_state_places():
    """Regression: diana_rr with the default n_slots=1 — the slot axis is
    present on the tables (size 1), and the sharding specs must carry the
    matching replicated entry instead of pushing TP onto the slot dim."""
    import jax.numpy as jnp
    from repro.configs import get_config, reduced
    from repro.core.dist import CompressedAggregation
    from repro.launch import steps
    from repro.launch.mesh import make_test_mesh, num_clients

    cfg = reduced(get_config("stablelm-1.6b"), seq=8)
    mesh = make_test_mesh((4, 2), ("data", "model"))
    m = num_clients(mesh)
    agg = CompressedAggregation(method="diana_rr", wire="shared",
                                fraction=0.5, shift_dtype=jnp.float32)
    state = steps.init_train_state(jax.random.key(0), cfg, agg, m, mesh=mesh)
    shardings = steps.train_state_shardings(
        mesh, state, steps.configure_agg(agg, mesh))
    placed = jax.device_put(state, shardings)  # crashed before the fix
    assert jax.tree.leaves(placed.shifts)[0].shape[:2] == (m, 1)
