"""data/paging — out-of-core fleet data (DESIGN.md §3.11).

Covers the on-disk `ClientDataStore` layout (sharded per-client rows, lazy
shard files, spec round-trip), the `LookaheadPager`'s windowed eviction and
LRU bounds, and THE acceptance criterion: a `CohortStream(paged=...)` —
and the fleet drivers on top of it — emits bit-identical batches and walks
a bit-identical trajectory (params, shift tables, bits, cursors) vs the
in-RAM client-stacked path, for `diana` AND `diana_rr`, including
`--resume` mid-walk and under seeded `AsyncPlanner` dropout (exactly-once
RR: non-completers must NOT advance page cursors).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.paging import ClientDataStore, LookaheadPager
from repro.data.pipeline import CohortStream
from repro.data.reshuffle import ReshuffleSampler
from repro.fleet import (AsyncFleetRunner, AsyncPlanner, ChaosConfig,
                         CohortSampler, ClientStateStore, FleetRunner)

needs_mesh = pytest.mark.skipif(
    jax.device_count() < 8, reason="needs 8 forced host devices"
)


def _stacked(C, n=3, b=1, seq=4, seed=0):
    """Two-leaf client-stacked population tree (the in-RAM reference)."""
    rng = np.random.default_rng(seed)
    return {
        "tokens": rng.integers(0, 97, (C, n, b, seq), dtype=np.int32),
        "mask": rng.random((C, n, b, seq), dtype=np.float32),
    }


# ---------------------------------------------------------------------------
# ClientDataStore: the on-disk layout
# ---------------------------------------------------------------------------

def test_data_store_roundtrip_and_spec(tmp_path):
    """from_stacked -> pages/open('r') reproduce the source tree exactly
    (shard boundaries and the short last shard included), the JSON spec
    round-trips, and the sizing helpers agree with the stacked bytes."""
    C, shard = 10, 3
    data = _stacked(C)
    path = str(tmp_path / "store")
    ds = ClientDataStore.from_stacked(path, data, shard_size=shard)

    assert ds.population == C and ds.shard_size == shard
    assert ds.num_shards == 4 and ds.shard_rows(3) == 1  # 3+3+3+1
    assert sorted(ds.leaf_names) == ["mask", "tokens"]
    assert ds.n_batches == 3
    for name, arr in data.items():
        for s in range(ds.num_shards):
            lo = s * shard
            page = ds.page(name, s)
            assert page.dtype == arr.dtype
            assert np.array_equal(page, arr[lo:lo + ds.shard_rows(s)]), \
                (name, s)
        assert ds.page_nbytes(name) == shard * arr[0].nbytes

    ro = ClientDataStore.open(path)
    assert ro.spec() == ds.spec()
    assert np.array_equal(ro.page("tokens", 1), data["tokens"][3:6])
    assert ds.nbytes == sum(a.nbytes for a in data.values())
    assert ds.nbytes == ClientDataStore.estimate_nbytes(
        {name: arr[0] for name, arr in data.items()}, C)


def test_data_store_lazy_shards(tmp_path):
    """`create` writes only the spec; absent shards read as zeros; a
    partial `write_rows` creates exactly the touched shard files — the
    1e6-client dry-run path must not pay disk for untouched clients."""
    path = str(tmp_path / "sparse")
    ds = ClientDataStore.create(
        path, 100, {"x": jax.ShapeDtypeStruct((2, 1, 4), jnp.float32)},
        shard_size=8)
    assert os.listdir(path) == ["data_store.json"]
    assert np.array_equal(ds.page("x", 5), np.zeros((8, 2, 1, 4), np.float32))

    rows = np.arange(2 * 2 * 1 * 4, dtype=np.float32).reshape(2, 2, 1, 4)
    ds.write_rows(np.array([3, 17]), {"x": rows})  # shards 0 and 2 only
    dats = sorted(f for f in os.listdir(path) if f.endswith(".dat"))
    assert dats == ["x.0.dat", "x.2.dat"]
    assert np.array_equal(ds.page("x", 0)[3], rows[0])
    assert np.array_equal(ds.page("x", 2)[1], rows[1])
    assert np.array_equal(ds.page("x", 0)[0], np.zeros((2, 1, 4)))
    assert np.array_equal(ds.page("x", 1), np.zeros((8, 2, 1, 4)))
    # reopen writable and overwrite one client's rows in place
    rw = ClientDataStore.open(path, mode="r+")
    rw.write_rows(np.array([3]), {"x": rows[1:] + 1})
    assert np.array_equal(ds.page("x", 0)[3], rows[1] + 1)


def test_data_store_validation(tmp_path):
    data = _stacked(4)
    with pytest.raises(ValueError, match="population"):
        ClientDataStore.create(str(tmp_path / "a"), 0, {"x": data["tokens"][0]})
    with pytest.raises(ValueError, match="non-empty"):
        ClientDataStore.create(str(tmp_path / "b"), 4, {})
    with pytest.raises(ValueError, match=r"\(n, b, \.\.\.\)"):
        ClientDataStore.create(
            str(tmp_path / "c"), 4,
            {"x": jax.ShapeDtypeStruct((3,), jnp.float32)})
    with pytest.raises(ValueError, match="client-stacked"):
        ClientDataStore.from_stacked(
            str(tmp_path / "d"), {"x": np.zeros((4, 3))})
    with pytest.raises(ValueError, match="holds 5 clients"):
        ClientDataStore.from_stacked(
            str(tmp_path / "e"),
            {"x": np.zeros((4, 3, 1)), "y": np.zeros((5, 3, 1))})
    with pytest.raises(ValueError, match="mode"):
        ClientDataStore.open(str(tmp_path / "f"), mode="w")
    with pytest.raises(OSError, match="not a client data store"):
        ClientDataStore.open(str(tmp_path / "nope"))
    # unwritable location: a FILE where the directory should go
    blocker = tmp_path / "blocker"
    blocker.write_text("x")
    with pytest.raises(OSError, match="not a writable directory"):
        ClientDataStore.create(str(blocker / "sub"), 4,
                               {"x": data["tokens"][0]})

    ds = ClientDataStore.from_stacked(str(tmp_path / "g"), data, shard_size=2)
    with pytest.raises(ValueError, match=r"outside \[0, 4\)"):
        ds.write_rows(np.array([4]), {"tokens": data["tokens"][:1]})
    with pytest.raises(ValueError, match="rows shape"):
        ds.write_rows(np.array([0]), {"tokens": data["tokens"]})
    ro = ClientDataStore.open(str(tmp_path / "g"))
    with pytest.raises(OSError, match="read-only"):
        ro.write_rows(np.array([0]), {"tokens": data["tokens"][:1]})


# ---------------------------------------------------------------------------
# LookaheadPager: windowed residency + LRU
# ---------------------------------------------------------------------------

def test_pager_window_eviction_and_sizing(tmp_path):
    """A windowed cohort walk keeps residency under
    `resident_bound_nbytes(m)` at every round regardless of population,
    and the lookahead turns the next round's reads into cache hits."""
    C, m, shard = 48, 4, 3
    data = _stacked(C)
    ds = ClientDataStore.from_stacked(str(tmp_path / "s"), data,
                                      shard_size=shard)
    pager = LookaheadPager(ds, lookahead=1)
    cs = CohortSampler(C, m, seed=7)
    bound = pager.resident_bound_nbytes(m)
    assert bound < ds.nbytes, "bound must beat holding the population"
    for t in range(24):  # 2 fleet epochs
        for c in cs.cohort_for_round(t):
            for name in ds.leaf_names:
                np.testing.assert_array_equal(pager.views[name][c],
                                              data[name][c])
        pager.advance_window(t, cs)
        assert pager.resident_nbytes() <= bound, t
        assert pager.resident_pages() <= 2 * m * len(ds.leaf_names), t
    st = pager.stats()
    assert st["evictions"] > 0, "window must drop out-of-window pages"
    assert st["hits"] > st["misses"], "prefetch must convert reads to hits"


def test_pager_cold_random_access_lru(tmp_path):
    """Outside the windowed walk (a resumed run's first lookups, debug
    pokes) the optional `max_resident` cap LRU-bounds the cache while
    reads stay correct."""
    C = 30
    data = _stacked(C, n=2)
    ds = ClientDataStore.from_stacked(str(tmp_path / "s"), data, shard_size=2)
    pager = LookaheadPager(ds, lookahead=0, max_resident=3)
    order = np.random.default_rng(3).permutation(C)
    for c in order:
        np.testing.assert_array_equal(pager.views["tokens"][c],
                                      data["tokens"][c])
        np.testing.assert_array_equal(pager.views["mask"][c],
                                      data["mask"][c])
        assert pager.resident_pages() <= 3
    assert pager.evictions > 0
    # re-reads after eviction still correct (pages reload from disk)
    np.testing.assert_array_equal(pager.views["tokens"][int(order[0])],
                                  data["tokens"][int(order[0])])


def test_pager_store_binding_and_warming(tmp_path):
    """gather/scatter route through the bound `ClientStateStore` (the
    drivers bind AFTER any chaos wrap so `_io_retry` covers paged reads),
    and `advance_window` pre-touches the next cohort's shift rows."""
    from repro.core.rules import get_rule

    C = 8
    ds = ClientDataStore.from_stacked(str(tmp_path / "s"), _stacked(C),
                                      shard_size=3)
    pager = LookaheadPager(ds, lookahead=1)
    with pytest.raises(RuntimeError, match="bind_store"):
        pager.gather(np.array([0]))
    with pytest.raises(RuntimeError, match="bind_store"):
        pager.scatter(np.array([0]), {})

    params = {"w": jnp.zeros((2, 3), jnp.float32)}
    store = ClientStateStore.create(params, C, get_rule("single"),
                                    shard_size=3)
    pager.bind_store(store)
    cohort = np.array([1, 5])
    got = pager.gather(cohort)
    got = jax.tree_util.tree_map(lambda a: np.asarray(a) + 2.0, got)
    pager.scatter(cohort, got)
    direct = store.gather(cohort)
    assert np.array_equal(np.asarray(direct["w"]),
                          np.full((2, 2, 3), 2.0, np.float32))
    assert pager.state_bytes_warmed == 0
    pager.advance_window(0, CohortSampler(C, 2, seed=1))
    assert pager.state_bytes_warmed > 0, "next cohort's shifts pre-touched"


# ---------------------------------------------------------------------------
# CohortStream(paged=...): THE bit-equality contract (host level)
# ---------------------------------------------------------------------------

def _batch_bytes(fr):
    return tuple(np.asarray(fr.batch[name]).tobytes()
                 for name in sorted(fr.batch))


def _run_stream(C, m, n, data=None, paged=None, *, local_steps=1,
                start_round=0, rounds=10, planner=None, prefetch=True):
    out = []
    with CohortStream(data, ReshuffleSampler(C, n, seed=1),
                      CohortSampler(C, m, seed=9), local_steps=local_steps,
                      start_round=start_round, planner=planner,
                      prefetch=prefetch, paged=paged) as stream:
        for _ in range(rounds):
            fr = next(stream)
            out.append((fr.round, fr.cohort.tobytes(), fr.cols.tobytes(),
                        _batch_bytes(fr)))
        counts = stream.counts.copy()
    return out, counts


def test_paged_stream_bit_equality_across_epochs(tmp_path):
    """ACCEPTANCE (stream layer): 2+ fleet epochs AND a data-epoch wrap,
    local_steps=2, two modalities — the paged stream's rounds (cohorts,
    cols, every leaf's rows) are byte-identical to the in-RAM stream's,
    and residency stays under the pager's bound throughout."""
    C, m, n = 10, 4, 3
    data = _stacked(C, n=n)
    ds = ClientDataStore.from_stacked(str(tmp_path / "s"), data, shard_size=3)
    pager = LookaheadPager(ds, lookahead=1)
    rounds = 10  # 40 slots / C=10 -> 4 fleet epochs; 8 micro-steps/client
    ram, counts_ram = _run_stream(C, m, n, data=data, local_steps=2,
                                  rounds=rounds)
    paged, counts_pg = _run_stream(C, m, n, paged=pager, local_steps=2,
                                   rounds=rounds)
    assert paged == ram
    assert np.array_equal(counts_pg, counts_ram)
    assert (counts_pg > n).any(), "walk must wrap a data epoch"
    assert pager.resident_nbytes() <= pager.resident_bound_nbytes(m)


def test_paged_stream_resume_mid_walk(tmp_path):
    """ACCEPTANCE (resume): a fresh pager + stream at `start_round=cut`
    replays the tail byte-identically — cursor state is closed-form, page
    residency rebuilds from the walk alone."""
    C, m, n, total, cut = 10, 4, 3, 8, 3
    data = _stacked(C, n=n)
    path = str(tmp_path / "s")
    ClientDataStore.from_stacked(path, data, shard_size=3)
    full, _ = _run_stream(
        C, m, n, paged=LookaheadPager(ClientDataStore.open(path)),
        local_steps=2, rounds=total)
    tail, _ = _run_stream(
        C, m, n, paged=LookaheadPager(ClientDataStore.open(path)),
        local_steps=2, start_round=cut, rounds=total - cut)
    assert tail == full[cut:]
    # and the paged tail == the in-RAM tail (cross-path resume equality)
    ram_tail, _ = _run_stream(C, m, n, data=data, local_steps=2,
                              start_round=cut, rounds=total - cut)
    assert tail == ram_tail


def test_paged_stream_dropout_exactly_once(tmp_path):
    """ACCEPTANCE (chaos): under a seeded dropout planner the paged stream
    matches the in-RAM stream byte-for-byte, non-completers do NOT advance
    page cursors (they re-read the SAME cols when resampled), and a paged
    mid-walk resume replays the planner prefix identically."""
    C, m, n, total, cut = 10, 4, 3, 12, 5
    data = _stacked(C, n=n)
    path = str(tmp_path / "s")
    ClientDataStore.from_stacked(path, data, shard_size=3)
    chaos = ChaosConfig(dropout=0.4, seed=11)
    mk_planner = lambda: AsyncPlanner(m, buffer_k=2, late="drop", chaos=chaos)

    ram, counts_ram = _run_stream(C, m, n, data=data, rounds=total,
                                  planner=mk_planner())
    paged, counts_pg = _run_stream(
        C, m, n, paged=LookaheadPager(ClientDataStore.open(path)),
        rounds=total, planner=mk_planner())
    assert paged == ram
    assert np.array_equal(counts_pg, counts_ram)
    # counts == pure planner replay: only completers advanced. The
    # prefetching stream has PLANNED one round beyond the `total` it
    # emitted, so the replay covers total + 1 rounds.
    cs = CohortSampler(C, m, seed=9)
    planner, replay = mk_planner(), np.zeros(C, np.int64)
    dropped_any = False
    for t in range(total + 1):
        cohort = cs.cohort_for_round(t)
        plan = planner(t, cohort)
        replay[cohort[plan.completes]] += 1
        dropped_any |= not plan.completes.all()
    assert dropped_any, "chaos seed must actually drop someone"
    assert np.array_equal(counts_pg, replay)
    assert replay.sum() < (total + 1) * m
    # paged resume under the planner: prefix replay matches the full run
    tail, _ = _run_stream(
        C, m, n, paged=LookaheadPager(ClientDataStore.open(path)),
        start_round=cut, rounds=total - cut, planner=mk_planner())
    assert tail == paged[cut:]


# ---------------------------------------------------------------------------
# fleet drivers on the pager: production acceptance (mesh level)
# ---------------------------------------------------------------------------

def _driver_fixtures(mesh, method, C, n):
    from test_fleet import _fleet_setup, _population_tokens

    cfg, m, agg, jitted, abstract, shardings, batch_sh = _fleet_setup(
        mesh, method, n=n)
    data = _population_tokens(cfg, C, n, 1, 8)
    return m, agg, jitted, abstract, shardings, batch_sh, data


def _state_snapshot(state, store, C):
    leaves = [np.asarray(a).tobytes() for a in
              jax.tree_util.tree_leaves(jax.device_get(state).params)]
    shifts = [np.asarray(a).tobytes() for a in
              jax.tree_util.tree_leaves(store.gather(np.arange(C)))]
    return leaves, shifts, store.bits.copy(), store.cursor.copy()


@needs_mesh
@pytest.mark.parametrize("method", ["diana", "diana_rr"])
def test_paged_fleet_bit_matches_in_ram(method, mesh_4x2, tmp_path):
    """ACCEPTANCE (driver): a partial-participation `FleetRunner` fed from
    the on-disk pager walks a bitwise-identical trajectory — params, full
    shift tables, bits, cursors — to the in-RAM run, for diana AND
    diana_rr, and the checkpoint manifest records the data-store spec."""
    from repro.core.rules import WIRE_RULES
    from repro.launch import compat, steps

    mesh = mesh_4x2
    # diana_rr's shared-slot wire needs C % m == 0 (no straddling cohorts);
    # diana takes C=10 so round 2 straddles the fleet-epoch boundary
    C = 12 if method == "diana_rr" else 10
    n, total = 3, 5  # 2 fleet epochs either way
    m, agg, jitted, abstract, shardings, batch_sh, data = _driver_fixtures(
        mesh, method, C, n)
    key = jax.random.key(4)
    slotted = method == "diana_rr"

    def run(pager):
        from test_fleet import _tiny_cfg

        store = ClientStateStore.create(
            abstract.params, C, WIRE_RULES[method], n_slots=agg.n_slots,
            dtype=np.float32, shard_size=3)
        with compat.set_mesh(mesh):
            state = jax.device_put(
                steps.init_train_state(jax.random.key(0), _tiny_cfg(), agg,
                                       m, mesh=mesh), shardings)
            with FleetRunner(
                    jitted, abstract, shardings, batch_sh, agg=agg,
                    mesh=mesh, data=None if pager else data,
                    sampler=ReshuffleSampler(
                        C, n, mode="rr_shared" if slotted else "rr", seed=1),
                    cohorts=CohortSampler(C, m, seed=9), store=store,
                    paged=pager) as runner:
                state = runner.run(state, key, total)
                meta = runner.checkpoint_meta()
        return _state_snapshot(state, store, C), meta

    ref, meta_ram = run(None)
    ds = ClientDataStore.from_stacked(str(tmp_path / "s"), data, shard_size=3)
    pager = LookaheadPager(ds, lookahead=1)
    got, meta_pg = run(pager)

    assert got[0] == ref[0], "params diverged"
    assert got[1] == ref[1], "shift tables diverged"
    assert np.array_equal(got[2], ref[2]) and np.array_equal(got[3], ref[3])
    assert "data_store" not in meta_ram
    assert meta_pg["data_store"] == ds.spec()
    assert pager.resident_nbytes() <= pager.resident_bound_nbytes(m)


@needs_mesh
def test_paged_fleet_resume_and_layout_refusal(mesh_4x2, tmp_path):
    """ACCEPTANCE (resume): a paged fleet checkpoint cut mid-walk restores
    bit-exactly through the pager, and `restore_fleet_checkpoint` REFUSES
    (a) a paged checkpoint restored without its data store and (b) a
    mismatched store layout — both before touching any buffers."""
    from repro.checkpoint import (CheckpointError, load_meta,
                                  restore_fleet_checkpoint,
                                  save_fleet_checkpoint)
    from repro.core.rules import WIRE_RULES
    from repro.launch import compat, steps
    from test_fleet import _tiny_cfg

    mesh = mesh_4x2
    C, n, total, cut = 10, 3, 6, 3
    m, agg, jitted, abstract, shardings, batch_sh, data = _driver_fixtures(
        mesh, "diana", C, n)
    ds = ClientDataStore.from_stacked(str(tmp_path / "s"), data, shard_size=3)
    key = jax.random.key(4)
    path = str(tmp_path / "fleet.ckpt")
    mk_store = lambda: ClientStateStore.create(
        abstract.params, C, WIRE_RULES["diana"], dtype=np.float32,
        shard_size=4)
    mk_runner = lambda start, store, pager: FleetRunner(
        jitted, abstract, shardings, batch_sh, agg=agg, mesh=mesh,
        data=None, sampler=ReshuffleSampler(C, n, mode="rr", seed=1),
        cohorts=CohortSampler(C, m, seed=9), store=store,
        start_round=start, paged=pager)

    with compat.set_mesh(mesh):
        state = jax.device_put(
            steps.init_train_state(jax.random.key(0), _tiny_cfg(), agg, m,
                                   mesh=mesh), shardings)
        store = mk_store()
        runner = mk_runner(0, store, LookaheadPager(ds))

        def snap(t, st, metrics):
            if t + 1 == cut:
                save_fleet_checkpoint(
                    path, jax.device_get(st), store, step=t + 1,
                    meta={"fleet": runner.checkpoint_meta()}, data_store=ds)

        with runner:
            state = runner.run(state, key, total, callback=snap)
        ref, ref_store = jax.device_get(state), store

        fm = load_meta(path)["meta"]
        assert fm["data_store_spec"] == ds.spec()
        assert fm["fleet"]["data_store"] == ds.spec()

        # refusal (a): paged checkpoint without its data store
        with pytest.raises(CheckpointError, match="no data store"):
            restore_fleet_checkpoint(path, abstract, shardings, mk_store())
        # refusal (b): a different on-disk layout
        other = ClientDataStore.from_stacked(str(tmp_path / "other"), data,
                                             shard_size=5)
        with pytest.raises(CheckpointError, match="shard_size"):
            restore_fleet_checkpoint(path, abstract, shardings, mk_store(),
                                     data_store=other)

        # the real resume: same layout, fresh pager
        store_b = mk_store()
        state_b = restore_fleet_checkpoint(path, abstract, shardings,
                                           store_b, data_store=ds)
        with mk_runner(fm["fleet"]["round"], store_b,
                       LookaheadPager(ClientDataStore.open(str(
                           tmp_path / "s")))) as runner_b:
            state_b = runner_b.run(state_b, key, total - cut)
        flt = jax.device_get(state_b)

    for (pa, a), (_, bb) in zip(
            jax.tree_util.tree_leaves_with_path(ref.params),
            jax.tree_util.tree_leaves_with_path(flt.params)):
        assert np.asarray(a).tobytes() == np.asarray(bb).tobytes(), pa
    everyone = np.arange(C)
    for (pa, a), (_, bb) in zip(
            jax.tree_util.tree_leaves_with_path(ref_store.gather(everyone)),
            jax.tree_util.tree_leaves_with_path(store_b.gather(everyone))):
        assert np.array_equal(a, bb), pa
    assert np.array_equal(ref_store.cursor, store_b.cursor)
    assert np.array_equal(ref_store.bits, store_b.bits)


@needs_mesh
def test_paged_async_fleet_under_dropout_bit_matches_ram(mesh_4x2, tmp_path):
    """ACCEPTANCE (async + chaos): the buffered-async driver under seeded
    dropout + injected store faults walks the SAME trajectory paged as
    in-RAM — gather/scatter route through the pager inside `_io_retry`,
    non-completers' page cursors hold still, and the injection schedule is
    unchanged by paging."""
    from repro.core.rules import WIRE_RULES
    from repro.launch import compat, steps
    from test_fleet import _tiny_cfg

    from test_fleet import _fleet_setup, _population_tokens

    mesh = mesh_4x2
    C, n, total = 8, 3, 6
    # elastic step: the async driver feeds variable completer counts
    cfg, m, agg, jitted, abstract, shardings, batch_sh = _fleet_setup(
        mesh, "diana", n=n, elastic=True)
    data = _population_tokens(cfg, C, n, 1, 8)
    chaos = ChaosConfig(dropout=0.2, straggler=0.4, delay=1.0,
                        store_fail=0.3, max_retries=3, seed=5)
    key = jax.random.key(4)

    def run(pager):
        store = ClientStateStore.create(
            abstract.params, C, WIRE_RULES["diana"], dtype=np.float32,
            shard_size=3)
        with compat.set_mesh(mesh):
            state = jax.device_put(
                steps.init_train_state(jax.random.key(0), _tiny_cfg(), agg,
                                       m, mesh=mesh), shardings)
            with AsyncFleetRunner(
                    jitted, abstract, shardings, batch_sh, agg=agg,
                    mesh=mesh, data=None if pager else data,
                    sampler=ReshuffleSampler(C, n, mode="rr", seed=1),
                    cohorts=CohortSampler(C, m, seed=9), store=store,
                    buffer_k=3, late="drop", chaos=chaos,
                    paged=pager) as runner:
                state = runner.run(state, key, total)
        return _state_snapshot(state, store, C), store

    ref, ref_store = run(None)
    ds = ClientDataStore.from_stacked(str(tmp_path / "s"), data, shard_size=3)
    got, got_store = run(LookaheadPager(ds, lookahead=1))

    assert got[0] == ref[0], "params diverged under chaos"
    assert got[1] == ref[1], "shift tables diverged under chaos"
    assert np.array_equal(got[2], ref[2]), "bits diverged"
    assert np.array_equal(got[3], ref[3]), "cursors diverged"
    # dropout really bit: somebody sits below the full walk
    assert ref_store.cursor.sum() < \
        CohortSampler(C, m, seed=9).participation_counts(total).sum()
