"""Two-level (hierarchical) pod wire: parity, statistics, fixed point, and
the NASTYA mapping's equivalence with the simulator (core/algorithms.py).

All tests run the wire the way production does — inside a fully-manual
shard_map over every mesh axis (core/dist.py docstring) — on the forced
8-host-device session (conftest). Meshes come from the conftest fixtures:

  mesh_4x2    flat wire          (4 clients x 2 TP)
  mesh_1x4x2  two-level, 1 pod   (must bit-match mesh_4x2)
  mesh_2x2x2  two-level, 2 pods  (both levels live)
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.dist import CompressedAggregation, DianaState
from repro.data.logreg import make_federated_logreg
from repro.launch import compat

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8, reason="needs 8 forced host devices"
)


def _shard_map(f, mesh, in_specs, out_specs):
    return compat.shard_map(f, mesh=mesh, in_specs=in_specs,
                            out_specs=out_specs,
                            axis_names=set(mesh.axis_names), check_vma=False)


GRADS = {
    "w": jnp.arange(4 * 64, dtype=jnp.float32).reshape(4, 64) / 100.0,
    "b": jnp.ones((4, 8), jnp.float32),
}
MEAN = jax.tree.map(lambda x: x.mean(0), GRADS)


def _wire_specs(mesh, grads):
    """Stacked-client specs for `grads` on `mesh`: leading dim = all client
    ranks, trailing dim TP when it divides."""
    caxes = tuple(n for n in mesh.axis_names if n != "model")
    msize = int(mesh.shape["model"])
    return jax.tree.map(
        lambda x: P(caxes, *(None,) * (x.ndim - 2),
                    "model" if x.shape[-1] % msize == 0 else None), grads)


def _configure(agg, mesh):
    from repro.launch.steps import configure_agg

    return configure_agg(agg, mesh)


def _run_rounds(agg, mesh, rounds, *, grads=GRADS, seed=0, slots=None,
                reduce="last"):
    """Direction of `rounds` aggregate() calls (per-client fixed gradients),
    executed inside the fully-manual wire region. `slots` is an optional
    (rounds,) vector of shared slot ids for per-slot methods; `reduce` picks
    the last round's direction or the running mean over rounds."""
    agg = _configure(agg, mesh)
    specs = _wire_specs(mesh, grads)
    slot_seq = (jnp.zeros((rounds,), jnp.int32) if slots is None
                else jnp.asarray(slots, jnp.int32))

    def body(g):
        g = jax.tree.map(lambda x: x[0], g)
        state = agg.init(g)
        key = jax.random.PRNGKey(seed)

        def one(state, inp):
            t, slot = inp
            d, state = agg.aggregate(g, state, jax.random.fold_in(key, t),
                                     slot=slot)
            return state, d

        _, ds = jax.lax.scan(one, state, (jnp.arange(rounds), slot_seq))
        if reduce == "mean":
            d = jax.tree.map(lambda x: jnp.mean(x, axis=0), ds)
        else:
            d = jax.tree.map(lambda x: x[-1], ds)
        return jax.tree.map(lambda x: x[None], d)

    out = jax.jit(_shard_map(body, mesh, (specs,), specs))(grads)
    return jax.tree.map(lambda x: x[0], out)


# ---------------------------------------------------------------------------
# parity: 1-pod two-level == flat single-level, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["q", "diana", "diana_rr", "ef"])
def test_one_pod_two_level_bit_matches_flat(method, mesh_4x2, mesh_1x4x2):
    """A single pod has no inter-pod link: the outer exchange is the exact
    identity, and the inner exchange draws the very same keys as the flat
    wire — the acceptance-criteria bit-match. Holds for every shift rule,
    per-slot tables and error-feedback residuals included."""
    agg = CompressedAggregation(method=method, wire="shared", fraction=0.25,
                                n_slots=3 if method == "diana_rr" else 1,
                                shift_dtype=jnp.float32)
    slots = np.arange(7) % 3 if method == "diana_rr" else None
    flat = _run_rounds(agg, mesh_4x2, 7, slots=slots)
    two = _run_rounds(agg, mesh_1x4x2, 7, slots=slots)
    for k in GRADS:
        assert np.array_equal(np.asarray(flat[k]), np.asarray(two[k])), k


def test_two_pod_wire_differs_from_flat(mesh_4x2, mesh_2x2x2):
    """Sanity for the parity test: with 2 real pods the outer level draws
    its own (salted) coordinates, so the wires must NOT coincide."""
    agg = CompressedAggregation(method="q", wire="shared", fraction=0.25)
    flat = _run_rounds(agg, mesh_4x2, 1)
    two = _run_rounds(agg, mesh_2x2x2, 1)
    assert any(
        not np.array_equal(np.asarray(flat[k]), np.asarray(two[k]))
        for k in GRADS
    )


# ---------------------------------------------------------------------------
# packed transports: bit-match the f32 wire at equal levels (DESIGN.md §3.13)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["q", "diana", "diana_rr"])
def test_packed8_bit_matches_f32_wire(method, mesh_4x2):
    """The tentpole guarantee: wire_dtype is transport, not math. Both modes
    round-trip the slab through the same pack->unpack kernels (same byte,
    same scale, same multiply), so moving the int8 lattice instead of the
    dequantized f32 slab changes NOTHING in the trajectory — params and
    shift state bitwise identical for every lossless shift rule."""
    n_slots = 3 if method == "diana_rr" else 1
    slots = np.arange(5) % 3 if method == "diana_rr" else None
    base = CompressedAggregation(method=method, wire="shared", fraction=0.25,
                                 n_slots=n_slots, shift_dtype=jnp.float32,
                                 wire_dtype="f32", wire_levels=127)
    packed = dataclasses.replace(base, wire_dtype="packed8", wire_levels=None)
    want = _run_rounds(base, mesh_4x2, 5, slots=slots)
    got = _run_rounds(packed, mesh_4x2, 5, slots=slots)
    for k in GRADS:
        assert np.array_equal(np.asarray(want[k]), np.asarray(got[k])), k


def test_packed8_bit_matches_f32_wire_two_pod(mesh_2x2x2):
    """Same guarantee with both wire levels live (2 pods): the inter-pod
    exchange packs and reduces with its own slab geometry and must stay
    bitwise exact too."""
    base = CompressedAggregation(method="diana_rr", wire="shared",
                                 fraction=0.25, n_slots=2,
                                 shift_dtype=jnp.float32,
                                 wire_dtype="f32", wire_levels=127)
    packed = dataclasses.replace(base, wire_dtype="packed8", wire_levels=None)
    slots = np.arange(4) % 2
    want = _run_rounds(base, mesh_2x2x2, 4, slots=slots)
    got = _run_rounds(packed, mesh_2x2x2, 4, slots=slots)
    for k in GRADS:
        assert np.array_equal(np.asarray(want[k]), np.asarray(got[k])), k


def test_packed4_bit_matches_f32_wire(mesh_4x2):
    """The nibble lane at its lossless cap L=7: two rows per byte on the
    wire, still bitwise identical to f32 transport at the same levels."""
    base = CompressedAggregation(method="diana", wire="shared", fraction=0.25,
                                 shift_dtype=jnp.float32,
                                 wire_dtype="f32", wire_levels=7)
    packed = dataclasses.replace(base, wire_dtype="packed4", wire_levels=None)
    want = _run_rounds(base, mesh_4x2, 3)
    got = _run_rounds(packed, mesh_4x2, 3)
    for k in GRADS:
        assert np.array_equal(np.asarray(want[k]), np.asarray(got[k])), k


def test_bf16_wire_close_to_f32(mesh_4x2):
    """bf16 transport is lossy (8 mantissa bits): no bit-match claim, but
    one round's direction must sit within downcast tolerance of the f32
    wire — the rounding enters only at the slab edges, not compounded."""
    base = CompressedAggregation(method="diana", wire="shared", fraction=0.25,
                                 shift_dtype=jnp.float32)
    bf = dataclasses.replace(base, wire_dtype="bf16")
    want = _run_rounds(base, mesh_4x2, 1)
    got = _run_rounds(bf, mesh_4x2, 1)
    rel = {}
    for k in GRADS:
        w = np.asarray(want[k])
        scale = np.abs(w).max() + 1e-12
        rel[k] = np.abs(np.asarray(got[k]) - w).max() / scale
        assert rel[k] < 1e-2, (k, rel[k])
    # the downcast is real: somewhere it must have rounded ("b" is all-ones,
    # exactly representable in bf16, so only "w" is guaranteed to move)
    assert max(rel.values()) > 0, rel


def test_packed_wire_byte_accounting(mesh_4x2):
    """True bytes on the wire: packed8 moves exactly slab/4 plus the 4B
    per-row f32 scale sideband (packed4 slab/8 + the same sideband) — the
    analytic identity the jaxpr census pins against the lowered step. On a
    matrix leaf the sideband is the +1/D term, keeping the total under
    0.26x / 0.135x of the f32 slab; 1-D cols=1 leaves pay the sideband per
    element and are a net LOSS (DESIGN.md §3.13)."""
    from repro.compression.backend import BLOCK_ROWS as BR
    from repro.core.dist import scale_sideband_bytes

    local = {"w": jnp.zeros((64, 128), jnp.float32)}
    aggs = {
        wd: _configure(
            CompressedAggregation(method="diana", wire="shared",
                                  fraction=0.25, shift_dtype=jnp.float32,
                                  wire_dtype=wd), mesh_4x2)
        for wd in ("f32", "bf16", "packed8", "packed4")
    }
    bytes_ = {wd: agg.wire_bytes_per_round(local)["intra_pod"]
              for wd, agg in aggs.items()}
    nb = 64 // BR
    slab_rows = max(1, int(0.25 * nb)) * BR
    sideband = scale_sideband_bytes("packed8", slab_rows)
    assert sideband == 4 * slab_rows
    assert bytes_["f32"] == slab_rows * 128 * 4
    assert bytes_["bf16"] == bytes_["f32"] // 2
    assert bytes_["packed8"] == bytes_["f32"] // 4 + sideband
    assert bytes_["packed4"] == bytes_["f32"] // 8 + sideband
    assert bytes_["packed8"] / bytes_["f32"] <= 0.26
    assert bytes_["packed4"] / bytes_["f32"] <= 0.135

    # the cols=1 caveat: a 1-D leaf's packed "compression" is a net loss
    flat = {"w": jnp.zeros((8192,), jnp.float32)}
    f32_flat = aggs["f32"].wire_bytes_per_round(flat)["intra_pod"]
    p8_flat = aggs["packed8"].wire_bytes_per_round(flat)["intra_pod"]
    assert p8_flat > f32_flat


# ---------------------------------------------------------------------------
# statistics: unbiased, composed variance bound (1+w1)(1+w2)
# ---------------------------------------------------------------------------

def test_two_level_q_unbiased_with_composed_variance(mesh_2x2x2):
    """E[Q2(Q1(x))] = x and E||Q2(Q1(x))||^2 <= (1+w1)(1+w2)||x||^2 (tower
    rule over the two independent draws). Every client holds the same x so
    the intra-pod mean is exactly Q1(x) and the bound is tight to sampling
    error. ~1e4 seeded trials, like tests/test_kernels.py."""
    trials = 10_000
    agg = _configure(
        CompressedAggregation(method="q", wire="shared", fraction=0.25),
        mesh_2x2x2)
    x = {"w": jnp.asarray(
        np.random.default_rng(7).normal(size=(4, 64)), jnp.float32)}
    x = {"w": jnp.broadcast_to(x["w"][:1], (4, 64))}  # same x on every client
    specs = {"w": P(("pod", "data"), "model")}

    def body(g):
        g = jax.tree.map(lambda x: x[0], g)
        key = jax.random.PRNGKey(3)

        def one(acc, t):
            d, _ = agg.aggregate(g, None, jax.random.fold_in(key, t))
            s, s2 = acc
            return (jax.tree.map(jnp.add, s, d),
                    s2 + sum(jnp.sum(jnp.square(l))
                             for l in jax.tree.leaves(d))), None

        zeros = jax.tree.map(jnp.zeros_like, g)
        (s, s2), _ = jax.lax.scan(one, (zeros, jnp.zeros(())),
                                  jnp.arange(trials))
        return jax.tree.map(lambda a: a[None] / trials, s), s2[None] / trials

    mean_d, second_moment = jax.jit(
        _shard_map(body, mesh_2x2x2, (specs,),
                   (specs, P(("pod", "data"))))
    )(x)
    got = np.asarray(mean_d["w"][0])
    want = np.asarray(x["w"][0])
    # unbiased: montecarlo error ~ sqrt(omega_composed/trials) * |x|
    scale = float(np.abs(want).max())
    assert float(np.abs(got - want).max()) < 0.3 * scale + 0.02

    omega1, omega2 = agg.omega(), agg.pod_omega()
    bound = (1 + omega1) * (1 + omega2) * float(np.sum(want**2))
    m2 = float(second_moment[0])
    # the composed second moment sits near the bound (shared draws make it
    # exact for identical clients) but must not exceed it beyond MC error
    assert m2 < bound * 1.05, (m2, bound)
    assert m2 > float(np.sum(want**2)) * (1 + omega2) * 0.95  # both levels real


# ---------------------------------------------------------------------------
# DIANA fixed point: pod-level shifts kill the inter-pod residual
# ---------------------------------------------------------------------------

def test_pod_shifts_drive_interpod_residual_to_zero(mesh_2x2x2):
    """Fixed heterogeneous gradients from the paper's logreg problem: with
    DIANA shifts at both levels the compressed residuals vanish and the
    two-level direction converges to the exact global mean (Theorem 2 logic,
    once per level)."""
    prob = make_federated_logreg(m=4, n_batches=2, batch=4, d=64, cond=50.0,
                                 seed=1)
    loss = prob.loss_fn()
    w0 = {"w": jnp.zeros((prob.d,), jnp.float32)}
    # per-client full-batch gradient at w0 — maximally heterogeneous
    grads = jax.vmap(
        lambda a, y: jax.grad(loss)(w0, {"a": a.reshape(-1, prob.d),
                                         "y": y.reshape(-1)})
    )(prob.data["a"], prob.data["y"])["w"]  # (4, d)
    grads = {"w": grads}
    mean = np.asarray(grads["w"]).mean(0)

    agg = CompressedAggregation(method="diana", wire="shared", fraction=0.25,
                                shift_dtype=jnp.float32)
    got = _run_rounds(agg, mesh_2x2x2,
                      300, grads=grads)
    np.testing.assert_allclose(np.asarray(got["w"]), mean, atol=1e-5)


def test_one_level_alone_leaves_interpod_noise(mesh_2x2x2):
    """Control for the fixed-point test: method 'q' (no shifts anywhere)
    does NOT converge to the mean on the same problem — the shifts are what
    kill the residual, not the averaging."""
    prob = make_federated_logreg(m=4, n_batches=2, batch=4, d=64, cond=50.0,
                                 seed=1)
    loss = prob.loss_fn()
    w0 = {"w": jnp.zeros((prob.d,), jnp.float32)}
    grads = {"w": jax.vmap(
        lambda a, y: jax.grad(loss)(w0, {"a": a.reshape(-1, prob.d),
                                         "y": y.reshape(-1)})
    )(prob.data["a"], prob.data["y"])["w"]}
    mean = np.asarray(grads["w"]).mean(0)
    agg = CompressedAggregation(method="q", wire="shared", fraction=0.25)
    got = _run_rounds(agg, mesh_2x2x2, 300, grads=grads)
    assert float(np.abs(np.asarray(got["w"]) - mean).max()) > 1e-3


# ---------------------------------------------------------------------------
# per-slot (diana_rr) and error-feedback (ef) rules on the production wire
# ---------------------------------------------------------------------------

def _logreg_grads():
    prob = make_federated_logreg(m=4, n_batches=2, batch=4, d=64, cond=50.0,
                                 seed=1)
    loss = prob.loss_fn()
    w0 = {"w": jnp.zeros((prob.d,), jnp.float32)}
    grads = {"w": jax.vmap(
        lambda a, y: jax.grad(loss)(w0, {"a": a.reshape(-1, prob.d),
                                         "y": y.reshape(-1)})
    )(prob.data["a"], prob.data["y"])["w"]}
    return grads, np.asarray(grads["w"]).mean(0)


def test_per_slot_shifts_reach_fixed_point(mesh_2x2x2):
    """diana_rr on the two-level wire: every slot's control variates kill
    their compressed residual, so the direction converges to the exact mean
    no matter which slot a round lands on (Theorem 2 logic per slot)."""
    grads, mean = _logreg_grads()
    n_slots = 3
    agg = CompressedAggregation(method="diana_rr", wire="shared",
                                fraction=0.25, n_slots=n_slots,
                                shift_dtype=jnp.float32)
    got = _run_rounds(agg, mesh_2x2x2, 450, grads=grads,
                      slots=np.arange(450) % n_slots)
    np.testing.assert_allclose(np.asarray(got["w"]), mean, atol=1e-5)


def test_ef_wire_fixed_point_on_logreg(mesh_4x2):
    """Error feedback on the wire: the residual memory telescopes, so the
    RUNNING MEAN of the directions converges to the exact gradient mean at
    rate ||e_T||/T — while the memory-free 'q' wire's mean keeps the
    compression noise floor. (The EF remedy the paper cites, now production.)
    """
    grads, mean = _logreg_grads()
    agg = CompressedAggregation(method="ef", wire="shared", fraction=0.25,
                                shift_dtype=jnp.float32)
    got = _run_rounds(agg, mesh_4x2, 300, grads=grads, reduce="mean")
    scale = float(np.abs(mean).max())
    err_ef = float(np.abs(np.asarray(got["w"]) - mean).max())
    assert err_ef < 0.02 * scale + 1e-4, (err_ef, scale)


def test_per_slot_wire_matches_simulator_and_pipeline_order(mesh_4x2):
    """The acceptance cross-check: the flat-mesh `diana_rr` pod wire and the
    simulator's `make_epoch_fn("diana_rr")` walk the SAME trajectory at
    fraction=1.0 (exact compression), fed by the same `rr_shared` sampler —
    params AND the full per-slot shift tables agree, which also pins the
    wire's slot selection to the pipeline's epoch order."""
    from repro.core.algorithms import ALGORITHMS, init_algorithm, make_epoch_fn
    from repro.compression.ops import RandK
    from repro.data.pipeline import make_batch_stream, run_epochs, \
        shared_slots_for_step
    from repro.data.reshuffle import ReshuffleSampler
    from repro.launch import steps
    from repro.launch.mesh import num_clients
    from repro.models import transformer

    cfg = _tiny_cfg()
    mesh = mesh_4x2
    m = num_clients(mesh)
    n, seq = 3, 8
    gamma, alpha = 0.02, 0.5
    epochs = 2

    rng = np.random.default_rng(0)
    tokens = rng.integers(0, cfg.vocab, size=(m, n, 1, seq + 1))  # (M,n,b,S+1)
    sim_data = {"tokens": jnp.asarray(tokens, jnp.int32)}
    sampler = ReshuffleSampler(m, n, mode="rr_shared", seed=5)

    loss_fn = lambda p, b: transformer.loss_fn(p, b, cfg, remat=False,
                                               seq_shard=False)
    params0 = transformer.init_params(jax.random.key(0), cfg)

    # --- simulator: run_epochs feeds the sampler's shared order -----------
    spec, epoch = make_epoch_fn("diana_rr", loss_fn, RandK(fraction=1.0),
                                gamma=gamma, alpha=alpha)
    sim = init_algorithm(ALGORITHMS["diana_rr"], params0, m, n)
    sim = run_epochs(epoch, sim, sim_data, sampler, epochs=epochs,
                     key=jax.random.PRNGKey(7))

    # --- production: one wire round per step, slots from the same sampler --
    agg = CompressedAggregation(method="diana_rr", wire="shared",
                                fraction=1.0, alpha=alpha, n_slots=n,
                                shift_dtype=jnp.float32)
    jitted, abstract, shardings, batch_sh = steps.make_train_step(
        cfg, mesh, agg=agg, lr=gamma, remat=False, seq_shard=False)
    stream = make_batch_stream(
        {"tokens": tokens.astype(np.int32)}, sampler, prefetch=False)
    with compat.set_mesh(mesh), stream:
        state = jax.device_put(
            steps.init_train_state(jax.random.key(0), cfg, agg, m, lr=gamma,
                                   mesh=mesh), shardings)
        for t in range(epochs * n):
            slots = jnp.asarray(shared_slots_for_step(sampler, t))
            state, _ = jitted(state, next(stream), jax.random.key(3), slots)

    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_leaves_with_path(sim.params),
            jax.tree_util.tree_leaves_with_path(state.params)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=2e-4, rtol=2e-3, err_msg=str(pa))
    # slot-selection coherence: the (M, n_slots, *param) tables themselves
    # match — the wire touched exactly the slots the pipeline ordered. The
    # tables integrate raw per-round gradients (no 1/M averaging), so they
    # carry more reduction-order float noise than the params; a wrong slot
    # would show up as O(0.1) row-level differences, not 1e-3 ripples.
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_leaves_with_path(sim.shifts),
            jax.tree_util.tree_leaves_with_path(state.shifts)):
        assert a.shape == b.shape, (pa, a.shape, b.shape)
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=5e-3, rtol=5e-2, err_msg=str(pa))


def test_per_slot_untouched_slots_stay_zero(mesh_4x2):
    """Two rounds into a 4-slot table only the two visited rows move."""
    from repro.launch.steps import configure_agg

    agg = configure_agg(
        CompressedAggregation(method="diana_rr", wire="shared", fraction=1.0,
                              n_slots=4, shift_dtype=jnp.float32), mesh_4x2)
    specs = _wire_specs(mesh_4x2, GRADS)
    visited = (2, 0)

    def body(g):
        g = jax.tree.map(lambda x: x[0], g)
        state = agg.init(g)
        key = jax.random.PRNGKey(0)
        for t, s in enumerate(visited):
            _, state = agg.aggregate(g, state, jax.random.fold_in(key, t),
                                     slot=jnp.int32(s))
        return jax.tree.map(lambda x: x[None], state.shifts)

    out_specs = jax.tree.map(
        lambda s: P(s[0], None, *s[1:]), _wire_specs(mesh_4x2, GRADS))
    shifts = jax.jit(_shard_map(body, mesh_4x2, (specs,), out_specs))(GRADS)
    for k in GRADS:
        table = np.asarray(shifts[k])  # (M, n_slots, ...)
        for s in range(4):
            touched = (np.abs(table[:, s]) > 0).any()
            assert touched == (s in visited), (k, s)


# ---------------------------------------------------------------------------
# simulator-vs-pod cross-check: the production NASTYA step inherits the
# simulator's (already theorem-tested) semantics
# ---------------------------------------------------------------------------

def _tiny_cfg():
    from repro.configs import get_config, reduced

    cfg = reduced(get_config("stablelm-1.6b"), seq=8)
    return dataclasses.replace(cfg, dtype=jnp.float32)


@pytest.mark.parametrize("name", ["q_nastya", "diana_nastya"])
def test_pod_nastya_matches_simulator(name, mesh_4x2):
    """`q_nastya`/`diana_nastya` from core/algorithms.py and the pod-level
    NASTYA step produce the same trajectory on a tiny problem: 4 clients
    (each its own pod on the flat mesh — paper Algorithms 4-5 exactly),
    full-batch (every local micro-batch identical, so the RR orders of the
    two implementations cannot diverge), fraction=1.0 (both compressors are
    exact at k=d, so the different Rand-k samplers coincide), same gamma/
    eta/alpha. The production wire must inherit the simulator's semantics.
    """
    from repro.core.algorithms import init_algorithm, make_epoch_fn, ALGORITHMS
    from repro.compression.ops import RandK
    from repro.launch import steps
    from repro.launch.mesh import num_clients
    from repro.models import transformer

    cfg = _tiny_cfg()
    mesh = mesh_4x2
    m = num_clients(mesh)
    local_steps = 3
    gamma, eta, alpha = 0.02, 0.05, 0.5
    seq = 8

    # one full-batch of tokens per client, repeated local_steps times
    rng = np.random.default_rng(0)
    tokens = rng.integers(0, cfg.vocab, size=(m, 1, seq + 1))  # (M, b=1, S+1)
    sim_data = {"tokens": jnp.asarray(
        np.broadcast_to(tokens[:, None], (m, local_steps, 1, seq + 1)).copy(),
        jnp.int32)}  # (M, n, b, S+1)

    loss_fn = lambda p, b: transformer.loss_fn(p, b, cfg, remat=False,
                                               seq_shard=False)
    params0 = transformer.init_params(jax.random.key(0), cfg)

    # --- simulator epochs ---------------------------------------------------
    spec, epoch = make_epoch_fn(name, loss_fn, RandK(fraction=1.0),
                                gamma=gamma, eta=eta, alpha=alpha)
    sim = init_algorithm(ALGORITHMS[name], params0, m, local_steps)
    ep = jax.jit(epoch)
    for e in range(2):
        sim = ep(sim, sim_data, jax.random.PRNGKey(10 + e))

    # --- production pod step ------------------------------------------------
    method = "diana" if name == "diana_nastya" else "q"
    agg = CompressedAggregation(method=method, wire="shared", fraction=1.0,
                                alpha=alpha, pod_alpha=alpha,
                                shift_dtype=jnp.float32)
    jitted, abstract, shardings, batch_sh = steps.make_train_step(
        cfg, mesh, agg=agg, lr=gamma, eta=eta, local_steps=local_steps,
        remat=False, seq_shard=False)
    with compat.set_mesh(mesh):
        state = jax.device_put(
            steps.init_train_state(jax.random.key(0), cfg, agg, m, lr=gamma,
                                   mesh=mesh, local_steps=local_steps),
            shardings)
        # client-major rows, local_steps identical micro-batches per client
        batch = {"tokens": jnp.asarray(
            np.repeat(tokens[:, 0], local_steps, axis=0), jnp.int32)}
        for e in range(2):
            state, _ = jitted(state, batch, jax.random.key(10 + e))

    # the two implementations compute identical math but with different
    # reduction orders (single-device simulator vs 8-way sharded step);
    # float noise grows chaotically along the trajectory — after 2 epochs
    # the parameter updates are O(1e-2) and the divergence O(5e-5) (<1%)
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_leaves_with_path(sim.params),
            jax.tree_util.tree_leaves_with_path(state.params)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=2e-4, rtol=2e-3, err_msg=str(pa))


# ---------------------------------------------------------------------------
# NASTYA on the two-level mesh: runs and trains
# ---------------------------------------------------------------------------

def test_nastya_two_pod_step_trains(mesh_2x2x2):
    """End-to-end: 2 pods x 2 clients, 2 local RR mini-epochs per round,
    DIANA at both levels — loss decreases over a few rounds."""
    from repro.configs import get_config, reduced
    from repro.launch import steps
    from repro.launch.mesh import num_clients

    cfg = reduced(get_config("stablelm-1.6b"), seq=8)
    mesh = mesh_2x2x2
    m = num_clients(mesh)
    local_steps = 2
    agg = CompressedAggregation(method="diana", wire="shared", fraction=0.5,
                                shift_dtype=jnp.float32)
    jitted, abstract, shardings, _ = steps.make_train_step(
        cfg, mesh, agg=agg, lr=0.05, eta=0.2, local_steps=local_steps,
        remat=False, seq_shard=False)
    with compat.set_mesh(mesh):
        state = jax.device_put(
            steps.init_train_state(jax.random.key(0), cfg, agg, m, mesh=mesh,
                                   local_steps=local_steps), shardings)
        batch = {"tokens": jax.random.randint(
            jax.random.key(1), (m * local_steps * 2, 9), 0, cfg.vocab)}
        losses = []
        for _ in range(10):
            state, metrics = jitted(state, batch, jax.random.key(2))
            losses.append(float(metrics["loss"]))
        assert np.isfinite(losses).all()
        assert losses[-1] < losses[0] - 0.05, losses


def test_nastya_two_pod_diana_rr_trains(mesh_2x2x2):
    """Acceptance: `CompressedAggregation(method="diana_rr")` on the 2-pod
    NASTYA mesh — per-slot shifts on the intra-pod wire (slots riding the
    per-pod micro-epoch permutation), single-shift row 0 on the inter-pod
    epoch gradient — trains."""
    from repro.configs import get_config, reduced
    from repro.launch import steps
    from repro.launch.mesh import num_clients

    cfg = reduced(get_config("stablelm-1.6b"), seq=8)
    mesh = mesh_2x2x2
    m = num_clients(mesh)
    local_steps = 2
    agg = CompressedAggregation(method="diana_rr", wire="shared",
                                fraction=0.5, n_slots=local_steps,
                                shift_dtype=jnp.float32)
    jitted, abstract, shardings, _ = steps.make_train_step(
        cfg, mesh, agg=agg, lr=0.05, eta=0.2, local_steps=local_steps,
        remat=False, seq_shard=False)
    with compat.set_mesh(mesh):
        state = jax.device_put(
            steps.init_train_state(jax.random.key(0), cfg, agg, m, mesh=mesh,
                                   local_steps=local_steps), shardings)
        batch = {"tokens": jax.random.randint(
            jax.random.key(1), (m * local_steps * 2, 9), 0, cfg.vocab)}
        slots = jnp.arange(local_steps, dtype=jnp.int32)
        losses = []
        for _ in range(10):
            state, metrics = jitted(state, batch, jax.random.key(2), slots)
            losses.append(float(metrics["loss"]))
        assert np.isfinite(losses).all()
        assert losses[-1] < losses[0] - 0.05, losses
        # both levels hold slot tables; the inner level saw both slots
        sh = np.asarray(jax.tree.leaves(state.shifts)[0])
        assert sh.shape[1] == local_steps
        assert (np.abs(sh) > 0).any(axis=tuple(range(2, sh.ndim))).all(), \
            "every (client, slot) table row should have been touched"
