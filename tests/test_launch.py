"""Launch layer on the 8-device test mesh: sharding rules, train/serve steps.

The full 512-device dry-run lives in launch/dryrun.py (own process, own
XLA_FLAGS); here the same step builders run on a 4x2 (data x model) mesh
with reduced configs — every code path that the production mesh exercises,
at unit-test cost.
"""
import jax

from repro.launch import compat
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, reduced
from repro.core.dist import CompressedAggregation
from repro.launch import sharding, steps
from repro.launch.mesh import make_test_mesh, num_clients
from repro.models import transformer as T

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8, reason="needs 8 forced host devices")



def _subprocess_isolated(test_fn):
    """Run the decorated test in its own pytest subprocess.

    XLA:CPU's collective runtime aborts natively when several distinct
    multi-device executables execute in one process (every test below passes
    in isolation); process isolation is the documented workaround. The
    512-device dry-run COMPILES all programs in one process — only host
    EXECUTION trips this.
    """
    import functools
    import os
    import subprocess
    import sys

    @functools.wraps(test_fn)
    def wrapper(*args, **kwargs):
        if os.environ.get("REPRO_SUBTEST") == "1":
            return test_fn(*args, **kwargs)
        request = kwargs.pop("request", None)
        node = f"tests/test_launch.py::{test_fn.__name__}"
        if args or kwargs:
            params = "-".join(str(v) for v in list(args) + list(kwargs.values()))
            node += f"[{params}]"
        env = dict(os.environ, REPRO_SUBTEST="1",
                   PYTHONPATH=os.environ.get("PYTHONPATH", "src"))
        r = subprocess.run([sys.executable, "-m", "pytest", "-q", "-x", node],
                           env=env, capture_output=True, text=True,
                           timeout=1200)
        assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-1000:]

    return wrapper

S, B = 16, 8


def make_batch(cfg, key):
    batch = {"tokens": jax.random.randint(key, (B, S + 1), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.vision_patches, cfg.d_model), cfg.dtype)
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model), cfg.dtype)
    return batch


def test_param_specs_shapes():
    cfg = reduced(get_config("deepseek-67b"))
    params = jax.eval_shape(lambda: T.init_params(jax.random.key(0), cfg))
    specs = sharding.param_specs(params)
    blocks = specs["blocks"]
    assert blocks["mixer"]["wq"] == P(None, None, "model")
    assert blocks["mixer"]["wo"] == P(None, "model", None)
    assert blocks["ffn"]["w_down"] == P(None, "model", None)
    assert specs["embed"] == P("model", None)
    assert blocks["ln1"]["scale"] == P(None, None)


def test_moe_specs():
    cfg = reduced(get_config("dbrx-132b"))
    params = jax.eval_shape(lambda: T.init_params(jax.random.key(0), cfg))
    specs = sharding.param_specs(params)
    assert specs["blocks"]["ffn"]["w_up"] == P(None, None, None, "model")
    assert specs["blocks"]["ffn"]["w_down"] == P(None, None, "model", None)
    assert specs["blocks"]["ffn"]["router"] == P(None, None, None)


# Execution coverage runs the paper's wire (method="diana"); the dense
# (uncompressed pmean) wire EXECUTES into a native XLA:CPU abort on this
# jaxlib (the program compiles — including at 512 dry-run devices — and the
# math is covered by test_dist's manual-mesh aggregation tests). Dense stays
# compile-covered via launch/dryrun.py --agg dense.
@pytest.mark.parametrize("arch,method", [
    ("stablelm-1.6b", "diana"), ("qwen2-moe-a2.7b", "diana"),
    ("rwkv6-7b", "diana"), ("hymba-1.5b", "diana"),
])
@_subprocess_isolated
def test_train_step_runs_sharded(arch, method):
    """Compressed train step on the 4x2 mesh: runs, loss finite + params
    move."""
    cfg = reduced(get_config(arch), seq=S)
    mesh = make_test_mesh((4, 2), ("data", "model"))
    agg = CompressedAggregation(method=method, wire="shared", fraction=0.25,
                                shift_dtype=jnp.float32)
    # seq_shard=False: XLA:CPU's collective runtime aborts on the
    # resharding-heavy seq-parallel program when several multi-device
    # executables run in one process; the seq-parallel path is exercised by
    # the dry-run (compile) and by test_train_step_loss_decreases (single
    # executable per process).
    jitted, abstract, shardings, _ = steps.make_train_step(
        cfg, mesh, agg=agg, lr=0.05, remat=False, seq_shard=False)
    with compat.set_mesh(mesh):
        state = steps.init_train_state(jax.random.key(0), cfg, agg,
                                       num_clients(mesh))
        state = jax.device_put(state, shardings)
        batch = make_batch(cfg, jax.random.key(1))
        key = jax.random.key(2)
        # the step donates its input state — snapshot params first
        before = [np.asarray(x, np.float32)
                  for x in jax.tree.leaves(state.params)]
        new_state, metrics = jitted(state, batch, key)
        assert bool(jnp.isfinite(metrics["loss"]))
        assert int(new_state.step) == 1
        # params moved
        delta = sum(
            float(np.sum(np.abs(np.asarray(a, np.float32) - b)))
            for a, b in zip(jax.tree.leaves(new_state.params), before))
        assert delta > 0


@_subprocess_isolated
def test_train_step_loss_decreases():
    cfg = reduced(get_config("stablelm-1.6b"), seq=S)
    mesh = make_test_mesh((4, 2), ("data", "model"))
    agg = CompressedAggregation(method="diana", wire="shared", fraction=0.5,
                                shift_dtype=jnp.float32)
    jitted, abstract, shardings, _ = steps.make_train_step(
        cfg, mesh, agg=agg, lr=0.2, remat=False)
    with compat.set_mesh(mesh):
        state = jax.device_put(
            steps.init_train_state(jax.random.key(0), cfg, agg,
                                   num_clients(mesh)), shardings)
        batch = make_batch(cfg, jax.random.key(1))
        losses = []
        for t in range(30):
            state, metrics = jitted(state, batch, jax.random.key(3))
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0] - 0.05, losses[::10]


@pytest.mark.parametrize("arch", ["starcoder2-15b", "whisper-medium"])
@_subprocess_isolated
def test_serve_step_sharded(arch):
    cfg = reduced(get_config(arch), seq=S)
    mesh = make_test_mesh((4, 2), ("data", "model"))
    params = T.init_params(jax.random.key(0), cfg)
    cache = T.init_cache(params, cfg, batch=B, cache_len=S)
    serve, lower_args = steps.make_serve_step(cfg, mesh)
    tokens = jnp.zeros((B, 1), jnp.int32)
    with compat.set_mesh(mesh):
        jitted, (psh, csh, tsh) = lower_args(
            jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params),
            jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), cache),
            jax.ShapeDtypeStruct(tokens.shape, tokens.dtype),
        )
        params = jax.device_put(params, psh)
        cache = jax.device_put(cache, csh)
        tokens = jax.device_put(tokens, tsh)
        logits, new_cache = jitted(params, cache, tokens, jnp.int32(0))
        assert logits.shape == (B, 1, cfg.padded_vocab())
        assert bool(jnp.all(jnp.isfinite(logits)))


def test_train_docstring_example_flags_stay_valid():
    """Doc/flag drift guard: the module docstring's example command must
    parse through the real argparse surface, and the --fraction default
    must equal the value the docstring advertises (the paper's k/d)."""
    import re

    from repro.launch import train

    m = re.search(r"python -m repro\.launch\.train (.+?)\n\n", train.__doc__,
                  re.S)
    assert m, "train.py docstring lost its example command line"
    example = m.group(1).replace("\\\n", " ").replace(
        "[--production-mesh]", "")
    parser = train.build_parser()
    args = parser.parse_args(example.split())
    assert args.fraction == parser.get_default("fraction") == 0.02
    assert "--fraction 0.02" in train.__doc__
