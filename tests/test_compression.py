"""Compression operators: Assumption 1 (unbiasedness + omega variance bound),
bit accounting, and pytree lifting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.compression.ops import (
    Identity,
    NaturalCompression,
    QSGDQuantizer,
    RandK,
    TopK,
    get_compressor,
    tree_compress,
    tree_compression_bits,
)

UNBIASED = [
    RandK(fraction=0.25),
    RandK(k=3),
    QSGDQuantizer(levels=4),
    NaturalCompression(),
]


@pytest.mark.parametrize("comp", UNBIASED, ids=lambda c: type(c).__name__ + str(getattr(c, "k", "")))
def test_unbiased(comp):
    d = 64
    x = jax.random.normal(jax.random.PRNGKey(0), (d,))
    trials = 4000
    keys = jax.random.split(jax.random.PRNGKey(1), trials)
    qs = jax.vmap(lambda k: comp.compress(k, x))(keys)
    mean = np.asarray(jnp.mean(qs, axis=0))
    # standard error of the MC mean
    se = np.asarray(jnp.std(qs, axis=0)) / np.sqrt(trials)
    assert np.all(np.abs(mean - np.asarray(x)) < 6 * se + 1e-4)


@pytest.mark.parametrize("comp", UNBIASED, ids=lambda c: type(c).__name__ + str(getattr(c, "k", "")))
def test_omega_bound(comp):
    d = 64
    x = jax.random.normal(jax.random.PRNGKey(2), (d,))
    trials = 2000
    keys = jax.random.split(jax.random.PRNGKey(3), trials)
    qs = jax.vmap(lambda k: comp.compress(k, x))(keys)
    var = float(jnp.mean(jnp.sum((qs - x[None]) ** 2, axis=-1)))
    bound = comp.omega(d) * float(jnp.sum(x**2))
    assert var <= bound * 1.15 + 1e-6  # 15% MC slack


def test_randk_omega_exact():
    # For Rand-k the bound is tight: E||Q-x||^2 = (d/k - 1)||x||^2
    comp = RandK(k=4)
    d = 32
    x = jax.random.normal(jax.random.PRNGKey(4), (d,))
    keys = jax.random.split(jax.random.PRNGKey(5), 20000)
    qs = jax.vmap(lambda k: comp.compress(k, x))(keys)
    var = float(jnp.mean(jnp.sum((qs - x[None]) ** 2, axis=-1)))
    expect = (d / 4 - 1) * float(jnp.sum(x**2))
    assert abs(var - expect) / expect < 0.05


def test_randk_sparsity_and_scale():
    comp = RandK(k=5)
    x = jnp.arange(1.0, 41.0)
    q = comp.compress(jax.random.PRNGKey(0), x)
    nz = np.nonzero(np.asarray(q))[0]
    assert len(nz) == 5
    np.testing.assert_allclose(np.asarray(q)[nz], np.asarray(x)[nz] * 40 / 5, rtol=1e-6)


def test_topk_selects_largest():
    comp = TopK(k=3)
    x = jnp.array([0.1, -5.0, 0.2, 4.0, -0.3, 3.0])
    q = np.asarray(comp.compress(jax.random.PRNGKey(0), x))
    assert set(np.nonzero(q)[0]) == {1, 3, 5}


def test_identity():
    x = jnp.arange(8.0)
    assert np.all(np.asarray(Identity().compress(jax.random.PRNGKey(0), x)) == np.asarray(x))
    assert Identity().omega(8) == 0.0


def test_qsgd_levels_grid():
    comp = QSGDQuantizer(levels=4)
    x = jax.random.normal(jax.random.PRNGKey(6), (32,))
    q = comp.compress(jax.random.PRNGKey(7), x)
    norm = float(jnp.linalg.norm(x))
    lv = np.asarray(jnp.abs(q)) / norm * 4
    np.testing.assert_allclose(lv, np.round(lv), atol=1e-4)


def test_tree_compress_and_bits():
    tree = {"a": jnp.ones((8, 4)), "b": jnp.ones((10,))}
    comp = RandK(fraction=0.5)
    out = tree_compress(comp, jax.random.PRNGKey(0), tree)
    assert jax.tree.structure(out) == jax.tree.structure(tree)
    assert out["a"].shape == (8, 4)
    bits = tree_compression_bits(comp, tree)
    assert bits == comp.bits(32) + comp.bits(10)
    assert bits < tree_compression_bits(Identity(), tree)


def test_registry():
    assert isinstance(get_compressor("randk", k=2), RandK)
    assert isinstance(get_compressor("qsgd"), QSGDQuantizer)
    with pytest.raises(ValueError):
        get_compressor("nope")


def test_randk_requires_k_or_fraction():
    """k=None + fraction=None used to crash later with a cryptic TypeError
    inside _k; now it raises a clear ValueError at construction."""
    with pytest.raises(ValueError, match="k or fraction"):
        RandK(k=None, fraction=None)
    with pytest.raises(ValueError, match="k or fraction"):
        TopK(k=None, fraction=None)
    with pytest.raises(ValueError, match="k or fraction"):
        get_compressor("randk", k=None, fraction=None)


def test_tree_compress_flat_buffer_semantics():
    """tree_compress ravels the whole tree into one operator call: for
    Rand-k the k is computed from the TOTAL size and the (scaled) survivors
    match the originals coordinate-wise."""
    tree = {"a": jnp.arange(1.0, 33.0).reshape(8, 4), "b": jnp.arange(1.0, 11.0)}
    comp = RandK(fraction=0.5)  # total d=42 -> k=21 across the whole tree
    out = tree_compress(comp, jax.random.PRNGKey(3), tree)
    flat = np.concatenate([np.asarray(out["a"]).ravel(), np.asarray(out["b"])])
    orig = np.concatenate([np.asarray(tree["a"]).ravel(), np.asarray(tree["b"])])
    (nz,) = np.nonzero(flat)
    assert len(nz) == 21
    np.testing.assert_allclose(flat[nz], orig[nz] * 42 / 21, rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    d=st.integers(min_value=2, max_value=257),
    frac=st.floats(min_value=0.05, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_randk_shape_dtype_property(d, frac, seed):
    """Property: any size/fraction/seed -> output preserves shape & dtype and
    contains exactly min(d, max(1, floor(frac*d))) non-zeros (a.s.)."""
    comp = RandK(fraction=frac)
    x = jax.random.normal(jax.random.PRNGKey(seed), (d,), jnp.float32) + 1.0
    q = comp.compress(jax.random.PRNGKey(seed + 1), x)
    assert q.shape == x.shape and q.dtype == x.dtype
    k = max(1, min(d, int(frac * d)))
    assert int(jnp.sum(q != 0)) == k


@settings(max_examples=15, deadline=None)
@given(
    shape=st.sampled_from([(16,), (4, 8), (2, 3, 5)]),
    levels=st.integers(min_value=1, max_value=64),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_qsgd_shape_property(shape, levels, seed):
    comp = QSGDQuantizer(levels=levels)
    x = jax.random.normal(jax.random.PRNGKey(seed), shape)
    q = comp.compress(jax.random.PRNGKey(seed + 1), x)
    assert q.shape == x.shape
    # reconstruction norm can't exceed (1 + 1/s)*||x|| by construction grid
    assert float(jnp.max(jnp.abs(q))) <= float(jnp.linalg.norm(x)) * (1 + 1 / levels) + 1e-5
