"""Render EXPERIMENTS.md §Dry-run/§Roofline tables from the grid JSONLs."""
import json
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from benchmarks.roofline import load, model_flops_per_device, table
from repro.configs import INPUT_SHAPES

ARCH_ORDER = [
    "stablelm-1.6b", "deepseek-67b", "rwkv6-7b", "hymba-1.5b",
    "starcoder2-15b", "qwen2-vl-2b", "qwen2.5-32b", "qwen2-moe-a2.7b",
    "whisper-medium", "dbrx-132b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_bytes(b):
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def dryrun_table():
    single = {(d["arch"], d["shape"]): d
              for d in map(json.loads, open("results/dryrun_single.jsonl"))}
    multi = {(d["arch"], d["shape"]): d
             for d in map(json.loads, open("results/dryrun_multi.jsonl"))}
    out = [
        "| arch | shape | 16×16 | 2×16×16 | bytes/device (args+temp) | "
        "HLO GFLOPs/dev | collective MB/dev | compile s |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            d1, d2 = single.get((a, s)), multi.get((a, s))
            if d1 is None:
                continue
            if d1["status"] == "skipped":
                out.append(f"| {a} | {s} | SKIP | SKIP | — | — | — | — |"
                           f" <!-- {d1['reason'][:60]} -->")
                continue
            mem = d1.get("memory", {})
            tot = mem.get("argument_size_in_bytes", 0) + mem.get(
                "temp_size_in_bytes", 0)
            r = d1["roofline"]
            s2 = "✓" if d2 and d2["status"] == "ok" else (
                "SKIP" if d2 and d2["status"] == "skipped" else "?")
            out.append(
                f"| {a} | {s} | ✓ | {s2} | {fmt_bytes(tot)} | "
                f"{r['flops']/1e9:,.0f} | {r['collective_bytes']/1e6:,.0f} | "
                f"{d1.get('compile_s', 0):.0f} |")
    n_ok1 = sum(1 for d in single.values() if d["status"] == "ok")
    n_ok2 = sum(1 for d in multi.values() if d["status"] == "ok")
    out.append("")
    out.append(f"Single-pod: {n_ok1} compiled OK; multi-pod: {n_ok2} "
               f"compiled OK; {sum(1 for d in single.values() if d['status']=='skipped')} "
               "skips by design (sub-quadratic-only shape).")
    return "\n".join(out)


def main():
    md = open("EXPERIMENTS.md").read()
    md = md.replace("TABLE-PLACEHOLDER-DRYRUN", dryrun_table())
    rows = load("results/dryrun_single.jsonl")
    md = md.replace("TABLE-PLACEHOLDER-ROOFLINE", table(rows))
    open("EXPERIMENTS.md", "w").write(md)
    print("EXPERIMENTS.md updated with",
          len(rows), "roofline rows")


if __name__ == "__main__":
    main()
