"""Render EXPERIMENTS.md §Dry-run/§Roofline tables from the grid JSONLs.

`--telemetry RUN.telemetry.jsonl [--out PREFIX]` instead plots the run's
loss and cumulative uplink-bits curves from a telemetry event stream
(matplotlib when importable, CSV fallback otherwise).
"""
import argparse
import json
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from benchmarks.roofline import load, model_flops_per_device, table
from repro.configs import INPUT_SHAPES

ARCH_ORDER = [
    "stablelm-1.6b", "deepseek-67b", "rwkv6-7b", "hymba-1.5b",
    "starcoder2-15b", "qwen2-vl-2b", "qwen2.5-32b", "qwen2-moe-a2.7b",
    "whisper-medium", "dbrx-132b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_bytes(b):
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def dryrun_table():
    single = {(d["arch"], d["shape"]): d
              for d in map(json.loads, open("results/dryrun_single.jsonl"))}
    multi = {(d["arch"], d["shape"]): d
             for d in map(json.loads, open("results/dryrun_multi.jsonl"))}
    out = [
        "| arch | shape | 16×16 | 2×16×16 | bytes/device (args+temp) | "
        "HLO GFLOPs/dev | collective MB/dev | compile s |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            d1, d2 = single.get((a, s)), multi.get((a, s))
            if d1 is None:
                continue
            if d1["status"] == "skipped":
                out.append(f"| {a} | {s} | SKIP | SKIP | — | — | — | — |"
                           f" <!-- {d1['reason'][:60]} -->")
                continue
            mem = d1.get("memory", {})
            tot = mem.get("argument_size_in_bytes", 0) + mem.get(
                "temp_size_in_bytes", 0)
            r = d1["roofline"]
            s2 = "✓" if d2 and d2["status"] == "ok" else (
                "SKIP" if d2 and d2["status"] == "skipped" else "?")
            out.append(
                f"| {a} | {s} | ✓ | {s2} | {fmt_bytes(tot)} | "
                f"{r['flops']/1e9:,.0f} | {r['collective_bytes']/1e6:,.0f} | "
                f"{d1.get('compile_s', 0):.0f} |")
    n_ok1 = sum(1 for d in single.values() if d["status"] == "ok")
    n_ok2 = sum(1 for d in multi.values() if d["status"] == "ok")
    out.append("")
    out.append(f"Single-pod: {n_ok1} compiled OK; multi-pod: {n_ok2} "
               f"compiled OK; {sum(1 for d in single.values() if d['status']=='skipped')} "
               "skips by design (sub-quadratic-only shape).")
    return "\n".join(out)


def telemetry_curves(path: str, out_prefix: str):
    """Loss-vs-round and loss-vs-cumulative-uplink-bits from one telemetry
    stream: rounds come from `round_metrics`, bits from the drivers'
    `fleet.uplink_bits` / `wire.uplink_bits` counters."""
    from repro.telemetry import read_events

    events = read_events(path)
    rounds, losses = [], []
    bits_by_round = {}
    for ev in events:
        if ev.get("kind") == "round_metrics":
            loss = (ev.get("metrics") or {}).get("loss")
            if isinstance(loss, (int, float)):
                rounds.append(int(ev["round"]))
                losses.append(float(loss))
        elif (ev.get("kind") == "counter"
              and ev.get("name", "").endswith("uplink_bits")):
            r = ev.get("round")
            if r is not None:
                bits_by_round[int(r)] = (bits_by_round.get(int(r), 0.0)
                                         + float(ev["value"]))
    if not rounds:
        raise SystemExit(f"{path}: no round_metrics with a numeric loss")
    cum, total = [], 0.0
    for r in rounds:
        total += bits_by_round.get(r, 0.0)
        cum.append(total)
    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except ImportError:
        csv = out_prefix + "_curves.csv"
        with open(csv, "w") as f:
            f.write("round,loss,cum_uplink_bits\n")
            for r, l, b in zip(rounds, losses, cum):
                f.write(f"{r},{l},{b}\n")
        print(f"matplotlib unavailable: wrote {csv} "
              f"({len(rounds)} rounds)")
        return
    fig, axes = plt.subplots(1, 2, figsize=(10, 4))
    axes[0].plot(rounds, losses)
    axes[0].set_xlabel("round")
    axes[0].set_ylabel("loss")
    axes[1].plot([b / 8e6 for b in cum], losses)
    axes[1].set_xlabel("cumulative uplink MB")
    axes[1].set_ylabel("loss")
    for ax in axes:
        ax.grid(True, alpha=0.3)
    fig.suptitle(path)
    fig.tight_layout()
    png = out_prefix + "_curves.png"
    fig.savefig(png, dpi=120)
    print(f"wrote {png} ({len(rounds)} rounds, "
          f"{cum[-1] / 8e6:.2f}MB uplink)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--telemetry", default=None, metavar="JSONL",
                    help="plot loss/uplink-bits curves from a telemetry "
                         "stream instead of rendering EXPERIMENTS.md")
    ap.add_argument("--out", default=None,
                    help="output prefix for --telemetry plots "
                         "(default: the stream path sans extension)")
    args = ap.parse_args()
    if args.telemetry:
        prefix = args.out or args.telemetry.rsplit(".jsonl", 1)[0]
        return telemetry_curves(args.telemetry, prefix)
    md = open("EXPERIMENTS.md").read()
    md = md.replace("TABLE-PLACEHOLDER-DRYRUN", dryrun_table())
    rows = load("results/dryrun_single.jsonl")
    md = md.replace("TABLE-PLACEHOLDER-ROOFLINE", table(rows))
    open("EXPERIMENTS.md", "w").write(md)
    print("EXPERIMENTS.md updated with",
          len(rows), "roofline rows")


if __name__ == "__main__":
    main()
