"""Cohort sampling: client-level random reshuffling over a population.

The paper states its algorithms for M workers that all participate every
round; `launch/steps.py` realizes exactly that — the mesh's ("pod","data")
ranks ARE the M clients. A real federated fleet samples a small cohort from
a population `C >> M` each round. Without-replacement *client* sampling is
the fleet-level analog of the paper's RR theme (cf. Malinovsky & Richtárik,
arXiv:2205.03914; Mishchenko, Khaled & Richtárik, arXiv:2102.06704): shuffle
the population once per *fleet epoch* and walk it in cohorts, so every
client participates exactly once per fleet epoch.

The sampler follows the same statelessness discipline as
`data.reshuffle.ReshuffleSampler` (DESIGN.md §3.7): the raw per-epoch
permutation is a pure function of `(seed, epoch)`, and a round's cohort is
a pure function of the round index — the walk is a single integer cursor
`g = round * cohort_size` over the concatenation of the fleet epochs'
orders, so a cohort may straddle a fleet-epoch boundary (tail of epoch e +
head of epoch e+1) exactly like `EpochIterator` straddles data epochs.
That is what makes the fleet run resumable from a `(fleet_epoch, round)`
cursor with no sampler state to checkpoint.

**Straddle deconfliction.** Two adjacent epochs' permutations are
independent, so a straddling cohort could sample the same client twice —
ill-defined for the state-store scatter (two mesh ranks would write one
client's shifts). The walk therefore reads each epoch's EFFECTIVE order
(`effective_order`): the raw permutation with its head deconflicted
against the previous epoch's effective tail — the straddling round takes
the first head elements NOT in the tail, and the displaced elements keep
their later positions. Each effective order is still a permutation of the
population (exactly-once-per-epoch coverage is preserved) and still a pure
function of the seed: epoch e's order depends only on the raw draws of
epochs ≤ e, chained through (< cohort_size)-element tail windows that are
memoized, so random access to any round stays cheap.

Cohorts are returned SORTED ascending. Membership is a set — the order in
which a cohort's clients map onto mesh ranks is an implementation choice —
and the canonical ascending assignment is what makes a
`cohort == population` run place client c on rank c every round, i.e.
bit-match the full-participation wire (DESIGN.md §3.9).
"""
from __future__ import annotations

import numpy as np

from repro.core import salts

COHORT_MODES = ("rr", "with_replacement")


class CohortSampler:
    """Yields per-round client cohorts from a population of C clients.

    mode:
      'rr'  — cohort-RR: one permutation of the population per fleet epoch
              (`np.random.default_rng((seed, epoch))`, head-deconflicted
              across epoch boundaries — see the module docstring), walked
              in chunks of `cohort_size`; every client participates exactly
              once per fleet epoch, cohorts may straddle epoch boundaries
              and are always distinct within a round.
      'with_replacement' — the baseline control: each round draws an
              independent uniform cohort (i.i.d. across rounds). Within a
              round the cohort is still distinct clients — a client
              appearing twice would make the state-store scatter
              ill-defined.
    """

    def __init__(self, population: int, cohort_size: int, *,
                 mode: str = "rr", seed: int = 0):
        if mode not in COHORT_MODES:
            raise ValueError(
                f"unknown cohort mode {mode!r}; options: {COHORT_MODES}")
        if cohort_size < 1 or population < cohort_size:
            raise ValueError(
                f"need 1 <= cohort_size <= population, got "
                f"cohort_size={cohort_size}, population={population}")
        self.population = int(population)
        self.cohort_size = int(cohort_size)
        self.mode = mode
        self.seed = int(seed)
        self._order_cache: dict[int, np.ndarray] = {}  # effective orders
        self._tails: dict[int, np.ndarray] = {}  # (< m)-element tail windows

    # -- the stateless order ------------------------------------------------

    def epoch_order(self, fleet_epoch: int) -> np.ndarray:
        """(C,) RAW permutation of the population for `fleet_epoch` — a
        pure function of (seed, fleet_epoch). The walk itself reads
        `effective_order` (head-deconflicted); this is the underlying
        draw."""
        rng = np.random.default_rng((self.seed, int(fleet_epoch)))
        return rng.permutation(self.population).astype(np.int64)

    def _straddle(self, fleet_epoch: int) -> int:
        """How many slots of the round containing this epoch's first slot
        belong to the PREVIOUS epoch (0 = the boundary is round-aligned)."""
        return (fleet_epoch * self.population) % self.cohort_size

    def _build_effective(self, fleet_epoch: int) -> np.ndarray:
        """Effective order of one epoch, given the previous epoch's cached
        tail window: move the first straddle-conflicting head elements out
        of the straddling round's reach (they keep their later positions)."""
        raw = self.epoch_order(fleet_epoch)
        a = self._straddle(fleet_epoch)
        if fleet_epoch == 0 or a == 0:
            return raw
        tail = self._tails[fleet_epoch - 1][-a:]
        k = self.cohort_size - a  # head slots the straddling round fills
        clear = np.flatnonzero(~np.isin(raw, tail))[:k]
        return np.concatenate([raw[clear], np.delete(raw, clear)])

    def effective_order(self, fleet_epoch: int) -> np.ndarray:
        """(C,) permutation the walk actually reads for `fleet_epoch` —
        `epoch_order` with the straddle deconfliction applied. Memoized;
        the chain of tail windows is built forward from the nearest
        round-aligned (or already-cached) epoch, so random access costs
        O(C) per uncached epoch, not a recursion to epoch 0 each call."""
        e = int(fleet_epoch)
        order = self._order_cache.get(e)
        if order is not None:
            return order
        start = e
        while start > 0 and self._straddle(start) != 0 \
                and (start - 1) not in self._tails:
            start -= 1
        win = min(self.cohort_size - 1, self.population)
        order = None
        for ep in range(start, e + 1):
            if ep < e and ep in self._tails:
                continue  # tail already known; full order not needed
            order = self._build_effective(ep)
            if win:
                self._tails[ep] = order[-win:]
        self._order_cache[e] = order
        while len(self._order_cache) > 2:
            self._order_cache.pop(next(iter(self._order_cache)))
        return order

    def cohort_for_round(self, rnd: int) -> np.ndarray:
        """(cohort_size,) sorted DISTINCT client ids for round `rnd`."""
        if rnd < 0:
            raise ValueError(f"round={rnd}")
        m = self.cohort_size
        if self.mode == "with_replacement":
            # 3-element entropy tuple (with a salt) — disjoint from the
            # 2-element (seed, epoch) sequences the 'rr' mode draws from
            rng = np.random.default_rng(
                (self.seed, salts.WR_COHORT_SALT, int(rnd)))
            ids = rng.choice(self.population, size=m, replace=False)
            return np.sort(ids.astype(np.int64))
        g = rnd * m
        out = np.empty((m,), np.int64)
        filled = 0
        while filled < m:
            epoch, i = divmod(g + filled, self.population)
            take = min(m - filled, self.population - i)
            out[filled:filled + take] = \
                self.effective_order(epoch)[i:i + take]
            filled += take
        return np.sort(out)

    # -- cursor / accounting ------------------------------------------------

    def cursor(self, rnd: int) -> tuple[int, int]:
        """(fleet_epoch, position-within-epoch) of the NEXT round's first
        slot — the checkpointable fleet cursor."""
        return divmod(rnd * self.cohort_size, self.population)

    @property
    def rounds_per_epoch(self) -> float:
        return self.population / self.cohort_size

    def participation_counts(self, rnd: int) -> np.ndarray:
        """(C,) number of rounds each client participated in during rounds
        [0, rnd).

        'rr' has a closed form (no replay): after `rnd * cohort_size` walk
        slots, every client holds `full_epochs` participations and the
        first `rem` clients of the current epoch's EFFECTIVE order hold one
        more. 'with_replacement' replays the per-round draws (O(rnd·m)
        host work — the price of the i.i.d. baseline; prefer checkpointing
        the state-store cursors for long runs).
        """
        counts = np.zeros((self.population,), np.int64)
        if self.mode == "with_replacement":
            for r in range(rnd):
                counts[self.cohort_for_round(r)] += 1
            return counts
        g = rnd * self.cohort_size
        full_epochs, rem = divmod(g, self.population)
        counts += full_epochs
        if rem:
            counts[self.effective_order(full_epochs)[:rem]] += 1
        return counts

    def spec(self) -> dict:
        """JSON-serializable description (checkpointed next to the fleet
        cursor so a resumed run can verify it is replaying the same walk)."""
        return {"population": self.population,
                "cohort_size": self.cohort_size,
                "mode": self.mode, "seed": self.seed}
