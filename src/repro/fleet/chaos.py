"""Deterministic fault injection + buffered-async round planning
(DESIGN.md §3.10).

Production fleets lose clients mid-round: some go dark (dropout), some
report late (stragglers), and the host store occasionally hiccups
(transient I/O). This module makes every one of those failure modes a PURE
FUNCTION of `(seed, round)` so a chaos run is exactly reproducible — the
same seed replays the same darkness/latency/I/O schedule, a resumed run
replays the prefix it skipped, and tests can assert trajectories bit-for-bit.

Three pieces:

``ChaosConfig``
    The knobs: per-round client dropout probability, straggler
    probability + delay, transient store-I/O failure rate with bounded
    retry/backoff, and the seed every draw derives from.

``AsyncPlanner``
    FedBuff-style K-of-m round planning. Each round it simulates report
    latencies for the cohort, sets the buffer deadline at the K-th fastest
    alive client, and emits a `ParticipationPlan`: per-rank participation
    weights for the elastic step (`launch.steps.make_train_step(...,
    elastic=True)`), plus the `completes` mask that drives exactly-once RR
    accounting — a client's data cursor advances ONLY when its report is
    folded in, so a dropped/late-dropped client re-enters the cohort walk
    at its pre-round position with its shift table untouched.

``FaultyStore``
    A `ClientStateStore` wrapper whose gather/scatter/advance/add_bits
    raise deterministic
    `TransientStoreError`s; the async driver retries with bounded
    exponential backoff (`AsyncFleetRunner._io_retry`). Injection happens
    BEFORE the underlying op, so a store op either happens atomically or
    raises — retries never double-apply.

Weight normalization is the bit-match trick: raw weights are rescaled so
that a fully-on-time cohort gets exactly 1.0 everywhere, and `x * 1.0` is
an IEEE754 no-op — chaos disabled + buffer_k == m reproduces the
synchronous trajectory bit-for-bit (tests/test_fleet.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import numpy as np

# salts folded into the seeded generators so the independent fault channels
# (darkness, latency, store I/O) never share a stream; registered (and
# uniqueness-checked) in repro.core.salts
from repro.core.salts import (
    CHAOS_DROP_SALT as _SALT_DROP,
    CHAOS_IO_SALT as _SALT_IO,
    CHAOS_LATENCY_SALT as _SALT_LATENCY,
)

LATE_POLICIES = ("discount", "drop")


class TransientStoreError(RuntimeError):
    """An injected (recoverable) store-I/O failure — retry the op."""


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """Deterministic fault-injection knobs (all off by default).

    dropout     P(a cohort client goes dark for the round — never reports)
    straggler   P(an alive client reports late)
    delay       mean extra latency a straggler adds (in units of the base
                round latency, which is uniform [0, 1))
    store_fail  P(one store gather/scatter raises TransientStoreError)
    max_retries bounded retry budget per store op
    backoff     base seconds for exponential retry backoff (0 = don't sleep)
    seed        every draw derives from (seed, salt, round) — same seed,
                same faults
    """

    dropout: float = 0.0
    straggler: float = 0.0
    delay: float = 1.0
    store_fail: float = 0.0
    max_retries: int = 3
    backoff: float = 0.0
    seed: int = 0

    def __post_init__(self):
        for name in ("dropout", "straggler", "store_fail"):
            v = getattr(self, name)
            if not 0.0 <= v < 1.0:
                raise ValueError(f"{name}={v} must be in [0, 1)")
        if self.delay < 0:
            raise ValueError(f"delay={self.delay}")
        if self.max_retries < 0:
            raise ValueError(f"max_retries={self.max_retries}")

    @property
    def any_faults(self) -> bool:
        return (self.dropout > 0 or self.straggler > 0
                or self.store_fail > 0)

    def spec(self) -> dict:
        """JSON-serializable config for the checkpoint manifest."""
        return dataclasses.asdict(self)


def _rng(seed: int, salt: int, rnd: int) -> np.random.Generator:
    return np.random.default_rng((int(seed), int(salt), int(rnd)))


class ParticipationPlan(NamedTuple):
    """One round's deterministic participation outcome (host-side).

    weights:   (m,) f32 per-rank wire weights, pre-normalized so a fully
               on-time round is exactly 1.0 everywhere (bitwise no-op);
    completes: (m,) bool — fold the report in: scatter shifts, advance the
               RR data cursor. ~completes clients re-enter the cohort walk
               at their pre-round position (exactly-once);
    reported:  (m,) bool — the client transmitted this round (uplink bits
               are charged even when a late report is dropped);
    latency:   (m,) simulated report latencies (inf = dark/padded);
    deadline:  the K-th fastest alive latency (the buffer trigger);
    on_time:   (m,) bool — alive AND within the deadline. This is the
               truth for participation metrics: the normalized `weights`
               can exceed 1.0 for discounted LATE reports whenever the
               rescale factor m/sum(w) > 1 (any late/dark client), so
               thresholding weights misclassifies them.
    """

    weights: np.ndarray
    completes: np.ndarray
    reported: np.ndarray
    latency: np.ndarray
    deadline: float
    on_time: np.ndarray


class AsyncPlanner:
    """FedBuff K-of-m round planner: a pure function `(round, cohort) ->
    ParticipationPlan` shared by the stream (cursor accounting) and the
    driver (wire weights).

    buffer_k  the server applies the update once this many reports arrive
              (None = cohort size m: wait for everyone — synchronous);
    late      'discount': late reports fold in with weight
              discount / (1 + staleness), cursor advances;
              'drop': late reports are discarded, weight 0, cursor rewound
              (never advanced) so the client re-reads the same RR batches
              next time it is sampled;
    discount  the staleness-discount numerator;
    resize    optional round -> active cohort size (<= m): elastic
              shrink/grow between rounds. Ranks past the active count are
              padding — weight 0, no cursor advance, no bits — so the
              compiled step never sees a shape change.
    """

    def __init__(self, m: int, *, buffer_k: int | None = None,
                 late: str = "discount", discount: float = 0.5,
                 chaos: ChaosConfig | None = None,
                 resize: Callable[[int], int] | None = None):
        if late not in LATE_POLICIES:
            raise ValueError(
                f"late={late!r}; options: {LATE_POLICIES}")
        if buffer_k is not None and not 1 <= buffer_k <= m:
            raise ValueError(
                f"buffer_k={buffer_k} must be in [1, cohort size {m}]")
        if not 0.0 < discount <= 1.0:
            raise ValueError(f"discount={discount} must be in (0, 1]")
        self.m = int(m)
        self.buffer_k = self.m if buffer_k is None else int(buffer_k)
        self.late = late
        self.discount = float(discount)
        self.chaos = chaos if chaos is not None else ChaosConfig()
        self.resize = resize

    @property
    def may_defer(self) -> bool:
        """True when some cohort client may finish a round without its
        cursor advancing (dropout, late-drop, or elastic padding) —
        incompatible with the shared-slot (diana_rr) cursor contract."""
        return (self.chaos.dropout > 0 or self.late == "drop"
                or self.resize is not None)

    def spec(self) -> dict:
        return {"buffer_k": self.buffer_k, "late": self.late,
                "discount": self.discount, "elastic_resize":
                self.resize is not None, "chaos": self.chaos.spec()}

    def __call__(self, rnd: int, cohort: np.ndarray) -> ParticipationPlan:
        m, c = self.m, self.chaos
        active = np.ones(m, bool)
        if self.resize is not None:
            a = int(self.resize(rnd))
            if not 1 <= a <= m:
                raise ValueError(
                    f"resize({rnd}) = {a} outside [1, {m}] — the padded "
                    "cohort can shrink below m but never below 1 or past "
                    "the compiled cohort size")
            active[a:] = False
        dark = np.zeros(m, bool)
        if c.dropout > 0:
            dark = _rng(c.seed, _SALT_DROP, rnd).random(m) < c.dropout
        lat_rng = _rng(c.seed, _SALT_LATENCY, rnd)
        latency = lat_rng.random(m)
        if c.straggler > 0:
            strag = lat_rng.random(m) < c.straggler
            latency = latency + strag * c.delay * (1.0 + lat_rng.random(m))
        alive = active & ~dark
        latency = np.where(alive, latency, np.inf)
        n_alive = int(alive.sum())
        weights = np.zeros(m, np.float64)
        completes = np.zeros(m, bool)
        if n_alive == 0:
            return ParticipationPlan(weights.astype(np.float32), completes,
                                     alive.copy(), latency, np.inf,
                                     np.zeros(m, bool))
        k = min(self.buffer_k, n_alive)
        deadline = float(np.partition(latency, k - 1)[k - 1])
        on_time = alive & (latency <= deadline)
        late = alive & ~on_time
        weights[on_time] = 1.0
        completes |= on_time
        if self.late == "discount":
            # staleness-discounted fold-in: the work is kept, so the RR
            # cursor advances — exactly-once is preserved by consumption
            weights[late] = self.discount / (1.0 + latency[late] - deadline)
            completes |= late
        # normalize so the collective mean over m ranks weights reports by
        # w / sum(w) * m; a fully on-time cohort gives exactly 1.0 per rank
        # (m / m), which the elastic wire multiplies in as a bitwise no-op
        weights = weights * (m / weights.sum())
        return ParticipationPlan(weights.astype(np.float32), completes,
                                 alive, latency, deadline, on_time)


class FaultyStore:
    """Deterministic transient-failure wrapper around a `ClientStateStore`.

    gather/scatter/advance/add_bits draw from `(seed, round-robin call
    index)` and raise `TransientStoreError` BEFORE touching the underlying
    store when the draw fires — the op either happens atomically or not at
    all, so the driver's bounded retry (a fresh call index per attempt) can
    never double-apply a scatter or a cursor advance. All other attributes
    delegate uninjected (`touch` is a prefetch hint, `as_tree` a
    checkpoint read — neither sits on the retried round path).
    """

    def __init__(self, store, chaos: ChaosConfig):
        self._store = store
        self._chaos = chaos
        self._calls = 0
        self.injected_failures = 0

    def _maybe_fail(self, op: str) -> None:
        n = self._calls
        self._calls += 1
        if _rng(self._chaos.seed, _SALT_IO, n).random() < self._chaos.store_fail:
            self.injected_failures += 1
            raise TransientStoreError(
                f"injected transient store {op} failure (I/O call {n})")

    def gather(self, cohort):
        self._maybe_fail("gather")
        return self._store.gather(cohort)

    def scatter(self, cohort, updated):
        self._maybe_fail("scatter")
        return self._store.scatter(cohort, updated)

    def advance(self, cohort, micro_steps):
        self._maybe_fail("advance")
        return self._store.advance(cohort, micro_steps)

    def add_bits(self, cohort, bits_per_client):
        self._maybe_fail("add_bits")
        return self._store.add_bits(cohort, bits_per_client)

    def __getattr__(self, name):
        return getattr(self._store, name)
