"""Partial participation at population scale (DESIGN.md §3.9–3.10).

The mesh's client ranks stop being *the* M clients and become the cohort
slots a population of C >> M clients rotates through:

- `CohortSampler` — client-level random reshuffling: shuffle the population
  once per fleet epoch, walk it in cohorts (every client participates
  exactly once per fleet epoch), with an i.i.d. `with_replacement` baseline;
- `ClientStateStore` — host-backed (numpy, mmap-friendly) sharded store of
  per-client persistent state: DIANA shifts / DIANA-RR slot tables, data
  cursors, uplink bit counters; `gather(cohort)`/`scatter(cohort, ...)` are
  the O(cohort) device boundary;
- `FleetRunner` — drives the UNCHANGED jitted train step over sampled
  cohorts (`launch.steps.with_cohort_shifts` swaps the gathered slices in);
- `AsyncFleetRunner` — buffered-async rounds: FedBuff-style K-of-m buffer
  trigger, staleness-discounted or dropped late reports with exactly-once
  RR cursor rewind, elastic cohort resizing via weight-0 padding, and the
  deterministic fault-injection layer in `repro.fleet.chaos`.

The simulator cross-check lives in `repro.core.algorithms.run_fleet_rounds`.
"""
from repro.fleet.chaos import (
    LATE_POLICIES,
    AsyncPlanner,
    ChaosConfig,
    FaultyStore,
    ParticipationPlan,
    TransientStoreError,
)
from repro.fleet.cohort import COHORT_MODES, CohortSampler
from repro.fleet.driver import AsyncFleetRunner, FleetRunner
from repro.fleet.store import ClientStateStore

__all__ = [
    "COHORT_MODES",
    "LATE_POLICIES",
    "AsyncFleetRunner",
    "AsyncPlanner",
    "ChaosConfig",
    "CohortSampler",
    "ClientStateStore",
    "FaultyStore",
    "FleetRunner",
    "ParticipationPlan",
    "TransientStoreError",
]
