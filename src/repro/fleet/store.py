"""Host-backed sharded per-client state store for fleet-scale training.

Device memory holds O(cohort) state; the population's persistent per-client
state lives here, on the host, sharded along the client axis:

  - DIANA shifts: one control variate per client (`(C, *param)` per leaf) or
    a DIANA-RR slot table (`(C, n_slots, *param)`), in the wire's
    `shift_dtype` so a gather/scatter round-trip is lossless;
  - per-client data cursors: micro-steps each client has consumed (drives
    the per-cohort batch stream, `data.pipeline.CohortStream`);
  - per-client uplink bit counters (float64 — host-side, no x64 ceremony).

Each leaf is a list of `shard_size`-row numpy arrays. With `path=...` the
shards are `np.memmap` files (one per leaf per shard) — zero pages are
never materialized, so a 10^5-client store costs disk sparsely and RSS only
for the rows actually touched. `gather(cohort)` returns device-ready
`(m, [n_slots,] *param)` slices that plug straight into the existing
`ShiftRule` layer (`core/rules.py`); `scatter(cohort, updated)` writes the
round's results back. The wire and simulator run unchanged math on the
gathered slice (DESIGN.md §3.9).
"""
from __future__ import annotations

import os
from typing import Any

import jax
import numpy as np


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
             for p, _ in flat]
    return names, [l for _, l in flat], treedef


def _np_dtype(dtype) -> np.dtype:
    """Portable numpy dtype for a (possibly jax) dtype; bf16 via ml_dtypes."""
    name = str(np.dtype(dtype)) if not hasattr(dtype, "name") else dtype.name
    if name == "bfloat16":
        import ml_dtypes

        return np.dtype(ml_dtypes.bfloat16)
    return np.dtype(name)


class ClientStateStore:
    """Sharded host store of per-client persistent state.

    Build with :meth:`create` (zeros, the fresh-run layout) and restore a
    checkpoint into it with :meth:`load_tree`. `population` rows are split
    into ceil(C / shard_size) shards; every accessor takes a SORTED cohort
    id vector (the canonical order `CohortSampler` emits).
    """

    def __init__(self, *, population: int, shard_size: int,
                 shift_leaves: list[list[np.ndarray]] | None,
                 shift_names: list[str], shift_treedef,
                 cursor: np.ndarray, bits: np.ndarray,
                 n_slots: int, path: str | None):
        self.population = int(population)
        self.shard_size = int(shard_size)
        self._shift_leaves = shift_leaves  # [leaf][shard] row-block arrays
        self._shift_names = shift_names
        self._shift_treedef = shift_treedef
        self.cursor = cursor  # (C,) int64 micro-steps consumed per client
        self.bits = bits  # (C,) float64 cumulative uplink bits per client
        self.n_slots = int(n_slots)
        self.path = path

    # -- construction -------------------------------------------------------

    @classmethod
    def create(cls, params, population: int, rule, *, n_slots: int = 1,
               dtype=np.float32, shard_size: int = 65_536,
               path: str | None = None) -> "ClientStateStore":
        """Zero store shaped for `rule` over `params`-shaped clients.

        `rule` is a `repro.core.rules.ShiftRule`: rules without memory
        (`has_shifts=False`) get a shift-less store (cursors/bits only);
        slotted rules insert the `n_slots` axis after the client axis.
        `params` may be concrete arrays or ShapeDtypeStructs. `path` makes
        every shard an `np.memmap` under that directory.
        """
        if population < 1:
            raise ValueError(f"population={population}")
        if shard_size < 1:
            raise ValueError(f"shard_size={shard_size}")
        dt = _np_dtype(dtype)
        names, leaves, treedef = _leaf_paths(params)
        shift_leaves = None
        if rule.has_shifts:
            lead = (n_slots,) if rule.slotted else ()
            if path is not None:
                # fail fast with a readable error instead of deep inside
                # np.memmap when the path is unwritable (read-only mount,
                # permission hole, a FILE where the dir should be, ...)
                try:
                    os.makedirs(path, exist_ok=True)
                    probe = os.path.join(path, ".write_probe")
                    with open(probe, "wb"):
                        pass
                    os.unlink(probe)
                except OSError as e:
                    raise OSError(
                        f"store path {path!r} is not a writable directory "
                        f"({e}) — pass a location the fleet driver can "
                        "memmap shift shards under") from e
            shift_leaves = []
            for name, leaf in zip(names, leaves):
                shards = []
                for s, rows in _shard_rows(population, shard_size):
                    shape = (rows,) + lead + tuple(leaf.shape)
                    if path is None:
                        shards.append(np.zeros(shape, dt))
                    else:
                        fn = os.path.join(
                            path, f"{name.replace('/', '.')}.{s}.dat")
                        shards.append(
                            np.memmap(fn, dtype=dt, mode="w+", shape=shape))
                shift_leaves.append(shards)
        return cls(population=population, shard_size=shard_size,
                   shift_leaves=shift_leaves, shift_names=names,
                   shift_treedef=treedef,
                   cursor=np.zeros((population,), np.int64),
                   bits=np.zeros((population,), np.float64),
                   n_slots=n_slots, path=path)

    @staticmethod
    def estimate_nbytes(params, population: int, rule, *, n_slots: int = 1,
                        dtype=np.float32) -> int:
        """Host bytes a `create` call would back (without allocating) —
        the dry-run's fleet sizing number."""
        if not rule.has_shifts:
            return population * (8 + 8)  # cursors + bit counters
        slot = n_slots if rule.slotted else 1
        per_client = sum(
            int(np.prod(l.shape)) for l in jax.tree.leaves(params)
        ) * slot * _np_dtype(dtype).itemsize
        return population * (per_client + 8 + 8)

    @property
    def has_shifts(self) -> bool:
        return self._shift_leaves is not None

    @property
    def num_shards(self) -> int:
        return -(-self.population // self.shard_size)

    def spec(self) -> dict:
        """JSON-serializable layout description (checkpoint validation)."""
        return {"population": self.population,
                "shard_size": self.shard_size, "n_slots": self.n_slots,
                "leaves": list(self._shift_names) if self.has_shifts else []}

    # -- sharded row access --------------------------------------------------

    def _check_cohort(self, cohort: np.ndarray) -> np.ndarray:
        cohort = np.asarray(cohort, np.int64)
        if cohort.ndim != 1:
            raise ValueError(f"cohort must be a 1-D id vector, got shape "
                             f"{cohort.shape}")
        # full-vector bounds check BEFORE sortedness: an unsorted cohort
        # with out-of-range ids must get the bounds error (naming the bad
        # ids), not a misleading "strictly increasing" complaint
        oob = cohort[(cohort < 0) | (cohort >= self.population)]
        if oob.size:
            shown = ", ".join(str(c) for c in oob[:8])
            more = f" (+{oob.size - 8} more)" if oob.size > 8 else ""
            raise ValueError(
                f"cohort ids outside [0, {self.population}): "
                f"[{shown}]{more}")
        if np.any(np.diff(cohort) <= 0):
            raise ValueError(
                "cohort must be strictly increasing — sorted, distinct ids "
                "(the canonical CohortSampler order); duplicates would make "
                "scatter ill-defined")
        return cohort

    def _take(self, shards: list[np.ndarray], idx: np.ndarray) -> np.ndarray:
        out = np.empty((idx.size,) + shards[0].shape[1:], shards[0].dtype)
        sid = idx // self.shard_size
        for s in np.unique(sid):
            sel = sid == s
            out[sel] = shards[s][idx[sel] - s * self.shard_size]
        return out

    def _put(self, shards: list[np.ndarray], idx: np.ndarray,
             values: np.ndarray) -> None:
        sid = idx // self.shard_size
        for s in np.unique(sid):
            sel = sid == s
            shards[s][idx[sel] - s * self.shard_size] = values[sel]

    # -- the gather/scatter contract ------------------------------------------

    def gather(self, cohort: np.ndarray):
        """Cohort shift slices: a pytree with leaves `(m, [n_slots,] *param)`
        in the store dtype — exactly the client-stacked layout
        `TrainState.shifts` / `FedState.shifts` hold for resident clients,
        ready for `device_put` onto the shift shardings. None for
        memory-free rules."""
        if not self.has_shifts:
            return None
        cohort = self._check_cohort(cohort)
        leaves = [self._take(shards, cohort)
                  for shards in self._shift_leaves]
        return jax.tree_util.tree_unflatten(self._shift_treedef, leaves)

    def scatter(self, cohort: np.ndarray, updated) -> None:
        """Write a round's updated cohort slices back (inverse of gather).
        Accepts jax or numpy leaves; dtype must round-trip losslessly (the
        wire keeps tables in the store's `shift_dtype`)."""
        if not self.has_shifts:
            if updated is not None:
                raise ValueError("store holds no shifts (memory-free rule) "
                                 "but scatter got a value")
            return
        cohort = self._check_cohort(cohort)
        _, leaves, _ = _leaf_paths(updated)
        if len(leaves) != len(self._shift_leaves):
            raise ValueError(
                f"scatter tree has {len(leaves)} leaves, store holds "
                f"{len(self._shift_leaves)}")
        for shards, leaf in zip(self._shift_leaves, leaves):
            arr = np.asarray(leaf)
            want = (cohort.size,) + shards[0].shape[1:]
            if arr.shape != want:
                raise ValueError(
                    f"scatter leaf shape {arr.shape} != cohort slice {want}")
            self._put(shards, cohort, arr.astype(shards[0].dtype, copy=False))

    def touch(self, cohort: np.ndarray) -> int:
        """Warm the cohort's shift rows (the lookahead pager's prefetch
        hint, DESIGN.md §3.11): reads and discards them so memmap-backed
        shards fault their pages in off the critical path. Returns bytes
        touched; no-op for memory-free rules."""
        if not self.has_shifts:
            return 0
        cohort = self._check_cohort(cohort)
        n = 0
        for shards in self._shift_leaves:
            n += self._take(shards, cohort).nbytes
        return n

    # -- cursors / accounting --------------------------------------------------

    def cursors(self, cohort: np.ndarray) -> np.ndarray:
        """(m,) per-client micro-step cursors for the cohort."""
        return self.cursor[self._check_cohort(cohort)].copy()

    def advance(self, cohort: np.ndarray, micro_steps: int) -> None:
        """Advance the cohort's data cursors after a round."""
        self.cursor[self._check_cohort(cohort)] += int(micro_steps)

    def add_bits(self, cohort: np.ndarray, bits_per_client: float) -> None:
        """Charge a round's uplink bits to the participating clients."""
        # analysis: allow[bits-accounting] host-side float64 counters
        # (53-bit mantissa): the f32 stall the rule guards against can't
        # happen off-device; api.accumulate_bits is for on-device arrays
        self.bits[self._check_cohort(cohort)] += float(bits_per_client)

    # -- checkpointing ----------------------------------------------------------

    def as_tree(self) -> dict:
        """The store as a plain pytree of numpy arrays (per-shard, no
        concatenation) for `checkpoint.save_pytree`. Shapes are a pure
        function of `spec()`, so a fresh `create` + `load_tree` restores."""
        tree: dict[str, Any] = {"cursor": self.cursor, "bits": self.bits}
        if self.has_shifts:
            tree["shifts"] = {
                name: list(shards)
                for name, shards in zip(self._shift_names,
                                        self._shift_leaves)}
        return tree

    def load_tree(self, tree: dict) -> None:
        """Restore `as_tree()` output in place (shapes/dtypes must match —
        build the store with the run's own `create` first)."""
        self.cursor[...] = np.asarray(tree["cursor"], np.int64)
        self.bits[...] = np.asarray(tree["bits"], np.float64)
        if not self.has_shifts:
            return
        shifts = tree["shifts"]
        for name, shards in zip(self._shift_names, self._shift_leaves):
            loaded = shifts[name]
            if len(loaded) != len(shards):
                raise ValueError(
                    f"{name}: checkpoint has {len(loaded)} shards, store "
                    f"{len(shards)} — population/shard_size mismatch")
            for dst, src in zip(shards, loaded):
                arr = np.asarray(src)
                if arr.shape != dst.shape:
                    raise ValueError(
                        f"{name}: shard shape {arr.shape} != {dst.shape}")
                dst[...] = arr.astype(dst.dtype, copy=False)


def _shard_rows(population: int, shard_size: int):
    """Yield (shard_index, rows_in_shard)."""
    for s in range(-(-population // shard_size)):
        lo = s * shard_size
        yield s, min(shard_size, population - lo)
