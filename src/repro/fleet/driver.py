"""Production fleet driver: partial participation around the jitted step.

`launch.steps.make_train_step` compiles a step for the mesh's M client
ranks; this driver decouples those ranks from the client *population*: each
round it samples a cohort of `M = num_clients(mesh)` clients from a
population of C (`CohortSampler`), swaps the cohort's persistent shifts
from the host `ClientStateStore` into the TrainState's client-granular
shift field (`steps.with_cohort_shifts` — device memory stays O(cohort)),
feeds the cohort's batch rows from the per-cohort stream
(`data.pipeline.CohortStream`), and scatters the updated shifts back after
the step. The jitted step itself is UNCHANGED — the same compiled function
a full-participation run calls — which is what makes a
`cohort == population` cohort-RR run bit-match the flat wire trajectory
(DESIGN.md §3.9, tests/test_fleet.py).

Which TrainState field holds the per-client state depends on the mesh
topology: `shifts` when the client ranks form the inner wire level, and
`pod_shifts` on flat-mesh NASTYA (`configure_agg` with `client_axes=()`
maps every client onto its own pod, so per-client DIANA state lives in the
outer tables) — the store round-trips either field.

Server/level wire state (`mean_shift`; `pod_shifts`/`pod_mean_shift` on
hierarchical meshes, where a "pod" is a group of clients) stays
device-resident in `TrainState` across rounds, updated incrementally
exactly as in full participation. See the stale-shift-semantics note in
DESIGN.md §3.9 — and set `agg.mean_scale = M/C` so the resident mean shift
tracks the population mean instead of its (C/M)-inflated cohort estimate.

`AsyncFleetRunner` is the buffered-async variant (DESIGN.md §3.10): the
server folds a round in once K of m reports arrive, late reports are
staleness-discounted or dropped with their RR cursor rewound, faults come
from the deterministic `repro.fleet.chaos` layer, and the cohort can
shrink/grow between rounds via weight-0 padding — all on the SAME compiled
(elastic) step.
"""
from __future__ import annotations

import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.data.pipeline import CohortStream
from repro.fleet.chaos import (
    AsyncPlanner,
    ChaosConfig,
    FaultyStore,
    TransientStoreError,
)
from repro.fleet.cohort import CohortSampler
from repro.fleet.store import ClientStateStore
from repro.launch import steps as _steps
from repro.launch.mesh import num_clients


class FleetRunner:
    """Drives a compiled train step over a sampled-cohort population.

    Parameters mirror what `train.py` already holds: the `make_train_step`
    outputs, the bound aggregation config, the population-sized
    client-stacked `data` + its stateless `ReshuffleSampler`, the
    `CohortSampler`, and the `ClientStateStore`. `start_round` resumes the
    walk; the runner verifies the restored store's per-client cursors
    against the cohort walk's replay, so a checkpoint from a different
    cohort/sampler config cannot silently resume.
    """

    def __init__(self, jitted, abstract, shardings, batch_sh, *, agg, mesh,
                 data=None, sampler, cohorts: CohortSampler,
                 store: ClientStateStore, local_steps: int = 1,
                 prefetch: bool = True, start_round: int = 0, planner=None,
                 paged=None):
        m = num_clients(mesh)
        if cohorts.cohort_size != m:
            raise ValueError(
                f"cohort_size={cohorts.cohort_size} must equal the mesh's "
                f"client rank count {m} — the step is compiled for M mesh "
                "clients and the cohort fills exactly those ranks")
        if store.population != cohorts.population:
            raise ValueError(
                f"store population {store.population} != cohort sampler "
                f"population {cohorts.population}")
        agg = _steps.configure_agg(agg, mesh, local_steps)
        # which TrainState field carries the per-client tables this driver
        # round-trips: flat-mesh NASTYA maps each client onto its own pod
        self._shift_field = "shifts" if agg.client_axes else "pod_shifts"
        if store.has_shifts:
            want_slots = (agg.n_slots if agg.client_axes
                          else agg._pod_slots) if agg.rule.slotted else 1
            if store.n_slots != want_slots:
                raise ValueError(
                    f"store n_slots={store.n_slots} but the wire's "
                    f"{self._shift_field} tables carry {want_slots} slot "
                    "rows — create the store with the configured agg's "
                    "slot count (configure_agg collapses outer tables to "
                    "1 row on NASTYA paths)")
        self._slotted = agg.rule.slotted
        if self._slotted:
            # the per-slot wire reads/writes ONE shared table row per round
            # (DESIGN.md §3.8): every cohort client must sit at the same
            # data position. Cohort-RR keeps participation counts equal
            # within a cohort only when cohorts never straddle a fleet-epoch
            # boundary; i.i.d. sampling never keeps them equal.
            if cohorts.mode != "rr" or cohorts.population % m != 0:
                raise ValueError(
                    "per-slot methods (diana_rr) need cohort-RR with "
                    "population divisible by the cohort size: a cohort that "
                    "straddles a fleet-epoch boundary (or i.i.d. cohorts) "
                    "mixes clients at different data positions, and the "
                    "shared-slot wire contract breaks (DESIGN.md §3.9)")
            if sampler.mode != "rr_shared":
                raise ValueError(
                    "per-slot methods need ReshuffleSampler(mode="
                    "'rr_shared') so every client walks the same index "
                    "order (DESIGN.md §3.8)")
            n_slots = agg.n_slots if agg.client_axes else agg._pod_slots
            if sampler.n > n_slots:
                raise ValueError(
                    f"sampler draws batch indices in [0, {sampler.n}) but "
                    f"the wire has n_slots={n_slots} shift rows")
        self._jitted = jitted
        self._shardings = shardings
        self._store = store
        self._local_steps = int(local_steps)
        self._pager = paged
        self._stream = CohortStream(
            data, sampler, cohorts, local_steps=local_steps,
            put=lambda b: jax.device_put(b, batch_sh(b)), prefetch=prefetch,
            start_round=start_round, planner=planner, paged=paged)
        if paged is not None:
            # all store I/O routes through the pager from here on; the
            # async subclass re-binds after its chaos FaultyStore wrap
            paged.bind_store(self._store)
        if not np.array_equal(store.cursor, self._stream.counts):
            bad = np.flatnonzero(store.cursor != self._stream.counts)
            shown = ", ".join(str(c) for c in bad[:8])
            more = f" (+{bad.size - 8} more)" if bad.size > 8 else ""
            raise ValueError(
                "store per-client cursors disagree with the cohort walk at "
                f"round {start_round} for client ids [{shown}]{more} — the "
                "checkpoint was written by a different cohort/sampler/"
                "chaos config (or rounds are missing)")
        # per-client uplink bits per round: this client's compressed slab on
        # the level it talks on (the intra-pod wire; on pod-granular NASTYA
        # meshes every client is its own pod and talks on the outer level)
        wire = agg.wire_bytes_per_round(abstract.params)
        self._bits_per_client = 8.0 * (
            wire["intra_pod"] if agg.client_axes else wire["inter_pod"])
        self._wire_dtype = agg.wire_dtype
        self._cohort_size = m
        # static accounting facts, once per run: the per-level wire bytes
        # every per-round uplink counter derives from
        telemetry.run_meta({
            "driver": type(self).__name__,
            "wire_bytes_per_round": {k: int(v) for k, v in wire.items()},
            "bits_per_client_round": self._bits_per_client,
            "wire_dtype": self._wire_dtype, "cohort": m,
            "population": store.population, "local_steps": self._local_steps})

    @property
    def store(self) -> ClientStateStore:
        return self._store

    @property
    def round(self) -> int:
        """Next unconsumed round (the checkpointable fleet cursor)."""
        return self._stream.round

    def checkpoint_meta(self) -> dict:
        """JSON-serializable fleet cursor + sampler/store specs for the
        checkpoint manifest (`checkpoint.save_fleet_checkpoint`)."""
        meta = {**self._stream.cursor_meta(),
                "store": self._store.spec(),
                "bits_per_client_round": self._bits_per_client,
                "wire_dtype": self._wire_dtype}
        if self._pager is not None:
            meta["data_store"] = self._pager.data.spec()
        return meta

    def _device_shifts(self, state):
        return getattr(state, self._shift_field)

    def run(self, state, key, rounds: int,
            callback: Callable[[int, Any, dict], None] | None = None):
        """Advance `rounds` fleet rounds from `state`; returns the final
        TrainState. `callback(round, state, metrics)` fires per round
        (logging/checkpoint hooks). The store is updated in place."""
        store = self._store
        # paged runs route gather/scatter through the pager (one I/O
        # object for data pages and state rows); it delegates to the store
        io = self._pager if self._pager is not None else store
        for _ in range(rounds):
            fr = next(self._stream)
            with telemetry.span("gather", round=fr.round):
                gathered = io.gather(fr.cohort)
            state = _steps.with_cohort_shifts(
                state, gathered, self._shardings, self._shift_field)
            if self._slotted:
                if not (fr.cols == fr.cols[:1]).all():
                    raise RuntimeError(
                        "cohort clients disagree on the round's batch "
                        "indices — shared-slot invariant broken (this is a "
                        "bug: the constructor gates should have rejected "
                        "the config)")
                slots = jnp.asarray(fr.cols[0], jnp.int32)
                with telemetry.span("device_step", round=fr.round):
                    state, metrics = self._jitted(state, fr.batch, key,
                                                  slots)
            else:
                with telemetry.span("device_step", round=fr.round):
                    state, metrics = self._jitted(state, fr.batch, key)
            if store.has_shifts:
                with telemetry.span("scatter", round=fr.round):
                    io.scatter(fr.cohort,
                               jax.device_get(self._device_shifts(state)))
            store.advance(fr.cohort, self._local_steps)
            store.add_bits(fr.cohort, self._bits_per_client)
            # one participation schema across sync/async: the sync round is
            # the degenerate plan where everyone reports on time, weight 1
            m = self._cohort_size
            metrics = dict(metrics)
            metrics.update(completed=m, on_time=m, weight_sum=float(m))
            telemetry.counter("fleet.uplink_bits",
                             m * self._bits_per_client, round=fr.round)
            telemetry.round_metrics(fr.round, metrics)
            if callback is not None:
                callback(fr.round, state, metrics)
        return state

    def close(self):
        self._stream.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class AsyncFleetRunner(FleetRunner):
    """Buffered-async fleet rounds with deterministic fault injection
    (DESIGN.md §3.10).

    Per round an `AsyncPlanner` — a pure function of `(chaos seed, round)`
    — decides who reports on time (the K-of-m buffer trigger), who is late
    (staleness-discounted or dropped), who went dark, and which padded
    ranks an elastic resize masked out. The plan becomes:

      - the (m,) weights vector of the ELASTIC jitted step (build it with
        `make_train_step(..., elastic=True)`): weight 0 masks a client out
        of the collective mean without recompiling;
      - the `completes` mask driving exactly-once RR accounting: only
        completing clients scatter shifts / advance cursors / get the next
        data positions — everyone else re-enters the cohort walk at their
        pre-round position, shift tables untouched.

    A round with zero completers skips the jitted launch entirely (the
    server buffer never fills, so no update is applied; `state.step` does
    not advance — deterministic, so resume stays bit-exact).

    With chaos disabled and `buffer_k == m` every round is fully on-time
    with weight exactly 1.0 per rank — bitwise the synchronous trajectory.
    """

    def __init__(self, jitted, abstract, shardings, batch_sh, *, agg, mesh,
                 data=None, sampler, cohorts: CohortSampler,
                 store: ClientStateStore, buffer_k: int | None = None,
                 late: str = "discount", discount: float = 0.5,
                 chaos: ChaosConfig | None = None,
                 resize: Callable[[int], int] | None = None,
                 local_steps: int = 1, prefetch: bool = True,
                 start_round: int = 0, paged=None):
        if local_steps != 1:
            raise ValueError(
                "async/elastic fleet rounds need local_steps == 1 (the "
                "elastic step rejects NASTYA epochs: a mid-local-epoch "
                "straggler has no well-defined RR rewind point)")
        self._chaos = chaos if chaos is not None else ChaosConfig()
        planner = AsyncPlanner(num_clients(mesh), buffer_k=buffer_k,
                               late=late, discount=discount,
                               chaos=self._chaos, resize=resize)
        super().__init__(jitted, abstract, shardings, batch_sh, agg=agg,
                         mesh=mesh, data=data, sampler=sampler,
                         cohorts=cohorts, store=store,
                         local_steps=local_steps, prefetch=prefetch,
                         start_round=start_round, planner=planner,
                         paged=paged)
        if self._slotted and planner.may_defer:
            raise ValueError(
                "per-slot methods (diana_rr) cannot run with dropout, "
                "late='drop', or elastic resizing: a client whose cursor "
                "rewinds falls out of lockstep with its cohort and the "
                "shared-slot contract breaks (DESIGN.md §3.10) — use "
                "buffered staleness discounting (late='discount') only, "
                "or method='diana'")
        self._planner = planner
        if self._chaos.store_fail > 0:
            # wrap AFTER the cursor cross-check: injection hits the round
            # loop's store ops, not construction
            self._store = FaultyStore(self._store, self._chaos)
            if self._pager is not None:
                # re-bind so paged gather/scatter hit the SAME injection
                # schedule as the unpaged path (pager.state.touch warming
                # delegates uninjected through FaultyStore.__getattr__)
                self._pager.bind_store(self._store)

    def checkpoint_meta(self) -> dict:
        return {**super().checkpoint_meta(), "async": self._planner.spec()}

    def _io_retry(self, op, *args):
        """Bounded-retry wrapper for injected transient store failures;
        every retry is a fresh deterministic draw, backoff doubles."""
        c = self._chaos
        for attempt in range(c.max_retries + 1):
            try:
                return op(*args)
            except TransientStoreError:
                telemetry.counter("fleet.store_retry", 1,
                                  op=getattr(op, "__name__", str(op)))
                if attempt >= c.max_retries:
                    raise
                if c.backoff > 0:
                    time.sleep(c.backoff * 2 ** attempt)

    _STALE_BINS = (0.0, 0.25, 0.5, 1.0, 2.0, 4.0, 8.0, np.inf)

    def _participation(self, plan) -> dict:
        """Chaos counters + the raw (pre-normalization) participation mass.

        `plan.weights` always sums to m after the `m/sum(w)` rescale, so
        the schema's `weight_sum` recomputes the RAW mass the server
        buffered: 1.0 per on-time reporter plus the staleness discount of
        every late fold-in."""
        late = plan.reported & ~plan.on_time
        raw = float(plan.on_time.sum())
        if self._planner.late == "discount" and late.any():
            raw += float(np.sum(
                self._planner.discount
                / (1.0 + plan.latency[late] - plan.deadline)))
        if telemetry.enabled():
            stale = plan.latency[late] - plan.deadline
            hist, _ = np.histogram(stale, bins=np.asarray(self._STALE_BINS))
            telemetry.counter("fleet.on_time", int(plan.on_time.sum()))
            telemetry.counter("fleet.late", int(late.sum()))
            telemetry.counter("fleet.dropped",
                              int(plan.on_time.size - plan.reported.sum()))
            telemetry.counter("fleet.staleness_hist", hist.tolist())
        return {"on_time": int(plan.on_time.sum()),
                "weight_sum": raw,
                "dropped": int(plan.on_time.size - plan.reported.sum()),
                "deadline": float(plan.deadline)}

    def run(self, state, key, rounds: int,
            callback: Callable[[int, Any, dict], None] | None = None):
        """Advance `rounds` buffered-async fleet rounds. The metrics dict
        gains per-round participation stats (`on_time`, `completed`,
        `weight_sum`, `dropped`, `deadline` — the same schema the sync
        runner emits); zero-completer rounds report `{"skipped": True}`
        and leave the state untouched."""
        store = self._store
        io = self._pager if self._pager is not None else store
        for _ in range(rounds):
            fr = next(self._stream)
            plan = fr.plan
            comp = plan.completes
            n_comp = int(comp.sum())
            # from the plan, not the weights: the m/sum(w) rescale pushes
            # discounted LATE weights past 1.0 whenever any client is
            # late/dark, so `weight_sum` is the raw buffered mass instead
            part = self._participation(plan)
            uplink = int(plan.reported.sum()) * self._bits_per_client
            telemetry.counter("fleet.uplink_bits", uplink, round=fr.round)
            if n_comp == 0:
                # the buffer never fills: no server update this round, but
                # reporters still burned uplink bits
                if plan.reported.any():
                    self._io_retry(store.add_bits, fr.cohort[plan.reported],
                                   self._bits_per_client)
                metrics = {"skipped": True, "completed": 0, **part}
                telemetry.round_metrics(fr.round, metrics)
                if callback is not None:
                    callback(fr.round, state, metrics)
                continue
            with telemetry.span("gather", round=fr.round):
                gathered = self._io_retry(io.gather, fr.cohort)
            state = _steps.with_cohort_shifts(
                state, gathered, self._shardings, self._shift_field)
            weights = jnp.asarray(plan.weights)
            with telemetry.span("device_step", round=fr.round):
                if self._slotted:
                    slots = jnp.asarray(fr.cols[0], jnp.int32)
                    state, metrics = self._jitted(state, fr.batch, key,
                                                  slots, weights)
                else:
                    state, metrics = self._jitted(state, fr.batch, key,
                                                  weights)
            if store.has_shifts:
                # only completers persist their round: non-completing rows
                # of the device table are discarded (the next gather
                # overwrites them), leaving their store rows pre-round
                with telemetry.span("scatter", round=fr.round):
                    upd = jax.device_get(self._device_shifts(state))
                    idx = np.flatnonzero(comp)
                    self._io_retry(
                        io.scatter, fr.cohort[idx],
                        jax.tree.map(lambda l: l[idx], upd))
            self._io_retry(store.advance, fr.cohort[comp], self._local_steps)
            self._io_retry(store.add_bits, fr.cohort[plan.reported],
                           self._bits_per_client)
            metrics = dict(metrics)
            metrics.update(completed=n_comp, **part)
            telemetry.round_metrics(fr.round, metrics)
            if callback is not None:
                callback(fr.round, state, metrics)
        return state
