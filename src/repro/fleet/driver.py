"""Production fleet driver: partial participation around the jitted step.

`launch.steps.make_train_step` compiles a step for the mesh's M client
ranks; this driver decouples those ranks from the client *population*: each
round it samples a cohort of `M = num_clients(mesh)` clients from a
population of C (`CohortSampler`), swaps the cohort's persistent shifts
from the host `ClientStateStore` into `TrainState.shifts`
(`steps.with_cohort_shifts` — device memory stays O(cohort)), feeds the
cohort's batch rows from the per-cohort stream
(`data.pipeline.CohortStream`), and scatters the updated shifts back after
the step. The jitted step itself is UNCHANGED — the same compiled function
a full-participation run calls — which is what makes a
`cohort == population` cohort-RR run bit-match the flat wire trajectory
(DESIGN.md §3.9, tests/test_fleet.py).

Server/level wire state (`mean_shift`; `pod_shifts`/`pod_mean_shift` on
hierarchical meshes, where a "pod" is a group of clients) stays
device-resident in `TrainState` across rounds, updated incrementally
exactly as in full participation. See the stale-shift-semantics note in
DESIGN.md §3.9 for what that means when a client is not sampled for many
rounds. One topology is rejected up front: flat-mesh NASTYA
(`local_steps > 1` without a pod axis) maps every CLIENT onto its own pod
(`configure_agg` sets `client_axes=()`), so the per-client DIANA state
lands in `pod_shifts` — which this driver does not round-trip through the
store (ROADMAP open item).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import CohortStream
from repro.fleet.cohort import CohortSampler
from repro.fleet.store import ClientStateStore
from repro.launch import steps as _steps
from repro.launch.mesh import num_clients


class FleetRunner:
    """Drives a compiled train step over a sampled-cohort population.

    Parameters mirror what `train.py` already holds: the `make_train_step`
    outputs, the bound aggregation config, the population-sized
    client-stacked `data` + its stateless `ReshuffleSampler`, the
    `CohortSampler`, and the `ClientStateStore`. `start_round` resumes the
    walk; the runner verifies the restored store's per-client cursors
    against the cohort walk's closed-form replay, so a checkpoint from a
    different cohort/sampler config cannot silently resume.
    """

    def __init__(self, jitted, abstract, shardings, batch_sh, *, agg, mesh,
                 data, sampler, cohorts: CohortSampler,
                 store: ClientStateStore, local_steps: int = 1,
                 prefetch: bool = True, start_round: int = 0):
        m = num_clients(mesh)
        if cohorts.cohort_size != m:
            raise ValueError(
                f"cohort_size={cohorts.cohort_size} must equal the mesh's "
                f"client rank count {m} — the step is compiled for M mesh "
                "clients and the cohort fills exactly those ranks")
        if store.population != cohorts.population:
            raise ValueError(
                f"store population {store.population} != cohort sampler "
                f"population {cohorts.population}")
        agg = _steps.configure_agg(agg, mesh, local_steps)
        if agg.rule.has_shifts and not agg.client_axes:
            raise ValueError(
                "fleet partial participation cannot run pod-granular NASTYA "
                "on a flat mesh: with client_axes=() every client is its own "
                "pod and the per-client DIANA state lives in TrainState."
                "pod_shifts, which the store does not round-trip (ROADMAP "
                "open item) — use a multi-pod mesh (per-client shifts stay "
                "intra-pod) or local_steps=1")
        self._slotted = agg.rule.slotted
        if self._slotted:
            # the per-slot wire reads/writes ONE shared table row per round
            # (DESIGN.md §3.8): every cohort client must sit at the same
            # data position. Cohort-RR keeps participation counts equal
            # within a cohort only when cohorts never straddle a fleet-epoch
            # boundary; i.i.d. sampling never keeps them equal.
            if cohorts.mode != "rr" or cohorts.population % m != 0:
                raise ValueError(
                    "per-slot methods (diana_rr) need cohort-RR with "
                    "population divisible by the cohort size: a cohort that "
                    "straddles a fleet-epoch boundary (or i.i.d. cohorts) "
                    "mixes clients at different data positions, and the "
                    "shared-slot wire contract breaks (DESIGN.md §3.9)")
            if sampler.mode != "rr_shared":
                raise ValueError(
                    "per-slot methods need ReshuffleSampler(mode="
                    "'rr_shared') so every client walks the same index "
                    "order (DESIGN.md §3.8)")
            if sampler.n > agg.n_slots:
                raise ValueError(
                    f"sampler draws batch indices in [0, {sampler.n}) but "
                    f"the wire has n_slots={agg.n_slots} shift rows")
        self._jitted = jitted
        self._shardings = shardings
        self._store = store
        self._local_steps = int(local_steps)
        self._stream = CohortStream(
            data, sampler, cohorts, local_steps=local_steps,
            put=lambda b: jax.device_put(b, batch_sh(b)), prefetch=prefetch,
            start_round=start_round)
        if not np.array_equal(store.cursor, self._stream.counts):
            raise ValueError(
                "store per-client cursors disagree with the cohort walk at "
                f"round {start_round} — the checkpoint was written by a "
                "different cohort/sampler config (or rounds are missing)")
        # per-client uplink bits per round: this client's compressed slab on
        # the level it talks on (the intra-pod wire; on pod-granular NASTYA
        # meshes every client is its own pod and talks on the outer level)
        wire = agg.wire_bytes_per_round(abstract.params)
        self._bits_per_client = 8.0 * (
            wire["intra_pod"] if agg.client_axes else wire["inter_pod"])

    @property
    def store(self) -> ClientStateStore:
        return self._store

    @property
    def round(self) -> int:
        """Next unconsumed round (the checkpointable fleet cursor)."""
        return self._stream.round

    def checkpoint_meta(self) -> dict:
        """JSON-serializable fleet cursor + sampler/store specs for the
        checkpoint manifest (`checkpoint.save_fleet_checkpoint`)."""
        return {**self._stream.cursor_meta(),
                "store": self._store.spec(),
                "bits_per_client_round": self._bits_per_client}

    def run(self, state, key, rounds: int,
            callback: Callable[[int, Any, dict], None] | None = None):
        """Advance `rounds` fleet rounds from `state`; returns the final
        TrainState. `callback(round, state, metrics)` fires per round
        (logging/checkpoint hooks). The store is updated in place."""
        store = self._store
        for _ in range(rounds):
            fr = next(self._stream)
            state = _steps.with_cohort_shifts(
                state, store.gather(fr.cohort), self._shardings)
            if self._slotted:
                if not (fr.cols == fr.cols[:1]).all():
                    raise RuntimeError(
                        "cohort clients disagree on the round's batch "
                        "indices — shared-slot invariant broken (this is a "
                        "bug: the constructor gates should have rejected "
                        "the config)")
                slots = jnp.asarray(fr.cols[0], jnp.int32)
                state, metrics = self._jitted(state, fr.batch, key, slots)
            else:
                state, metrics = self._jitted(state, fr.batch, key)
            if store.has_shifts:
                store.scatter(fr.cohort, jax.device_get(state.shifts))
            store.advance(fr.cohort, self._local_steps)
            store.add_bits(fr.cohort, self._bits_per_client)
            if callback is not None:
                callback(fr.round, state, metrics)
        return state

    def close(self):
        self._stream.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
