"""whisper-medium — enc-dec, conv frontend (stub) [arXiv:2212.04356].

24L decoder + 24L encoder, d_model=1024 16H (kv=16) d_ff=4096 vocab=51865.
The mel-spectrogram + conv feature extractor is the spec'd STUB:
`input_specs` feeds precomputed frame embeddings (B, 1500, d_model).
Encoder is bidirectional (sinusoidal positions); decoder is causal with
learned positions + cross-attention over the 1500-frame encoder output.
long_500k is skipped (decoder is full attention; real context <= 448).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab=51865,
    norm="layernorm",
    act="gelu",
    qkv_bias=True,
    rope_theta=0.0,  # learned/sinusoidal positions, no rotary
    encoder_layers=24,
    encoder_seq=1500,
    max_seq=32_768,  # decoder learned-position table (decode_32k structurally)
)
