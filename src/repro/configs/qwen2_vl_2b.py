"""qwen2-vl-2b — M-RoPE, dynamic resolution [arXiv:2409.12191].

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936. The vision encoder +
projector are the spec'd STUB: `input_specs` feeds precomputed patch
embeddings (B, 256, d_model); the language decoder applies M-RoPE with
(t, h, w) sections (16, 24, 24) over head_dim/2 = 64 channels.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab=151936,
    qkv_bias=True,
    mrope_sections=(16, 24, 24),
    vision_patches=256,
    norm="rmsnorm",
    act="swiglu",
    rope_theta=1_000_000.0,
)
