"""Assigned input shapes + ShapeDtypeStruct stand-ins for every model input.

The four shapes exercise three step kinds:
  train_4k     -> train_step   (tokens + labels, full fwd/bwd + paper's agg)
  prefill_32k  -> prefill_step (prompt forward, KV-cache build)
  decode_32k   -> serve_step   (ONE token, KV cache of seq_len)
  long_500k    -> serve_step   (ONE token, sub-quadratic archs only)

`input_specs` returns weak-type-correct ShapeDtypeStructs — shardable,
never allocated (the dry-run contract, DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def shape_supported(cfg: ArchConfig, shape: InputShape) -> tuple[bool, str]:
    """(supported, reason). Skips follow DESIGN.md §Arch-applicability."""
    if shape.name == "long_500k":
        if not cfg.supports_long_context():
            return False, (
                "full-attention arch: 512k dense KV decode is out of scope "
                "(needs sub-quadratic attention)"
            )
    if cfg.is_encdec and shape.name == "long_500k":
        return False, "whisper decoder is full attention; real context <= 448"
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ArchConfig, shape: InputShape):
    """Batch pytree ShapeDtypeStructs for loss_fn/train_step."""
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": _sds((b, s + 1), jnp.int32)}
    if cfg.family == "vlm":
        batch["patches"] = _sds((b, cfg.vision_patches, cfg.d_model), cfg.dtype)
    if cfg.is_encdec:
        batch["frames"] = _sds((b, cfg.encoder_seq, cfg.d_model), cfg.dtype)
    return batch


def prefill_batch_specs(cfg: ArchConfig, shape: InputShape):
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": _sds((b, s), jnp.int32)}
    if cfg.family == "vlm":
        batch["patches"] = _sds((b, cfg.vision_patches, cfg.d_model), cfg.dtype)
    if cfg.is_encdec:
        batch["frames"] = _sds((b, cfg.encoder_seq, cfg.d_model), cfg.dtype)
    return batch


def decode_specs(cfg: ArchConfig, shape: InputShape):
    """(cache_specs, tokens_spec, pos_spec) for serve_step."""
    from repro.models.transformer import init_cache

    b, s = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(
        lambda: init_cache(None, cfg, batch=b, cache_len=s)
    )
    return cache, _sds((b, 1), jnp.int32), _sds((), jnp.int32)


def input_specs(cfg: ArchConfig, shape: InputShape):
    """Dict of kwargs-by-name for the step function this shape lowers."""
    if shape.kind == "train":
        return {"batch": train_batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        return {"batch": prefill_batch_specs(cfg, shape)}
    cache, tok, pos = decode_specs(cfg, shape)
    return {"cache": cache, "tokens": tok, "pos": pos}
