"""hymba-1.5b — parallel attn+mamba heads [arXiv:2411.13676].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Every layer runs softmax-attention heads and Mamba-2/SSD heads in parallel
and mean-fuses the normalized head groups (paper's hybrid-head module; we use
SWA-1024 on all layers — the paper keeps 3 global layers — and skip
meta-tokens; recorded in DESIGN.md §Arch-applicability). long_500k runs
(SSM state + sliding window).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    d_ff=5504,
    vocab=32001,
    head_dim=64,
    attention_mixer="hymba",
    ssm_state=16,
    ssm_heads=25,
    sliding_window=1024,
    norm="rmsnorm",
    act="swiglu",
    rope_theta=10_000.0,
)
