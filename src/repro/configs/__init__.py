"""Architecture registry: the 10 assigned configs + reduced smoke variants.

Usage:
    cfg = get_config("deepseek-67b")
    small = reduced(cfg)            # 2 layers, d_model<=512, <=4 experts
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.configs.shapes import (
    INPUT_SHAPES,
    InputShape,
    input_specs,
    shape_supported,
    train_batch_specs,
)
from repro.models.config import ArchConfig

_MODULES = {
    "stablelm-1.6b": "stablelm_1_6b",
    "deepseek-67b": "deepseek_67b",
    "rwkv6-7b": "rwkv6_7b",
    "hymba-1.5b": "hymba_1_5b",
    "starcoder2-15b": "starcoder2_15b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "qwen2.5-32b": "qwen2_5_32b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "whisper-medium": "whisper_medium",
    "dbrx-132b": "dbrx_132b",
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str) -> ArchConfig:
    try:
        mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    except KeyError:
        raise ValueError(f"unknown arch {name!r}; options: {sorted(_MODULES)}")
    return mod.CONFIG


def all_configs() -> dict[str, ArchConfig]:
    return {n: get_config(n) for n in ARCH_NAMES}


def reduced(cfg: ArchConfig, *, seq: int = 64) -> ArchConfig:
    """Same-family reduced variant for CPU smoke tests:
    2 layers, d_model <= 512, <= 4 experts, tiny vocab/window."""
    heads = 4
    head_dim = 32
    d_model = heads * head_dim  # 128 — rwkv needs d % heads == 0
    kv = max(1, round(heads * cfg.num_kv_heads / cfg.num_heads))
    changes = dict(
        num_layers=2,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=head_dim,
        d_ff=256,
        vocab=503,  # deliberately pad-worthy (503 -> 512)
        max_seq=max(seq * 2, 128),
    )
    if cfg.num_experts:
        changes.update(num_experts=4, experts_per_token=2)
        if cfg.shared_expert_ff:
            changes.update(shared_expert_ff=128)
    if cfg.ssm_state:
        changes.update(ssm_state=8, ssm_heads=heads)
    if cfg.sliding_window:
        changes.update(sliding_window=min(cfg.sliding_window, seq // 2))
    if cfg.encoder_layers:
        changes.update(encoder_layers=2, encoder_seq=24)
    if cfg.vision_patches:
        changes.update(vision_patches=16)
    if cfg.mrope_sections is not None:
        changes.update(mrope_sections=(4, 6, 6))  # head_dim/2 = 16 channels
    return dataclasses.replace(cfg, **changes)


__all__ = [
    "ARCH_NAMES",
    "ArchConfig",
    "INPUT_SHAPES",
    "InputShape",
    "all_configs",
    "get_config",
    "input_specs",
    "reduced",
    "shape_supported",
    "train_batch_specs",
]
