"""deepseek-67b — llama-arch [arXiv:2401.02954].

95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400. RMSNorm + SwiGLU +
RoPE; the deepest assigned config — exercises scan-over-layers compile
flatness and the sequence-parallel residual (DESIGN.md §5).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-67b",
    family="dense",
    num_layers=95,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab=102400,
    norm="rmsnorm",
    act="swiglu",
    rope_theta=10_000.0,
)
