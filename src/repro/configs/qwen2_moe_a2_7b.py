"""qwen2-moe-a2.7b — 4 shared + 60 routed top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (kv=16) per-expert d_ff=1408 vocab=151936, MoE 60
experts top-4. The 4 shared experts are folded into one always-on dense FFN
of width 4*1408 = 5632 (mathematically identical; DESIGN.md §5).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab=151936,
    qkv_bias=True,
    num_experts=60,
    experts_per_token=4,
    shared_expert_ff=5632,
    norm="rmsnorm",
    act="swiglu",
    rope_theta=1_000_000.0,
)
