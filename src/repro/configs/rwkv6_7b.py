"""rwkv6-7b — Finch, data-dependent decay [arXiv:2404.05892].

32L d_model=4096 (attention-free) d_ff=14336 vocab=65536. 64 heads of size
64 (RWKV convention hd=64). Channel-mix uses relu^2. Decode is O(1) state —
long_500k runs for this arch.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,
    num_kv_heads=64,
    d_ff=14336,
    vocab=65536,
    head_dim=64,
    attention_mixer="rwkv6",
    norm="layernorm",
    act="relu2",
    rope_theta=0.0,  # attention-free; no rotary stream
)
