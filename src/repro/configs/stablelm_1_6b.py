"""stablelm-1.6b — [hf:stabilityai/stablelm-2-1_6b].

24L d_model=2048 32H (GQA kv=32, i.e. MHA) d_ff=5632 vocab=100352.
StableLM-2 uses LayerNorm + SwiGLU + (partial) RoPE; we apply full-dim RoPE.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-1.6b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=5632,
    vocab=100352,
    norm="layernorm",
    act="swiglu",
    rope_theta=10_000.0,
)
