"""qwen2.5-32b — GQA, QKV bias [hf:Qwen/Qwen2.5-0.5B family].

64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064. RMSNorm + SwiGLU +
RoPE + QKV bias. 40 heads are not divisible by the 16-way model axis — GSPMD
shards the fused head axis unevenly (padding); see EXPERIMENTS.md §Roofline.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=27648,
    vocab=152064,
    qkv_bias=True,
    norm="rmsnorm",
    act="swiglu",
    rope_theta=1_000_000.0,
)
