"""starcoder2-15b — GQA, RoPE [arXiv:2402.19173].

40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152. LayerNorm + GELU +
biases (GPT-lineage), sliding window 4096 per the model card — which makes
long_500k runnable via the ring-buffered SWA cache.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-15b",
    family="dense",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    d_ff=24576,
    vocab=49152,
    norm="layernorm",
    act="gelu",
    qkv_bias=True,
    sliding_window=4096,
    rope_theta=100_000.0,
)
