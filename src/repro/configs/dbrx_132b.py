"""dbrx-132b — 16 experts top-4, fine-grained MoE [hf:databricks/dbrx-base].

40L d_model=6144 48H (GQA kv=8) per-expert d_ff=10752 vocab=100352,
MoE 16 experts top-4. LayerNorm + GLU + RoPE. Largest assigned config
(~132B total, ~36B active).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=10752,
    vocab=100352,
    num_experts=16,
    experts_per_token=4,
    norm="layernorm",
    act="swiglu",
    rope_theta=500_000.0,
)
