"""Pallas TPU kernel: blockwise stochastic quantization (QSGD on VPU).

GPU QSGD is an elementwise CUDA kernel with a *global* L2 scale — a bad fit
for TPU (a global reduction before any quantization serializes the grid).
The TPU-native adaptation quantizes per lane-aligned (8, 128) VMEM tile with
a per-tile max-abs scale: one pass over HBM, scale + stochastic rounding
fused, still unbiased conditional on the tile scale (DESIGN.md §3.4).

The uniform randoms are generated OUTSIDE the kernel (jax.random.uniform) and
streamed in — keeps the kernel deterministic and interpretable on CPU.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 1024  # one (8, 128) VPU tile
_BLOCK_TILES = 8  # tiles per grid step: (64, 128) VMEM block


def _qsgd_kernel(x_ref, u_ref, o_ref, *, levels: int):
    x = x_ref[...].astype(jnp.float32)  # (tiles, TILE) block
    u = u_ref[...]
    scale = jnp.max(jnp.abs(x), axis=1, keepdims=True) + 1e-30
    s = float(levels)
    y = jnp.abs(x) / scale * s
    f = jnp.floor(y)
    q = f + (u < (y - f)).astype(jnp.float32)
    o_ref[...] = (jnp.sign(x) * q * (scale / s)).astype(o_ref.dtype)


@partial(jax.jit, static_argnames=("levels", "interpret"))
def qsgd_quantize(x: jax.Array, u: jax.Array, *, levels: int = 8,
                  interpret: bool | None = None) -> jax.Array:
    """x, u: (N,) with N % TILE == 0 (ops.py handles padding)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n = x.shape[0]
    tiles = n // TILE
    # interpret mode (CPU correctness path): one grid step — the emulated
    # grid loop copies the full output buffers every step, so block size is
    # a pure overhead knob there; VMEM limits only bind on real TPUs.
    bt = tiles if interpret else min(_BLOCK_TILES, tiles)
    grid = (pl.cdiv(tiles, bt),)
    xt = x.reshape(tiles, TILE)
    ut = u.reshape(tiles, TILE)
    out = pl.pallas_call(
        partial(_qsgd_kernel, levels=levels),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, TILE), lambda i: (i, 0)),
            pl.BlockSpec((bt, TILE), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bt, TILE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((tiles, TILE), x.dtype),
        interpret=interpret,
    )(xt, ut)
    return out.reshape(n)
