"""jit'd public wrappers for the Pallas kernels (padding, views, dispatch).

On CPU (this container) every kernel runs in interpret mode — the kernel
body executes in Python for correctness; on TPU the same `pallas_call`
compiles to Mosaic. `ref.py` holds the pure-jnp oracles the tests compare
against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.diana_shift import LANES, diana_shift_update as _shift_raw
from repro.kernels.qsgd import TILE, qsgd_quantize as _qsgd_raw
from repro.kernels.randk import BLOCK_ROWS, randk_compress, randk_decompress


def _pad_to(x: jax.Array, multiple: int) -> tuple[jax.Array, int]:
    n = x.shape[0]
    pad = (-n) % multiple
    if pad:
        x = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))
    return x, n


def qsgd(x: jax.Array, key: jax.Array, *, levels: int = 8) -> jax.Array:
    """Blockwise-QSGD quantize->dequantize of an arbitrary-shape tensor."""
    flat = x.reshape(-1)
    padded, n = _pad_to(flat, TILE)
    u = jax.random.uniform(key, padded.shape)
    out = _qsgd_raw(padded, u, levels=levels)
    return out[:n].reshape(x.shape)


def diana_shift(h, q_own, mh, q_mean, *, alpha: float):
    """Fused DIANA update on arbitrary-shape tensors (same shape each).

    Returns (direction, h', H') — see kernels/diana_shift.py.
    """
    shape = h.shape
    flats = [t.reshape(-1) for t in (h, q_own, mh, q_mean)]
    padded = []
    n = flats[0].shape[0]
    for t in flats:
        p, _ = _pad_to(t, LANES)
        padded.append(p)
    d, hn, mhn = _shift_raw(*padded, alpha=alpha)
    return (d[:n].reshape(shape), hn[:n].reshape(shape), mhn[:n].reshape(shape))


def randk_rows(rows: jax.Array, start_block: jax.Array, *, fraction: float,
               block_rows: int = BLOCK_ROWS):
    """Circular block Rand-k of a (N, D) row view.

    Returns (values (K, D), reconstruct_fn) where reconstruct_fn scatters the
    (possibly all-reduced) values back to a dense (N, D) canvas.
    """
    padded, n = _pad_to(rows, block_rows)
    np_ = padded.shape[0]
    nb = np_ // block_rows
    k_blocks = max(1, int(fraction * nb))
    vals = randk_compress(padded, start_block, k_blocks=k_blocks,
                          block_rows=block_rows)

    def reconstruct(v):
        dense = randk_decompress(v, start_block, n_rows=np_,
                                 block_rows=block_rows)
        return dense[:n]

    return vals, reconstruct


__all__ = ["qsgd", "diana_shift", "randk_rows", "randk_compress",
           "randk_decompress", "TILE", "LANES", "BLOCK_ROWS"]
