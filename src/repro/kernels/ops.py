"""jit'd public wrappers for the Pallas kernels (padding, views, dispatch).

On CPU (this container) every kernel runs in interpret mode — the kernel
body executes in Python for correctness; on TPU the same `pallas_call`
compiles to Mosaic. `ref.py` holds the pure-jnp oracles the tests compare
against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.diana_shift import LANES, diana_shift_update as _shift_raw
from repro.kernels.qsgd import TILE, qsgd_quantize as _qsgd_raw
from repro.kernels.randk import BLOCK_ROWS, randk_compress, randk_decompress


def _pad_to(x: jax.Array, multiple: int) -> tuple[jax.Array, int]:
    n = x.shape[0]
    pad = (-n) % multiple
    if pad:
        x = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))
    return x, n


def qsgd(x: jax.Array, key: jax.Array, *, levels: int = 8) -> jax.Array:
    """Blockwise-QSGD quantize->dequantize of an arbitrary-shape tensor."""
    flat = x.reshape(-1)
    padded, n = _pad_to(flat, TILE)
    u = jax.random.uniform(key, padded.shape)
    out = _qsgd_raw(padded, u, levels=levels)
    return out[:n].reshape(x.shape)


def diana_shift(h, q_own, mh, q_mean, *, alpha: float,
                beta: float | None = None):
    """Fused DIANA update on arbitrary-shape tensors (same shape each).

    Returns (direction, h', H') — see kernels/diana_shift.py.
    """
    shape = h.shape
    flats = [t.reshape(-1) for t in (h, q_own, mh, q_mean)]
    padded = []
    n = flats[0].shape[0]
    for t in flats:
        p, _ = _pad_to(t, LANES)
        padded.append(p)
    d, hn, mhn = _shift_raw(*padded, alpha=alpha, beta=beta)
    return (d[:n].reshape(shape), hn[:n].reshape(shape), mhn[:n].reshape(shape))


# NOTE: the circular-block wire path (pad to BLOCK_ROWS, k_blocks geometry,
# compress -> pmean -> decompress) lives in repro.core.dist, dispatched per
# backend by repro.compression.backend.wire_compress/wire_decompress.

__all__ = ["qsgd", "diana_shift", "randk_compress", "randk_decompress",
           "TILE", "LANES", "BLOCK_ROWS"]
