"""Pallas TPU kernels: circular row-block gather/scatter (Rand-k wire).

The production compressor (core/dist.py) selects a circular block of rows
from the (n_rows, D) view of each gradient leaf. On GPU this is a gather
kernel over scattered indices; on TPU the natural unit is a *block-aligned*
circular window — the gather becomes `k_blocks` sequential VMEM copies whose
source block index is computed from a prefetched scalar (`start_block`), so
the whole compression is one HBM read of k rows, no index lists.

  randk_compress:   rows (N, D), start -> (K, D) * (N/K)   [gather+scale]
  randk_decompress: vals (K, D), start -> (N, D) zeros elsewhere [scatter]
  randk_mask:       x (M, Dp), starts (M,) -> dense Q(x) per client

`randk_mask` is the simulator-side fused compress+decompress (DESIGN.md
§3.5): the algorithms' math consumes the dense reconstruction Q(x), and for
a circular-window Rand-k that is just a masked scale — one elementwise pass,
batched over all M clients in a single launch, each client with its own
prefetched window start. No gather, no scatter, no per-leaf loop.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_ROWS = 8  # sublane-aligned row block


def _gather_kernel(start_ref, x_ref, o_ref, *, scale: float):
    del start_ref  # consumed by the index_map
    o_ref[...] = (x_ref[...].astype(jnp.float32) * scale).astype(o_ref.dtype)


@partial(jax.jit, static_argnames=("k_blocks", "block_rows", "interpret"))
def randk_compress(rows: jax.Array, start_block: jax.Array, *, k_blocks: int,
                   block_rows: int = BLOCK_ROWS,
                   interpret: bool | None = None) -> jax.Array:
    """rows: (N, D), N % block_rows == 0. Returns (k_blocks*block_rows, D)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n, d = rows.shape
    nb = n // block_rows
    scale = nb / k_blocks

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(k_blocks,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i, start: ((start[0] + i) % nb, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i, start: (i, 0)),
    )
    return pl.pallas_call(
        partial(_gather_kernel, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((k_blocks * block_rows, d), rows.dtype),
        interpret=interpret,
    )(start_block.reshape(1).astype(jnp.int32), rows)


def _scatter_kernel(start_ref, vals_ref, o_ref, *, k_blocks: int, nb: int):
    j = pl.program_id(0)
    # offset of this output block inside the circular window (or >= k_blocks
    # if the block is outside the window and must stay zero)
    off = jax.lax.rem(j - start_ref[0] + nb, nb)
    inside = off < k_blocks
    o_ref[...] = jnp.where(inside, vals_ref[...], 0.0).astype(o_ref.dtype)


@partial(jax.jit, static_argnames=("n_rows", "block_rows", "interpret"))
def randk_decompress(vals: jax.Array, start_block: jax.Array, *, n_rows: int,
                     block_rows: int = BLOCK_ROWS,
                     interpret: bool | None = None) -> jax.Array:
    """vals: (K, D) -> (n_rows, D), zero outside the circular window."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    k, d = vals.shape
    kb = k // block_rows
    nb = n_rows // block_rows

    def val_index(j, start):
        off = jax.lax.rem(j - start[0] + nb, nb)
        return (jnp.minimum(off, kb - 1), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block_rows, d), val_index)],
        out_specs=pl.BlockSpec((block_rows, d), lambda j, start: (j, 0)),
    )
    return pl.pallas_call(
        partial(_scatter_kernel, k_blocks=kb, nb=nb),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_rows, d), vals.dtype),
        interpret=interpret,
    )(start_block.reshape(1).astype(jnp.int32), vals)


# ---------------------------------------------------------------------------
# fused dense Rand-k reconstruction (simulator hot path)
# ---------------------------------------------------------------------------

MASK_LANES = 128
_MASK_ROWS = 512  # (512, 128) f32 block = 256 KiB VMEM per input


def _mask_kernel(starts_ref, x_ref, o_ref, *, d: int, k: int, lanes: int,
                 block_rows: int):
    m = pl.program_id(0)
    j = pl.program_id(1)
    start = starts_ref[m]
    base = j * block_rows * lanes
    row_i = jax.lax.broadcasted_iota(jnp.int32, (1, block_rows, lanes), 1)
    lane_i = jax.lax.broadcasted_iota(jnp.int32, (1, block_rows, lanes), 2)
    idx = base + row_i * lanes + lane_i  # flat coordinate within this client
    # circular window of k real coordinates: (idx - start) mod d < k; padding
    # coordinates (idx >= d) are always dropped. `idx - start + d` keeps the
    # rem argument non-negative (lax.rem keeps the dividend's sign).
    off = jax.lax.rem(idx - start + d, d)
    inside = (off < k) & (idx < d)
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.where(inside, x * (d / k), 0.0).astype(o_ref.dtype)


@partial(jax.jit, static_argnames=("d", "k", "block_rows", "interpret"))
def randk_mask(x: jax.Array, starts: jax.Array, *, d: int, k: int,
               block_rows: int = _MASK_ROWS,
               interpret: bool | None = None) -> jax.Array:
    """Dense circular-window Rand-k for M clients in one launch.

    x: (M, Dp) with Dp % (block_rows*MASK_LANES) adjusted internally;
    starts: (M,) int32 window offsets in [0, d). `d` is the REAL flat length
    (d <= Dp); coordinates past d are padding and stay zero. Returns Q(x)
    with Q(x)[m, i] = x[m, i] * (d/k) if (i - starts[m]) mod d < k else 0.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    m, dp = x.shape
    rows = dp // MASK_LANES
    if interpret:
        br = rows  # one grid step per client (see kernels/qsgd.py note)
    else:
        br = min(block_rows, rows)
        while rows % br:  # keep the grid exact (dp is 1024-aligned by callers)
            br //= 2
        br = max(br, 1)
    grid = (m, rows // br)
    xt = x.reshape(m, rows, MASK_LANES)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[pl.BlockSpec((1, br, MASK_LANES), lambda i, j, starts: (i, j, 0))],
        out_specs=pl.BlockSpec((1, br, MASK_LANES), lambda i, j, starts: (i, j, 0)),
    )
    out = pl.pallas_call(
        partial(_mask_kernel, d=d, k=k, lanes=MASK_LANES, block_rows=br),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, rows, MASK_LANES), x.dtype),
        interpret=interpret,
    )(starts.astype(jnp.int32), xt)
    return out.reshape(m, dp)
