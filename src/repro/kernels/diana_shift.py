"""Pallas TPU kernel: fused DIANA shift/direction update.

The per-step elementwise hot loop the paper's method adds on top of SGD
(Algorithm 3 lines 7-9 / Algorithm 5 lines 8-11):

    direction = H_t + Q_mean
    h'        = h   + alpha * Q_own
    H'        = H_t + beta  * Q_mean

`beta` defaults to `alpha` (the paper's full-participation form). Under
cohort sampling only M of C clients contribute per round, so the resident
mean shift H tracks (C/M)*h_bar unless the H update is rescaled by M/C —
the second stepsize beta = (M/C)*alpha (DESIGN.md §3.10).

Unfused this is five HBM round-trips over param-sized arrays; the kernel
streams all four inputs once per (block, 128) VMEM tile and writes the three
outputs in the same pass.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
_BLOCK = 512  # rows of 128 lanes per grid step -> 256 KiB/input in VMEM


def _shift_kernel(h_ref, qo_ref, mh_ref, qm_ref, dir_ref, h_out, mh_out, *,
                  alpha: float, beta: float):
    h = h_ref[...].astype(jnp.float32)
    qo = qo_ref[...].astype(jnp.float32)
    mh = mh_ref[...].astype(jnp.float32)
    qm = qm_ref[...].astype(jnp.float32)
    dir_ref[...] = (mh + qm).astype(dir_ref.dtype)
    h_out[...] = (h + alpha * qo).astype(h_out.dtype)
    mh_out[...] = (mh + beta * qm).astype(mh_out.dtype)


@partial(jax.jit, static_argnames=("alpha", "beta", "interpret"))
def diana_shift_update(h, q_own, mh, q_mean, *, alpha: float,
                       beta: float | None = None,
                       interpret: bool | None = None):
    """All inputs (N,) with N % LANES == 0. Returns (direction, h', H')."""
    if beta is None:
        beta = alpha
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n = h.shape[0]
    rows = n // LANES
    # single grid step in interpret mode (see kernels/qsgd.py note)
    br = rows if interpret else min(_BLOCK, rows)
    grid = (pl.cdiv(rows, br),)
    spec = pl.BlockSpec((br, LANES), lambda i: (i, 0))
    view = lambda x: x.reshape(rows, LANES)
    direction, h_new, mh_new = pl.pallas_call(
        partial(_shift_kernel, alpha=alpha, beta=beta),
        grid=grid,
        in_specs=[spec] * 4,
        out_specs=[spec] * 3,
        out_shape=[
            jax.ShapeDtypeStruct((rows, LANES), q_mean.dtype),
            jax.ShapeDtypeStruct((rows, LANES), h.dtype),
            jax.ShapeDtypeStruct((rows, LANES), mh.dtype),
        ],
        interpret=interpret,
    )(view(h), view(q_own), view(mh), view(q_mean))
    return direction.reshape(n), h_new.reshape(n), mh_new.reshape(n)
