"""Pallas TPU kernels for the paper's compression hot spots.

<name>.py = pl.pallas_call + BlockSpec; ops.py = jit wrappers; ref.py =
pure-jnp oracles (the tests' allclose targets).
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
