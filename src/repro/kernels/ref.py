"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

All functions operate on the same padded/tiled views the kernels see, so
tests compare bit-for-bit semantics (modulo float accumulation order).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def qsgd_quantize_ref(x: jax.Array, u: jax.Array, *, levels: int,
                      tile: int = 1024) -> jax.Array:
    """Blockwise stochastic quantization (TPU-native QSGD variant).

    x: (N,) f32 with N % tile == 0; u: (N,) uniform [0,1) randoms.
    Each `tile` block is scaled by its own max-abs (the lane-aligned
    per-block scale that replaces QSGD's global L2 norm on TPU; unbiased
    conditional on the block scale).
    """
    xt = x.reshape(-1, tile).astype(jnp.float32)
    ut = u.reshape(-1, tile)
    scale = jnp.max(jnp.abs(xt), axis=1, keepdims=True) + 1e-30
    s = float(levels)
    y = jnp.abs(xt) / scale * s
    f = jnp.floor(y)
    q = f + (ut < (y - f)).astype(jnp.float32)
    out = jnp.sign(xt) * q * (scale / s)
    return out.reshape(x.shape).astype(x.dtype)


def randk_compress_ref(rows: jax.Array, start_block: jax.Array, *,
                       k_blocks: int, block_rows: int) -> jax.Array:
    """Circular block-aligned row gather + unbiased (n/k) scaling.

    rows: (N, D) with N % block_rows == 0. Returns (k_blocks*block_rows, D).
    """
    n, d = rows.shape
    nb = n // block_rows
    blocks = rows.reshape(nb, block_rows, d)
    idx = (start_block + jnp.arange(k_blocks)) % nb
    vals = blocks[idx].reshape(k_blocks * block_rows, d)
    return vals * (nb / k_blocks)


def randk_decompress_ref(vals: jax.Array, start_block: jax.Array, *,
                         n_rows: int, block_rows: int) -> jax.Array:
    """Scatter the compressed row-block back into an (N, D) zero canvas."""
    k, d = vals.shape
    kb = k // block_rows
    nb = n_rows // block_rows
    canvas = jnp.zeros((nb, block_rows, d), vals.dtype)
    idx = (start_block + jnp.arange(kb)) % nb
    canvas = canvas.at[idx].set(vals.reshape(kb, block_rows, d))
    return canvas.reshape(n_rows, d)


def randk_mask_ref(x: jax.Array, starts: jax.Array, *, d: int, k: int) -> jax.Array:
    """Dense circular-window Rand-k, batched over clients.

    x: (M, Dp) possibly padded past the real flat length d; starts: (M,).
    Q(x)[m, i] = x[m, i] * (d/k) for (i - starts[m]) mod d < k, else 0.
    """
    dp = x.shape[1]
    idx = jnp.arange(dp, dtype=jnp.int32)
    off = jnp.mod(idx[None, :] - starts[:, None].astype(jnp.int32), d)
    inside = (off < k) & (idx[None, :] < d)
    return jnp.where(inside, x.astype(jnp.float32) * (d / k), 0.0).astype(x.dtype)


def _pad_rows_ref(x, block_rows: int):
    pad = (-x.shape[0]) % block_rows
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return x


def pack_slab_ref(vals: jax.Array, u: jax.Array, *, levels: int,
                  nibble: bool = False, block_rows: int = 8):
    """Quantize + bit-pack one wire slab (oracle for kernels/pack.py).

    vals, u: (K, D); rows pad to a `block_rows` multiple. Per-row max-abs
    scale, stochastic rounding to q in [-levels, levels], biased byte
    b = q + levels. nibble=True packs two consecutive ROWS per byte
    (lo | hi<<4). Returns (packed uint8, scales (Kp, 1) f32)."""
    x = _pad_rows_ref(vals.astype(jnp.float32), block_rows)
    ut = _pad_rows_ref(u, block_rows)
    s = float(levels)
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True) + 1e-30
    y = jnp.abs(x) / amax * s
    f = jnp.floor(y)
    q = jnp.minimum(f + (ut < (y - f)).astype(jnp.float32), s)
    b = (jnp.sign(x) * q + s).astype(jnp.int32)
    if nibble:
        kp, d = b.shape
        br = b.reshape(kp // 2, 2, d)
        b = br[:, 0, :] + 16 * br[:, 1, :]
    return b.astype(jnp.uint8), (amax / s).astype(jnp.float32)


def _decode_ref(packed: jax.Array, scales: jax.Array, levels: int,
                nibble: bool) -> jax.Array:
    b = packed.astype(jnp.int32)
    if nibble:
        prows, d = b.shape
        b = jnp.stack([b % 16, b // 16], axis=1).reshape(prows * 2, d)
    return (b.astype(jnp.float32) - float(levels)) * scales


def unpack_slab_ref(packed: jax.Array, scales: jax.Array, *, levels: int,
                    n_rows: int, nibble: bool = False) -> jax.Array:
    """Decode one packed slab: v = (b - levels) * scale, trimmed to n_rows."""
    return _decode_ref(packed, scales, levels, nibble)[:n_rows]


def unpack_reduce_ref(packed: jax.Array, scales: jax.Array, *, levels: int,
                      n_rows: int, nibble: bool = False) -> jax.Array:
    """(R, Kp[/2], D) packed + (R, Kp, 1) scales -> (n_rows, D) mean slab.

    Accumulates decoded slabs in RANK ORDER (r = 0..R-1) then divides by R —
    the exact float schedule of the fused kernel, which in turn bit-matches
    `lax.pmean` of the decoded slabs on power-of-two rank counts."""
    r = packed.shape[0]
    acc = _decode_ref(packed[0], scales[0], levels, nibble)
    for i in range(1, r):
        acc = acc + _decode_ref(packed[i], scales[i], levels, nibble)
    return (acc / float(r))[:n_rows]


def diana_shift_update_ref(h, q_own, mh, q_mean, alpha: float,
                           beta: float | None = None):
    """Fused DIANA state update (Algorithm 3/5 lines 7-11):
        direction = H_t + Q_mean
        h'        = h  + alpha * Q_own
        H'        = H_t + beta  * Q_mean
    `beta` defaults to alpha; under cohort sampling the caller passes
    beta = (M/C)*alpha so H tracks the population mean shift.
    Returns (direction, h', H'). All f32 math, cast back to input dtypes.
    """
    f = jnp.float32
    if beta is None:
        beta = alpha
    direction = mh.astype(f) + q_mean.astype(f)
    h_new = h.astype(f) + alpha * q_own.astype(f)
    mh_new = mh.astype(f) + beta * q_mean.astype(f)
    return (direction.astype(q_mean.dtype), h_new.astype(h.dtype),
            mh_new.astype(mh.dtype))
