"""Pallas TPU kernels: bit-packed wire slabs (quantize/pack/unpack-reduce).

The shared wire's Rand-block slab is an f32 (K, D) buffer; moving it at four
bytes per lane wastes the interconnect the paper's communication-complexity
curves are about. These kernels make the slab's *wire* representation a byte
lattice (DESIGN.md §3.13):

  pack_slab       (K, D) f32 values + uniforms -> (packed uint8, scales)
                  Per-row max-abs scale, stochastic rounding to integer
                  levels q in [-L, L], biased to the byte b = q + L. With
                  ``nibble=True`` two consecutive ROWS share a byte
                  (lo | hi<<4): K is BLOCK_ROWS-aligned (even) on the wire,
                  and pairing rows instead of lanes keeps the lane dimension
                  D intact for TPU tiling. Scales stay an f32 (K, 1)
                  sideband: scale_r = (maxabs_r + eps) / L.
  unpack_slab     decode one packed slab back to f32: v = (b - L) * scale.
                  This is the ONLY dequantization formula in the repo — the
                  f32-transport quantized wire round-trips through the same
                  pack/unpack pair, which is what makes packed8 transport
                  bit-match the f32 wire (same byte, same scale, same
                  multiply).
  unpack_reduce   the fused unpack-accumulate half of the packed collective:
                  all-gathered (R, Kp, D) bytes + (R, K, 1) scales -> the
                  f32 mean slab in ONE kernel — grid over ranks, each step
                  decodes rank r's slab and accumulates into the same output
                  block, the last step divides by R. Accumulation is in rank
                  order, which bit-matches ``lax.pmean`` of the decoded
                  slabs on the meshes we run (R a power of two; the division
                  by R is then exact either way).

Bias representation needs 2L+1 <= 256 byte values (L <= 127 for int8,
L <= 7 for the nibble lanes); `core.dist` validates the caps. The uniforms
are generated OUTSIDE the kernel (shared wire key + WIRE_QUANT_SALT) and
streamed in, like kernels/qsgd.py. Block shapes are tuned for interpret
mode on CPU (one grid step; see the qsgd.py note); on Mosaic the uint8
blocks want >= (32, 128) tiles — revisit the row blocking before enabling
packed wires on real TPUs.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.randk import BLOCK_ROWS


def _quantize(x, u, levels: int):
    """f32 block -> (biased int32 lattice, f32 per-row scale)."""
    s = float(levels)
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True) + 1e-30
    y = jnp.abs(x) / amax * s  # in [0, s]
    f = jnp.floor(y)
    q = jnp.minimum(f + (u < (y - f)).astype(jnp.float32), s)
    b = (jnp.sign(x) * q + s).astype(jnp.int32)  # biased, in [0, 2s]
    return b, amax / s


def _pair_rows(b):
    """(rows, D) int32 lattice -> (rows/2, D) two-nibble bytes (lo | hi<<4)."""
    rows, d = b.shape
    br = b.reshape(rows // 2, 2, d)
    return br[:, 0, :] + 16 * br[:, 1, :]


def _decode(p, scales, levels: int, nibble: bool):
    """Packed uint8 block + (rows, 1) scales -> f32 values (b - L) * scale."""
    b = p.astype(jnp.int32)
    if nibble:
        prows, d = b.shape
        lo = jax.lax.rem(b, 16)
        hi = b // 16
        b = jnp.stack([lo, hi], axis=1).reshape(prows * 2, d)
    return (b.astype(jnp.float32) - float(levels)) * scales


def _pack_kernel(x_ref, u_ref, p_ref, s_ref, *, levels: int, nibble: bool):
    b, scale = _quantize(x_ref[...].astype(jnp.float32), u_ref[...], levels)
    if nibble:
        b = _pair_rows(b)
    p_ref[...] = b.astype(jnp.uint8)
    s_ref[...] = scale.astype(jnp.float32)


def _unpack_kernel(p_ref, s_ref, o_ref, *, levels: int, nibble: bool):
    o_ref[...] = _decode(p_ref[...], s_ref[...], levels, nibble)


def _unpack_reduce_kernel(p_ref, s_ref, o_ref, *, levels: int, nibble: bool,
                          ranks: int):
    r = pl.program_id(0)
    contrib = _decode(p_ref[0], s_ref[0], levels, nibble)

    @pl.when(r == 0)
    def _():
        o_ref[...] = contrib

    @pl.when(r != 0)
    def _():
        o_ref[...] = o_ref[...] + contrib

    @pl.when(r == ranks - 1)
    def _():
        o_ref[...] = o_ref[...] / float(ranks)


def _pad_rows(x):
    pad = (-x.shape[0]) % BLOCK_ROWS
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return x


def _row_blocking(row_blocks: int, interpret: bool) -> int:
    """Row-groups per grid step: everything at once in interpret mode (one
    emulated grid step, see kernels/qsgd.py), else a small exact divisor."""
    if interpret:
        return row_blocks
    br = min(4, row_blocks)
    while row_blocks % br:
        br //= 2
    return max(br, 1)


@partial(jax.jit, static_argnames=("levels", "nibble", "interpret"))
def pack_slab(vals: jax.Array, u: jax.Array, *, levels: int,
              nibble: bool = False, interpret: bool | None = None):
    """vals, u: (K, D). Returns (packed uint8, scales (Kp, 1) f32) with
    Kp = K padded to a BLOCK_ROWS multiple; packed is (Kp, D) or, with
    nibble=True, (Kp/2, D). Padding rows quantize to the zero byte."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    vals = _pad_rows(vals.astype(jnp.float32))
    u = _pad_rows(u)
    kp, d = vals.shape
    rb = kp // BLOCK_ROWS
    br = _row_blocking(rb, interpret)
    rows = br * BLOCK_ROWS
    prows = rows // 2 if nibble else rows
    return pl.pallas_call(
        partial(_pack_kernel, levels=levels, nibble=nibble),
        grid=(rb // br,),
        in_specs=[
            pl.BlockSpec((rows, d), lambda i: (i, 0)),
            pl.BlockSpec((rows, d), lambda i: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((prows, d), lambda i: (i, 0)),
            pl.BlockSpec((rows, 1), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((kp // 2 if nibble else kp, d), jnp.uint8),
            jax.ShapeDtypeStruct((kp, 1), jnp.float32),
        ),
        interpret=interpret,
    )(vals, u)


@partial(jax.jit, static_argnames=("levels", "n_rows", "nibble", "interpret"))
def unpack_slab(packed: jax.Array, scales: jax.Array, *, levels: int,
                n_rows: int, nibble: bool = False,
                interpret: bool | None = None) -> jax.Array:
    """(Kp[/2], D) packed + (Kp, 1) scales -> (n_rows, D) f32 values."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    kp = scales.shape[0]
    d = packed.shape[1]
    rb = kp // BLOCK_ROWS
    br = _row_blocking(rb, interpret)
    rows = br * BLOCK_ROWS
    prows = rows // 2 if nibble else rows
    out = pl.pallas_call(
        partial(_unpack_kernel, levels=levels, nibble=nibble),
        grid=(rb // br,),
        in_specs=[
            pl.BlockSpec((prows, d), lambda i: (i, 0)),
            pl.BlockSpec((rows, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((kp, d), jnp.float32),
        interpret=interpret,
    )(packed, scales)
    return out[:n_rows]


@partial(jax.jit, static_argnames=("levels", "n_rows", "nibble", "interpret"))
def unpack_reduce(packed: jax.Array, scales: jax.Array, *, levels: int,
                  n_rows: int, nibble: bool = False,
                  interpret: bool | None = None) -> jax.Array:
    """All-gathered (R, Kp[/2], D) packed + (R, Kp, 1) scales -> the
    (n_rows, D) f32 MEAN slab, decoded and accumulated in rank order in one
    kernel (the receive half of the packed collective)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    r, prows, d = packed.shape
    kp = scales.shape[1]
    out = pl.pallas_call(
        partial(_unpack_reduce_kernel, levels=levels, nibble=nibble, ranks=r),
        grid=(r,),
        in_specs=[
            pl.BlockSpec((1, prows, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, kp, 1), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((kp, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((kp, d), jnp.float32),
        interpret=interpret,
    )(packed, scales)
    return out[:n_rows]
