from repro.checkpoint.io import (
    CheckpointError,
    load_meta,
    load_pytree,
    restore_fleet_checkpoint,
    restore_train_state,
    save_fleet_checkpoint,
    save_pytree,
)

__all__ = ["CheckpointError", "save_pytree", "load_pytree", "load_meta",
           "restore_train_state", "save_fleet_checkpoint",
           "restore_fleet_checkpoint"]
