"""Pytree checkpointing: msgpack-framed, per-leaf raw buffers.

Layout-agnostic (any pytree of jnp/np arrays + scalars), atomic
(write-to-temp + rename), and restores onto a target sharding tree so a
checkpoint written on one mesh can be loaded onto another (the leaves are
saved fully replicated — fine at the scales this container runs; a real
deployment would use per-shard OCDBT, noted in DESIGN.md).
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any

import jax
import msgpack
import numpy as np

from repro import telemetry

_FORMAT_VERSION = 1


class CheckpointError(RuntimeError):
    """The file is not a readable repro checkpoint (truncated, corrupt, or
    a different format). Raised instead of the raw msgpack/json/numpy
    decode traceback so callers can tell a bad file from a code bug."""


# exceptions the msgpack/json/numpy decode stack throws on a truncated or
# corrupt blob; atomic write-then-rename means a live run never leaves a
# partial file, so any of these signals out-of-band damage
_DECODE_ERRORS = (msgpack.exceptions.UnpackException, msgpack.exceptions.ExtraData,
                  ValueError, KeyError, TypeError, EOFError)


def _corrupt(path: str, what: str, e: Exception) -> CheckpointError:
    return CheckpointError(
        f"{path}: cannot decode {what} — checkpoint is truncated or corrupt "
        f"({type(e).__name__}: {e})")


def _tree_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
             for p, _ in flat]
    leaves = [l for _, l in flat]
    return paths, leaves, treedef


def save_pytree(path: str, tree: Any, *, step: int | None = None,
                meta: dict | None = None) -> None:
    """`meta`: optional JSON-serializable sidecar stored in the manifest —
    the train loop checkpoints the data-pipeline cursor (epoch, step) and
    sampler spec here so resume bit-reproduces the batch stream."""
    with telemetry.span("checkpoint", op="save", path=path):
        paths, leaves, _ = _tree_paths(tree)
        manifest = {"version": _FORMAT_VERSION, "step": step, "meta": meta,
                    "leaves": []}
        payload = []
        for p, leaf in zip(paths, leaves):
            arr = np.asarray(leaf)
            # bfloat16 has no portable numpy dtype string; save raw u2 view
            dtype = str(arr.dtype)
            if dtype == "bfloat16":
                raw = arr.view(np.uint16)
                manifest["leaves"].append(
                    {"path": p, "dtype": "bfloat16",
                     "shape": list(arr.shape)})
                payload.append(raw.tobytes())
            else:
                manifest["leaves"].append(
                    {"path": p, "dtype": dtype, "shape": list(arr.shape)})
                payload.append(arr.tobytes())
        blob = msgpack.packb({"manifest": json.dumps(manifest),
                              "buffers": payload})
        d = os.path.dirname(os.path.abspath(path)) or "."
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".ckpt.tmp")
        with os.fdopen(fd, "wb") as f:
            f.write(blob)
        os.replace(tmp, path)


def load_meta(path: str) -> dict:
    """Manifest sidecar only: {"step": ..., "meta": ...} without
    materializing any leaf buffer — used to restore the data-pipeline cursor
    before deciding how to rebuild the stream. Streams the msgpack map and
    stops at the manifest entry (save_pytree packs it first), so a
    production-size checkpoint costs one small read, not a full decode."""
    try:
        with open(path, "rb") as f:
            unpacker = msgpack.Unpacker(f)
            for _ in range(unpacker.read_map_header()):
                if unpacker.unpack() == "manifest":
                    manifest = json.loads(unpacker.unpack())
                    return {"step": manifest.get("step"),
                            "meta": manifest.get("meta")}
                unpacker.skip()
    except _DECODE_ERRORS as e:
        raise _corrupt(path, "manifest", e) from e
    raise CheckpointError(
        f"{path}: no manifest entry — not a repro checkpoint")


def load_pytree(path: str, like: Any, *, device: bool = True) -> Any:
    """Restore into the structure (and dtypes) of `like` (abstract ok).

    device=False keeps every leaf a host numpy array — required when part
    of the tree is population-sized host state (the fleet client-state
    store) that must never be materialized on device."""
    import jax.numpy as jnp
    import ml_dtypes

    with telemetry.span("checkpoint", op="load", path=path):
        try:
            with open(path, "rb") as f:
                data = msgpack.unpackb(f.read())
            manifest = json.loads(data["manifest"])
            by_path = {}
            for meta, buf in zip(manifest["leaves"], data["buffers"]):
                if meta["dtype"] == "bfloat16":
                    arr = np.frombuffer(buf, np.uint16).reshape(
                        meta["shape"]).view(ml_dtypes.bfloat16)
                else:
                    arr = np.frombuffer(buf, np.dtype(meta["dtype"])).reshape(
                        meta["shape"])
                by_path[meta["path"]] = arr
        except _DECODE_ERRORS as e:
            raise _corrupt(path, "leaf buffers", e) from e

        paths, leaves, treedef = _tree_paths(like)
        out = []
        for p, leaf in zip(paths, leaves):
            if p not in by_path:
                raise KeyError(f"checkpoint missing leaf {p!r}")
            arr = by_path[p]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"{p}: shape {arr.shape} != expected {leaf.shape}")
            if device:
                out.append(jnp.asarray(arr, dtype=leaf.dtype))
            else:
                out.append(arr.astype(np.dtype(leaf.dtype), copy=False))
        return jax.tree_util.tree_unflatten(treedef, out)


def restore_train_state(path: str, abstract_state: Any, shardings: Any) -> Any:
    """Load + device_put onto the target sharding tree (cross-mesh restore)."""
    host = load_pytree(path, abstract_state)
    return jax.device_put(host, shardings)


# ---------------------------------------------------------------------------
# fleet checkpoints: device TrainState + host client-state store in ONE file
# ---------------------------------------------------------------------------

def save_fleet_checkpoint(path: str, state: Any, store, *,
                          step: int | None = None,
                          meta: dict | None = None,
                          data_store=None) -> None:
    """One atomic checkpoint of a fleet run: the (host-fetched) TrainState,
    the population store (`ClientStateStore.as_tree()` — per-shard arrays,
    no concatenation), and the fleet cursor/sampler specs in the manifest
    meta (`FleetRunner.checkpoint_meta()` under the 'fleet' key) so
    `--resume` can validate + rebuild the walk before touching buffers.

    `data_store`: the paged run's `ClientDataStore` — its layout spec is
    recorded so a resume refuses a mismatched (or missing) data store."""
    meta = dict(meta or {})
    meta.setdefault("store_spec", store.spec())
    if data_store is not None:
        meta.setdefault("data_store_spec", data_store.spec())
    save_pytree(path, {"state": state, "store": store.as_tree()},
                step=step, meta=meta)


def restore_fleet_checkpoint(path: str, abstract_state: Any, shardings: Any,
                             store, *, data_store=None) -> Any:
    """Restore a `save_fleet_checkpoint` file: the TrainState goes onto the
    target shardings, the store (built fresh by the caller with the run's
    own layout) is filled IN PLACE from host memory — population-sized
    buffers never touch a device. Returns the device TrainState.

    Pass the resumed run's `data_store` (or None for an in-RAM run): its
    layout is checked against the recorded `data_store_spec` BEFORE any
    buffer is decoded — a paged checkpoint refuses to resume in-RAM or
    onto a store with a different population/shard/leaf layout, because
    page identities and the resident-set bound both derive from it."""
    saved = (load_meta(path)["meta"] or {}).get("data_store_spec")
    have = None if data_store is None else data_store.spec()
    if saved != have:
        def _describe(spec):
            if spec is None:
                return "in-RAM client-stacked data (no data store)"
            return (f"data store with population {spec['population']}, "
                    f"shard_size {spec['shard_size']}, leaves "
                    f"{sorted(spec['leaves'])}")
        raise CheckpointError(
            f"{path}: checkpoint was written against "
            f"{_describe(saved)} but this run uses {_describe(have)} — "
            "resume with the matching --data-store layout (the paged walk "
            "is only bit-reproducible over the same layout)")
    tree = load_pytree(path, {"state": abstract_state,
                              "store": store.as_tree()}, device=False)
    store.load_tree(tree["store"])
    return jax.device_put(tree["state"], shardings)
