"""Compression backend: one dispatch layer for every compress / decompress /
shift-update in the repo (DESIGN.md §3.5).

Two backends implement the same primitives:

``reference``
    Pure-jnp implementations (`repro.kernels.ref` plus the vectorized mask
    formula). The semantics oracle — every pallas result must match it to
    atol 1e-6 (f32), enforced by tests/test_kernels.py.

``pallas``
    The Pallas kernels in `repro.kernels`: Mosaic on TPU, interpret mode on
    CPU. One kernel launch covers the whole flat buffer — the simulator
    ravels each client's gradient pytree once and compresses all M clients
    in a single call, and the pod wire's circular row-block gather/scatter
    runs as `k_blocks` VMEM copies instead of a `jnp.roll` of the full leaf.

Consumers:

- `repro.core.algorithms` routes per-client compression and the shift-rule
  updates (repro.core.rules) through `compress_clients` / `tree_diana_shift`;
- `repro.core.dist` routes the shared wire through `wire_compress` /
  `wire_decompress`;
- `benchmarks/compression_bench.py` times both backends against the seed
  per-leaf `jax.random.choice` path and writes BENCH_compression.json.

Backend selection: pass a name explicitly, or set REPRO_COMPRESSION_BACKEND
(default "pallas" — on CPU the kernels run in interpret mode, which lowers
to the same XLA ops as the reference but keeps the TPU path exercised).

Operator semantics on the batched paths (all Assumption-1 compliant):

- Rand-k is the circular-window sampler over the raveled tree (marginal
  inclusion probability exactly k/d -> unbiased, omega = d/k - 1 exact).
- QSGD is the TPU-native blockwise variant: per-1024-tile max-abs scale
  instead of the global L2 norm (kernels/qsgd.py). Unbiased conditional on
  the tile scales. The leaf-level `QSGDQuantizer.compress` API keeps the
  paper-exact global-norm semantics.
"""
from __future__ import annotations

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.diana_shift import LANES
from repro.kernels.pack import pack_slab, unpack_reduce, unpack_slab
from repro.kernels.qsgd import TILE, qsgd_quantize
from repro.kernels.randk import (
    BLOCK_ROWS,
    randk_compress,
    randk_decompress,
    randk_mask,
)
from repro.kernels.ops import diana_shift as _pallas_diana_shift

# Re-exported kernel geometry: BLOCK_ROWS is the row-block granularity every
# wire-level Rand-k draw is quantized to. Consumers (repro.core.dist) import
# it from here — this module owns the stable kernel surface; reaching into
# repro.kernels directly is a lint error (rule `kernel-import`).
__all__ = ["BLOCK_ROWS", "LANES", "TILE", "WIRE_DTYPES", "get_backend"]

# Wire transport formats for the shared wire's slab (core.dist validates the
# method/wire combinations; this module owns the mechanics). 'f32' is the
# status-quo psum; 'bf16' downcasts the value slab before the psum; the
# packed modes move a byte lattice + f32 scale sideband via all_gather and a
# fused unpack-reduce (kernels/pack.py, DESIGN.md §3.13).
WIRE_DTYPES = ("f32", "bf16", "packed8", "packed4")

BACKENDS = ("reference", "pallas")
_ENV_VAR = "REPRO_COMPRESSION_BACKEND"

# flat buffers are padded to the coarsest alignment any kernel needs so one
# padded layout serves qsgd (TILE=1024) and the mask kernel (8*128=1024)
_ALIGN = TILE


def tree_ravel_clients(tree):
    """Ravel a client-stacked pytree (leaves (M, *s)) into one (M, D) buffer.

    Returns (mat, unravel). unravel(mat) restores per-leaf shapes/dtypes.
    """
    leaves, treedef = jax.tree.flatten(tree)
    m = leaves[0].shape[0]
    sizes = [int(np.prod(leaf.shape[1:])) for leaf in leaves]
    shapes = [leaf.shape for leaf in leaves]
    dtypes = [leaf.dtype for leaf in leaves]
    offsets = np.cumsum([0] + sizes)
    mat = jnp.concatenate(
        [jnp.reshape(leaf, (m, -1)).astype(jnp.float32) for leaf in leaves],
        axis=1,
    )

    def unravel(out):
        parts = [
            jnp.reshape(out[:, offsets[i]:offsets[i + 1]], shapes[i]).astype(dtypes[i])
            for i in range(len(sizes))
        ]
        return jax.tree.unflatten(treedef, parts)

    return mat, unravel


def _pad_cols(mat: jax.Array, multiple: int) -> jax.Array:
    pad = (-mat.shape[1]) % multiple
    if pad:
        mat = jnp.pad(mat, ((0, 0), (0, pad)))
    return mat


@dataclasses.dataclass(frozen=True)
class CompressionBackend:
    """Static dispatch between the jnp reference and the Pallas kernels."""

    name: str = "pallas"
    interpret: bool | None = None  # None -> auto (interpret on CPU)

    def __post_init__(self):
        if self.name not in BACKENDS:
            raise ValueError(f"unknown backend {self.name!r}; options: {BACKENDS}")

    @property
    def is_pallas(self) -> bool:
        return self.name == "pallas"

    # -- flat batched primitives ----------------------------------------------

    def randk_dense(self, mat: jax.Array, starts: jax.Array, *, d: int,
                    k: int) -> jax.Array:
        """Dense Q(x) for M clients: circular window mask + (d/k) scale.

        mat: (M, Dp) with Dp 1024-aligned and d <= Dp the real flat length.
        """
        if self.is_pallas:
            return randk_mask(mat, starts, d=d, k=k, interpret=self.interpret)
        return ref.randk_mask_ref(mat, starts, d=d, k=k)

    def qsgd_dense(self, mat: jax.Array, u: jax.Array, *, levels: int) -> jax.Array:
        """Blockwise-QSGD quantize->dequantize; mat (M, Dp), Dp % TILE == 0."""
        m, dp = mat.shape
        flat, uf = mat.reshape(m * dp), u.reshape(m * dp)
        if self.is_pallas:
            out = qsgd_quantize(flat, uf, levels=levels, interpret=self.interpret)
        else:
            out = ref.qsgd_quantize_ref(flat, uf, levels=levels, tile=TILE)
        return out.reshape(m, dp)

    def diana_shift_flat(self, h, q_own, mh, q_mean, *, alpha: float,
                         beta: float | None = None):
        """Fused DIANA update on flat (N,) buffers -> (direction, h', H').

        `beta` is the mean-shift stepsize (H' = H + beta*Q_mean); defaults to
        alpha. Cohort-sampled fleets pass beta = (M/C)*alpha (DESIGN.md §3.10).
        """
        if self.is_pallas:
            return _pallas_diana_shift(h, q_own, mh, q_mean, alpha=alpha,
                                       beta=beta)
        return ref.diana_shift_update_ref(h, q_own, mh, q_mean, alpha, beta)

    # -- pytree entry points (the simulator hot path) -------------------------

    def compress_clients(self, comp, key: jax.Array, tree):
        """Q(g_m) for all M clients of a client-stacked pytree in ONE launch.

        Ravel once -> compress once -> unravel: the per-leaf Python loop and
        the per-leaf PRNG sorts of the seed path collapse into a single flat
        buffer operation over the (M, D) matrix of client gradients.
        """
        from repro.compression.ops import Identity, QSGDQuantizer, RandK

        if isinstance(comp, Identity):
            return tree
        m = jax.tree.leaves(tree)[0].shape[0]
        mat, unravel = tree_ravel_clients(tree)
        d = mat.shape[1]
        if isinstance(comp, RandK):
            k = comp._k(d)
            starts = jax.random.randint(key, (m,), 0, d)  # independent/client
            dense = self.randk_dense(_pad_cols(mat, _ALIGN), starts, d=d, k=k)
            return unravel(dense[:, :d])
        if isinstance(comp, QSGDQuantizer):
            padded = _pad_cols(mat, _ALIGN)
            u = jax.random.uniform(key, padded.shape)
            dense = self.qsgd_dense(padded, u, levels=comp.levels)
            return unravel(dense[:, :d])
        # generic operators (TopK, NaturalCompression, user-defined): still a
        # single ravel; the operator itself runs once per client under vmap.
        keys = jax.random.split(key, m)
        dense = jax.vmap(comp.compress)(keys, mat)
        return unravel(dense)

    def tree_diana_shift(self, h_tree, qo_tree, mh_tree, qm_tree, *,
                         alpha: float, beta: float | None = None):
        """Fused DIANA update over whole pytrees (same structure/shapes).

        Returns (direction_tree, h_tree', mh_tree'). On the pallas backend
        this is ONE kernel launch over the raveled buffer — each input reads
        HBM once and the three outputs write in the same pass, vs five
        param-sized round-trips for three separate tree_maps. The reference
        backend stays per-leaf (no ravel copies) and is the semantics oracle.
        """
        if self.is_pallas:
            from repro.compression.ops import tree_ravel

            h, unravel = tree_ravel(h_tree)
            qo, _ = tree_ravel(qo_tree)
            mh, _ = tree_ravel(mh_tree)
            qm, _ = tree_ravel(qm_tree)
            direction, h_new, mh_new = self.diana_shift_flat(h, qo, mh, qm,
                                                             alpha=alpha,
                                                             beta=beta)
            return unravel(direction), unravel(h_new), unravel(mh_new)
        h_leaves, treedef = jax.tree.flatten(h_tree)
        trips = [
            ref.diana_shift_update_ref(a, b, c, d, alpha, beta)
            for a, b, c, d in zip(h_leaves, jax.tree.leaves(qo_tree),
                                  jax.tree.leaves(mh_tree),
                                  jax.tree.leaves(qm_tree))
        ]
        return tuple(
            jax.tree.unflatten(treedef, [t[i] for t in trips]) for i in range(3)
        )

    # -- wire primitives (the pod shared-seed Rand-block collective) ----------

    def wire_exchange(self, rows: jax.Array, start_block: jax.Array, *,
                      k_blocks: int, block_rows: int,
                      axes: tuple[str, ...], weight: jax.Array | None = None,
                      wire_dtype: str = "f32", levels: int | None = None,
                      quant_u: jax.Array | None = None):
        """One level of the (possibly hierarchical) shared wire: circular
        gather of the k-row slab, then the sparse collective over `axes`.

        Returns (own_vals, mean_vals). This is the per-level dispatch point:
        the intra-pod ("data") and inter-pod ("pod") exchanges both land
        here, each with its own start_block/k_blocks, so only the compressed
        slab ever crosses either wire. Must run inside a shard_map whose
        manual axes include `axes`.

        `weight` (per-rank scalar, pre-normalized so an all-ones cohort gives
        exactly 1.0) scales this rank's contribution to the collective mean —
        the buffered-async / elastic-masking hook. Own vals stay unweighted so
        local shift updates use the client's actual message.

        Transport (`wire_dtype`, DESIGN.md §3.13):

        'f32'      the status quo: psum the value slab. With `levels` set the
                   slab is first quantized through the SAME pack->unpack pair
                   the packed modes use — the bit-match reference for them,
                   and a QSGD-on-the-wire mode in its own right.
        'bf16'     psum the slab at bf16 (2 B/lane, lossy); own vals are the
                   bf16 round-trip so shift updates see what the wire moved.
        'packed8'  quantize (levels <= 127) and all_gather the biased byte
                   lattice + f32 per-row scale sideband, then ONE fused
                   unpack-accumulate kernel forms the mean (a psum of packed
                   ints would be wrong — scales are per rank). Elastic
                   weights fold into the scale sideband, so no extra
                   collective; q_own decodes this rank's own slab with the
                   UNWEIGHTED scale.
        'packed4'  same, two rows per byte (levels <= 7).

        `quant_u` are the shared stochastic-rounding uniforms (slab-shaped),
        drawn by the caller from the level key + WIRE_QUANT_SALT; required
        iff `levels` is set.
        """
        vals = self.wire_compress(rows, start_block, k_blocks=k_blocks,
                                  block_rows=block_rows)
        if wire_dtype in ("packed8", "packed4"):
            nib = wire_dtype == "packed4"
            packed, scales = self.pack_slab(vals, quant_u, levels=levels,
                                            nibble=nib)
            own = self.unpack_slab(packed, scales, levels=levels,
                                   n_rows=vals.shape[0], nibble=nib)
            wscales = scales if weight is None else scales * weight
            gathered_p = jax.lax.all_gather(packed, axes)
            gathered_s = jax.lax.all_gather(wscales, axes)
            mean = self.unpack_reduce(gathered_p, gathered_s, levels=levels,
                                      n_rows=vals.shape[0], nibble=nib)
            return own, mean
        if levels is not None:
            # f32 transport of the quantized payload: round-trip through the
            # pack kernels so every byte/scale is bitwise identical to what
            # the packed transport would move (the lossless-levels argument)
            packed, scales = self.pack_slab(vals, quant_u, levels=levels)
            vals = self.unpack_slab(packed, scales, levels=levels,
                                    n_rows=vals.shape[0])
        if wire_dtype == "bf16":
            own = vals.astype(jnp.bfloat16).astype(jnp.float32)
            shared = own if weight is None else own * weight
            mean = jax.lax.pmean(shared.astype(jnp.bfloat16), axes)
            return own, mean.astype(jnp.float32)
        shared = vals if weight is None else vals * weight
        return vals, jax.lax.pmean(shared, axes)

    def pack_slab(self, vals: jax.Array, u: jax.Array, *, levels: int,
                  nibble: bool = False):
        """Quantize + bit-pack a wire slab -> (packed uint8, f32 scales)."""
        if self.is_pallas:
            return pack_slab(vals, u, levels=levels, nibble=nibble,
                             interpret=self.interpret)
        return ref.pack_slab_ref(vals, u, levels=levels, nibble=nibble,
                                 block_rows=BLOCK_ROWS)

    def unpack_slab(self, packed: jax.Array, scales: jax.Array, *,
                    levels: int, n_rows: int, nibble: bool = False):
        """Decode one packed slab back to (n_rows, D) f32 values."""
        if self.is_pallas:
            return unpack_slab(packed, scales, levels=levels, n_rows=n_rows,
                               nibble=nibble, interpret=self.interpret)
        return ref.unpack_slab_ref(packed, scales, levels=levels,
                                   n_rows=n_rows, nibble=nibble)

    def unpack_reduce(self, packed: jax.Array, scales: jax.Array, *,
                      levels: int, n_rows: int, nibble: bool = False):
        """All-gathered packed slabs + scales -> fused f32 mean slab."""
        if self.is_pallas:
            return unpack_reduce(packed, scales, levels=levels, n_rows=n_rows,
                                 nibble=nibble, interpret=self.interpret)
        return ref.unpack_reduce_ref(packed, scales, levels=levels,
                                     n_rows=n_rows, nibble=nibble)

    def wire_compress(self, rows: jax.Array, start_block: jax.Array, *,
                      k_blocks: int, block_rows: int) -> jax.Array:
        """(N, D) rows -> (k_blocks*block_rows, D) circular gather + scale."""
        if self.is_pallas:
            return randk_compress(rows, start_block, k_blocks=k_blocks,
                                  block_rows=block_rows,
                                  interpret=self.interpret)
        return ref.randk_compress_ref(rows, start_block, k_blocks=k_blocks,
                                      block_rows=block_rows)

    def wire_decompress(self, vals: jax.Array, start_block: jax.Array, *,
                        n_rows: int, block_rows: int) -> jax.Array:
        """(K, D) vals -> (n_rows, D) zero-padded circular scatter."""
        if self.is_pallas:
            return randk_decompress(vals, start_block, n_rows=n_rows,
                                    block_rows=block_rows,
                                    interpret=self.interpret)
        return ref.randk_decompress_ref(vals, start_block, n_rows=n_rows,
                                        block_rows=block_rows)


def get_backend(name: str | CompressionBackend | None = None) -> CompressionBackend:
    """Resolve a backend: explicit arg > $REPRO_COMPRESSION_BACKEND > pallas."""
    if isinstance(name, CompressionBackend):
        return name
    if name is None:
        name = os.environ.get(_ENV_VAR, "pallas")
    return CompressionBackend(name=name)
