"""Compression operators.

All operators are dataclass pytrees with static hyper-parameters so they can be
closed over inside jit'd functions. `compress(key, x)` returns the *dense
reconstruction* Q(x) (the algorithms' math needs the decompressed vector), and
`bits(shape)` accounts for what would actually travel on the wire so the
communication benchmarks can report honest byte counts.

The sparse wire format for Rand-k (indices + values) is exposed separately via
`randk_indices` / gather-scatter helpers; `repro.core.dist` uses those to build
the shared-seed sparse collective path, and `repro.kernels` provides the Pallas
TPU implementations of the same primitives.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np


@runtime_checkable
class Compressor(Protocol):
    def compress(self, key: jax.Array, x: jax.Array) -> jax.Array:
        """Return the dense reconstruction Q(x)."""
        ...

    def omega(self, size: int) -> float:
        """Variance bound omega for a vector of `size` elements."""
        ...

    def bits(self, size: int) -> int:
        """Bits on the wire for a vector of `size` float32 elements."""
        ...


def _flatten(x: jax.Array) -> jax.Array:
    return jnp.reshape(x, (-1,))


@dataclasses.dataclass(frozen=True)
class Identity:
    """No compression: Q(x) = x, omega = 0."""

    def compress(self, key, x):
        del key  # analysis: allow[ignored-argument] identity is deterministic; key is interface-wide
        return x

    def omega(self, size):
        del size  # analysis: allow[ignored-argument] omega = 0 at every dimension
        return 0.0

    def bits(self, size):
        return 32 * size


@dataclasses.dataclass(frozen=True)
class RandK:
    """Rand-k sparsification (Beznosikov et al., 2020), Rand-block sampler.

    Q(x) = (d/k) * sum_{i in S} x_i e_i with S a circular window of k
    coordinates starting at a uniform offset (DESIGN.md §3.2). Every
    coordinate has marginal inclusion probability exactly k/d, so Q is
    unbiased with omega = d/k - 1 EXACT — Assumption 1 only needs the
    marginals, the paper's constants are unchanged. Unlike the uniform
    k-subset sampler (`jax.random.choice(replace=False)`, an O(d log d)
    permutation sort per call), the window is O(d) and sort-free, which is
    what makes the simulator hot path kernel-friendly.

    `fraction` sets k = max(1, floor(fraction * d)) when `k` is None.
    """

    k: int | None = None
    fraction: float | None = 0.02

    def __post_init__(self):
        if self.k is None and self.fraction is None:
            raise ValueError(
                "RandK needs either k or fraction; both are None. "
                "Pass k=<int> or fraction=<float in (0, 1]>."
            )

    def _k(self, size: int) -> int:
        if self.k is not None:
            return max(1, min(self.k, size))
        return max(1, min(size, int(self.fraction * size)))

    def indices(self, key, size: int) -> jax.Array:
        """The k selected coordinates: a circular window at a random start."""
        k = self._k(size)
        start = jax.random.randint(key, (), 0, size)
        return (start + jnp.arange(k)) % size

    def compress(self, key, x):
        flat = _flatten(x)
        d = flat.shape[0]
        k = self._k(d)
        start = jax.random.randint(key, (), 0, d)
        # roll the window to the front, mask, roll back: O(d), no gather/sort
        shifted = jnp.roll(flat, -start)
        kept = jnp.where(jnp.arange(d) < k, shifted * (d / k), 0.0)
        return jnp.reshape(jnp.roll(kept, start), x.shape).astype(x.dtype)

    def omega(self, size):
        return size / self._k(size) - 1.0

    def bits(self, size):
        k = self._k(size)
        # 32-bit value + ceil(log2(d))-bit index per coordinate
        idx_bits = max(1, int(np.ceil(np.log2(max(size, 2)))))
        return k * (32 + idx_bits)


@dataclasses.dataclass(frozen=True)
class TopK:
    """Top-k by magnitude. BIASED (kept as a contrast baseline only)."""

    k: int | None = None
    fraction: float | None = 0.02

    def __post_init__(self):
        if self.k is None and self.fraction is None:
            raise ValueError(
                "TopK needs either k or fraction; both are None. "
                "Pass k=<int> or fraction=<float in (0, 1]>."
            )

    def _k(self, size: int) -> int:
        if self.k is not None:
            return max(1, min(self.k, size))
        return max(1, min(size, int(self.fraction * size)))

    def compress(self, key, x):
        del key  # analysis: allow[ignored-argument] Top-k is deterministic; key is interface-wide
        flat = _flatten(x)
        k = self._k(flat.shape[0])
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        out = jnp.zeros_like(flat).at[idx].set(flat[idx])
        return jnp.reshape(out, x.shape)

    def omega(self, size):
        del size  # analysis: allow[ignored-argument] biased operator: no omega at any dimension
        # not an unbiased operator; report the delta-contraction instead
        return float("nan")

    def bits(self, size):
        k = self._k(size)
        idx_bits = max(1, int(np.ceil(np.log2(max(size, 2)))))
        return k * (32 + idx_bits)


@dataclasses.dataclass(frozen=True)
class QSGDQuantizer:
    """QSGD stochastic quantization (Alistarh et al., 2017).

    Q(x) = ||x||_2 * sign(x) * u / s  with  u ~ stochastic rounding of
    s*|x|/||x||_2 to the integer grid {0..s}.  Unbiased;
    omega <= min(d/s^2, sqrt(d)/s).
    """

    levels: int = 8  # s

    def compress(self, key, x):
        flat = _flatten(x).astype(jnp.float32)
        norm = jnp.linalg.norm(flat)
        s = float(self.levels)
        scaled = jnp.where(norm > 0, jnp.abs(flat) / norm * s, 0.0)
        floor = jnp.floor(scaled)
        prob = scaled - floor
        u = floor + (jax.random.uniform(key, flat.shape) < prob)
        out = norm * jnp.sign(flat) * u / s
        return jnp.reshape(out, x.shape).astype(x.dtype)

    def omega(self, size):
        s = float(self.levels)
        return min(size / s**2, np.sqrt(size) / s)

    def bits(self, size):
        # norm (32) + sign+level per coordinate
        lvl_bits = max(1, int(np.ceil(np.log2(self.levels + 1)))) + 1
        return 32 + size * lvl_bits


@dataclasses.dataclass(frozen=True)
class NaturalCompression:
    """Natural compression (Horvath et al., 2019): stochastic rounding to
    powers of two. Unbiased with omega = 1/8; ~9 bits/coordinate."""

    def compress(self, key, x):
        flat = _flatten(x).astype(jnp.float32)
        absx = jnp.abs(flat)
        # decompose |x| = 2^e * m, m in [1, 2)
        safe = jnp.where(absx > 0, absx, 1.0)
        e = jnp.floor(jnp.log2(safe))
        lo = jnp.exp2(e)
        # round to 2^e w.p. (2^{e+1}-|x|)/2^e else 2^{e+1} -> unbiased
        p_up = (absx - lo) / lo
        up = jax.random.uniform(key, flat.shape) < p_up
        out = jnp.where(absx > 0, jnp.sign(flat) * lo * jnp.where(up, 2.0, 1.0), 0.0)
        return jnp.reshape(out, x.shape).astype(x.dtype)

    def omega(self, size):
        del size  # analysis: allow[ignored-argument] natural rounding: omega = 1/8 dimension-free
        return 1.0 / 8.0

    def bits(self, size):
        return 9 * size


def tree_ravel(tree):
    """Concatenate all leaves into one flat vector.

    Returns (flat, unravel) where unravel(flat) rebuilds the pytree. A
    deterministic, jit/vmap-friendly subset of `jax.flatten_util.ravel_pytree`
    (no dtype promotion: leaves keep their dtypes on the way back).
    """
    leaves, treedef = jax.tree.flatten(tree)
    sizes = [int(np.prod(leaf.shape)) for leaf in leaves]
    shapes = [leaf.shape for leaf in leaves]
    dtypes = [leaf.dtype for leaf in leaves]
    offsets = np.cumsum([0] + sizes)
    flat = jnp.concatenate([jnp.reshape(leaf, (-1,)).astype(jnp.float32)
                            for leaf in leaves]) if leaves else jnp.zeros((0,))

    def unravel(vec):
        parts = [
            jnp.reshape(vec[offsets[i]:offsets[i + 1]], shapes[i]).astype(dtypes[i])
            for i in range(len(sizes))
        ]
        return jax.tree.unflatten(treedef, parts)

    return flat, unravel


def tree_compress(compressor, key: jax.Array, tree):
    """Compress a whole pytree in ONE flat-buffer operator call.

    Ravel once -> compress once -> unravel (DESIGN.md §3.5): the compressor
    sees the concatenated vector, so a single kernel launch covers every leaf
    instead of one launch (plus one PRNG sort, for Rand-k) per leaf. Q stays
    unbiased leaf-wise because it is unbiased coordinate-wise. For operators
    with a global statistic (QSGD's L2 norm) the statistic now spans the tree
    — still Assumption-1 compliant with omega evaluated at the total d.
    """
    flat, unravel = tree_ravel(tree)
    return unravel(compressor.compress(key, flat))


def tree_compress_per_leaf(compressor, key: jax.Array, tree):
    """Seed-era per-leaf path (independent key per leaf). Kept as the
    baseline for benchmarks/compression_bench.py and for callers that need
    per-leaf operator statistics."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    out = [compressor.compress(k, leaf) for k, leaf in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, out)


def tree_compression_bits(compressor, tree) -> int:
    """Total wire bits for one compressed message of this pytree."""
    return sum(compressor.bits(int(np.prod(leaf.shape))) for leaf in jax.tree.leaves(tree))


def tree_omega(compressor, tree) -> float:
    """Worst-case (max over leaves) omega for per-leaf compression."""
    return max(compressor.omega(int(np.prod(leaf.shape))) for leaf in jax.tree.leaves(tree))


_REGISTRY = {
    "identity": Identity,
    "none": Identity,
    "randk": RandK,
    "topk": TopK,
    "qsgd": QSGDQuantizer,
    "natural": NaturalCompression,
}


def get_compressor(name: str, **kwargs) -> Compressor:
    try:
        return _REGISTRY[name](**kwargs)
    except KeyError:
        raise ValueError(f"unknown compressor {name!r}; options: {sorted(_REGISTRY)}")
