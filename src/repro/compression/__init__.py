"""Unbiased gradient compression operators (paper Assumption 1).

Every operator Q satisfies  E[Q(x)] = x  and  E||Q(x) - x||^2 <= omega * ||x||^2
for a known omega (except TopK, which is *biased* and included only as a
contrast baseline — the paper's theory does not cover it).

Operators act on flat vectors; `tree_compress` lifts them to pytrees
(per-leaf compression with split PRNG keys, per-leaf omega bookkeeping).
"""
from repro.compression.ops import (
    Compressor,
    Identity,
    RandK,
    TopK,
    QSGDQuantizer,
    NaturalCompression,
    tree_compress,
    tree_compression_bits,
    get_compressor,
)

__all__ = [
    "Compressor",
    "Identity",
    "RandK",
    "TopK",
    "QSGDQuantizer",
    "NaturalCompression",
    "tree_compress",
    "tree_compression_bits",
    "get_compressor",
]
