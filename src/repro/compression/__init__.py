"""Unbiased gradient compression operators (paper Assumption 1).

Every operator Q satisfies  E[Q(x)] = x  and  E||Q(x) - x||^2 <= omega * ||x||^2
for a known omega (except TopK, which is *biased* and included only as a
contrast baseline — the paper's theory does not cover it).

Operators act on flat vectors; `tree_compress` lifts them to pytrees by
raveling the whole tree into ONE flat buffer (single operator call). The
`backend` module dispatches every compress / decompress / shift-update to
either the pure-jnp reference or the Pallas kernels (DESIGN.md §3.5).
"""
from repro.compression.backend import (
    BACKENDS,
    CompressionBackend,
    get_backend,
)
from repro.compression.ops import (
    Compressor,
    Identity,
    RandK,
    TopK,
    QSGDQuantizer,
    NaturalCompression,
    tree_compress,
    tree_compress_per_leaf,
    tree_compression_bits,
    tree_ravel,
    get_compressor,
)

__all__ = [
    "BACKENDS",
    "CompressionBackend",
    "Compressor",
    "Identity",
    "RandK",
    "TopK",
    "QSGDQuantizer",
    "NaturalCompression",
    "get_backend",
    "tree_compress",
    "tree_compress_per_leaf",
    "tree_compression_bits",
    "tree_ravel",
    "get_compressor",
]
