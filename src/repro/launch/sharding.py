"""Sharding rules: param/state/batch PartitionSpecs for the production mesh.

Layout (DESIGN.md §5) — Megatron-style TP over "model", clients over
("pod","data"):

  embeddings / lm_head (V, D)           -> ("model", None)   vocab-parallel
  column-parallel projections (.., D,F) -> last axis "model"
  row-parallel projections    (.., F,D) -> axis -2  "model"
  per-head vectors (.., H, hd)          -> axis -2, falling back to the last
                                           axis when H doesn't divide the
                                           model axis (hymba's 25 heads)
  norms / router / small vectors        -> replicated
  DIANA shifts (M, *param)              -> ("pod","data") on axis 0 + param spec
  batches                               -> axis 0 over ("pod","data")

jit argument shardings must divide exactly (GSPMD pads intermediates, not
arguments), so every rule checks divisibility and falls back to the next
candidate axis, then to replication. Weight leaves carry a leading
stacked-layer axis — never sharded (it's the `lax.scan` axis).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

# last-axis column-parallel weights (and their biases)
_COL = {
    "wq", "wk", "wv", "wx", "wbc", "wdt", "wr", "wg", "w_up", "w_gate", "wA",
    "bq", "bk", "bv", "b_up", "w0", "mu",
}
# axis -2 row-parallel weights / per-head (H, hd) tensors
_ROW = {"wo", "w_down", "wo_fused", "wB", "u", "ln", "ln_attn", "ln_out"}
_VOCAB = {"embed", "lm_head"}
_REPLICATED = {"router", "scale", "bias", "a_log", "pos_embed"}


def _model_size(mesh) -> int:
    return int(mesh.shape["model"]) if mesh is not None else 16


def _path_names(path) -> list[str]:
    out = []
    for pk in path:
        if isinstance(pk, jax.tree_util.DictKey):
            out.append(str(pk.key))
        elif isinstance(pk, jax.tree_util.GetAttrKey):
            out.append(pk.name)
    return out


def _leaf_spec(path, leaf, msize: int) -> P:
    names = _path_names(path)
    name = names[-1] if names else ""
    nd = leaf.ndim
    shape = leaf.shape

    def try_axes(*axes):
        entries = [None] * nd
        for ax in axes:
            if 0 <= ax < nd and shape[ax] % msize == 0 and shape[ax] > 0:
                entries[ax] = "model"
                return P(*entries)
        return P(*entries)

    if name in _VOCAB:
        return try_axes(0)
    if name in _REPLICATED:
        return P(*(None,) * nd)
    if name in _COL and nd >= 1:
        return try_axes(nd - 1)
    if name in _ROW and nd >= 2:
        return try_axes(nd - 2, nd - 1)
    return P(*(None,) * nd)


def param_specs(params, *, mesh=None) -> Any:
    """PartitionSpec pytree matching `params` (abstract or concrete)."""
    msize = _model_size(mesh)
    return jax.tree_util.tree_map_with_path(
        lambda p, l: _leaf_spec(p, l, msize), params)


def param_shardings(mesh, params) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, mesh=mesh))


def shifts_specs(params, client_axes: tuple[str, ...], *, mesh=None,
                 n_slots: int = 0) -> Any:
    """DIANA per-client shifts: leading client axis over ('pod','data').

    n_slots >= 1 (DIANA-RR slot tables, leaves (M, n_slots, *param))
    inserts a replicated slot axis between the client axis and the param
    spec — the axis is present whenever the RULE is slotted, size-1 tables
    included; 0 means no slot axis (non-slotted rules)."""
    msize = _model_size(mesh)
    slot = (None,) if n_slots else ()

    def shift_spec(path, leaf):
        base = _leaf_spec(path, leaf, msize)
        return P(client_axes, *slot, *base)

    return jax.tree_util.tree_map_with_path(shift_spec, params)


def podded_specs(params, pod_axes: tuple[str, ...], *, mesh=None,
                 n_slots: int = 0) -> Any:
    """Per-pod state (level-2 DIANA shifts, per-pod mean shifts, local NASTYA
    params): leading pod axis + the leaf's own TP spec (replicated slot axis
    inserted when n_slots >= 1; 0 = no slot axis)."""
    msize = _model_size(mesh)
    slot = (None,) if n_slots else ()

    def spec(path, leaf):
        base = _leaf_spec(path, leaf, msize)
        return P(pod_axes, *slot, *base)

    return jax.tree_util.tree_map_with_path(spec, params)


def slotted_specs(params, *, mesh=None, n_slots: int = 0) -> Any:
    """Param-aligned specs with a leading replicated slot axis (flat-mesh
    DIANA-RR mean tables, global pod_mean_shift): leaves (n_slots, *param);
    n_slots=0 degrades to plain param specs."""
    msize = _model_size(mesh)
    slot = (None,) if n_slots else ()
    return jax.tree_util.tree_map_with_path(
        lambda p, l: P(*slot, *_leaf_spec(p, l, msize)), params)


def batch_specs(batch, client_axes: tuple[str, ...]) -> Any:
    return jax.tree.map(lambda x: P(client_axes, *(None,) * (x.ndim - 1)), batch)


def cache_specs(cache, client_axes: tuple[str, ...], *, mesh,
                n_clients: int = 1) -> Any:
    """Decode-cache shardings. Cache leaves are (L, B, ...):

    - B >= n_clients (and divisible): batch over client axes; then the
      widest divisible remaining axis over "model".
    - B  < n_clients (long_500k, B=1): batch replicated; the widest axis
      over ("data","model") jointly when divisible, else "model"-only,
      else replicated.
    """
    msize = _model_size(mesh)
    joint = int(np.prod([mesh.shape[a] for a in (*client_axes, "model")]))

    def spec(leaf):
        nd = leaf.ndim
        if nd < 2:
            return P(*(None,) * nd)
        b = leaf.shape[1]
        entries: list[Any] = [None] * nd
        shard_batch = b >= n_clients and b % n_clients == 0
        rest = sorted(range(2, nd), key=lambda i: -leaf.shape[i])
        if shard_batch:
            entries[1] = client_axes
            for i in rest:
                if leaf.shape[i] % msize == 0:
                    entries[i] = "model"
                    break
        else:
            for i in rest:
                if leaf.shape[i] % joint == 0:
                    entries[i] = (*client_axes, "model")
                    break
                if leaf.shape[i] % msize == 0:
                    entries[i] = "model"
                    break
        return P(*entries)

    return jax.tree.map(spec, cache)


def zero1_specs(params, client_axes: tuple[str, ...], *, mesh=None) -> Any:
    """Optimizer-state sharding: param spec + client axes on the first
    unsharded, divisible axis (ZeRO-1)."""
    msize = _model_size(mesh)
    csize = (int(np.prod([mesh.shape[a] for a in client_axes]))
             if mesh is not None else 16)

    def spec(path, leaf):
        base = list(_leaf_spec(path, leaf, msize))
        start = 1 if "blocks" in _path_names(path) and leaf.ndim >= 2 else 0
        for i in range(start, leaf.ndim):  # never shard the scan (layer) axis
            if base[i] is None and leaf.shape[i] % csize == 0 and leaf.shape[i] > 0:
                base[i] = client_axes if len(client_axes) > 1 else client_axes[0]
                break
        return P(*base)

    return jax.tree_util.tree_map_with_path(spec, params)
