"""Post-compile HLO analysis: collective byte accounting + roofline terms.

`cost_analysis()` has no collective statistics, so we parse the compiled
HLO text: every `all-gather` / `all-reduce` / `reduce-scatter` /
`all-to-all` / `collective-permute` instruction's operand bytes are summed
(per device — the compiled module is the per-device SPMD program).

Hardware model (TPU v5e targets, DESIGN.md §6):
    peak bf16 compute  197 TFLOP/s / chip
    HBM bandwidth      819 GB/s / chip
    ICI                ~50 GB/s / link (per direction)
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+?)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^=]*?\)|\S+\[[^\]]*\]\S*)\s+([\w\-]+)\(")
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(shape_str: str) -> float:
    """Bytes of one HLO shape string (handles tuple shapes)."""
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict[str, float]
    count_by_kind: dict[str, int]
    top: list[tuple[float, str, str]] = dataclasses.field(default_factory=list)
    # (bytes, op kind, shape string) of the largest collectives — the
    # §Perf diagnosis view ("which tensor is being gathered?")

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


def collective_stats(hlo_text: str, top_n: int = 8) -> CollectiveStats:
    """Sum output-shape bytes of every collective instruction.

    For all-reduce / all-to-all / collective-permute the output shape equals
    the operand shape (the wire bytes). For all-gather the output is the
    gathered (larger) buffer — an upper bound on wire traffic; for
    reduce-scatter the output is the scattered (smaller) buffer — we scale
    by the group factor where derivable, else keep the conservative value.
    """
    bytes_by_kind: dict[str, float] = {}
    count_by_kind: dict[str, int] = {}
    tops: list[tuple[float, str, str]] = []
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        _, shape_str, op = m.groups()
        base = op.removesuffix("-start").removesuffix("-done")
        if base not in _COLLECTIVES:
            continue
        if op.endswith("-done"):
            continue  # counted at -start
        b = _shape_bytes(shape_str)
        bytes_by_kind[base] = bytes_by_kind.get(base, 0.0) + b
        count_by_kind[base] = count_by_kind.get(base, 0) + 1
        tops.append((b, base, shape_str[:80]))
    tops.sort(reverse=True)
    return CollectiveStats(bytes_by_kind, count_by_kind, tops[:top_n])


@dataclasses.dataclass
class Roofline:
    """Three-term per-device roofline (seconds)."""

    flops: float
    hbm_bytes: float
    collective_bytes: float
    n_devices: int
    ici_links: int = 4  # v5e 2D torus: 4 links/chip

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / (ICI_BW * self.ici_links)

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
        }


def roofline_from_compiled(compiled, n_devices: int) -> Roofline:
    """Roofline terms from a compiled executable. cost_analysis() on this
    JAX/XLA build reports PER-DEVICE flops/bytes (verified in DESIGN.md §6)."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # older jaxlibs wrap the dict
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    stats = collective_stats(compiled.as_text())
    return Roofline(
        flops=flops,
        hbm_bytes=hbm,
        collective_bytes=stats.total_bytes,
        n_devices=n_devices,
    )


def memory_summary(compiled) -> dict:
    ma = compiled.memory_analysis()
    if ma is None:
        return {}
    keys = (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    )
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out
