"""Production serving driver: prefill a request batch, stream decode.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b --tokens 16

CPU runs the reduced config on the 8-device test mesh; --production-mesh
builds the pod mesh with the full config (requires hardware / the dry-run's
forced host devices).
"""
import os

if "--production-mesh" not in os.sys.argv:
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import time

import jax

from repro.launch import compat
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config, reduced
from repro.core import salts
from repro.launch import steps
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="stablelm-1.6b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    if args.production_mesh:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        cfg = get_config(args.arch)
    else:
        mesh = make_test_mesh((4, 2), ("data", "model"))
        cfg = reduced(get_config(args.arch), seq=max(64, 2 * args.prompt_len))
    key = salts.root_key(0, salts.SERVE_KEY_SALT)
    params = T.init_params(key, cfg)
    cache_len = args.prompt_len + args.tokens + 8

    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (args.batch, cfg.vision_patches, cfg.d_model), cfg.dtype)
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            key, (args.batch, cfg.encoder_seq, cfg.d_model), cfg.dtype)

    serve, lower_args = steps.make_serve_step(cfg, mesh)
    with compat.set_mesh(mesh):
        logits, cache = T.prefill(params, batch, cfg, cache_len=cache_len)
        jitted, (psh, csh, tsh) = lower_args(
            jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params),
            jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), cache),
            jax.ShapeDtypeStruct((args.batch, 1), jnp.int32),
        )
        params = jax.device_put(params, psh)
        cache = jax.device_put(cache, csh)

        def sample(lg, k):
            lg = lg[:, :, :cfg.vocab]
            if args.temperature <= 0:
                return jnp.argmax(lg, -1).astype(jnp.int32)
            return jax.random.categorical(
                k, lg / args.temperature, axis=-1).astype(jnp.int32)

        tok = sample(logits, key)
        out = [tok]
        t0 = time.time()
        for i in range(args.tokens):
            key, sk = jax.random.split(key)
            logits, cache = jitted(params, cache, jax.device_put(tok, tsh),
                                   jnp.int32(args.prompt_len + i))
            tok = sample(logits, sk)
            out.append(tok)
        dt = (time.time() - t0) / args.tokens
    gen = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name} batch={args.batch} | {dt*1e3:.1f} ms/token")
    print("request 0 token ids:", gen[0].tolist())


if __name__ == "__main__":
    main()
