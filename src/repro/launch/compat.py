"""Version compatibility for the launch layer's newer-JAX APIs.

The production code targets current JAX (`jax.shard_map` with partial-manual
`axis_names`, `jax.set_mesh`); the pinned CPU environment ships 0.4.x where
the same machinery lives in `jax.experimental.shard_map` (`auto=` is the
complement of `axis_names`) and there is no ambient-mesh setter. These
wrappers keep one call site per feature so the rest of launch/ reads like
the current-JAX production code.
"""
from __future__ import annotations

import jax

_HAS_NEW_SHARD_MAP = hasattr(jax, "shard_map")
_HAS_SET_MESH = hasattr(jax, "set_mesh")


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma: bool = False):
    """`jax.shard_map` on new JAX; `jax.experimental.shard_map` otherwise.

    `axis_names` = the MANUAL axes (new-API convention); on the experimental
    API that becomes `auto = mesh.axis_names - axis_names`.
    """
    if _HAS_NEW_SHARD_MAP:
        kwargs = {"check_vma": check_vma}
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map

    auto = (frozenset(mesh.axis_names) - frozenset(axis_names)
            if axis_names is not None else frozenset())
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma, auto=auto)


def set_mesh(mesh):
    """`jax.set_mesh` context on new JAX; on 0.4.x the Mesh object itself is
    the context manager that sets the ambient resource env (what
    `with_sharding_constraint(x, PartitionSpec(...))` resolves against)."""
    if _HAS_SET_MESH:
        return jax.set_mesh(mesh)
    return mesh
