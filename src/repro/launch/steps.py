"""Production step functions: train (paper's compressed-RR wire) + serve.

`make_train_step` is where the paper's contribution meets the pod:

  - the mesh's ("pod","data") ranks are the M federated clients; per-client
    gradients are computed under GSPMD (`jax.vmap` over the stacked client
    batch, "model" tensor parallelism compiler-managed);
  - the WIRE — compression, shift updates, and the sparse collectives — runs
    in a fully-manual `jax.shard_map` over every mesh axis, so the paper's
    per-client semantics are explicit and nothing depends on the partial-auto
    shard_map path (which miscompiles on the pinned 0.4.x JAX: GSPMD emits
    malformed tile assignments for replicated inputs of a partial-manual
    region — see ROADMAP "launch layer" history);
  - `CompressedAggregation` (core/dist.py) is hierarchical: the "data" axis
    inside a pod runs the kernelized shared Rand-block psum and the "pod"
    axis runs a second, independently-keyed compressed exchange with its own
    DIANA shifts (DESIGN.md §3.6);
  - with `local_steps > 1` the step is the paper's Q-NASTYA / DIANA-NASTYA
    (Algorithms 4-5) at pod granularity: each pod runs `local_steps` local
    RR mini-epochs at stepsize `lr` (gamma), the epoch gradient
    (x_t - x^n) / (gamma * n) crosses the inter-pod wire once, and the
    server update reuses `optim` at the server stepsize `eta`;
  - the server update is plain SGD (Algorithms 2-5; momentum/AdamW are the
    beyond-paper variants, state replicated over clients, TP over model).

`make_prefill_step` / `make_serve_step` are pure-GSPMD inference paths (no
client wire — serving has no gradients to compress).

The step's `shifts` are NOT assumed to belong to mesh-resident clients:
under partial participation (`repro.fleet`, DESIGN.md §3.9) each round's
cohort slice is swapped in via `with_cohort_shifts` and scattered back to
the host `ClientStateStore` after the step — same compiled step, O(cohort)
device memory.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import salts
from repro.core.dist import CompressedAggregation, DianaState
from repro.launch import compat, sharding
from repro.launch.mesh import (
    client_axes as _client_axes,
    data_axes as _data_axes,
    num_clients,
    num_pods,
    pod_axes as _pod_axes,
)
from repro.models import transformer
from repro.models.config import ArchConfig
from repro.optim import optimizers as optim


class TrainState(NamedTuple):
    """Production train state. Shift-table layouts follow the aggregation
    method's rule (repro.core.rules): 'diana' keeps one (M, *param) shift
    per client; 'diana_rr' inserts an n_slots axis after the client/pod
    axis on every table ((M, n_slots, *param) etc.); 'ef' keeps only the
    per-client residual in `shifts` (mean tables None)."""

    params: Any
    shifts: Any  # (M, [n_slots,] *param) intra-pod shift/residual, or None
    mean_shift: Any  # per-pod mean: (P, [ns,] *param) on pod meshes, else ([ns,] *param)
    step: jax.Array
    opt_state: Any = ()  # server optimizer state (paper uses plain SGD)
    pod_shifts: Any = None  # (P, [ns,] *param) inter-pod shifts, or None
    pod_mean_shift: Any = None  # ([ns,] *param) global mean of pod shifts, or None


def configure_agg(agg: CompressedAggregation, mesh,
                  local_steps: int = 1) -> CompressedAggregation:
    """Bind an aggregation config to a mesh's wire topology.

    - multi-pod mesh: inner level over the in-pod "data" ranks, outer level
      over "pod" (the two-level wire, DESIGN.md §3.6);
    - flat mesh with local steps: every client is its own pod (paper
      Algorithms 4-5 exactly — no intra-pod wire, one compressed exchange
      per epoch over the client axes);
    - flat mesh, no local steps: the single-level wire, unchanged.
    """
    # on NASTYA paths the inter-pod wire only carries the slot-free epoch
    # gradient (row 0), so outer slot tables collapse to one row
    pod_slots = 1 if local_steps > 1 else agg.pod_slots
    if _pod_axes(mesh):
        return dataclasses.replace(
            agg, client_axes=_data_axes(mesh), pod_axes=_pod_axes(mesh),
            pod_size=num_pods(mesh), pod_slots=pod_slots)
    if local_steps > 1:
        return dataclasses.replace(
            agg, client_axes=(), pod_axes=_client_axes(mesh),
            pod_size=num_clients(mesh), pod_slots=pod_slots)
    return dataclasses.replace(agg, client_axes=_client_axes(mesh),
                               pod_axes=(), pod_size=1)


def _outer_ranks(agg: CompressedAggregation) -> int:
    """Number of outer-level ranks ("pods"): pod_size when hierarchical."""
    return agg.pod_size if agg.pod_axes else 1


# ---------------------------------------------------------------------------
# state construction (concrete + abstract for the dry-run)
# ---------------------------------------------------------------------------

def _make_optimizer(optimizer: str, lr: float) -> optim.Optimizer:
    if optimizer == "sgd":
        return optim.sgd(lr)
    if optimizer == "momentum":
        return optim.momentum(lr)
    if optimizer == "adamw":
        return optim.adamw(lr, weight_decay=0.1)
    raise ValueError(optimizer)


def init_train_state(key, cfg: ArchConfig, agg: CompressedAggregation,
                     m: int, *, optimizer: str = "sgd", lr: float = 3e-3,
                     mesh=None, local_steps: int = 1) -> TrainState:
    """Initial state. Pass `mesh` (and `local_steps`) so the DIANA shift
    tables get the mesh's wire topology; without it `agg` is used as-is
    (correct for flat single-level meshes, the pre-pod behaviour)."""
    if mesh is not None:
        agg = configure_agg(agg, mesh, local_steps)
    params = transformer.init_params(key, cfg)
    shifts = mean_shift = pod_shifts = pod_mean_shift = None
    rule = agg.rule
    if rule.has_shifts:
        init = lambda lead, ns: rule.init_shifts(
            params, lead, n_slots=ns, dtype=agg.shift_dtype)
        n_pods_ = _outer_ranks(agg)
        if agg.client_axes:
            shifts = init(m, agg.n_slots)
            if rule.has_mean:
                mean_shift = init(n_pods_ if agg.pod_axes else None,
                                  agg.n_slots)
        if agg.pod_axes:
            pod_shifts = init(n_pods_, agg._pod_slots)
            if rule.has_mean:
                pod_mean_shift = init(None, agg._pod_slots)
    opt_state = _make_optimizer(optimizer, lr).init(params)
    return TrainState(params, shifts, mean_shift, jnp.zeros((), jnp.int32),
                      opt_state, pod_shifts, pod_mean_shift)


def abstract_train_state(cfg: ArchConfig, agg: CompressedAggregation,
                         m: int, *, optimizer: str = "sgd", mesh=None,
                         local_steps: int = 1) -> TrainState:
    return jax.eval_shape(
        lambda: init_train_state(salts.root_key(0, salts.PARAMS_KEY_SALT),
                                 cfg, agg, m, optimizer=optimizer, mesh=mesh,
                                 local_steps=local_steps)
    )


def train_state_shardings(mesh, state: TrainState, agg) -> TrainState:
    caxes = _client_axes(mesh)
    paxes = _pod_axes(mesh) or (agg.pod_axes if agg.pod_axes else ())
    ns = lambda spec: NamedSharding(mesh, spec)
    pspecs = sharding.param_specs(state.params, mesh=mesh)
    # slot-axis presence is keyed on the RULE (size-1 tables still carry the
    # axis); 0 means no axis. Outer-level tables may have fewer rows
    # (configure_agg collapses them to 1 on NASTYA paths).
    nslots = agg.n_slots if agg.rule.slotted else 0
    pod_nslots = agg._pod_slots if agg.rule.slotted else 0

    def maybe(tree, spec_tree):
        return None if tree is None else jax.tree.map(ns, spec_tree)

    # mean_shift is per-pod (leading pod axis) on hierarchical wires
    podded = (sharding.podded_specs(state.params, paxes, mesh=mesh,
                                    n_slots=nslots)
              if paxes else None)
    podded_pod = (sharding.podded_specs(state.params, paxes, mesh=mesh,
                                        n_slots=pod_nslots)
                  if paxes else None)
    slotted = sharding.slotted_specs(state.params, mesh=mesh, n_slots=nslots)
    ms_specs = podded if (state.mean_shift is not None and agg.pod_axes) \
        else slotted

    # optimizer state: mu/nu shard like params, scalars replicated
    if state.opt_state == ():
        osh = ()
    elif isinstance(state.opt_state, optim.AdamState):
        osh = optim.AdamState(
            mu=jax.tree.map(ns, pspecs), nu=jax.tree.map(ns, pspecs),
            count=ns(P()))
    elif (jax.tree.structure(state.opt_state)
          == jax.tree.structure(state.params)):
        osh = jax.tree.map(ns, pspecs)  # momentum: param-shaped
    else:
        osh = jax.tree.map(lambda _: ns(P()), state.opt_state)
    return TrainState(
        params=jax.tree.map(ns, pspecs),
        shifts=maybe(state.shifts,
                     sharding.shifts_specs(state.params, caxes, mesh=mesh,
                                           n_slots=nslots)),
        mean_shift=maybe(state.mean_shift, ms_specs),
        step=ns(P()),
        opt_state=osh,
        pod_shifts=maybe(state.pod_shifts, podded_pod),
        pod_mean_shift=maybe(state.pod_mean_shift,
                             sharding.slotted_specs(state.params, mesh=mesh,
                                                    n_slots=pod_nslots)),
    )


def with_cohort_shifts(state: TrainState, host_shifts, shardings: TrainState,
                       field: str = "shifts") -> TrainState:
    """Swap cohort-gathered shift slices into a TrainState (fleet path).

    The train step never assumes `shifts` belongs to mesh-resident clients —
    it runs the rule arithmetic on whatever (M, [n_slots,] *param) slice it
    is handed. Under partial participation (`repro.fleet.FleetRunner`) that
    slice is the round's cohort, gathered from the host
    `ClientStateStore` and placed onto the step's shift shardings here;
    after the step the runner scatters the field back. `host_shifts`
    is None for memory-free methods ('q'/'dense') — the state passes
    through untouched. Device memory stays O(cohort), never O(population).

    `field` selects which table holds the per-client state: "shifts" when
    the mesh's client ranks are the inner wire level, "pod_shifts" on flat
    NASTYA meshes (`configure_agg` with `client_axes=()` maps each client to
    its own pod, so the per-client DIANA state lives in the outer tables).
    """
    if host_shifts is None:
        return state
    return state._replace(
        **{field: jax.device_put(host_shifts, getattr(shardings, field))})


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------

def make_train_step(cfg: ArchConfig, mesh, *, agg: CompressedAggregation,
                    lr: float = 3e-3, eta: float | None = None,
                    local_steps: int = 1, remat="full", unroll: bool = False,
                    ce: str = "gather", seq_shard: bool = True,
                    optimizer: str = "sgd", elastic: bool = False,
                    debug_metrics: bool = False):
    """Returns jitted (state, batch, key) -> (state, metrics).

    lr: the client/local stepsize gamma. With `local_steps == 1` it is also
    the server stepsize (Algorithms 2-3). With `local_steps > 1` the step is
    NASTYA at pod granularity (Algorithms 4-5): `eta` is the server stepsize
    applied to the epoch gradient (default gamma * local_steps, which makes
    Q-NASTYA degrade to FedRR per the Corollary 3 remark); the batch must
    carry `local_steps` micro-batches per client, client-major
    (leading dim = M * local_steps * b).

    Per-slot methods (`agg.method == "diana_rr"`) change the signature to
    (state, batch, key, slots): `slots` is a (local_steps,) int32 vector of
    the SHARED batch indices this step's micro-batches occupy in every
    client's dataset — `data.pipeline.shared_slots_for_step` derives it
    from the `rr_shared` sampler that also orders the batch stream. With
    local_steps == 1 the single slot drives the round's shift-table row at
    both wire levels; in NASTYA mode the slots ride the per-pod micro-epoch
    permutation and index the intra-pod tables, while the inter-pod
    exchange of the (slot-free) epoch gradient uses table row 0.

    optimizer: the SERVER update applied to the aggregated direction —
    "sgd" is the paper's Algorithms 2-5; "momentum"/"adamw" are the
    beyond-paper variants (state replicated over clients, TP over model).

    elastic: the step takes a trailing (m,) f32 `weights` vector — each
    client rank's participation weight, pre-normalized by the host so an
    all-ones cohort is exactly 1.0 everywhere (x * 1.0 is a bitwise no-op,
    so full participation matches the non-elastic step bit-for-bit). The
    async fleet driver (repro.fleet, DESIGN.md §3.10) uses weight 0 to mask
    dropped/padded clients and fractional weights to discount stale
    reports; the cohort can shrink/grow between rounds without recompiling.

    debug_metrics: opt-in device-side compression diagnostics carried in
    the metrics pytree — `compression_err_sq` (‖ḡ − D‖², the distance
    between the uncompressed mean gradient and the wire's aggregated
    direction), `direction_norm_sq`, and the shift-table norms. Everything
    is pure jnp riding reductions GSPMD already does, no extra
    collectives; default OFF so the traced step's jaxpr is unchanged
    (pinned by the analysis census).
    """
    if eta is not None and local_steps == 1:
        raise ValueError("eta is the NASTYA server stepsize and requires "
                         "local_steps > 1 (with one local step the server "
                         "stepsize IS lr; Algorithms 2-3)")
    if elastic and local_steps > 1:
        raise ValueError(
            "elastic=True requires local_steps == 1: a NASTYA epoch "
            "consumes a full local mini-epoch per client, so a mid-epoch "
            "straggler has no well-defined RR rewind point")
    mcaxes = _client_axes(mesh)
    m = num_clients(mesh)
    agg = configure_agg(agg, mesh, local_steps)
    n_pods_ = _outer_ranks(agg)
    clients_per_pod = m // n_pods_
    gamma = lr
    server_lr = (eta if eta is not None else gamma * local_steps) \
        if local_steps > 1 else lr
    opt = _make_optimizer(optimizer, server_lr)
    loss_fn = partial(transformer.loss_fn, cfg=cfg, remat=remat,
                      unroll=unroll, ce=ce, seq_shard=seq_shard)
    stateful = agg.rule.has_shifts  # diana / diana_rr / ef keep wire memory
    slotted = agg.rule.slotted
    nslots = agg.n_slots if slotted else 0  # 0 = tables carry no slot axis
    pod_nslots = agg._pod_slots if slotted else 0

    abstract = abstract_train_state(cfg, agg, m, optimizer=optimizer,
                                    mesh=mesh, local_steps=local_steps)
    pspecs = sharding.param_specs(abstract.params, mesh=mesh)
    stacked_specs = jax.tree.map(lambda s: P(mcaxes, *s), pspecs)
    pod_axis = agg.pod_axes  # leading axis of per-pod trees
    podded_specs = (sharding.podded_specs(abstract.params, pod_axis,
                                          mesh=mesh)
                    if pod_axis else pspecs)
    all_axes = set(mesh.axis_names)

    def manual(f, in_specs, out_specs):
        """Fully-manual shard_map (every axis manual) — the wire region."""
        return compat.shard_map(f, mesh=mesh, in_specs=in_specs,
                                out_specs=out_specs, axis_names=all_axes,
                                check_vma=False)

    # spec trees matching the (possibly None) state fields; slotted tables
    # carry a replicated n_slots axis after the client/pod axis
    def tspec(tree, spec_tree):
        return None if tree is None else spec_tree
    shifts_sp = tspec(abstract.shifts,
                      sharding.shifts_specs(abstract.params, mcaxes,
                                            mesh=mesh, n_slots=nslots))
    slotted_sp = sharding.slotted_specs(abstract.params, mesh=mesh,
                                        n_slots=nslots)
    podded_slot_sp = (sharding.podded_specs(abstract.params, pod_axis,
                                            mesh=mesh, n_slots=nslots)
                      if pod_axis else slotted_sp)
    ms_sp = tspec(abstract.mean_shift,
                  podded_slot_sp if pod_axis else slotted_sp)
    psh_sp = tspec(abstract.pod_shifts,
                   sharding.podded_specs(abstract.params, pod_axis,
                                         mesh=mesh, n_slots=pod_nslots)
                   if pod_axis else None)
    pms_sp = tspec(abstract.pod_mean_shift,
                   sharding.slotted_specs(abstract.params, mesh=mesh,
                                          n_slots=pod_nslots))

    strip = lambda t: None if t is None else jax.tree.map(lambda x: x[0], t)
    stack = lambda t: None if t is None else jax.tree.map(
        lambda x: x[None], t)
    strip_pod = strip if pod_axis else (lambda t: t)
    stack_pod = stack if pod_axis else (lambda t: t)

    def grads_and_loss(params_stacked, batch_c):
        """Per-client (loss, grad) under GSPMD: vmap over the client dim."""
        return jax.vmap(
            lambda p, b: jax.value_and_grad(loss_fn)(p, b)
        )(params_stacked, batch_c)

    def broadcast_clients(tree):
        """params -> (M, *shape) client-stacked view (replication, no copy
        per device: the leading dim shards over the client axes)."""
        out = jax.tree.map(
            lambda p: jnp.broadcast_to(p[None], (m,) + p.shape), tree)
        return jax.lax.with_sharding_constraint(
            out, jax.tree.map(lambda s: NamedSharding(mesh, s), stacked_specs))

    # -- wire regions (fully-manual shard_map bodies) --------------------------

    def full_wire_fn(g, shifts, mean_shift, pod_shifts, pod_mean_shift, kd,
                     slot, w=None):
        """Composed two-level exchange (the local_steps == 1 round).

        `w` is this rank's (1,)-block of the elastic weights vector (spec
        P(mcaxes): one scalar per client rank), or None on the non-elastic
        path — the two variants compile to different graphs but the weight
        only ever scales the compressed message into the collective mean."""
        g = strip(g)
        dstate = DianaState(strip(shifts), strip_pod(mean_shift),
                            strip_pod(pod_shifts), pod_mean_shift) \
            if stateful else None
        direction, nd = agg.aggregate(g, dstate,
                                      jax.random.wrap_key_data(kd), slot=slot,
                                      weight=None if w is None else w[0])
        if stateful:
            return (direction, stack(nd.shifts), stack_pod(nd.mean_shift),
                    stack_pod(nd.pod_shifts), nd.pod_mean_shift)
        return direction, shifts, mean_shift, pod_shifts, pod_mean_shift

    wire_out_specs = (pspecs, shifts_sp, ms_sp, psh_sp, pms_sp)
    if elastic:
        full_wire = manual(
            full_wire_fn,
            in_specs=(stacked_specs, shifts_sp, ms_sp, psh_sp, pms_sp, P(),
                      P(), P(mcaxes)),
            out_specs=wire_out_specs,
        )
    else:
        _full_wire = manual(
            lambda g, sh, ms, psh, pms, kd, slot: full_wire_fn(
                g, sh, ms, psh, pms, kd, slot),
            in_specs=(stacked_specs, shifts_sp, ms_sp, psh_sp, pms_sp, P(),
                      P()),
            out_specs=wire_out_specs,
        )
        full_wire = lambda g, sh, ms, psh, pms, kd, slot, w: _full_wire(
            g, sh, ms, psh, pms, kd, slot)

    def local_wire_fn(g, shifts, mean_shift, kd, slot):
        """Inner (intra-pod) exchange — one NASTYA local step's psum.

        `slot` arrives per-pod (spec P(pod_axis)): the micro-batch's shared
        batch index after the pod's own micro-epoch permutation."""
        g = strip(g)
        dstate = DianaState(strip(shifts), strip_pod(mean_shift)) \
            if stateful else None
        direction, nd = agg.aggregate_local(g, dstate,
                                            jax.random.wrap_key_data(kd),
                                            slot=slot[0])
        new_shifts, new_ms = (stack(nd.shifts), stack_pod(nd.mean_shift)) \
            if stateful else (shifts, mean_shift)
        # direction is identical on every rank of a pod; emit the pod block
        # (local_wire only exists on NASTYA paths, where pod_axis is set)
        return stack(direction), new_shifts, new_ms

    pod_lead = P(pod_axis) if pod_axis else P()
    local_wire = manual(
        local_wire_fn,
        in_specs=(stacked_specs, shifts_sp, ms_sp, P(), pod_lead),
        out_specs=(podded_specs, shifts_sp, ms_sp),
    )

    def pod_wire_fn(g_pod, pod_shifts, pod_mean_shift, kd):
        """Outer (inter-pod) exchange of the NASTYA epoch gradient (no batch
        slot — per-slot rules use table row 0 here)."""
        g = strip_pod(g_pod) if pod_axis else strip(g_pod)
        dstate = DianaState(None, None, strip_pod(pod_shifts),
                            pod_mean_shift) if stateful else None
        direction, nd = agg.aggregate_pod(g, dstate,
                                          jax.random.wrap_key_data(kd))
        if stateful:
            return direction, stack_pod(nd.pod_shifts), nd.pod_mean_shift
        return direction, pod_shifts, pod_mean_shift

    pod_wire = manual(
        pod_wire_fn,
        in_specs=(podded_specs, psh_sp, pms_sp, P()),
        out_specs=(pspecs, psh_sp, pms_sp),
    )

    # -- the step ---------------------------------------------------------------

    def _sq_norm(tree):
        """Σ‖leaf‖² in f32 — pure jnp, trace-safe."""
        return sum((jnp.sum(jnp.square(x.astype(jnp.float32)))
                    for x in jax.tree.leaves(tree)), jnp.float32(0.0))

    def _debug_extras(g_stacked, direction, new_shifts, new_ms):
        """Opt-in compression diagnostics: ‖ḡ − D‖² plus wire-state norms.

        ḡ is the uncompressed mean over the stacked leading axis (clients,
        or pods in NASTYA mode) — a reduction GSPMD lowers exactly like the
        wire's own mean, so no new collective patterns appear."""
        g_mean = jax.tree.map(
            lambda x: jnp.mean(x.astype(jnp.float32), axis=0), g_stacked)
        err = sum(
            (jnp.sum(jnp.square(gm - d.astype(jnp.float32)))
             for gm, d in zip(jax.tree.leaves(g_mean),
                              jax.tree.leaves(direction))),
            jnp.float32(0.0))
        return {"compression_err_sq": err,
                "direction_norm_sq": _sq_norm(direction),
                "shift_norm_sq": _sq_norm(new_shifts),
                "mean_shift_norm_sq": _sq_norm(new_ms)}

    def nastya_epoch(state: TrainState, batch, rkey, slots):
        """local_steps local RR mini-epochs per pod + one inter-pod round."""
        bsz = jax.tree.leaves(batch)[0].shape[0] // (m * local_steps)
        batch_r = jax.tree.map(
            lambda x: x.reshape((m, local_steps, bsz) + x.shape[1:]), batch)
        bspecs = jax.tree.map(
            lambda x: P(mcaxes, *(None,) * (x.ndim - 1)), batch_r)

        def permute_fn(b, sl, kd):
            # per-pod RR order over the local micro-epochs (Alg. 4 line 5);
            # device-local gather — every rank of a pod draws the same
            # order. The shared slot indices ride the same permutation so
            # per-slot shift tables stay aligned with the batches consumed.
            key = jax.random.wrap_key_data(kd)
            for ax in pod_axis:
                key = jax.random.fold_in(key, lax.axis_index(ax))
            perm = jax.random.permutation(key, local_steps)
            return jax.tree.map(lambda x: x[:, perm], b), sl[perm][None]

        batch_r, slots_pod = manual(
            permute_fn, in_specs=(bspecs, P(), P()),
            out_specs=(bspecs, P(pod_axis if pod_axis else None, None)))(
            batch_r, slots,
            jax.random.key_data(
                jax.random.fold_in(rkey, salts.NASTYA_PERM_SALT)))
        xs = jax.tree.map(lambda x: jnp.moveaxis(x, 1, 0), batch_r)
        slot_cols = jnp.moveaxis(slots_pod, 1, 0)  # (local_steps, n_pods)

        x_pods = jax.lax.with_sharding_constraint(
            jax.tree.map(
                lambda p: jnp.broadcast_to(p[None], (n_pods_,) + p.shape),
                state.params),
            jax.tree.map(lambda s: NamedSharding(mesh, s), podded_specs))

        def body(carry, inp):
            x, shifts, mean_shift = carry
            batch_j, slot_j, t = inp
            x_clients = jax.lax.with_sharding_constraint(
                jax.tree.map(
                    lambda p: jnp.repeat(p, clients_per_pod, axis=0), x),
                jax.tree.map(lambda s: NamedSharding(mesh, s), stacked_specs))
            losses, g = grads_and_loss(x_clients, batch_j)
            kd = jax.random.key_data(
                jax.random.fold_in(rkey, salts.NASTYA_LOCAL_SALT + t))
            direction, shifts, mean_shift = local_wire(
                g, shifts, mean_shift, kd, slot_j)
            x = jax.tree.map(
                lambda xi, d: (xi.astype(jnp.float32)
                               - gamma * d.astype(jnp.float32)
                               ).astype(xi.dtype), x, direction)
            return (x, shifts, mean_shift), jnp.mean(losses)

        (x_pods, new_shifts, new_ms), losses = lax.scan(
            body, (x_pods, state.shifts, state.mean_shift),
            (xs, slot_cols, jnp.arange(local_steps)))

        # g_pod = (x_t - x_t^n) / (gamma * n)   (Alg. 4/5 line 7)
        g_pod = jax.tree.map(
            lambda p, xn: (p[None].astype(jnp.float32)
                           - xn.astype(jnp.float32))
            / (gamma * local_steps), state.params, x_pods)
        direction, new_psh, new_pms = pod_wire(
            g_pod, state.pod_shifts, state.pod_mean_shift,
            jax.random.key_data(rkey))
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(g_pod)) / n_pods_)
        extras = (_debug_extras(g_pod, direction, new_shifts, new_ms)
                  if debug_metrics else {})
        return (direction, new_shifts, new_ms, new_psh, new_pms,
                jnp.mean(losses), gnorm, extras)

    def flat_round(state: TrainState, batch, rkey, slots, weights):
        """One communication round (Algorithms 2-3 / the composed wire)."""
        bsz = jax.tree.leaves(batch)[0].shape[0] // m
        batch_c = jax.tree.map(
            lambda x: x.reshape((m, bsz) + x.shape[1:]), batch)
        losses, g = grads_and_loss(broadcast_clients(state.params), batch_c)
        direction, new_shifts, new_ms, new_psh, new_pms = full_wire(
            g, state.shifts, state.mean_shift, state.pod_shifts,
            state.pod_mean_shift, jax.random.key_data(rkey), slots[0],
            weights)
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(g)) / m)
        extras = (_debug_extras(g, direction, new_shifts, new_ms)
                  if debug_metrics else {})
        return (direction, new_shifts, new_ms, new_psh, new_pms,
                jnp.mean(losses), gnorm, extras)

    def check_batch(batch):
        """The batch contract (fed by data.pipeline.make_batch_stream):
        every leaf client-major with m * local_steps * b leading rows."""
        leads = {x.shape[0] for x in jax.tree.leaves(batch)}
        if not leads:
            raise ValueError("empty batch: the step needs at least one "
                             "client-major (m * local_steps * b)-row leaf")
        if len(leads) != 1:
            raise ValueError(
                f"batch leaves disagree on leading rows {sorted(leads)} — "
                "every modality must ride the same client-major row stream")
        rows = leads.pop()
        if rows == 0 or rows % (m * local_steps) != 0:
            raise ValueError(
                f"batch has {rows} leading rows, not divisible by "
                f"m*local_steps = {m}*{local_steps} — the step consumes "
                "client-major (m * local_steps * b)-row batches; feed it "
                "with data.pipeline.make_batch_stream")

    def step(state: TrainState, batch, key, slots, weights=None):
        check_batch(batch)
        if slots is None:
            slots = jnp.zeros((local_steps,), jnp.int32)
        slots = jnp.asarray(slots, jnp.int32)
        if slots.shape != (local_steps,):
            raise ValueError(
                f"slots must be a ({local_steps},) int32 vector of shared "
                f"batch indices (one per local micro-step), got "
                f"{slots.shape} — see data.pipeline.shared_slots_for_step")
        if elastic:
            weights = jnp.asarray(weights, jnp.float32)
            if weights.shape != (m,):
                raise ValueError(
                    f"elastic weights must be an ({m},) f32 vector (one "
                    f"participation weight per client rank), got "
                    f"{weights.shape}")
        rkey = jax.random.fold_in(key, state.step)
        if local_steps > 1:
            (direction, new_shifts, new_ms, new_psh, new_pms, loss,
             gnorm, extras) = nastya_epoch(state, batch, rkey, slots)
        else:
            (direction, new_shifts, new_ms, new_psh, new_pms, loss,
             gnorm, extras) = flat_round(state, batch, rkey, slots,
                                         weights if elastic else None)
        updates, new_opt = opt.update(
            jax.tree.map(lambda d: d.astype(jnp.float32), direction),
            state.opt_state, state.params)
        new_params = optim.apply_updates(state.params, updates)
        metrics = {"loss": loss, "grad_norm": gnorm, **extras}
        return TrainState(new_params, new_shifts, new_ms, state.step + 1,
                          new_opt, new_psh, new_pms), metrics

    shardings = train_state_shardings(mesh, abstract, agg)
    batch_sh = lambda batch: jax.tree.map(
        lambda x: NamedSharding(mesh, P(mcaxes, *(None,) * (x.ndim - 1))),
        batch)
    # signature grows right-to-left: per-slot methods append the round's
    # shared slot vector, elastic steps append the (m,) weights vector last
    if slotted and elastic:
        jitted = jax.jit(
            step,
            in_shardings=(shardings, None, None, None, None),
            out_shardings=(shardings, None),
            donate_argnums=(0,),
        )
    elif slotted:
        jitted = jax.jit(
            lambda state, batch, key, slots: step(state, batch, key, slots),
            in_shardings=(shardings, None, None, None),
            out_shardings=(shardings, None),
            donate_argnums=(0,),
        )
    elif elastic:
        jitted = jax.jit(
            lambda state, batch, key, weights: step(state, batch, key, None,
                                                    weights),
            in_shardings=(shardings, None, None, None),
            out_shardings=(shardings, None),
            donate_argnums=(0,),
        )
    else:
        jitted = jax.jit(
            lambda state, batch, key: step(state, batch, key, None),
            in_shardings=(shardings, None, None),
            out_shardings=(shardings, None),
            donate_argnums=(0,),
        )
    return jitted, abstract, shardings, batch_sh


# ---------------------------------------------------------------------------
# inference steps (pure GSPMD)
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ArchConfig, mesh, *, cache_len: int,
                      remat: bool = True, unroll: bool = False):
    caxes = _client_axes(mesh)

    def prefill(params, batch):
        return transformer.prefill(params, batch, cfg, cache_len=cache_len,
                                   remat=remat, unroll=unroll)

    def lower_args(params_abs, batch_abs):
        psh = sharding.param_shardings(mesh, params_abs)
        bsh = jax.tree.map(
            lambda x: NamedSharding(mesh, P(caxes, *(None,) * (x.ndim - 1))),
            batch_abs,
        )
        cache_abs = jax.eval_shape(prefill, params_abs, batch_abs)[1]
        csh = jax.tree.map(
            lambda s: NamedSharding(mesh, s),
            sharding.cache_specs(cache_abs, caxes, mesh=mesh,
                                 n_clients=num_clients(mesh)),
        )
        jitted = jax.jit(prefill, in_shardings=(psh, bsh),
                         out_shardings=(None, csh))
        return jitted

    return prefill, lower_args


def make_serve_step(cfg: ArchConfig, mesh, *, unroll: bool = False):
    caxes = _client_axes(mesh)

    def serve(params, cache, tokens, pos):
        return transformer.decode_step(params, cache, tokens, pos, cfg,
                                       unroll=unroll)

    def lower_args(params_abs, cache_abs, tokens_abs):
        psh = sharding.param_shardings(mesh, params_abs)
        b = tokens_abs.shape[0]
        n_cl = num_clients(mesh)
        csh = jax.tree.map(
            lambda s: NamedSharding(mesh, s),
            sharding.cache_specs(cache_abs, caxes, mesh=mesh,
                                 n_clients=n_cl),
        )
        tsh = NamedSharding(mesh, P(caxes) if b >= n_cl else P())
        jitted = jax.jit(
            serve,
            in_shardings=(psh, csh, tsh, NamedSharding(mesh, P())),
            out_shardings=(None, csh),
            donate_argnums=(1,),
        )
        return jitted, (psh, csh, tsh)

    return serve, lower_args
