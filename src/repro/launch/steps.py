"""Production step functions: train (paper's compressed-RR wire) + serve.

`make_train_step` is where the paper's contribution meets the pod:

  - the mesh's ("pod","data") ranks are the M federated clients;
  - each client computes its LOCAL gradient inside a partial-manual
    `jax.shard_map` (manual over the client axes, GSPMD/auto over "model" —
    so the transformer's tensor parallelism is compiler-managed while the
    paper's per-client compression semantics are explicit);
  - `CompressedAggregation` (core/dist.py) compresses, all-reduces the
    k-row slabs over the client axes (Q-RR / DIANA-RR wire), and returns the
    descent direction;
  - the server update is plain SGD with stepsize gamma (Algorithms 2-3; an
    AdamW variant is available for the beyond-paper examples).

`make_prefill_step` / `make_serve_step` are pure-GSPMD inference paths (no
client wire — serving has no gradients to compress).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.dist import CompressedAggregation, DianaState
from repro.launch import compat, sharding
from repro.launch.mesh import client_axes as _client_axes, num_clients
from repro.models import transformer
from repro.models.config import ArchConfig
from repro.optim import optimizers as optim


class TrainState(NamedTuple):
    params: Any
    shifts: Any  # (M, *param_shape) per-client DIANA shifts, or None
    mean_shift: Any  # param-shaped running mean shift H_t, or None
    step: jax.Array
    opt_state: Any = ()  # server optimizer state (paper uses plain SGD)


# ---------------------------------------------------------------------------
# state construction (concrete + abstract for the dry-run)
# ---------------------------------------------------------------------------

def _make_optimizer(optimizer: str, lr: float) -> optim.Optimizer:
    if optimizer == "sgd":
        return optim.sgd(lr)
    if optimizer == "momentum":
        return optim.momentum(lr)
    if optimizer == "adamw":
        return optim.adamw(lr, weight_decay=0.1)
    raise ValueError(optimizer)


def init_train_state(key, cfg: ArchConfig, agg: CompressedAggregation,
                     m: int, *, optimizer: str = "sgd",
                     lr: float = 3e-3) -> TrainState:
    params = transformer.init_params(key, cfg)
    shifts = mean_shift = None
    if agg.method == "diana":
        shifts = jax.tree.map(
            lambda p: jnp.zeros((m,) + p.shape, agg.shift_dtype), params
        )
        mean_shift = jax.tree.map(
            lambda p: jnp.zeros(p.shape, agg.shift_dtype), params
        )
    opt_state = _make_optimizer(optimizer, lr).init(params)
    return TrainState(params, shifts, mean_shift, jnp.zeros((), jnp.int32),
                      opt_state)


def abstract_train_state(cfg: ArchConfig, agg: CompressedAggregation,
                         m: int, *, optimizer: str = "sgd") -> TrainState:
    return jax.eval_shape(
        lambda: init_train_state(jax.random.key(0), cfg, agg, m,
                                 optimizer=optimizer)
    )


def train_state_shardings(mesh, state: TrainState, agg) -> TrainState:
    caxes = _client_axes(mesh)
    ns = lambda spec: NamedSharding(mesh, spec)
    pspecs = sharding.param_specs(state.params, mesh=mesh)
    def opt_spec(sub):
        # mu/nu are param-shaped (model-TP); count replicated
        return jax.tree.map(
            lambda leaf: ns(sharding.param_specs(state.params, mesh=mesh)
                            if False else P()), sub)

    # optimizer state: mu/nu shard like params, scalars replicated
    if state.opt_state == ():
        osh = ()
    else:
        osh = jax.tree.map(
            lambda leaf: ns(P()) if leaf.ndim == 0 else None, state.opt_state)
        # replace param-shaped leaves with the matching param sharding
        if isinstance(state.opt_state, optim.AdamState):
            osh = optim.AdamState(
                mu=jax.tree.map(ns, pspecs), nu=jax.tree.map(ns, pspecs),
                count=ns(P()))
        elif state.opt_state is not None:
            osh = jax.tree.map(ns, sharding.param_specs(state.params, mesh=mesh))                 if jax.tree.structure(state.opt_state) == jax.tree.structure(state.params) else osh
    return TrainState(
        params=jax.tree.map(ns, pspecs),
        shifts=None if state.shifts is None else jax.tree.map(
            ns, sharding.shifts_specs(state.params, caxes, mesh=mesh)
        ),
        mean_shift=None if state.mean_shift is None else jax.tree.map(ns, pspecs),
        step=ns(P()),
        opt_state=osh,
    )


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------

def make_train_step(cfg: ArchConfig, mesh, *, agg: CompressedAggregation,
                    lr: float = 3e-3, remat="full", unroll: bool = False,
                    ce: str = "gather", seq_shard: bool = True,
                    optimizer: str = "sgd"):
    """Returns jitted (state, batch, key) -> (state, metrics).

    optimizer: the SERVER update applied to the aggregated direction —
    "sgd" is the paper's Algorithms 2-5; "momentum"/"adamw" are the
    beyond-paper variants (state replicated over clients, TP over model).
    """
    caxes = _client_axes(mesh)
    agg = dataclasses.replace(agg, client_axes=caxes)
    opt = _make_optimizer(optimizer, lr)
    loss_fn = partial(transformer.loss_fn, cfg=cfg, remat=remat,
                      unroll=unroll, ce=ce, seq_shard=seq_shard)

    def client_fn(state: TrainState, batch, key):
        # per-client slice of the shift table: (1, *shape) -> (*shape)
        local_shifts = (
            None if state.shifts is None
            else jax.tree.map(lambda s: s[0], state.shifts)
        )
        loss, g = jax.value_and_grad(loss_fn)(state.params, batch)
        dstate = (
            DianaState(local_shifts, state.mean_shift)
            if agg.method == "diana" else None
        )
        direction, new_dstate = agg.aggregate(
            g, dstate, jax.random.fold_in(key, state.step)
        )
        updates, new_opt = opt.update(
            jax.tree.map(lambda d: d.astype(jnp.float32), direction),
            state.opt_state, state.params)
        new_params = optim.apply_updates(state.params, updates)
        gnorm = jnp.sqrt(lax.pmean(
            sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                for x in jax.tree.leaves(g)), caxes))
        metrics = {
            "loss": lax.pmean(loss, caxes),
            "grad_norm": gnorm,
        }
        if agg.method == "diana":
            new_shifts = jax.tree.map(lambda s: s[None], new_dstate.shifts)
            new_mean = new_dstate.mean_shift
        else:
            new_shifts, new_mean = state.shifts, state.mean_shift
        return TrainState(new_params, new_shifts, new_mean, state.step + 1,
                          new_opt), metrics

    state_manual_specs = TrainState(
        params=P(),
        shifts=P(caxes),  # leading client axis is the manual slice
        mean_shift=P(),
        step=P(),
        opt_state=P(),  # server state: identical on every client
    )
    mapped = compat.shard_map(
        client_fn,
        mesh=mesh,
        in_specs=(state_manual_specs, P(caxes), P()),
        out_specs=(state_manual_specs, P()),
        axis_names=set(caxes),
        check_vma=False,
    )

    def step(state: TrainState, batch, key):
        return mapped(state, batch, key)

    abstract = abstract_train_state(cfg, agg, num_clients(mesh),
                                    optimizer=optimizer)
    shardings = train_state_shardings(mesh, abstract, agg)
    batch_sh = lambda batch: jax.tree.map(
        lambda x: NamedSharding(mesh, P(caxes, *(None,) * (x.ndim - 1))), batch
    )
    jitted = jax.jit(
        step,
        in_shardings=(tuple_to_state(shardings), None, None),
        out_shardings=(tuple_to_state(shardings), None),
        donate_argnums=(0,),
    )
    return jitted, abstract, shardings, batch_sh


def tuple_to_state(x):
    # NamedTuple passthrough (kept for call-site readability)
    return x


# ---------------------------------------------------------------------------
# inference steps (pure GSPMD)
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ArchConfig, mesh, *, cache_len: int,
                      remat: bool = True, unroll: bool = False):
    caxes = _client_axes(mesh)

    def prefill(params, batch):
        return transformer.prefill(params, batch, cfg, cache_len=cache_len,
                                   remat=remat, unroll=unroll)

    def lower_args(params_abs, batch_abs):
        psh = sharding.param_shardings(mesh, params_abs)
        bsh = jax.tree.map(
            lambda x: NamedSharding(mesh, P(caxes, *(None,) * (x.ndim - 1))),
            batch_abs,
        )
        batch_size = jax.tree.leaves(batch_abs)[0].shape[0]
        cache_abs = jax.eval_shape(prefill, params_abs, batch_abs)[1]
        csh = jax.tree.map(
            lambda s: NamedSharding(mesh, s),
            sharding.cache_specs(cache_abs, caxes, mesh=mesh,
                                 batch_size=batch_size,
                                 n_clients=num_clients(mesh)),
        )
        jitted = jax.jit(prefill, in_shardings=(psh, bsh),
                         out_shardings=(None, csh))
        return jitted

    return prefill, lower_args


def make_serve_step(cfg: ArchConfig, mesh, *, unroll: bool = False):
    caxes = _client_axes(mesh)

    def serve(params, cache, tokens, pos):
        return transformer.decode_step(params, cache, tokens, pos, cfg,
                                       unroll=unroll)

    def lower_args(params_abs, cache_abs, tokens_abs):
        psh = sharding.param_shardings(mesh, params_abs)
        b = tokens_abs.shape[0]
        n_cl = num_clients(mesh)
        csh = jax.tree.map(
            lambda s: NamedSharding(mesh, s),
            sharding.cache_specs(cache_abs, caxes, mesh=mesh, batch_size=b,
                                 n_clients=n_cl),
        )
        tsh = NamedSharding(mesh, P(caxes) if b >= n_cl else P())
        jitted = jax.jit(
            serve,
            in_shardings=(psh, csh, tsh, NamedSharding(mesh, P())),
            out_shardings=(None, csh),
            donate_argnums=(1,),
        )
        return jitted, (psh, csh, tsh)

    return serve, lower_args
