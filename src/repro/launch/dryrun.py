import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).
"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

For each combination this proves, without hardware:
  - the sharding config is coherent (no mismatched collectives),
  - the per-device memory fits (memory_analysis),
  - and it yields the FLOPs/bytes/collective numbers for EXPERIMENTS.md
    (§Dry-run, §Roofline).

Usage:
  python -m repro.launch.dryrun --arch deepseek-67b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out results.jsonl
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro import telemetry
from repro.launch import compat

from repro.configs import (
    ARCH_NAMES,
    INPUT_SHAPES,
    get_config,
    input_specs,
    shape_supported,
)
from repro.core import salts
from repro.core.dist import CompressedAggregation
from repro.data.pipeline import abstract_stream_batch
from repro.launch import steps
from repro.launch.hlo_analysis import (
    Roofline,
    collective_stats,
    memory_summary,
    roofline_from_compiled,
)
from repro.models import flags
from repro.launch.mesh import make_production_mesh, num_clients
from repro.models import transformer


def _compile_one(cfg, shape, mesh, agg, *, remat, unroll: bool,
                 ce: str = "gather", seq_shard: bool = True,
                 local_steps: int = 1, elastic: bool = False):
    """Lower + compile the step this shape exercises for config `cfg`."""
    specs = input_specs(cfg, shape)
    if shape.kind == "train":
        jitted, abstract, shardings, _ = steps.make_train_step(
            cfg, mesh, agg=agg, remat=remat, unroll=unroll, ce=ce,
            seq_shard=seq_shard, local_steps=local_steps, elastic=elastic
        )
        # the batch contract of data.pipeline.make_batch_stream: client-major
        # m * local_steps * b rows on every leaf
        batch = abstract_stream_batch(specs["batch"], local_steps)
        key = jax.ShapeDtypeStruct(
            (), salts.root_key(0, salts.ROUNDS_KEY_SALT).dtype)
        # the buffered-async wire weights vector (elastic step only)
        extra = ((jax.ShapeDtypeStruct((num_clients(mesh),), jnp.float32),)
                 if elastic else ())
        with compat.set_mesh(mesh):
            if agg.rule.slotted:  # per-slot methods take the slot vector
                slots = jax.ShapeDtypeStruct((local_steps,), jnp.int32)
                lowered = jitted.lower(abstract, batch, key, slots, *extra)
            else:
                lowered = jitted.lower(abstract, batch, key, *extra)
    elif shape.kind == "prefill":
        prefill, lower_args = steps.make_prefill_step(
            cfg, mesh, cache_len=shape.seq_len, remat=remat, unroll=unroll
        )
        params_abs = jax.eval_shape(
            lambda: transformer.init_params(
                salts.root_key(0, salts.PARAMS_KEY_SALT), cfg)
        )
        jitted = lower_args(params_abs, specs["batch"])
        with compat.set_mesh(mesh):
            lowered = jitted.lower(params_abs, specs["batch"])
    else:  # decode
        serve, lower_args = steps.make_serve_step(cfg, mesh, unroll=unroll)
        params_abs = jax.eval_shape(
            lambda: transformer.init_params(
                salts.root_key(0, salts.PARAMS_KEY_SALT), cfg)
        )
        jitted, _ = lower_args(params_abs, specs["cache"], specs["tokens"])
        with compat.set_mesh(mesh):
            lowered = jitted.lower(params_abs, specs["cache"],
                                   specs["tokens"], specs["pos"])
    return lowered.compile()


def _probe_cfg(cfg, k: int):
    changes = {"num_layers": k}
    if cfg.encoder_layers:
        changes["encoder_layers"] = k
    return dataclasses.replace(cfg, **changes)


def fleet_smoke(cfg, mesh, agg, clients: int, *, local_steps: int = 1,
                buffer_k: int | None = None, chaos_dropout: float = 0.0,
                chaos_seed: int = 0, data_store: str | None = None):
    """Fleet sizing at population scale C — NO population-sized allocation.

    Proves, next to the compiled step, that the fleet layer scales: the
    cohort walk draws valid mesh-rank-sized cohorts, the host store's
    byte footprint is a closed-form estimate (`estimate_nbytes`), and the
    per-round device shift memory is O(cohort) — every TrainState shift
    table is keyed on the MESH client count, so the population size must
    not appear in any device shape (DESIGN.md §3.9).
    """
    import numpy as np

    from repro.fleet import ClientStateStore, CohortSampler
    from repro.launch import steps

    m = num_clients(mesh)
    agg_c = steps.configure_agg(agg, mesh, local_steps)
    abstract = steps.abstract_train_state(cfg, agg, m, mesh=mesh,
                                          local_steps=local_steps)
    cohorts = CohortSampler(clients, m, seed=0)
    for r in (0, 1, clients // m):  # incl. a fleet-epoch-straddling round
        c = cohorts.cohort_for_round(r)
        assert c.shape == (m,) and 0 <= c[0] and c[-1] < clients
        assert (np.diff(c) > 0).all(), "cohorts must be sorted + distinct"
    # O(cohort) device memory: every per-client device table is keyed on
    # the MESH client count, never the population (checking the client
    # leading axis specifically — bare `clients in shape` membership would
    # false-positive whenever C coincides with a model dimension)
    shift_leaves = [] if abstract.shifts is None else jax.tree.leaves(
        abstract.shifts)
    for leaf in shift_leaves:
        assert leaf.shape[0] == m, (
            f"device shift table leading dim {leaf.shape} != cohort size "
            f"{m} — per-client state must stay O(cohort)")
    device_shift_bytes = sum(
        int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
        for l in shift_leaves)
    store_bytes = ClientStateStore.estimate_nbytes(
        abstract.params, clients, agg_c.rule, n_slots=agg_c.n_slots,
        dtype=agg_c.shift_dtype)
    out = {"population": clients, "cohort": m,
           "cohort_mode": "rr",
           "rounds_per_fleet_epoch": clients / m,
           "device_shift_bytes": device_shift_bytes,
           "store_bytes": store_bytes}
    if buffer_k is not None or chaos_dropout > 0:
        # host-side buffered-async planning at population scale: the
        # planner is O(cohort) per round no matter how big C is, and the
        # probe shows how many completers the K-of-m trigger keeps
        from repro.fleet import AsyncPlanner, ChaosConfig

        planner = AsyncPlanner(
            m, buffer_k=buffer_k,
            chaos=ChaosConfig(dropout=chaos_dropout, seed=chaos_seed))
        probed = [planner(r, cohorts.cohort_for_round(r))
                  for r in range(16)]
        done = [int(p.completes.sum()) for p in probed]
        out["async"] = {"buffer_k": planner.buffer_k,
                        "chaos_dropout": chaos_dropout,
                        "rounds_probed": len(probed),
                        "mean_completers": float(np.mean(done)),
                        "min_completers": int(min(done))}
    if data_store is not None:
        # paged-data probe at population scale: a sparse on-disk store (no
        # shard file until written — absent shards read as zeros, so a
        # 10^5-client layout costs one spec file), a REAL paged
        # CohortStream walking 8 rounds including a fleet-epoch straddle,
        # and the §3.11 invariant: resident bytes stay under the lookahead
        # window bound no matter how big C is
        from repro.data.paging import ClientDataStore, LookaheadPager
        from repro.data.pipeline import CohortStream
        from repro.data.reshuffle import ReshuffleSampler

        n_probe, b_probe = 2, 1
        dstore = ClientDataStore.create(
            data_store, clients,
            {"tokens": jax.ShapeDtypeStruct((n_probe, b_probe, 64),
                                            jnp.int32)},
            shard_size=512)
        pager = LookaheadPager(dstore, lookahead=1)
        # start 3 rounds before the fleet-epoch boundary so the 8-round
        # walk crosses it (straddle cohorts deconflict, counts resume
        # closed-form)
        start = max(0, clients // m - 3)
        stream = CohortStream(None, ReshuffleSampler(clients, n_probe,
                                                     seed=1),
                              cohorts, paged=pager, start_round=start)
        with stream:
            for _ in range(8):
                fr = next(stream)
                assert fr.batch["tokens"].shape[0] == m * b_probe
        bound = pager.resident_bound_nbytes(m)
        assert pager.resident_nbytes() <= bound, (
            f"paged resident set {pager.resident_nbytes()}B exceeds the "
            f"lookahead window bound {bound}B")
        out["paging"] = {"path": data_store,
                         "num_shards": dstore.num_shards,
                         "store_nbytes": dstore.nbytes,
                         "resident_nbytes": pager.resident_nbytes(),
                         "resident_bound_nbytes": bound,
                         **{k: pager.stats()[k]
                            for k in ("hits", "misses", "evictions")}}
    return out


def lower_pair(arch: str, shape_name: str, *, multi_pod: bool,
               agg_method: str = "diana", agg_wire: str = "shared",
               wire_dtype: str = "f32",
               fraction: float = 0.02, remat="full", ce: str = "gather",
               seq_shard: bool = True, probes: bool = True,
               local_steps: int = 1, clients: int | None = None,
               buffer_k: int | None = None, chaos_dropout: float = 0.0,
               data_store: str | None = None,
               extra_tags: dict | None = None):
    """Lower + compile one (arch, shape, mesh). Returns a result dict.

    Protocol (DESIGN.md §6): the FULL-depth model is compiled with the
    production `lax.scan` layer loop — that is the must-succeed dry-run and
    the source of `memory_analysis()` (scan gives true buffer reuse). XLA's
    cost model counts loop bodies once, so FLOPs/bytes/collective terms come
    from two shallow FULLY-UNROLLED depth probes (k=1, 2 layers, inner scans
    unrolled too) extrapolated affinely to the real depth — every per-layer
    term (compute, HBM traffic, gradient-compression collectives) is exactly
    affine in layer count.
    """
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    ok, reason = shape_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    m = num_clients(mesh)
    # diana_rr at the dry-run scale: a representative 8-slot shift table
    # (the real n comes from the data; the compile only needs the layout)
    agg = CompressedAggregation(method=agg_method, wire=agg_wire,
                                fraction=fraction, wire_dtype=wire_dtype,
                                n_slots=8 if agg_method == "diana_rr" else 1)
    n_dev = mesh.devices.size

    # buffered-async knobs compile the ELASTIC step (trailing per-rank
    # weights vector) — the variant AsyncFleetRunner drives
    elastic = buffer_k is not None or chaos_dropout > 0

    # 1) full-depth scan compile: the dry-run proper + memory analysis
    t0 = time.time()
    flags.set_unroll_inner_scans(False)
    compiled_full = _compile_one(cfg, shape, mesh, agg, remat=remat,
                                 unroll=False, ce=ce, seq_shard=seq_shard,
                                 local_steps=local_steps, elastic=elastic)
    t_full = time.time() - t0
    mem = memory_summary(compiled_full)
    roof_scan = roofline_from_compiled(compiled_full, n_dev)

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok",
        "n_devices": n_dev,
        "clients": m,
        "agg": {"method": agg_method, "wire": agg_wire, "fraction": fraction,
                "wire_dtype": wire_dtype},
        "remat": str(remat),
        "ce": ce,
        "seq_shard": seq_shard,
        "local_steps": local_steps,
        "elastic": elastic,
        "compile_s": round(t_full, 1),
        "memory": mem,
        "roofline_scan_raw": roof_scan.as_dict(),
        "model_params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    if clients is not None and shape.kind == "train":
        result["fleet"] = fleet_smoke(cfg, mesh, agg, clients,
                                      local_steps=local_steps,
                                      buffer_k=buffer_k,
                                      chaos_dropout=chaos_dropout,
                                      data_store=data_store)

    # 2) depth probes (unrolled) -> affine extrapolation of cost terms
    if probes:
        t1 = time.time()
        flags.set_unroll_inner_scans(True)
        try:
            probes_raw = {}
            for k in (1, 2):
                ck = _compile_one(_probe_cfg(cfg, k), shape, mesh, agg,
                                  remat=remat, unroll=True, ce=ce,
                                  seq_shard=seq_shard,
                                  local_steps=local_steps, elastic=elastic)
                probes_raw[k] = roofline_from_compiled(ck, n_dev)
                result.setdefault("top_collectives", {})[k] = [
                    (f"{b:.3e}", kind, shp)
                    for b, kind, shp in collective_stats(ck.as_text()).top[:5]
                ]
        finally:
            flags.set_unroll_inner_scans(False)
        L = cfg.num_layers
        def extrap(term):
            f1, f2 = getattr(probes_raw[1], term), getattr(probes_raw[2], term)
            return max(f1 + (L - 1) * (f2 - f1), f1)
        roof = Roofline(
            flops=extrap("flops"),
            hbm_bytes=extrap("hbm_bytes"),
            collective_bytes=extrap("collective_bytes"),
            n_devices=n_dev,
        )
        result["probe_s"] = round(time.time() - t1, 1)
        result["probes"] = {k: v.as_dict() for k, v in probes_raw.items()}
        result["roofline"] = roof.as_dict()
    else:
        result["roofline"] = roof_scan.as_dict()

    if extra_tags:
        result.update(extra_tags)
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=tuple(INPUT_SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) combination")
    ap.add_argument("--agg", "--method", default="diana",
                    choices=("dense", "q", "diana", "diana_rr", "ef"))
    ap.add_argument("--wire", default="shared",
                    choices=("shared", "independent"))
    ap.add_argument("--wire-dtype", default="f32",
                    choices=("f32", "bf16", "packed8", "packed4"),
                    help="transport dtype for the shared wire slab "
                         "(DESIGN.md §3.13)")
    ap.add_argument("--fraction", type=float, default=0.02)
    ap.add_argument("--remat", default="full", choices=("full", "dots", "none"))
    ap.add_argument("--ce", default="gather", choices=("streaming", "gather"))
    ap.add_argument("--seq-shard", dest="seq_shard", action="store_true", default=True)
    ap.add_argument("--no-seq-shard", dest="seq_shard", action="store_false")
    ap.add_argument("--local-steps", type=int, default=1,
                    help="NASTYA local mini-epochs per round (pod granularity)")
    ap.add_argument("--clients", type=int, default=None,
                    help="fleet population size: record cohort-walk + "
                         "state-store sizing next to the compile and assert "
                         "device shift memory stays O(cohort) — DESIGN.md "
                         "§3.9 (train shapes only)")
    ap.add_argument("--buffer-k", type=int, default=None,
                    help="compile the buffered-async ELASTIC step and probe "
                         "the K-of-m participation plan host-side "
                         "(DESIGN.md §3.10; train shapes with --clients)")
    ap.add_argument("--chaos-dropout", type=float, default=0.0,
                    help="per-round client dropout probability for the "
                         "async participation probe")
    ap.add_argument("--data-store", default=None,
                    help="probe the out-of-core paged-data path: lay a "
                         "sparse per-client data store under this directory "
                         "and walk a real paged CohortStream across a "
                         "fleet-epoch boundary, asserting host residency "
                         "stays under the lookahead-window bound "
                         "(DESIGN.md §3.11; train shapes with --clients)")
    ap.add_argument("--no-probes", action="store_true",
                    help="skip the unrolled depth probes (report raw scan "
                         "cost terms, which count loop bodies once)")
    ap.add_argument("--out", default=None, help="append JSONL here")
    ap.add_argument("--tag", default=None, help="label stored with results")
    ap.add_argument("--telemetry", default=None, metavar="JSONL",
                    help="stream dry-run telemetry (compile spans; with "
                         "--data-store the fleet smoke's assemble/page_in "
                         "spans and pager counters) to this JSONL file")
    ap.add_argument("--trace", default=None, metavar="JSON",
                    help="also export a Chrome/Perfetto trace at exit")
    args = ap.parse_args(argv)

    tpath = args.telemetry
    if args.trace and not tpath:
        base = (args.trace[:-5] if args.trace.endswith(".json")
                else args.trace)
        tpath = base + ".telemetry.jsonl"
    if tpath is not None:
        telemetry.install(telemetry.MetricsSink(tpath))
        telemetry.run_meta({"tool": "dryrun", "agg": args.agg,
                            "wire_dtype": args.wire_dtype,
                            "clients": args.clients,
                            "data_store": bool(args.data_store)})

    pairs = (
        [(a, s) for a in ARCH_NAMES for s in INPUT_SHAPES]
        if args.all else [(args.arch, args.shape)]
    )
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = 0
    try:
        for arch, shape in pairs:
            for multi in meshes:
                try:
                    with telemetry.span("compile", arch=arch, shape=shape):
                        res = lower_pair(
                            arch, shape, multi_pod=multi,
                            agg_method=args.agg,
                            agg_wire=args.wire, wire_dtype=args.wire_dtype,
                            fraction=args.fraction,
                            remat=args.remat, ce=args.ce,
                            seq_shard=args.seq_shard,
                            probes=not args.no_probes,
                            local_steps=args.local_steps,
                            clients=args.clients, buffer_k=args.buffer_k,
                            chaos_dropout=args.chaos_dropout,
                            data_store=args.data_store,
                            extra_tags={"tag": args.tag} if args.tag
                            else None,
                        )
                except Exception as e:  # a dry-run failure is a sharding bug
                    failures += 1
                    res = {"arch": arch, "shape": shape,
                           "mesh": "multi" if multi else "single",
                           "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                line = json.dumps(res)
                print(line, flush=True)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(line + "\n")
    finally:
        sink = telemetry.active()
        if sink is not None:
            telemetry.uninstall()
            sink.close()
            if args.trace:
                n = telemetry.write_trace(
                    telemetry.read_events(tpath), args.trace)
                print(f"trace -> {args.trace} ({n} trace events)",
                      file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
