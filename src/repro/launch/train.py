"""Production training driver.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
        --steps 100 --agg diana --fraction 0.02 [--production-mesh]

On CPU (this container) it runs the REDUCED config of the chosen arch on an
8-host-device (data=4, model=2) mesh; on a real pod pass --production-mesh
to build the 16x16 (or 2x16x16 with --multi-pod) mesh and the full config.
Every piece is the production path: shard_map per-client gradients, the
paper's compressed wire, DIANA shifts, the epoch-indexed RR batch stream
(`data.pipeline`, DESIGN.md §3.7) with double-buffered prefetch, and
cursor-checkpointed resume (`--resume` bit-reproduces the data stream).

`--clients C` (with C > the mesh client count) switches to the FLEET path
(DESIGN.md §3.9): each round samples a cohort of mesh-rank-many clients
from a C-client population (`--cohort-mode rr` walks a fresh population
permutation per fleet epoch — client-level RR; `with_replacement` is the
i.i.d. baseline), DIANA(-RR) shifts live in a host-sharded
`ClientStateStore` and only the cohort's slices touch the device, and
`--checkpoint/--resume` persist the store + fleet cursor so a resumed run
bit-reproduces an uninterrupted one. With C equal to the mesh client count
the fleet path bit-matches this file's full-participation loop.
"""
import os

if "--production-mesh" not in os.sys.argv:
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse

import jax

from repro.launch import compat
import jax.numpy as jnp
import numpy as np

from repro import telemetry

from repro.checkpoint import load_meta, restore_train_state, save_pytree
from repro.checkpoint.io import (
    restore_fleet_checkpoint,
    save_fleet_checkpoint,
)
from repro.configs import ARCH_NAMES, get_config, reduced
from repro.core import salts
from repro.core.dist import CompressedAggregation
from repro.data.paging import ClientDataStore, LookaheadPager
from repro.data.pipeline import make_batch_stream, shared_slots_for_step
from repro.data.reshuffle import ReshuffleSampler
from repro.data.tokens import synthetic_token_batches
from repro.fleet import (
    COHORT_MODES,
    LATE_POLICIES,
    AsyncFleetRunner,
    AsyncPlanner,
    ChaosConfig,
    CohortSampler,
    ClientStateStore,
    FleetRunner,
)
from repro.launch import steps
from repro.launch.mesh import make_production_mesh, make_test_mesh, num_clients


def stub_modalities(cfg, m: int, n_batches: int, b: int, *, seed: int = 0):
    """Client-stacked VLM/audio stub leaves, (m, n, b, ...) like the tokens.

    Each (client, batch-slot) holds its own deterministic rows, so the
    stream's RR gather keeps modalities row-aligned with the tokens (the
    seed-era `tile_extra` handed every local micro-step byte-identical
    rows — indistinguishable from a misaligned stream in any test).
    """
    extras = {}
    rng = np.random.default_rng((seed, salts.MODALITY_STUB_SALT))
    if cfg.family == "vlm":
        extras["patches"] = rng.normal(
            size=(m, n_batches, b, cfg.vision_patches, cfg.d_model)
        ).astype(cfg.dtype)
    if cfg.is_encdec:
        extras["frames"] = rng.normal(
            size=(m, n_batches, b, cfg.encoder_seq, cfg.d_model)
        ).astype(cfg.dtype)
    return extras


def chaos_from_args(args) -> ChaosConfig:
    """The --chaos-* CLI surface -> one deterministic fault config."""
    return ChaosConfig(
        dropout=args.chaos_dropout, straggler=args.chaos_straggler,
        delay=args.chaos_delay, store_fail=args.chaos_store_fail,
        max_retries=args.chaos_retries, backoff=args.chaos_backoff,
        seed=args.chaos_seed)


def fleet_is_async(args) -> bool:
    """Buffered-async mode turns on when any async/chaos knob is set; a
    plain --clients run keeps the synchronous driver (and its compiled
    step) byte-identical to before."""
    chaos = chaos_from_args(args)
    return (args.buffer_k is not None or args.late == "drop"
            or chaos.dropout > 0 or chaos.straggler > 0
            or chaos.store_fail > 0)


def run_fleet(args, cfg, mesh, agg, m, n_batches, b,
              jitted, abstract, shardings, batch_sh):
    """The fleet (partial-participation) loop: C-client population, cohort
    of m mesh ranks per round, host state store (DESIGN.md §3.9).

    Without --data-store the synthetic population DATASET is materialized
    dense on the host (O(C * n * b * seq) — fine for demo scales). With
    --data-store PATH the dataset lives on disk as per-client rows
    (`repro.data.paging.ClientDataStore`) and each round's cohort pages in
    through the deterministic lookahead pager — host RSS is bounded by the
    lookahead window, not the population (DESIGN.md §3.11). Batches are
    bit-identical either way.
    """
    C = args.clients
    data = {"tokens": np.asarray(synthetic_token_batches(
        vocab=cfg.vocab, seq_len=args.seq, batch=b,
        num_batches=n_batches, num_clients=C, seed=0))}
    data.update(stub_modalities(cfg, C, n_batches, b))
    sampler = ReshuffleSampler(C, n_batches, mode=args.sampling, seed=1)
    cohorts = CohortSampler(C, m, mode=args.cohort_mode, seed=2)
    store = ClientStateStore.create(
        abstract.params, C, agg.rule, n_slots=agg.n_slots,
        dtype=agg.shift_dtype, path=args.store_path)
    est = ClientStateStore.estimate_nbytes(
        abstract.params, C, agg.rule, n_slots=agg.n_slots,
        dtype=agg.shift_dtype)
    print(f"fleet: population {C}, cohort {m} ({args.cohort_mode}), "
          f"store {est/1e6:.1f}MB "
          + (f"mmap@{args.store_path}" if args.store_path else "host RAM")
          + " / O(cohort) device")

    pager = None
    if args.data_store:
        if os.path.exists(os.path.join(args.data_store, "data_store.json")):
            dstore = ClientDataStore.open(args.data_store)
        else:
            dstore = ClientDataStore.from_stacked(args.data_store, data)
        pager = LookaheadPager(dstore, state=store)
        print(f"data store: {dstore.nbytes/1e6:.1f}MB on disk "
              f"@{args.data_store} ({dstore.num_shards} shards x "
              f"{dstore.shard_size} clients), resident <= "
              f"{pager.resident_bound_nbytes(m)/1e6:.1f}MB")
        data = None

    use_async = fleet_is_async(args)
    chaos = chaos_from_args(args)
    async_spec = AsyncPlanner(
        m, buffer_k=args.buffer_k, late=args.late, discount=args.discount,
        chaos=chaos).spec() if use_async else None

    start_round = 0
    if args.resume:
        meta = load_meta(args.resume)
        fm = (meta.get("meta") or {}).get("fleet")
        if fm is None:
            raise SystemExit(f"{args.resume}: no fleet cursor in manifest — "
                             "not a fleet checkpoint?")
        if fm["sampler"] != sampler.spec() or \
                fm["cohort_sampler"] != cohorts.spec() or \
                fm["local_steps"] != args.local_steps:
            raise SystemExit(
                f"{args.resume}: checkpointed fleet walk {fm} does not "
                "match this run's samplers/local_steps — refusing to "
                "resume onto a different cohort walk")
        if fm.get("async") != async_spec:
            raise SystemExit(
                f"{args.resume}: checkpointed async/chaos plan "
                f"{fm.get('async')} does not match this run's "
                f"{async_spec} — the participation schedule is part of "
                "the walk; resume with the same --buffer-k/--late/"
                "--chaos-* flags")
        have_ds = None if pager is None else pager.data.spec()
        if fm.get("data_store") != have_ds:
            raise SystemExit(
                f"{args.resume}: checkpointed data-store layout "
                f"{fm.get('data_store')} does not match this run's "
                f"{have_ds} — resume with the same --data-store layout "
                "(page identities derive from it)")
        start_round = fm["round"]

    key = salts.root_key(0, salts.ROUNDS_KEY_SALT)
    with compat.set_mesh(mesh):
        if args.resume:
            state = restore_fleet_checkpoint(
                args.resume, abstract, shardings, store,
                data_store=None if pager is None else pager.data)
            print(f"resumed {args.resume} at round {start_round} "
                  f"(fleet epoch {fm['fleet_epoch']})")
        else:
            state = jax.device_put(
                steps.init_train_state(
                    salts.root_key(0, salts.PARAMS_KEY_SALT), cfg, agg, m,
                    optimizer=args.optimizer, mesh=mesh,
                    local_steps=args.local_steps),
                shardings)
        if use_async:
            runner = AsyncFleetRunner(
                jitted, abstract, shardings, batch_sh, agg=agg, mesh=mesh,
                data=data, sampler=sampler, cohorts=cohorts, store=store,
                buffer_k=args.buffer_k, late=args.late,
                discount=args.discount, chaos=chaos,
                local_steps=args.local_steps, prefetch=args.prefetch,
                start_round=start_round, paged=pager)
            print(f"async: buffer K={runner._planner.buffer_k}/{m} "
                  f"late={args.late} chaos={chaos.spec()}")
        else:
            runner = FleetRunner(
                jitted, abstract, shardings, batch_sh, agg=agg, mesh=mesh,
                data=data, sampler=sampler, cohorts=cohorts, store=store,
                local_steps=args.local_steps, prefetch=args.prefetch,
                start_round=start_round, paged=pager)

        # monotonic rate over the stepping window only: start() fires after
        # restore + runner/stream construction, and the checkpoint write
        # below lands after the last report — neither folds into s/round
        reporter = telemetry.ConsoleReporter(
            unit="round", log_every=args.log_every, total=args.steps,
            start=start_round)

        def log(t, _state, metrics):
            reporter.report(t, metrics, cohort=m)

        with runner:
            reporter.start()
            state = runner.run(state, key, args.steps - start_round,
                               callback=log)
            if args.checkpoint:
                save_fleet_checkpoint(
                    args.checkpoint, jax.device_get(state), store,
                    step=int(state.step),
                    meta={"fleet": runner.checkpoint_meta()},
                    data_store=None if pager is None else pager.data)
                print(f"fleet checkpoint -> {args.checkpoint} "
                      f"(round {runner.round})")


def build_parser() -> argparse.ArgumentParser:
    """The CLI surface (separate so tests can assert the module docstring's
    example flags stay parseable — flag/doc drift is a bug)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="stablelm-1.6b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=0.1,
                    help="client/local stepsize gamma")
    ap.add_argument("--local-steps", type=int, default=1,
                    help=">1 runs Q-NASTYA/DIANA-NASTYA at pod granularity: "
                         "that many local RR mini-epochs between rounds")
    ap.add_argument("--eta", type=float, default=None,
                    help="server stepsize for --local-steps>1 "
                         "(default gamma*local_steps = FedRR equivalence)")
    ap.add_argument("--agg", "--method",
                    choices=("diana", "q", "dense", "diana_rr", "ef"),
                    default="diana",
                    help="wire aggregation method; 'diana_rr' runs the "
                         "paper's per-slot shifts (Algorithm 3) and needs "
                         "--sampling rr_shared, 'ef' is error feedback")
    ap.add_argument("--wire", choices=("shared", "independent"), default="shared")
    ap.add_argument("--wire-dtype",
                    choices=("f32", "bf16", "packed8", "packed4"),
                    default="f32",
                    help="shared-wire slab transport: 'packed8'/'packed4' "
                         "bit-pack quantized levels and all_gather the byte "
                         "lattice + f32 scale sideband (DESIGN.md §3.13); "
                         "'bf16' halves the psum lanes")
    # the paper's headline compression ratio (k/d ~= 0.02, Sec. 3) — must
    # stay in sync with the module-docstring example above
    ap.add_argument("--fraction", type=float, default=0.02)
    ap.add_argument("--pods", type=int, default=1,
                    help="CPU test-mesh pods: >1 builds a (pods, 4/pods, 2) "
                         "('pod','data','model') mesh for the two-level wire")
    ap.add_argument("--optimizer", choices=("sgd", "momentum", "adamw"),
                    default="sgd")
    ap.add_argument("--sampling", choices=("rr", "rr_once", "rr_shared", "wr"),
                    default="rr")
    ap.add_argument("--clients", type=int, default=None,
                    help="fleet population size C: sample a cohort of "
                         "mesh-rank-many clients per round from C clients "
                         "whose shifts live in a host state store "
                         "(DESIGN.md §3.9); default = full participation")
    ap.add_argument("--cohort-mode", choices=COHORT_MODES, default="rr",
                    help="'rr' = cohort-RR (every client once per fleet "
                         "epoch); 'with_replacement' = i.i.d. baseline")
    ap.add_argument("--buffer-k", type=int, default=None,
                    help="buffered-async trigger: apply the server update "
                         "once K of the cohort's reports arrive "
                         "(DESIGN.md §3.10); default = synchronous rounds")
    ap.add_argument("--late", choices=LATE_POLICIES, default="discount",
                    help="late reports past the K-of-m deadline: "
                         "'discount' folds them in with weight "
                         "discount/(1+staleness); 'drop' discards them and "
                         "rewinds their RR data cursor (exactly-once)")
    ap.add_argument("--discount", type=float, default=0.5,
                    help="staleness-discount numerator for --late discount")
    ap.add_argument("--chaos-dropout", type=float, default=0.0,
                    help="P(a cohort client goes dark for the round) — "
                         "deterministic per (--chaos-seed, round)")
    ap.add_argument("--chaos-straggler", type=float, default=0.0,
                    help="P(an alive client reports after the deadline)")
    ap.add_argument("--chaos-delay", type=float, default=1.0,
                    help="mean extra straggler latency (base-round units)")
    ap.add_argument("--chaos-store-fail", type=float, default=0.0,
                    help="P(a store gather/scatter raises a transient "
                         "error); the driver retries with backoff")
    ap.add_argument("--chaos-retries", type=int, default=3,
                    help="bounded retry budget per store op")
    ap.add_argument("--chaos-backoff", type=float, default=0.0,
                    help="base seconds for exponential retry backoff")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="seed every fault draw derives from")
    ap.add_argument("--data-store", default=None,
                    help="page the fleet population's DATASETS from disk: "
                         "lay them out as per-client rows in sharded memmap "
                         "files under this directory (built on first run, "
                         "reused if present) and stream each cohort through "
                         "the deterministic lookahead pager — host RSS is "
                         "bounded by the lookahead window, batches are "
                         "bit-identical to the in-RAM path (DESIGN.md §3.11)")
    ap.add_argument("--store-path", default=None,
                    help="back the fleet client-state store with np.memmap "
                         "shards under this directory (zero pages cost "
                         "nothing on disk); default keeps shards in host "
                         "RAM — large --clients runs want this")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--checkpoint", default=None, help="save state here at end")
    ap.add_argument("--resume", default=None,
                    help="checkpoint to restore (state + data-stream cursor; "
                         "the continued run bit-matches an uninterrupted one)")
    ap.add_argument("--no-prefetch", dest="prefetch", action="store_false",
                    help="disable the double-buffered host prefetch")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--telemetry", default=None, metavar="JSONL",
                    help="stream structured run events (round metrics, host "
                         "phase spans, wire/chaos/pager counters) to this "
                         "JSONL file; inspect with `python -m "
                         "repro.telemetry` (DESIGN.md §3.14). Off by "
                         "default and byte-identical when off")
    ap.add_argument("--trace", default=None, metavar="JSON",
                    help="also export a Chrome/Perfetto trace_event JSON at "
                         "exit (implies --telemetry to a sibling file when "
                         "not set)")
    ap.add_argument("--device-metrics", action="store_true",
                    help="carry opt-in compression diagnostics in the "
                         "step's metrics pytree (‖ḡ−D‖², shift norms) — "
                         "changes the compiled step, so off by default")
    return ap


def telemetry_path(args) -> str | None:
    """--telemetry wins; --trace alone derives a sibling JSONL path."""
    if args.telemetry:
        return args.telemetry
    if args.trace:
        base = (args.trace[:-5] if args.trace.endswith(".json")
                else args.trace)
        return base + ".telemetry.jsonl"
    return None


def main():
    ap = build_parser()
    args = ap.parse_args()

    if args.production_mesh:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        cfg = get_config(args.arch)
    elif args.pods > 1:
        if args.pods not in (2, 4):
            ap.error("--pods must be 1, 2 or 4 (the CPU test mesh has 4 "
                     "client ranks to split into pods)")
        mesh = make_test_mesh((args.pods, 4 // args.pods, 2),
                              ("pod", "data", "model"))
        cfg = reduced(get_config(args.arch), seq=args.seq)
    else:
        mesh = make_test_mesh((4, 2), ("data", "model"))
        cfg = reduced(get_config(args.arch), seq=args.seq)
    m = num_clients(mesh)
    n_batches = 8
    slotted = args.agg == "diana_rr"
    if slotted and args.sampling != "rr_shared":
        ap.error("--agg diana_rr needs --sampling rr_shared: the per-slot "
                 "wire reads/writes one shared shift-table row per round, "
                 "so every client must walk its data in the same index "
                 "order (DESIGN.md §3.8)")
    if args.clients is not None:
        if args.clients < m:
            ap.error(f"--clients {args.clients} < mesh client ranks {m}: "
                     "the cohort fills every mesh rank each round")
        if slotted and (args.cohort_mode != "rr" or args.clients % m != 0):
            ap.error("--agg diana_rr on the fleet path needs --cohort-mode "
                     "rr and --clients divisible by the mesh client count "
                     "(shared-slot wire contract, DESIGN.md §3.9)")
        if fleet_is_async(args) and args.local_steps > 1:
            ap.error("--buffer-k/--chaos-* need --local-steps 1: a NASTYA "
                     "epoch has no well-defined RR rewind point for a "
                     "mid-epoch straggler (DESIGN.md §3.10)")
    elif fleet_is_async(args):
        ap.error("--buffer-k/--late drop/--chaos-* are fleet knobs — pass "
                 "--clients C to run partial participation")
    # cohort-sampled fleets rescale the DIANA mean-shift update by M/C so
    # the server's resident mean shift tracks the population mean h_bar
    # rather than a (C/M)-inflated cohort estimate (DESIGN.md §3.10);
    # M == C gives 1.0, the exact full-participation form
    mean_scale = m / args.clients if args.clients is not None else 1.0
    agg = CompressedAggregation(method=args.agg, wire=args.wire,
                                fraction=args.fraction,
                                n_slots=n_batches if slotted else 1,
                                mean_scale=mean_scale,
                                shift_dtype=jnp.float32,
                                wire_dtype=args.wire_dtype)
    remat = "full" if args.production_mesh else False
    jitted, abstract, shardings, batch_sh = steps.make_train_step(
        cfg, mesh, agg=agg, lr=args.lr, eta=args.eta,
        local_steps=args.local_steps, remat=remat,
        optimizer=args.optimizer, elastic=fleet_is_async(args),
        debug_metrics=args.device_metrics)
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(abstract.params))
    print(f"arch={cfg.name} ({n_params/1e6:.1f}M params) clients={m} "
          f"agg={args.agg}/{args.wire}"
          + (f"/{args.wire_dtype}" if args.wire_dtype != "f32" else "")
          + f" k/d={args.fraction} "
          f"local_steps={args.local_steps} opt={args.optimizer}"
          + (f" fleet=C{args.clients}/{args.cohort_mode}"
             if args.clients is not None else ""))

    tpath = telemetry_path(args)
    if tpath is not None:
        telemetry.install(telemetry.MetricsSink(tpath))
        flags = {k: v for k, v in sorted(vars(args).items())
                 if isinstance(v, (str, int, float, bool, type(None)))}
        agg_c = steps.configure_agg(agg, mesh, args.local_steps)
        wire = agg_c.wire_bytes_per_round(abstract.params)
        telemetry.run_meta({
            "argv": flags, "arch": cfg.name, "n_params": n_params,
            "mesh_clients": m,
            "wire_bytes_per_round": {k: int(v) for k, v in wire.items()}})
    try:
        return _run(args, cfg, mesh, agg, m, n_batches,
                    jitted, abstract, shardings, batch_sh)
    finally:
        sink = telemetry.active()
        if sink is not None:
            telemetry.uninstall()
            sink.close()
            print(f"telemetry -> {tpath}")
            if args.trace:
                n = telemetry.write_trace(
                    telemetry.read_events(tpath), args.trace)
                print(f"trace -> {args.trace} ({n} trace events)")


def _run(args, cfg, mesh, agg, m, n_batches,
         jitted, abstract, shardings, batch_sh):
    slotted = args.agg == "diana_rr"
    b = max(1, args.batch // m)
    if args.clients is not None:
        return run_fleet(args, cfg, mesh, agg, m, n_batches, b,
                         jitted, abstract, shardings, batch_sh)
    data = {"tokens": synthetic_token_batches(
        vocab=cfg.vocab, seq_len=args.seq, batch=b,
        num_batches=n_batches, num_clients=m, seed=0)}
    sampler = ReshuffleSampler(m, n_batches, mode=args.sampling, seed=1)

    start_step = 0
    if args.resume:
        meta = load_meta(args.resume)
        cursor = (meta.get("meta") or {}).get("data_stream")
        if cursor is None:
            raise SystemExit(f"{args.resume}: no data-stream cursor in "
                             "manifest — not a train.py checkpoint?")
        if cursor["sampler"] != sampler.spec() or \
                cursor["local_steps"] != args.local_steps:
            raise SystemExit(
                f"{args.resume}: checkpointed stream {cursor} does not match "
                "this run's sampler/local_steps — refusing to resume onto a "
                "different data stream")
        start_step = cursor["train_step"]

    with compat.set_mesh(mesh):
        if args.resume:
            state = restore_train_state(args.resume, abstract, shardings)
            print(f"resumed {args.resume} at step {start_step} "
                  f"(epoch {cursor['epoch']}, batch {cursor['step']})")
        else:
            state = jax.device_put(
                steps.init_train_state(
                    salts.root_key(0, salts.PARAMS_KEY_SALT), cfg, agg, m,
                    optimizer=args.optimizer, mesh=mesh,
                    local_steps=args.local_steps), shardings)
        key = salts.root_key(0, salts.ROUNDS_KEY_SALT)

        if telemetry.enabled():
            agg_c = steps.configure_agg(agg, mesh, args.local_steps)
            wire = agg_c.wire_bytes_per_round(abstract.params)
            bits_per_client = 8.0 * (wire["intra_pod"] if agg_c.client_axes
                                     else wire["inter_pod"])
        reporter = telemetry.ConsoleReporter(
            unit="step", log_every=args.log_every, total=args.steps,
            start=start_step)

        # the NASTYA-aware stream owns RR order, client-major assembly,
        # modality alignment, and prefetch+device_put overlap
        stream = make_batch_stream(
            data, sampler, local_steps=args.local_steps,
            extras=stub_modalities(cfg, m, n_batches, b),
            put=lambda batch: jax.device_put(batch, batch_sh(batch)),
            prefetch=args.prefetch, start_step=start_step)
        with stream:
            # start the rate clock AFTER restore + stream construction so
            # neither checkpoint-restore nor first-build time folds in
            reporter.start()
            for t, batch in zip(range(start_step, args.steps), stream):
                if slotted:
                    # the shared slot stream is a pure function of the
                    # stateless sampler, so --resume re-derives it exactly
                    slots = jnp.asarray(shared_slots_for_step(
                        sampler, t, args.local_steps, n_slots=agg.n_slots))
                    with telemetry.span("device_step", round=t):
                        state, metrics = jitted(state, batch, key, slots)
                else:
                    with telemetry.span("device_step", round=t):
                        state, metrics = jitted(state, batch, key)
                if telemetry.enabled():
                    telemetry.counter("wire.uplink_bits",
                                      m * bits_per_client, round=t)
                    telemetry.round_metrics(t, metrics)
                reporter.report(t, metrics)
            if args.checkpoint:
                save_pytree(args.checkpoint, jax.device_get(state),
                            step=int(state.step),
                            meta={"data_stream": stream.cursor_meta()})
                print(f"checkpoint -> {args.checkpoint} "
                      f"(cursor {stream.cursor})")


if __name__ == "__main__":
    main()
