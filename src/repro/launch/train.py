"""Production training driver.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
        --steps 100 --agg diana --fraction 0.02 [--production-mesh]

On CPU (this container) it runs the REDUCED config of the chosen arch on an
8-host-device (data=4, model=2) mesh; on a real pod pass --production-mesh
to build the 16x16 (or 2x16x16 with --multi-pod) mesh and the full config.
Every piece is the production path: shard_map per-client gradients, the
paper's compressed wire, DIANA shifts, RR data pipeline, checkpointing.
"""
import os

if "--production-mesh" not in os.sys.argv:
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import time

import jax

from repro.launch import compat
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_pytree
from repro.configs import ARCH_NAMES, get_config, reduced
from repro.core.dist import CompressedAggregation
from repro.data.reshuffle import ReshuffleSampler
from repro.data.tokens import synthetic_token_batches
from repro.launch import steps
from repro.launch.mesh import make_production_mesh, make_test_mesh, num_clients


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="stablelm-1.6b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=0.1,
                    help="client/local stepsize gamma")
    ap.add_argument("--local-steps", type=int, default=1,
                    help=">1 runs Q-NASTYA/DIANA-NASTYA at pod granularity: "
                         "that many local RR mini-epochs between rounds")
    ap.add_argument("--eta", type=float, default=None,
                    help="server stepsize for --local-steps>1 "
                         "(default gamma*local_steps = FedRR equivalence)")
    ap.add_argument("--agg", choices=("diana", "q", "dense"), default="diana")
    ap.add_argument("--wire", choices=("shared", "independent"), default="shared")
    ap.add_argument("--fraction", type=float, default=0.05)
    ap.add_argument("--pods", type=int, default=1,
                    help="CPU test-mesh pods: >1 builds a (pods, 4/pods, 2) "
                         "('pod','data','model') mesh for the two-level wire")
    ap.add_argument("--optimizer", choices=("sgd", "momentum", "adamw"),
                    default="sgd")
    ap.add_argument("--sampling", choices=("rr", "rr_once", "wr"), default="rr")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--checkpoint", default=None, help="save state here at end")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    if args.production_mesh:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        cfg = get_config(args.arch)
    elif args.pods > 1:
        if args.pods not in (2, 4):
            ap.error("--pods must be 1, 2 or 4 (the CPU test mesh has 4 "
                     "client ranks to split into pods)")
        mesh = make_test_mesh((args.pods, 4 // args.pods, 2),
                              ("pod", "data", "model"))
        cfg = reduced(get_config(args.arch), seq=args.seq)
    else:
        mesh = make_test_mesh((4, 2), ("data", "model"))
        cfg = reduced(get_config(args.arch), seq=args.seq)
    m = num_clients(mesh)
    agg = CompressedAggregation(method=args.agg, wire=args.wire,
                                fraction=args.fraction,
                                shift_dtype=jnp.float32)
    remat = "full" if args.production_mesh else False
    jitted, abstract, shardings, _ = steps.make_train_step(
        cfg, mesh, agg=agg, lr=args.lr, eta=args.eta,
        local_steps=args.local_steps, remat=remat,
        optimizer=args.optimizer)
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(abstract.params))
    print(f"arch={cfg.name} ({n_params/1e6:.1f}M params) clients={m} "
          f"agg={args.agg}/{args.wire} k/d={args.fraction} "
          f"local_steps={args.local_steps} opt={args.optimizer}")

    n_batches = 8
    data = synthetic_token_batches(
        vocab=cfg.vocab, seq_len=args.seq, batch=max(1, args.batch // m),
        num_batches=n_batches, num_clients=m, seed=0)
    # VLM / audio stub inputs
    extras = {}
    if cfg.family == "vlm":
        extras["patches"] = np.random.default_rng(0).normal(
            size=(args.batch, cfg.vision_patches, cfg.d_model)).astype(np.float32)
    if cfg.is_encdec:
        extras["frames"] = np.random.default_rng(0).normal(
            size=(args.batch, cfg.encoder_seq, cfg.d_model)).astype(np.float32)
    sampler = ReshuffleSampler(m, n_batches, mode=args.sampling, seed=1)

    with compat.set_mesh(mesh):
        state = jax.device_put(
            steps.init_train_state(jax.random.key(0), cfg, agg, m,
                                   optimizer=args.optimizer, mesh=mesh,
                                   local_steps=args.local_steps), shardings)
        key = jax.random.key(1)
        t0 = time.time()
        ls = args.local_steps

        def micro_batch(c, g):  # g-th global micro-step of client c
            e, i = divmod(g, n_batches)
            return data[c, sampler.epoch_order(e)[c, i]]

        def tile_extra(v):
            # every batch leaf must be client-major (m * ls * b) rows: give
            # each client ls copies of its own stub rows
            b = v.shape[0] // m
            v = v[:m * b].reshape((m, 1, b) + v.shape[1:])
            return np.repeat(v, ls, axis=1).reshape((m * ls * b,) + v.shape[3:])

        for t in range(args.steps):
            # client-major rows; ls micro-batches per client per call,
            # consumed strictly in RR order across epoch boundaries
            tok = np.concatenate(
                [micro_batch(c, t * ls + j)
                 for c in range(m) for j in range(ls)], 0)
            batch = {"tokens": jnp.asarray(tok)}
            batch.update({k: jnp.asarray(tile_extra(v)).astype(cfg.dtype)
                          for k, v in extras.items()})
            state, metrics = jitted(state, batch, key)
            if t % args.log_every == 0 or t == args.steps - 1:
                print(f"step {t:5d} | loss {float(metrics['loss']):8.4f} | "
                      f"gnorm {float(metrics['grad_norm']):9.3f} | "
                      f"{(time.time()-t0)/(t+1):6.2f}s/step", flush=True)
        if args.checkpoint:
            save_pytree(args.checkpoint, jax.device_get(state),
                        step=int(state.step))
            print(f"checkpoint -> {args.checkpoint}")


if __name__ == "__main__":
    main()
