"""Production training driver.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
        --steps 100 --agg diana --fraction 0.02 [--production-mesh]

On CPU (this container) it runs the REDUCED config of the chosen arch on an
8-host-device (data=4, model=2) mesh; on a real pod pass --production-mesh
to build the 16x16 (or 2x16x16 with --multi-pod) mesh and the full config.
Every piece is the production path: shard_map per-client gradients, the
paper's compressed wire, DIANA shifts, RR data pipeline, checkpointing.
"""
import os

if "--production-mesh" not in os.sys.argv:
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import time

import jax

from repro.launch import compat
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_pytree
from repro.configs import ARCH_NAMES, get_config, reduced
from repro.core.dist import CompressedAggregation
from repro.data.reshuffle import ReshuffleSampler
from repro.data.tokens import synthetic_token_batches
from repro.launch import steps
from repro.launch.mesh import make_production_mesh, make_test_mesh, num_clients


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="stablelm-1.6b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--agg", choices=("diana", "q", "dense"), default="diana")
    ap.add_argument("--wire", choices=("shared", "independent"), default="shared")
    ap.add_argument("--fraction", type=float, default=0.05)
    ap.add_argument("--optimizer", choices=("sgd", "momentum", "adamw"),
                    default="sgd")
    ap.add_argument("--sampling", choices=("rr", "rr_once", "wr"), default="rr")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--checkpoint", default=None, help="save state here at end")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    if args.production_mesh:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        cfg = get_config(args.arch)
    else:
        mesh = make_test_mesh((4, 2), ("data", "model"))
        cfg = reduced(get_config(args.arch), seq=args.seq)
    m = num_clients(mesh)
    agg = CompressedAggregation(method=args.agg, wire=args.wire,
                                fraction=args.fraction,
                                shift_dtype=jnp.float32)
    remat = "full" if args.production_mesh else False
    jitted, abstract, shardings, _ = steps.make_train_step(
        cfg, mesh, agg=agg, lr=args.lr, remat=remat,
        optimizer=args.optimizer)
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(abstract.params))
    print(f"arch={cfg.name} ({n_params/1e6:.1f}M params) clients={m} "
          f"agg={args.agg}/{args.wire} k/d={args.fraction} opt={args.optimizer}")

    n_batches = 8
    data = synthetic_token_batches(
        vocab=cfg.vocab, seq_len=args.seq, batch=max(1, args.batch // m),
        num_batches=n_batches, num_clients=m, seed=0)
    # VLM / audio stub inputs
    extras = {}
    if cfg.family == "vlm":
        extras["patches"] = np.random.default_rng(0).normal(
            size=(args.batch, cfg.vision_patches, cfg.d_model)).astype(np.float32)
    if cfg.is_encdec:
        extras["frames"] = np.random.default_rng(0).normal(
            size=(args.batch, cfg.encoder_seq, cfg.d_model)).astype(np.float32)
    sampler = ReshuffleSampler(m, n_batches, mode=args.sampling, seed=1)

    with compat.set_mesh(mesh):
        state = jax.device_put(
            steps.init_train_state(jax.random.key(0), cfg, agg, m,
                                   optimizer=args.optimizer), shardings)
        key = jax.random.key(1)
        t0 = time.time()
        for t in range(args.steps):
            epoch, i = divmod(t, n_batches)
            order = sampler.epoch_order(epoch)
            tok = np.concatenate([data[c, order[c, i]] for c in range(m)], 0)
            batch = {"tokens": jnp.asarray(tok)}
            batch.update({k: jnp.asarray(v).astype(cfg.dtype)
                          for k, v in extras.items()})
            state, metrics = jitted(state, batch, key)
            if t % args.log_every == 0 or t == args.steps - 1:
                print(f"step {t:5d} | loss {float(metrics['loss']):8.4f} | "
                      f"gnorm {float(metrics['grad_norm']):9.3f} | "
                      f"{(time.time()-t0)/(t+1):6.2f}s/step", flush=True)
        if args.checkpoint:
            save_pytree(args.checkpoint, jax.device_get(state),
                        step=int(state.step))
            print(f"checkpoint -> {args.checkpoint}")


if __name__ == "__main__":
    main()
