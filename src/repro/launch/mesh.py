"""Production meshes. Functions only — importing this module never touches
jax device state (DESIGN.md §6 / dry-run contract)."""
from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips).

    Axes: ("data", "model") or ("pod", "data", "model"). The paper's M
    federated clients are the ("pod", "data") ranks; "model" is 16-way
    tensor parallelism inside each client.
    """
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — run under "
            "launch/dryrun.py (it forces 512 host devices) or on real hardware"
        )
    from jax.sharding import Mesh

    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for unit tests on forced host devices."""
    import jax
    from jax.sharding import Mesh

    n = int(np.prod(shape))
    return Mesh(np.asarray(jax.devices()[:n]).reshape(shape), axes)


def client_axes(mesh) -> tuple[str, ...]:
    """The mesh axes that enumerate federated clients (everything but TP)."""
    return tuple(n for n in mesh.axis_names if n != "model")


def num_clients(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in client_axes(mesh)]))


def pod_axes(mesh) -> tuple[str, ...]:
    """The outer (inter-pod) wire axes — present only on multi-pod meshes."""
    return ("pod",) if "pod" in mesh.axis_names else ()


def data_axes(mesh) -> tuple[str, ...]:
    """The inner (intra-pod) client axes: everything but TP and "pod"."""
    return tuple(n for n in mesh.axis_names if n not in ("model", "pod"))


def num_pods(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in pod_axes(mesh)])) if pod_axes(
        mesh) else 1
