from repro.optim.optimizers import (
    Optimizer,
    sgd,
    momentum,
    adamw,
    clip_by_global_norm,
)

__all__ = ["Optimizer", "sgd", "momentum", "adamw", "clip_by_global_norm"]
