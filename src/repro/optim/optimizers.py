"""Pure-JAX pytree optimizers (no optax in this environment).

Minimal optax-like interface:
    opt = adamw(3e-4)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

States are pytrees so they shard with `NamedSharding` like everything else
(ZeRO-1: `launch.steps` annotates them sharded over the data axis).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def sgd(lr: float) -> Optimizer:
    def init(params):
        del params
        return ()

    def update(grads, state, params=None):
        del params
        return jax.tree.map(lambda g: -lr * g, grads), state

    return Optimizer(init, update)


def momentum(lr: float, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def update(grads, state, params=None):
        del params
        new_m = jax.tree.map(lambda m, g: beta * m + g.astype(jnp.float32), state, grads)
        if nesterov:
            upd = jax.tree.map(lambda m, g: -lr * (beta * m + g), new_m, grads)
        else:
            upd = jax.tree.map(lambda m: -lr * m, new_m)
        return upd, new_m

    return Optimizer(init, update)


class AdamState(NamedTuple):
    mu: Any
    nu: Any
    count: jax.Array


def adamw(
    lr: float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamState(
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
            count=jnp.zeros((), jnp.int32),
        )

    def update(grads, state, params):
        count = state.count + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                          state.nu, grads)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)

        def upd(m, v, p):
            step = (m / c1) / (jnp.sqrt(v / c2) + eps)
            return -lr * (step + weight_decay * p.astype(jnp.float32))

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, AdamState(mu=mu, nu=nu, count=count)

    return Optimizer(init, update)


def clip_by_global_norm(grads, max_norm: float):
    sq = jax.tree.reduce(
        jnp.add, jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), grads)
    )
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm
