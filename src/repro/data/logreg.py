"""Federated L2-regularized logistic regression problems (paper Sec. 3.1).

The paper's experiments use LibSVM datasets (mushrooms/w8a/a9a) sorted by
label and split equally among 20 clients — a maximally heterogeneous split.
We reproduce the same construction on synthetic data (no network access in
this environment): draw a separable-ish binary classification task, sort by
label, and split contiguously so clients 1..M/2 hold mostly class -1 and the
rest class +1, exactly the heterogeneity pattern of paper Tables 2-4.

Smoothness/strong-convexity constants follow paper App. A.1:
    L      = lambda_max( (1/4N) A^T A ) + 2*lam
    L_m    = lambda_max( (1/4n_m) A_m^T A_m ) + 2*lam
    L_max  = max_{i,m} ||a_{mi}||^2 / 4 + 2*lam
    mu     = mu_tilde = 2*lam
and the paper picks lam so that L/mu ~ 1e4.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class LogRegProblem:
    """A federated logreg instance in client-stacked layout."""

    data: Any  # {"a": (M, n, b, d), "y": (M, n, b)}
    lam: float
    l_smooth: float
    l_max: float
    mu: float
    f_star: float
    x_star: np.ndarray

    @property
    def m(self) -> int:
        return self.data["a"].shape[0]

    @property
    def n(self) -> int:
        return self.data["a"].shape[1]

    @property
    def d(self) -> int:
        return self.data["a"].shape[3]

    def loss_fn(self):
        lam = self.lam

        def loss(params, batch):
            logits = batch["a"] @ params["w"]
            return jnp.mean(jnp.logaddexp(0.0, -batch["y"] * logits)) + lam * jnp.sum(
                params["w"] ** 2
            )

        return loss

    def full_objective(self, w: np.ndarray) -> float:
        a = np.asarray(self.data["a"]).reshape(-1, self.d)
        y = np.asarray(self.data["y"]).reshape(-1)
        return float(np.mean(np.logaddexp(0.0, -y * (a @ w))) + self.lam * np.sum(w**2))

    def suboptimality(self, w) -> float:
        return self.full_objective(np.asarray(w)) - self.f_star


def logreg_constants(a: np.ndarray, lam: float) -> tuple[float, float, float]:
    """(L, L_max, mu) for f = mean logloss + lam||x||^2 over rows of `a`."""
    n_total = a.shape[0]
    gram = a.T @ a / (4.0 * n_total)
    l_smooth = float(np.linalg.eigvalsh(gram)[-1]) + 2.0 * lam
    l_max = float(np.max(np.sum(a * a, axis=1)) / 4.0) + 2.0 * lam
    mu = 2.0 * lam
    return l_smooth, l_max, mu


def _solve_logreg(a: np.ndarray, y: np.ndarray, lam: float,
                  tol: float = 1e-12, iters: int = 5000) -> np.ndarray:
    """High-accuracy reference solution via (damped) Newton — the paper's
    preprocessing computes f(x*) to 1e-16 with CG; Newton on this smooth
    strongly-convex objective reaches machine precision in a handful of
    iterations."""
    d = a.shape[1]
    w = np.zeros(d)
    n = a.shape[0]
    for _ in range(iters):
        z = y * (a @ w)
        sig = 1.0 / (1.0 + np.exp(z))  # sigma(-z)
        grad = -(a.T @ (y * sig)) / n + 2.0 * lam * w
        s = sig * (1.0 - sig)
        hess = (a.T * s) @ a / n + 2.0 * lam * np.eye(d)
        step = np.linalg.solve(hess, grad)
        w = w - step
        if np.linalg.norm(grad) < tol:
            break
    return w


def make_federated_logreg(
    *,
    m: int = 20,
    n_batches: int = 10,
    batch: int = 8,
    d: int = 40,
    cond: float = 1e4,
    seed: int = 0,
    heterogeneous: bool = True,
) -> LogRegProblem:
    """Synthetic analogue of the paper's LibSVM setup.

    cond: target condition number L/mu (paper uses ~1e4); fixes lam.
    heterogeneous: label-sorted contiguous split (paper App. A Tables 2-4).
    """
    # analysis: allow[rng-unstructured-seed] the generator stream IS the
    # dataset's identity — pinned bit-exact to the seed-era draws (the
    # suite's convergence floors and the figure-1 curves depend on it)
    rng = np.random.default_rng(seed)
    n_total = m * n_batches * batch
    # anisotropic features so L_max >> mu like the LibSVM datasets
    scales = np.exp(rng.uniform(-1.0, 1.0, size=(d,)))
    a = rng.normal(size=(n_total, d)) * scales
    w_true = rng.normal(size=(d,))
    logits = a @ w_true + 0.5 * rng.normal(size=(n_total,))
    y = np.where(logits > 0, 1.0, -1.0)

    if heterogeneous:
        order = np.argsort(y, kind="stable")  # class -1 first, then +1
        a, y = a[order], y[order]
    else:
        order = rng.permutation(n_total)
        a, y = a[order], y[order]

    # lam from target condition number: L(lam)/ (2 lam) = cond
    gram_top = float(np.linalg.eigvalsh(a.T @ a / (4.0 * n_total))[-1])
    lam = gram_top / (2.0 * cond - 2.0)
    l_smooth, l_max, mu = logreg_constants(a, lam)

    x_star = _solve_logreg(a, y, lam)
    f_star = float(np.mean(np.logaddexp(0.0, -y * (a @ x_star))) + lam * np.sum(x_star**2))

    data = {
        "a": jnp.asarray(a.reshape(m, n_batches, batch, d), jnp.float32),
        "y": jnp.asarray(y.reshape(m, n_batches, batch), jnp.float32),
    }
    return LogRegProblem(
        data=data, lam=lam, l_smooth=l_smooth, l_max=l_max, mu=mu,
        f_star=f_star, x_star=x_star,
    )
