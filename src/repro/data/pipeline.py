"""NASTYA-aware streaming data pipeline (DESIGN.md §3.7).

This module owns everything the production loop used to hand-roll:

  - the epoch-indexed RR order (`EpochIterator` over a stateless
    `ReshuffleSampler`), consumed coherently ACROSS epoch boundaries — with
    `local_steps > 1` a train step's micro-batches may straddle two epochs
    and each side must come from its own epoch's permutation;
  - client-major batch assembly: every leaf of the emitted batch has
    `m * local_steps * b` leading rows, client-major, which is exactly the
    contract of `launch.steps.make_train_step` — and EVERY leaf (tokens and
    the VLM/audio modality stubs alike) is gathered through the same RR
    index stream, so modalities stay row-aligned;
  - uneven per-client dataset sizes with explicit drop-remainder semantics;
  - host-side double-buffered prefetch: while the jit'd step runs batch t,
    a single worker thread assembles (and `put`s — device transfer) batch
    t+1, so input assembly stops serializing with the step;
  - a checkpointable cursor `(epoch, step)` so a restored run bit-reproduces
    the data stream from any point, mid-epoch included.

The sampler side is pure numpy (permutations never need a device); anything
jax-typed enters only through the caller-supplied `put` callable and the
small simulator/dry-run helpers at the bottom.
"""
from __future__ import annotations

from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Mapping, NamedTuple

import numpy as np

from repro import telemetry
from repro.data.reshuffle import ReshuffleSampler

PutFn = Callable[[dict], Any]


# ---------------------------------------------------------------------------
# client-stacked data normalization (uneven sizes, drop-remainder)
# ---------------------------------------------------------------------------

def _normalize_leaf(name: str, leaf, m: int):
    """A leaf is either a stacked (m, n, b, ...) array or a length-m sequence
    of per-client (n_c, b, ...) arrays (uneven datasets). Returns
    (per-client views, per-client batch counts)."""
    if isinstance(leaf, (list, tuple)):
        views = [np.asarray(c) for c in leaf]
    else:
        arr = np.asarray(leaf)
        if arr.ndim < 2:
            raise ValueError(
                f"leaf {name!r}: expected client-stacked (m, n, ...) data, "
                f"got shape {arr.shape}")
        views = [arr[c] for c in range(arr.shape[0])]
    if len(views) != m:
        raise ValueError(
            f"leaf {name!r}: {len(views)} clients, sampler has {m}")
    return views, [v.shape[0] for v in views]


def normalize_client_data(data: Mapping[str, Any], m: int, *,
                          drop_remainder: bool = True):
    """Validate a client-stacked data dict and resolve a common per-client
    batch count n.

    drop_remainder=True: clients with more than min_c n_c batches have their
    tail batches dropped (never sampled), keeping every client in lockstep —
    the explicit analogue of the paper's equal-n assumption. With
    drop_remainder=False uneven sizes are an error: pad the data instead
    (the paper's code assigns the remainder to the last worker).

    Returns (views, n): views[name] is a length-m list of (n_or_more, b, ...)
    arrays, n the usable per-client batch count.
    """
    if not isinstance(data, Mapping) or not data:
        raise ValueError("data must be a non-empty mapping of named leaves")
    views: dict[str, list[np.ndarray]] = {}
    counts: dict[str, list[int]] = {}
    for name, leaf in data.items():
        views[name], counts[name] = _normalize_leaf(name, leaf, m)
    all_counts = {c for per_leaf in counts.values() for c in per_leaf}
    n = min(all_counts)
    if len(all_counts) > 1 and not drop_remainder:
        raise ValueError(
            f"uneven per-client batch counts {sorted(all_counts)} with "
            "drop_remainder=False — pad every client to the same n (the "
            "paper assigns the remainder to the last worker) or pass "
            "drop_remainder=True to truncate to the minimum")
    if n < 1:
        raise ValueError("some client holds zero batches")
    return views, n


# ---------------------------------------------------------------------------
# the epoch-indexed RR cursor
# ---------------------------------------------------------------------------

class EpochIterator:
    """Walks a `ReshuffleSampler`'s order coherently across epochs.

    The position is a single integer `g` — the per-client micro-step count
    consumed so far (all clients advance in lockstep, one column of the
    order matrix per micro-step). `(epoch, step) = divmod(g, n)` is the
    checkpointable cursor; because the sampler is stateless, rebuilding an
    iterator at any `g` replays the identical stream.
    """

    def __init__(self, sampler: ReshuffleSampler, *, start: int = 0):
        if start < 0:
            raise ValueError(f"start={start}")
        self.sampler = sampler
        self._g = int(start)
        self._cached_epoch: int | None = None
        self._order: np.ndarray | None = None

    @property
    def global_step(self) -> int:
        return self._g

    @property
    def cursor(self) -> tuple[int, int]:
        """(epoch, step-within-epoch) of the NEXT micro-step to be drawn."""
        return divmod(self._g, self.sampler.n)

    def _order_for(self, epoch: int) -> np.ndarray:
        if epoch != self._cached_epoch:
            self._order = self.sampler.epoch_order(epoch)
            self._cached_epoch = epoch
        return self._order

    def take(self, count: int) -> np.ndarray:
        """(M, count) batch indices for the next `count` micro-steps,
        advancing the cursor. A call may straddle an epoch boundary: columns
        before the boundary come from the old epoch's permutation, columns
        after from the new one (RR-coherent mid-step rollover)."""
        m = self.sampler.m
        cols = np.empty((m, count), np.int32)
        for j in range(count):
            epoch, i = divmod(self._g + j, self.sampler.n)
            cols[:, j] = self._order_for(epoch)[:, i]
        self._g += count
        return cols


# ---------------------------------------------------------------------------
# the stream
# ---------------------------------------------------------------------------

class _PrefetchStream:
    """Shared double-buffered prefetch lifecycle for the batch streams.

    Subclasses implement `_plan()` (calling thread ONLY — it advances the
    stream's cursor, so worker timing can never reorder the walk),
    `_build(plan)` (worker thread: assembly + `put` — device transfer
    overlaps the running step), and `_emit(plan, built)` (calling thread:
    bookkeeping + the yielded value). With `prefetch=True` exactly one
    built batch is kept in flight. A failed plan/build POISONS the stream
    — the cursor no longer matches the batches actually delivered, and a
    caught-and-retried next() must not silently skip a batch.
    """

    def __init__(self, prefetch: bool):
        self._pool = ThreadPoolExecutor(max_workers=1) if prefetch else None
        self._pending = None
        self._closed = False

    # -- subclass hooks ----------------------------------------------------

    def _plan(self):
        raise NotImplementedError

    def _build(self, plan):
        raise NotImplementedError

    def _emit(self, plan, built):
        raise NotImplementedError

    # -- iteration ---------------------------------------------------------

    def _build_traced(self, plan):
        # spans fire from the worker thread on prefetch paths — the sink's
        # per-thread nesting keeps them on their own trace track
        with telemetry.span("assemble", stream=type(self).__name__):
            return self._build(plan)

    def _submit(self):
        plan = self._plan()
        fut = (self._pool.submit(self._build_traced, plan)
               if self._pool is not None else None)
        return plan, fut

    def __iter__(self):
        return self

    def __next__(self):
        if self._closed:
            raise ValueError(
                f"{type(self).__name__} is closed (or died on a failed "
                "assemble/put) — its cursor no longer matches the emitted "
                "batches; rebuild the stream from the last checkpointed "
                "cursor")
        try:
            if self._pool is None:
                plan, _ = self._submit()
                return self._emit(plan, self._build_traced(plan))
            if self._pending is None:
                self._pending = self._submit()
            (plan, fut), self._pending = self._pending, self._submit()
            return self._emit(plan, fut.result())
        except BaseException:
            self.close()
            raise

    def close(self):
        self._closed = True
        if self._pool is not None:
            self._pool.shutdown(wait=False, cancel_futures=True)
            self._pool = None
            self._pending = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class BatchStream(_PrefetchStream):
    """Iterator of client-major `(m * local_steps * b)`-row train batches.

    Each `next()` yields one train step's feed: for every client c, its
    `local_steps` next RR micro-batches (in order), stacked client-major —
    rows `[c*ls*b, (c+1)*ls*b)` belong to client c. All leaves are gathered
    with the same index stream, so multi-modal rows stay aligned. Prefetch
    and poisoning semantics come from `_PrefetchStream`.
    """

    def __init__(self, data: Mapping[str, Any], sampler: ReshuffleSampler, *,
                 local_steps: int = 1, put: PutFn | None = None,
                 prefetch: bool = True, drop_remainder: bool = True,
                 start_step: int = 0):
        if local_steps < 1:
            raise ValueError(f"local_steps={local_steps}")
        self._views, n_avail = normalize_client_data(
            data, sampler.m, drop_remainder=drop_remainder)
        if sampler.n > n_avail:
            raise ValueError(
                f"sampler indexes {sampler.n} batches/client but the data "
                f"holds only {n_avail} usable batches/client")
        self.m = sampler.m
        self.n = sampler.n  # batches beyond sampler.n are dropped remainder
        self.local_steps = int(local_steps)
        self._put = put
        self._start_step = int(start_step)
        self._consumed = 0  # train steps handed to the caller
        self._it = EpochIterator(sampler, start=start_step * local_steps)
        super().__init__(prefetch)

    # -- cursor / checkpointing --------------------------------------------

    @property
    def step(self) -> int:
        """Train steps consumed so far (including `start_step`)."""
        return self._start_step + self._consumed

    @property
    def cursor(self) -> tuple[int, int]:
        """(epoch, step-within-epoch) of the next UNCONSUMED micro-step —
        prefetched-but-not-yet-returned batches don't count, so this is
        always the right place to restart after a restore."""
        return divmod(self.step * self.local_steps, self.n)

    def cursor_meta(self) -> dict:
        """JSON-serializable cursor + sampler spec, for the checkpoint
        manifest. Resume with `make_batch_stream(..., start_step=
        meta['train_step'])` after checking `sampler` matches."""
        epoch, step = self.cursor
        return {"train_step": self.step,
                "global_micro_step": self.step * self.local_steps,
                "epoch": epoch, "step": step,
                "local_steps": self.local_steps,
                "sampler": self._it.sampler.spec()}

    # -- _PrefetchStream hooks ---------------------------------------------

    def _plan(self) -> np.ndarray:
        return self._it.take(self.local_steps)

    def _build(self, cols: np.ndarray):
        return _assemble_rows(self._views, range(self.m), cols, self._put)

    def _emit(self, cols: np.ndarray, built):
        self._consumed += 1
        return built


def _assemble_rows(views: dict, clients, cols: np.ndarray,
                   put: PutFn | None):
    """Client-major row assembly — THE row contract, shared by the
    full-participation and per-cohort streams: for the i-th client in
    `clients`, its `cols[i, :]` batches in order, every leaf gathered by
    the same index stream (modalities stay row-aligned), then `put`."""
    ls = cols.shape[1]
    out = {}
    for name, v in views.items():
        rows = [v[c][cols[i, j]]
                for i, c in enumerate(clients) for j in range(ls)]
        out[name] = np.concatenate(rows, axis=0)
    return put(out) if put is not None else out


def make_batch_stream(data: Mapping[str, Any], sampler: ReshuffleSampler, *,
                      local_steps: int = 1, extras: Mapping[str, Any] | None = None,
                      put: PutFn | None = None, prefetch: bool = True,
                      drop_remainder: bool = True,
                      start_step: int = 0) -> BatchStream:
    """Build the production input stream.

    data / extras: named client-stacked leaves — `(m, n, b, ...)` arrays or
    length-m lists of `(n_c, b, ...)` arrays (uneven datasets; see
    `normalize_client_data`). `extras` (VLM patches, audio frames, ...) are
    merged into the same stream so every modality's rows are gathered by the
    same RR indices as the tokens.

    put: applied to each assembled host batch on the prefetch thread —
    typically `lambda b: jax.device_put(b, batch_shardings(b))` so transfer
    overlaps the running step.

    start_step: first train step to emit (the checkpointed cursor's
    `train_step`); the stream is identical to a fresh run that consumed
    `start_step` steps.
    """
    if extras:
        overlap = set(data) & set(extras)
        if overlap:
            raise ValueError(f"extras duplicate data leaves: {sorted(overlap)}")
        data = {**data, **extras}
    return BatchStream(data, sampler, local_steps=local_steps, put=put,
                       prefetch=prefetch, drop_remainder=drop_remainder,
                       start_step=start_step)


# ---------------------------------------------------------------------------
# the per-cohort stream view (fleet partial participation, DESIGN.md §3.9)
# ---------------------------------------------------------------------------

class ClientOrderWalk:
    """Memoized per-client (cursor -> batch index) lookup over a stateless
    `ReshuffleSampler` — the ONE copy of the divmod-into-epoch-order walk
    that both the per-cohort stream and the simulator fleet driver
    (`core.algorithms.run_fleet_rounds`) consume. Memoization is pure
    caching; the lookup stays a pure function of `(sampler, client,
    cursor)`."""

    def __init__(self, sampler: ReshuffleSampler, *, cache: int = 8):
        self.sampler = sampler
        self._cache = int(cache)
        self._orders: dict[int, np.ndarray] = {}

    def order_for(self, epoch: int) -> np.ndarray:
        order = self._orders.get(epoch)
        if order is None:
            order = self.sampler.epoch_order(epoch)
            self._orders[epoch] = order
            while len(self._orders) > self._cache:
                self._orders.pop(next(iter(self._orders)))
        return order

    def cols_at(self, clients: np.ndarray, counts: np.ndarray,
                local_steps: int = 1) -> np.ndarray:
        """(len(clients), local_steps) batch indices: client i's next
        `local_steps` RR positions starting at ITS OWN micro-step cursor
        `counts[i]` — per-client data-epoch boundaries included (each
        client draws from its own epoch's permutation)."""
        n = self.sampler.n
        cols = np.empty((clients.size, local_steps), np.int32)
        for j in range(local_steps):
            epochs, i = np.divmod(counts + j, n)
            for e in np.unique(epochs):
                sel = epochs == e
                cols[sel, j] = self.order_for(int(e))[clients[sel], i[sel]]
        return cols


class FleetRound(NamedTuple):
    """One round's feed from a `CohortStream`.

    cohort: (m,) sorted client ids participating this round;
    cols:   (m, local_steps) per-client batch indices consumed — client i's
            next RR micro-batches at ITS OWN data cursor (clients advance
            only when sampled, so cursors diverge under partial
            participation);
    batch:  the assembled (and `put`-applied) client-major
            `(m * local_steps * b)`-row batch, same row contract as
            `BatchStream`;
    plan:   the round's `ParticipationPlan` when the stream has a planner
            (buffered-async fleets, `repro.fleet.chaos`): only clients with
            `plan.completes` had their cursor advanced — the others re-read
            the SAME cols next time they are sampled (exactly-once RR).
            None on synchronous streams.
    """

    round: int
    cohort: np.ndarray
    cols: np.ndarray
    batch: Any
    plan: Any = None


class CohortStream(_PrefetchStream):
    """Per-cohort view of a population-sized client-stacked dataset.

    The full-participation `BatchStream` walks every client in lockstep; a
    fleet run (`repro.fleet`) samples a cohort of `cohort_size` clients from
    a population of C each round and must assemble rows for the sampled
    clients ONLY, each at its own RR position. This stream owns that:

      - per-client micro-step cursors, advanced only on participation —
        derived in closed form from the stateless `CohortSampler`
        (`participation_counts`), so the stream is a pure function of
        `(data, data_sampler, cohort_sampler, start_round)` and resumes
        bit-exactly from a round index;
      - per-client epoch boundaries via `ClientOrderWalk` (each sampled
        client draws from its own data epoch's permutation);
      - the same client-major assembly and modality alignment as
        `BatchStream`, with the `_PrefetchStream` double-buffer/poisoning
        lifecycle (cohort planning always happens on the calling thread,
        so worker timing never reorders the walk).

    With `cohort == population` under cohort-RR every round samples every
    client in ascending order and the emitted batches are exactly
    `BatchStream`'s — the fleet bit-match invariant (DESIGN.md §3.9).

    `paged=` (a `repro.data.paging.LookaheadPager`, exclusive with `data=`)
    swaps the in-RAM client-stacked tree for the out-of-core store behind
    the SAME per-cohort view: the pager's `views` satisfy the identical
    `views[name][c]` indexing contract, so `_assemble_rows` — and therefore
    every emitted batch — is bit-identical to the in-RAM path. After each
    build the stream calls `paged.advance_window(t, cohort_sampler)` on the
    prefetch worker, so the next cohort's pages load while the current
    round's step runs (DESIGN.md §3.11). Page residency follows the cohort
    walk, NOT per-client cursors: a planner's non-completers re-read the
    same rows next time sampled because their `counts` never advanced —
    paging changes where rows live, never which rows are read.
    """

    def __init__(self, data: Mapping[str, Any] | None,
                 sampler: ReshuffleSampler,
                 cohort_sampler, *, local_steps: int = 1,
                 put: PutFn | None = None, prefetch: bool = True,
                 drop_remainder: bool = True, start_round: int = 0,
                 planner=None, paged=None):
        if local_steps < 1:
            raise ValueError(f"local_steps={local_steps}")
        if sampler.m != cohort_sampler.population:
            raise ValueError(
                f"data sampler covers {sampler.m} clients but the cohort "
                f"sampler draws from a population of "
                f"{cohort_sampler.population}")
        if paged is not None:
            if data is not None:
                raise ValueError(
                    "pass data= (in-RAM client-stacked tree) OR paged= "
                    "(LookaheadPager over an on-disk ClientDataStore), "
                    "not both")
            if paged.population != sampler.m:
                raise ValueError(
                    f"paged store holds {paged.population} clients but the "
                    f"data sampler covers {sampler.m}")
            self._views, n_avail = paged.views, paged.n_batches
        else:
            self._views, n_avail = normalize_client_data(
                data, sampler.m, drop_remainder=drop_remainder)
        self._paged = paged
        if sampler.n > n_avail:
            raise ValueError(
                f"sampler indexes {sampler.n} batches/client but the data "
                f"holds only {n_avail} usable batches/client")
        self.sampler = sampler
        self.cohorts = cohort_sampler
        self.local_steps = int(local_steps)
        self._put = put
        self._round = int(start_round)
        # `planner` (repro.fleet.chaos.AsyncPlanner, or any pure callable
        # (round, cohort) -> plan with a `.completes` bool mask) gates
        # cursor advancement: a sampled client consumes its batches only
        # when its report completes, so dropped/late-dropped clients re-read
        # the SAME RR positions next time (exactly-once, DESIGN.md §3.10)
        self._planner = planner
        if planner is None:
            # closed-form replay of the cohort walk: every sampled client
            # completes, so counts need no per-round replay
            self.counts = (cohort_sampler.participation_counts(start_round)
                           * self.local_steps)
        else:
            # under faults the closed form is invalid — replay the planner
            # over the skipped prefix (pure in round, O(start_round * m))
            self.counts = np.zeros(cohort_sampler.population, np.int64)
            for t in range(int(start_round)):
                cohort = cohort_sampler.cohort_for_round(t)
                done = planner(t, cohort).completes
                self.counts[cohort[done]] += self.local_steps
        self._walk = ClientOrderWalk(sampler)
        super().__init__(prefetch)

    # -- cursor / checkpointing --------------------------------------------

    @property
    def round(self) -> int:
        """Next UNCONSUMED round (prefetched batches don't count)."""
        return self._round - (0 if self._pending is None else 1)

    def cursor_meta(self) -> dict:
        """JSON-serializable fleet cursor + sampler specs for the
        checkpoint manifest; resume with `start_round=meta['round']`."""
        fleet_epoch, pos = self.cohorts.cursor(self.round)
        return {"round": self.round, "fleet_epoch": fleet_epoch,
                "epoch_position": pos, "local_steps": self.local_steps,
                "cohort_sampler": self.cohorts.spec(),
                "sampler": self.sampler.spec()}

    # -- _PrefetchStream hooks ---------------------------------------------

    def _plan(self) -> tuple[int, np.ndarray, np.ndarray, Any]:
        t = self._round
        cohort = self.cohorts.cohort_for_round(t)
        cols = self._walk.cols_at(cohort, self.counts[cohort],
                                  self.local_steps)
        if self._planner is None:
            self.counts[cohort] += self.local_steps
            part = None
        else:
            part = self._planner(t, cohort)
            self.counts[cohort[part.completes]] += self.local_steps
        self._round = t + 1
        return t, cohort, cols, part

    def _build(self, plan):
        t, cohort, cols, _ = plan
        built = _assemble_rows(self._views, cohort, cols, self._put)
        if self._paged is not None:
            # closed-form lookahead: round t is assembled, so prefetch the
            # pages rounds t+1.. will touch and evict the rest (worker
            # thread — overlaps the running step, DESIGN.md §3.11)
            self._paged.advance_window(t, self.cohorts)
        return built

    def _emit(self, plan, built) -> FleetRound:
        t, cohort, cols, part = plan
        return FleetRound(t, cohort, cols, built, part)


# ---------------------------------------------------------------------------
# slot streams (production DIANA-RR: which shift slot each round touches)
# ---------------------------------------------------------------------------

def slots_for_step(sampler: ReshuffleSampler, step: int,
                   local_steps: int = 1) -> np.ndarray:
    """(M, local_steps) batch indices consumed by train step `step`.

    Pure function of the stateless sampler — exactly the columns
    `BatchStream` gathers for that step, epoch-boundary straddling
    included, so a resumed run derives the same slots from its cursor.
    """
    return EpochIterator(sampler, start=step * local_steps).take(local_steps)


def shared_slots_at(sampler: ReshuffleSampler, micro_step: int,
                    count: int = 1, *,
                    n_slots: int | None = None) -> np.ndarray:
    """(count,) SHARED slot indices starting at per-client micro-step
    `micro_step`.

    The production per-slot wire needs every client of a wire level on the
    same slot per round (DESIGN.md §3.8); that requires a sampler whose
    epoch orders agree across clients (`mode='rr_shared'`, or trivially
    m == 1). Raises when the clients' orders diverge rather than silently
    de-aligning shift slots from the batches actually consumed. The fleet
    driver addresses by micro-step directly because under partial
    participation a cohort's clients share a PARTICIPATION count, not the
    global train-step count (DESIGN.md §3.9).

    Pass `n_slots` (the wire's `CompressedAggregation.n_slots`) to verify
    the shift tables cover the sampler's index range — an out-of-range
    slot would be CLAMPED by the device gather/scatter onto the last table
    row, silently corrupting that control variate.
    """
    if n_slots is not None and sampler.n > n_slots:
        raise ValueError(
            f"sampler draws batch indices in [0, {sampler.n}) but the wire "
            f"has only n_slots={n_slots} shift rows — out-of-range slots "
            "would silently clamp onto the last row; build the aggregation "
            "with n_slots == sampler.n")
    cols = EpochIterator(sampler, start=micro_step).take(count)
    if not (cols == cols[:1]).all():
        raise ValueError(
            f"sampler mode {sampler.mode!r} gives clients different batch "
            "orders — the per-slot wire needs a shared order; use "
            "ReshuffleSampler(mode='rr_shared')")
    return cols[0]


def shared_slots_for_step(sampler: ReshuffleSampler, step: int,
                          local_steps: int = 1, *,
                          n_slots: int | None = None) -> np.ndarray:
    """(local_steps,) SHARED slot indices for full-participation train step
    `step` (every client at micro-step `step * local_steps`); see
    `shared_slots_at` for the contract."""
    return shared_slots_at(sampler, step * local_steps, local_steps,
                           n_slots=n_slots)


# ---------------------------------------------------------------------------
# simulator + dry-run entry points (the same order source, other consumers)
# ---------------------------------------------------------------------------

def run_epochs(epoch_fn, state, data, sampler: ReshuffleSampler, *,
               epochs: int, key, start_epoch: int = 0, jit: bool = True,
               callback=None):
    """Drive a simulator epoch fn (`core.algorithms.make_epoch_fn`) through
    the SAME stateless sampler as the production stream.

    Each epoch e receives `sampler.epoch_order(e)` as its `order` argument
    (replacing the on-device draw) and the key `fold_in(key, e)`, so the
    trajectory is a pure function of `(state, data, sampler, key, e)`:
    checkpointing `state` after epoch e-1 and calling again with
    `start_epoch=e` bit-reproduces the uninterrupted run.

    `callback(e, state)` fires after each epoch (metric tracking for the
    paper-table experiments) — it does not influence the trajectory.
    """
    import jax
    import jax.numpy as jnp

    ep = jax.jit(epoch_fn) if jit else epoch_fn
    for e in range(start_epoch, start_epoch + epochs):
        order = jnp.asarray(sampler.epoch_order(e))
        state = ep(state, data, jax.random.fold_in(key, e), order)
        if callback is not None:
            callback(e, state)
    return state


def abstract_stream_batch(batch_struct, local_steps: int = 1):
    """ShapeDtypeStructs of the stream's emitted batch, given one round's
    per-client-major batch structs (leading dim m*b): the dry-run's view of
    the batch contract (leading dim becomes m * local_steps * b)."""
    import jax

    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            (s.shape[0] * local_steps,) + s.shape[1:], s.dtype),
        batch_struct)
