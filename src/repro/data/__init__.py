from repro.data.logreg import (
    LogRegProblem,
    make_federated_logreg,
    logreg_constants,
)
from repro.data.reshuffle import ReshuffleSampler
from repro.data.tokens import synthetic_token_batches

__all__ = [
    "LogRegProblem",
    "make_federated_logreg",
    "logreg_constants",
    "ReshuffleSampler",
    "synthetic_token_batches",
]
