from repro.data.logreg import (
    LogRegProblem,
    make_federated_logreg,
    logreg_constants,
)
from repro.data.paging import ClientDataStore, LookaheadPager
from repro.data.pipeline import (
    BatchStream,
    CohortStream,
    EpochIterator,
    FleetRound,
    abstract_stream_batch,
    make_batch_stream,
    normalize_client_data,
    run_epochs,
)
from repro.data.reshuffle import ReshuffleSampler
from repro.data.tokens import synthetic_token_batches

__all__ = [
    "BatchStream",
    "ClientDataStore",
    "CohortStream",
    "EpochIterator",
    "FleetRound",
    "LogRegProblem",
    "LookaheadPager",
    "ReshuffleSampler",
    "abstract_stream_batch",
    "logreg_constants",
    "make_batch_stream",
    "make_federated_logreg",
    "normalize_client_data",
    "run_epochs",
    "synthetic_token_batches",
]
