"""Out-of-core fleet data: on-disk per-client datasets + deterministic
lookahead paging (DESIGN.md §3.11).

`CohortStream` historically materialized the whole population's datasets as
one host-RAM client-stacked tree — fine at 10^3 clients, fatal at the 10^6+
populations the fleet targets. But the cohort walk is *stateless and pure
in `(seed, round)`* (`CohortSampler.cohort_for_round`), so round t+1's
cohort — and therefore exactly which data rows and which `ClientStateStore`
shift rows it needs — is known while round t's jitted step runs. This
module exploits that:

``ClientDataStore``
    Population datasets on disk as per-client rows, sharded along the
    client axis with the same `shard_size`-row memmap layout discipline as
    `fleet.store.ClientStateStore`: one `{leaf}.{shard}.dat` file per leaf
    per shard plus a `data_store.json` spec. A shard file is created only
    when rows are first written; an absent shard reads as zeros — the
    file-granularity analogue of memmap zero pages, so a `create`d
    population costs no disk until touched. `from_stacked` converts the
    in-RAM client-stacked tree; `open` attaches to an existing layout;
    `spec()` feeds checkpoint-manifest validation so a resume refuses a
    mismatched layout.

``LookaheadPager``
    The deterministic prefetcher: a bounded LRU page cache over
    `(leaf, shard)` pages with an `advance_window(round, cohort_sampler)`
    hook the per-cohort stream calls from its `_PrefetchStream` worker
    thread after assembling round t — it loads exactly the pages rounds
    t+1..t+lookahead will touch, drops resident pages outside that window,
    and (when a store is bound) warms the next cohort's shift rows. The
    pager's `views` expose the identical `views[name][c] -> (n, b, ...)`
    indexing contract `_assemble_rows` already consumes, so paged batches
    are bit-identical to the in-RAM path by construction. `gather`/
    `scatter` delegate to the bound `ClientStateStore` (or its chaos
    `FaultyStore` wrapper), letting the fleet drivers route all paged I/O
    through one object and keep `_io_retry` coverage.

Thread model: the page cache is touched only by whoever assembles batches —
with prefetch enabled that is the single `_PrefetchStream` worker thread,
and exactly one build is ever in flight, so no locking is needed. Stats
reads (`resident_nbytes`, hit/miss counters) from the calling thread are
racy-but-monotonic diagnostics, never correctness inputs.
"""
from __future__ import annotations

import json
import os
from collections import OrderedDict
from typing import Any, Mapping

import numpy as np

from repro import telemetry

_SPEC_FILE = "data_store.json"


def _np_dtype(dtype) -> np.dtype:
    """Portable numpy dtype for a (possibly jax) dtype; bf16 via ml_dtypes."""
    name = str(np.dtype(dtype)) if not hasattr(dtype, "name") else dtype.name
    if name == "bfloat16":
        import ml_dtypes

        return np.dtype(ml_dtypes.bfloat16)
    return np.dtype(name)


def _probe_writable(path: str) -> None:
    """Fail fast with a readable error instead of deep inside np.memmap when
    the path is unwritable (read-only mount, permission hole, a FILE where
    the dir should be, ...) — same probe as `ClientStateStore.create`."""
    try:
        os.makedirs(path, exist_ok=True)
        probe = os.path.join(path, ".write_probe")
        with open(probe, "wb"):
            pass
        os.unlink(probe)
    except OSError as e:
        raise OSError(
            f"data-store path {path!r} is not a writable directory ({e}) — "
            "pass a location the pager can memmap per-client rows under"
        ) from e


class ClientDataStore:
    """Per-client dataset rows on disk, sharded along the client axis.

    Every leaf holds `(n, b, ...)` rows per client (client c's rows live in
    shard `c // shard_size` at local row `c % shard_size`), mirroring the
    client-stacked `(C, n, b, ...)` tree `normalize_client_data` accepts —
    uniform n only; uneven per-client sizes stay an in-RAM niche. Reads
    come back as materialized numpy copies (one page = one leaf's shard),
    so resident memory is whatever the caller keeps, not mmap guesswork.
    """

    def __init__(self, *, path: str, population: int, shard_size: int,
                 leaves: dict[str, tuple[tuple[int, ...], np.dtype]],
                 writable: bool):
        self.path = path
        self.population = int(population)
        self.shard_size = int(shard_size)
        self._leaves = dict(leaves)  # name -> (per-client shape, dtype)
        self._writable = bool(writable)

    # -- construction -------------------------------------------------------

    @classmethod
    def create(cls, path: str, population: int,
               leaf_structs: Mapping[str, Any], *,
               shard_size: int = 4096) -> "ClientDataStore":
        """Lay out an (all-zeros) population store under `path`.

        `leaf_structs` maps leaf name -> array or ShapeDtypeStruct whose
        shape is ONE client's rows `(n, b, ...)`. No shard files are
        written — absent shards read as zeros — so a 10^6-client store
        costs a spec file until rows arrive via `write_rows`.
        """
        if population < 1:
            raise ValueError(f"population={population}")
        if shard_size < 1:
            raise ValueError(f"shard_size={shard_size}")
        if not leaf_structs:
            raise ValueError("leaf_structs must be a non-empty mapping")
        leaves = {}
        for name, s in leaf_structs.items():
            shape = tuple(int(d) for d in s.shape)
            if len(shape) < 2:
                raise ValueError(
                    f"leaf {name!r}: per-client rows must be (n, b, ...), "
                    f"got shape {shape}")
            leaves[name] = (shape, _np_dtype(s.dtype))
        _probe_writable(path)
        spec = {"version": 1, "population": int(population),
                "shard_size": int(shard_size),
                "leaves": {name: {"shape": list(shape), "dtype": dt.name}
                           for name, (shape, dt) in leaves.items()}}
        tmp = os.path.join(path, _SPEC_FILE + ".tmp")
        with open(tmp, "w") as f:
            json.dump(spec, f, indent=1, sort_keys=True)
        os.replace(tmp, os.path.join(path, _SPEC_FILE))
        return cls(path=path, population=population, shard_size=shard_size,
                   leaves=leaves, writable=True)

    @classmethod
    def from_stacked(cls, path: str, data: Mapping[str, Any], *,
                     shard_size: int = 4096) -> "ClientDataStore":
        """Convert an in-RAM client-stacked tree (`{name: (C, n, b, ...)}`,
        the exact thing `CohortStream(data=...)` takes) into an on-disk
        store. Uniform per-client n only."""
        if not isinstance(data, Mapping) or not data:
            raise ValueError("data must be a non-empty mapping of named "
                             "client-stacked (C, n, b, ...) leaves")
        arrays = {}
        pop = None
        for name, leaf in data.items():
            arr = np.asarray(leaf)
            if arr.ndim < 3:
                raise ValueError(
                    f"leaf {name!r}: expected client-stacked (C, n, b, ...) "
                    f"rows, got shape {arr.shape}")
            if pop is None:
                pop = arr.shape[0]
            elif arr.shape[0] != pop:
                raise ValueError(
                    f"leaf {name!r} holds {arr.shape[0]} clients, "
                    f"others hold {pop}")
            arrays[name] = arr
        structs = {name: arr[0] for name, arr in arrays.items()}
        store = cls.create(path, pop, structs, shard_size=shard_size)
        store.write_rows(np.arange(pop, dtype=np.int64), arrays)
        return store

    @classmethod
    def open(cls, path: str, *, mode: str = "r") -> "ClientDataStore":
        """Attach to an existing layout. mode 'r' (read-only) or 'r+'."""
        if mode not in ("r", "r+"):
            raise ValueError(f"mode={mode!r}; options: 'r', 'r+'")
        fn = os.path.join(path, _SPEC_FILE)
        try:
            with open(fn) as f:
                spec = json.load(f)
        except OSError as e:
            raise OSError(
                f"{path!r} is not a client data store (no {_SPEC_FILE}: "
                f"{e}) — build one with ClientDataStore.from_stacked/"
                "create first") from e
        leaves = {name: (tuple(l["shape"]), np.dtype(l["dtype"]))
                  for name, l in spec["leaves"].items()}
        return cls(path=path, population=spec["population"],
                   shard_size=spec["shard_size"], leaves=leaves,
                   writable=(mode == "r+"))

    # -- layout --------------------------------------------------------------

    @property
    def leaf_names(self) -> list[str]:
        return list(self._leaves)

    @property
    def num_shards(self) -> int:
        return -(-self.population // self.shard_size)

    @property
    def n_batches(self) -> int:
        """Usable batches per client: min over leaves of their n."""
        return min(shape[0] for shape, _ in self._leaves.values())

    def shard_rows(self, s: int) -> int:
        lo = s * self.shard_size
        if not 0 <= lo < self.population:
            raise IndexError(f"shard {s} outside [0, {self.num_shards})")
        return min(self.shard_size, self.population - lo)

    def page_nbytes(self, name: str) -> int:
        """Bytes of one FULL shard page of `name` (the last shard may be
        smaller)."""
        shape, dt = self._leaves[name]
        return self.shard_size * int(np.prod(shape)) * dt.itemsize

    @staticmethod
    def estimate_nbytes(leaf_structs: Mapping[str, Any],
                        population: int) -> int:
        """Disk bytes a fully-written store would hold (spec file aside) —
        the dry-run's paged-fleet sizing number."""
        return population * sum(
            int(np.prod(s.shape)) * _np_dtype(s.dtype).itemsize
            for s in leaf_structs.values())

    @property
    def nbytes(self) -> int:
        """Fully-written size of THIS store's layout."""
        return self.population * sum(
            int(np.prod(shape)) * dt.itemsize
            for shape, dt in self._leaves.values())

    def spec(self) -> dict:
        """JSON-serializable layout description — recorded in fleet
        checkpoints so a resume refuses a mismatched data-store layout."""
        return {"population": self.population,
                "shard_size": self.shard_size,
                "leaves": {name: {"shape": list(shape), "dtype": dt.name}
                           for name, (shape, dt) in self._leaves.items()}}

    # -- pages ---------------------------------------------------------------

    def _shard_path(self, name: str, s: int) -> str:
        return os.path.join(self.path, f"{name.replace('/', '.')}.{s}.dat")

    def page(self, name: str, s: int) -> np.ndarray:
        """Materialize shard `s` of leaf `name` as a `(rows, n, b, ...)`
        RAM copy; absent shard files read as zeros."""
        shape, dt = self._leaves[name]
        rows = self.shard_rows(s)
        fn = self._shard_path(name, s)
        if not os.path.exists(fn):
            return np.zeros((rows,) + shape, dt)
        mm = np.memmap(fn, dtype=dt, mode="r", shape=(rows,) + shape)
        out = np.array(mm)
        del mm
        return out

    def write_rows(self, ids: np.ndarray,
                   values: Mapping[str, np.ndarray]) -> None:
        """Write per-client rows: `values[name][i]` becomes client
        `ids[i]`'s rows. Creates shard files on first touch (incremental
        population ingest; `from_stacked` is one call of this)."""
        if not self._writable:
            raise OSError(f"store at {self.path!r} was opened read-only")
        ids = np.asarray(ids, np.int64)
        if ids.size and (ids.min() < 0 or ids.max() >= self.population):
            raise ValueError(f"client ids outside [0, {self.population})")
        for name, vals in values.items():
            shape, dt = self._leaves[name]
            arr = np.asarray(vals)
            if arr.shape != (ids.size,) + shape:
                raise ValueError(
                    f"leaf {name!r}: rows shape {arr.shape} != "
                    f"{(ids.size,) + shape}")
            sid = ids // self.shard_size
            for s in np.unique(sid):
                rows = self.shard_rows(int(s))
                fn = self._shard_path(name, int(s))
                mode = "r+" if os.path.exists(fn) else "w+"
                mm = np.memmap(fn, dtype=dt, mode=mode,
                               shape=(rows,) + shape)
                sel = sid == s
                mm[ids[sel] - int(s) * self.shard_size] = (
                    arr[sel].astype(dt, copy=False))
                mm.flush()
                del mm


class _PagedLeafView:
    """The `views[name][c] -> (n, b, ...)` indexing contract of
    `normalize_client_data`, backed by the pager's page cache — so
    `_assemble_rows` consumes paged and in-RAM data identically."""

    def __init__(self, pager: "LookaheadPager", name: str):
        self._pager = pager
        self._name = name

    def __getitem__(self, client: int) -> np.ndarray:
        pager = self._pager
        s, r = divmod(int(client), pager.data.shard_size)
        return pager._page(self._name, s)[r]


class LookaheadPager:
    """Bounded-resident page cache with closed-form cohort lookahead.

    lookahead     rounds of prefetch window (>= 0); `advance_window(t, cs)`
                  keeps exactly the pages rounds t+1..t+lookahead touch and
                  evicts the rest — the steady-state resident set is
                  bounded by `resident_bound_nbytes(cohort_size)`
                  regardless of population;
    max_resident  optional hard page-count cap (LRU eviction) for
                  cold random access outside the windowed walk;
    state         optional `ClientStateStore` (or `FaultyStore` wrapper):
                  `gather`/`scatter` delegate to it so drivers route all
                  paged I/O here, and `advance_window` warms the next
                  cohort's shift rows via `state.touch` (uninjected — a
                  prefetch hint must not perturb the chaos I/O schedule).
    """

    def __init__(self, data: ClientDataStore, *, lookahead: int = 1,
                 max_resident: int | None = None, state=None):
        if lookahead < 0:
            raise ValueError(f"lookahead={lookahead}")
        if max_resident is not None and max_resident < 1:
            raise ValueError(f"max_resident={max_resident}")
        self.data = data
        self.lookahead = int(lookahead)
        self.max_resident = max_resident
        self.state = state
        self._pages: OrderedDict[tuple[str, int], np.ndarray] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.state_bytes_warmed = 0
        self.views = {name: _PagedLeafView(self, name)
                      for name in data.leaf_names}

    # -- the CohortStream-facing data contract -------------------------------

    @property
    def population(self) -> int:
        return self.data.population

    @property
    def n_batches(self) -> int:
        return self.data.n_batches

    def _page(self, name: str, s: int) -> np.ndarray:
        key = (name, int(s))
        page = self._pages.get(key)
        if page is not None:
            self._pages.move_to_end(key)
            self.hits += 1
            return page
        self.misses += 1
        page = self.data.page(name, s)
        self._pages[key] = page
        if self.max_resident is not None:
            while len(self._pages) > self.max_resident:
                self._pages.popitem(last=False)
                self.evictions += 1
        return page

    def pages_for_round(self, rnd: int, cohort_sampler) -> set:
        """The `(leaf, shard)` pages round `rnd` will touch — closed form
        via `cohort_for_round`."""
        cohort = cohort_sampler.cohort_for_round(rnd)
        shards = np.unique(np.asarray(cohort, np.int64) // self.data.shard_size)
        return {(name, int(s)) for name in self.data.leaf_names
                for s in shards}

    def advance_window(self, done_round: int, cohort_sampler) -> None:
        """Called (from the prefetch worker) after round `done_round`'s
        batch is assembled: evict pages outside the lookahead window, then
        load the window's pages so round t+1 assembles from cache while
        round t's step runs. Also warms the next cohort's shift rows on
        the bound store."""
        with telemetry.span("page_in", round=done_round + 1):
            keep = set()
            for r in range(done_round + 1, done_round + 1 + self.lookahead):
                keep |= self.pages_for_round(r, cohort_sampler)
            for key in [k for k in self._pages if k not in keep]:
                del self._pages[key]
                self.evictions += 1
            for name, s in sorted(keep):
                self._page(name, s)
            if self.state is not None and self.lookahead > 0:
                touch = getattr(self.state, "touch", None)
                if touch is not None:
                    nxt = cohort_sampler.cohort_for_round(done_round + 1)
                    self.state_bytes_warmed += touch(nxt)
        if telemetry.enabled():
            # cumulative residency/hit-rate snapshot after the window move
            for name, v in self.stats().items():
                telemetry.counter(f"pager.{name}", int(v),
                                  round=done_round + 1)

    # -- store I/O routing (drivers call through the pager) ------------------

    def bind_store(self, store) -> None:
        """Late-bind the state store the drivers route gather/scatter
        through — bound AFTER any chaos `FaultyStore` wrap so `_io_retry`
        covers paged reads on the same injection schedule."""
        self.state = store

    def gather(self, cohort):
        if self.state is None:
            raise RuntimeError(
                "pager has no bound ClientStateStore — call bind_store "
                "(the fleet drivers do this) before gather/scatter")
        return self.state.gather(cohort)

    def scatter(self, cohort, updated):
        if self.state is None:
            raise RuntimeError(
                "pager has no bound ClientStateStore — call bind_store "
                "(the fleet drivers do this) before gather/scatter")
        return self.state.scatter(cohort, updated)

    # -- diagnostics ---------------------------------------------------------

    def resident_pages(self) -> int:
        return len(self._pages)

    def resident_nbytes(self) -> int:
        return sum(p.nbytes for p in self._pages.values())

    def resident_bound_nbytes(self, cohort_size: int) -> int:
        """Worst-case steady-state resident bytes for a windowed walk:
        (lookahead + 1) rounds' pages (the round being assembled plus the
        prefetched window), each round touching at most min(num_shards,
        cohort_size) pages per leaf."""
        pages_per_round = min(self.data.num_shards, int(cohort_size))
        per_round = sum(self.data.page_nbytes(name)
                        for name in self.data.leaf_names) * pages_per_round
        return (self.lookahead + 1) * per_round

    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions,
                "resident_pages": self.resident_pages(),
                "resident_nbytes": self.resident_nbytes(),
                "state_bytes_warmed": self.state_bytes_warmed}
