"""Random-reshuffling batcher for production training.

RR is a *data pipeline* property: once per epoch every client permutes its
local dataset and walks it in order. On a pod the "client" is a data-parallel
rank; this sampler produces, per epoch, the permutation matrix that the input
pipeline uses to order host-side batches. It is deliberately host-side
(numpy) — permutations never need to be on device, and keeping them out of
the jit'd step preserves identical lowering between RR and with-replacement
runs (see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import numpy as np


class ReshuffleSampler:
    """Yields per-epoch, per-client batch orders.

    mode:
      'rr'  — fresh independent permutation per client per epoch (Q-RR,
              Q-NASTYA, DIANA-NASTYA in the paper's experiments)
      'rr_once' — single permutation sampled at epoch 0 and reused (Shuffle-
              Once; the paper uses this for DIANA-RR so shift slots stay
              aligned with datapoints)
      'wr'  — with-replacement sampling (QSGD/DIANA/FedAvg baselines)
    """

    def __init__(self, num_clients: int, num_batches: int, *, mode: str = "rr",
                 seed: int = 0):
        if mode not in ("rr", "rr_once", "wr"):
            raise ValueError(mode)
        self.m = num_clients
        self.n = num_batches
        self.mode = mode
        self._rng = np.random.default_rng(seed)
        self._fixed: np.ndarray | None = None

    def epoch_order(self, epoch: int) -> np.ndarray:
        """(M, n) int32 array of batch indices for this epoch."""
        del epoch
        if self.mode == "wr":
            return self._rng.integers(0, self.n, size=(self.m, self.n)).astype(np.int32)
        if self.mode == "rr_once":
            if self._fixed is None:
                self._fixed = self._permutations()
            return self._fixed
        return self._permutations()

    def _permutations(self) -> np.ndarray:
        return np.stack(
            [self._rng.permutation(self.n) for _ in range(self.m)]
        ).astype(np.int32)
