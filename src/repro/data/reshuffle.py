"""Random-reshuffling batcher for production training.

RR is a *data pipeline* property: once per epoch every client permutes its
local dataset and walks it in order. On a pod the "client" is a data-parallel
rank; this sampler produces, per epoch, the permutation matrix that the input
pipeline uses to order host-side batches. It is deliberately host-side
(numpy) — permutations never need to be on device, and keeping them out of
the jit'd step preserves identical lowering between RR and with-replacement
runs (see DESIGN.md §Arch-applicability).

The sampler is STATELESS: `epoch_order(e)` derives its generator by folding
the epoch into the seed (`np.random.default_rng((seed, e))`), so the same
call always returns the same order. That idempotence is what makes the
pipeline resumable from any `(epoch, step)` cursor and is what rules out the
seed-era bug where a mutating RNG handed every micro-batch a fresh
permutation (near-with-replacement sampling in an "RR" run); see
DESIGN.md §3.7.
"""
from __future__ import annotations

import numpy as np


class ReshuffleSampler:
    """Yields per-epoch, per-client batch orders.

    mode:
      'rr'  — fresh independent permutation per client per epoch (Q-RR,
              Q-NASTYA, DIANA-NASTYA in the paper's experiments)
      'rr_once' — single permutation sampled at epoch 0 and reused (Shuffle-
              Once; the paper uses this for DIANA-RR so shift slots stay
              aligned with datapoints)
      'rr_shared' — fresh permutation per epoch, SHARED by every client
              (synchronized reshuffling). This is the production DIANA-RR
              order: the wire's per-slot shift tables need every rank of a
              wire level on the same slot each round (DESIGN.md §3.8), so
              all clients walk their (different) local datasets in the same
              index order.
      'wr'  — with-replacement sampling (QSGD/DIANA/FedAvg baselines)
    """

    def __init__(self, num_clients: int, num_batches: int, *, mode: str = "rr",
                 seed: int = 0):
        if mode not in ("rr", "rr_once", "rr_shared", "wr"):
            raise ValueError(mode)
        self.m = num_clients
        self.n = num_batches
        self.mode = mode
        self.seed = seed

    def _rng(self, epoch: int) -> np.random.Generator:
        # rr_once pins every epoch to the epoch-0 draw (Shuffle-Once): the
        # DIANA-RR shift slot i then always maps to the same datapoint.
        if self.mode == "rr_once":
            epoch = 0
        return np.random.default_rng((self.seed, epoch))

    def epoch_order(self, epoch: int) -> np.ndarray:
        """(M, n) int32 array of batch indices for epoch `epoch`.

        Idempotent: repeated calls with the same epoch return identical
        orders for all three modes.
        """
        rng = self._rng(epoch)
        if self.mode == "wr":
            return rng.integers(0, self.n, size=(self.m, self.n)).astype(np.int32)
        if self.mode == "rr_shared":
            one = rng.permutation(self.n).astype(np.int32)
            return np.broadcast_to(one, (self.m, self.n)).copy()
        return np.stack(
            [rng.permutation(self.n) for _ in range(self.m)]
        ).astype(np.int32)

    def batch_index(self, client: int, global_step: int) -> int:
        """Batch index for `client` at per-client micro-step `global_step`
        (epoch = global_step // n). Convenience for spot checks; the
        pipeline caches whole epochs via `epoch_order`."""
        epoch, i = divmod(global_step, self.n)
        return int(self.epoch_order(epoch)[client, i])

    def spec(self) -> dict:
        """JSON-serializable description (checkpointed next to the cursor so
        a resumed run can verify it is replaying the same stream)."""
        return {"m": self.m, "n": self.n, "mode": self.mode,
                "seed": self.seed}
