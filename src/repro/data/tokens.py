"""Synthetic token pipeline for LM examples and smoke tests.

Deterministic per-(client, batch) token streams with a simple Markov-ish
structure so a ~100M model actually has something learnable (loss decreases
over a few hundred steps) — pure-noise tokens would make the end-to-end
example meaningless.
"""
from __future__ import annotations

import numpy as np


def synthetic_token_batches(
    *,
    vocab: int,
    seq_len: int,
    batch: int,
    num_batches: int,
    num_clients: int = 1,
    seed: int = 0,
) -> np.ndarray:
    """(clients, num_batches, batch, seq_len+1) int32 tokens.

    Each position t+1 depends on t via a fixed random permutation with noise,
    giving ~1.5 bits of learnable structure per token. Slicing [:-1] / [1:]
    yields inputs/labels.
    """
    # analysis: allow[rng-unstructured-seed] the generator stream IS the
    # dataset's identity — pinned bit-exact to the seed-era draws (loss
    # trajectories across the suite and benches depend on it)
    rng = np.random.default_rng(seed)
    succ = rng.permutation(vocab)  # deterministic successor table
    out = np.empty((num_clients, num_batches, batch, seq_len + 1), np.int32)
    x = rng.integers(0, vocab, size=(num_clients, num_batches, batch))
    for t in range(seq_len + 1):
        out[..., t] = x
        noise = rng.random(x.shape) < 0.3
        x = np.where(noise, rng.integers(0, vocab, size=x.shape), succ[x])
    return out


def lm_inputs_labels(tokens: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    return tokens[..., :-1], tokens[..., 1:]
