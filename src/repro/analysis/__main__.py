"""CLI driver: ``python -m repro.analysis --lint --graph [--baseline FILE]``.

Exit status is the contract CI gates on: 0 iff every finding is covered by
the checked-in baseline (which the repo ships EMPTY — suppressions need a
written reason, and stale ones are themselves findings).

``--lint`` runs without importing jax. ``--graph`` imports jax lazily,
*after* forcing 8 host devices via XLA_FLAGS, so the census can trace
multi-pod meshes on any machine.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path

from repro.analysis.findings import Finding, apply_baseline, load_baseline
from repro.analysis.lint import lint_paths, rule_catalog

_DEFAULT_BASELINE = "analysis_baseline.json"


def _repo_root() -> Path:
    # src/repro/analysis/__main__.py -> repo root is three parents above src/
    return Path(__file__).resolve().parents[3]


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro invariant analysis: AST lint + jaxpr wire census")
    parser.add_argument("--lint", action="store_true",
                        help="run the layer-1 AST checkers over src/repro")
    parser.add_argument("--graph", action="store_true",
                        help="run the layer-2 jaxpr census (traces the train "
                             "steps; no device execution)")
    parser.add_argument("--baseline", default=None,
                        help="suppression baseline JSON (default: "
                             f"{_DEFAULT_BASELINE} at the repo root, if "
                             "present)")
    parser.add_argument("--json", action="store_true",
                        help="emit findings as JSON records")
    parser.add_argument("--rules", action="store_true",
                        help="print the rule catalog and exit")
    parser.add_argument("paths", nargs="*", type=Path,
                        help="files/dirs to lint (default: src/repro)")
    args = parser.parse_args(argv)

    if args.rules:
        for rule, doc in sorted(rule_catalog().items()):
            print(f"{rule:26s} {doc}")
        return 0

    if not (args.lint or args.graph):
        parser.error("nothing to do: pass --lint and/or --graph")

    root = _repo_root()
    findings: list[Finding] = []

    if args.lint:
        paths = args.paths or [root / "src" / "repro"]
        findings.extend(lint_paths(paths, repo_root=root))

    if args.graph:
        # Force a fixed 8-device host topology BEFORE jax initializes, so
        # the census meshes are constructible on a 1-CPU CI runner.
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count=8".strip())
        from repro.analysis import graph

        findings.extend(graph.run_census())

    baseline_path = args.baseline
    if baseline_path is None:
        default = root / _DEFAULT_BASELINE
        baseline_path = str(default) if default.exists() else None
    if baseline_path is not None:
        try:
            entries = load_baseline(baseline_path)
        except (ValueError, OSError, json.JSONDecodeError) as e:
            findings.append(Finding(
                file=str(baseline_path), line=0, rule="bad-baseline",
                message=str(e)))
        else:
            findings = apply_baseline(findings, entries,
                                      baseline_file=str(baseline_path))

    if args.json:
        print(json.dumps([f.to_json() for f in findings], indent=2))
    else:
        for f in findings:
            print(f)

    if findings:
        n = len(findings)
        print(f"\n{n} finding{'s' if n != 1 else ''} "
              "(suppress via inline allow with rationale, or the baseline)",
              file=sys.stderr)
        return 1
    mode = "+".join(m for m, on in [("lint", args.lint),
                                    ("graph", args.graph)] if on)
    print(f"analysis clean ({mode})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
