"""Layer-2 jaxpr census: trace the real train steps, audit the wire
(DESIGN.md §3.12).

The AST linter can't see what a program compiles TO. This layer traces
`launch.steps.make_train_step` for every wire method on a flat and a 2-pod
mesh — `jit(...).trace` / `.lower` only, no device execution — and checks
the compiled artifact against the repo's analytic claims:

collective census
    Inside the fully-manual shard_map wire regions only EXPLICIT collectives
    exist (GSPMD inserts its comms later, invisibly to the jaxpr), so the
    collective equations ARE the wire. Per level (axis names distinguish the
    intra-pod exchange over "data" from the inter-pod one over "pod") the
    f32/bf16 wire must show exactly L psums — one per parameter leaf — and
    their payload bytes must equal
    `CompressedAggregation.wire_bytes_per_round` exactly. The packed wires
    (wire_dtype 'packed8'/'packed4', DESIGN.md §3.13) have NO psums on the
    wire axes: the census must instead show exactly 2L all_gathers per level
    (the byte slab + the f32 scale sideband, per leaf) whose per-rank
    operand bytes sum to the same analytic number — all_gather payload is
    what each rank CONTRIBUTES (the operand), matching the accounting. The
    CLI runs TP=1 meshes ((4,1) and (2,2,1)): per-device jaxpr payloads
    divide the lane (cols) dimension by the model-axis size, while the
    analytic model counts a client's full contribution, so byte EQUALITY
    holds only at TP=1 (the f32-lane caveat: on TP>1 meshes compare counts,
    or scale by the model-axis factor — tests/test_analysis.py does the
    former).

dtype audit
    No float64 anywhere in the traced program (a silent x64 promotion would
    double every wire payload), and the output state's leaf dtypes must
    equal the input state's (a promotion inside the step would break
    donation silently before it broke numerics).

donation audit
    The step donates its input state (`donate_argnums=(0,)`); every state
    leaf must actually alias an output buffer in the lowered StableHLO
    (`tf.aliasing_output`). A dtype/shape mismatch makes XLA silently drop
    the alias and double peak memory.

elastic invariant
    The elastic step's participation-weights vector must be a live runtime
    input of the jaxpr — consumed by the program, never constant-folded —
    which is the single-compile guarantee: cohorts can shrink/grow without
    retracing.

Everything here must be importable only AFTER XLA_FLAGS forces >= 8 host
devices (the CLI driver does this; tests inherit conftest's env).
"""
from __future__ import annotations

import numpy as np

from repro.analysis.findings import Finding

RULES = {
    "census-collective-count":
        "collective count per wire level != the wire model (one psum per "
        "leaf; two all_gathers per leaf on packed wires)",
    "census-collective-bytes":
        "collective payload bytes != the analytic wire_bytes_per_round",
    "census-unexpected-collective":
        "a collective over axes no wire level owns (e.g. 'model'), or of a "
        "kind the wire_dtype must not emit (psum on a packed wire)",
    "census-dtype-promotion":
        "float64 in the traced step, or state dtype changed in flight",
    "census-donation":
        "a donated state buffer is not aliased in the lowered program",
    "census-elastic-invariant":
        "the elastic weights vector is not a live jaxpr input",
    "census-telemetry-identity":
        "installing a telemetry sink changed the traced step's jaxpr — "
        "instrumentation leaked into the compiled program",
}

# Census points: every wire method on both topologies. TP=1 so payload
# bytes match the analytic model exactly (see module docstring).
CENSUS_METHODS = ("q", "diana", "diana_rr", "ef")
CENSUS_MESHES = (
    ("flat", (4, 1), ("data", "model")),
    ("two_pod", (2, 2, 1), ("pod", "data", "model")),
)
# Non-f32 transports audited on top: packed8 on both topologies (the
# all-gather wire replaces every psum), packed4 + bf16 spot-checked flat.
CENSUS_PACKED_METHODS = ("q", "diana_rr")
CENSUS_EXTRA_DTYPES = ("packed4", "bf16")


def _iter_jaxprs(jaxpr):
    """The jaxpr and every sub-jaxpr nested in its equation params."""
    yield jaxpr
    for eqn in jaxpr.eqns:
        for v in eqn.params.values():
            for vv in v if isinstance(v, (list, tuple)) else (v,):
                inner = getattr(vv, "jaxpr", vv)
                if hasattr(inner, "eqns"):
                    yield from _iter_jaxprs(inner)


def collective_census(jaxpr, primitive: str = "psum"
                      ) -> dict[tuple[str, ...], tuple[int, int]]:
    """{axes -> (eqn count, payload bytes)} for one collective primitive
    over all nested jaxprs. Payload is the per-rank OPERAND bytes — for
    psum the reduced buffer, for all_gather what this rank contributes
    (the gathered result is axis_size times larger but only the operand
    crosses the wire once per rank)."""
    out: dict[tuple[str, ...], tuple[int, int]] = {}
    for jx in _iter_jaxprs(jaxpr):
        for eqn in jx.eqns:
            if eqn.primitive.name != primitive:
                continue
            axes = eqn.params.get("axes", eqn.params.get("axis_name", ()))
            axes = (axes,) if isinstance(axes, str) else tuple(axes)
            nbytes = sum(
                int(np.prod(v.aval.shape)) * v.aval.dtype.itemsize
                for v in eqn.invars)
            c, b = out.get(axes, (0, 0))
            out[axes] = (c + 1, b + nbytes)
    return out


def has_float64(jaxpr) -> bool:
    for jx in _iter_jaxprs(jaxpr):
        for eqn in jx.eqns:
            for v in list(eqn.invars) + list(eqn.outvars):
                aval = getattr(v, "aval", None)
                if aval is not None and getattr(aval, "dtype", None) is not None:
                    if str(aval.dtype) == "float64":
                        return True
    return False


def _trace_step(cfg, mesh, method: str, *, elastic: bool = False,
                fraction: float = 0.25, wire_dtype: str = "f32"):
    """Build + trace one train step; returns everything the checks need."""
    import jax
    import jax.numpy as jnp

    from repro.core.dist import CompressedAggregation
    from repro.launch import compat, steps
    from repro.launch.mesh import num_clients

    agg0 = CompressedAggregation(method=method, wire="shared",
                                 fraction=fraction,
                                 shift_dtype=jnp.float32,
                                 wire_dtype=wire_dtype)
    jitted, abstract, _, _ = steps.make_train_step(
        cfg, mesh, agg=agg0, remat=False, seq_shard=False, elastic=elastic)
    agg = steps.configure_agg(agg0, mesh, 1)
    m = num_clients(mesh)
    batch = {"tokens": jax.ShapeDtypeStruct((2 * m, cfg.max_seq + 1),
                                            jnp.int32)}
    # round-key argument: abstract typed-key scalar (eval_shape never
    # materializes a key, so this is not a root-key construction site)
    key = jax.ShapeDtypeStruct((), jax.eval_shape(jax.random.key, 0).dtype)
    extra = []
    if agg.rule.slotted:
        extra.append(jax.ShapeDtypeStruct((1,), jnp.int32))
    if elastic:
        extra.append(jax.ShapeDtypeStruct((m,), jnp.float32))
    with compat.set_mesh(mesh):
        traced = jitted.trace(abstract, batch, key, *extra)
        lowered = jitted.lower(abstract, batch, key, *extra)
    return traced, lowered, abstract, agg


def check_step(cfg, mesh, method: str, label: str, *,
               wire_dtype: str = "f32") -> list[Finding]:
    """All census checks for one (mesh, method, wire_dtype) point."""
    import jax

    traced, lowered, abstract, agg = _trace_step(cfg, mesh, method,
                                                 wire_dtype=wire_dtype)
    where = f"jaxpr:{label}/{method}"
    if wire_dtype != "f32":
        where += f"/{wire_dtype}"
    out: list[Finding] = []
    jaxpr = traced.jaxpr.jaxpr

    packed = wire_dtype in ("packed8", "packed4")
    wire_prim = "all_gather" if packed else "psum"
    levels = collective_census(jaxpr, wire_prim)
    wire = agg.wire_bytes_per_round(abstract.params)
    n_leaves = len(jax.tree.leaves(abstract.params))
    # packed wires move two gathers per leaf: the byte slab + the f32
    # per-row scale sideband; psum wires one reduction per leaf
    per_leaf = 2 if packed else 1
    expected = {}
    if agg.client_axes:
        expected[tuple(agg.client_axes)] = wire["intra_pod"]
    if agg.pod_axes and agg.pod_size > 1:
        expected[tuple(agg.pod_axes)] = wire["inter_pod"]

    for axes, (count, nbytes) in sorted(levels.items()):
        if axes not in expected:
            out.append(Finding(
                file=where, line=0, rule="census-unexpected-collective",
                message=f"{wire_prim} over axes {axes} — no wire level owns "
                        "these axes (GSPMD comms never appear in the jaxpr, "
                        "so this is an explicit stray collective)"))
            continue
        if count != per_leaf * n_leaves:
            out.append(Finding(
                file=where, line=0, rule="census-collective-count",
                message=f"{count} {wire_prim}s over {axes}, expected "
                        f"{per_leaf * n_leaves} ({per_leaf} per parameter "
                        "leaf)"))
        if nbytes != expected[axes]:
            out.append(Finding(
                file=where, line=0, rule="census-collective-bytes",
                message=f"{wire_prim} payload over {axes} is {nbytes} "
                        f"B/rank, analytic wire model says {expected[axes]} "
                        "B — the wire and its accounting have diverged"))
    for axes in expected:
        if axes not in levels:
            out.append(Finding(
                file=where, line=0, rule="census-collective-count",
                message=f"no {wire_prim}s over {axes} — an expected wire "
                        "level is missing from the compiled step"))
    # the OTHER wire primitive must not appear at all: a psum on a packed
    # wire would sum per-rank byte lattices with different scales (wrong);
    # an all_gather on a psum wire is an unaccounted dense collective
    other = "psum" if packed else "all_gather"
    for axes, (count, _) in sorted(collective_census(jaxpr, other).items()):
        out.append(Finding(
            file=where, line=0, rule="census-unexpected-collective",
            message=f"{count} {other}(s) over {axes} — the {wire_dtype} "
                    f"wire must move only {wire_prim}s"))

    if has_float64(jaxpr):
        out.append(Finding(
            file=where, line=0, rule="census-dtype-promotion",
            message="float64 appears in the traced step — a silent x64 "
                    "promotion doubles wire payloads"))
    in_dtypes = [str(x.dtype) for x in jax.tree.leaves(abstract)]
    out_state = traced.out_info[0]
    out_dtypes = [str(x.dtype) for x in jax.tree.leaves(out_state)]
    if in_dtypes != out_dtypes:
        out.append(Finding(
            file=where, line=0, rule="census-dtype-promotion",
            message="output state dtypes differ from the input state — "
                    "an in-flight promotion breaks donation silently"))

    n_state = len(jax.tree.leaves(abstract))
    aliased = lowered.as_text().count("tf.aliasing_output")
    if aliased != n_state:
        out.append(Finding(
            file=where, line=0, rule="census-donation",
            message=f"{aliased} of {n_state} donated state buffers alias an "
                    "output — XLA silently dropped the rest (shape/dtype "
                    "mismatch), doubling peak memory"))
    return out


def check_elastic(cfg, mesh, label: str, method: str = "diana"
                  ) -> list[Finding]:
    """The elastic step's weights must be live runtime data in the jaxpr."""
    traced, _, _, _ = _trace_step(cfg, mesh, method, elastic=True)
    where = f"jaxpr:{label}/{method}+elastic"
    jaxpr = traced.jaxpr.jaxpr
    wvar = jaxpr.invars[-1]  # weights is the trailing argument
    used = any(wvar in eqn.invars for eqn in jaxpr.eqns)
    if not used:
        return [Finding(
            file=where, line=0, rule="census-elastic-invariant",
            message="the (m,) participation-weights input is never consumed "
                    "— it was constant-folded, so cohort changes would "
                    "retrace (the single-compile guarantee is broken)")]
    return []


def check_telemetry_identity(cfg, mesh, label: str, method: str = "diana"
                             ) -> list[Finding]:
    """The zero-cost-when-off claim, compiled form: tracing the step with
    an active in-memory `MetricsSink` must yield a byte-identical jaxpr —
    telemetry lives entirely on the host side of the jit boundary."""
    from repro import telemetry

    traced_off, _, _, _ = _trace_step(cfg, mesh, method)
    sink = telemetry.install(telemetry.MetricsSink())
    try:
        traced_on, _, _, _ = _trace_step(cfg, mesh, method)
    finally:
        telemetry.uninstall()
        sink.close()
    where = f"jaxpr:{label}/{method}+telemetry"
    if str(traced_off.jaxpr) != str(traced_on.jaxpr):
        return [Finding(
            file=where, line=0, rule="census-telemetry-identity",
            message="the traced step's jaxpr differs with a telemetry sink "
                    "installed — something threads host instrumentation "
                    "through the compiled program")]
    return []


def run_census() -> list[Finding]:
    """The CLI entry point: every method on both topologies + elastic."""
    from repro.configs import get_config, reduced
    from repro.launch.mesh import make_test_mesh

    cfg = reduced(get_config("stablelm-1.6b"), seq=16)
    findings: list[Finding] = []
    for label, shape, axes in CENSUS_MESHES:
        mesh = make_test_mesh(shape, axes)
        for method in CENSUS_METHODS:
            findings.extend(check_step(cfg, mesh, method, label))
        for method in CENSUS_PACKED_METHODS:
            findings.extend(check_step(cfg, mesh, method, label,
                                       wire_dtype="packed8"))
    flat_mesh = make_test_mesh(*CENSUS_MESHES[0][1:])
    for wire_dtype in CENSUS_EXTRA_DTYPES:
        findings.extend(check_step(cfg, flat_mesh, "diana",
                                   CENSUS_MESHES[0][0],
                                   wire_dtype=wire_dtype))
    findings.extend(check_elastic(cfg, flat_mesh, CENSUS_MESHES[0][0]))
    findings.extend(check_telemetry_identity(cfg, flat_mesh,
                                             CENSUS_MESHES[0][0]))
    return sorted(findings)
