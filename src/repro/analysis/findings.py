"""Finding records + the checked-in suppression baseline (DESIGN.md §3.12).

A finding is a structured record — rule id, repo-relative file, 1-based
line, message — never free text, so CI can gate on the exact set and the
baseline can suppress a *specific* (rule, file) pair with a recorded reason.

Suppression has two layers, both explicit and both self-checking:

inline allow annotations
    ``# analysis: allow[rule-a,rule-b] rationale`` on the offending line
    (or the ``def`` line for function-level rules). The rationale is
    REQUIRED — an allow without one is itself a finding
    (``allow-missing-rationale``), and an allow that suppresses nothing is a
    finding too (``stale-allow``), so annotations can't rot in place.

baseline file (``analysis_baseline.json``)
    ``{"suppressions": [{"rule", "file", "reason"}, ...]}`` — the escape
    hatch for findings that can't carry an inline comment (e.g. jaxpr-census
    findings, whose "file" is a trace label). Entries need a non-empty
    reason and must match at least one live finding, or they are reported as
    ``stale-baseline`` — the committed baseline is kept honest the same way
    the annotations are. The repo ships an EMPTY baseline.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path

META_RULES = {
    "allow-missing-rationale":
        "an `# analysis: allow[...]` annotation must state why",
    "stale-allow":
        "an allow annotation that no longer suppresses any finding",
    "stale-baseline":
        "a baseline suppression that no longer matches any finding",
    "bad-baseline":
        "the baseline file is malformed (not the documented schema)",
}


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One invariant violation: where, which rule, and what it means."""

    file: str  # repo-relative posix path, or a trace label (jaxpr:...)
    line: int  # 1-based; 0 for whole-file / graph-level findings
    rule: str  # kebab-case id from the rule catalog
    message: str

    def __str__(self) -> str:
        loc = f"{self.file}:{self.line}" if self.line else self.file
        return f"{loc}: [{self.rule}] {self.message}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def load_baseline(path: str | Path) -> list[dict]:
    """Parse the baseline file into its suppression entries.

    Raises ValueError on schema violations (a malformed baseline must fail
    the run loudly, not silently suppress nothing)."""
    raw = json.loads(Path(path).read_text())
    if not isinstance(raw, dict) or "suppressions" not in raw:
        raise ValueError(
            f"{path}: baseline must be an object with a 'suppressions' list")
    entries = raw["suppressions"]
    if not isinstance(entries, list):
        raise ValueError(f"{path}: 'suppressions' must be a list")
    for e in entries:
        if not (isinstance(e, dict) and e.get("rule") and e.get("file")):
            raise ValueError(
                f"{path}: each suppression needs 'rule' and 'file': {e!r}")
        if not str(e.get("reason", "")).strip():
            raise ValueError(
                f"{path}: suppression of [{e['rule']}] in {e['file']} has "
                "no 'reason' — baselined findings must be justified")
    return entries


def apply_baseline(findings: list[Finding],
                   entries: list[dict],
                   baseline_file: str = "analysis_baseline.json",
                   ) -> list[Finding]:
    """Drop findings matched by baseline entries; flag unused entries.

    A suppression matches every finding with its (rule, file) pair — line
    numbers are deliberately not part of the match so an unrelated edit
    above a baselined finding doesn't resurrect it.
    """
    out, used = [], [False] * len(entries)
    for f in findings:
        hit = False
        for i, e in enumerate(entries):
            if e["rule"] == f.rule and e["file"] == f.file:
                used[i] = hit = True
        if not hit:
            out.append(f)
    for e, u in zip(entries, used):
        if not u:
            out.append(Finding(
                file=baseline_file, line=0, rule="stale-baseline",
                message=f"suppression of [{e['rule']}] in {e['file']} "
                        "matches no finding — delete it"))
    return sorted(out)
