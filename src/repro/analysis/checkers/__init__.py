"""The invariant checkers (layer 1 of repro.analysis).

Each module exports ``check(module) -> list[Finding]`` and a ``RULES``
dict documenting its rule ids. Checkers are pure AST passes — no jax
import, no file IO — so the lint layer stays fast enough for CI and for
pre-commit use.
"""
from __future__ import annotations

from repro.analysis.checkers import args, bits, kernels, rng, trace

ALL_CHECKERS = (
    rng.check,
    args.check,
    bits.check,
    kernels.check,
    trace.check,
)

RULE_DOCS = [rng.RULES, args.RULES, bits.RULES, kernels.RULES, trace.RULES]
