"""Shared AST utilities for the invariant checkers."""
from __future__ import annotations

import ast


def dotted(node: ast.AST) -> str:
    """Best-effort dotted name of a Name/Attribute chain ('' otherwise).

    `np.random.default_rng` -> "np.random.default_rng"; anything that is not
    a pure attribute chain (calls, subscripts) truncates to ''.
    """
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return ""
    parts.append(node.id)
    return ".".join(reversed(parts))


def is_int_literal(node: ast.AST) -> bool:
    return isinstance(node, ast.Constant) and isinstance(node.value, int) \
        and not isinstance(node.value, bool)


def func_params(fn: ast.FunctionDef | ast.AsyncFunctionDef) -> list[ast.arg]:
    a = fn.args
    return list(a.posonlyargs) + list(a.args) + list(a.kwonlyargs)


def is_stub_body(fn: ast.FunctionDef | ast.AsyncFunctionDef) -> bool:
    """Docstring-only / `...` / `pass` / `raise NotImplementedError` bodies —
    protocol and ABC stubs legitimately name arguments they never read."""
    body = fn.body
    if body and isinstance(body[0], ast.Expr) and isinstance(
            body[0].value, ast.Constant) and isinstance(
            body[0].value.value, str):
        body = body[1:]
    if not body:
        return True
    if len(body) > 1:
        return False
    stmt = body[0]
    if isinstance(stmt, ast.Pass):
        return True
    if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Constant):
        return True  # `...`
    if isinstance(stmt, ast.Raise) and stmt.exc is not None:
        name = dotted(stmt.exc.func if isinstance(stmt.exc, ast.Call)
                      else stmt.exc)
        return name.endswith("NotImplementedError")
    return False
