"""Backend-only kernel imports.

``kernel-import``
    `repro.kernels.*` holds Pallas kernels plus their interpret-mode
    fallbacks; `repro.compression.backend` is the dispatch layer that picks
    between them and re-exports the stable symbols (geometry constants
    included). Any other module importing `repro.kernels.*` directly couples
    itself to one backend's internals — exactly how `core/dist.py` ended up
    reaching into `kernels.randk` for `BLOCK_ROWS` — and silently bypasses
    the dispatch policy (interpret-vs-compiled, future TPU specialization).
"""
from __future__ import annotations

import ast

from repro.analysis.findings import Finding

RULES = {
    "kernel-import":
        "repro.kernels.* imported outside the kernels package and the "
        "compression backend dispatch layer",
}

_ALLOWED_PREFIXES = ("repro/kernels/", "repro/compression/")


def _allowed(rel: str) -> bool:
    rel = rel.replace("\\", "/")
    return any(f"/{p}" in f"/{rel}" for p in _ALLOWED_PREFIXES)


def check(module) -> list[Finding]:
    if _allowed(module.rel):
        return []
    out: list[Finding] = []
    for node in ast.walk(module.tree):
        target = ""
        if isinstance(node, ast.Import):
            hit = [a.name for a in node.names
                   if a.name.split(".")[:2] == ["repro", "kernels"]]
            target = hit[0] if hit else ""
        elif isinstance(node, ast.ImportFrom) and node.module:
            if node.module.split(".")[:2] == ["repro", "kernels"]:
                target = node.module
        if target:
            out.append(Finding(
                file=module.rel, line=node.lineno, rule="kernel-import",
                message=f"direct import of {target} — go through "
                        "repro.compression.backend, the dispatch layer that "
                        "owns backend selection and re-exports the stable "
                        "kernel surface"))
    return out
