"""Ignored-semantic-argument detection — the PR 3 bug class.

``ignored-argument``
    A public function (or public method of a public class) that accepts a
    parameter and then either ``del``-etes it or never reads it. PR 3's
    epoch-indexed sampler did exactly this: the signature promised
    ``sample(..., epoch)`` but the body ``del epoch``-ed it and advanced a
    mutable rng instead, turning without-replacement reshuffling into
    near-with-replacement sampling while every call site looked correct.

    The checker intentionally covers only the *public semantic surface*:
    nested defs, lambdas, underscore-prefixed functions/params, ``self`` /
    ``cls``, protocol stubs (docstring-only / ``...`` / ``pass`` / ``raise
    NotImplementedError`` bodies) and ``@abstractmethod`` / ``@overload``
    declarations are all exempt. Interface-mandated unused parameters are
    legitimate — annotate the ``del`` (or the ``def``) with
    ``# analysis: allow[ignored-argument] <why the interface needs it>``.
"""
from __future__ import annotations

import ast

from repro.analysis.checkers.base import dotted, func_params, is_stub_body
from repro.analysis.findings import Finding

RULES = {
    "ignored-argument":
        "a public function accepts a semantic argument it deletes or "
        "never reads (the PR 3 `del epoch` sampler bug class)",
}

_EXEMPT_DECORATORS = {"abstractmethod", "overload", "overrides"}


def _is_exempt(fn: ast.FunctionDef | ast.AsyncFunctionDef) -> bool:
    if fn.name.startswith("_"):
        return True
    if is_stub_body(fn):
        return True
    for dec in fn.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = dotted(target).rsplit(".", 1)[-1]
        if name in _EXEMPT_DECORATORS:
            return True
    return False


def _public_functions(tree: ast.Module):
    """Module-level functions + methods of module-level classes, public only.

    Nested defs and lambdas are implementation detail, not API surface."""
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node
        elif isinstance(node, ast.ClassDef) and not node.name.startswith("_"):
            for item in node.body:
                if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    yield item


def _check_function(fn, rel: str, out: list[Finding]) -> None:
    params = {a.arg for a in func_params(fn)}
    params -= {"self", "cls"}
    params = {p for p in params if not p.startswith("_")}
    if not params:
        return

    deleted: dict[str, int] = {}  # param -> line of the `del`
    read: set[str] = set()
    # Walk the body only; skip nested function/class scopes — a param read
    # inside a closure IS a read, so nested defs are walked for Loads but
    # their own params shadow nothing we track here (shadowing a param in a
    # nested def is rare enough that a false negative is acceptable).
    for stmt in fn.body:
        for node in ast.walk(stmt):
            if isinstance(node, ast.Delete):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name) and tgt.id in params:
                        deleted.setdefault(tgt.id, node.lineno)
            elif isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
                read.add(node.id)

    for p in sorted(params):
        if p in deleted:
            out.append(Finding(
                file=rel, line=deleted[p], rule="ignored-argument",
                message=f"{fn.name}() deletes parameter '{p}' without "
                        "reading it — the signature promises semantics the "
                        "body ignores (PR 3 sampler bug class)"))
        elif p not in read:
            out.append(Finding(
                file=rel, line=fn.lineno, rule="ignored-argument",
                message=f"{fn.name}() never reads parameter '{p}' — "
                        "dead semantic surface, or a silently dropped "
                        "behavior knob"))


def check(module) -> list[Finding]:
    out: list[Finding] = []
    for fn in _public_functions(module.tree):
        if not _is_exempt(fn):
            _check_function(fn, module.rel, out)
    return out
