"""Bit-accounting arithmetic outside the compensated helper — PR 4 bug class.

``bits-accounting``
    Direct ``+``/``-`` arithmetic on a ``bits`` / ``bits_lo`` accumulator
    anywhere except ``repro.core.api`` (where ``accumulate_bits`` owns the
    Kahan/compensated-summation update). PR 4's regression was exactly this:
    a plain f32 ``state.bits + inc`` stalls once the running total crosses
    ~2^24 (f32 integer gap exceeds the per-round increment) and the reported
    communication cost silently flatlines. Any new accumulation site must go
    through ``api.accumulate_bits`` so the ``(bits, bits_lo)`` pair stays
    compensated.

    Host-side Python accumulators (float64: 53-bit mantissa, no stall at
    realistic totals) are legitimate — annotate them with
    ``# analysis: allow[bits-accounting] <why compensation is unnecessary>``.
"""
from __future__ import annotations

import ast

from repro.analysis.findings import Finding

RULES = {
    "bits-accounting":
        "arithmetic on a bits/bits_lo accumulator outside "
        "repro.core.api.accumulate_bits (the PR 4 f32-stall bug class)",
}

_ACCUMULATOR_NAMES = {"bits", "bits_lo"}
_ALLOWED_MODULE = "core/api.py"


def _is_bits(node: ast.AST) -> bool:
    if isinstance(node, ast.Name):
        return node.id in _ACCUMULATOR_NAMES
    if isinstance(node, ast.Attribute):
        return node.attr in _ACCUMULATOR_NAMES
    if isinstance(node, ast.Subscript):
        return _is_bits(node.value)
    return False


def check(module) -> list[Finding]:
    rel = module.rel.replace("\\", "/")
    if rel.endswith(_ALLOWED_MODULE):
        return []
    out: list[Finding] = []
    for node in ast.walk(module.tree):
        if isinstance(node, ast.BinOp) and isinstance(
                node.op, (ast.Add, ast.Sub)):
            if _is_bits(node.left) or _is_bits(node.right):
                out.append(Finding(
                    file=module.rel, line=node.lineno, rule="bits-accounting",
                    message="plain add/sub on a bits accumulator — route it "
                            "through api.accumulate_bits (f32 totals stall "
                            "past ~2^24; the PR 4 bug)"))
        elif isinstance(node, ast.AugAssign) and isinstance(
                node.op, (ast.Add, ast.Sub)):
            if _is_bits(node.target):
                out.append(Finding(
                    file=module.rel, line=node.lineno, rule="bits-accounting",
                    message="augmented add/sub on a bits accumulator — route "
                            "it through api.accumulate_bits (f32 totals "
                            "stall past ~2^24; the PR 4 bug)"))
    return out
