"""RNG purity: structured entropy tuples + named salts (DESIGN.md §3.12).

Two rules:

``rng-unstructured-seed``
    Every `np.random.default_rng(...)` must be seeded with a structured
    entropy tuple of >= 2 components — `(seed, salt)` for one-shot
    synthesis, `(seed, salt, round/epoch)` (or `(seed, epoch)` with
    stream-disjoint tuple shapes) for per-round draws — never a bare
    integer, and never unseeded. Bare `jax.random.key` / `PRNGKey`
    construction outside `repro.core.salts` is the same violation: root
    keys come from `salts.root_key(seed, salt)` so equal integer seeds
    in different subsystems still yield disjoint key trees. Legacy global
    numpy streams (`np.random.seed/rand/...`) are flagged unconditionally.

``rng-literal-salt``
    Numeric salt literals — inside an entropy tuple, as a `fold_in` stream
    separator, or assigned to a `*_SALT` name — must live in the
    `repro.core.salts` registry, where uniqueness is checked at import.
    A literal anywhere else can silently collide with an existing stream.
"""
from __future__ import annotations

import ast

from repro.analysis.checkers.base import dotted, is_int_literal
from repro.analysis.findings import Finding

RULES = {
    "rng-unstructured-seed":
        "RNG/key construction must derive from a structured "
        "(seed, salt, round/epoch) tuple (np) or salts.root_key (jax)",
    "rng-literal-salt":
        "numeric salt literals belong in the repro.core.salts registry",
}

_SALTS_MODULE = "core/salts.py"
_NP_GLOBAL_DRAWS = {
    "seed", "rand", "randn", "randint", "random", "random_sample",
    "permutation", "choice", "shuffle", "uniform", "normal", "integers",
}


def _is_salts_module(rel: str) -> bool:
    return rel.replace("\\", "/").endswith(_SALTS_MODULE)


def _check_default_rng(node: ast.Call, rel: str, out: list[Finding]) -> None:
    if not node.args and not node.keywords:
        out.append(Finding(
            file=rel, line=node.lineno, rule="rng-unstructured-seed",
            message="default_rng() without a seed is OS-entropy — every "
                    "draw must be a pure function of (seed, salt, round)"))
        return
    arg = node.args[0] if node.args else node.keywords[0].value
    if not isinstance(arg, ast.Tuple) or len(arg.elts) < 2:
        out.append(Finding(
            file=rel, line=node.lineno, rule="rng-unstructured-seed",
            message="default_rng seed is not a structured entropy tuple — "
                    "pass (seed, salt[, round/epoch]) so streams can't "
                    "alias across subsystems"))
        return
    for elt in arg.elts:
        if is_int_literal(elt):
            out.append(Finding(
                file=rel, line=elt.lineno, rule="rng-literal-salt",
                message=f"literal salt {elt.value:#x} in an entropy tuple — "
                        "use a named constant from repro.core.salts"))


def check(module) -> list[Finding]:
    out: list[Finding] = []
    rel = module.rel
    in_salts = _is_salts_module(rel)
    for node in ast.walk(module.tree):
        if isinstance(node, ast.Call):
            name = dotted(node.func)
            base = name.rsplit(".", 1)[-1] if name else ""
            if base == "default_rng" and (name == "default_rng"
                                          or ".random." in f".{name}"):
                _check_default_rng(node, rel, out)
            elif (name.endswith("random.key") or base == "PRNGKey") \
                    and not in_salts:
                out.append(Finding(
                    file=rel, line=node.lineno, rule="rng-unstructured-seed",
                    message=f"bare {base}(...) root-key construction — "
                            "derive it via repro.core.salts.root_key"
                            "(seed, salt) so key trees are salted apart"))
            elif base == "fold_in" and len(node.args) >= 2 and not in_salts:
                salt = node.args[1]
                literal = is_int_literal(salt) or (
                    isinstance(salt, ast.BinOp)
                    and (is_int_literal(salt.left)
                         or is_int_literal(salt.right)))
                if literal:
                    out.append(Finding(
                        file=rel, line=node.lineno, rule="rng-literal-salt",
                        message="literal fold_in stream separator — register "
                                "a named salt in repro.core.salts"))
            elif name.startswith(("np.random.", "numpy.random.")) \
                    and base in _NP_GLOBAL_DRAWS:
                out.append(Finding(
                    file=rel, line=node.lineno, rule="rng-unstructured-seed",
                    message=f"global numpy stream np.random.{base} — draws "
                            "are not a pure function of (seed, salt, round)"))
        elif isinstance(node, ast.Assign) and not in_salts:
            for tgt in node.targets:
                if isinstance(tgt, ast.Name) and "SALT" in tgt.id.upper() \
                        and is_int_literal(node.value):
                    out.append(Finding(
                        file=rel, line=node.lineno, rule="rng-literal-salt",
                        message=f"salt constant {tgt.id} defined outside the "
                                "repro.core.salts registry — uniqueness is "
                                "unchecked here"))
    return out
