"""Host-side hazards inside trace-reachable functions.

``trace-hazard``
    Wall-clock reads (``time.time()``/``perf_counter()``/...), global numpy
    draws, ``.item()`` materialization, and ``float()``/``int()``/``bool()``
    on tracer-producing expressions are all host-side operations. Inside a
    function that jax traces (jit / shard_map / vmap / grad / scan /
    eval_shape), they either crash (``ConcretizationTypeError``) or — worse —
    bake a single host value into the compiled program, so every subsequent
    step silently replays the value captured at trace time.

    Reachability is a module-local over-approximation: functions passed to /
    decorated by a tracing entry point are roots, and any module-level
    function called by bare name from a traced function is traced too.
    Cross-module reachability is handled by listing the modules whose whole
    public surface runs under trace (``TRACED_MODULES``) — the wire regions,
    rules, algorithms, models, optimizers, and kernels.

    Host-side code that must live in a traced *module* (e.g. setup helpers)
    carries ``# analysis: allow[trace-hazard] <why this never runs under
    trace>``.
"""
from __future__ import annotations

import ast

from repro.analysis.checkers.base import dotted
from repro.analysis.findings import Finding

RULES = {
    "trace-hazard":
        "host-side operation (wall clock, global numpy RNG, .item(), "
        "float()-on-tracer) inside a trace-reachable function",
}

# Modules whose function surface is (transitively) traced: the wire regions
# and everything they call. Matched as a path suffix of the repo-relative
# file. Keep in sync with DESIGN.md §3.12.
TRACED_MODULES = (
    "repro/core/dist.py",
    "repro/core/rules.py",
    "repro/core/api.py",
    "repro/core/algorithms.py",
    "repro/compression/backend.py",
    "repro/compression/ops.py",
    "repro/models/transformer.py",
    "repro/models/layers.py",
    "repro/models/moe.py",
    "repro/models/mixers.py",
    "repro/models/linear_attention.py",
    "repro/optim/optimizers.py",
    "repro/kernels/",
)

# Call targets that make their function-argument (or decorated function) a
# trace root.
_TRACE_ENTRY_POINTS = {
    "jit", "shard_map", "manual", "vmap", "pmap", "grad", "value_and_grad",
    "scan", "eval_shape", "make_jaxpr", "checkpoint", "remat", "pallas_call",
    "fori_loop", "while_loop", "cond", "switch",
}

_CLOCK_CALLS = {
    "time.time", "time.perf_counter", "time.monotonic", "time.time_ns",
    "time.perf_counter_ns", "time.monotonic_ns", "datetime.now",
    "datetime.datetime.now", "datetime.utcnow",
}

_CASTS = {"float", "int", "bool", "complex"}


def _in_traced_module(rel: str) -> bool:
    rel = "/" + rel.replace("\\", "/")
    return any(f"/{m}" in rel for m in TRACED_MODULES)


def _module_functions(tree: ast.Module) -> dict[str, ast.AST]:
    fns: dict[str, ast.AST] = {}
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            fns[node.name] = node
        elif isinstance(node, ast.ClassDef):
            for item in node.body:
                if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    fns.setdefault(item.name, item)
    return fns


def _trace_roots(tree: ast.Module, fns: dict[str, ast.AST]) -> set[str]:
    """Function names handed to (or decorated by) a tracing entry point."""
    roots: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            base = dotted(node.func).rsplit(".", 1)[-1]
            if base in _TRACE_ENTRY_POINTS:
                for arg in list(node.args) + [k.value for k in node.keywords]:
                    if isinstance(arg, ast.Name) and arg.id in fns:
                        roots.add(arg.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                target = dec.func if isinstance(dec, ast.Call) else dec
                if dotted(target).rsplit(".", 1)[-1] in _TRACE_ENTRY_POINTS:
                    roots.add(node.name)
    return roots


def _reachable(fns: dict[str, ast.AST], roots: set[str]) -> set[str]:
    """Fixpoint of bare-name calls from traced functions to module defs."""
    reached = set(roots)
    frontier = list(roots)
    while frontier:
        fn = fns.get(frontier.pop())
        if fn is None:
            continue
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
                callee = node.func.id
                if callee in fns and callee not in reached:
                    reached.add(callee)
                    frontier.append(callee)
    return reached


def _contains_tracer_math(node: ast.AST) -> bool:
    """Heuristic: the expression subtree calls into jnp./jax./lax. —
    so casting its value to a Python scalar forces a tracer."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            name = dotted(sub.func)
            if name.startswith(("jnp.", "jax.", "lax.")):
                return True
    return False


def _hazards(fn: ast.AST, rel: str) -> list[Finding]:
    out: list[Finding] = []
    seen: set[tuple[int, str]] = set()

    def emit(line: int, message: str) -> None:
        if (line, message) not in seen:
            seen.add((line, message))
            out.append(Finding(file=rel, line=line, rule="trace-hazard",
                               message=message))

    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        name = dotted(node.func)
        base = name.rsplit(".", 1)[-1] if name else ""
        if name in _CLOCK_CALLS:
            emit(node.lineno,
                 f"{name}() under trace bakes the trace-time clock value "
                 "into the compiled program")
        elif name.startswith(("np.random.", "numpy.random.")):
            emit(node.lineno,
                 f"{name}() under trace draws once at trace time and "
                 "replays the same value every step")
        elif isinstance(node.func, ast.Attribute) and base == "item" \
                and not node.args:
            emit(node.lineno,
                 ".item() forces a device sync / fails on tracers")
        elif isinstance(node.func, ast.Name) and base in _CASTS \
                and node.args and _contains_tracer_math(node.args[0]):
            emit(node.lineno,
                 f"{base}() on a tracer-producing expression raises "
                 "ConcretizationTypeError under trace")
    return out


def check(module) -> list[Finding]:
    fns = _module_functions(module.tree)
    if _in_traced_module(module.rel):
        traced = set(fns)
    else:
        traced = _reachable(fns, _trace_roots(module.tree, fns))
    out: list[Finding] = []
    for name in sorted(traced):
        out.extend(_hazards(fns[name], module.rel))
    return out
