"""repro.analysis — static invariant enforcement (DESIGN.md §3.12).

Two layers, one CLI (``python -m repro.analysis --lint --graph``):

layer 1 — AST linter (`lint`, `checkers/`)
    Pure-AST checkers for the repo's prose invariants: RNG purity and salt
    hygiene, ignored semantic arguments, bit accounting, backend-only kernel
    imports, trace hazards. Never imports jax — fast enough for pre-commit.

layer 2 — jaxpr census (`graph`)
    Traces the real train steps (no device execution) and checks what the
    lint layer can't see from source: collective-op counts and payload bytes
    against the analytic wire model, dtype promotion, buffer donation, and
    the elastic step's weight-invariant jaxpr.

Keep this module import-light: importing `repro.analysis` must not import
jax (the graph layer is imported lazily by the CLI after XLA_FLAGS is set).
"""
from __future__ import annotations

from repro.analysis.findings import (
    Finding,
    apply_baseline,
    load_baseline,
)
from repro.analysis.lint import lint_paths, lint_source, rule_catalog

__all__ = [
    "Finding",
    "apply_baseline",
    "load_baseline",
    "lint_paths",
    "lint_source",
    "rule_catalog",
]
