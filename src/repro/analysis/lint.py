"""Layer-1 AST linter: repo-specific invariant checkers (DESIGN.md §3.12).

This is not a style linter. Each checker encodes one invariant that
DESIGN.md states in prose and that a past PR found silently violated (or
could have): RNG purity and salt hygiene, ignored semantic arguments
(the PR 3 `del epoch` bug class), bit-accounting outside the Kahan helper
(the PR 4 f32-stall bug class), kernel imports bypassing the backend
dispatch layer, and host-side hazards inside trace-reachable functions.

The driver parses every file once into a `Module` (source, AST, allow
annotations) and hands it to each checker; checkers return `Finding`
records. Suppression semantics (inline allows, their required rationale,
staleness detection) live here so individual checkers never see them.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import re
import tokenize
from pathlib import Path

from repro.analysis.findings import Finding

_ALLOW_RE = re.compile(
    r"#\s*analysis:\s*allow\[([a-z0-9_,\- ]+)\]\s*(.*)$")


@dataclasses.dataclass
class Module:
    """One parsed source file, as every checker sees it."""

    rel: str  # repo-relative posix path (what findings report)
    source: str
    tree: ast.Module
    lines: list[str]
    # line -> set of rule ids allowed on that line (rationale already
    # validated by the driver)
    allows: dict[int, set[str]]


def parse_annotations(source: str, rel: str
                      ) -> tuple[dict[int, set[str]], list[Finding]]:
    """Extract `# analysis: allow[rules] rationale` markers per line.

    Only real COMMENT tokens count — an allow-annotation example quoted in a
    docstring (this package documents its own syntax) is not an annotation.
    A trailing comment covers its own line; an annotation on a comment-only
    line covers the next code line (for statements too long to annotate
    inline).
    """
    allows: dict[int, set[str]] = {}
    findings: list[Finding] = []
    lines = source.splitlines()
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(source).readline))
    except (tokenize.TokenizeError, IndentationError):
        return allows, findings  # ast.parse will report the syntax error
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        m = _ALLOW_RE.search(tok.string)
        if not m:
            continue
        i = tok.start[0]
        rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
        rationale = m.group(2).strip()
        if not rationale:
            findings.append(Finding(
                file=rel, line=i, rule="allow-missing-rationale",
                message=f"allow[{','.join(sorted(rules))}] has no rationale "
                        "— say why the invariant doesn't apply here"))
            continue
        if lines[i - 1].lstrip().startswith("#"):
            # comment-only line: cover the next code line (skip any
            # rationale-continuation comments and blanks in between)
            while i < len(lines) and (not lines[i].strip()
                                      or lines[i].lstrip().startswith("#")):
                i += 1
            i += 1
        allows.setdefault(i, set()).update(rules)
    return allows, findings


def parse_module(source: str, rel: str) -> tuple[Module, list[Finding]]:
    lines = source.splitlines()
    allows, findings = parse_annotations(source, rel)
    tree = ast.parse(source, filename=rel)
    return Module(rel=rel, source=source, tree=tree, lines=lines,
                  allows=allows), findings


def _apply_allows(module: Module, findings: list[Finding]
                  ) -> list[Finding]:
    """Drop findings covered by an allow on their line; flag stale allows."""
    used: dict[int, set[str]] = {}
    out = []
    for f in findings:
        rules = module.allows.get(f.line, set())
        if f.rule in rules:
            used.setdefault(f.line, set()).add(f.rule)
        else:
            out.append(f)
    for line, rules in module.allows.items():
        stale = rules - used.get(line, set())
        if stale:
            out.append(Finding(
                file=module.rel, line=line, rule="stale-allow",
                message=f"allow[{','.join(sorted(stale))}] suppresses "
                        "nothing on this line — delete it"))
    return out


def lint_source(source: str, rel: str = "<memory>", checkers=None
                ) -> list[Finding]:
    """Lint one in-memory source blob (the test fixtures' entry point)."""
    from repro.analysis.checkers import ALL_CHECKERS

    module, findings = parse_module(source, rel)
    for check in (ALL_CHECKERS if checkers is None else checkers):
        findings.extend(check(module))
    return sorted(_apply_allows(module, findings))


def iter_source_files(root: Path) -> list[Path]:
    return sorted(p for p in root.rglob("*.py") if "__pycache__" not in p.parts)


def lint_paths(paths: list[Path], *, repo_root: Path) -> list[Finding]:
    """Lint every .py file under `paths`; report repo-relative locations."""
    findings: list[Finding] = []
    for path in paths:
        files = iter_source_files(path) if path.is_dir() else [path]
        for f in files:
            rel = f.resolve().relative_to(repo_root.resolve()).as_posix()
            try:
                findings.extend(lint_source(f.read_text(), rel))
            except SyntaxError as e:  # a file that won't parse IS a finding
                findings.append(Finding(
                    file=rel, line=int(e.lineno or 0), rule="syntax-error",
                    message=str(e.msg)))
    return sorted(findings)


def rule_catalog() -> dict[str, str]:
    """Every rule id -> one-line description (the DESIGN.md §3.12 catalog)."""
    from repro.analysis import checkers
    from repro.analysis.findings import META_RULES

    catalog = dict(META_RULES)
    catalog["syntax-error"] = "file does not parse"
    for mod_rules in checkers.RULE_DOCS:
        catalog.update(mod_rules)
    return catalog
