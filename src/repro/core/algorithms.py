"""The paper's federated optimization algorithms + the baselines it compares to.

Two driver families cover all eleven methods:

Non-local (communicate every iteration; Sec. 2.1-2.2):
    sgd       distributed SGD, with-replacement           (Q=identity)
    qsgd      Alistarh et al. 2017, with-replacement
    rr        distributed Random Reshuffling              (Q=identity)
    q_rr      Algorithm 2 (paper)   — RR + compression
    diana     Mishchenko et al. 2019 — 1 shift / worker, with-replacement
    diana_rr  Algorithm 3 (paper)   — RR + compression + n shifts / worker

Local (communicate once per epoch of n local steps; Sec. 2.3-2.4):
    fedavg        local SGD, with-replacement, server averaging
    fedrr         Mishchenko et al. 2021 — local RR, server averaging
    nastya        Malinovsky et al. 2022 — local RR, server stepsize
    fedpaq        Reisizadeh et al. 2020 — local SGD + quantized update, avg
    fedcom        Haddadpour et al. 2021 — local SGD + quantized update, eta
    q_nastya      Algorithm 4 (paper)   — local RR + compression + eta
    diana_nastya  Algorithm 5 (paper)   — Q-NASTYA + 1 shift / worker

Every driver is a pure function ``epoch(state, data, key) -> FedState`` built
by :func:`make_epoch_fn`, jit-compatible, with `lax.scan` over the inner
iterations and `vmap` over clients. Stepsize defaults follow the theory
(Theorems 1-4); pass explicit values to override (the paper multiplies the
theoretical stepsize by a tuned constant).

What distinguishes the methods — the client memory and how it shapes the
wire message — lives in the shared shift-rule layer (`repro.core.rules`,
DESIGN.md §3.8): each `AlgoSpec.shift_mode` names a `ShiftRule`, and the
drivers below dispatch select/payload/update/scatter through it. The
production wire (`repro.core.dist`) consumes the SAME rule instances, so
simulator and pod paths cannot drift apart.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.compression.backend import CompressionBackend, get_backend
from repro.compression.ops import Identity, tree_compression_bits
from repro.core.api import (
    FedState,
    LossFn,
    accumulate_bits,
    clients_grad,
    init_state,
    num_batches,
    num_clients,
    round_batches,
    sample_permutations,
    tree_mean_clients,
    tree_zeros_like,
)
from repro.core.rules import get_rule


@dataclasses.dataclass(frozen=True)
class AlgoSpec:
    """Static description of a method in the paper's design space."""

    name: str
    family: str  # 'nonlocal' | 'local'
    sampling: str  # 'rr' (without replacement) | 'wr' (with replacement)
    shift_mode: str  # 'none' | 'single' | 'per_slot' | 'ef'
    server_stepsize: bool = False  # local family: eta != gamma*n
    default_compressed: bool = True  # identity-compressor methods set False


ALGORITHMS: dict[str, AlgoSpec] = {
    # non-local
    "sgd": AlgoSpec("sgd", "nonlocal", "wr", "none", default_compressed=False),
    "qsgd": AlgoSpec("qsgd", "nonlocal", "wr", "none"),
    "rr": AlgoSpec("rr", "nonlocal", "rr", "none", default_compressed=False),
    "q_rr": AlgoSpec("q_rr", "nonlocal", "rr", "none"),
    "diana": AlgoSpec("diana", "nonlocal", "wr", "single"),
    "diana_rr": AlgoSpec("diana_rr", "nonlocal", "rr", "per_slot"),
    # beyond-paper: error feedback (Stich et al. 2018; the remedy the paper
    # cites for BIASED compressors like Top-k) with RR sampling
    "ef_topk_rr": AlgoSpec("ef_topk_rr", "nonlocal", "rr", "ef"),
    # local
    "fedavg": AlgoSpec("fedavg", "local", "wr", "none", default_compressed=False),
    "fedrr": AlgoSpec("fedrr", "local", "rr", "none", default_compressed=False),
    "nastya": AlgoSpec("nastya", "local", "rr", "none", server_stepsize=True,
                       default_compressed=False),
    "fedpaq": AlgoSpec("fedpaq", "local", "wr", "none"),
    "fedcom": AlgoSpec("fedcom", "local", "wr", "none", server_stepsize=True),
    "q_nastya": AlgoSpec("q_nastya", "local", "rr", "none", server_stepsize=True),
    "diana_nastya": AlgoSpec("diana_nastya", "local", "rr", "single",
                             server_stepsize=True),
}


def init_algorithm(spec: AlgoSpec, params, m: int, n: int) -> FedState:
    """Build the initial FedState with the right shift layout for `spec`."""
    rule = get_rule(spec.shift_mode)
    shifts = rule.init_shifts(params, m, n_slots=n)
    server_h = tree_zeros_like(params) if rule.needs_server_h else None
    return init_state(params, shifts=shifts, server_h=server_h)


def _sample_round_indices(spec: AlgoSpec, key, m: int, n: int) -> jax.Array:
    """(M, n) matrix of batch indices for one epoch."""
    if spec.sampling == "rr":
        return sample_permutations(key, m, n)
    return jax.random.randint(key, (m, n), 0, n)


# ---------------------------------------------------------------------------
# non-local family: one compressed aggregation per iteration
# ---------------------------------------------------------------------------

def _nonlocal_epoch(spec: AlgoSpec, loss_fn: LossFn, comp, gamma: float,
                    alpha: float, backend: CompressionBackend,
                    state: FedState, data, key, order=None) -> FedState:
    m, n = num_clients(data), num_batches(data)
    rule = get_rule(spec.shift_mode)
    k_idx, k_comp = jax.random.split(key)
    # the epoch's batch order: host-side pipeline (data.pipeline feeds the
    # stateless ReshuffleSampler's matrix) or the on-device fallback draw
    idx = order if order is not None else \
        _sample_round_indices(spec, k_idx, m, n)  # (M, n)
    step_keys = jax.random.split(k_comp, n)
    arange_m = jnp.arange(m)

    def step(carry, inp):
        params, shifts = carry
        col, k = inp  # col: (M,) batch index per client
        batches = round_batches(data, col)
        g = clients_grad(loss_fn, params, batches)  # leaves (M, ...)

        # one rule call-chain replaces the per-method ladders: select the
        # round's memory (per-slot tables index by (client, batch)), build
        # the compressed payload, run every client through ONE backend
        # launch (independent randomness per client — the paper's 1/M
        # variance factor), apply the rule's fused update, write back.
        h = rule.select(shifts, (arange_m, col))
        p = rule.payload(g, h, gamma=gamma)
        q = backend.compress_clients(comp, k, p)
        ghat, h_new, _ = rule.update(h, q, h, q, alpha=alpha, gamma=gamma,
                                     backend=backend, payload=p)
        new_shifts = rule.scatter(shifts, (arange_m, col), h_new)

        direction = tree_mean_clients(ghat)
        new_params = jax.tree.map(lambda p, d: p - gamma * d, params, direction)
        return (new_params, new_shifts), None

    (params, shifts), _ = jax.lax.scan(
        step, (state.params, state.shifts), (idx.T, step_keys)
    )
    bits_per_round = float(m * tree_compression_bits(comp, state.params))
    bits, bits_lo = accumulate_bits(state.bits, state.bits_lo,
                                    n * bits_per_round)
    return state._replace(
        params=params,
        shifts=shifts,
        rounds=state.rounds + n,
        bits=bits,
        bits_lo=bits_lo,
    )


# ---------------------------------------------------------------------------
# local family: n local steps, one compressed aggregation per epoch
# ---------------------------------------------------------------------------

def _local_epoch(spec: AlgoSpec, loss_fn: LossFn, comp, gamma: float, eta: float,
                 alpha: float, backend: CompressionBackend,
                 state: FedState, data, key, order=None) -> FedState:
    m, n = num_clients(data), num_batches(data)
    rule = get_rule(spec.shift_mode)
    if not rule.supports_local:
        raise ValueError(
            f"shift rule {rule.name!r} has no local-family driver (the "
            "local methods communicate one epoch gradient — there is no "
            "per-batch slot or residual stream to feed it)")
    k_idx, k_comp = jax.random.split(key)
    idx = order if order is not None else \
        _sample_round_indices(spec, k_idx, m, n)  # (M, n)

    def client_run(params, client_data, order):
        def lstep(x, i):
            batch = jax.tree.map(lambda leaf: leaf[i], client_data)
            g = jax.grad(loss_fn)(x, batch)
            return jax.tree.map(lambda xi, gi: xi - gamma * gi, x, g), None

        xn, _ = jax.lax.scan(lstep, params, order)
        return xn

    xns = jax.vmap(client_run, in_axes=(None, 0, 0))(state.params, data, idx)
    # g_{t,m} = (x_t - x^n_{t,m}) / (gamma * n)   (Alg. 4/5 line 7)
    g = jax.tree.map(lambda p, xn: (p - xn) / (gamma * n), state.params, xns)

    # rule chain (Alg. 5 lines 8-11 when shifts exist): compress the epoch
    # messages, let the rule combine the aggregate with the server memory
    # (\hat g_t = h_t + (1/M) sum_m Q(g_{t,m} - h_{t,m}), fused direction +
    # H-update in one pass), and axpy the client tables.
    h = rule.select(state.shifts, None)
    p = rule.payload(g, h, gamma=gamma)
    qd = backend.compress_clients(comp, k_comp, p)
    direction, server_h = rule.direction(
        state.server_h, tree_mean_clients(qd), alpha=alpha, gamma=gamma,
        backend=backend)
    shifts = rule.table_axpy(state.shifts, qd, alpha=alpha)

    step = eta if spec.server_stepsize else gamma * n
    params = jax.tree.map(lambda p, d: p - step * d, state.params, direction)
    bits_per_round = float(m * tree_compression_bits(comp, state.params))
    bits, bits_lo = accumulate_bits(state.bits, state.bits_lo, bits_per_round)
    return state._replace(
        params=params,
        shifts=shifts,
        server_h=server_h,
        rounds=state.rounds + 1,
        bits=bits,
        bits_lo=bits_lo,
    )


# ---------------------------------------------------------------------------
# public factory
# ---------------------------------------------------------------------------

def make_epoch_fn(name: str, loss_fn: LossFn, compressor=None, *, gamma: float,
                  eta: float | None = None, alpha: float | None = None,
                  backend: str | CompressionBackend | None = None):
    """Return (spec, epoch_fn) for algorithm `name`.

    epoch_fn(state, data, key, order=None) -> FedState runs one full data
    epoch (n communication rounds for non-local methods, 1 for local
    methods). `order` is an optional (M, n) batch-index matrix from the
    host-side pipeline (`data.pipeline.run_epochs` passes the stateless
    `ReshuffleSampler`'s epoch order — Shuffle-Once for DIANA-RR included);
    without it the epoch draws its own on-device order per `spec.sampling`.

    `backend` selects the compression execution path ("reference" |
    "pallas"); default follows $REPRO_COMPRESSION_BACKEND, then "pallas"
    (interpret mode on CPU, Mosaic on TPU) — see repro.compression.backend.
    """
    spec = ALGORITHMS[name]
    be = get_backend(backend)
    # no compressor given -> identity (the old condition's second arm,
    # `not spec.default_compressed and compressor is None`, was dead code:
    # operator precedence made it reachable only when `comp is None` had
    # already short-circuited the `or`)
    comp = Identity() if compressor is None else compressor
    if alpha is None:
        # Theorems 2/4: alpha <= 1/(1+omega); identity => alpha=1
        try:
            om = max(comp.omega(1024), 0.0)
        except Exception:
            om = 0.0
        alpha = 1.0 / (1.0 + (0.0 if om != om else om))  # NaN-safe (TopK)
    if eta is None:
        eta = gamma  # caller should set for server-stepsize methods

    if spec.family == "nonlocal":
        def epoch(state, data, key, order=None):
            return _nonlocal_epoch(spec, loss_fn, comp, gamma, alpha, be,
                                   state, data, key, order)
    else:
        def epoch(state, data, key, order=None):
            return _local_epoch(spec, loss_fn, comp, gamma, eta, alpha, be,
                                state, data, key, order)

    return spec, epoch


def theoretical_stepsizes(name: str, *, l_max: float, mu: float, omega: float,
                          m: int, n: int) -> dict[str, float]:
    """Largest stepsizes allowed by Theorems 1-4 (and the baselines' papers).

    The paper tunes a constant multiplier on top of these; we return the raw
    theory values.
    """
    if name in ("q_rr", "rr"):
        return {"gamma": 1.0 / ((1.0 + 2.0 * omega / m) * l_max)}
    if name == "qsgd" or name == "sgd":
        return {"gamma": 1.0 / ((1.0 + 2.0 * omega / m) * l_max)}
    if name == "diana_rr":
        alpha = 1.0 / (1.0 + omega)
        gamma = min(alpha / (2.0 * n * mu), 1.0 / ((1.0 + 6.0 * omega / m) * l_max))
        return {"gamma": gamma, "alpha": alpha}
    if name == "diana":
        alpha = 1.0 / (1.0 + omega)
        gamma = 1.0 / ((1.0 + 6.0 * omega / m) * l_max)
        return {"gamma": gamma, "alpha": alpha}
    if name in ("q_nastya", "fedcom", "nastya"):
        eta = 1.0 / (16.0 * l_max * (1.0 + omega / m))
        gamma = 1.0 / (5.0 * n * l_max)
        return {"gamma": gamma, "eta": eta}
    if name == "diana_nastya":
        alpha = 1.0 / (1.0 + omega)
        eta = min(alpha / (2.0 * mu), 1.0 / (16.0 * l_max * (1.0 + 9.0 * omega / m)))
        gamma = min(1.0 / (16.0 * l_max * n), eta / n)
        return {"gamma": gamma, "eta": eta, "alpha": alpha}
    if name in ("fedavg", "fedrr", "fedpaq"):
        return {"gamma": 1.0 / (5.0 * n * l_max)}
    if name == "ef_topk_rr":
        # EF-SGD (Stich et al. 2018; Karimireddy et al. 2019): a CONTRACTIVE
        # compressor with contraction delta admits gamma = O(delta / L). Map
        # the caller's omega onto delta via delta = 1/(1+omega) — exact for
        # (Rand-/Top-)k at k/d = delta, where omega = d/k - 1.
        delta = 1.0 / (1.0 + max(omega, 0.0))
        return {"gamma": delta / (2.0 * l_max)}
    raise ValueError(name)
