"""The paper's federated optimization algorithms + the baselines it compares to.

Two driver families cover all eleven methods:

Non-local (communicate every iteration; Sec. 2.1-2.2):
    sgd       distributed SGD, with-replacement           (Q=identity)
    qsgd      Alistarh et al. 2017, with-replacement
    rr        distributed Random Reshuffling              (Q=identity)
    q_rr      Algorithm 2 (paper)   — RR + compression
    diana     Mishchenko et al. 2019 — 1 shift / worker, with-replacement
    diana_rr  Algorithm 3 (paper)   — RR + compression + n shifts / worker

Local (communicate once per epoch of n local steps; Sec. 2.3-2.4):
    fedavg        local SGD, with-replacement, server averaging
    fedrr         Mishchenko et al. 2021 — local RR, server averaging
    nastya        Malinovsky et al. 2022 — local RR, server stepsize
    fedpaq        Reisizadeh et al. 2020 — local SGD + quantized update, avg
    fedcom        Haddadpour et al. 2021 — local SGD + quantized update, eta
    q_nastya      Algorithm 4 (paper)   — local RR + compression + eta
    diana_nastya  Algorithm 5 (paper)   — Q-NASTYA + 1 shift / worker

Every driver is a pure function ``epoch(state, data, key) -> FedState`` built
by :func:`make_epoch_fn`, jit-compatible, with `lax.scan` over the inner
iterations and `vmap` over clients. Stepsize defaults follow the theory
(Theorems 1-4); pass explicit values to override (the paper multiplies the
theoretical stepsize by a tuned constant).

What distinguishes the methods — the client memory and how it shapes the
wire message — lives in the shared shift-rule layer (`repro.core.rules`,
DESIGN.md §3.8): each `AlgoSpec.shift_mode` names a `ShiftRule`, and the
drivers below dispatch select/payload/update/scatter through it. The
production wire (`repro.core.dist`) consumes the SAME rule instances, so
simulator and pod paths cannot drift apart.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.compression.backend import CompressionBackend, get_backend
from repro.compression.ops import Identity, tree_compression_bits
from repro.core.api import (
    FedState,
    LossFn,
    accumulate_bits,
    clients_grad,
    init_state,
    num_batches,
    num_clients,
    round_batches,
    sample_permutations,
    tree_mean_clients,
    tree_zeros_like,
)
from repro.core.rules import get_rule


@dataclasses.dataclass(frozen=True)
class AlgoSpec:
    """Static description of a method in the paper's design space."""

    name: str
    family: str  # 'nonlocal' | 'local'
    sampling: str  # 'rr' (without replacement) | 'wr' (with replacement)
    shift_mode: str  # 'none' | 'single' | 'per_slot' | 'ef'
    server_stepsize: bool = False  # local family: eta != gamma*n
    default_compressed: bool = True  # identity-compressor methods set False


ALGORITHMS: dict[str, AlgoSpec] = {
    # non-local
    "sgd": AlgoSpec("sgd", "nonlocal", "wr", "none", default_compressed=False),
    "qsgd": AlgoSpec("qsgd", "nonlocal", "wr", "none"),
    "rr": AlgoSpec("rr", "nonlocal", "rr", "none", default_compressed=False),
    "q_rr": AlgoSpec("q_rr", "nonlocal", "rr", "none"),
    "diana": AlgoSpec("diana", "nonlocal", "wr", "single"),
    "diana_rr": AlgoSpec("diana_rr", "nonlocal", "rr", "per_slot"),
    # beyond-paper: error feedback (Stich et al. 2018; the remedy the paper
    # cites for BIASED compressors like Top-k) with RR sampling
    "ef_topk_rr": AlgoSpec("ef_topk_rr", "nonlocal", "rr", "ef"),
    # local
    "fedavg": AlgoSpec("fedavg", "local", "wr", "none", default_compressed=False),
    "fedrr": AlgoSpec("fedrr", "local", "rr", "none", default_compressed=False),
    "nastya": AlgoSpec("nastya", "local", "rr", "none", server_stepsize=True,
                       default_compressed=False),
    "fedpaq": AlgoSpec("fedpaq", "local", "wr", "none"),
    "fedcom": AlgoSpec("fedcom", "local", "wr", "none", server_stepsize=True),
    "q_nastya": AlgoSpec("q_nastya", "local", "rr", "none", server_stepsize=True),
    "diana_nastya": AlgoSpec("diana_nastya", "local", "rr", "single",
                             server_stepsize=True),
}


def init_algorithm(spec: AlgoSpec, params, m: int, n: int) -> FedState:
    """Build the initial FedState with the right shift layout for `spec`."""
    rule = get_rule(spec.shift_mode)
    shifts = rule.init_shifts(params, m, n_slots=n)
    server_h = tree_zeros_like(params) if rule.needs_server_h else None
    return init_state(params, shifts=shifts, server_h=server_h)


def _sample_round_indices(spec: AlgoSpec, key, m: int, n: int) -> jax.Array:
    """(M, n) matrix of batch indices for one epoch."""
    if spec.sampling == "rr":
        return sample_permutations(key, m, n)
    return jax.random.randint(key, (m, n), 0, n)


# ---------------------------------------------------------------------------
# non-local family: one compressed aggregation per iteration
# ---------------------------------------------------------------------------

def _make_round(rule, loss_fn: LossFn, comp, gamma: float, alpha: float,
                backend: CompressionBackend):
    """One non-local communication round on a client-stacked slice.

    round(params, shifts, data, col, key) -> (params, shifts): `data` leaves
    are (M, n, ...), `col` the (M,) batch index per client. This is the body
    `_nonlocal_epoch` scans over an epoch's order matrix — and, unchanged,
    what `run_fleet_rounds` applies to a cohort-gathered slice of a larger
    population (the fleet bit-match obligation, DESIGN.md §3.9).
    """

    def round_fn(params, shifts, data, col, key):
        m = num_clients(data)
        arange_m = jnp.arange(m)
        batches = round_batches(data, col)
        g = clients_grad(loss_fn, params, batches)  # leaves (M, ...)

        # one rule call-chain replaces the per-method ladders: select the
        # round's memory (per-slot tables index by (client, batch)), build
        # the compressed payload, run every client through ONE backend
        # launch (independent randomness per client — the paper's 1/M
        # variance factor), apply the rule's fused update, write back.
        h = rule.select(shifts, (arange_m, col))
        p = rule.payload(g, h, gamma=gamma)
        q = backend.compress_clients(comp, key, p)
        ghat, h_new, _ = rule.update(h, q, h, q, alpha=alpha, gamma=gamma,
                                     backend=backend, payload=p)
        new_shifts = rule.scatter(shifts, (arange_m, col), h_new)

        direction = tree_mean_clients(ghat)
        new_params = jax.tree.map(lambda p, d: p - gamma * d, params,
                                  direction)
        return new_params, new_shifts

    return round_fn


def _nonlocal_epoch(spec: AlgoSpec, loss_fn: LossFn, comp, gamma: float,
                    alpha: float, backend: CompressionBackend,
                    state: FedState, data, key, order=None) -> FedState:
    m, n = num_clients(data), num_batches(data)
    rule = get_rule(spec.shift_mode)
    k_idx, k_comp = jax.random.split(key)
    # the epoch's batch order: host-side pipeline (data.pipeline feeds the
    # stateless ReshuffleSampler's matrix) or the on-device fallback draw
    idx = order if order is not None else \
        _sample_round_indices(spec, k_idx, m, n)  # (M, n)
    step_keys = jax.random.split(k_comp, n)
    round_fn = _make_round(rule, loss_fn, comp, gamma, alpha, backend)

    def step(carry, inp):
        params, shifts = carry
        col, k = inp  # col: (M,) batch index per client
        return round_fn(params, shifts, data, col, k), None

    (params, shifts), _ = jax.lax.scan(
        step, (state.params, state.shifts), (idx.T, step_keys)
    )
    bits_per_round = float(m * tree_compression_bits(comp, state.params))
    bits, bits_lo = accumulate_bits(state.bits, state.bits_lo,
                                    n * bits_per_round)
    return state._replace(
        params=params,
        shifts=shifts,
        rounds=state.rounds + n,
        bits=bits,
        bits_lo=bits_lo,
    )


# ---------------------------------------------------------------------------
# local family: n local steps, one compressed aggregation per epoch
# ---------------------------------------------------------------------------

def _local_epoch(spec: AlgoSpec, loss_fn: LossFn, comp, gamma: float, eta: float,
                 alpha: float, backend: CompressionBackend,
                 state: FedState, data, key, order=None) -> FedState:
    m, n = num_clients(data), num_batches(data)
    rule = get_rule(spec.shift_mode)
    if not rule.supports_local:
        raise ValueError(
            f"shift rule {rule.name!r} has no local-family driver (the "
            "local methods communicate one epoch gradient — there is no "
            "per-batch slot or residual stream to feed it)")
    k_idx, k_comp = jax.random.split(key)
    idx = order if order is not None else \
        _sample_round_indices(spec, k_idx, m, n)  # (M, n)

    def client_run(params, client_data, order):
        def lstep(x, i):
            batch = jax.tree.map(lambda leaf: leaf[i], client_data)
            g = jax.grad(loss_fn)(x, batch)
            return jax.tree.map(lambda xi, gi: xi - gamma * gi, x, g), None

        xn, _ = jax.lax.scan(lstep, params, order)
        return xn

    xns = jax.vmap(client_run, in_axes=(None, 0, 0))(state.params, data, idx)
    # g_{t,m} = (x_t - x^n_{t,m}) / (gamma * n)   (Alg. 4/5 line 7)
    g = jax.tree.map(lambda p, xn: (p - xn) / (gamma * n), state.params, xns)

    # rule chain (Alg. 5 lines 8-11 when shifts exist): compress the epoch
    # messages, let the rule combine the aggregate with the server memory
    # (\hat g_t = h_t + (1/M) sum_m Q(g_{t,m} - h_{t,m}), fused direction +
    # H-update in one pass), and axpy the client tables.
    h = rule.select(state.shifts, None)
    p = rule.payload(g, h, gamma=gamma)
    qd = backend.compress_clients(comp, k_comp, p)
    direction, server_h = rule.direction(
        state.server_h, tree_mean_clients(qd), alpha=alpha, gamma=gamma,
        backend=backend)
    shifts = rule.table_axpy(state.shifts, qd, alpha=alpha)

    step = eta if spec.server_stepsize else gamma * n
    params = jax.tree.map(lambda p, d: p - step * d, state.params, direction)
    bits_per_round = float(m * tree_compression_bits(comp, state.params))
    bits, bits_lo = accumulate_bits(state.bits, state.bits_lo, bits_per_round)
    return state._replace(
        params=params,
        shifts=shifts,
        server_h=server_h,
        rounds=state.rounds + 1,
        bits=bits,
        bits_lo=bits_lo,
    )


# ---------------------------------------------------------------------------
# public factory
# ---------------------------------------------------------------------------

def _resolve_comp_alpha(compressor, alpha):
    # no compressor given -> identity (the old condition's second arm,
    # `not spec.default_compressed and compressor is None`, was dead code:
    # operator precedence made it reachable only when `comp is None` had
    # already short-circuited the `or`)
    comp = Identity() if compressor is None else compressor
    if alpha is None:
        # Theorems 2/4: alpha <= 1/(1+omega); identity => alpha=1
        try:
            om = max(comp.omega(1024), 0.0)
        except Exception:
            om = 0.0
        alpha = 1.0 / (1.0 + (0.0 if om != om else om))  # NaN-safe (TopK)
    return comp, alpha


def make_epoch_fn(name: str, loss_fn: LossFn, compressor=None, *, gamma: float,
                  eta: float | None = None, alpha: float | None = None,
                  backend: str | CompressionBackend | None = None):
    """Return (spec, epoch_fn) for algorithm `name`.

    epoch_fn(state, data, key, order=None) -> FedState runs one full data
    epoch (n communication rounds for non-local methods, 1 for local
    methods). `order` is an optional (M, n) batch-index matrix from the
    host-side pipeline (`data.pipeline.run_epochs` passes the stateless
    `ReshuffleSampler`'s epoch order — Shuffle-Once for DIANA-RR included);
    without it the epoch draws its own on-device order per `spec.sampling`.

    `backend` selects the compression execution path ("reference" |
    "pallas"); default follows $REPRO_COMPRESSION_BACKEND, then "pallas"
    (interpret mode on CPU, Mosaic on TPU) — see repro.compression.backend.
    """
    spec = ALGORITHMS[name]
    be = get_backend(backend)
    comp, alpha = _resolve_comp_alpha(compressor, alpha)
    if eta is None:
        eta = gamma  # caller should set for server-stepsize methods

    if spec.family == "nonlocal":
        def epoch(state, data, key, order=None):
            return _nonlocal_epoch(spec, loss_fn, comp, gamma, alpha, be,
                                   state, data, key, order)
    else:
        def epoch(state, data, key, order=None):
            return _local_epoch(spec, loss_fn, comp, gamma, eta, alpha, be,
                                state, data, key, order)

    return spec, epoch


def make_round_fn(name: str, loss_fn: LossFn, compressor=None, *,
                  gamma: float, alpha: float | None = None,
                  backend: str | CompressionBackend | None = None):
    """Return (spec, round_fn) for non-local algorithm `name`.

    round_fn(params, shifts, data, col, key) -> (params, shifts) is ONE
    communication round on a client-stacked slice (`data` leaves (M, n,
    ...), `col` the (M,) batch index per client) — the exact body
    `_nonlocal_epoch` scans over an epoch, exposed so partial-participation
    drivers (`run_fleet_rounds`) can apply it to cohort-gathered slices of
    a larger population. Local-family methods have no per-round form (they
    communicate once per epoch) and raise.
    """
    spec = ALGORITHMS[name]
    if spec.family != "nonlocal":
        raise ValueError(
            f"{name!r} is a local-family method — it communicates one epoch "
            "gradient, not per-round messages; there is no round function")
    be = get_backend(backend)
    comp, alpha = _resolve_comp_alpha(compressor, alpha)
    rule = get_rule(spec.shift_mode)
    return spec, _make_round(rule, loss_fn, comp, gamma, alpha, be)


def run_fleet_rounds(name: str, loss_fn: LossFn, compressor=None, *,
                     gamma: float, alpha: float | None = None,
                     backend: str | CompressionBackend | None = None,
                     params, data, sampler, store, cohort_sampler,
                     rounds: int, key, start_round: int = 0,
                     jit: bool = True):
    """Simulator fleet driver: partial participation at population scale.

    Each round t samples a cohort of client ids (`repro.fleet.
    CohortSampler`, sorted — the canonical mesh-rank order), gathers the
    cohort's rows of the population `data` (leaves (C, n, ...)) and its
    persistent shifts from the host `store` (`repro.fleet.
    ClientStateStore`), runs ONE paper round — the same `_make_round` body
    `_nonlocal_epoch` scans — on the gathered slice, and scatters the
    updated shifts back. Batch indices come from each client's OWN data
    cursor (the store's per-client micro-step counter: clients advance only
    when sampled) through the stateless `sampler`, so the walk is resumable
    from `(store, start_round)` alone.

    With cohort == population under cohort-RR every round is exactly one
    `_nonlocal_epoch` scan step — the cross-check that pins the production
    fleet path's semantics (DESIGN.md §3.9; tests/test_fleet.py). The
    store is updated in place; returns (params, info) with round/bit
    totals.
    """
    from repro.data.pipeline import ClientOrderWalk  # deferred: data -> core

    comp, alpha = _resolve_comp_alpha(compressor, alpha)
    _, round_fn = make_round_fn(name, loss_fn, comp, gamma=gamma,
                                alpha=alpha, backend=backend)
    if store.population != cohort_sampler.population or \
            store.population != sampler.m:
        raise ValueError(
            f"population mismatch: store {store.population}, cohort sampler "
            f"{cohort_sampler.population}, data sampler {sampler.m}")
    step = jax.jit(round_fn) if jit else round_fn
    walk = ClientOrderWalk(sampler)  # the same cursor walk CohortStream runs

    bits_per_client = float(tree_compression_bits(comp, params))
    for t in range(start_round, start_round + rounds):
        cohort = cohort_sampler.cohort_for_round(t)
        col = walk.cols_at(cohort, store.cursors(cohort))[:, 0]
        data_slice = jax.tree.map(lambda l: l[cohort], data)
        shifts = store.gather(cohort)
        params, new_shifts = step(params, shifts, data_slice,
                                  jnp.asarray(col),
                                  jax.random.fold_in(key, t))
        if store.has_shifts:
            store.scatter(cohort, jax.device_get(new_shifts))
        store.advance(cohort, 1)
        store.add_bits(cohort, bits_per_client)
    info = {"rounds": rounds,
            "bits": rounds * cohort_sampler.cohort_size * bits_per_client}
    return params, info


def theoretical_stepsizes(name: str, *, l_max: float, mu: float, omega: float,
                          m: int, n: int) -> dict[str, float]:
    """Largest stepsizes allowed by Theorems 1-4 (and the baselines' papers).

    The paper tunes a constant multiplier on top of these; we return the raw
    theory values.
    """
    if name in ("q_rr", "rr"):
        return {"gamma": 1.0 / ((1.0 + 2.0 * omega / m) * l_max)}
    if name == "qsgd" or name == "sgd":
        return {"gamma": 1.0 / ((1.0 + 2.0 * omega / m) * l_max)}
    if name == "diana_rr":
        alpha = 1.0 / (1.0 + omega)
        gamma = min(alpha / (2.0 * n * mu), 1.0 / ((1.0 + 6.0 * omega / m) * l_max))
        return {"gamma": gamma, "alpha": alpha}
    if name == "diana":
        alpha = 1.0 / (1.0 + omega)
        gamma = 1.0 / ((1.0 + 6.0 * omega / m) * l_max)
        return {"gamma": gamma, "alpha": alpha}
    if name in ("q_nastya", "fedcom", "nastya"):
        eta = 1.0 / (16.0 * l_max * (1.0 + omega / m))
        gamma = 1.0 / (5.0 * n * l_max)
        return {"gamma": gamma, "eta": eta}
    if name == "diana_nastya":
        alpha = 1.0 / (1.0 + omega)
        eta = min(alpha / (2.0 * mu), 1.0 / (16.0 * l_max * (1.0 + 9.0 * omega / m)))
        gamma = min(1.0 / (16.0 * l_max * n), eta / n)
        return {"gamma": gamma, "eta": eta, "alpha": alpha}
    if name in ("fedavg", "fedrr", "fedpaq"):
        return {"gamma": 1.0 / (5.0 * n * l_max)}
    if name == "ef_topk_rr":
        # EF-SGD (Stich et al. 2018; Karimireddy et al. 2019): a CONTRACTIVE
        # compressor with contraction delta admits gamma = O(delta / L). Map
        # the caller's omega onto delta via delta = 1/(1+omega) — exact for
        # (Rand-/Top-)k at k/d = delta, where omega = d/k - 1.
        delta = 1.0 / (1.0 + max(omega, 0.0))
        return {"gamma": delta / (2.0 * l_max)}
    raise ValueError(name)
