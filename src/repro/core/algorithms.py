"""The paper's federated optimization algorithms + the baselines it compares to.

Two driver families cover all eleven methods:

Non-local (communicate every iteration; Sec. 2.1-2.2):
    sgd       distributed SGD, with-replacement           (Q=identity)
    qsgd      Alistarh et al. 2017, with-replacement
    rr        distributed Random Reshuffling              (Q=identity)
    q_rr      Algorithm 2 (paper)   — RR + compression
    diana     Mishchenko et al. 2019 — 1 shift / worker, with-replacement
    diana_rr  Algorithm 3 (paper)   — RR + compression + n shifts / worker

Local (communicate once per epoch of n local steps; Sec. 2.3-2.4):
    fedavg        local SGD, with-replacement, server averaging
    fedrr         Mishchenko et al. 2021 — local RR, server averaging
    nastya        Malinovsky et al. 2022 — local RR, server stepsize
    fedpaq        Reisizadeh et al. 2020 — local SGD + quantized update, avg
    fedcom        Haddadpour et al. 2021 — local SGD + quantized update, eta
    q_nastya      Algorithm 4 (paper)   — local RR + compression + eta
    diana_nastya  Algorithm 5 (paper)   — Q-NASTYA + 1 shift / worker

Every driver is a pure function ``epoch(state, data, key) -> FedState`` built
by :func:`make_epoch_fn`, jit-compatible, with `lax.scan` over the inner
iterations and `vmap` over clients. Stepsize defaults follow the theory
(Theorems 1-4); pass explicit values to override (the paper multiplies the
theoretical stepsize by a tuned constant).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.compression.backend import CompressionBackend, get_backend
from repro.compression.ops import Identity, tree_compression_bits
from repro.core.api import (
    FedState,
    LossFn,
    clients_grad,
    init_state,
    num_batches,
    num_clients,
    round_batches,
    sample_permutations,
    tree_mean_clients,
    tree_scale,
    tree_sub,
    tree_zeros_like,
)


@dataclasses.dataclass(frozen=True)
class AlgoSpec:
    """Static description of a method in the paper's design space."""

    name: str
    family: str  # 'nonlocal' | 'local'
    sampling: str  # 'rr' (without replacement) | 'wr' (with replacement)
    shift_mode: str  # 'none' | 'single' | 'per_slot' | 'ef'
    server_stepsize: bool = False  # local family: eta != gamma*n
    default_compressed: bool = True  # identity-compressor methods set False


ALGORITHMS: dict[str, AlgoSpec] = {
    # non-local
    "sgd": AlgoSpec("sgd", "nonlocal", "wr", "none", default_compressed=False),
    "qsgd": AlgoSpec("qsgd", "nonlocal", "wr", "none"),
    "rr": AlgoSpec("rr", "nonlocal", "rr", "none", default_compressed=False),
    "q_rr": AlgoSpec("q_rr", "nonlocal", "rr", "none"),
    "diana": AlgoSpec("diana", "nonlocal", "wr", "single"),
    "diana_rr": AlgoSpec("diana_rr", "nonlocal", "rr", "per_slot"),
    # beyond-paper: error feedback (Stich et al. 2018; the remedy the paper
    # cites for BIASED compressors like Top-k) with RR sampling
    "ef_topk_rr": AlgoSpec("ef_topk_rr", "nonlocal", "rr", "ef"),
    # local
    "fedavg": AlgoSpec("fedavg", "local", "wr", "none", default_compressed=False),
    "fedrr": AlgoSpec("fedrr", "local", "rr", "none", default_compressed=False),
    "nastya": AlgoSpec("nastya", "local", "rr", "none", server_stepsize=True,
                       default_compressed=False),
    "fedpaq": AlgoSpec("fedpaq", "local", "wr", "none"),
    "fedcom": AlgoSpec("fedcom", "local", "wr", "none", server_stepsize=True),
    "q_nastya": AlgoSpec("q_nastya", "local", "rr", "none", server_stepsize=True),
    "diana_nastya": AlgoSpec("diana_nastya", "local", "rr", "single",
                             server_stepsize=True),
}


def init_algorithm(spec: AlgoSpec, params, m: int, n: int) -> FedState:
    """Build the initial FedState with the right shift layout for `spec`."""
    if spec.shift_mode == "none":
        shifts = None
    elif spec.shift_mode in ("single", "ef"):
        shifts = jax.tree.map(lambda p: jnp.zeros((m,) + p.shape, p.dtype), params)
    elif spec.shift_mode == "per_slot":
        shifts = jax.tree.map(lambda p: jnp.zeros((m, n) + p.shape, p.dtype), params)
    else:
        raise ValueError(spec.shift_mode)
    server_h = tree_zeros_like(params) if spec.shift_mode == "single" else None
    return init_state(params, shifts=shifts, server_h=server_h)


def _compress_clients(comp, key, grads_stacked, backend: CompressionBackend):
    """Compress every client's gradient pytree in one backend launch.

    Each client uses independent randomness (the paper's Q are independent
    across workers — this is what makes the 1/M variance factor appear); the
    backend ravels the whole (M, D) client matrix once and runs a single
    flat-buffer kernel instead of a per-leaf loop under vmap.
    """
    return backend.compress_clients(comp, key, grads_stacked)


def _sample_round_indices(spec: AlgoSpec, key, m: int, n: int) -> jax.Array:
    """(M, n) matrix of batch indices for one epoch."""
    if spec.sampling == "rr":
        return sample_permutations(key, m, n)
    return jax.random.randint(key, (m, n), 0, n)


# ---------------------------------------------------------------------------
# non-local family: one compressed aggregation per iteration
# ---------------------------------------------------------------------------

def _nonlocal_epoch(spec: AlgoSpec, loss_fn: LossFn, comp, gamma: float,
                    alpha: float, backend: CompressionBackend,
                    state: FedState, data, key, order=None) -> FedState:
    m, n = num_clients(data), num_batches(data)
    k_idx, k_comp = jax.random.split(key)
    # the epoch's batch order: host-side pipeline (data.pipeline feeds the
    # stateless ReshuffleSampler's matrix) or the on-device fallback draw
    idx = order if order is not None else \
        _sample_round_indices(spec, k_idx, m, n)  # (M, n)
    step_keys = jax.random.split(k_comp, n)
    arange_m = jnp.arange(m)

    def step(carry, inp):
        params, shifts = carry
        col, k = inp  # col: (M,) batch index per client
        batches = round_batches(data, col)
        g = clients_grad(loss_fn, params, batches)  # leaves (M, ...)

        if spec.shift_mode == "none":
            ghat = _compress_clients(comp, k, g, backend)
            new_shifts = shifts
        elif spec.shift_mode == "ef":
            # error feedback: p_m = gamma*g_m + e_m; send C(p_m); keep the
            # compression residual as next round's memory. The common
            # `params - gamma*direction` update divides gamma back out.
            p_t = jax.tree.map(lambda gi, e: gamma * gi + e, g, shifts)
            qd = _compress_clients(comp, k, p_t, backend)
            new_shifts = jax.tree.map(jnp.subtract, p_t, qd)
            ghat = jax.tree.map(lambda q: q / gamma, qd)
        elif spec.shift_mode == "single":
            delta = tree_sub(g, shifts)
            qd = _compress_clients(comp, k, delta, backend)
            # fused kernel: ghat = h + Q, h' = h + alpha*Q in one pass
            ghat, new_shifts, _ = backend.tree_diana_shift(
                shifts, qd, shifts, qd, alpha=alpha
            )
        elif spec.shift_mode == "per_slot":
            h_i = jax.tree.map(lambda s: s[arange_m, col], shifts)
            delta = tree_sub(g, h_i)
            qd = _compress_clients(comp, k, delta, backend)
            ghat, h_i_new, _ = backend.tree_diana_shift(
                h_i, qd, h_i, qd, alpha=alpha
            )
            new_shifts = jax.tree.map(
                lambda s, hn: s.at[arange_m, col].set(hn), shifts, h_i_new
            )
        else:
            raise ValueError(spec.shift_mode)

        direction = tree_mean_clients(ghat)
        new_params = jax.tree.map(lambda p, d: p - gamma * d, params, direction)
        return (new_params, new_shifts), None

    (params, shifts), _ = jax.lax.scan(
        step, (state.params, state.shifts), (idx.T, step_keys)
    )
    bits_per_round = float(m * tree_compression_bits(comp, state.params))
    return state._replace(
        params=params,
        shifts=shifts,
        rounds=state.rounds + n,
        bits=state.bits + n * bits_per_round,
    )


# ---------------------------------------------------------------------------
# local family: n local steps, one compressed aggregation per epoch
# ---------------------------------------------------------------------------

def _local_epoch(spec: AlgoSpec, loss_fn: LossFn, comp, gamma: float, eta: float,
                 alpha: float, backend: CompressionBackend,
                 state: FedState, data, key, order=None) -> FedState:
    m, n = num_clients(data), num_batches(data)
    k_idx, k_comp = jax.random.split(key)
    idx = order if order is not None else \
        _sample_round_indices(spec, k_idx, m, n)  # (M, n)

    def client_run(params, client_data, order):
        def lstep(x, i):
            batch = jax.tree.map(lambda leaf: leaf[i], client_data)
            g = jax.grad(loss_fn)(x, batch)
            return jax.tree.map(lambda xi, gi: xi - gamma * gi, x, g), None

        xn, _ = jax.lax.scan(lstep, params, order)
        return xn

    xns = jax.vmap(client_run, in_axes=(None, 0, 0))(state.params, data, idx)
    # g_{t,m} = (x_t - x^n_{t,m}) / (gamma * n)   (Alg. 4/5 line 7)
    g = jax.tree.map(lambda p, xn: (p - xn) / (gamma * n), state.params, xns)

    if spec.shift_mode == "none":
        ghat = _compress_clients(comp, k_comp, g, backend)
        shifts, server_h = state.shifts, state.server_h
        direction = tree_mean_clients(ghat)
    elif spec.shift_mode == "single":
        delta = tree_sub(g, state.shifts)
        qd = _compress_clients(comp, k_comp, delta, backend)
        mean_qd = tree_mean_clients(qd)
        # \hat g_t = h_t + (1/M) sum_m Q(g_{t,m} - h_{t,m})   (Alg. 5 line 11)
        # fused: direction = H + mean_Q and H' = H + alpha*mean_Q in one pass
        direction, _, server_h = backend.tree_diana_shift(
            state.server_h, mean_qd, state.server_h, mean_qd, alpha=alpha
        )
        # the (M, d) client shifts only need the axpy — a fused call here
        # would write two discarded M-times-param-sized outputs
        shifts = jax.tree.map(lambda h, q: h + alpha * q, state.shifts, qd)
    else:
        raise ValueError(spec.shift_mode)

    step = eta if spec.server_stepsize else gamma * n
    params = jax.tree.map(lambda p, d: p - step * d, state.params, direction)
    bits_per_round = float(m * tree_compression_bits(comp, state.params))
    return state._replace(
        params=params,
        shifts=shifts,
        server_h=server_h,
        rounds=state.rounds + 1,
        bits=state.bits + bits_per_round,
    )


# ---------------------------------------------------------------------------
# public factory
# ---------------------------------------------------------------------------

def make_epoch_fn(name: str, loss_fn: LossFn, compressor=None, *, gamma: float,
                  eta: float | None = None, alpha: float | None = None,
                  backend: str | CompressionBackend | None = None):
    """Return (spec, epoch_fn) for algorithm `name`.

    epoch_fn(state, data, key, order=None) -> FedState runs one full data
    epoch (n communication rounds for non-local methods, 1 for local
    methods). `order` is an optional (M, n) batch-index matrix from the
    host-side pipeline (`data.pipeline.run_epochs` passes the stateless
    `ReshuffleSampler`'s epoch order — Shuffle-Once for DIANA-RR included);
    without it the epoch draws its own on-device order per `spec.sampling`.

    `backend` selects the compression execution path ("reference" |
    "pallas"); default follows $REPRO_COMPRESSION_BACKEND, then "pallas"
    (interpret mode on CPU, Mosaic on TPU) — see repro.compression.backend.
    """
    spec = ALGORITHMS[name]
    be = get_backend(backend)
    comp = compressor
    if comp is None or not spec.default_compressed and compressor is None:
        comp = Identity()
    if alpha is None:
        # Theorems 2/4: alpha <= 1/(1+omega); identity => alpha=1
        try:
            om = max(comp.omega(1024), 0.0)
        except Exception:
            om = 0.0
        alpha = 1.0 / (1.0 + (0.0 if om != om else om))  # NaN-safe (TopK)
    if eta is None:
        eta = gamma  # caller should set for server-stepsize methods

    if spec.family == "nonlocal":
        def epoch(state, data, key, order=None):
            return _nonlocal_epoch(spec, loss_fn, comp, gamma, alpha, be,
                                   state, data, key, order)
    else:
        def epoch(state, data, key, order=None):
            return _local_epoch(spec, loss_fn, comp, gamma, eta, alpha, be,
                                state, data, key, order)

    return spec, epoch


def theoretical_stepsizes(name: str, *, l_max: float, mu: float, omega: float,
                          m: int, n: int) -> dict[str, float]:
    """Largest stepsizes allowed by Theorems 1-4 (and the baselines' papers).

    The paper tunes a constant multiplier on top of these; we return the raw
    theory values.
    """
    if name in ("q_rr", "rr"):
        return {"gamma": 1.0 / ((1.0 + 2.0 * omega / m) * l_max)}
    if name == "qsgd" or name == "sgd":
        return {"gamma": 1.0 / ((1.0 + 2.0 * omega / m) * l_max)}
    if name == "diana_rr":
        alpha = 1.0 / (1.0 + omega)
        gamma = min(alpha / (2.0 * n * mu), 1.0 / ((1.0 + 6.0 * omega / m) * l_max))
        return {"gamma": gamma, "alpha": alpha}
    if name == "diana":
        alpha = 1.0 / (1.0 + omega)
        gamma = 1.0 / ((1.0 + 6.0 * omega / m) * l_max)
        return {"gamma": gamma, "alpha": alpha}
    if name in ("q_nastya", "fedcom", "nastya"):
        eta = 1.0 / (16.0 * l_max * (1.0 + omega / m))
        gamma = 1.0 / (5.0 * n * l_max)
        return {"gamma": gamma, "eta": eta}
    if name == "diana_nastya":
        alpha = 1.0 / (1.0 + omega)
        eta = min(alpha / (2.0 * mu), 1.0 / (16.0 * l_max * (1.0 + 9.0 * omega / m)))
        gamma = min(1.0 / (16.0 * l_max * n), eta / n)
        return {"gamma": gamma, "eta": eta, "alpha": alpha}
    if name in ("fedavg", "fedrr", "fedpaq"):
        return {"gamma": 1.0 / (5.0 * n * l_max)}
    raise ValueError(name)
