"""The paper's primary contribution: federated optimization with random
reshuffling and gradient compression (Q-RR, DIANA-RR, Q-NASTYA,
DIANA-NASTYA) plus every baseline it compares against, as composable JAX
modules — a faithful simulator (`algorithms`) and the TPU-pod production
wire (`dist`)."""
from repro.core.api import FedState, init_state
from repro.core.algorithms import (
    ALGORITHMS,
    AlgoSpec,
    init_algorithm,
    make_epoch_fn,
    make_round_fn,
    run_fleet_rounds,
    theoretical_stepsizes,
)
from repro.core.dist import CompressedAggregation, DianaState
from repro.core.rules import RULES, WIRE_RULES, ShiftRule, get_rule

__all__ = [
    "FedState",
    "init_state",
    "ALGORITHMS",
    "AlgoSpec",
    "init_algorithm",
    "make_epoch_fn",
    "make_round_fn",
    "run_fleet_rounds",
    "theoretical_stepsizes",
    "CompressedAggregation",
    "DianaState",
    "ShiftRule",
    "RULES",
    "WIRE_RULES",
    "get_rule",
]
