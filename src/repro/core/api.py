"""Core API for federated optimization algorithms.

The simulator treats the federated system exactly as the paper does:
`M` clients, each holding `n` minibatches; communication rounds alternate
client computation with (possibly compressed) aggregation. Everything is a
pytree and every driver is a pure `epoch(state, data, key, order=None) ->
state` function, so algorithms compose with jit/vmap/scan and run unchanged
under `shard_map` (see `repro.core.dist` for the pod execution path).
`order` is the epoch's (M, n) batch-index matrix from the host-side
pipeline (`repro.data.pipeline.run_epochs` — the same stateless sampler
the production stream consumes); omitted, the driver draws on device.

Data layout: a *client-stacked* pytree whose leaves have shape
``(M, n, *batch_shape)`` — M clients, n minibatches each (paper assumes equal
n; `repro.data` pads uneven datasets the same way the paper's code assigns the
remainder to the last worker).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Any
Batch = Any
LossFn = Callable[[Params, Batch], jax.Array]


class FedState(NamedTuple):
    """State carried across communication rounds.

    shifts:    DIANA-style control variates. Layout depends on the algorithm:
               - None                       (no variance reduction)
               - leaves (M, *param_shape)   (DIANA, DIANA-NASTYA: 1/worker)
               - leaves (M, n, *param_shape)(DIANA-RR: n shift vectors/worker)
    server_h:  running mean shift  h_t = (1/M) sum_m h_{t,m}  (DIANA-NASTYA
               server bookkeeping; None elsewhere).
    rounds:    communication rounds elapsed (int32 scalar).
    bits:      cumulative uplink bits actually sent by all clients. Stored as
               a compensated (Kahan) float32 pair — `bits` is the running
               total, `bits_lo` the compensation term — because a plain f32
               accumulator silently stops incrementing once the total passes
               ~2^24 x the per-round increment (24-bit mantissa), and jax's
               default x64-disabled mode truncates a requested float64 back
               to f32. The pair gives float64-grade accumulation (~48
               effective mantissa bits); update via `accumulate_bits`.
    """

    params: Params
    shifts: Any
    server_h: Any
    rounds: jax.Array
    bits: jax.Array
    # np.float32 (not a Python float): a weak-typed 0.0 default would
    # promote hand-built states under tree maps against init_state's f32
    # scalar (np is used so importing this module never initializes jax
    # device state — the dry-run contract, DESIGN.md §6)
    bits_lo: jax.Array = np.float32(0.0)


def init_state(params: Params, shifts: Any = None, server_h: Any = None) -> FedState:
    return FedState(
        params=params,
        shifts=shifts,
        server_h=server_h,
        rounds=jnp.zeros((), jnp.int32),
        bits=jnp.zeros((), jnp.float32),
        bits_lo=jnp.zeros((), jnp.float32),
    )


def accumulate_bits(bits, bits_lo, inc):
    """Compensated (Kahan-Neumaier style) f32 add: (bits', bits_lo').

    Exactly the classic two-term recurrence: the low word keeps whatever the
    high-word add rounded away, so increments of ~1e7 bits keep landing even
    when the running total is >2^24 x larger. Works under jit — XLA does not
    reassociate float adds, so `(t - bits) - y` is not folded to zero.
    """
    y = inc - bits_lo
    t = bits + y
    return t, (t - bits) - y


# ---------------------------------------------------------------------------
# pytree helpers (the lingua franca of every driver below)
# ---------------------------------------------------------------------------

def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def tree_axpy(alpha, x, y):
    """alpha * x + y."""
    return jax.tree.map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_mean_clients(tree):
    """Mean over the leading client axis of every leaf."""
    return jax.tree.map(lambda x: jnp.mean(x, axis=0), tree)


def tree_stack_clients(tree, m: int):
    """Broadcast a pytree to M stacked client copies."""
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (m,) + x.shape), tree)


def tree_dot(a, b) -> jax.Array:
    parts = jax.tree.map(lambda x, y: jnp.vdot(x, y), a, b)
    return jax.tree.reduce(jnp.add, parts)


def tree_sqnorm(a) -> jax.Array:
    return tree_dot(a, a)


def client_batch(data, m_idx, i_idx):
    """Select minibatch i of client m from a client-stacked data pytree."""
    return jax.tree.map(lambda leaf: leaf[m_idx, i_idx], data)


def round_batches(data, perm_column):
    """Batch `perm[m, i]` for every client m (one synchronous round).

    perm_column: (M,) int32 — the i-th column of this epoch's permutations.
    Returns leaves of shape (M, *batch_shape).
    """
    m = perm_column.shape[0]
    return jax.tree.map(lambda leaf: leaf[jnp.arange(m), perm_column], data)


def num_clients(data) -> int:
    return jax.tree.leaves(data)[0].shape[0]


def num_batches(data) -> int:
    return jax.tree.leaves(data)[0].shape[1]


def sample_permutations(key: jax.Array, m: int, n: int) -> jax.Array:
    """Independent per-client permutations of [n] — the 'RR' in Q-RR."""
    keys = jax.random.split(key, m)
    return jax.vmap(lambda k: jax.random.permutation(k, n))(keys)


def clients_grad(loss_fn: LossFn, params, batches):
    """Per-client gradients: vmap(grad) over stacked client batches.

    params are shared (the server iterate); batches leaves are (M, ...).
    Returns a pytree with leaves (M, *param_shape).
    """
    g = jax.vmap(jax.grad(loss_fn), in_axes=(None, 0))(params, batches)
    return g


def clients_grad_at(loss_fn: LossFn, params_stacked, batches):
    """Per-client gradients at per-client iterates (local methods)."""
    return jax.vmap(jax.grad(loss_fn))(params_stacked, batches)
