"""The shift-rule layer: one source of truth for shift/control-variate
arithmetic across BOTH execution paths (DESIGN.md §3.8).

The paper's design space varies exactly one thing between methods: what a
client remembers between rounds and how that memory shapes what crosses the
wire. Four rules cover every method in the repo:

``NoShift``      no memory: send Q(g)                 (SGD/QSGD/RR/Q-RR, 'q')
``SingleShift``  one DIANA control variate h per client: send Q(g - h),
                 h += alpha*Q  (DIANA, DIANA-NASTYA, wire method 'diana')
``PerSlotShift`` a table of n control variates per client, the round's batch
                 index selects the slot (DIANA-RR Algorithm 3, wire method
                 'diana_rr')
``EfRule``       error feedback (Stich et al. 2018): memory is the
                 compression residual e; send C(gamma*g + e), keep what the
                 compressor dropped ('ef_topk_rr', wire method 'ef')

Both consumers dispatch through the same instances:

- the simulator drivers (`core.algorithms._nonlocal_epoch`/`_local_epoch`)
  call the rules on whole client-stacked pytrees (leaves `(M, ...)`, the
  per-slot index is `(arange(M), col)`);
- the production wire (`core.dist.CompressedAggregation._level`) calls them
  per leaf inside the fully-manual shard_map region (the client axis is the
  mesh, the per-slot index is the round's shared scalar slot).

That polymorphism is free because every rule method is either a
`jax.tree.map` (works on bare arrays — an array is a pytree) or dispatches
to the compression backend, which has tree (`tree_diana_shift`, one fused
kernel launch over the raveled buffer) and flat (`diana_shift_flat`) entry
points for the same fused DIANA update.

Slot semantics on the wire: every rank of a wire level must use the SAME
slot in a given round (the mean-shift table update `mh[s] += alpha*q_mean`
is only locally computable when all ranks touch the same row s; per-rank
slots would need a dense collective of `h_m[slot_m]`, forfeiting the sparse
wire). The data side provides this via `ReshuffleSampler(mode="rr_shared")`
— one permutation per epoch shared by every client — and
`data.pipeline.shared_slots_for_step`. The simulator keeps the paper-exact
independent per-client permutations (everything is on one device there).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Index = Any  # tuple of index arrays applied as table[idx], or None


def _lead_zeros(params, lead: tuple[int, ...], dtype):
    return jax.tree.map(
        lambda p: jnp.zeros(lead + p.shape, dtype or p.dtype), params)


@dataclasses.dataclass(frozen=True)
class ShiftRule:
    """Protocol + shared plumbing for the four rules.

    Capability flags drive state allocation in both consumers:

    has_shifts      the rule keeps per-client/rank memory
    has_mean        the rule keeps a running mean table (the wire's
                    `mean_shift`; the local family's `server_h`)
    needs_server_h  the simulator allocates `FedState.server_h`
    slotted         memory tables carry a leading slot axis
    supports_local  legal in the local (NASTYA) simulator family
    contractive     the wire must apply the UNSCALED (contractive)
                    compression to this rule's payload (EF diverges under
                    the unbiased d/k-scaled reconstruction)
    """

    name: str = "none"
    has_shifts: bool = False
    has_mean: bool = False
    needs_server_h: bool = False
    slotted: bool = False
    supports_local: bool = True
    contractive: bool = False

    # -- state layout ---------------------------------------------------------

    def init_shifts(self, params, m: int | None = None, *, n_slots: int = 1,
                    dtype=None):
        """Zero memory tables shaped for this rule.

        m=None gives the wire layout (per-rank local blocks, no client
        axis); an integer m prepends the stacked client axis (simulator /
        TrainState layouts). Slotted rules insert the `n_slots` axis next.
        """
        del n_slots, dtype  # analysis: allow[ignored-argument] stateless rule keeps no tables
        del params, m  # analysis: allow[ignored-argument] stateless rule keeps no tables
        return None

    # -- per-round arithmetic -------------------------------------------------

    def select(self, shifts, idx: Index):
        """The active memory view for this round (slot tables index here)."""
        del idx  # analysis: allow[ignored-argument] unslotted tables have one view
        return shifts

    def payload(self, g, h, *, gamma: float = 1.0):
        """What goes through the compressor."""
        del h, gamma  # analysis: allow[ignored-argument] shift-free payload is the raw gradient
        return g

    def update(self, h, q_own, mh, q_mean, *, alpha: float,
               beta: float | None = None, gamma: float = 1.0, backend,
               payload=None):
        """Post-compression arithmetic: (direction, h_new, mh_new).

        h/q/mh are matching pytrees (the simulator passes whole stacked
        trees; the wire passes single leaves). `q_own` is this client's
        compressed message, `q_mean` the aggregated one; the simulator's
        per-client view passes the same tree for both. `beta` is the
        mean-table stepsize (defaults to alpha); cohort-sampled fleets use
        beta = (M/C)*alpha so the resident mean tracks the population mean.
        """
        del h, q_own, mh, alpha, beta, gamma, backend, payload  # analysis: allow[ignored-argument] memory-free rule: direction is the aggregate itself
        return q_mean, None, None

    def scatter(self, shifts, idx: Index, h_new):
        """Write the round's updated memory back into the table."""
        del idx, h_new  # analysis: allow[ignored-argument] no tables to write back
        return shifts

    # -- local (NASTYA) family server side ------------------------------------

    def direction(self, server_h, q_mean, *, alpha: float, gamma: float = 1.0,
                  backend):
        """(direction, new_server_h) from the aggregated epoch message."""
        del alpha, gamma, backend  # analysis: allow[ignored-argument] shift-free server applies the aggregate directly
        return q_mean, server_h

    def table_axpy(self, shifts, q, *, alpha: float):
        """Local-family client-table update h += alpha*q (the fused kernel
        would write discarded M-times-param-sized outputs here)."""
        del q, alpha  # analysis: allow[ignored-argument] no client tables to update
        return shifts


@dataclasses.dataclass(frozen=True)
class NoShift(ShiftRule):
    name: str = "none"


@dataclasses.dataclass(frozen=True)
class SingleShift(ShiftRule):
    """DIANA: one control variate per client, one mean per server/level."""

    name: str = "single"
    has_shifts: bool = True
    has_mean: bool = True
    needs_server_h: bool = True

    def init_shifts(self, params, m=None, *, n_slots=1, dtype=None):
        del n_slots  # analysis: allow[ignored-argument] unslotted: one shift per client
        return _lead_zeros(params, () if m is None else (m,), dtype)

    def payload(self, g, h, *, gamma: float = 1.0):
        del gamma  # analysis: allow[ignored-argument] DIANA payload g-h is stepsize-free
        return jax.tree.map(jnp.subtract, g, h)

    def update(self, h, q_own, mh, q_mean, *, alpha, beta=None, gamma=1.0,
               backend, payload=None):
        del gamma, payload  # analysis: allow[ignored-argument] fused DIANA update needs only alpha/beta
        # the fused path: direction = H + Q_mean, h' = h + alpha*Q_own,
        # H' = H + beta*Q_mean in ONE pass (kernels/diana_shift.py)
        if isinstance(h, jax.Array):
            return backend.diana_shift_flat(h, q_own, mh, q_mean, alpha=alpha,
                                            beta=beta)
        return backend.tree_diana_shift(h, q_own, mh, q_mean, alpha=alpha,
                                        beta=beta)

    def scatter(self, shifts, idx, h_new):
        del shifts, idx  # analysis: allow[ignored-argument] unslotted table IS the round's view
        return h_new

    def direction(self, server_h, q_mean, *, alpha, gamma=1.0, backend):
        d, _, new_h = self.update(server_h, q_mean, server_h, q_mean,
                                  alpha=alpha, gamma=gamma, backend=backend)
        return d, new_h

    def table_axpy(self, shifts, q, *, alpha):
        return jax.tree.map(lambda h, qi: h + alpha * qi, shifts, q)


@dataclasses.dataclass(frozen=True)
class PerSlotShift(SingleShift):
    """DIANA-RR (Algorithm 3): n control variates per client; the batch
    index selects which one a round reads and writes. Same fused update as
    SingleShift — only the table layout and the select/scatter differ."""

    name: str = "per_slot"
    slotted: bool = True
    needs_server_h: bool = False
    supports_local: bool = False

    def init_shifts(self, params, m=None, *, n_slots=1, dtype=None):
        lead = (() if m is None else (m,)) + (n_slots,)
        return _lead_zeros(params, lead, dtype)

    def select(self, shifts, idx):
        if idx is None:
            idx = (0,)  # slot-less rounds (the NASTYA epoch gradient)
        return jax.tree.map(lambda s: s[idx], shifts)

    def scatter(self, shifts, idx, h_new):
        if idx is None:
            idx = (0,)
        return jax.tree.map(lambda s, hn: s.at[idx].set(hn), shifts, h_new)


@dataclasses.dataclass(frozen=True)
class EfRule(ShiftRule):
    """Error feedback: memory is the compression residual. Needs a
    CONTRACTIVE compressor (Top-k in the simulator; the wire applies the
    unscaled Rand-block window, contraction factor k/d).

    The simulator form is p = gamma*g + e, direction = C(p)/gamma (the
    common `params - gamma*direction` update divides gamma back out); the
    wire passes gamma=1 — identical trajectories for positively homogeneous
    compressors (C(cx) = c·C(x), true of Top-k/Rand-k/QSGD), since e then
    just carries a constant gamma factor.
    """

    name: str = "ef"
    has_shifts: bool = True
    supports_local: bool = False
    contractive: bool = True

    def init_shifts(self, params, m=None, *, n_slots=1, dtype=None):
        del n_slots  # analysis: allow[ignored-argument] EF keeps one residual per client
        return _lead_zeros(params, () if m is None else (m,), dtype)

    def payload(self, g, h, *, gamma: float = 1.0):
        return jax.tree.map(lambda gi, e: gamma * gi + e, g, h)

    def update(self, h, q_own, mh, q_mean, *, alpha, beta=None, gamma=1.0,
               backend, payload=None):
        del h, alpha, beta, backend  # analysis: allow[ignored-argument] EF memory is payload-q, no stepsize
        direction = q_mean if gamma == 1.0 else jax.tree.map(
            lambda q: q / gamma, q_mean)
        new_e = jax.tree.map(jnp.subtract, payload, q_own)
        return direction, new_e, mh

    def scatter(self, shifts, idx, h_new):
        del shifts, idx  # analysis: allow[ignored-argument] residual table IS the round's view
        return h_new


RULES: dict[str, ShiftRule] = {
    "none": NoShift(),
    "single": SingleShift(),
    "per_slot": PerSlotShift(),
    "ef": EfRule(),
}

# production wire method name -> rule ('dense' skips compression entirely
# but shares NoShift's no-memory semantics)
WIRE_RULES: dict[str, ShiftRule] = {
    "dense": RULES["none"],
    "q": RULES["none"],
    "diana": RULES["single"],
    "diana_rr": RULES["per_slot"],
    "ef": RULES["ef"],
}


def get_rule(name: str) -> ShiftRule:
    try:
        return RULES[name]
    except KeyError:
        raise ValueError(
            f"unknown shift rule {name!r}; options: {sorted(RULES)}")
