"""Central RNG salt registry — the single home for stream-separation
constants (DESIGN.md §3.12).

Every convergence claim in the paper holds only if each stochastic draw is a
pure function of a structured entropy tuple ``(seed, salt, round/epoch)``.
The *salt* is what keeps independent channels (wire levels, fault channels,
dataset synthesis, cohort baselines) from silently sharing a stream when a
user reuses the same integer seed across subsystems. Scattering salt
literals across modules is how collisions happen without anyone noticing;
this registry makes every salt a named, uniqueness-checked constant, and the
static analyzer (`repro.analysis`, rule ``rng-literal-salt``) rejects any
numeric salt literal outside this file.

Import the NAMES, never restate the values. `_register` raises at import
time on a duplicate value or name, and tests/test_analysis.py pins the
registry's global uniqueness.

`root_key(seed, salt)` is the sanctioned way to construct a jax root key:
`jax.random.key(seed)` folded with a named salt, so two subsystems seeded
with the same integer still draw from disjoint key trees (rule
``rng-unstructured-seed`` flags bare `jax.random.key(...)` construction
anywhere else in the package).
"""
from __future__ import annotations

_REGISTRY: dict[str, int] = {}


def _register(name: str, value: int) -> int:
    if name in _REGISTRY:
        raise ValueError(f"salt {name!r} registered twice")
    if value in _REGISTRY.values():
        clash = next(k for k, v in _REGISTRY.items() if v == value)
        raise ValueError(
            f"salt value {value:#x} of {name!r} collides with {clash!r} — "
            "two channels would share an entropy stream")
    _REGISTRY[name] = int(value)
    return int(value)


def registered_salts() -> dict[str, int]:
    """Name -> value snapshot (the uniqueness test and the linter read it)."""
    return dict(_REGISTRY)


# -- wire (repro.core.dist) --------------------------------------------------
# folded into the round key to derive the inter-pod (outer) wire key: the two
# levels' coordinate draws must be independent (the composed variance bound
# is a tower-rule product of two independent expectations)
POD_KEY_SALT = _register("POD_KEY_SALT", 0x70D5)
# folded into the per-leaf wire key to derive the stochastic-rounding uniforms
# of the quantized/packed wire (wire_levels / wire_dtype on
# CompressedAggregation): the rounding draw must be independent of the
# coordinate-window draw that shares the same leaf key, and — like the window
# — SHARED across the level's ranks, so every rank packs and unpacks the same
# byte lattice
WIRE_QUANT_SALT = _register("WIRE_QUANT_SALT", 0xB175)

# -- NASTYA sub-streams (repro.launch.steps) ---------------------------------
# the round key rkey = fold_in(key, step) splits into per-purpose sub-streams:
# the per-pod micro-epoch permutation draw, and one key per local micro-step
# (consecutive salts NASTYA_LOCAL_SALT + t for t in range(local_steps); the
# registry entry reserves the base — local_steps stays far below any other
# registered value, and the permutation salt sits below the base).
NASTYA_PERM_SALT = _register("NASTYA_PERM_SALT", 1)
NASTYA_LOCAL_SALT = _register("NASTYA_LOCAL_SALT", 2)

# -- fleet (repro.fleet.cohort / repro.fleet.chaos) --------------------------
# 3-element entropy tuple (seed, WR_COHORT_SALT, round) for the i.i.d.
# with-replacement baseline — disjoint from the 2-element (seed, epoch)
# sequences the 'rr' mode draws from
WR_COHORT_SALT = _register("WR_COHORT_SALT", 0x5EED)
# the three independent fault channels (darkness, latency, store I/O) never
# share a stream even under one chaos seed
CHAOS_DROP_SALT = _register("CHAOS_DROP_SALT", 0xD42C)
CHAOS_LATENCY_SALT = _register("CHAOS_LATENCY_SALT", 0x1A7E)
CHAOS_IO_SALT = _register("CHAOS_IO_SALT", 0x10FA)

# -- dataset synthesis (launch.train modality stubs) -------------------------
# salted so seed-0 stub extras never alias the (seed, epoch) sampler streams.
# NOTE: the repro.data token/logreg generators deliberately keep their
# seed-era unsalted streams (inline-allowed at the call sites) — their draws
# ARE the pinned datasets the suite's convergence floors were calibrated on.
MODALITY_STUB_SALT = _register("MODALITY_STUB_SALT", 0x3D0D)

# -- jax root keys (repro.launch) --------------------------------------------
PARAMS_KEY_SALT = _register("PARAMS_KEY_SALT", 0x9A2A)
ROUNDS_KEY_SALT = _register("ROUNDS_KEY_SALT", 0x207D)
SERVE_KEY_SALT = _register("SERVE_KEY_SALT", 0x5E2E)


def root_key(seed: int, salt: int):
    """Structured jax root key: key(seed) folded with a registry salt.

    The only sanctioned `jax.random.key` construction site in the package
    (DESIGN.md §3.12). jax is imported lazily so importing this module never
    initializes device state (the dry-run contract, DESIGN.md §6).
    """
    import jax

    return jax.random.fold_in(jax.random.key(seed), salt)
