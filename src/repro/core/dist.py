"""Production compressed-gradient aggregation for TPU pods.

This is the paper's communication layer rethought for ICI collectives
(DESIGN.md §3). Two wire modes:

``independent`` (paper-exact semantics)
    Every client Rand-k-compresses its own gradient with an *independent*
    key (paper Assumption 1 + the 1/M variance factor in Theorems 1-2), then
    the results are averaged with a dense ``psum``. On TPU the zeros travel
    too — the collective term does not shrink; this is the faithful baseline
    recorded in EXPERIMENTS.md §Perf.

``shared`` (TPU-native sparse collective — beyond-paper optimization)
    All clients draw the *same* coordinate block per round (shared PRNG
    seed). Then only the k selected values are psum'd: collective bytes drop
    by d/k (~50x at the paper's k/d≈0.02). Coordinates are a contiguous
    random block of whole 8-row groups ("Rand-block", DESIGN.md §3.2):
    uniform marginal inclusion probability k/d gives exactly the Rand-k
    variance bound omega = d/k - 1 (the second moment only needs marginals),
    while the gather/scatter runs through the Pallas circular row-block
    kernels (`repro.kernels.randk`) dispatched by the compression backend
    (DESIGN.md §3.5) — k_blocks sequential VMEM copies driven by one
    prefetched scalar, instead of a `jnp.roll` of the full leaf. Because
    coordinates are shared, mean_m Q(d_m) == Q(mean_m d_m): the omega/M
    factor of the paper becomes omega applied to the already-averaged vector
    — still Assumption-1 compliant per round, and with DIANA shifts the
    compressed residual d_m -> 0 so the fixed point is unchanged (Theorem 2
    logic carries over).

Two-level (pod) hierarchy (DESIGN.md §3.6):

    When `pod_axes` is non-empty the wire is HIERARCHICAL. The inner level
    runs the exchange above over `client_axes` (the ranks inside one pod,
    fast ICI); the outer level runs a second, *independently keyed*
    compressed exchange over `pod_axes` (the slow inter-pod links), applied
    to the inner level's output. DIANA shifts exist at both levels
    (`DianaState.shifts/mean_shift` inner, `pod_shifts/pod_mean_shift`
    outer), so both compressed residuals -> 0 and the fixed point is still
    the exact mean. The composed operator is unbiased with second moment
    (1+omega_1)(1+omega_2)||x||^2 (tower rule over the two independent
    draws). With a single pod (`pod_size == 1`) there is no inter-pod link,
    so the outer exchange degrades to the identity — the two-level wire
    bit-matches the flat wire (tests/test_pod_wire.py parity test).

    `client_axes=()` is also allowed: each outer rank is a pod of one
    client, which is exactly the paper's Algorithms 4-5 layout when the
    launch layer maps NASTYA local epochs onto the mesh (launch/steps.py).

Aggregation methods (paper Secs. 2.1-2.2, production variants):

- ``dense``     plain mean gradient (no compression) — sanity baseline
- ``q``         Q-RR-style: direction = mean_m Q(g_m)
- ``diana``     DIANA-RR-style with one shift per client (the n-shift variant
                is exercised in the simulator; one shift per round-gradient is
                the production memory-feasible choice, DESIGN.md §3.3):
                    direction = H_t + mean_m Q(g_m - h_m)
                    h_m   += alpha * Q(g_m - h_m)
                    H_t+1  = H_t + alpha * mean_m Q(g_m - h_m)

All functions are designed to run INSIDE a `shard_map` body whose manual axes
include the client/pod axes; gradients arrive as this device's local block of
the parameter pytree, and `lax.pmean` over the level's axes is the server.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.compression.backend import get_backend
from repro.kernels.randk import BLOCK_ROWS

# salt folded into the round key to derive the inter-pod (outer) wire key —
# the two levels' coordinate draws must be independent (the composed variance
# bound is a tower-rule product of two independent expectations)
POD_KEY_SALT = 0x70D5


class DianaState(NamedTuple):
    """Per-device compression state (local blocks of param-shaped trees).

    `shifts`/`mean_shift` are the inner (intra-pod) level: h_m per client
    rank and their per-pod running mean. `pod_shifts`/`pod_mean_shift` are
    the outer (inter-pod) level: one shift per pod and the global mean.
    Unused levels hold None (flat wire: pod_* is None; pod-granular NASTYA
    with `client_axes=()`: the inner pair is None).
    """

    shifts: Any  # h_m: this client's shift (differs across client_axes)
    mean_shift: Any  # H_t = (1/M) sum_m h_m (identical within a pod)
    pod_shifts: Any = None  # h_p: this pod's shift (differs across pod_axes)
    pod_mean_shift: Any = None  # (1/P) sum_p h_p (identical everywhere)


@dataclasses.dataclass(frozen=True)
class CompressedAggregation:
    """Config + pure functions for the production gradient wire."""

    method: str = "diana"  # 'dense' | 'q' | 'diana'
    wire: str = "shared"  # 'shared' | 'independent'
    fraction: float = 0.02  # k/d on the intra-pod (inner) wire
    alpha: float | None = None  # shift stepsize; None -> 1/(1+omega) (Thm 2)
    shift_dtype: Any = jnp.bfloat16
    client_axes: tuple[str, ...] = ("data",)  # inner level (ranks in a pod)
    pod_axes: tuple[str, ...] = ()  # outer level; () = flat single-level wire
    pod_size: int = 1  # static product of pod_axes sizes (1 = no inter-pod link)
    pod_fraction: float | None = None  # inter-pod k/d; None -> `fraction`
    pod_alpha: float | None = None  # pod shift stepsize; None -> 1/(1+omega_pod)
    backend: str | None = None  # 'reference' | 'pallas' | None (env/default)

    # -- state ---------------------------------------------------------------

    def init(self, local_params) -> DianaState | None:
        if self.method != "diana":
            return None
        zeros = lambda p: jnp.zeros(p.shape, self.shift_dtype)
        inner = bool(self.client_axes)
        outer = bool(self.pod_axes)
        return DianaState(
            shifts=jax.tree.map(zeros, local_params) if inner else None,
            mean_shift=jax.tree.map(zeros, local_params) if inner else None,
            pod_shifts=jax.tree.map(zeros, local_params) if outer else None,
            pod_mean_shift=jax.tree.map(zeros, local_params) if outer else None,
        )

    def omega(self) -> float:
        if self.method == "dense":
            return 0.0
        return 1.0 / self.fraction - 1.0

    def pod_omega(self) -> float:
        if self.method == "dense" or self.pod_size == 1:
            return 0.0
        f = self.fraction if self.pod_fraction is None else self.pod_fraction
        return 1.0 / f - 1.0

    @property
    def shift_lr(self) -> float:
        """alpha <= 1/(1+omega) (Theorem 2 / 4 condition)."""
        if self.alpha is not None:
            return self.alpha
        return 1.0 / (1.0 + self.omega())

    @property
    def pod_shift_lr(self) -> float:
        if self.pod_alpha is not None:
            return self.pod_alpha
        return 1.0 / (1.0 + self.pod_omega())

    @property
    def _pod_fraction(self) -> float:
        return self.fraction if self.pod_fraction is None else self.pod_fraction

    # -- per-leaf compression primitives --------------------------------------
    #
    # Compression operates on a ROW view of each leaf: (prod(shape[:-1]),
    # shape[-1]). The last axis is the tensor-parallel ("model") sharded axis
    # in every weight layout (DESIGN.md §5), so selecting whole rows never
    # reshards a leaf — the sparse collective runs directly on model-sharded
    # row slabs. Row selection is uniform, so the operator stays unbiased
    # with omega = n_rows/k_rows - 1 = 1/fraction - 1 (block-granular Rand-k).

    @staticmethod
    def _row_view(leaf):
        if leaf.ndim >= 2:
            return jnp.reshape(leaf, (-1, leaf.shape[-1]))
        return jnp.reshape(leaf, (-1, 1))

    def _k(self, size: int, fraction: float) -> int:
        return max(1, int(fraction * size))

    def _leaf_key(self, key, leaf_idx: int) -> jax.Array:
        return jax.random.fold_in(key, leaf_idx)

    # -- aggregation ----------------------------------------------------------

    def aggregate(self, grads, state: DianaState | None, key):
        """(direction, new_state); call inside shard_map over the wire axes.

        Composed two-level exchange: the inner (intra-pod) level over
        `client_axes` with `key`, then the outer (inter-pod) level over
        `pod_axes` with an independently salted key. Either level degrades
        to a passthrough when its axes are empty (flat wire / 1-client pod).
        """
        if self.method == "dense":
            axes = tuple(self.client_axes) + tuple(self.pod_axes)
            direction = jax.tree.map(lambda g: lax.pmean(g, axes), grads)
            return direction, state
        direction, state = self.aggregate_local(grads, state, key)
        return self.aggregate_pod(direction, state, key)

    def aggregate_local(self, grads, state: DianaState | None, key):
        """Inner level only: compressed exchange over `client_axes`.

        This is what each NASTYA local step runs — the pod's ranks psum
        their compressed gradients over the fast intra-pod ICI; the slow
        inter-pod wire is only touched once per epoch by `aggregate_pod`.
        """
        if self.method == "dense":
            direction = jax.tree.map(
                lambda g: lax.pmean(g, self.client_axes), grads
            )
            return direction, state
        if not self.client_axes:  # a pod of one client: no intra-pod wire
            return grads, state
        h = state.shifts if self.method == "diana" else None
        mh = state.mean_shift if self.method == "diana" else None
        dirs, new_h, new_mh = self._level(
            grads, h, mh, key,
            axes=self.client_axes,
            fold_axes=tuple(self.pod_axes) + tuple(self.client_axes),
            fraction=self.fraction, alpha=self.shift_lr,
        )
        if self.method == "diana":
            state = state._replace(shifts=new_h, mean_shift=new_mh)
        return dirs, state

    def aggregate_pod(self, direction, state: DianaState | None, key):
        """Outer level only: compressed exchange over `pod_axes`.

        `key` is the same round key given to `aggregate_local`; the actual
        coordinate draw uses fold_in(key, POD_KEY_SALT) so the two levels
        are independent. A single pod (`pod_size == 1`) has no inter-pod
        link: the exchange is the exact mean over the (size-1) pod axes —
        numerically the identity, which is what makes the 1-pod two-level
        wire bit-match the flat wire.
        """
        if not self.pod_axes or self.method == "dense":
            if self.pod_axes:
                direction = jax.tree.map(
                    lambda g: lax.pmean(g, self.pod_axes), direction
                )
            return direction, state
        if self.pod_size == 1:
            direction = jax.tree.map(
                lambda g: lax.pmean(g, self.pod_axes), direction
            )
            return direction, state
        pod_key = jax.random.fold_in(key, POD_KEY_SALT)
        h = state.pod_shifts if self.method == "diana" else None
        mh = state.pod_mean_shift if self.method == "diana" else None
        dirs, new_h, new_mh = self._level(
            direction, h, mh, pod_key,
            axes=self.pod_axes, fold_axes=tuple(self.pod_axes),
            fraction=self._pod_fraction, alpha=self.pod_shift_lr,
        )
        if self.method == "diana":
            state = state._replace(pod_shifts=new_h, pod_mean_shift=new_mh)
        return dirs, state

    # -- one exchange level ----------------------------------------------------

    def _level(self, grads, h_tree, mh_tree, key, *, axes, fold_axes,
               fraction, alpha):
        """One compressed exchange over `axes`: Q per rank, psum, (DIANA).

        Returns (direction_tree, new_shifts_tree, new_mean_shift_tree); the
        shift trees are None when h_tree is None (method 'q').
        """
        compress = (self._exchange_shared if self.wire == "shared"
                    else self._exchange_independent)
        leaves, treedef = jax.tree.flatten(grads)
        if h_tree is None:  # 'q': direction = mean_m Q(g_m)
            out = []
            for i, g in enumerate(leaves):
                _, q_mean = compress(self._leaf_key(key, i), g, axes,
                                     fold_axes, fraction)
                out.append(q_mean.astype(g.dtype))
            return jax.tree.unflatten(treedef, out), None, None

        # 'diana' — the shift/direction arithmetic runs through the fused
        # kernel (one pass over four inputs, three outputs) instead of five
        # separate param-sized HBM round-trips.
        be = get_backend(self.backend)
        h_leaves = jax.tree.leaves(h_tree)
        mh_leaves = jax.tree.leaves(mh_tree)
        dirs, new_h, new_mh = [], [], []
        for i, (g, h, mh) in enumerate(zip(leaves, h_leaves, mh_leaves)):
            delta = g.astype(jnp.float32) - h.astype(jnp.float32)
            q_own, q_mean = compress(self._leaf_key(key, i), delta, axes,
                                     fold_axes, fraction)
            direction, h_new, mh_new = be.diana_shift_flat(
                h.astype(self.shift_dtype), q_own.astype(jnp.float32),
                mh.astype(self.shift_dtype), q_mean.astype(jnp.float32),
                alpha=alpha,
            )
            new_h.append(h_new)
            new_mh.append(mh_new)
            dirs.append(direction.astype(g.dtype))
        return (jax.tree.unflatten(treedef, dirs),
                jax.tree.unflatten(treedef, new_h),
                jax.tree.unflatten(treedef, new_mh))

    # shared-seed Rand-block: sparse collectives -------------------------------
    #
    # The circular window is block-granular (whole BLOCK_ROWS=8 row groups)
    # so the gather/scatter maps onto the Pallas kernels' sublane-aligned
    # VMEM copies. Rows are zero-padded up to a block multiple; padding rows
    # travel (zeros) but never reach real coordinates on reconstruction.
    # Marginal inclusion probability is k_blocks/n_blocks for every real row
    # -> unbiased with the same omega formula (DESIGN.md §3.2).

    def _pad_rows(self, rows):
        pad = (-rows.shape[0]) % BLOCK_ROWS
        if pad:
            rows = jnp.pad(rows, ((0, pad), (0, 0)))
        return rows

    def _wire_geometry(self, n_rows_padded: int,
                       fraction: float) -> tuple[int, int]:
        nb = n_rows_padded // BLOCK_ROWS
        return nb, max(1, int(fraction * nb))

    def _exchange_shared(self, key, delta, axes, fold_axes, fraction):
        """Shared-key Rand-block exchange of one leaf over `axes`.

        Returns (q_own, q_mean) dense reconstructions. Only the k-row slab
        crosses the wire (the sparse collective runs inside the backend's
        `wire_exchange`); both reconstructions reuse the one start_block.
        """
        del fold_axes  # shared draw: every rank uses the same key
        be = get_backend(self.backend)
        rows = self._pad_rows(self._row_view(delta))
        nb, kb = self._wire_geometry(rows.shape[0], fraction)
        start_block = jax.random.randint(key, (), 0, nb)
        vals, mean_vals = be.wire_exchange(rows, start_block, k_blocks=kb,
                                           block_rows=BLOCK_ROWS, axes=axes)
        return (self._scatter_block(delta, start_block, vals),
                self._scatter_block(delta, start_block, mean_vals))

    def _scatter_block(self, template, start_block, vals):
        be = get_backend(self.backend)
        shape = self._row_view(template).shape
        n_padded = shape[0] + (-shape[0]) % BLOCK_ROWS
        dense = be.wire_decompress(vals, start_block, n_rows=n_padded,
                                   block_rows=BLOCK_ROWS)
        return jnp.reshape(dense[:shape[0]], template.shape)

    # independent-seed Rand-k: paper-exact, dense collectives ------------------

    def _exchange_independent(self, key, delta, axes, fold_axes, fraction):
        """Unbiased Rand-k over rows (with-replacement indices: omega <= n/k,
        avoids a full permutation sort on device; see DESIGN.md §3), one
        independent draw per rank (key folded with the rank's coordinates
        along `fold_axes`), then a dense psum over `axes`."""
        for ax in fold_axes:
            key = jax.random.fold_in(key, lax.axis_index(ax))
        rows = self._row_view(delta.astype(jnp.float32))
        n = rows.shape[0]
        k = self._k(n, fraction)
        idx = jax.random.randint(key, (k,), 0, n)
        vals = rows[idx] * (n / k)
        out = jnp.reshape(jnp.zeros_like(rows).at[idx].add(vals), delta.shape)
        return out, lax.pmean(out, axes)

    # -- wire accounting (benchmarks / EXPERIMENTS.md) -------------------------

    def wire_bytes_per_round(self, params) -> dict[str, int]:
        """Bytes one rank contributes to each wire level per round.

        'intra_pod' is the inner shared-wire slab (k-row blocks, f32);
        'inter_pod' the outer level's slab; 'dense' what an uncompressed
        psum of the same tree would move. The shared wire's sparse psum
        moves exactly the compressed slab; the independent wire moves the
        dense size regardless of k (the zeros travel — DESIGN.md §3.1).
        """
        dense = intra = inter = 0
        for leaf in jax.tree.leaves(params):
            rows = int(np.prod(leaf.shape[:-1])) if leaf.ndim >= 2 else int(
                np.prod(leaf.shape))
            cols = leaf.shape[-1] if leaf.ndim >= 2 else 1
            padded = rows + (-rows) % BLOCK_ROWS
            dense += rows * cols * jnp.dtype(leaf.dtype).itemsize
            if self.method == "dense" or self.wire == "independent":
                continue
            # the diana wire psums f32 deltas; 'q' slabs travel at leaf dtype
            slab_item = 4 if self.method == "diana" else jnp.dtype(
                leaf.dtype).itemsize
            nb, kb = self._wire_geometry(padded, self.fraction)
            if self.client_axes:
                intra += kb * BLOCK_ROWS * cols * slab_item
            if self.pod_axes and self.pod_size > 1:
                nb, kb = self._wire_geometry(padded, self._pod_fraction)
                inter += kb * BLOCK_ROWS * cols * slab_item
        if self.method != "dense" and self.wire == "independent":
            intra = dense if self.client_axes else 0
            inter = dense if (self.pod_axes and self.pod_size > 1) else 0
        return {"dense": dense, "intra_pod": intra, "inter_pod": inter}
