"""Production compressed-gradient aggregation for TPU pods.

This is the paper's communication layer rethought for ICI collectives
(DESIGN.md §3). Two wire modes:

``independent`` (paper-exact semantics)
    Every client Rand-k-compresses its own gradient with an *independent*
    key (paper Assumption 1 + the 1/M variance factor in Theorems 1-2), then
    the results are averaged with a dense ``psum``. On TPU the zeros travel
    too — the collective term does not shrink; this is the faithful baseline
    recorded in EXPERIMENTS.md §Perf.

``shared`` (TPU-native sparse collective — beyond-paper optimization)
    All clients draw the *same* coordinate block per round (shared PRNG
    seed). Then only the k selected values are psum'd: collective bytes drop
    by d/k (~50x at the paper's k/d≈0.02). Coordinates are a contiguous
    random block of whole 8-row groups ("Rand-block", DESIGN.md §3.2):
    uniform marginal inclusion probability k/d gives exactly the Rand-k
    variance bound omega = d/k - 1 (the second moment only needs marginals),
    while the gather/scatter runs through the Pallas circular row-block
    kernels (`repro.kernels.randk`) dispatched by the compression backend
    (DESIGN.md §3.5) — k_blocks sequential VMEM copies driven by one
    prefetched scalar, instead of a `jnp.roll` of the full leaf. Because
    coordinates are shared, mean_m Q(d_m) == Q(mean_m d_m): the omega/M
    factor of the paper becomes omega applied to the already-averaged vector
    — still Assumption-1 compliant per round, and with DIANA shifts the
    compressed residual d_m -> 0 so the fixed point is unchanged (Theorem 2
    logic carries over).

Two-level (pod) hierarchy (DESIGN.md §3.6):

    When `pod_axes` is non-empty the wire is HIERARCHICAL. The inner level
    runs the exchange above over `client_axes` (the ranks inside one pod,
    fast ICI); the outer level runs a second, *independently keyed*
    compressed exchange over `pod_axes` (the slow inter-pod links), applied
    to the inner level's output. DIANA shifts exist at both levels
    (`DianaState.shifts/mean_shift` inner, `pod_shifts/pod_mean_shift`
    outer), so both compressed residuals -> 0 and the fixed point is still
    the exact mean. The composed operator is unbiased with second moment
    (1+omega_1)(1+omega_2)||x||^2 (tower rule over the two independent
    draws). With a single pod (`pod_size == 1`) there is no inter-pod link,
    so the outer exchange degrades to the identity — the two-level wire
    bit-matches the flat wire (tests/test_pod_wire.py parity test).

    `client_axes=()` is also allowed: each outer rank is a pod of one
    client, which is exactly the paper's Algorithms 4-5 layout when the
    launch layer maps NASTYA local epochs onto the mesh (launch/steps.py).

Aggregation methods (paper Secs. 2.1-2.2, production variants). The
shift/memory arithmetic of every method lives in ONE place — the shift-rule
layer (`repro.core.rules`, DESIGN.md §3.8) shared with the simulator; this
module only owns the wire (compression geometry, collectives, key derivation):

- ``dense``     plain mean gradient (no compression) — sanity baseline
- ``q``         Q-RR-style: direction = mean_m Q(g_m)           (NoShift)
- ``diana``     DIANA with one shift per client               (SingleShift):
                    direction = H_t + mean_m Q(g_m - h_m)
                    h_m   += alpha * Q(g_m - h_m)
                    H_t+1  = H_t + alpha * mean_m Q(g_m - h_m)
- ``diana_rr``  DIANA-RR (paper Algorithm 3) with an n_slots-entry shift
                table per rank (PerSlotShift): the round's shared batch
                index selects which control variate the exchange reads and
                updates. Requires every rank of a wire level on the SAME
                slot per round — the `rr_shared` sampler order; see the
                slot-semantics note in repro/core/rules.py.
- ``ef``        error feedback (EfRule): memory is the compression residual
                e_m; the wire sends the CONTRACTIVE (unscaled) Rand-block
                window of g_m + e_m and keeps what it dropped.

All functions are designed to run INSIDE a `shard_map` body whose manual axes
include the client/pod axes; gradients arrive as this device's local block of
the parameter pytree, and `lax.pmean` over the level's axes is the server.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.compression.backend import BLOCK_ROWS, WIRE_DTYPES, get_backend
from repro.core.rules import WIRE_RULES, ShiftRule
from repro.core.salts import POD_KEY_SALT, WIRE_QUANT_SALT

# Biased-byte representation caps: 2*levels + 1 distinct lattice points must
# fit the lane (256 byte values / 16 nibble values) — the lossless-levels
# bound of DESIGN.md §3.13. Packed wires default to the largest level count
# their lane can carry losslessly.
_WIRE_LEVEL_CAPS = {"packed8": 127, "packed4": 7}


def payload_itemsize(wire_dtype: str, rule: ShiftRule,
                     leaf_dtype=jnp.float32) -> float:
    """Bytes per slab element one rank puts on the shared wire.

    The single accounting authority for the wire's transport width — dist's
    `wire_bytes_per_round`, the fleet driver's bit charging, and the jaxpr
    census all derive from it, so the three byte accountings cannot drift.

    f32 transport: stateful rules (diana/diana_rr/ef) psum f32 payloads; the
    memory-free 'q' slabs travel at leaf dtype. bf16 halves the lane. The
    packed modes move one byte per element (packed8) or one byte per TWO
    row-paired elements (packed4 -> 0.5); their f32 per-row scale sideband
    is accounted separately (`scale_sideband_bytes`).
    """
    if wire_dtype == "bf16":
        return 2
    if wire_dtype == "packed8":
        return 1
    if wire_dtype == "packed4":
        return 0.5
    return 4 if rule.has_shifts else jnp.dtype(leaf_dtype).itemsize


def scale_sideband_bytes(wire_dtype: str, slab_rows: int) -> int:
    """Bytes of the packed wire's f32 per-row scale sideband (0 otherwise)."""
    if wire_dtype in _WIRE_LEVEL_CAPS:
        return 4 * slab_rows
    return 0


class DianaState(NamedTuple):
    """Per-device compression state (local blocks of param-shaped trees).

    `shifts`/`mean_shift` are the inner (intra-pod) level: h_m per client
    rank and their per-pod running mean. `pod_shifts`/`pod_mean_shift` are
    the outer (inter-pod) level: one shift per pod and the global mean.
    Unused levels hold None (flat wire: pod_* is None; pod-granular NASTYA
    with `client_axes=()`: the inner pair is None).

    Layout depends on the method's shift rule: 'diana' keeps param-shaped
    leaves; 'diana_rr' prepends an `n_slots` axis to every table (the
    round's slot indexes it); 'ef' keeps the residual in `shifts` only
    (mean tables stay None — error feedback has no server memory).
    """

    shifts: Any  # h_m: this client's shift (differs across client_axes)
    mean_shift: Any  # H_t = (1/M) sum_m h_m (identical within a pod)
    pod_shifts: Any = None  # h_p: this pod's shift (differs across pod_axes)
    pod_mean_shift: Any = None  # (1/P) sum_p h_p (identical everywhere)


@dataclasses.dataclass(frozen=True)
class CompressedAggregation:
    """Config + pure functions for the production gradient wire."""

    method: str = "diana"  # 'dense' | 'q' | 'diana' | 'diana_rr' | 'ef'
    wire: str = "shared"  # 'shared' | 'independent'
    fraction: float = 0.02  # k/d on the intra-pod (inner) wire
    alpha: float | None = None  # shift stepsize; None -> 1/(1+omega) (Thm 2)
    shift_dtype: Any = jnp.bfloat16
    n_slots: int = 1  # per-slot shift-table rows ('diana_rr': the data n)
    client_axes: tuple[str, ...] = ("data",)  # inner level (ranks in a pod)
    pod_axes: tuple[str, ...] = ()  # outer level; () = flat single-level wire
    pod_size: int = 1  # static product of pod_axes sizes (1 = no inter-pod link)
    pod_fraction: float | None = None  # inter-pod k/d; None -> `fraction`
    pod_alpha: float | None = None  # pod shift stepsize; None -> 1/(1+omega_pod)
    pod_slots: int | None = None  # outer-level slot rows; None -> n_slots.
    # configure_agg sets 1 on NASTYA paths: the inter-pod exchange carries
    # the slot-free epoch gradient, so rows past 0 would never be touched.
    mean_scale: float = 1.0  # mean-shift stepsize scale: beta = mean_scale *
    # alpha at the client-granular level. Cohort-sampled fleets set M/C so
    # the resident mean shift tracks the population mean h_bar instead of
    # (C/M)*h_bar (DESIGN.md §3.10); 1.0 = the paper's full-participation form.
    backend: str | None = None  # 'reference' | 'pallas' | None (env/default)
    wire_dtype: str = "f32"  # slab transport: 'f32'|'bf16'|'packed8'|'packed4'
    # (applies to BOTH wire levels; DESIGN.md §3.13). 'f32' + wire_levels=None
    # is the bitwise status quo.
    wire_levels: int | None = None  # stochastic-quantization levels for the
    # slab (None -> unquantized f32/bf16; packed modes default to their lane
    # cap: 127 for packed8, 7 for packed4). Orthogonal to wire_dtype: 'f32'
    # with levels set moves the SAME quantized payload at 4 B/lane — the
    # bit-match reference for the packed transports.

    def __post_init__(self):
        if self.method not in WIRE_RULES:
            raise ValueError(f"unknown method {self.method!r}; options: "
                             f"{sorted(WIRE_RULES)}")
        if self.n_slots < 1:
            raise ValueError(f"n_slots={self.n_slots}")
        if self.pod_slots is not None and self.pod_slots < 1:
            raise ValueError(f"pod_slots={self.pod_slots}")
        if self.wire_dtype not in WIRE_DTYPES:
            raise ValueError(f"unknown wire_dtype {self.wire_dtype!r}; "
                             f"options: {WIRE_DTYPES}")
        if self.wire_dtype != "f32" or self.wire_levels is not None:
            if self.method == "dense":
                raise ValueError(
                    "method 'dense' has no compressed slab; wire_dtype must "
                    "stay 'f32' with wire_levels=None")
            if self.wire != "shared":
                raise ValueError(
                    "bf16/packed/quantized transport needs the shared wire "
                    f"(wire={self.wire!r} moves dense leaves, not slabs)")
        if self.wire_dtype == "bf16" and self.wire_levels is not None:
            raise ValueError(
                "wire_levels with bf16 transport is ambiguous (quantize to a "
                "lattice, then round the lattice to bf16?) — pick one of "
                "'f32'+levels (QSGD wire) or plain 'bf16'")
        cap = _WIRE_LEVEL_CAPS.get(self.wire_dtype)
        if self.wire_levels is not None:
            if self.wire_levels < 1:
                raise ValueError(f"wire_levels={self.wire_levels}")
            if cap is not None and self.wire_levels > cap:
                raise ValueError(
                    f"wire_levels={self.wire_levels} overflows the "
                    f"{self.wire_dtype} lane: 2*levels+1 lattice points must "
                    f"fit, so levels <= {cap}")

    @property
    def _quant_levels(self) -> int | None:
        """Effective quantization level count (packed lanes default full)."""
        if self.wire_levels is not None:
            return self.wire_levels
        return _WIRE_LEVEL_CAPS.get(self.wire_dtype)

    @property
    def _pod_slots(self) -> int:
        return self.n_slots if self.pod_slots is None else self.pod_slots

    @property
    def rule(self) -> ShiftRule:
        """The method's shift rule — the single source of shift semantics
        (shared with the simulator drivers; repro.core.rules)."""
        return WIRE_RULES[self.method]

    # -- state ---------------------------------------------------------------

    def init(self, local_params) -> DianaState | None:
        rule = self.rule
        if not rule.has_shifts:
            return None
        inner = bool(self.client_axes)
        outer = bool(self.pod_axes)
        mk = lambda ns: rule.init_shifts(local_params, n_slots=ns,
                                         dtype=self.shift_dtype)
        return DianaState(
            shifts=mk(self.n_slots) if inner else None,
            mean_shift=mk(self.n_slots) if inner and rule.has_mean else None,
            pod_shifts=mk(self._pod_slots) if outer else None,
            pod_mean_shift=mk(self._pod_slots) if outer and rule.has_mean
            else None,
        )

    def omega(self) -> float:
        if self.method == "dense":
            return 0.0
        return 1.0 / self.fraction - 1.0

    def pod_omega(self) -> float:
        if self.method == "dense" or self.pod_size == 1:
            return 0.0
        f = self.fraction if self.pod_fraction is None else self.pod_fraction
        return 1.0 / f - 1.0

    @property
    def shift_lr(self) -> float:
        """alpha <= 1/(1+omega) (Theorem 2 / 4 condition)."""
        if self.alpha is not None:
            return self.alpha
        return 1.0 / (1.0 + self.omega())

    @property
    def pod_shift_lr(self) -> float:
        if self.pod_alpha is not None:
            return self.pod_alpha
        return 1.0 / (1.0 + self.pod_omega())

    @property
    def _pod_fraction(self) -> float:
        return self.fraction if self.pod_fraction is None else self.pod_fraction

    # -- per-leaf compression primitives --------------------------------------
    #
    # Compression operates on a ROW view of each leaf: (prod(shape[:-1]),
    # shape[-1]). The last axis is the tensor-parallel ("model") sharded axis
    # in every weight layout (DESIGN.md §5), so selecting whole rows never
    # reshards a leaf — the sparse collective runs directly on model-sharded
    # row slabs. Row selection is uniform, so the operator stays unbiased
    # with omega = n_rows/k_rows - 1 = 1/fraction - 1 (block-granular Rand-k).

    @staticmethod
    def _row_view(leaf):
        if leaf.ndim >= 2:
            return jnp.reshape(leaf, (-1, leaf.shape[-1]))
        return jnp.reshape(leaf, (-1, 1))

    def _k(self, size: int, fraction: float) -> int:
        return max(1, int(fraction * size))

    def _leaf_key(self, key, leaf_idx: int) -> jax.Array:
        return jax.random.fold_in(key, leaf_idx)

    # -- aggregation ----------------------------------------------------------

    def _slot_idx(self, slot):
        """Rule index for the round's shared slot (None when slot-free)."""
        if not self.rule.slotted or slot is None:
            return None
        return (slot,)

    def _beta(self, alpha: float) -> float | None:
        """Mean-table stepsize for the client-granular level (None = alpha)."""
        if self.mean_scale == 1.0:
            return None
        return self.mean_scale * alpha

    def aggregate(self, grads, state: DianaState | None, key, *, slot=None,
                  weight=None):
        """(direction, new_state); call inside shard_map over the wire axes.

        Composed two-level exchange: the inner (intra-pod) level over
        `client_axes` with `key`, then the outer (inter-pod) level over
        `pod_axes` with an independently salted key. Either level degrades
        to a passthrough when its axes are empty (flat wire / 1-client pod).

        `slot` is the round's shared batch index (scalar int32), consumed
        by per-slot methods ('diana_rr') to pick the shift-table row at
        both levels; other methods ignore it.

        `weight` is this rank's participation weight (scalar, pre-normalized
        by the host so an all-ones cohort gives exactly 1.0): the compressed
        message is scaled by it before the collective mean, which is how the
        buffered-async driver masks dropped/padded clients (weight 0) and
        discounts stale reports. It applies at the client-granular level
        (inner when `client_axes` is set, outer otherwise); None leaves the
        wire untouched.
        """
        if self.method == "dense":
            axes = tuple(self.client_axes) + tuple(self.pod_axes)
            g_in = grads if weight is None else jax.tree.map(
                lambda g: g * weight, grads)
            direction = jax.tree.map(lambda g: lax.pmean(g, axes), g_in)
            return direction, state
        cw = weight if self.client_axes else None
        pw = None if self.client_axes else weight
        direction, state = self.aggregate_local(grads, state, key, slot=slot,
                                                weight=cw)
        return self.aggregate_pod(direction, state, key, slot=slot, weight=pw)

    def aggregate_local(self, grads, state: DianaState | None, key, *,
                        slot=None, weight=None):
        """Inner level only: compressed exchange over `client_axes`.

        This is what each NASTYA local step runs — the pod's ranks psum
        their compressed gradients over the fast intra-pod ICI; the slow
        inter-pod wire is only touched once per epoch by `aggregate_pod`.
        """
        if self.method == "dense":
            g_in = grads if weight is None else jax.tree.map(
                lambda g: g * weight, grads)
            direction = jax.tree.map(
                lambda g: lax.pmean(g, self.client_axes), g_in
            )
            return direction, state
        if not self.client_axes:  # a pod of one client: no intra-pod wire
            return grads, state
        rule = self.rule
        h = state.shifts if rule.has_shifts else None
        mh = state.mean_shift if rule.has_mean else None
        dirs, new_h, new_mh = self._level(
            grads, h, mh, key,
            axes=self.client_axes,
            fold_axes=tuple(self.pod_axes) + tuple(self.client_axes),
            fraction=self.fraction, alpha=self.shift_lr,
            beta=self._beta(self.shift_lr),
            idx=self._slot_idx(slot), weight=weight,
        )
        if rule.has_shifts:
            state = state._replace(shifts=new_h, mean_shift=new_mh)
        return dirs, state

    def aggregate_pod(self, direction, state: DianaState | None, key, *,
                      slot=None, weight=None):
        """Outer level only: compressed exchange over `pod_axes`.

        `key` is the same round key given to `aggregate_local`; the actual
        coordinate draw uses fold_in(key, POD_KEY_SALT) so the two levels
        are independent. A single pod (`pod_size == 1`) has no inter-pod
        link: the exchange is the exact mean over the (size-1) pod axes —
        numerically the identity, which is what makes the 1-pod two-level
        wire bit-match the flat wire.

        With a per-slot method and `slot=None` (the NASTYA epoch gradient,
        which has no batch index) the rule falls back to table row 0.
        """
        if weight is not None and (not self.pod_axes or self.method == "dense"
                                   or self.pod_size == 1):
            direction = jax.tree.map(lambda g: g * weight, direction)
        if not self.pod_axes or self.method == "dense":
            if self.pod_axes:
                direction = jax.tree.map(
                    lambda g: lax.pmean(g, self.pod_axes), direction
                )
            return direction, state
        if self.pod_size == 1:
            direction = jax.tree.map(
                lambda g: lax.pmean(g, self.pod_axes), direction
            )
            return direction, state
        rule = self.rule
        pod_key = jax.random.fold_in(key, POD_KEY_SALT)
        h = state.pod_shifts if rule.has_shifts else None
        mh = state.pod_mean_shift if rule.has_mean else None
        # weight is only ever non-None here when this outer level IS the
        # client-granular level (client_axes=(), flat NASTYA fleets), and
        # then the pod tables are per-client too — so mean_scale applies.
        dirs, new_h, new_mh = self._level(
            direction, h, mh, pod_key,
            axes=self.pod_axes, fold_axes=tuple(self.pod_axes),
            fraction=self._pod_fraction, alpha=self.pod_shift_lr,
            beta=(self._beta(self.pod_shift_lr) if not self.client_axes
                  else None),
            idx=self._slot_idx(slot), weight=weight,
        )
        if rule.has_shifts:
            state = state._replace(pod_shifts=new_h, pod_mean_shift=new_mh)
        return dirs, state

    # -- one exchange level ----------------------------------------------------

    def _level(self, grads, h_tree, mh_tree, key, *, axes, fold_axes,
               fraction, alpha, beta=None, idx=None, weight=None):
        """One compressed exchange over `axes`: Q per rank, psum, rule update.

        Returns (direction_tree, new_shifts_tree, new_mean_shift_tree); the
        shift trees are None when h_tree is None. This module only owns the
        wire mechanics — select/payload/update/scatter all come from the
        shift rule (repro.core.rules), the same arithmetic the simulator
        drivers run, with the fused diana_shift kernel on the DIANA paths
        (one pass over four inputs, three outputs, instead of five separate
        param-sized HBM round-trips).

        `beta` (None = alpha) is the mean-table stepsize handed to the rule;
        `weight` scales this rank's message into the collective mean (own
        message stays unweighted so the local shift update is unchanged).
        """
        rule = self.rule
        compress = (self._exchange_shared if self.wire == "shared"
                    else self._exchange_independent)
        leaves, treedef = jax.tree.flatten(grads)
        if h_tree is None:  # memory-free ('q'): direction = mean_m Q(g_m)
            out = []
            for i, g in enumerate(leaves):
                _, q_mean = compress(self._leaf_key(key, i), g, axes,
                                     fold_axes, fraction, weight=weight)
                out.append(q_mean.astype(g.dtype))
            return jax.tree.unflatten(treedef, out), None, None

        be = get_backend(self.backend)
        h_leaves = jax.tree.leaves(h_tree)
        mh_leaves = (jax.tree.leaves(mh_tree) if mh_tree is not None
                     else [None] * len(leaves))
        dirs, new_h, new_mh = [], [], []
        for i, (g, ht, mht) in enumerate(zip(leaves, h_leaves, mh_leaves)):
            h = rule.select(ht, idx)  # shift_dtype table row (or residual)
            mh = rule.select(mht, idx) if mht is not None else None
            p = rule.payload(g.astype(jnp.float32), h.astype(jnp.float32))
            q_own, q_mean = compress(self._leaf_key(key, i), p, axes,
                                     fold_axes, fraction,
                                     contractive=rule.contractive,
                                     weight=weight)
            direction, h_new, mh_new = rule.update(
                h, q_own.astype(jnp.float32), mh, q_mean.astype(jnp.float32),
                alpha=alpha, beta=beta, backend=be, payload=p,
            )
            new_h.append(rule.scatter(ht, idx, h_new.astype(ht.dtype)))
            if mht is not None:
                new_mh.append(rule.scatter(mht, idx, mh_new.astype(mht.dtype)))
            dirs.append(direction.astype(g.dtype))
        return (jax.tree.unflatten(treedef, dirs),
                jax.tree.unflatten(treedef, new_h),
                jax.tree.unflatten(treedef, new_mh) if mh_tree is not None
                else None)

    # shared-seed Rand-block: sparse collectives -------------------------------
    #
    # The circular window is block-granular (whole BLOCK_ROWS=8 row groups)
    # so the gather/scatter maps onto the Pallas kernels' sublane-aligned
    # VMEM copies. Rows are zero-padded up to a block multiple; padding rows
    # travel (zeros) but never reach real coordinates on reconstruction.
    # Marginal inclusion probability is k_blocks/n_blocks for every real row
    # -> unbiased with the same omega formula (DESIGN.md §3.2).

    def _pad_rows(self, rows):
        pad = (-rows.shape[0]) % BLOCK_ROWS
        if pad:
            rows = jnp.pad(rows, ((0, pad), (0, 0)))
        return rows

    def _wire_geometry(self, n_rows_padded: int,
                       fraction: float) -> tuple[int, int]:
        nb = n_rows_padded // BLOCK_ROWS
        return nb, max(1, int(fraction * nb))

    def _exchange_shared(self, key, delta, axes, fold_axes, fraction,
                         contractive: bool = False, weight=None):
        """Shared-key Rand-block exchange of one leaf over `axes`.

        Returns (q_own, q_mean) dense reconstructions. Only the k-row slab
        crosses the wire (the sparse collective runs inside the backend's
        `wire_exchange`); both reconstructions reuse the one start_block.

        contractive=True divides out the unbiased nb/kb scaling — the
        UNSCALED window projection (contraction factor kb/nb) that error
        feedback requires; the d/k-scaled reconstruction makes the EF
        residual grow instead of contract. `weight` scales this rank's slab
        into the collective mean only (q_own stays unweighted).

        Transport is `wire_dtype`/`wire_levels` (DESIGN.md §3.13): when the
        slab is quantized, the stochastic-rounding uniforms come from the
        level key + WIRE_QUANT_SALT — shared across the level's ranks like
        the window draw, so every rank agrees on the byte lattice.
        """
        del fold_axes  # shared draw: every rank uses the same key
        be = get_backend(self.backend)
        rows = self._pad_rows(self._row_view(delta))
        nb, kb = self._wire_geometry(rows.shape[0], fraction)
        start_block = jax.random.randint(key, (), 0, nb)
        levels = self._quant_levels
        quant_u = None
        if levels is not None:
            qkey = jax.random.fold_in(key, WIRE_QUANT_SALT)
            quant_u = jax.random.uniform(
                qkey, (kb * BLOCK_ROWS, rows.shape[1]))
        vals, mean_vals = be.wire_exchange(rows, start_block, k_blocks=kb,
                                           block_rows=BLOCK_ROWS, axes=axes,
                                           weight=weight,
                                           wire_dtype=self.wire_dtype,
                                           levels=levels, quant_u=quant_u)
        if contractive:
            vals = vals * (kb / nb)
            mean_vals = mean_vals * (kb / nb)
        return (self._scatter_block(delta, start_block, vals),
                self._scatter_block(delta, start_block, mean_vals))

    def _scatter_block(self, template, start_block, vals):
        be = get_backend(self.backend)
        shape = self._row_view(template).shape
        n_padded = shape[0] + (-shape[0]) % BLOCK_ROWS
        dense = be.wire_decompress(vals, start_block, n_rows=n_padded,
                                   block_rows=BLOCK_ROWS)
        return jnp.reshape(dense[:shape[0]], template.shape)

    # independent-seed Rand-k: paper-exact, dense collectives ------------------

    def _exchange_independent(self, key, delta, axes, fold_axes, fraction,
                              contractive: bool = False, weight=None):
        """Unbiased Rand-k over rows (with-replacement indices: omega <= n/k,
        avoids a full permutation sort on device; see DESIGN.md §3), one
        independent draw per rank (key folded with the rank's coordinates
        along `fold_axes`), then a dense psum over `axes`.
        contractive=True keeps the selected rows UNSCALED (set semantics:
        duplicate draws count once) — the projection error feedback needs.
        `weight` scales this rank's contribution to the mean only."""
        for ax in fold_axes:
            key = jax.random.fold_in(key, lax.axis_index(ax))
        rows = self._row_view(delta.astype(jnp.float32))
        n = rows.shape[0]
        k = self._k(n, fraction)
        idx = jax.random.randint(key, (k,), 0, n)
        if contractive:
            out = jnp.reshape(
                jnp.zeros_like(rows).at[idx].set(rows[idx]), delta.shape)
        else:
            vals = rows[idx] * (n / k)
            out = jnp.reshape(
                jnp.zeros_like(rows).at[idx].add(vals), delta.shape)
        shared = out if weight is None else out * weight
        return out, lax.pmean(shared, axes)

    # -- wire accounting (benchmarks / EXPERIMENTS.md) -------------------------

    def wire_bytes_per_round(self, params) -> dict[str, int]:
        """Bytes one rank contributes to each wire level per round.

        'intra_pod' is the inner shared-wire slab (k-row blocks);
        'inter_pod' the outer level's slab; 'dense' what an uncompressed
        psum of the same tree would move. The shared wire's sparse
        collective moves exactly the compressed slab — at the transport
        width of `wire_dtype` (`payload_itemsize`), plus the packed modes'
        f32 per-row scale sideband — while the independent wire moves the
        dense size regardless of k (the zeros travel — DESIGN.md §3.1).
        The jaxpr census (analysis/graph.py) pins the compiled step's
        collective payloads against these numbers exactly, and the fleet
        driver charges `FedState.bits` from them.
        """
        dense = intra = inter = 0
        for leaf in jax.tree.leaves(params):
            rows = int(np.prod(leaf.shape[:-1])) if leaf.ndim >= 2 else int(
                np.prod(leaf.shape))
            cols = leaf.shape[-1] if leaf.ndim >= 2 else 1
            padded = rows + (-rows) % BLOCK_ROWS
            dense += rows * cols * jnp.dtype(leaf.dtype).itemsize
            if self.method == "dense" or self.wire == "independent":
                continue
            item = payload_itemsize(self.wire_dtype, self.rule, leaf.dtype)

            def slab_bytes(fraction):
                _, kb = self._wire_geometry(padded, fraction)
                slab_rows = kb * BLOCK_ROWS
                return int(slab_rows * cols * item) + scale_sideband_bytes(
                    self.wire_dtype, slab_rows)

            if self.client_axes:
                intra += slab_bytes(self.fraction)
            if self.pod_axes and self.pod_size > 1:
                inter += slab_bytes(self._pod_fraction)
        if self.method != "dense" and self.wire == "independent":
            intra = dense if self.client_axes else 0
            inter = dense if (self.pod_axes and self.pod_size > 1) else 0
        return {"dense": dense, "intra_pod": intra, "inter_pod": inter}
