"""Production compressed-gradient aggregation for TPU pods.

This is the paper's communication layer rethought for ICI collectives
(DESIGN.md §3). Clients are the mesh's ("pod","data") ranks. Two wire modes:

``independent`` (paper-exact semantics)
    Every client Rand-k-compresses its own gradient with an *independent*
    key (paper Assumption 1 + the 1/M variance factor in Theorems 1-2), then
    the results are averaged with a dense ``psum``. On TPU the zeros travel
    too — the collective term does not shrink; this is the faithful baseline
    recorded in EXPERIMENTS.md §Perf.

``shared`` (TPU-native sparse collective — beyond-paper optimization)
    All clients draw the *same* coordinate block per round (shared PRNG seed,
    folded with the model-axis index so every model shard picks its own
    block). Then only the k selected values are psum'd: collective bytes drop
    by d/k (~50x at the paper's k/d≈0.02). Coordinates are a contiguous
    random block ("Rand-block"): uniform marginal inclusion probability k/d
    gives exactly the Rand-k variance bound omega = d/k - 1 (the second
    moment only needs marginals — see DESIGN.md), while replacing the gather/
    scatter with dynamic_slice / dynamic_update_slice, which is the memory-
    friendly access pattern on TPU. Because coordinates are shared,
    mean_m Q(d_m) == Q(mean_m d_m): the omega/M factor of the paper becomes
    omega applied to the already-averaged vector — still Assumption-1
    compliant per round, and with DIANA shifts the compressed residual
    d_m -> 0 so the fixed point is unchanged (Theorem 2 logic carries over).

Aggregation methods (paper Secs. 2.1-2.2, production variants):

- ``dense``     plain mean gradient (no compression) — sanity baseline
- ``q``         Q-RR-style: direction = mean_m Q(g_m)
- ``diana``     DIANA-RR-style with one shift per client (the n-shift variant
                is exercised in the simulator; one shift per round-gradient is
                the production memory-feasible choice, DESIGN.md §3.3):
                    direction = H_t + mean_m Q(g_m - h_m)
                    h_m   += alpha * Q(g_m - h_m)
                    H_t+1  = H_t + alpha * mean_m Q(g_m - h_m)

All functions are designed to run INSIDE a `shard_map` body whose manual axes
include the client axes; gradients arrive as this device's local block of the
parameter pytree, and `lax.pmean` over `client_axes` is the server.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax


class DianaState(NamedTuple):
    """Per-device compression state (local blocks of param-shaped trees)."""

    shifts: Any  # h_m: this client's shift (per-client, differs across data axis)
    mean_shift: Any  # H_t = (1/M) sum_m h_m (identical on every client)


@dataclasses.dataclass(frozen=True)
class CompressedAggregation:
    """Config + pure functions for the production gradient wire."""

    method: str = "diana"  # 'dense' | 'q' | 'diana'
    wire: str = "shared"  # 'shared' | 'independent'
    fraction: float = 0.02  # k/d
    alpha: float | None = None  # shift stepsize; None -> 1/(1+omega) (Thm 2)
    shift_dtype: Any = jnp.bfloat16
    client_axes: tuple[str, ...] = ("data",)

    # -- state ---------------------------------------------------------------

    def init(self, local_params) -> DianaState | None:
        if self.method != "diana":
            return None
        zeros = lambda p: jnp.zeros(p.shape, self.shift_dtype)
        return DianaState(
            shifts=jax.tree.map(zeros, local_params),
            mean_shift=jax.tree.map(zeros, local_params),
        )

    def omega(self) -> float:
        if self.method == "dense":
            return 0.0
        return 1.0 / self.fraction - 1.0

    @property
    def shift_lr(self) -> float:
        """alpha <= 1/(1+omega) (Theorem 2 / 4 condition)."""
        if self.alpha is not None:
            return self.alpha
        return 1.0 / (1.0 + self.omega())

    # -- per-leaf compression primitives --------------------------------------
    #
    # Compression operates on a ROW view of each leaf: (prod(shape[:-1]),
    # shape[-1]). The last axis is the tensor-parallel ("model") sharded axis
    # in every weight layout (DESIGN.md §5), so selecting whole rows never
    # reshards a leaf — the sparse collective runs directly on model-sharded
    # row slabs. Row selection is uniform, so the operator stays unbiased
    # with omega = n_rows/k_rows - 1 = 1/fraction - 1 (block-granular Rand-k).

    @staticmethod
    def _row_view(leaf):
        if leaf.ndim >= 2:
            return jnp.reshape(leaf, (-1, leaf.shape[-1]))
        return jnp.reshape(leaf, (-1, 1))

    def _k(self, size: int) -> int:
        return max(1, int(self.fraction * size))

    def _leaf_key(self, key, leaf_idx: int) -> jax.Array:
        return jax.random.fold_in(key, leaf_idx)

    # -- aggregation ----------------------------------------------------------

    def aggregate(self, grads, state: DianaState | None, key):
        """(direction, new_state); call inside shard_map over client axes."""
        if self.method == "dense":
            direction = jax.tree.map(
                lambda g: lax.pmean(g, self.client_axes), grads
            )
            return direction, state
        if self.wire == "shared":
            return self._aggregate_shared(grads, state, key)
        return self._aggregate_independent(grads, state, key)

    # shared-seed Rand-block: sparse collectives -------------------------------

    def _compress_shared_leaf(self, key, delta):
        """Returns (start, own_rows, mean_rows, k_rows) for one leaf."""
        rows = self._row_view(delta)
        n = rows.shape[0]
        k = self._k(n)
        start = jax.random.randint(key, (), 0, n)
        # circular row block: roll so the block begins at row 0, then a
        # static slice (the roll axis is never sharded — rows wrap locally).
        vals = jnp.roll(rows, -start, axis=0)[:k] * (n / k)
        mean_vals = lax.pmean(vals, self.client_axes)  # the sparse collective
        return start, vals, mean_vals, k

    def _scatter_block(self, template, start, vals):
        rows = jnp.zeros(self._row_view(template).shape, vals.dtype)
        rows = lax.dynamic_update_slice(rows, vals, (0, 0))
        return jnp.reshape(jnp.roll(rows, start, axis=0), template.shape)

    def _aggregate_shared(self, grads, state, key):
        leaves, treedef = jax.tree.flatten(grads)
        if self.method == "q":
            out = []
            for i, g in enumerate(leaves):
                start, _, mean_vals, _ = self._compress_shared_leaf(
                    self._leaf_key(key, i), g
                )
                out.append(self._scatter_block(g, start, mean_vals))
            return jax.tree.unflatten(treedef, out), state

        # diana
        h_leaves = jax.tree.leaves(state.shifts)
        mh_leaves = jax.tree.leaves(state.mean_shift)
        dirs, new_h, new_mh = [], [], []
        for i, (g, h, mh) in enumerate(zip(leaves, h_leaves, mh_leaves)):
            delta = g.astype(jnp.float32) - h.astype(jnp.float32)
            start, own_vals, mean_vals, _ = self._compress_shared_leaf(
                self._leaf_key(key, i), delta
            )
            q_mean = self._scatter_block(g, start, mean_vals)
            direction = mh.astype(jnp.float32) + q_mean
            q_own = self._scatter_block(g, start, own_vals)
            new_h.append((h.astype(jnp.float32) + self.shift_lr * q_own).astype(self.shift_dtype))
            new_mh.append((mh.astype(jnp.float32) + self.shift_lr * q_mean).astype(self.shift_dtype))
            dirs.append(direction.astype(g.dtype))
        new_state = DianaState(
            shifts=jax.tree.unflatten(treedef, new_h),
            mean_shift=jax.tree.unflatten(treedef, new_mh),
        )
        return jax.tree.unflatten(treedef, dirs), new_state

    # independent-seed Rand-k: paper-exact, dense collectives ------------------

    def _compress_independent_leaf(self, key, delta):
        """Unbiased Rand-k over rows (with-replacement indices: omega <= n/k,
        avoids a full permutation sort on device; see DESIGN.md §3)."""
        rows = self._row_view(delta)
        n = rows.shape[0]
        k = self._k(n)
        idx = jax.random.randint(key, (k,), 0, n)
        vals = rows[idx] * (n / k)
        out = jnp.zeros_like(rows).at[idx].add(vals)
        return jnp.reshape(out, delta.shape)

    def _client_key(self, key, leaf_idx: int) -> jax.Array:
        key = self._leaf_key(key, leaf_idx)
        for ax in self.client_axes:
            key = jax.random.fold_in(key, lax.axis_index(ax))
        return key

    def _aggregate_independent(self, grads, state, key):
        leaves, treedef = jax.tree.flatten(grads)
        if self.method == "q":
            out = []
            for i, g in enumerate(leaves):
                q = self._compress_independent_leaf(self._client_key(key, i),
                                                    g.astype(jnp.float32))
                out.append(lax.pmean(q, self.client_axes).astype(g.dtype))
            return jax.tree.unflatten(treedef, out), state

        h_leaves = jax.tree.leaves(state.shifts)
        mh_leaves = jax.tree.leaves(state.mean_shift)
        dirs, new_h, new_mh = [], [], []
        for i, (g, h, mh) in enumerate(zip(leaves, h_leaves, mh_leaves)):
            delta = g.astype(jnp.float32) - h.astype(jnp.float32)
            q_own = self._compress_independent_leaf(self._client_key(key, i), delta)
            q_mean = lax.pmean(q_own, self.client_axes)  # dense collective
            dirs.append((mh.astype(jnp.float32) + q_mean).astype(g.dtype))
            new_h.append((h.astype(jnp.float32) + self.shift_lr * q_own).astype(self.shift_dtype))
            new_mh.append((mh.astype(jnp.float32) + self.shift_lr * q_mean).astype(self.shift_dtype))
        new_state = DianaState(
            shifts=jax.tree.unflatten(treedef, new_h),
            mean_shift=jax.tree.unflatten(treedef, new_mh),
        )
        return jax.tree.unflatten(treedef, dirs), new_state
