"""Production compressed-gradient aggregation for TPU pods.

This is the paper's communication layer rethought for ICI collectives
(DESIGN.md §3). Clients are the mesh's ("pod","data") ranks. Two wire modes:

``independent`` (paper-exact semantics)
    Every client Rand-k-compresses its own gradient with an *independent*
    key (paper Assumption 1 + the 1/M variance factor in Theorems 1-2), then
    the results are averaged with a dense ``psum``. On TPU the zeros travel
    too — the collective term does not shrink; this is the faithful baseline
    recorded in EXPERIMENTS.md §Perf.

``shared`` (TPU-native sparse collective — beyond-paper optimization)
    All clients draw the *same* coordinate block per round (shared PRNG seed,
    folded with the model-axis index so every model shard picks its own
    block). Then only the k selected values are psum'd: collective bytes drop
    by d/k (~50x at the paper's k/d≈0.02). Coordinates are a contiguous
    random block of whole 8-row groups ("Rand-block", DESIGN.md §3.2):
    uniform marginal inclusion probability k/d gives exactly the Rand-k
    variance bound omega = d/k - 1 (the second moment only needs marginals),
    while the gather/scatter runs through the Pallas circular row-block
    kernels (`repro.kernels.randk`) dispatched by the compression backend
    (DESIGN.md §3.5) — k_blocks sequential VMEM copies driven by one
    prefetched scalar, instead of a `jnp.roll` of the full leaf. Because
    coordinates are shared, mean_m Q(d_m) == Q(mean_m d_m): the omega/M
    factor of the paper becomes omega applied to the already-averaged vector
    — still Assumption-1 compliant per round, and with DIANA shifts the
    compressed residual d_m -> 0 so the fixed point is unchanged (Theorem 2
    logic carries over).

Aggregation methods (paper Secs. 2.1-2.2, production variants):

- ``dense``     plain mean gradient (no compression) — sanity baseline
- ``q``         Q-RR-style: direction = mean_m Q(g_m)
- ``diana``     DIANA-RR-style with one shift per client (the n-shift variant
                is exercised in the simulator; one shift per round-gradient is
                the production memory-feasible choice, DESIGN.md §3.3):
                    direction = H_t + mean_m Q(g_m - h_m)
                    h_m   += alpha * Q(g_m - h_m)
                    H_t+1  = H_t + alpha * mean_m Q(g_m - h_m)

All functions are designed to run INSIDE a `shard_map` body whose manual axes
include the client axes; gradients arrive as this device's local block of the
parameter pytree, and `lax.pmean` over `client_axes` is the server.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.compression.backend import get_backend
from repro.kernels.randk import BLOCK_ROWS


class DianaState(NamedTuple):
    """Per-device compression state (local blocks of param-shaped trees)."""

    shifts: Any  # h_m: this client's shift (per-client, differs across data axis)
    mean_shift: Any  # H_t = (1/M) sum_m h_m (identical on every client)


@dataclasses.dataclass(frozen=True)
class CompressedAggregation:
    """Config + pure functions for the production gradient wire."""

    method: str = "diana"  # 'dense' | 'q' | 'diana'
    wire: str = "shared"  # 'shared' | 'independent'
    fraction: float = 0.02  # k/d
    alpha: float | None = None  # shift stepsize; None -> 1/(1+omega) (Thm 2)
    shift_dtype: Any = jnp.bfloat16
    client_axes: tuple[str, ...] = ("data",)
    backend: str | None = None  # 'reference' | 'pallas' | None (env/default)

    # -- state ---------------------------------------------------------------

    def init(self, local_params) -> DianaState | None:
        if self.method != "diana":
            return None
        zeros = lambda p: jnp.zeros(p.shape, self.shift_dtype)
        return DianaState(
            shifts=jax.tree.map(zeros, local_params),
            mean_shift=jax.tree.map(zeros, local_params),
        )

    def omega(self) -> float:
        if self.method == "dense":
            return 0.0
        return 1.0 / self.fraction - 1.0

    @property
    def shift_lr(self) -> float:
        """alpha <= 1/(1+omega) (Theorem 2 / 4 condition)."""
        if self.alpha is not None:
            return self.alpha
        return 1.0 / (1.0 + self.omega())

    # -- per-leaf compression primitives --------------------------------------
    #
    # Compression operates on a ROW view of each leaf: (prod(shape[:-1]),
    # shape[-1]). The last axis is the tensor-parallel ("model") sharded axis
    # in every weight layout (DESIGN.md §5), so selecting whole rows never
    # reshards a leaf — the sparse collective runs directly on model-sharded
    # row slabs. Row selection is uniform, so the operator stays unbiased
    # with omega = n_rows/k_rows - 1 = 1/fraction - 1 (block-granular Rand-k).

    @staticmethod
    def _row_view(leaf):
        if leaf.ndim >= 2:
            return jnp.reshape(leaf, (-1, leaf.shape[-1]))
        return jnp.reshape(leaf, (-1, 1))

    def _k(self, size: int) -> int:
        return max(1, int(self.fraction * size))

    def _leaf_key(self, key, leaf_idx: int) -> jax.Array:
        return jax.random.fold_in(key, leaf_idx)

    # -- aggregation ----------------------------------------------------------

    def aggregate(self, grads, state: DianaState | None, key):
        """(direction, new_state); call inside shard_map over client axes."""
        if self.method == "dense":
            direction = jax.tree.map(
                lambda g: lax.pmean(g, self.client_axes), grads
            )
            return direction, state
        if self.wire == "shared":
            return self._aggregate_shared(grads, state, key)
        return self._aggregate_independent(grads, state, key)

    # shared-seed Rand-block: sparse collectives -------------------------------
    #
    # The circular window is block-granular (whole BLOCK_ROWS=8 row groups)
    # so the gather/scatter maps onto the Pallas kernels' sublane-aligned
    # VMEM copies. Rows are zero-padded up to a block multiple; padding rows
    # travel (zeros) but never reach real coordinates on reconstruction.
    # Marginal inclusion probability is k_blocks/n_blocks for every real row
    # -> unbiased with the same omega formula (DESIGN.md §3.2).

    def _pad_rows(self, rows):
        pad = (-rows.shape[0]) % BLOCK_ROWS
        if pad:
            rows = jnp.pad(rows, ((0, pad), (0, 0)))
        return rows

    def _wire_geometry(self, n_rows_padded: int) -> tuple[int, int]:
        nb = n_rows_padded // BLOCK_ROWS
        return nb, max(1, int(self.fraction * nb))

    def _compress_shared_leaf(self, key, delta):
        """Returns (start_block, own_vals, mean_vals) for one leaf."""
        be = get_backend(self.backend)
        rows = self._pad_rows(self._row_view(delta))
        nb, kb = self._wire_geometry(rows.shape[0])
        start_block = jax.random.randint(key, (), 0, nb)
        vals = be.wire_compress(rows, start_block, k_blocks=kb,
                                block_rows=BLOCK_ROWS)
        mean_vals = lax.pmean(vals, self.client_axes)  # the sparse collective
        return start_block, vals, mean_vals

    def _scatter_block(self, template, start_block, vals):
        be = get_backend(self.backend)
        shape = self._row_view(template).shape
        n_padded = shape[0] + (-shape[0]) % BLOCK_ROWS
        dense = be.wire_decompress(vals, start_block, n_rows=n_padded,
                                   block_rows=BLOCK_ROWS)
        return jnp.reshape(dense[:shape[0]], template.shape)

    def _aggregate_shared(self, grads, state, key):
        leaves, treedef = jax.tree.flatten(grads)
        if self.method == "q":
            out = []
            for i, g in enumerate(leaves):
                start, _, mean_vals = self._compress_shared_leaf(
                    self._leaf_key(key, i), g
                )
                out.append(self._scatter_block(g, start, mean_vals))
            return jax.tree.unflatten(treedef, out), state

        # diana — the shift/direction arithmetic runs through the fused
        # kernel (one pass over four inputs, three outputs) instead of five
        # separate param-sized HBM round-trips.
        be = get_backend(self.backend)
        h_leaves = jax.tree.leaves(state.shifts)
        mh_leaves = jax.tree.leaves(state.mean_shift)
        dirs, new_h, new_mh = [], [], []
        for i, (g, h, mh) in enumerate(zip(leaves, h_leaves, mh_leaves)):
            delta = g.astype(jnp.float32) - h.astype(jnp.float32)
            start, own_vals, mean_vals = self._compress_shared_leaf(
                self._leaf_key(key, i), delta
            )
            q_mean = self._scatter_block(g, start, mean_vals)
            q_own = self._scatter_block(g, start, own_vals)
            direction, h_new, mh_new = be.diana_shift_flat(
                h.astype(self.shift_dtype), q_own.astype(jnp.float32),
                mh.astype(self.shift_dtype), q_mean.astype(jnp.float32),
                alpha=self.shift_lr,
            )
            new_h.append(h_new)
            new_mh.append(mh_new)
            dirs.append(direction.astype(g.dtype))
        new_state = DianaState(
            shifts=jax.tree.unflatten(treedef, new_h),
            mean_shift=jax.tree.unflatten(treedef, new_mh),
        )
        return jax.tree.unflatten(treedef, dirs), new_state

    # independent-seed Rand-k: paper-exact, dense collectives ------------------

    def _compress_independent_leaf(self, key, delta):
        """Unbiased Rand-k over rows (with-replacement indices: omega <= n/k,
        avoids a full permutation sort on device; see DESIGN.md §3)."""
        rows = self._row_view(delta)
        n = rows.shape[0]
        k = self._k(n)
        idx = jax.random.randint(key, (k,), 0, n)
        vals = rows[idx] * (n / k)
        out = jnp.zeros_like(rows).at[idx].add(vals)
        return jnp.reshape(out, delta.shape)

    def _client_key(self, key, leaf_idx: int) -> jax.Array:
        key = self._leaf_key(key, leaf_idx)
        for ax in self.client_axes:
            key = jax.random.fold_in(key, lax.axis_index(ax))
        return key

    def _aggregate_independent(self, grads, state, key):
        leaves, treedef = jax.tree.flatten(grads)
        if self.method == "q":
            out = []
            for i, g in enumerate(leaves):
                q = self._compress_independent_leaf(self._client_key(key, i),
                                                    g.astype(jnp.float32))
                out.append(lax.pmean(q, self.client_axes).astype(g.dtype))
            return jax.tree.unflatten(treedef, out), state

        be = get_backend(self.backend)
        h_leaves = jax.tree.leaves(state.shifts)
        mh_leaves = jax.tree.leaves(state.mean_shift)
        dirs, new_h, new_mh = [], [], []
        for i, (g, h, mh) in enumerate(zip(leaves, h_leaves, mh_leaves)):
            delta = g.astype(jnp.float32) - h.astype(jnp.float32)
            q_own = self._compress_independent_leaf(self._client_key(key, i), delta)
            q_mean = lax.pmean(q_own, self.client_axes)  # dense collective
            direction, h_new, mh_new = be.diana_shift_flat(
                h.astype(self.shift_dtype), q_own,
                mh.astype(self.shift_dtype), q_mean, alpha=self.shift_lr,
            )
            dirs.append(direction.astype(g.dtype))
            new_h.append(h_new)
            new_mh.append(mh_new)
        new_state = DianaState(
            shifts=jax.tree.unflatten(treedef, new_h),
            mean_shift=jax.tree.unflatten(treedef, new_mh),
        )
        return jax.tree.unflatten(treedef, dirs), new_state
