"""Chrome/Perfetto `trace_event` export for telemetry streams.

Spans become complete ("X") events, scalar counters and numeric round
metrics become counter ("C") tracks — load the output in
`chrome://tracing` / https://ui.perfetto.dev. Timestamps are the sink's
monotonic seconds converted to the format's microseconds.
"""
from __future__ import annotations

import json
import numbers

_PID = 1


def to_trace_events(events: list[dict]) -> list[dict]:
    """Convert decoded telemetry events to `trace_event` dicts."""
    out: list[dict] = [
        {"ph": "M", "name": "process_name", "pid": _PID, "ts": 0,
         "args": {"name": "repro.telemetry"}},
    ]
    for ev in events:
        kind = ev.get("kind")
        ts_us = float(ev.get("ts", 0.0)) * 1e6
        if kind == "span":
            args = dict(ev.get("args") or {})
            args["depth"] = ev.get("depth", 0)
            out.append({"ph": "X", "name": ev["name"], "cat": "host",
                        "ts": ts_us, "dur": float(ev["dur"]) * 1e6,
                        "pid": _PID, "tid": ev.get("tid", 0), "args": args})
        elif kind == "counter":
            v = ev.get("value")
            if isinstance(v, numbers.Real) and not isinstance(v, bool):
                out.append({"ph": "C", "name": ev["name"], "ts": ts_us,
                            "pid": _PID, "args": {"value": float(v)}})
        elif kind == "round_metrics":
            for name, v in (ev.get("metrics") or {}).items():
                if isinstance(v, numbers.Real) and not isinstance(v, bool):
                    out.append({"ph": "C", "name": f"metrics/{name}",
                                "ts": ts_us, "pid": _PID,
                                "args": {"value": float(v)}})
        elif kind == "run_meta":
            out.append({"ph": "i", "name": "run_meta", "s": "g",
                        "ts": ts_us, "pid": _PID, "tid": 0,
                        "args": ev.get("meta") or {}})
    return out


def write_trace(events: list[dict], path: str) -> int:
    """Write the Chrome trace JSON; returns the trace event count."""
    trace = {"traceEvents": to_trace_events(events),
             "displayTimeUnit": "ms"}
    with open(path, "w") as f:
        json.dump(trace, f)
    return len(trace["traceEvents"])
