"""`MetricsSink` + the module-global hook surface (DESIGN.md §3.14).

Zero-cost-when-off: the hot paths (drivers, streams, pager, checkpoint io)
call the MODULE-LEVEL `span`/`counter`/`round_metrics` helpers, which read
one module global and return immediately (a shared no-op context manager
for spans) when no sink is installed. Nothing telemetry-shaped is ever
threaded through jit — the census job pins that the traced step's jaxpr is
byte-identical with a sink attached (`census-telemetry-identity`).

No extra device syncs when ON: `round_metrics`/`counter` values may be jax
arrays (the step's metrics pytree). The sink never materializes them on the
calling thread — records go onto a queue as-is and the BACKGROUND WRITER
thread converts them (`_jsonable` -> `np.asarray`), so the one
device->host fetch the loop already pays happens off the dispatch path.
Spans read `time.perf_counter()` twice and never call `block_until_ready`,
so a span measures host phase time (dispatch, not device completion) by
construction.

Thread model: builds/spans fire from both the round loop and the prefetch
worker, so emission is queue-based (`queue.SimpleQueue`, lock-free put)
and span nesting depth is tracked per-thread.
"""
from __future__ import annotations

import json
import queue
import threading
import time
from contextlib import contextmanager

import numpy as np

from repro.telemetry.events import SCHEMA_VERSION

_CLOSE = object()


def _jsonable(v):
    """Materialize one record value for JSON. Runs on the WRITER thread
    (or at `events()` read time for in-memory sinks) — this is where jax
    scalars finally sync to host, off the round loop's critical path."""
    if v is None or isinstance(v, (str, bool, int, float)):
        return v
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    arr = np.asarray(v)  # jax/np scalars land here: the one host fetch
    return arr.item() if arr.ndim == 0 else arr.tolist()


class _Span:
    """One host phase interval; records (ts, dur, tid, depth) on exit."""

    __slots__ = ("_sink", "_name", "_args", "_t0", "_depth")

    def __init__(self, sink: "MetricsSink", name: str, args: dict):
        self._sink = sink
        self._name = name
        self._args = args

    def __enter__(self):
        tls = self._sink._tls
        self._depth = getattr(tls, "depth", 0)
        tls.depth = self._depth + 1
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        sink = self._sink
        sink._tls.depth = self._depth
        rec = {"v": SCHEMA_VERSION, "kind": "span",
               "ts": self._t0 - sink._epoch, "dur": t1 - self._t0,
               "name": self._name, "tid": threading.get_ident(),
               "depth": self._depth}
        if self._args:
            rec["args"] = self._args
        sink._emit(rec)
        return False


class MetricsSink:
    """Append-only JSONL event stream with a buffered background writer.

    path=None keeps events in memory (`events()`) — used by tests and the
    census identity check. With a path, a daemon writer thread drains the
    emission queue, materializes values, and flushes every `flush_every`
    records (and at close), so an interrupted run loses at most the torn
    tail `read_events` already tolerates.
    """

    def __init__(self, path: str | None = None, *, flush_every: int = 64):
        self.path = path
        self._epoch = time.perf_counter()
        self._tls = threading.local()
        self._closed = False
        self._mem: list[dict] = []
        self._q: queue.SimpleQueue | None = None
        self._thread: threading.Thread | None = None
        self._file = None
        self._flush_every = max(1, int(flush_every))
        if path is not None:
            self._file = open(path, "w")
            self._q = queue.SimpleQueue()
            self._thread = threading.Thread(
                target=self._drain, name="telemetry-writer", daemon=True)
            self._thread.start()

    # -- emission ----------------------------------------------------------

    def _now(self) -> float:
        return time.perf_counter() - self._epoch

    def _emit(self, rec: dict) -> None:
        if self._closed:
            return
        if self._q is not None:
            self._q.put(rec)
        else:
            self._mem.append(rec)  # GIL-atomic append: thread-safe

    def _drain(self) -> None:
        n = 0
        while True:
            rec = self._q.get()
            if rec is _CLOSE:
                break
            self._file.write(json.dumps(_jsonable(rec)) + "\n")
            n += 1
            if n % self._flush_every == 0:
                self._file.flush()
        self._file.flush()

    # -- record constructors ----------------------------------------------

    def run_meta(self, meta: dict) -> None:
        self._emit({"v": SCHEMA_VERSION, "kind": "run_meta",
                    "ts": self._now(), "meta": meta})

    def round_metrics(self, rnd: int, metrics: dict) -> None:
        """Values may be live jax arrays — materialized on the writer
        thread, never here (the no-extra-syncs argument)."""
        self._emit({"v": SCHEMA_VERSION, "kind": "round_metrics",
                    "ts": self._now(), "round": int(rnd),
                    "metrics": dict(metrics)})

    def counter(self, name: str, value, *, round: int | None = None,
                **tags) -> None:
        rec = {"v": SCHEMA_VERSION, "kind": "counter", "ts": self._now(),
               "name": name, "value": value}
        if round is not None:
            rec["round"] = int(round)
        if tags:
            rec["tags"] = tags
        self._emit(rec)

    def span(self, name: str, **args) -> _Span:
        return _Span(self, name, args)

    # -- reads / lifecycle -------------------------------------------------

    def events(self) -> list[dict]:
        """Materialized in-memory events (path=None sinks only)."""
        if self.path is not None:
            raise RuntimeError(
                "this sink writes to a file — close() it and use "
                "telemetry.read_events(path)")
        return [_jsonable(r) for r in list(self._mem)]

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        if self._q is not None:
            self._q.put(_CLOSE)
            self._thread.join()
            self._file.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


# ---------------------------------------------------------------------------
# the module-global hook surface (what instrumented code calls)
# ---------------------------------------------------------------------------

_ACTIVE: MetricsSink | None = None


class _NoopSpan:
    """Shared do-nothing context manager: the telemetry-off span cost is
    one global load, one None check, and returning this singleton."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NOOP = _NoopSpan()


def install(sink: MetricsSink) -> MetricsSink:
    """Make `sink` the process-wide active sink (returns it)."""
    global _ACTIVE
    _ACTIVE = sink
    return sink


def uninstall() -> None:
    global _ACTIVE
    _ACTIVE = None


def active() -> MetricsSink | None:
    return _ACTIVE


def enabled() -> bool:
    return _ACTIVE is not None


def span(name: str, **args):
    s = _ACTIVE
    return _NOOP if s is None else s.span(name, **args)


def counter(name: str, value, *, round: int | None = None, **tags) -> None:
    s = _ACTIVE
    if s is not None:
        s.counter(name, value, round=round, **tags)


def round_metrics(rnd: int, metrics: dict) -> None:
    s = _ACTIVE
    if s is not None:
        s.round_metrics(rnd, metrics)


def run_meta(meta: dict) -> None:
    s = _ACTIVE
    if s is not None:
        s.run_meta(meta)


@contextmanager
def session(sink: MetricsSink):
    """install -> yield -> uninstall + close, exception-safe."""
    install(sink)
    try:
        yield sink
    finally:
        uninstall()
        sink.close()


class ConsoleReporter:
    """The train.py round/step reporter (replaces its hand-rolled prints).

    Rates are monotonic (`time.perf_counter`) and measure the stepping
    window only: `start()` is called after checkpoint restore / stream
    construction, and checkpoint writes happen outside the reported window
    — so checkpoint I/O time is never folded into s/round.
    """

    def __init__(self, *, unit: str = "step", log_every: int = 10,
                 total: int | None = None, start: int = 0):
        self.unit = unit
        self.log_every = max(1, int(log_every))
        self.total = total
        self._start = int(start)
        self._t0: float | None = None

    def start(self) -> "ConsoleReporter":
        self._t0 = time.perf_counter()
        return self

    def report(self, t: int, metrics: dict, *, cohort: int | None = None
               ) -> None:
        if self._t0 is None:
            self.start()
        last = self.total is not None and t == self.total - 1
        if t % self.log_every != 0 and not last:
            return
        if metrics.get("skipped"):
            print(f"{self.unit} {t:5d} | skipped (buffer never filled)",
                  flush=True)
            return
        rate = (time.perf_counter() - self._t0) / (t - self._start + 1)
        part = (f" | done {int(metrics['completed'])}/{cohort}"
                if cohort is not None and "completed" in metrics else "")
        print(f"{self.unit} {t:5d} | loss {float(metrics['loss']):8.4f} | "
              f"gnorm {float(metrics['grad_norm']):9.3f} | "
              f"{rate:6.2f}s/{self.unit}" + part, flush=True)
