"""Structured metrics, round-phase tracing, and a zero-cost-when-off event
pipeline for train/fleet/wire (DESIGN.md §3.14).

    from repro import telemetry

    with telemetry.session(telemetry.MetricsSink("run.telemetry.jsonl")):
        ...   # drivers/streams/pager/checkpoint emit spans + counters

    python -m repro.telemetry run.telemetry.jsonl --validate --to-trace t.json

Instrumented code calls the module-level `span`/`counter`/`round_metrics`
helpers; with no sink installed they cost one global load and a None
check. Import stays numpy-only — the streams and checkpoint layers pull
this in, and nothing here may drag jax along.
"""
from repro.telemetry.events import (
    EVENT_KINDS,
    SCHEMA_VERSION,
    TelemetryError,
    read_events,
    validate_events,
)
from repro.telemetry.sink import (
    ConsoleReporter,
    MetricsSink,
    active,
    counter,
    enabled,
    install,
    round_metrics,
    run_meta,
    session,
    span,
    uninstall,
)
from repro.telemetry.trace import to_trace_events, write_trace

__all__ = [
    "EVENT_KINDS", "SCHEMA_VERSION", "TelemetryError",
    "read_events", "validate_events",
    "ConsoleReporter", "MetricsSink",
    "active", "counter", "enabled", "install", "round_metrics", "run_meta",
    "session", "span", "uninstall",
    "to_trace_events", "write_trace",
]
