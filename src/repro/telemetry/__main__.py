"""Telemetry stream CLI: validate, summarize, export Chrome traces.

    python -m repro.telemetry RUN.telemetry.jsonl --validate
    python -m repro.telemetry RUN.telemetry.jsonl --to-trace trace.json
    python -m repro.telemetry RUN.telemetry.jsonl --summary

Exit codes: 0 clean, 1 schema problems (--validate), 2 unreadable file.
"""
from __future__ import annotations

import argparse
import sys
from collections import defaultdict

from repro.telemetry.events import TelemetryError, read_events, validate_events
from repro.telemetry.trace import write_trace


def _summary(events: list[dict]) -> None:
    kinds = defaultdict(int)
    spans: dict[str, list[float]] = defaultdict(list)
    counters: dict[str, float] = defaultdict(float)
    last_metrics: dict | None = None
    last_round = None
    for ev in events:
        kinds[ev.get("kind", "?")] += 1
        if ev.get("kind") == "span":
            spans[ev["name"]].append(float(ev["dur"]))
        elif ev.get("kind") == "counter":
            v = ev.get("value")
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                counters[ev["name"]] += v
        elif ev.get("kind") == "round_metrics":
            last_metrics, last_round = ev.get("metrics"), ev.get("round")
    print("events: " + ", ".join(f"{k}={n}" for k, n in sorted(kinds.items())))
    for name in sorted(spans):
        d = spans[name]
        print(f"span {name:14s} n={len(d):5d} total={sum(d):8.3f}s "
              f"mean={sum(d) / len(d) * 1e3:8.3f}ms")
    for name in sorted(counters):
        print(f"counter {name:28s} total={counters[name]:.6g}")
    if last_metrics is not None:
        shown = {k: v for k, v in last_metrics.items()
                 if isinstance(v, (int, float))}
        print(f"last round {last_round}: " + ", ".join(
            f"{k}={v:.6g}" for k, v in sorted(shown.items())))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.telemetry")
    ap.add_argument("file", help="telemetry JSONL stream")
    ap.add_argument("--to-trace", metavar="OUT", default=None,
                    help="write Chrome/Perfetto trace_event JSON here")
    ap.add_argument("--validate", action="store_true",
                    help="schema-check every record; exit 1 on problems")
    ap.add_argument("--summary", action="store_true",
                    help="print per-span totals, counter sums, last metrics")
    args = ap.parse_args(argv)

    try:
        events = read_events(args.file)
    except (TelemetryError, OSError) as e:
        print(e, file=sys.stderr)
        return 2

    rc = 0
    if args.validate:
        problems = validate_events(events)
        for p in problems:
            print(f"INVALID: {p}", file=sys.stderr)
        print(f"{args.file}: {len(events)} events, "
              + ("schema OK" if not problems
                 else f"{len(problems)} schema problems"))
        rc = 1 if problems else 0
    if args.summary:
        _summary(events)
    if args.to_trace:
        n = write_trace(events, args.to_trace)
        print(f"wrote {n} trace events -> {args.to_trace}")
    if not (args.validate or args.summary or args.to_trace):
        ap.error("nothing to do: pass --validate, --summary, or --to-trace")
    return rc


if __name__ == "__main__":
    sys.exit(main())
