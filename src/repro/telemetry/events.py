"""Event schema + JSONL decode for `repro.telemetry` (DESIGN.md §3.14).

One run = one append-only JSONL file. Every line is a self-describing,
schema-versioned record (`"v"`), one of four kinds:

  run_meta       static run facts, emitted once near the start: the CLI
                 config, arch/param counts, and the analytic per-level
                 wire accounting (`wire_bytes_per_round`) — so a telemetry
                 file is interpretable without the run's argv;
  round_metrics  the per-round metrics dict (loss, grad_norm, the fleet
                 participation keys, opt-in device-side norms);
  span           one host-side phase interval: `ts` (start, seconds since
                 the sink's monotonic epoch), `dur`, `tid` (thread), and
                 `depth` (per-thread nesting level);
  counter        a named domain measurement (uplink bits, chaos events,
                 pager residency); `value` is a number or a small list of
                 numbers (histogram buckets).

Decoding tolerates a TORN TAIL exactly like `checkpoint/io.py` tolerates a
truncated checkpoint read: a crash mid-write can only damage the final
line, so `read_events` drops an undecodable last line silently but raises
`TelemetryError` on damage anywhere else (that is out-of-band corruption,
not an interrupted run).
"""
from __future__ import annotations

import json
import numbers

SCHEMA_VERSION = 1
EVENT_KINDS = ("run_meta", "round_metrics", "span", "counter")


class TelemetryError(RuntimeError):
    """The file is not a readable telemetry stream (corrupt beyond the
    tolerated torn tail, or records violate the schema)."""


def read_events(path: str) -> list[dict]:
    """Decode a telemetry JSONL file; the inverse of the sink's writes.

    An undecodable FINAL line (torn by a crash mid-write) is dropped; an
    undecodable interior line raises `TelemetryError`.
    """
    with open(path, "rb") as f:
        raw = f.read()
    lines = raw.split(b"\n")
    while lines and not lines[-1].strip():
        lines.pop()
    events: list[dict] = []
    for i, line in enumerate(lines):
        if not line.strip():
            continue
        try:
            ev = json.loads(line)
        except ValueError as e:
            if i == len(lines) - 1:
                break  # torn tail: the interrupted run's final write
            raise TelemetryError(
                f"{path}: line {i + 1} is not valid JSON mid-file — the "
                f"stream is corrupt beyond a torn tail "
                f"({type(e).__name__}: {e})") from e
        if not isinstance(ev, dict):
            raise TelemetryError(
                f"{path}: line {i + 1} decodes to {type(ev).__name__}, "
                "not an event object")
        events.append(ev)
    return events


def _is_num(v) -> bool:
    return isinstance(v, numbers.Real) and not isinstance(v, bool)


def _is_metric_value(v) -> bool:
    """round_metrics values: scalars (bool allowed — e.g. `skipped`) or a
    small list of numbers (histograms ride counters, but keep symmetric)."""
    if isinstance(v, (bool, str)) or v is None or _is_num(v):
        return True
    return isinstance(v, list) and all(_is_num(x) for x in v)


def validate_events(events: list[dict]) -> list[str]:
    """Schema check; returns a list of human-readable problems (empty =
    valid). The CI telemetry smoke gates on this."""
    problems: list[str] = []

    def bad(i: int, ev: dict, why: str) -> None:
        problems.append(f"event {i} ({ev.get('kind', '?')}): {why}")

    for i, ev in enumerate(events):
        if ev.get("v") != SCHEMA_VERSION:
            bad(i, ev, f"schema version {ev.get('v')!r} != {SCHEMA_VERSION}")
            continue
        kind = ev.get("kind")
        if kind not in EVENT_KINDS:
            bad(i, ev, f"unknown kind {kind!r}")
            continue
        if not _is_num(ev.get("ts")) or ev["ts"] < 0:
            bad(i, ev, f"ts {ev.get('ts')!r} is not a non-negative number")
        if kind == "run_meta":
            if not isinstance(ev.get("meta"), dict):
                bad(i, ev, "meta is not an object")
        elif kind == "round_metrics":
            if not isinstance(ev.get("round"), int):
                bad(i, ev, f"round {ev.get('round')!r} is not an int")
            metrics = ev.get("metrics")
            if not isinstance(metrics, dict):
                bad(i, ev, "metrics is not an object")
            else:
                for k, v in metrics.items():
                    if not _is_metric_value(v):
                        bad(i, ev, f"metric {k!r} value {v!r} is not a "
                                   "scalar or list of numbers")
        elif kind == "span":
            if not isinstance(ev.get("name"), str):
                bad(i, ev, "span has no name")
            if not _is_num(ev.get("dur")) or ev["dur"] < 0:
                bad(i, ev, f"dur {ev.get('dur')!r} is not a non-negative "
                           "number")
            if not isinstance(ev.get("tid"), int):
                bad(i, ev, "tid is not an int")
            if not isinstance(ev.get("depth"), int) or ev["depth"] < 0:
                bad(i, ev, "depth is not a non-negative int")
        elif kind == "counter":
            if not isinstance(ev.get("name"), str):
                bad(i, ev, "counter has no name")
            v = ev.get("value")
            if not (_is_num(v)
                    or (isinstance(v, list) and all(_is_num(x) for x in v))):
                bad(i, ev, f"value {v!r} is not a number or list of numbers")
            if "round" in ev and not isinstance(ev["round"], int):
                bad(i, ev, f"round {ev['round']!r} is not an int")
    return problems
