"""Model assembly: embeddings + scan-over-layers blocks + LM head.

One assembly covers all six assigned families (DESIGN.md §4):

  dense  — GQA + RoPE (+ optional sliding window / QKV bias)
  moe    — dense attention + capacity-free top-k MoE FFN (`moe.py`)
  ssm    — RWKV6 mixer, attention-free (`mixers.py`)
  hybrid — Hymba parallel attention+SSD heads
  vlm    — qwen2-vl: M-RoPE, patch-embedding stub spliced into the stream
  audio  — whisper: bidirectional encoder over frame-embedding stub +
           causal decoder with cross-attention

Layer parameters are *stacked* (leading L axis) and the stack is traversed
with `lax.scan`, keeping compile time flat in depth (deepseek-67b has 95
layers). Entry points:

  init_params(key, cfg)                         -> params
  loss_fn(params, batch, cfg)                   -> scalar loss
  forward(params, batch, cfg)                   -> logits          (no loss)
  prefill(params, batch, cfg, cache_len)        -> (last logits, cache)
  decode_step(params, cache, tokens, pos, cfg)  -> (logits, cache)
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import mixers
from repro.models.config import ArchConfig
from repro.models.layers import (
    cross_entropy,
    embed_tokens,
    init_mlp,
    init_norm,
    lm_logits,
    mlp,
    norm,
)
from repro.models.moe import init_moe, moe_ffn


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ArchConfig):
    ks = jax.random.split(key, 6)
    p = {"ln1": init_norm(cfg.d_model, cfg.norm, cfg.dtype),
         "ln2": init_norm(cfg.d_model, cfg.norm, cfg.dtype)}
    if cfg.attention_mixer == "attn":
        p["mixer"] = mixers.init_attention(ks[0], cfg)
    elif cfg.attention_mixer == "rwkv6":
        p["mixer"] = mixers.init_rwkv6(ks[0], cfg)
    elif cfg.attention_mixer == "hymba":
        p["mixer"] = mixers.init_hymba(ks[0], cfg)
    else:
        raise ValueError(cfg.attention_mixer)
    if cfg.num_experts:
        p["ffn"] = init_moe(ks[1], cfg)
    else:
        p["ffn"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.act, cfg.dtype)
    if cfg.is_encdec:
        p["ln_cross"] = init_norm(cfg.d_model, cfg.norm, cfg.dtype)
        p["cross"] = mixers.init_cross_attention(ks[2], cfg)
    return p


def _init_encoder_block(key, cfg: ArchConfig):
    ks = jax.random.split(key, 2)
    return {
        "ln1": init_norm(cfg.d_model, cfg.norm, cfg.dtype),
        "mixer": mixers.init_attention(ks[0], cfg),
        "ln2": init_norm(cfg.d_model, cfg.norm, cfg.dtype),
        "ffn": init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.act, cfg.dtype),
    }


def init_params(key, cfg: ArchConfig):
    ks = jax.random.split(key, 6)
    vp = cfg.padded_vocab()
    p: dict[str, Any] = {
        "embed": jax.random.normal(ks[0], (vp, cfg.d_model), cfg.dtype) * 0.02,
        "blocks": jax.vmap(lambda k: _init_block(k, cfg))(
            jax.random.split(ks[1], cfg.num_layers)
        ),
        "final_norm": init_norm(cfg.d_model, cfg.norm, cfg.dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = jax.random.normal(ks[2], (vp, cfg.d_model), cfg.dtype) * 0.02
    if cfg.is_encdec:
        p["enc_blocks"] = jax.vmap(lambda k: _init_encoder_block(k, cfg))(
            jax.random.split(ks[3], cfg.encoder_layers)
        )
        p["enc_final_norm"] = init_norm(cfg.d_model, cfg.norm, cfg.dtype)
        # whisper: learned decoder positions, sinusoidal encoder positions
        p["pos_embed"] = (
            jax.random.normal(ks[4], (cfg.max_seq, cfg.d_model), cfg.dtype) * 0.02
        )
    return p


# ---------------------------------------------------------------------------
# positions (RoPE streams; M-RoPE for the VLM)
# ---------------------------------------------------------------------------

def mrope_grid(cfg: ArchConfig) -> int:
    return max(1, int(math.ceil(math.sqrt(max(cfg.vision_patches, 1)))))


def mrope_positions(cfg: ArchConfig, s: int, b: int):
    """(3, B, S) t/h/w position ids: patch grid then text (qwen2-vl)."""
    g = mrope_grid(cfg)
    i = jnp.arange(s)
    is_patch = i < cfg.vision_patches
    text = g + (i - cfg.vision_patches)
    t = jnp.where(is_patch, 0, text)
    h = jnp.where(is_patch, i // g, text)
    w = jnp.where(is_patch, i % g, text)
    pos = jnp.stack([t, h, w])  # (3, S)
    return jnp.broadcast_to(pos[:, None, :], (3, b, s))


def _positions(cfg: ArchConfig, b: int, s: int, offset: int = 0):
    if cfg.mrope_sections is not None:
        return mrope_positions(cfg, s, b)
    return jnp.broadcast_to(jnp.arange(offset, offset + s), (b, s))


def _decode_rope_positions(cfg: ArchConfig, b: int, pos):
    if cfg.mrope_sections is not None:
        g = mrope_grid(cfg)
        eff = g + (pos - cfg.vision_patches)
        return jnp.broadcast_to(eff, (3, b, 1))
    return jnp.broadcast_to(pos, (b, 1))


def _sinusoid(s: int, d: int, dtype):
    pos = jnp.arange(s)[:, None].astype(jnp.float32)
    dim = jnp.arange(0, d, 2)[None].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# ---------------------------------------------------------------------------
# block application (train / prefill / decode)
# ---------------------------------------------------------------------------

def _ffn(bp, x, cfg: ArchConfig):
    if cfg.num_experts:
        return moe_ffn(bp["ffn"], x, cfg)
    return mlp(x, bp["ffn"], cfg.act)


def _block_train(bp, x, cfg: ArchConfig, positions, enc):
    h = norm(x, bp["ln1"], cfg.norm)
    if cfg.attention_mixer == "attn":
        y = mixers.attention_train(bp["mixer"], h, cfg, positions=positions)
    elif cfg.attention_mixer == "rwkv6":
        y = mixers.rwkv6_train(bp["mixer"], h, cfg)
    else:
        y = mixers.hymba_train(bp["mixer"], h, cfg, positions=positions)
    x = x + y
    if cfg.is_encdec:
        x = x + mixers.cross_attention_train(
            bp["cross"], norm(x, bp["ln_cross"], cfg.norm), enc, cfg
        )
    return x + _ffn(bp, norm(x, bp["ln2"], cfg.norm), cfg)


def _block_prefill(bp, x, cfg: ArchConfig, positions, enc, cache_len: int):
    h = norm(x, bp["ln1"], cfg.norm)
    if cfg.attention_mixer == "attn":
        y, c = mixers.attention_prefill(
            bp["mixer"], h, cfg, positions=positions, cache_len=cache_len
        )
    elif cfg.attention_mixer == "rwkv6":
        y, c = mixers.rwkv6_prefill(bp["mixer"], h, cfg)
    else:
        y, c = mixers.hymba_prefill(
            bp["mixer"], h, cfg, positions=positions, cache_len=cache_len
        )
    x = x + y
    cache = {"mixer": c}
    if cfg.is_encdec:
        hc = norm(x, bp["ln_cross"], cfg.norm)
        x = x + mixers.cross_attention_train(bp["cross"], hc, enc, cfg)
        cache["cross"] = mixers.cross_attention_cache(bp["cross"], enc, cfg)
    return x + _ffn(bp, norm(x, bp["ln2"], cfg.norm), cfg), cache


def _block_decode(bp, x, cfg: ArchConfig, cache, pos, rope_pos):
    h = norm(x, bp["ln1"], cfg.norm)
    if cfg.attention_mixer == "attn":
        y, c = mixers.attention_decode(
            bp["mixer"], h, cfg, cache["mixer"], pos, rope_positions=rope_pos
        )
    elif cfg.attention_mixer == "rwkv6":
        y, c = mixers.rwkv6_decode(bp["mixer"], h, cfg, cache["mixer"])
    else:
        y, c = mixers.hymba_decode(bp["mixer"], h, cfg, cache["mixer"], pos)
    x = x + y
    new_cache = {"mixer": c}
    if cfg.is_encdec:
        hc = norm(x, bp["ln_cross"], cfg.norm)
        x = x + mixers.cross_attention_decode(bp["cross"], hc, cfg, cache["cross"])
        new_cache["cross"] = cache["cross"]
    return x + _ffn(bp, norm(x, bp["ln2"], cfg.norm), cfg), new_cache


def _apply_remat(body, remat):
    """remat: True/"full" = save nothing; "dots" = save matmul outputs with
    no batch dims (weight-stationary recompute only); False/"none" = store
    all activations."""
    if remat is True or remat == "full":
        return jax.checkpoint(body)
    if remat == "dots":
        return jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        )
    return body


def _scan_blocks(blocks, x, body, *, remat, unroll: bool = False):
    """Traverse the stacked layer params.

    unroll=False: `lax.scan` — flat compile time (the production default).
    unroll=True: python loop — exact per-layer HLO, used by the dry-run so
    `cost_analysis()` / collective parsing see every layer (XLA's cost model
    counts a while-loop body ONCE regardless of trip count; EXPERIMENTS.md
    §Dry-run).
    """
    body = _apply_remat(body, remat)

    if unroll:
        n = jax.tree.leaves(blocks)[0].shape[0]
        for i in range(n):
            x = body(jax.tree.map(lambda a: a[i], blocks), x)
        return x

    def step(carry, bp):
        return body(bp, carry), None

    out, _ = lax.scan(step, x, blocks)
    return out


# ---------------------------------------------------------------------------
# encoder (whisper)
# ---------------------------------------------------------------------------

def encode(params, frames, cfg: ArchConfig, *, remat="full",
           unroll: bool = False):
    """frames: (B, T_enc, D) precomputed frame embeddings (conv-frontend stub)."""
    x = frames + _sinusoid(frames.shape[1], cfg.d_model, frames.dtype)[None]

    def body(bp, x):
        h = norm(x, bp["ln1"], cfg.norm)
        y = mixers.attention_train(
            bp["mixer"], h, cfg, positions=_positions(cfg, x.shape[0], x.shape[1]),
            causal=False, window=None,
        )
        x = x + y
        return x + mlp(norm(x, bp["ln2"], cfg.norm), bp["ffn"], cfg.act)

    x = _scan_blocks(params["enc_blocks"], x, body, remat=remat, unroll=unroll)
    return norm(x, params["enc_final_norm"], cfg.norm)


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------

def _embed_inputs(params, batch, cfg: ArchConfig, inputs):
    x = embed_tokens(inputs, params["embed"])
    if cfg.family == "vlm" and "patches" in batch:
        p = batch["patches"].astype(x.dtype)  # (B, P, D) stub embeddings
        x = jnp.concatenate([p, x[:, p.shape[1]:]], axis=1)
    if cfg.is_encdec:
        s = inputs.shape[1]
        x = x + params["pos_embed"][:s][None]
    return x


def _head(params, x, cfg: ArchConfig):
    table = params.get("lm_head", params["embed"])
    return lm_logits(norm(x, params["final_norm"], cfg.norm), table, cfg.vocab)


def _head_raw(params, x, cfg: ArchConfig):
    """Unmasked logits over the padded vocab (loss path: the pad mask is
    folded into the CE reductions instead of materializing a masked copy)."""
    table = params.get("lm_head", params["embed"])
    h = norm(x, params["final_norm"], cfg.norm)
    return jnp.einsum("...d,vd->...v", h, table)


def _streaming_ce(logits, labels, true_vocab: int):
    """Vocab-parallel-friendly CE: no gather over the (sharded) vocab axis.

    gold logit is recovered with an iota==label masked reduction and pad-ids
    are excluded from logsumexp by the same predicate — both are elementwise
    + reduce, which GSPMD keeps sharded over "model" (the gather in
    take_along_axis forced an all-gather of the f32 logits; §Perf change A).
    """
    l32 = logits.astype(jnp.float32)
    iota = lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    valid = iota < true_vocab
    neg = jnp.float32(-1e30)
    m = lax.stop_gradient(jnp.max(jnp.where(valid, l32, neg), axis=-1))
    ex = jnp.exp(jnp.where(valid, l32 - m[..., None], neg))
    logz = m + jnp.log(jnp.sum(ex, axis=-1))
    gold = jnp.sum(jnp.where(iota == labels[..., None], l32, 0.0), axis=-1)
    return logz - gold


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def forward(params, batch, cfg: ArchConfig, *, remat="full",
            unroll: bool = False, head: str = "masked",
            seq_shard: bool = False):
    """Teacher-forced logits over the full input sequence."""
    inputs = batch["tokens"][:, :-1] if batch["tokens"].shape[1] > 1 else batch["tokens"]
    b, s = inputs.shape
    enc = None
    if cfg.is_encdec:
        enc = encode(params, batch["frames"], cfg, remat=remat, unroll=unroll)
    x = _embed_inputs(params, batch, cfg, inputs)
    positions = _positions(cfg, b, s)
    body = partial(
        lambda bp, x: _block_train(bp, x, cfg, positions, enc)
    )
    if seq_shard:
        # §Perf change E — sequence parallelism: the per-layer residual
        # (what jax.checkpoint stores for the backward pass) is sharded
        # seq->"model", cutting the dominant activation-stash term 16x.
        # GSPMD re-gathers inside the block where attention needs full seq.
        from jax.sharding import PartitionSpec as _P
        inner = body
        body = lambda bp, x: inner(
            bp, lax.with_sharding_constraint(x, _P(None, "model", None)))
    x = _scan_blocks(params["blocks"], x, body, remat=remat, unroll=unroll)
    if head == "raw":
        return _head_raw(params, x, cfg)
    return _head(params, x, cfg)


def loss_fn(params, batch, cfg: ArchConfig, *, remat="full",
            unroll: bool = False, ce: str = "gather",
            seq_shard: bool = False):
    labels = batch["tokens"][:, 1:]
    mask = None
    if cfg.family == "vlm" and "patches" in batch:
        # only text positions contribute to the LM loss
        p = batch["patches"].shape[1]
        mask = (jnp.arange(labels.shape[1]) >= p)[None, :]
    if ce == "streaming":
        logits = forward(params, batch, cfg, remat=remat, unroll=unroll,
                         head="raw", seq_shard=seq_shard)
        nll = _streaming_ce(logits, labels, cfg.vocab)
    else:  # "gather": the pre-§Perf baseline implementation
        logits = forward(params, batch, cfg, remat=remat, unroll=unroll,
                         seq_shard=seq_shard)
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = jnp.broadcast_to(mask, nll.shape).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def prefill(params, batch, cfg: ArchConfig, *, cache_len: int,
            remat="full", unroll: bool = False):
    """Consume the prompt, return (last-token logits, stacked cache)."""
    inputs = batch["tokens"]
    b, s = inputs.shape
    enc = None
    if cfg.is_encdec:
        enc = encode(params, batch["frames"], cfg, remat=remat, unroll=unroll)
    x = _embed_inputs(params, batch, cfg, inputs)
    positions = _positions(cfg, b, s)

    if unroll:
        n = jax.tree.leaves(params["blocks"])[0].shape[0]
        cache_list = []
        for i in range(n):
            bp = jax.tree.map(lambda a: a[i], params["blocks"])
            x, cache = _block_prefill(bp, x, cfg, positions, enc, cache_len)
            cache_list.append(cache)
        caches = jax.tree.map(lambda *xs: jnp.stack(xs), *cache_list)
    else:
        def step(carry, bp):
            y, cache = _block_prefill(bp, carry, cfg, positions, enc, cache_len)
            return y, cache

        x, caches = lax.scan(step, x, params["blocks"])
    logits = _head(params, x[:, -1:], cfg)
    return logits, caches


# analysis: allow[ignored-argument] `params` keeps the cache constructor
# signature parallel to prefill/decode; shapes derive from cfg alone
def init_cache(params, cfg: ArchConfig, *, batch: int, cache_len: int,
               dtype=None):
    """Zero cache pytree with stacked layer axis (for serve_step lowering)."""
    dtype = dtype or cfg.dtype
    l, b = cfg.num_layers, batch
    kh, hd = cfg.num_kv_heads, cfg.head_dim
    window = cfg.sliding_window
    cap = min(cache_len, window) if window else cache_len

    def attn_cache():
        return mixers.AttnCache(
            k=jnp.zeros((l, b, cap, kh, hd), dtype),
            v=jnp.zeros((l, b, cap, kh, hd), dtype),
        )

    if cfg.attention_mixer == "attn":
        cache: dict[str, Any] = {"mixer": attn_cache()}
    elif cfg.attention_mixer == "rwkv6":
        h = cfg.num_heads
        rhd = cfg.d_model // h
        cache = {"mixer": mixers.Rwkv6Cache(
            state=jnp.zeros((l, b, h, rhd, rhd), jnp.float32),
            x_prev=jnp.zeros((l, b, cfg.d_model), dtype),
        )}
    else:  # hymba
        cache = {"mixer": mixers.HymbaCache(
            attn=attn_cache(),
            ssm_state=jnp.zeros(
                (l, b, cfg.num_heads, cfg.ssm_state, cfg.head_dim), jnp.float32
            ),
        )}
    if cfg.is_encdec:
        cache["cross"] = mixers.AttnCache(
            k=jnp.zeros((l, b, cfg.encoder_seq, kh, hd), dtype),
            v=jnp.zeros((l, b, cfg.encoder_seq, kh, hd), dtype),
        )
    return cache


def decode_step(params, cache, tokens, pos, cfg: ArchConfig, *,
                unroll: bool = False):
    """One decode step. tokens: (B, 1) int32; pos: () int32 absolute position.

    cache leaves carry a leading layer axis; the layer stack is scanned with
    the cache consumed/produced as scan xs/ys.
    """
    b = tokens.shape[0]
    x = embed_tokens(tokens, params["embed"])
    if cfg.is_encdec:
        x = x + lax.dynamic_slice_in_dim(params["pos_embed"], pos, 1)[None]
    rope_pos = _decode_rope_positions(cfg, b, pos)

    if unroll:
        n = jax.tree.leaves(params["blocks"])[0].shape[0]
        cache_list = []
        for i in range(n):
            bp = jax.tree.map(lambda a: a[i], params["blocks"])
            cache_l = jax.tree.map(lambda a: a[i], cache)
            x, nc = _block_decode(bp, x, cfg, cache_l, pos, rope_pos)
            cache_list.append(nc)
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *cache_list)
    else:
        def step(carry, xs):
            bp, cache_l = xs
            y, new_cache = _block_decode(bp, carry, cfg, cache_l, pos, rope_pos)
            return y, new_cache

        x, new_cache = lax.scan(step, x, (params["blocks"], cache))
    logits = _head(params, x, cfg)
    return logits, new_cache
