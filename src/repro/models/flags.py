"""Trace-time flags (set before lowering; never mutated inside jit).

UNROLL_INNER_SCANS: unroll the kv-block / linear-attention-chunk scans so
XLA's cost analysis counts every iteration (it counts a while-loop body
ONCE regardless of trip count). Used only by the dry-run's shallow
depth-probe lowerings — production keeps rolled loops.
"""
UNROLL_INNER_SCANS = False


def set_unroll_inner_scans(value: bool) -> None:
    global UNROLL_INNER_SCANS
    UNROLL_INNER_SCANS = bool(value)


def inner_scan_unroll():
    return True if UNROLL_INNER_SCANS else 1
