"""Architecture configuration shared by every model family."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # 'dense' | 'moe' | 'ssm' | 'hybrid' | 'vlm' | 'audio'
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None

    # attention
    rope_theta: float = 1e4
    qkv_bias: bool = False
    sliding_window: int | None = None
    mrope_sections: tuple[int, int, int] | None = None  # (t, h, w) — qwen2-vl
    attention_mixer: str = "attn"  # 'attn' | 'rwkv6' | 'hymba'

    # ffn
    act: str = "swiglu"  # 'swiglu' | 'gelu'
    num_experts: int = 0
    experts_per_token: int = 0
    shared_expert_ff: int = 0  # qwen2-moe shared experts as one fused FFN

    # ssm / hybrid
    ssm_state: int = 0
    ssm_heads: int = 0  # hymba: number of parallel mamba heads

    # enc-dec (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0  # stub frame count (1500 for whisper)

    # vlm stub
    vision_patches: int = 0  # patches consumed per sample at train/prefill

    norm: str = "rmsnorm"  # 'rmsnorm' | 'layernorm'
    tie_embeddings: bool = False
    dtype: Any = jnp.bfloat16

    # training shape defaults (overridden by input-shape presets)
    max_seq: int = 4096

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return self.attention_mixer == "rwkv6"

    def padded_vocab(self, multiple: int = 16) -> int:
        """Vocab padded for TP divisibility (Megatron practice); logits at pad
        ids are masked so the math is unchanged."""
        return ((self.vocab + multiple - 1) // multiple) * multiple

    def supports_long_context(self) -> bool:
        """Sub-quadratic decode path exists (DESIGN.md §Arch-applicability)."""
        return self.attention_mixer in ("rwkv6", "hymba") or self.sliding_window is not None

    def param_count(self) -> int:
        """Approximate total parameters (embedding + blocks), for 6ND."""
        d, f, hd = self.d_model, self.d_ff, self.head_dim
        qh, kh = self.num_heads, self.num_kv_heads
        attn = d * qh * hd + 2 * d * kh * hd + qh * hd * d
        if self.attention_mixer == "rwkv6":
            # r,k,v,g,w projections + output
            attn = 6 * d * d
        elif self.attention_mixer == "hymba":
            ssm_inner = self.ssm_heads * hd
            attn += 2 * d * ssm_inner + ssm_inner * d + ssm_inner * (2 * self.ssm_state + 2)
        if self.num_experts:
            ffn = self.num_experts * (3 if self.act == "swiglu" else 2) * d * f
            ffn += d * self.num_experts
            if self.shared_expert_ff:
                ffn += (3 if self.act == "swiglu" else 2) * d * self.shared_expert_ff
        else:
            ffn = (3 if self.act == "swiglu" else 2) * d * f
        per_layer = attn + ffn + 2 * d
        total = self.num_layers * per_layer + self.vocab * d
        if not self.tie_embeddings:
            total += self.vocab * d
        if self.is_encdec:
            enc_attn = 4 * d * d
            enc_ffn = (3 if self.act == "swiglu" else 2) * d * f
            total += self.encoder_layers * (enc_attn + enc_ffn + 2 * d)
            total += self.num_layers * (4 * d * d)  # cross-attn in decoder
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top-k + shared only)."""
        if not self.num_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        n_ff_mats = 3 if self.act == "swiglu" else 2
        dense_ffn = self.num_experts * n_ff_mats * d * f
        active_ffn = self.experts_per_token * n_ff_mats * d * f
        return self.param_count() - self.num_layers * (dense_ffn - active_ffn)
