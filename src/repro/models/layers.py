"""Shared neural-net layers, pure-functional JAX.

Conventions:
  - activations (B, S, D); attention heads (B, S, H, hd)
  - params are plain dicts of jnp arrays; init fns return (params, ...)
  - softmax / norms accumulate in f32 regardless of activation dtype
  - attention uses a streaming kv-block softmax ("flash pattern") so a
    32k-token prefill never materializes an S x S score matrix
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def layernorm(x, scale, bias=None, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mean) * jax.lax.rsqrt(var + eps)
    out = out.astype(x.dtype) * scale
    if bias is not None:
        out = out + bias
    return out


def norm(x, params, kind: str):
    if kind == "rmsnorm":
        return rmsnorm(x, params["scale"])
    return layernorm(x, params["scale"], params.get("bias"))


def init_norm(d: int, kind: str, dtype):
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


# ---------------------------------------------------------------------------
# rotary embeddings (RoPE + qwen2-vl's M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, hd); positions: (B, S) int32."""
    freqs = rope_freqs(x.shape[-1], theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections: tuple[int, int, int]):
    """Multimodal RoPE (qwen2-vl, arXiv:2409.12191).

    positions3: (3, B, S) — temporal / height / width position ids.
    The head_dim/2 frequency channels are split into three sections; each
    section rotates by its own position stream.
    """
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    sec = jnp.asarray(
        sum(([i] * s for i, s in enumerate(sections)), []), jnp.int32
    )  # (hd/2,) section id per freq channel
    # per-channel position stream: (hd/2, B, S) -> (B, S, hd/2)
    pos = jnp.moveaxis(jnp.take(positions3, sec, axis=0), 0, -1).astype(jnp.float32)
    angles = pos * freqs  # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (streaming-softmax; GQA; optional sliding window)
# ---------------------------------------------------------------------------

def _gqa_expand(k, n_rep: int):
    """(B, S, KH, hd) -> (B, S, KH*n_rep, hd) by repetition."""
    if n_rep == 1:
        return k
    b, s, kh, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kh, n_rep, hd)).reshape(
        b, s, kh * n_rep, hd
    )


def chunked_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                      q_offset: int = 0, block: int = 1024):
    """Block-sparse streaming-softmax attention.

    q: (B, Sq, H, hd); k, v: (B, Skv, KH, hd) with H % KH == 0.
    q_offset: absolute position of q[0] relative to k[0] (decode/prefill).
    window: sliding-window size (None = full).
    Returns (B, Sq, H, hd).

    Queries are processed in blocks too (§Perf change G): for each q block
    only the kv blocks that are not FULLY masked are visited — upper-triangle
    blocks are skipped under causal masking (~2x at long seq) and
    out-of-window blocks under SWA (Skv/window x, e.g. 16x for hymba's
    window-1024 at 4k context). Partially-masked diagonal blocks keep the
    exact elementwise mask, so results are identical to dense masking.
    """
    b, sq, h, hd = q.shape
    skv, kh = k.shape[1], k.shape[2]
    k = _gqa_expand(k, h // kh)
    v = _gqa_expand(v, h // kh)
    scale = 1.0 / math.sqrt(hd)

    block = min(block, skv)
    nblk = (skv + block - 1) // block
    pad = nblk * block - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(b, nblk, block, h, hd)
    vb = v.reshape(b, nblk, block, h, hd)

    qb_size = min(block, sq)
    nqb = (sq + qb_size - 1) // qb_size
    qpad = nqb * qb_size - sq
    q32 = q.astype(jnp.float32) * scale
    if qpad:
        q32 = jnp.pad(q32, ((0, 0), (0, qpad), (0, 0), (0, 0)))

    from repro.models import flags

    def make_body(q_blk, q_pos):
        def body(carry, blk):
            m_prev, l_prev, acc = carry
            kj, vj, j = blk
            kv_pos = j * block + jnp.arange(block)
            s = jnp.einsum("bqhd,bkhd->bhqk", q_blk, kj.astype(jnp.float32))
            mask = jnp.ones((qb_size, block), bool)
            if causal:
                mask &= q_pos[:, None] >= kv_pos[None, :]
            if window is not None:
                mask &= q_pos[:, None] - kv_pos[None, :] < window
            mask &= (kv_pos < skv)[None, :]
            s = jnp.where(mask[None, None], s, -jnp.inf)
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
            # guard fully-masked rows (m=-inf): exp(-inf - -inf) -> safe m
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            corr = jnp.exp(
                jnp.where(jnp.isfinite(m_prev), m_prev - m_safe, -jnp.inf))
            l_new = l_prev * corr + jnp.sum(p, axis=-1)
            # §Perf change F: probabilities feed the MXU in bf16 (the
            # TPU-native dot input dtype); max/sum/acc statistics stay f32.
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(jnp.bfloat16),
                vj.astype(jnp.bfloat16), preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc), None

        return body

    outs = []
    for qi in range(nqb):
        q_blk = lax.slice_in_dim(q32, qi * qb_size, (qi + 1) * qb_size, axis=1)
        q_lo = q_offset + qi * qb_size
        q_hi = q_offset + min((qi + 1) * qb_size, sq) - 1
        j_lo = 0 if window is None else max(0, (q_lo - window + 1) // block)
        j_hi = min(nblk - 1, q_hi // block) if causal else nblk - 1
        j_hi = max(j_hi, j_lo)
        idx = jnp.arange(j_lo, j_hi + 1)
        m0 = jnp.full((b, h, qb_size), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, h, qb_size), jnp.float32)
        acc0 = jnp.zeros((b, h, qb_size, hd), jnp.float32)
        (m, l, acc), _ = lax.scan(
            make_body(q_blk, q_offset + qi * qb_size + jnp.arange(qb_size)),
            (m0, l0, acc0),
            (kb[:, j_lo:j_hi + 1].swapaxes(0, 1),
             vb[:, j_lo:j_hi + 1].swapaxes(0, 1), idx),
            unroll=flags.inner_scan_unroll(),
        )
        outs.append(acc / jnp.maximum(l, 1e-30)[..., None])
    out = jnp.concatenate(outs, axis=2) if nqb > 1 else outs[0]
    out = out[:, :, :sq]
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)  # (B, Sq, H, hd)


def decode_attention(q, k_cache, v_cache, cache_len, *, window: int | None = None):
    """Single-token attention against a (possibly ring-buffered) KV cache.

    q: (B, 1, H, hd); caches: (B, C, KH, hd); cache_len: () int32 — number of
    valid entries (for ring buffers C == window and all entries valid once
    wrapped; masking handles the warmup).

    §Perf change H: GQA is expressed as a grouped einsum (q reshaped to
    (B, KH, rep, hd)) instead of materially broadcasting the cache KH -> H,
    and both dots run on bf16 inputs with f32 accumulation. Without this,
    GSPMD's cheapest strategy was to all-gather an f32 COPY of the whole
    cache over the model axis (2 x 1.07 GB per layer per token on
    deepseek-67b decode_32k). Scores stay sharded over the cache axis; the
    softmax reductions become small psums.
    """
    b, _, h, hd = q.shape
    c, kh = k_cache.shape[1], k_cache.shape[2]
    rep = h // kh
    qg = q.reshape(b, kh, rep, hd).astype(jnp.bfloat16)
    s = jnp.einsum("bkrd,bckd->bkrc", qg, k_cache.astype(jnp.bfloat16),
                   preferred_element_type=jnp.float32)
    s = s / math.sqrt(hd)
    pos = jnp.arange(c)
    valid = pos[None, None, None, :] < cache_len
    if window is not None:
        valid &= pos[None, None, None, :] >= cache_len - window
    s = jnp.where(valid, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkrc,bckd->bkrd", p.astype(jnp.bfloat16),
                     v_cache.astype(jnp.bfloat16),
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# dense projections / FFN
# ---------------------------------------------------------------------------

def linear(x, w, b=None):
    y = jnp.einsum("...d,df->...f", x, w)
    if b is not None:
        y = y + b
    return y


def mlp(x, p, act: str):
    if act == "swiglu":
        return linear(jax.nn.silu(linear(x, p["w_gate"])) * linear(x, p["w_up"]),
                      p["w_down"])
    if act == "relu2":  # RWKV channel-mix: relu(xW)^2
        h = jnp.square(jax.nn.relu(linear(x, p["w_up"])))
        return linear(h, p["w_down"])
    h = jax.nn.gelu(linear(x, p["w_up"], p.get("b_up")))
    return linear(h, p["w_down"], p.get("b_down"))


def init_linear(key, d_in, d_out, dtype, bias=False, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": jax.random.normal(key, (d_in, d_out), dtype) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def init_mlp(key, d, f, act, dtype):
    ks = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(f)
    p = {
        "w_up": jax.random.normal(ks[0], (d, f), dtype) * s_in,
        "w_down": jax.random.normal(ks[1], (f, d), dtype) * s_out,
    }
    if act == "swiglu":
        p["w_gate"] = jax.random.normal(ks[2], (d, f), dtype) * s_in
    return p


def embed_tokens(tokens, table):
    return jnp.take(table, tokens, axis=0)


def lm_logits(x, table, true_vocab: int):
    """Project to (padded) vocab and mask pad ids to -inf."""
    logits = jnp.einsum("...d,vd->...v", x, table)
    v_pad = table.shape[0]
    if v_pad > true_vocab:
        neg = jnp.full((v_pad - true_vocab,), -1e30, logits.dtype)
        logits = logits.at[..., true_vocab:].set(neg)
    return logits


def cross_entropy(logits, labels, true_vocab: int):
    """Mean CE in f32; labels int32 (..., ) in [0, true_vocab).

    Masks the padded vocab tail itself (idempotent after `lm_logits`), so
    the logsumexp never includes garbage columns of an unmasked head."""
    logits = logits.astype(jnp.float32)
    v_pad = logits.shape[-1]
    if v_pad > true_vocab:
        neg = jnp.full((v_pad - true_vocab,), -1e30, logits.dtype)
        logits = logits.at[..., true_vocab:].set(neg)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
