"""Sequence mixers: softmax attention (GQA/RoPE/M-RoPE/SWA), RWKV6, Hymba.

Every mixer exposes the same three entry points so the block assembly in
`transformer.py` stays family-agnostic:

    init_<name>(key, cfg)                      -> params (no layer axis)
    <name>_train(params, x, cfg, *, pos, ...)  -> y                (full seq)
    <name>_prefill(params, x, cfg, *, pos)     -> (y, cache)       (build cache)
    <name>_decode(params, x, cfg, cache, pos)  -> (y, cache)       (1 token)

Caches are per-layer pytrees; `transformer.py` stacks them over layers.
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import ArchConfig
from repro.models.layers import (
    apply_mrope,
    apply_rope,
    chunked_attention,
    decode_attention,
    linear,
)
from repro.models.linear_attention import (
    chunked_linear_attention,
    linear_attention_decode,
)


def _normal(key, shape, dtype, fan_in):
    return jax.random.normal(key, shape, dtype) * (1.0 / math.sqrt(fan_in))


# ---------------------------------------------------------------------------
# softmax attention (dense / VLM / encoder-decoder self-attention)
# ---------------------------------------------------------------------------

class AttnCache(NamedTuple):
    k: jax.Array  # (B, C, KH, hd)
    v: jax.Array  # (B, C, KH, hd)


def init_attention(key, cfg: ArchConfig, *, d_model: int | None = None):
    d = d_model or cfg.d_model
    hd, qh, kh = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": _normal(ks[0], (d, qh * hd), cfg.dtype, d),
        "wk": _normal(ks[1], (d, kh * hd), cfg.dtype, d),
        "wv": _normal(ks[2], (d, kh * hd), cfg.dtype, d),
        "wo": _normal(ks[3], (qh * hd, d), cfg.dtype, qh * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((qh * hd,), cfg.dtype)
        p["bk"] = jnp.zeros((kh * hd,), cfg.dtype)
        p["bv"] = jnp.zeros((kh * hd,), cfg.dtype)
    return p


def _qkv(p, x, cfg: ArchConfig):
    b, s, _ = x.shape
    hd = cfg.head_dim
    q = linear(x, p["wq"], p.get("bq")).reshape(b, s, cfg.num_heads, hd)
    k = linear(x, p["wk"], p.get("bk")).reshape(b, s, cfg.num_kv_heads, hd)
    v = linear(x, p["wv"], p.get("bv")).reshape(b, s, cfg.num_kv_heads, hd)
    return q, k, v


def _rotate(q, k, cfg: ArchConfig, positions):
    """positions: (B, S) int32, or (3, B, S) for M-RoPE."""
    if cfg.mrope_sections is not None:
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    elif cfg.rope_theta > 0:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k


def attention_train(p, x, cfg: ArchConfig, *, positions, causal: bool = True,
                    window: int | None = "cfg"):
    if window == "cfg":
        window = cfg.sliding_window
    q, k, v = _qkv(p, x, cfg)
    q, k = _rotate(q, k, cfg, positions)
    out = chunked_attention(q, k, v, causal=causal, window=window)
    b, s = x.shape[:2]
    return linear(out.reshape(b, s, -1), p["wo"])


def attention_prefill(p, x, cfg: ArchConfig, *, positions, cache_len: int,
                      window: int | None = "cfg"):
    """Run causal attention over the prompt and leave a KV cache of capacity
    `cache_len` (ring-buffered when `window` is set)."""
    if window == "cfg":
        window = cfg.sliding_window
    b, s, _ = x.shape
    q, k, v = _qkv(p, x, cfg)
    q, k = _rotate(q, k, cfg, positions)
    out = chunked_attention(q, k, v, causal=True, window=window)
    cap = min(cache_len, window) if window is not None else cache_len
    kc = jnp.zeros((b, cap, cfg.num_kv_heads, cfg.head_dim), x.dtype)
    vc = jnp.zeros_like(kc)
    if window is None or s <= cap:
        take = min(s, cap)
        kc = lax.dynamic_update_slice(kc, k[:, -take:], (0, 0, 0, 0))
        vc = lax.dynamic_update_slice(vc, v[:, -take:], (0, 0, 0, 0))
    else:
        # ring buffer: last `cap` tokens, placed at their pos % cap slots
        tail_k, tail_v = k[:, -cap:], v[:, -cap:]
        slots = (jnp.arange(s - cap, s)) % cap
        kc = kc.at[:, slots].set(tail_k)
        vc = vc.at[:, slots].set(tail_v)
    y = linear(out.reshape(b, s, -1), p["wo"])
    return y, AttnCache(kc, vc)


def attention_decode(p, x, cfg: ArchConfig, cache: AttnCache, pos,
                     window: int | None = "cfg", rope_positions=None):
    """x: (B, 1, D); pos: () int32 — absolute position of this token.

    rope_positions overrides the rotation stream (M-RoPE text positions
    differ from the raw cache position); cache slots always use `pos`.
    """
    if window == "cfg":
        window = cfg.sliding_window
    b = x.shape[0]
    q, k, v = _qkv(p, x, cfg)
    if rope_positions is None:
        rope_positions = jnp.broadcast_to(pos, (b, 1))
        if cfg.mrope_sections is not None:
            rope_positions = jnp.broadcast_to(pos, (3, b, 1))
    q, k = _rotate(q, k, cfg, rope_positions)
    cap = cache.k.shape[1]
    slot = pos % cap if window is not None else pos
    kc = lax.dynamic_update_slice(cache.k, k, (0, slot, 0, 0))
    vc = lax.dynamic_update_slice(cache.v, v, (0, slot, 0, 0))
    n_valid = jnp.minimum(pos + 1, cap) if window is not None else pos + 1
    # ring buffer: once wrapped, every slot is within the window; masking by
    # count handles warmup (slots >= n_valid are zeros).
    out = decode_attention(q, kc, vc, n_valid, window=None)
    y = linear(out.reshape(b, 1, -1), p["wo"])
    return y, AttnCache(kc, vc)


# ---------------------------------------------------------------------------
# cross-attention (whisper decoder)
# ---------------------------------------------------------------------------

def init_cross_attention(key, cfg: ArchConfig):
    return init_attention(key, cfg)


def cross_attention_train(p, x, enc, cfg: ArchConfig):
    """x: (B, S, D) decoder stream; enc: (B, T_enc, D) encoder output."""
    b, s, _ = x.shape
    t = enc.shape[1]
    hd = cfg.head_dim
    q = linear(x, p["wq"], p.get("bq")).reshape(b, s, cfg.num_heads, hd)
    k = linear(enc, p["wk"], p.get("bk")).reshape(b, t, cfg.num_kv_heads, hd)
    v = linear(enc, p["wv"], p.get("bv")).reshape(b, t, cfg.num_kv_heads, hd)
    out = chunked_attention(q, k, v, causal=False)
    return linear(out.reshape(b, s, -1), p["wo"])


def cross_attention_cache(p, enc, cfg: ArchConfig) -> AttnCache:
    b, t, _ = enc.shape
    hd = cfg.head_dim
    k = linear(enc, p["wk"], p.get("bk")).reshape(b, t, cfg.num_kv_heads, hd)
    v = linear(enc, p["wv"], p.get("bv")).reshape(b, t, cfg.num_kv_heads, hd)
    return AttnCache(k, v)


def cross_attention_decode(p, x, cfg: ArchConfig, cache: AttnCache):
    b = x.shape[0]
    hd = cfg.head_dim
    q = linear(x, p["wq"], p.get("bq")).reshape(b, 1, cfg.num_heads, hd)
    t = cache.k.shape[1]
    out = decode_attention(q, cache.k, cache.v, jnp.int32(t))
    return linear(out.reshape(b, 1, -1), p["wo"])


# ---------------------------------------------------------------------------
# RWKV6 ("Finch", arXiv:2404.05892) — attention-free, data-dependent decay
# ---------------------------------------------------------------------------

class Rwkv6Cache(NamedTuple):
    state: jax.Array  # (B, H, dk, hd) linear-attention state
    x_prev: jax.Array  # (B, D) last token's input (token shift)


DECAY_LORA = 64


def init_rwkv6(key, cfg: ArchConfig):
    d, h = cfg.d_model, cfg.num_heads
    hd = d // h  # rwkv head size
    ks = jax.random.split(key, 8)
    p = {
        # token-shift lerp coefficients per stream (static mu; Finch makes
        # these data-dependent via lora — we keep the decay lora, the hallmark)
        "mu": jnp.full((5, d), 0.5, cfg.dtype),  # r,k,v,g,w order
        "wr": _normal(ks[0], (d, d), cfg.dtype, d),
        "wk": _normal(ks[1], (d, d), cfg.dtype, d),
        "wv": _normal(ks[2], (d, d), cfg.dtype, d),
        "wg": _normal(ks[3], (d, d), cfg.dtype, d),
        "wo": _normal(ks[4], (d, d), cfg.dtype, d),
        # data-dependent decay: w = -exp(w0 + tanh(x A) B)  (per channel)
        "w0": jnp.full((d,), -2.0, jnp.float32),
        "wA": _normal(ks[5], (d, DECAY_LORA), cfg.dtype, d),
        "wB": _normal(ks[6], (DECAY_LORA, d), cfg.dtype, DECAY_LORA) * 0.1,
        # per-(head, channel) bonus u on the current token
        "u": jax.random.normal(ks[7], (h, hd), jnp.float32) * 0.1,
        "ln_out": jnp.ones((h, hd), jnp.float32),  # per-head groupnorm scale
    }
    return p


def _rwkv6_streams(p, x, x_prev, cfg: ArchConfig):
    """Token-shifted projection streams. x: (B, S, D); x_prev: (B, S, D) with
    x_prev[:, t] = x[:, t-1] (caller supplies the shifted stream)."""
    mu = p["mu"].astype(jnp.float32)
    x32, xp32 = x.astype(jnp.float32), x_prev.astype(jnp.float32)
    mix = lambda i: (x32 + (xp32 - x32) * mu[i]).astype(x.dtype)
    b, s, d = x.shape
    h = cfg.num_heads
    hd = d // h
    r = linear(mix(0), p["wr"]).reshape(b, s, h, hd)
    k = linear(mix(1), p["wk"]).reshape(b, s, h, hd)
    v = linear(mix(2), p["wv"]).reshape(b, s, h, hd)
    g = jax.nn.silu(linear(mix(3), p["wg"]))
    xw = mix(4)
    lora = jnp.tanh(linear(xw, p["wA"])).astype(jnp.float32)
    log_decay = -jnp.exp(
        p["w0"] + (lora @ p["wB"].astype(jnp.float32))
    )  # (B, S, D), strictly negative — data-dependent decay
    log_decay = log_decay.reshape(b, s, h, hd)
    return r, k, v, g, log_decay


def _rwkv6_out(p, wkv, g, cfg: ArchConfig):
    """Per-head groupnorm on wkv, gate, output projection."""
    b, s, h, hd = wkv.shape
    w32 = wkv.astype(jnp.float32)
    mean = jnp.mean(w32, axis=-1, keepdims=True)
    var = jnp.var(w32, axis=-1, keepdims=True)
    normed = (w32 - mean) * lax.rsqrt(var + 1e-5) * p["ln_out"]
    y = normed.reshape(b, s, h * hd).astype(g.dtype) * g
    return linear(y, p["wo"])


def rwkv6_train(p, x, cfg: ArchConfig):
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, g, ld = _rwkv6_streams(p, x, x_prev, cfg)
    wkv, _ = chunked_linear_attention(
        r, k, v, ld, bonus=p["u"], inclusive=False
    )
    return _rwkv6_out(p, wkv, g, cfg)


def rwkv6_prefill(p, x, cfg: ArchConfig):
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, g, ld = _rwkv6_streams(p, x, x_prev, cfg)
    wkv, state = chunked_linear_attention(
        r, k, v, ld, bonus=p["u"], inclusive=False
    )
    y = _rwkv6_out(p, wkv, g, cfg)
    return y, Rwkv6Cache(state=state, x_prev=x[:, -1])


def rwkv6_decode(p, x, cfg: ArchConfig, cache: Rwkv6Cache):
    """x: (B, 1, D)."""
    b, _, d = x.shape
    x_prev = cache.x_prev[:, None]
    r, k, v, g, ld = _rwkv6_streams(p, x, x_prev, cfg)
    out, state = linear_attention_decode(
        r[:, 0], k[:, 0], v[:, 0], ld[:, 0], cache.state.astype(jnp.float32),
        bonus=p["u"], inclusive=False,
    )
    y = _rwkv6_out(p, out[:, None], g, cfg)
    return y, Rwkv6Cache(state=state, x_prev=x[:, 0])


# ---------------------------------------------------------------------------
# Hymba (arXiv:2411.13676) — parallel attention + Mamba-2/SSD heads per layer
# ---------------------------------------------------------------------------

class HymbaCache(NamedTuple):
    attn: AttnCache
    ssm_state: jax.Array  # (B, H, N, hd)


def init_hymba(key, cfg: ArchConfig):
    d, h, hd, n = cfg.d_model, cfg.num_heads, cfg.head_dim, cfg.ssm_state
    ks = jax.random.split(key, 6)
    p = {"attn": init_attention(ks[0], cfg)}
    # SSD heads: values x (H, hd), input/output gates B_t, C_t (H, N), dt (H,)
    p["ssm"] = {
        "wx": _normal(ks[1], (d, h * hd), cfg.dtype, d),
        "wbc": _normal(ks[2], (d, h * 2 * n), cfg.dtype, d),
        "wdt": _normal(ks[3], (d, h), cfg.dtype, d),
        "a_log": jnp.zeros((h,), jnp.float32),
        "ln": jnp.ones((h, hd), jnp.float32),  # per-head norm before fusion
    }
    # shared output projection over the fused (attn + ssm) heads
    p["wo_fused"] = _normal(ks[4], (h * hd, d), cfg.dtype, h * hd)
    p["attn"].pop("wo")  # fused projection replaces the attention-only wo
    p["ln_attn"] = jnp.ones((h, hd), jnp.float32)
    return p


def _hymba_ssm_streams(p, x, cfg: ArchConfig):
    b, s, d = x.shape
    h, hd, n = cfg.num_heads, cfg.head_dim, cfg.ssm_state
    sp = p["ssm"]
    xv = linear(x, sp["wx"]).reshape(b, s, h, hd)
    bc = linear(x, sp["wbc"]).reshape(b, s, h, 2 * n)
    b_t, c_t = jnp.split(bc, 2, axis=-1)  # (B,S,H,N) each
    dt = jax.nn.softplus(linear(x, sp["wdt"]).astype(jnp.float32))  # (B,S,H)
    log_decay = -jnp.exp(sp["a_log"]) * dt  # scalar-per-head decay <= 0
    # SSD discretization: inputs scaled by dt
    xv = (xv.astype(jnp.float32) * dt[..., None]).astype(x.dtype)
    return c_t, b_t, xv, log_decay


def _headnorm(y, scale):
    y32 = y.astype(jnp.float32)
    var = jnp.mean(jnp.square(y32), axis=-1, keepdims=True)
    return y32 * lax.rsqrt(var + 1e-6) * scale


def _hymba_fuse(p, attn_out, ssm_out, x_dtype, b, s):
    """Mean-fuse the two normalized head groups, shared output projection."""
    a = _headnorm(attn_out, p["ln_attn"])
    m = _headnorm(ssm_out, p["ssm"]["ln"])
    fused = (0.5 * (a + m)).astype(x_dtype).reshape(b, s, -1)
    return linear(fused, p["wo_fused"])


def hymba_train(p, x, cfg: ArchConfig, *, positions):
    b, s, _ = x.shape
    q, k, v = _qkv(p["attn"], x, cfg)
    q, k = _rotate(q, k, cfg, positions)
    attn_out = chunked_attention(q, k, v, causal=True, window=cfg.sliding_window)
    c_t, b_t, xv, ld = _hymba_ssm_streams(p, x, cfg)
    ssm_out, _ = chunked_linear_attention(c_t, b_t, xv, ld, inclusive=True)
    return _hymba_fuse(p, attn_out, ssm_out, x.dtype, b, s)


def hymba_prefill(p, x, cfg: ArchConfig, *, positions, cache_len: int):
    b, s, _ = x.shape
    q, k, v = _qkv(p["attn"], x, cfg)
    q, k = _rotate(q, k, cfg, positions)
    attn_out = chunked_attention(q, k, v, causal=True, window=cfg.sliding_window)
    window = cfg.sliding_window or cache_len
    cap = min(cache_len, window)
    kc = jnp.zeros((b, cap, cfg.num_kv_heads, cfg.head_dim), x.dtype)
    vc = jnp.zeros_like(kc)
    take = min(s, cap)
    slots = jnp.arange(s - take, s) % cap
    kc = kc.at[:, slots].set(k[:, -take:])
    vc = vc.at[:, slots].set(v[:, -take:])
    c_t, b_t, xv, ld = _hymba_ssm_streams(p, x, cfg)
    ssm_out, state = chunked_linear_attention(c_t, b_t, xv, ld, inclusive=True)
    y = _hymba_fuse(p, attn_out, ssm_out, x.dtype, b, s)
    return y, HymbaCache(AttnCache(kc, vc), state)


def hymba_decode(p, x, cfg: ArchConfig, cache: HymbaCache, pos):
    b = x.shape[0]
    q, k, v = _qkv(p["attn"], x, cfg)
    pos_b = jnp.broadcast_to(pos, (b, 1))
    q, k = _rotate(q, k, cfg, pos_b)
    cap = cache.attn.k.shape[1]
    slot = pos % cap
    kc = lax.dynamic_update_slice(cache.attn.k, k, (0, slot, 0, 0))
    vc = lax.dynamic_update_slice(cache.attn.v, v, (0, slot, 0, 0))
    n_valid = jnp.minimum(pos + 1, cap)
    attn_out = decode_attention(q, kc, vc, n_valid)
    c_t, b_t, xv, ld = _hymba_ssm_streams(p, x, cfg)
    ssm_out, state = linear_attention_decode(
        c_t[:, 0], b_t[:, 0], xv[:, 0], ld[:, 0],
        cache.ssm_state.astype(jnp.float32), inclusive=True,
    )
    y = _hymba_fuse(p, attn_out, ssm_out[:, None], x.dtype, b, 1)
    return y, HymbaCache(AttnCache(kc, vc), state)
