"""Chunked (gated) linear attention — the TPU-native form of recurrent mixers.

One engine serves two families (DESIGN.md §4):
  - RWKV6 ("Finch"): per-channel data-dependent decay w_t in (0,1)^{dk},
    output at t reads the PRE-update state plus a "bonus" u on the current
    token (exclusive scores, s < t).
  - Mamba-2 / SSD (Hymba's SSM heads): scalar per-head decay a_t, output
    reads the POST-update state (inclusive scores, s <= t).

Instead of a T-step sequential scan (hopeless on the MXU), the sequence is
split into chunks of length C: intra-chunk interactions are dense matmuls
with decay-weighted masks, and only the (B, H, dk, dv) state crosses chunk
boundaries via `lax.scan`. This is the standard GLA chunk decomposition;
the per-channel variant is stabilized by clamping log-decay per step to
[-LOG_DECAY_CLAMP, 0) so intra-chunk exp() factors stay in f32 range
(|la| <= C * clamp = 64 * 1.25 = 80 < 88). C=64 feeds the MXU 64-wide
intra-chunk matmuls (C=32 underutilizes the 128x128 systolic array even
more; C=128 would need clamp <= 0.69, too restrictive a floor on decay).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

LOG_DECAY_CLAMP = 1.25
CHUNK = 64


def chunked_linear_attention(r, k, v, log_decay, *, bonus=None, inclusive: bool,
                             initial_state=None, chunk: int = CHUNK):
    """r, k: (B, S, H, dk); v: (B, S, H, dv).

    log_decay: (B, S, H, dk) per-channel (RWKV6) or (B, S, H) scalar (SSD);
               values must be <= 0 (decay in (0, 1]).
    bonus:     (H, dk) — RWKV6's u term on the current token (exclusive mode).
    inclusive: scores include s == t (SSD) or not (RWKV6).
    Returns (out (B, S, H, dv), final_state (B, H, dk, dv)).
    """
    b, s, h, dk = r.shape
    dv = v.shape[-1]
    scalar_decay = log_decay.ndim == 3
    if scalar_decay:
        log_decay = log_decay[..., None]  # broadcast channel dim of size 1

    c = min(chunk, s)
    assert s % c == 0, f"seq {s} must divide chunk {c}"
    nc = s // c

    def to_chunks(x):
        return jnp.moveaxis(x.reshape(b, nc, c, *x.shape[2:]), 1, 0)

    # streams stay in their storage dtype (bf16) across the chunk scan and
    # are cast to f32 one chunk at a time inside the body — §Perf change C:
    # the S-length f32 copies of r/k/v doubled the SSD path's HBM traffic.
    # log-decay must remain f32 (cumsum/exp error compounds over the chunk).
    r_c, k_c, v_c = to_chunks(r), to_chunks(k), to_chunks(v)
    lw_c = to_chunks(jnp.clip(log_decay.astype(jnp.float32), -LOG_DECAY_CLAMP, 0.0))

    if initial_state is None:
        initial_state = jnp.zeros((b, h, dk, v.shape[-1]), jnp.float32)

    mask = jnp.tril(jnp.ones((c, c), bool), 0 if inclusive else -1)

    def body(state, xs):
        rc, kc, vc, lwc = xs  # (B, C, H, dk/dv)
        rc = rc.astype(jnp.float32)
        kc = kc.astype(jnp.float32)
        vc = vc.astype(jnp.float32)
        la = jnp.cumsum(lwc, axis=1)  # inclusive cumulative log decay
        la_q = la if inclusive else la - lwc  # exclusive for rwkv
        r_t = rc * jnp.exp(la_q)  # decayed queries
        k_t = kc * jnp.exp(-la)  # inverse-decayed keys (clamp keeps range)
        # inter-chunk: read carried state
        o_inter = jnp.einsum("bchk,bhkv->bchv", r_t, state)
        # intra-chunk: masked decay-weighted scores
        scores = jnp.einsum("bqhk,bshk->bhqs", r_t, k_t)
        scores = jnp.where(mask[None, None], scores, 0.0)
        o_intra = jnp.einsum("bhqs,bshv->bqhv", scores, vc)
        if bonus is not None:
            diag = jnp.einsum("bchk,hk,bchk->bch", rc, bonus.astype(jnp.float32), kc)
            o_intra = o_intra + diag[..., None] * vc
        # state update: S' = exp(la_C) . S + sum_s exp(la_C - la_s) k_s v_s^T
        la_end = la[:, -1:]  # (B, 1, H, dk)
        k_carry = kc * jnp.exp(la_end - la)
        decay_state = jnp.exp(la_end[:, 0])  # (B, H, dk)
        new_state = state * decay_state[..., None] + jnp.einsum(
            "bshk,bshv->bhkv", k_carry, vc
        )
        return new_state, o_inter + o_intra

    from repro.models import flags

    final_state, out = lax.scan(body, initial_state, (r_c, k_c, v_c, lw_c),
                                unroll=flags.inner_scan_unroll())
    out = jnp.moveaxis(out, 0, 1).reshape(b, s, h, dv)
    return out.astype(r.dtype), final_state


def linear_attention_decode(r, k, v, log_decay, state, *, bonus=None,
                            inclusive: bool):
    """One-token recurrent step.

    r, k: (B, H, dk); v: (B, H, dv); log_decay per-channel (B, H, dk) or
    scalar (B, H); state (B, H, dk, dv). Returns (out (B, H, dv), new_state).
    """
    r32, k32, v32 = (x.astype(jnp.float32) for x in (r, k, v))
    ld = jnp.clip(log_decay.astype(jnp.float32), -LOG_DECAY_CLAMP, 0.0)
    if ld.ndim == 2:
        ld = ld[..., None]
    w = jnp.exp(ld)  # (B, H, dk)
    kv = jnp.einsum("bhk,bhv->bhkv", k32, v32)
    if inclusive:
        new_state = state * w[..., None] + kv
        out = jnp.einsum("bhk,bhkv->bhv", r32, new_state)
    else:
        read = state + (bonus.astype(jnp.float32)[None, :, :, None] * kv
                        if bonus is not None else kv * 0.0)
        out = jnp.einsum("bhk,bhkv->bhv", r32, read)
        new_state = state * w[..., None] + kv
    return out.astype(r.dtype), new_state


def reference_linear_attention(r, k, v, log_decay, *, bonus=None, inclusive: bool,
                               initial_state=None):
    """O(T) sequential oracle for tests (pure scan, f64-friendly)."""
    b, s, h, dk = r.shape
    dv = v.shape[-1]
    if initial_state is None:
        initial_state = jnp.zeros((b, h, dk, dv), jnp.float32)
    ld = log_decay if log_decay.ndim == 4 else log_decay[..., None]

    def step(state, xs):
        rt, kt, vt, lt = xs
        out, state = linear_attention_decode(
            rt, kt, vt, lt if log_decay.ndim == 4 else lt[..., 0],
            state, bonus=bonus, inclusive=inclusive,
        )
        return state, out

    xs = tuple(jnp.moveaxis(x, 1, 0) for x in (r, k, v, ld))
    state, outs = lax.scan(step, initial_state, xs)
    return jnp.moveaxis(outs, 0, 1), state
