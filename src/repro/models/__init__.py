from repro.models.config import ArchConfig

__all__ = ["ArchConfig"]
