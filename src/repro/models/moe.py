"""Mixture-of-Experts FFN — capacity-free (dropless) top-k routing.

TPU-native dispatch (DESIGN.md §5): tokens stay data-sharded, every expert's
d_ff is tensor-parallel over the "model" axis, and dispatch is a *per-batch-row
local sort* + `lax.ragged_dot_general`:

  1. router logits -> top-k experts + softmax weights per token
  2. per batch row, replicate tokens k times and argsort by expert id
     (a local sort: the sorted axis is never sharded, so no collectives)
  3. one batched ragged_dot per FFN matmul — only active-expert FLOPs
  4. unsort, weighted-sum over the k copies

Qwen2-MoE's 4 shared experts are folded into one dense FFN of width
`shared_expert_ff` applied to every token (mathematically identical to always-
routed experts of the same total width).

Note (roofline): on the CPU backend XLA lowers ragged_dot as a dense
group-loop, so `cost_analysis()` FLOPs over-count by ~E/k; on TPU the
Megablox/grouped-matmul lowering does active FLOPs only. Recorded in
EXPERIMENTS.md §Roofline via the MODEL_FLOPS/HLO_FLOPS ratio.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

try:  # jax >= 0.5 exposes the batched ragged dot; older pins need the fallback
    from jax.lax import RaggedDotDimensionNumbers, ragged_dot_general

    _HAS_RAGGED_GENERAL = True
except ImportError:  # pragma: no cover - exercised on the pinned 0.4.x JAX
    RaggedDotDimensionNumbers = ragged_dot_general = None
    _HAS_RAGGED_GENERAL = False

from repro.models.config import ArchConfig
from repro.models.layers import init_mlp, linear, mlp


def init_moe(key, cfg: ArchConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    p = {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * s_in,
        "w_gate": jax.random.normal(ks[1], (e, d, f), cfg.dtype) * s_in,
        "w_up": jax.random.normal(ks[2], (e, d, f), cfg.dtype) * s_in,
        "w_down": jax.random.normal(ks[3], (e, f, d), cfg.dtype) * s_out,
    }
    if cfg.shared_expert_ff:
        p["shared"] = init_mlp(ks[4], d, cfg.shared_expert_ff, cfg.act, cfg.dtype)
    return p


_RAGGED_DN = RaggedDotDimensionNumbers(
    dot_dimension_numbers=(((2,), (1,)), ((), ())),
    lhs_ragged_dimensions=[1],
    rhs_group_dimensions=[0],
) if _HAS_RAGGED_GENERAL else None


def _segment_ids(group_sizes, length):
    """group_sizes (B, E) -> (B, length) expert id of each sorted token slot."""
    ends = jnp.cumsum(group_sizes, axis=-1)  # (B, E)
    slots = jnp.arange(length)
    return jnp.sum(slots[None, :, None] >= ends[:, None, :], axis=-1)


def _ragged(lhs, rhs, group_sizes):
    """lhs (B, T, K_dim) x rhs (E, K_dim, N) grouped by row -> (B, T, N)."""
    if _HAS_RAGGED_GENERAL:
        return ragged_dot_general(lhs, rhs, group_sizes, _RAGGED_DN,
                                  preferred_element_type=lhs.dtype)
    # Dense einsum fallback for JAX pins without lax.ragged_dot_general: run
    # every expert on every token, then select each token's expert by its
    # group segment. Same result; E/k more FLOPs — matches what XLA's CPU
    # group-loop lowering does anyway (see the roofline note above).
    seg = _segment_ids(group_sizes, lhs.shape[1])  # (B, T)
    onehot = jax.nn.one_hot(seg, rhs.shape[0], dtype=lhs.dtype)  # (B, T, E)
    h = jnp.einsum("btd,edf->btef", lhs, rhs)
    return jnp.einsum("btef,bte->btf", h, onehot).astype(lhs.dtype)


def moe_ffn(p, x, cfg: ArchConfig, *, return_aux: bool = False):
    """x: (B, S, D) -> (B, S, D). Works for S == 1 (decode) unchanged."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    logits = linear(x.astype(jnp.float32), p["router"])  # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = lax.top_k(probs, k)  # (B, S, K)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)

    # flatten token copies per row: (B, S*K)
    flat_e = top_e.reshape(b, s * k)
    order = jnp.argsort(flat_e, axis=-1)  # local sort per batch row
    inv = jnp.argsort(order, axis=-1)
    xk = jnp.repeat(x, k, axis=1)  # (B, S*K, D) token copies
    xs = jnp.take_along_axis(xk, order[..., None], axis=1)
    counts = jnp.sum(
        jax.nn.one_hot(flat_e, e, dtype=jnp.int32), axis=1
    )  # (B, E) group sizes

    if cfg.act == "swiglu":
        h = jax.nn.silu(_ragged(xs, p["w_gate"], counts)) * _ragged(
            xs, p["w_up"], counts
        )
    else:
        h = jax.nn.gelu(_ragged(xs, p["w_up"], counts))
    ys = _ragged(h, p["w_down"], counts)  # (B, S*K, D)

    yk = jnp.take_along_axis(ys, inv[..., None], axis=1).reshape(b, s, k, d)
    y = jnp.sum(yk * top_w[..., None].astype(yk.dtype), axis=2)

    if cfg.shared_expert_ff:
        y = y + mlp(x, p["shared"], cfg.act)

    if return_aux:
        # Switch-style load-balance diagnostics (fraction routed per expert
        # vs mean router prob) — exposed to the training loop for logging.
        frac = jnp.mean(
            jax.nn.one_hot(top_e, e, dtype=jnp.float32), axis=(0, 1, 2)
        )
        mean_p = jnp.mean(probs, axis=(0, 1))
        aux = e * jnp.sum(frac * mean_p)
        return y, aux
    return y


def moe_ffn_ref(p, x, cfg: ArchConfig):
    """Dense-einsum oracle (all experts for all tokens, masked sum) — used by
    tests to validate the ragged dispatch."""
    e, k = cfg.num_experts, cfg.experts_per_token
    logits = linear(x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = lax.top_k(probs, k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)
    # (B, S, E) combine weights
    comb = jnp.zeros(probs.shape, jnp.float32)
    comb = jnp.sum(jax.nn.one_hot(top_e, e) * top_w[..., None], axis=2)
    if cfg.act == "swiglu":
        h = jax.nn.silu(jnp.einsum("bsd,edf->bsef", x, p["w_gate"])) * jnp.einsum(
            "bsd,edf->bsef", x, p["w_up"]
        )
    else:
        h = jax.nn.gelu(jnp.einsum("bsd,edf->bsef", x, p["w_up"]))
    y_all = jnp.einsum("bsef,efd->bsed", h, p["w_down"])
    y = jnp.sum(y_all * comb[..., None].astype(y_all.dtype), axis=2)
    if cfg.shared_expert_ff:
        y = y + mlp(x, p["shared"], cfg.act)
    return y
