"""Experiment 3 analog (paper Sec. 3.2): the non-local methods on a *neural
network* instead of logreg. The paper trains ResNet-18/CIFAR10 on a GPU
simulator; this container is CPU-only, so the same four algorithms train a
tiny transformer LM on a learnable synthetic token stream — the claim under
test is identical: (i) Q-RR ~ QSGD, (ii) DIANA-RR beats DIANA.

Returns rows (name, final_train_loss, bits_uplinked).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.compression.ops import RandK
from repro.core.algorithms import init_algorithm, make_epoch_fn
from repro.data.tokens import synthetic_token_batches
from repro.models import transformer as T
from repro.models.config import ArchConfig

CFG = ArchConfig(
    name="tiny-lm", family="dense", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=4, d_ff=128, vocab=256, norm="rmsnorm", act="swiglu",
)


def experiment3(epochs: int = 30, m: int = 4, n_batches: int = 4,
                seq: int = 32, batch: int = 4, lr: float = 0.5,
                fraction: float = 0.05, seed: int = 0):
    tokens = synthetic_token_batches(
        vocab=CFG.vocab, seq_len=seq, batch=batch, num_batches=n_batches,
        num_clients=m, seed=seed)
    data = {"tokens": jnp.asarray(tokens)}  # (M, n, batch, seq+1)
    comp = RandK(fraction=fraction)

    def loss(params, b):
        return T.loss_fn(params, b, CFG, remat=False)

    params0 = T.init_params(jax.random.key(seed), CFG)
    params0 = jax.tree.map(lambda x: x.astype(jnp.float32), params0)

    rows = []
    for name in ("qsgd", "q_rr", "diana", "diana_rr"):
        spec, epoch = make_epoch_fn(name, loss, comp, gamma=lr,
                                    alpha=1.0 / (1.0 + comp.omega(10_000)))
        state = init_algorithm(spec, params0, m, n_batches)
        epoch = jax.jit(epoch)
        key = jax.random.PRNGKey(seed)
        for e in range(epochs):
            key, k = jax.random.split(key)
            state = epoch(state, data, k)
        # full train loss at the final iterate
        flat = jax.tree.map(lambda x: x.reshape((-1,) + x.shape[2:]), data)
        final = float(np.mean([
            float(loss(state.params, {"tokens": flat["tokens"][i]}))
            for i in range(flat["tokens"].shape[0])
        ]))
        rows.append((f"exp3/{name}", final, float(state.bits)))
    return rows


if __name__ == "__main__":
    for r in experiment3():
        print(",".join(str(x) for x in r))
