"""Paper-table experiments (Sec. 3, Figure 1a/1b) on federated logistic
regression with the paper's setup: M=20 clients, label-sorted heterogeneous
split, Rand-k with k/d ~= 0.02, stepsizes = theory * tuned multiplier.

experiment1: non-local methods  QSGD vs Q-RR vs DIANA vs DIANA-RR
experiment2: local methods      FedPAQ vs FedCOM vs Q-NASTYA vs DIANA-NASTYA

Expected qualitative outcome (the paper's claims):
  E1: Q-RR ~ QSGD; DIANA-RR best by orders of magnitude.
  E2: Q-NASTYA ~ FedCOM/FedPAQ; DIANA-NASTYA best.

Each function returns CSV rows: (name, seconds_per_epoch, final_suboptimality).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.compression.ops import RandK
from repro.core.algorithms import ALGORITHMS, init_algorithm, make_epoch_fn, theoretical_stepsizes
from repro.data.logreg import make_federated_logreg
from repro.data.pipeline import run_epochs
from repro.data.reshuffle import ReshuffleSampler


def _problem(cond: float = 1e3, seed: int = 0):
    return make_federated_logreg(
        m=20, n_batches=10, batch=10, d=100, cond=cond, seed=seed,
        heterogeneous=True,
    )


def _sampler_mode(name: str) -> str:
    """The paper's order source per method: Shuffle-Once for DIANA-RR (slot
    i always maps to the same datapoint), fresh per-epoch RR for the other
    reshuffling methods, with-replacement for the rest."""
    if name == "diana_rr":
        return "rr_once"
    return ALGORITHMS[name].sampling  # 'rr' | 'wr'


def _run(problem, name, comp, epochs, mult, seed=0, track_every=0):
    loss = problem.loss_fn()
    omega = comp.omega(problem.d)
    th = theoretical_stepsizes(
        name, l_max=problem.l_max, mu=problem.mu, omega=omega,
        m=problem.m, n=problem.n,
    )
    gamma = th["gamma"] * mult
    eta = th.get("eta", gamma) * mult if "eta" in th else None
    alpha = th.get("alpha")
    spec, epoch = make_epoch_fn(name, loss, comp, gamma=gamma, eta=eta, alpha=alpha)
    st = init_algorithm(spec, {"w": jnp.zeros((problem.d,))}, problem.m, problem.n)
    # epoch order from the SAME stateless epoch-indexed sampler the
    # production stream consumes (pipeline.run_epochs / DESIGN.md §3.7) —
    # paper-table runs and the pod wire share one order source
    sampler = ReshuffleSampler(problem.m, problem.n, mode=_sampler_mode(name),
                               seed=seed)
    trace = []

    def track(e, st_e):
        if track_every and (e + 1) % track_every == 0:
            trace.append((e + 1, float(st_e.bits),
                          problem.suboptimality(st_e.params["w"])))

    t0 = time.perf_counter()
    st = run_epochs(epoch, st, problem.data, sampler, epochs=epochs,
                    key=jax.random.PRNGKey(seed), callback=track)
    jax.block_until_ready(st.params["w"])
    dt = (time.perf_counter() - t0) / epochs
    sub = problem.suboptimality(st.params["w"])
    return sub, dt, trace, st


def _tune_and_run(problem, name, comp, epochs, mults, seed=0):
    """Mimic the paper's tuning: pick the multiplier with best final subopt."""
    best = None
    for mult in mults:
        sub, dt, _, _ = _run(problem, name, comp, epochs, mult, seed)
        if not np.isfinite(sub):
            continue
        if best is None or sub < best[0]:
            best = (sub, dt, mult)
    return best


def experiment1(epochs: int = 800, quick: bool = False):
    """Non-local methods, paper Fig. 1a."""
    problem = _problem(cond=1e3 if not quick else 100.0)
    comp = RandK(fraction=0.02)
    mults = (1.0,) if quick else (1.0, 4.0, 16.0)
    rows = []
    for name in ("qsgd", "q_rr", "diana", "diana_rr"):
        sub, dt, mult = _tune_and_run(problem, name, comp, epochs, mults)
        rows.append((f"exp1/{name}", dt * 1e6, sub))
    return rows


def experiment2(epochs: int = 800, quick: bool = False):
    """Local methods, paper Fig. 1b."""
    problem = _problem(cond=1e3 if not quick else 100.0)
    comp = RandK(fraction=0.02)
    mults = (1.0,) if quick else (1.0, 4.0, 16.0)
    rows = []
    for name in ("fedpaq", "fedcom", "q_nastya", "diana_nastya"):
        sub, dt, mult = _tune_and_run(problem, name, comp, epochs, mults)
        rows.append((f"exp2/{name}", dt * 1e6, sub))
    return rows


def communication_table(epochs: int = 400):
    """Bits-to-accuracy: uplink bits each method needs for its final subopt
    (the x-axis of the paper's Fig. 1 right columns)."""
    problem = _problem(cond=100.0)
    comp = RandK(fraction=0.02)
    rows = []
    for name in ("sgd", "qsgd", "q_rr", "diana_rr", "q_nastya", "diana_nastya"):
        use = comp if ALGORITHMS[name].default_compressed else None
        sub, dt, trace, st = _run(problem, name, use or RandK(fraction=1.0),
                                  epochs, 4.0, track_every=0)
        rows.append((f"bits/{name}", float(st.bits), sub))
    return rows


if __name__ == "__main__":
    for row in experiment1(quick=True, epochs=200) + experiment2(quick=True, epochs=200):
        print(",".join(str(x) for x in row))
