"""Benchmark orchestrator — one section per paper table/figure + the
dry-run-derived roofline report.

    PYTHONPATH=src python -m benchmarks.run [--full]

Sections:
  [exp1]    Fig. 1a analog: non-local methods on federated logreg
  [exp2]    Fig. 1b analog: local methods on federated logreg
  [exp3]    Sec. 3.2 analog: the same methods on a neural net (tiny LM)
  [bits]    uplink bits-to-accuracy accounting (Fig. 1 right columns)
  [omega]   compressor variance table (Assumption 1 constants)
  [kernels] Pallas kernel parity vs jnp oracles (smoke; the full parity
            matrix lives in tests/test_kernels.py, and the kernel/backend
            TIMING trajectory is benchmarks/compression_bench.py ->
            BENCH_compression.json — the canonical perf file for this repo)
  [roofline] §Roofline table from results/dryrun_single.jsonl (if present)
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np


def section(name):
    print(f"\n=== [{name}] " + "=" * max(4, 66 - len(name)), flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale epochs/tuning (slow)")
    args = ap.parse_args()
    quick = not args.full
    t0 = time.time()

    from benchmarks.experiments import communication_table, experiment1, experiment2
    from benchmarks.experiment3 import experiment3

    section("exp1: non-local methods (QSGD vs Q-RR vs DIANA vs DIANA-RR)")
    rows1 = experiment1(epochs=200 if quick else 800, quick=quick)
    for name, us, sub in rows1:
        print(f"{name:22s} {us:12.1f} us/epoch   f-f* = {sub:.3e}")
    sub = {n.split("/")[1]: s for n, _, s in rows1}
    print(f"-> Q-RR ~ QSGD (ratio {sub['q_rr']/max(sub['qsgd'],1e-30):.2f}); "
          f"DIANA-RR vs DIANA improvement: {sub['diana']/max(sub['diana_rr'],1e-30):.1e}x")

    section("exp2: local methods (FedPAQ vs FedCOM vs Q-NASTYA vs DIANA-NASTYA)")
    rows2 = experiment2(epochs=200 if quick else 800, quick=quick)
    for name, us, sub2 in rows2:
        print(f"{name:22s} {us:12.1f} us/epoch   f-f* = {sub2:.3e}")

    section("exp3: neural-net training (tiny LM stands in for ResNet-18)")
    rows3 = experiment3(epochs=20 if quick else 60)
    for name, loss, bits in rows3:
        print(f"{name:22s} final train loss = {loss:.4f}   uplink bits = {bits:.3e}")
    l3 = {n.split("/")[1]: v for n, v, _ in rows3}
    print(f"-> DIANA-RR {'<' if l3['diana_rr'] < l3['diana'] else '!>'} DIANA; "
          f"|Q-RR - QSGD| = {abs(l3['q_rr']-l3['qsgd']):.3f}")

    section("bits: uplink bits-to-accuracy")
    for name, bits, sub3 in communication_table(epochs=150 if quick else 400):
        print(f"{name:22s} bits = {bits:.3e}   f-f* = {sub3:.3e}")

    section("omega: compressor variance constants (Assumption 1)")
    from repro.compression.ops import NaturalCompression, QSGDQuantizer, RandK
    d = 10_000
    for comp in (RandK(fraction=0.02), RandK(fraction=0.1),
                 QSGDQuantizer(levels=8), NaturalCompression()):
        bits = comp.bits(d)
        print(f"{type(comp).__name__:22s} omega(d={d}) = {comp.omega(d):8.2f}  "
              f"bits/coord = {bits/d:6.2f} (vs 32 dense)")

    section("kernels: Pallas vs jnp oracle parity "
            "(timings: compression_bench.py -> BENCH_compression.json)")
    from repro.kernels import ops, ref
    key = jax.random.key(0)
    x = jax.random.normal(key, (8192,))
    u = jax.random.uniform(jax.random.key(1), (8192,))
    from repro.kernels.qsgd import qsgd_quantize
    got = qsgd_quantize(x, u, levels=8)
    want = ref.qsgd_quantize_ref(x, u, levels=8)
    print(f"qsgd_quantize      max|err| = {float(jnp.max(jnp.abs(got-want))):.2e}")
    rows = jax.random.normal(key, (64, 128))
    from repro.kernels.randk import randk_compress
    v = randk_compress(rows, jnp.int32(5), k_blocks=2)
    vr = ref.randk_compress_ref(rows, jnp.int32(5), k_blocks=2, block_rows=8)
    print(f"randk_compress     max|err| = {float(jnp.max(jnp.abs(v-vr))):.2e}")
    h, qo, mh, qm = (jax.random.normal(jax.random.key(i), (4096,)) for i in range(4))
    g3 = ops.diana_shift(h, qo, mh, qm, alpha=0.2)
    w3 = ref.diana_shift_update_ref(h, qo, mh, qm, 0.2)
    err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(g3, w3))
    print(f"diana_shift fused  max|err| = {err:.2e}")

    section("roofline: dry-run grid report")
    path = "results/dryrun_single.jsonl"
    if os.path.exists(path):
        from benchmarks.roofline import load, table
        rows = load(path)
        print(table(rows))
        mpath = "results/dryrun_multi.jsonl"
        if os.path.exists(mpath):
            ok = sum(1 for l in open(mpath)
                     if json.loads(l).get("status") == "ok")
            print(f"\nmulti-pod (2x16x16) compile passes: {ok}")
    else:
        print("no dry-run results yet — run scripts/run_dryrun_grid.sh")

    print(f"\n[benchmarks done in {time.time()-t0:.0f}s]")


if __name__ == "__main__":
    main()
