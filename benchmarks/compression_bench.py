"""Compression hot-path benchmark: seed per-leaf path vs the backend layer.

    PYTHONPATH=src python -m benchmarks.compression_bench [--quick] [--out F]

Times one full M-client compression round (and the fused DIANA shift update)
three ways at two scales:

  seed       the seed repo's path: per-leaf Python loop under vmap, Rand-k
             indices from `jax.random.choice(replace=False)` — a full
             O(d log d) permutation sort per leaf per client per round.
  reference  repro.compression.backend, pure-jnp: ravel the client pytree
             once, sort-free circular-window Rand-k over the (M, D) buffer.
  pallas     the same backend dispatching to the Pallas kernels (interpret
             mode on CPU, Mosaic on TPU).

Scales: "logreg" is the paper's convex-experiment shape (one dense weight
vector, many clients); "transformer" is a tiny-LM pytree (the exp3 analog)
with a dozen leaves per client, where the seed path pays one sort PER LEAF.

Results land in BENCH_compression.json — the repo's canonical perf
trajectory file (see ROADMAP.md Open items): every PR that touches the
compression, kernels, or wire layers should re-run this and keep the
speedup-vs-seed from regressing.
"""
from __future__ import annotations

import os

# the pod-wire section runs real multi-device meshes (1x4x2 / 2x2x2);
# must precede the first jax import (device count locks on init)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import dataclasses
import json
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.compression.backend import CompressionBackend
from repro.compression.ops import QSGDQuantizer, RandK, tree_compress_per_leaf
from repro.core.api import tree_axpy


# ---------------------------------------------------------------------------
# the seed path, reproduced verbatim as the baseline under test
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SeedRandK:
    """The seed repo's Rand-k: uniform k-subset via a permutation sort."""

    fraction: float = 0.02

    def _k(self, size: int) -> int:
        return max(1, min(size, int(self.fraction * size)))

    def compress(self, key, x):
        flat = jnp.reshape(x, (-1,))
        d = flat.shape[0]
        k = self._k(d)
        idx = jax.random.choice(key, d, shape=(k,), replace=False)
        vals = flat[idx] * (d / k)
        return jnp.reshape(jnp.zeros_like(flat).at[idx].set(vals), x.shape)


def seed_compress_clients(comp, key, tree):
    """Seed `_compress_clients`: vmap over clients of the per-leaf loop
    (`tree_compress_per_leaf`, the retained seed-era path)."""
    m = jax.tree.leaves(tree)[0].shape[0]
    keys = jax.random.split(key, m)
    return jax.vmap(lambda k, g: tree_compress_per_leaf(comp, k, g))(keys, tree)


def seed_diana_shift(h, qd, mh, qmean, alpha):
    """Seed shift update: three separate tree_maps (five HBM passes)."""
    direction = jax.tree.map(jnp.add, mh, qmean)
    new_h = tree_axpy(alpha, qd, h)
    new_mh = tree_axpy(alpha, qmean, mh)
    return direction, new_h, new_mh


# ---------------------------------------------------------------------------
# workloads
# ---------------------------------------------------------------------------

def logreg_tree(m: int, d: int, key):
    """The paper's convex experiments: one dense weight vector per client."""
    return {"w": jax.random.normal(key, (m, d), jnp.float32)}


def transformer_tree(m: int, key, *, layers: int, d_model: int, vocab: int):
    """Tiny-LM gradient pytree (the exp3/train_lm_diana_rr shape)."""
    ks = iter(jax.random.split(key, 2 + 5 * layers))
    tree = {"embed": jax.random.normal(next(ks), (m, vocab, d_model))}
    for i in range(layers):
        tree[f"l{i}"] = {
            "qkv": jax.random.normal(next(ks), (m, d_model, 3 * d_model)),
            "o": jax.random.normal(next(ks), (m, d_model, d_model)),
            "up": jax.random.normal(next(ks), (m, d_model, 4 * d_model)),
            "down": jax.random.normal(next(ks), (m, 4 * d_model, d_model)),
            "ln": jax.random.normal(next(ks), (m, d_model)),
        }
    return tree


def tree_size(tree) -> int:
    return sum(int(np.prod(l.shape[1:])) for l in jax.tree.leaves(tree))


# ---------------------------------------------------------------------------
# timing harness
# ---------------------------------------------------------------------------

def bench(fn, *args, reps: int = 20) -> float:
    """Median wall-clock seconds of jit(fn) after warmup."""
    jitted = jax.jit(fn)
    out = jitted(*args)
    jax.block_until_ready(out)  # compile + warm
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(jitted(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def fmt(sec: float) -> str:
    return f"{sec * 1e3:9.3f} ms"


def run_scale(name: str, tree, *, fraction: float, levels: int, reps: int):
    key = jax.random.key(17)
    d = tree_size(tree)
    m = jax.tree.leaves(tree)[0].shape[0]
    print(f"\n--- {name}: M={m} clients, d={d:,} params/client, "
          f"k/d={fraction} " + "-" * max(4, 30 - len(name)))
    out = {"clients": m, "d": d, "fraction": fraction}

    seed_comp = SeedRandK(fraction=fraction)
    comp = RandK(fraction=fraction)
    backends = {
        "reference": CompressionBackend("reference"),
        "pallas": CompressionBackend("pallas"),
    }

    randk = {}
    randk["seed"] = bench(
        lambda k, t: seed_compress_clients(seed_comp, k, t), key, tree, reps=reps
    )
    for bname, be in backends.items():
        randk[bname] = bench(
            lambda k, t, be=be: be.compress_clients(comp, k, t), key, tree,
            reps=reps,
        )
    for path, sec in randk.items():
        extra = "" if path == "seed" else \
            f"   ({randk['seed'] / sec:5.1f}x vs seed)"
        print(f"randk  {path:10s} {fmt(sec)}{extra}")
    out["randk"] = randk
    out["randk_speedup_pallas_vs_seed"] = randk["seed"] / randk["pallas"]
    out["randk_speedup_reference_vs_seed"] = randk["seed"] / randk["reference"]

    qcomp = QSGDQuantizer(levels=levels)
    qsgd = {}
    qsgd["seed"] = bench(
        lambda k, t: seed_compress_clients(qcomp, k, t), key, tree, reps=reps
    )
    for bname, be in backends.items():
        qsgd[bname] = bench(
            lambda k, t, be=be: be.compress_clients(qcomp, k, t), key, tree,
            reps=reps,
        )
    for path, sec in qsgd.items():
        extra = "" if path == "seed" else \
            f"   ({qsgd['seed'] / sec:5.1f}x vs seed)"
        print(f"qsgd   {path:10s} {fmt(sec)}{extra}")
    out["qsgd"] = qsgd

    # fused DIANA shift update on the same stacked tree
    ks = jax.random.split(jax.random.key(23), 4)
    h, qd, mh, qm = (jax.tree.map(
        lambda l, kk=kk: jax.random.normal(kk, l.shape), tree) for kk in ks)
    alpha = fraction  # 1/(1+omega) for Rand-k
    shift = {}
    shift["seed"] = bench(
        lambda *t: seed_diana_shift(*t, alpha), h, qd, mh, qm, reps=reps
    )
    for bname, be in backends.items():
        shift[bname] = bench(
            lambda *t, be=be: be.tree_diana_shift(*t, alpha=alpha),
            h, qd, mh, qm, reps=reps,
        )
    for path, sec in shift.items():
        extra = "" if path == "seed" else \
            f"   ({shift['seed'] / sec:5.1f}x vs seed)"
        print(f"shift  {path:10s} {fmt(sec)}{extra}")
    out["diana_shift"] = shift
    out["randk_speedup_pallas_vs_reference"] = (
        randk["reference"] / randk["pallas"])
    # honesty: record which path actually won each row — on CPU interpret
    # mode pallas legitimately loses to reference, and the JSON should say so
    out["winner"] = {row: min(times, key=times.get)
                     for row, times in (("randk", randk), ("qsgd", qsgd),
                                        ("diana_shift", shift))}
    return out


def run_rules(*, m: int, n_slots: int, d: int, reps: int):
    """Shift-rule layer hot path: the per-slot (DIANA-RR) round update.

    One round reads each client's active table row, applies the fused
    DIANA update to the row, and scatters it back. Three paths:

      unfused    seed-style arithmetic: select, three separate tree_maps
                 (five HBM passes over the M-row slab), scatter.
      reference  rule chain (select/update/scatter via repro.core.rules)
                 dispatching to the pure-jnp backend.
      pallas     same rule chain through the fused Pallas kernel.

    The rule layer must not cost anything over hand-written arithmetic —
    this is the guard that the unification kept the kernelized hot loop.
    """
    from repro.core.rules import get_rule

    key = jax.random.key(29)
    ks = jax.random.split(key, 3)
    table = {"w": jax.random.normal(ks[0], (m, n_slots, d), jnp.float32)}
    g = {"w": jax.random.normal(ks[1], (m, d), jnp.float32)}
    col = jax.random.randint(ks[2], (m,), 0, n_slots)
    alpha = 0.25
    rule = get_rule("per_slot")
    print(f"\n--- rules: per-slot update, M={m} x n={n_slots} slots x "
          f"d={d:,} " + "-" * 16)
    out = {"clients": m, "n_slots": n_slots, "d": d}

    def unfused(table, g, col):
        idx = (jnp.arange(m), col)
        h = jax.tree.map(lambda s: s[idx], table)
        q = jax.tree.map(jnp.subtract, g, h)
        ghat = jax.tree.map(jnp.add, h, q)
        h_new = jax.tree.map(lambda hi, qi: hi + alpha * qi, h, q)
        new_table = jax.tree.map(lambda s, hn: s.at[idx].set(hn), table, h_new)
        return ghat, new_table

    def ruled(be):
        def f(table, g, col):
            idx = (jnp.arange(m), col)
            h = rule.select(table, idx)
            q = rule.payload(g, h)
            ghat, h_new, _ = rule.update(h, q, h, q, alpha=alpha, backend=be)
            return ghat, rule.scatter(table, idx, h_new)
        return f

    times = {"unfused": bench(unfused, table, g, col, reps=reps)}
    for bname in ("reference", "pallas"):
        times[bname] = bench(ruled(CompressionBackend(bname)), table, g, col,
                             reps=reps)
    for path, sec in times.items():
        extra = "" if path == "unfused" else \
            f"   ({times['unfused'] / sec:5.1f}x vs unfused)"
        print(f"slot   {path:10s} {fmt(sec)}{extra}")
    out["per_slot"] = times
    out["per_slot_speedup_reference_vs_unfused"] = (
        times["unfused"] / times["reference"])
    out["winner"] = min(times, key=times.get)
    return out


def run_pod_wire(*, d: int, fraction: float, reps: int):
    """Two-level pod wire vs flat wire: step time + bytes on each wire.

    Runs the production aggregate() inside the fully-manual shard_map wire
    region (core/dist.py) on two 8-device meshes: (1,4,2) — one pod, the
    flat-equivalent path — and (2,2,2) — two pods, where the inter-pod
    exchange is live. Bytes come from the static wire accounting
    (`wire_bytes_per_round`); the headline is that the inter-pod wire moves
    ~fraction of the dense bytes while the step time stays flat.
    """
    from jax.sharding import PartitionSpec as P

    from repro.core.dist import CompressedAggregation
    from repro.launch import compat
    from repro.launch.mesh import make_test_mesh
    from repro.launch.steps import configure_agg

    print(f"\n--- pod wire: d={d:,} params/client, k/d={fraction} " + "-" * 18)
    out = {"d": d, "fraction": fraction}
    for label, shape, axes in (
        ("1-pod", (1, 4, 2), ("pod", "data", "model")),
        ("2-pod", (2, 2, 2), ("pod", "data", "model")),
    ):
        mesh = make_test_mesh(shape, axes)
        agg = configure_agg(
            CompressedAggregation(method="diana", wire="shared",
                                  fraction=fraction,
                                  shift_dtype=jnp.float32), mesh)
        grads = {"w": jax.random.normal(jax.random.key(5), (4, d),
                                        jnp.float32)}
        specs = {"w": P(("pod", "data"), "model")}

        def round_fn(g, agg=agg):
            g = jax.tree.map(lambda x: x[0], g)
            state = agg.init(g)
            direction, _ = agg.aggregate(g, state, jax.random.PRNGKey(0))
            return jax.tree.map(lambda x: x[None], direction)

        mapped = compat.shard_map(round_fn, mesh=mesh, in_specs=(specs,),
                                  out_specs=specs,
                                  axis_names=set(mesh.axis_names),
                                  check_vma=False)
        sec = bench(mapped, grads, reps=reps)
        local = {"w": jnp.zeros((d // 2,), jnp.float32)}  # per-device block
        wire = agg.wire_bytes_per_round(local)
        print(f"pod    {label:10s} {fmt(sec)}   intra {wire['intra_pod']:>10,}B"
              f"  inter {wire['inter_pod']:>10,}B  (dense {wire['dense']:,}B)")
        out[label] = {"step_s": sec, **wire}
    ratio = out["2-pod"]["step_s"] / out["1-pod"]["step_s"]
    out["two_pod_overhead_x"] = ratio
    comp = out["2-pod"]["inter_pod"] / max(out["2-pod"]["dense"], 1)
    out["winner"] = min(("1-pod", "2-pod"), key=lambda k: out[k]["step_s"])
    print(f"pod    2-pod/1-pod step time {ratio:5.2f}x; inter-pod wire moves "
          f"{100 * comp:.1f}% of dense bytes")
    return out


def run_wire_packed(*, d: int, fraction: float, reps: int):
    """Bit-packed wire transports vs the f32 slab: step time + true bytes.

    Runs the production aggregate() (diana, shared wire) on the flat-
    equivalent (1,4,2) mesh at every `wire_dtype`, on a MATRIX leaf (the
    shape packing is built for — 1-D cols=1 leaves pay the full per-row
    sideband and are a net loss, DESIGN.md §3.13). Bytes come from the
    static accounting (`wire_bytes_per_round`), which the jaxpr census pins
    against the lowered step's collective payloads — so the byte column is
    deterministic, not a measurement. Step time is reported honestly: on
    CPU interpret mode the pack/unpack kernels ADD work and f32 usually
    wins the clock; the byte ratios are the point.
    """
    from jax.sharding import PartitionSpec as P

    from repro.compression.backend import WIRE_DTYPES
    from repro.core.dist import CompressedAggregation
    from repro.launch import compat
    from repro.launch.mesh import make_test_mesh
    from repro.launch.steps import configure_agg

    cols = 256
    rows = d // cols
    print(f"\n--- wire packed: {rows} x {cols} matrix/client, k/d={fraction} "
          + "-" * 10)
    out = {"d": d, "rows": rows, "cols": cols, "fraction": fraction}
    mesh = make_test_mesh((1, 4, 2), ("pod", "data", "model"))
    grads = {"w": jax.random.normal(jax.random.key(7), (4, rows, cols),
                                    jnp.float32)}
    specs = {"w": P(("pod", "data"), None, "model")}
    local = {"w": jnp.zeros((rows, cols // 2), jnp.float32)}  # device block
    for wd in WIRE_DTYPES:
        agg = configure_agg(
            CompressedAggregation(method="diana", wire="shared",
                                  fraction=fraction, shift_dtype=jnp.float32,
                                  wire_dtype=wd), mesh)

        def round_fn(g, agg=agg):
            g = jax.tree.map(lambda x: x[0], g)
            state = agg.init(g)
            direction, _ = agg.aggregate(g, state, jax.random.PRNGKey(0))
            return jax.tree.map(lambda x: x[None], direction)

        mapped = compat.shard_map(round_fn, mesh=mesh, in_specs=(specs,),
                                  out_specs=specs,
                                  axis_names=set(mesh.axis_names),
                                  check_vma=False)
        sec = bench(mapped, grads, reps=reps)
        wire = agg.wire_bytes_per_round(local)
        out[wd] = {"step_s": sec, "intra_pod": wire["intra_pod"]}
    f32_bytes = out["f32"]["intra_pod"]
    for wd in WIRE_DTYPES:
        r = out[wd]["intra_pod"] / max(f32_bytes, 1)
        out[wd]["bytes_ratio_vs_f32"] = r
        print(f"wire   {wd:10s} {fmt(out[wd]['step_s'])}   "
              f"intra {out[wd]['intra_pod']:>8,}B  ({r:5.3f}x f32 bytes)")
    out["winner"] = min(WIRE_DTYPES, key=lambda w: out[w]["step_s"])
    out["bytes_winner"] = min(WIRE_DTYPES, key=lambda w: out[w]["intra_pod"])
    print(f"wire   fastest clock: {out['winner']}; fewest bytes: "
          f"{out['bytes_winner']}")
    return out


def run_pipeline_bench(*, quick: bool, reps: int):
    """Host input pipeline: seed hand-rolled feed vs data.pipeline stream.

    assembly — host time to build one client-major (m*ls*b)-row batch. The
    seed loop called the STATEFUL sampler's `epoch_order` once per
    micro-batch (m*ls full (M, n) permutation draws per step — and, the
    headline bug, each from a fresh permutation); the stream draws each
    epoch's order once and gathers.

    overlap — wall-clock per step of a loop whose "train step" blocks for a
    fixed t_step (GIL released, like block_until_ready), fed synchronously
    vs double-buffered prefetch: with prefetch the assembly cost should
    disappear into the step.
    """
    from repro.data.pipeline import make_batch_stream
    from repro.data.reshuffle import ReshuffleSampler

    # sized so one batch is a few MB: host assembly must be well above the
    # container's timer granularity for the overlap numbers to mean anything
    m, n, b, seq, ls = (16, 8, 4, 512, 2) if quick else (32, 8, 8, 1024, 2)
    steps = 10 if quick else 20
    rng = np.random.default_rng(0)
    tokens = rng.integers(0, 50_000, size=(m, n, b, seq + 1), dtype=np.int32)
    patches = rng.normal(size=(m, n, b, 64, 64)).astype(np.float32)
    print(f"\n--- pipeline: M={m} clients, n={n} x b={b} batches, "
          f"ls={ls}, seq={seq} " + "-" * 14)
    out = {"clients": m, "n_batches": n, "batch": b, "seq": seq,
           "local_steps": ls}

    # the seed repo's feed, reproduced verbatim as the baseline under test
    class SeedSampler:  # the stateful epoch_order (the fixed bug)
        def __init__(self, seed):
            self._rng = np.random.default_rng(seed)

        def epoch_order(self, epoch):
            del epoch
            return np.stack([self._rng.permutation(n) for _ in range(m)])

    flat_patches = patches[:, 0].reshape((m * b,) + patches.shape[3:])

    def seed_feed(t, sampler):
        def micro_batch(c, g):
            e, i = divmod(g, n)
            return tokens[c, sampler.epoch_order(e)[c, i]]

        def tile_extra(v):  # byte-identical rows per local step (seed bug)
            v = v[:m * b].reshape((m, 1, b) + v.shape[1:])
            return np.repeat(v, ls, axis=1).reshape((m * ls * b,) + v.shape[3:])

        tok = np.concatenate([micro_batch(c, t * ls + j)
                              for c in range(m) for j in range(ls)], 0)
        return {"tokens": tok, "patches": tile_extra(flat_patches)}

    def time_feed(fn, setup):
        times = []
        for _ in range(reps):
            ctx = setup()
            t0 = time.perf_counter()
            for t in range(steps):
                fn(t, ctx)
            times.append((time.perf_counter() - t0) / steps)
        return float(np.median(times))

    data = {"tokens": tokens, "patches": patches}

    def fresh_stream(prefetch):
        return make_batch_stream(data, ReshuffleSampler(m, n, seed=1),
                                 local_steps=ls, prefetch=prefetch)

    seed_s = time_feed(seed_feed, lambda: SeedSampler(1))
    stream_s = time_feed(lambda t, st: next(st), lambda: fresh_stream(False))
    print(f"assemble  seed       {fmt(seed_s)}")
    print(f"assemble  stream     {fmt(stream_s)}   "
          f"({seed_s / stream_s:5.1f}x vs seed)")
    out["assemble"] = {"seed": seed_s, "stream": stream_s}
    out["assemble_speedup_stream_vs_seed"] = seed_s / stream_s
    out["winner"] = min(out["assemble"], key=out["assemble"].get)

    # prefetch overlap: the "train step" sleeps ~2x the assembly cost —
    # like a jitted step blocking in block_until_ready, it releases the GIL
    # so the worker thread can assemble the next batch underneath it
    t_step = max(2.0 * stream_s, 2e-3)

    def busy_step():
        time.sleep(t_step)

    def run_loop(prefetch):
        times = []
        for _ in range(max(2, reps // 2)):
            with fresh_stream(prefetch) as st:
                t0 = time.perf_counter()
                for _ in range(steps):
                    next(st)
                    busy_step()
                times.append((time.perf_counter() - t0) / steps)
        return float(np.median(times))

    sync_s, pre_s = run_loop(False), run_loop(True)
    # 1.0 = assembly fully hidden behind the step; 0.0 = fully serialized
    hidden = min(1.0, max(0.0, (sync_s - pre_s) / max(stream_s, 1e-9)))
    print(f"overlap   sync       {fmt(sync_s)}/step  (step busy {fmt(t_step)})")
    print(f"overlap   prefetch   {fmt(pre_s)}/step   "
          f"({100 * hidden:.0f}% of assembly hidden)")
    out["overlap"] = {"step_busy_s": t_step, "sync_s_per_step": sync_s,
                      "prefetch_s_per_step": pre_s,
                      "assembly_hidden_frac": hidden}
    return out


def run_fleet_bench(*, quick: bool, reps: int):
    """Fleet layer: gather/scatter overhead vs resident shifts.

    A fleet round (repro.fleet, DESIGN.md §3.9) pays a host round-trip the
    resident wire does not: gather the cohort's shift rows from the sharded
    `ClientStateStore`, device_put, run the round's fused shift update,
    device_get, scatter back. This times that full round-trip per cohort at
    population scales C ∈ {1e3, 1e5} against the resident baseline (just
    the device update) — the claim under test is that the overhead scales
    with the COHORT (fixed here), not the population: the two C rows should
    cost the same. The 1e5-client store is memmap-backed, so the benchmark
    also exercises the mmap path without 1e5 × d of RSS.
    """
    import tempfile

    from repro.core.rules import get_rule
    from repro.fleet import ClientStateStore, CohortSampler

    m = 8
    d = 4_096 if quick else 32_768
    rounds = 20 if quick else 50
    params = {"w": np.zeros((d,), np.float32)}
    rule = get_rule("single")
    alpha = 0.25
    q = jnp.ones((m, d), jnp.float32)
    update = jax.jit(lambda h: h + alpha * q)

    print(f"\n--- fleet: cohort {m} x d={d:,}, store gather/scatter "
          + "-" * 22)
    out = {"cohort": m, "d": d}

    # resident baseline: the same device update, shifts never leave HBM
    h = update(jnp.zeros((m, d), jnp.float32))
    jax.block_until_ready(h)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(rounds):
            h = update(h)
        jax.block_until_ready(h)
        times.append((time.perf_counter() - t0) / rounds)
    resident_s = float(np.median(times))
    print(f"shift  resident   {fmt(resident_s)}")
    out["resident_s"] = resident_s

    for pop in (1_000, 100_000):
        with tempfile.TemporaryDirectory() as tmp:
            store = ClientStateStore.create(
                params, pop, rule, dtype=np.float32,
                shard_size=16_384, path=tmp if pop > 10_000 else None)
            cohorts = CohortSampler(pop, m, seed=0)

            def fleet_round(t):
                cohort = cohorts.cohort_for_round(t)
                hd = jax.device_put(store.gather(cohort))
                hd = {"w": update(hd["w"])}
                store.scatter(cohort, jax.device_get(hd))

            fleet_round(0)  # warm (compile + touch store pages)
            times = []
            for r in range(reps):
                t0 = time.perf_counter()
                for t in range(rounds):
                    fleet_round(1 + r * rounds + t)
                times.append((time.perf_counter() - t0) / rounds)
            sec = float(np.median(times))
            label = f"C=1e{int(math.log10(pop))}"
            over = sec / resident_s
            print(f"fleet  {label:10s} {fmt(sec)}   ({over:5.1f}x resident, "
                  f"store {store.num_shards} shards"
                  f"{', mmap' if store.path else ''})")
            out[label] = {"round_s": sec, "overhead_x_vs_resident": over,
                          "population": pop, "mmap": store.path is not None}
    # O(cohort) claim: the two population rows should cost about the same —
    # the residual gap is the 1e5 store's mmap first-touch page faults and
    # its cohort spreading over more shards, not population-linear work
    out["pop_scaling_x"] = out["C=1e5"]["round_s"] / out["C=1e3"]["round_s"]
    print(f"fleet  1e5/1e3 round-time ratio {out['pop_scaling_x']:5.2f}x "
          "(O(cohort) gather/scatter: ~1x + mmap first-touch)")
    return out


def run_fleet_async_bench(*, quick: bool, reps: int):
    """Buffered-async fleet rounds (DESIGN.md §3.10) vs the synchronous loop.

    Per round the async driver adds: one `AsyncPlanner` call (the
    deterministic K-of-m participation plan), a per-rank weights vector fed
    to the device update, and a completer-sliced scatter (dropped/late-drop
    clients keep their store rows untouched — exactly-once). This times the
    full host round-trip at dropout ∈ {0, 0.1, 0.3} against the synchronous
    round from `run_fleet_bench`'s pattern. The claim under test: the async
    machinery is host-side O(cohort) bookkeeping — round latency stays
    within noise of synchronous, and rising dropout only SHRINKS the
    scatter.
    """
    from repro.core.rules import get_rule
    from repro.fleet import (AsyncPlanner, ChaosConfig, ClientStateStore,
                             CohortSampler)

    m = 8
    d = 4_096 if quick else 32_768
    rounds = 20 if quick else 50
    pop = 1_000
    params = {"w": np.zeros((d,), np.float32)}
    rule = get_rule("single")
    alpha = 0.25
    q = jnp.ones((m, d), jnp.float32)
    sync_update = jax.jit(lambda h: h + alpha * q)
    elastic_update = jax.jit(lambda h, w: h + alpha * (q * w[:, None]))

    print(f"\n--- fleet async: cohort {m} x d={d:,}, K-of-m buffer "
          + "-" * 24)
    out = {"cohort": m, "d": d, "population": pop}

    def time_rounds(round_fn):
        round_fn(0)  # warm (compile + touch store pages)
        times = []
        for r in range(reps):
            t0 = time.perf_counter()
            for t in range(rounds):
                round_fn(1 + r * rounds + t)
            times.append((time.perf_counter() - t0) / rounds)
        return float(np.median(times))

    # synchronous baseline: every rank completes every round
    store = ClientStateStore.create(params, pop, rule, dtype=np.float32,
                                    shard_size=16_384)
    cohorts = CohortSampler(pop, m, seed=0)

    def sync_round(t):
        cohort = cohorts.cohort_for_round(t)
        hd = jax.device_put(store.gather(cohort))
        hd = {"w": sync_update(hd["w"])}
        store.scatter(cohort, jax.device_get(hd))

    sync_s = time_rounds(sync_round)
    print(f"async  sync       {fmt(sync_s)}")
    out["sync_round_s"] = sync_s

    for drop in (0.0, 0.1, 0.3):
        store = ClientStateStore.create(params, pop, rule, dtype=np.float32,
                                        shard_size=16_384)
        cohorts = CohortSampler(pop, m, seed=0)
        planner = AsyncPlanner(m, buffer_k=max(1, (3 * m) // 4),
                               late="drop",
                               chaos=ChaosConfig(dropout=drop, seed=11))

        def async_round(t, cohorts=cohorts, planner=planner, store=store):
            cohort = cohorts.cohort_for_round(t)
            plan = planner(t, cohort)
            comp = plan.completes
            if not comp.any():
                return  # buffer never fills: no launch, no store writes
            hd = jax.device_put(store.gather(cohort))
            hd = {"w": elastic_update(hd["w"], jnp.asarray(plan.weights))}
            idx = np.flatnonzero(comp)
            host = jax.device_get(hd)
            store.scatter(cohort[idx], {"w": host["w"][idx]})

        sec = time_rounds(async_round)
        label = f"drop={drop}"
        over = sec / sync_s
        print(f"async  {label:10s} {fmt(sec)}   ({over:5.2f}x sync, "
              f"K={planner.buffer_k}/{m})")
        out[label] = {"round_s": sec, "overhead_x_vs_sync": over,
                      "dropout": drop, "buffer_k": planner.buffer_k}
    return out


def run_fleet_paging_bench(*, quick: bool, reps: int):
    """Out-of-core fleet data (DESIGN.md §3.11): the O(cohort) paging claim.

    pop_scaling — COLD per-round cohort assembly through
    `CohortStream(paged=LookaheadPager(...))` with lookahead 0 (every round
    reads its pages from disk) at populations 1e3..1e6. A round touches at
    most min(num_shards, m) pages per leaf — ~32KB here — so per-round time
    must track the COHORT, not the population: the largest/smallest ratio
    should sit near 1x. The 1e6-client store is written sparsely (only the
    clients the timed walk visits; absent shards read as zeros), so the
    bench itself stays O(rounds), not O(population).

    overlap — the prefetch-hidden fraction, mirroring `run_pipeline_bench`:
    a busy "train step" (GIL-releasing sleep) fed by a lookahead-1 paged
    stream, synchronous vs prefetching. The lookahead worker loads round
    t+1's pages while round t's step runs, so the page-in cost should
    disappear into the step.
    """
    import tempfile

    from repro.data.paging import ClientDataStore, LookaheadPager
    from repro.data.pipeline import CohortStream
    from repro.data.reshuffle import ReshuffleSampler
    from repro.fleet import CohortSampler

    m, n, b, d = 8, 2, 1, 64  # one f32 leaf (n, b, d): 512B per client
    shard = 64                # page = shard * 512B = 32KB
    rounds = 20 if quick else 50
    pops = (1_000, 100_000) if quick else (1_000, 100_000, 1_000_000)
    per_client = n * b * d * 4

    print(f"\n--- fleet paging: cohort {m}, {per_client}B/client, "
          f"{shard}-client shards " + "-" * 14)
    out = {"cohort": m, "shard_size": shard, "bytes_per_client": per_client,
           "page_bytes": shard * per_client}

    def build_store(path, pop, touched):
        rng = np.random.default_rng(pop)
        if pop <= 100_000:
            return ClientDataStore.from_stacked(
                path, {"x": rng.normal(
                    size=(pop, n, b, d)).astype(np.float32)},
                shard_size=shard)
        ds = ClientDataStore.create(
            path, pop, {"x": jax.ShapeDtypeStruct((n, b, d), jnp.float32)},
            shard_size=shard)
        ds.write_rows(touched, {"x": rng.normal(
            size=(touched.size, n, b, d)).astype(np.float32)})
        return ds

    def fresh_stream(pop, pager, prefetch, start=0):
        return CohortStream(None, ReshuffleSampler(pop, n, seed=1),
                            CohortSampler(pop, m, seed=0), paged=pager,
                            prefetch=prefetch, start_round=start)

    round_s = {}
    for pop in pops:
        total = 1 + reps * rounds
        cs = CohortSampler(pop, m, seed=0)
        touched = np.unique(np.concatenate(
            [cs.cohort_for_round(t) for t in range(total + 1)]))
        with tempfile.TemporaryDirectory() as tmp:
            ds = build_store(tmp, pop, touched)
            pager = LookaheadPager(ds, lookahead=0)  # cold every round
            times = []
            with fresh_stream(pop, pager, False) as stream:
                next(stream)  # warm: sampler epoch orders + first pages
                for _ in range(reps):
                    t0 = time.perf_counter()
                    for _ in range(rounds):
                        next(stream)
                    times.append((time.perf_counter() - t0) / rounds)
            sec = float(np.median(times))
            label = f"C=1e{int(math.log10(pop))}"
            round_s[label] = sec
            print(f"paging {label:10s} {fmt(sec)}/round cold  "
                  f"(store {ds.nbytes / 1e6:7.1f}MB, "
                  f"resident {pager.resident_nbytes() / 1e3:.0f}KB)")
            out[label] = {"round_s": sec, "population": pop,
                          "store_nbytes": ds.nbytes,
                          "resident_nbytes": pager.resident_nbytes()}
    # THE claim: round cost is O(cohort pages), flat in population
    out["pop_scaling_x"] = max(round_s.values()) / min(round_s.values())
    print(f"paging 1e{int(math.log10(pops[-1]))}/1e3 round-time ratio "
          f"{out['pop_scaling_x']:5.2f}x (O(cohort) paging: ~1x)")

    # prefetch overlap at the mid population, pipeline-bench style
    pop = pops[1]
    with tempfile.TemporaryDirectory() as tmp:
        ds = build_store(tmp, pop, np.empty((0,), np.int64))

        def run_loop(prefetch):
            times = []
            for r in range(max(2, reps // 2)):
                pager = LookaheadPager(ds, lookahead=1)
                with fresh_stream(pop, pager, prefetch,
                                  start=r * (rounds + 1)) as st:
                    next(st)  # warm the window before timing
                    t0 = time.perf_counter()
                    for _ in range(rounds):
                        next(st)
                        busy_step()
                    times.append((time.perf_counter() - t0) / rounds)
            return float(np.median(times))

        assemble_s = round_s[f"C=1e{int(math.log10(pop))}"]
        t_step = max(2.0 * assemble_s, 2e-3)

        def busy_step():
            time.sleep(t_step)

        sync_s, pre_s = run_loop(False), run_loop(True)
        hidden = min(1.0, max(0.0, (sync_s - pre_s) / max(assemble_s, 1e-9)))
        print(f"paging sync       {fmt(sync_s)}/step  "
              f"(step busy {fmt(t_step)})")
        print(f"paging prefetch   {fmt(pre_s)}/step   "
              f"({100 * hidden:.0f}% of page-in hidden)")
        out["overlap"] = {"population": pop, "step_busy_s": t_step,
                          "sync_s_per_step": sync_s,
                          "prefetch_s_per_step": pre_s,
                          "pagein_hidden_frac": hidden}
    return out


def run_telemetry_bench(*, quick: bool, reps: int):
    """Telemetry on-vs-off overhead around a busy host round loop.

    Each round does real jitted device work (a chain of d x d matmuls,
    tens of ms on this CPU backend — the dispatch window of a small train
    step) and, when a sink is installed, emits the per-round event mix the
    fleet drivers produce: one span, one counter, one round_metrics
    carrying live jax scalars. The per-round cost when on is dominated by
    the writer thread forcing those two scalars (~0.1ms each here) — a
    fetch the round's logging pays anyway in a real run — so the busy step
    must be train-step-sized for the ratio to mean anything. The committed
    gate is ABSOLUTE: overhead_frac <= 3% at both scales, the §3.14
    budget. Reported per scale:

      off_s / on_s      median s/round without / with an active file sink
      overhead_frac     on/off - 1 (clamped at 0 for timer noise)
    """
    import tempfile

    from repro import telemetry

    scales = {"small": (512, 12), "large": (640, 8)} if quick else \
        {"small": (640, 16), "large": (768, 10)}
    out = {}
    print("\n-- telemetry: event-pipeline overhead (on vs off) --")
    for name, (d, rounds) in scales.items():
        x = jnp.asarray(np.random.default_rng(0).normal(size=(d, d)),
                        jnp.float32)

        @jax.jit
        def step(a, _d=jnp.float32(d)):
            for _ in range(8):
                a = a @ a.T / _d  # renormalize: no overflow across rounds
            return a

        step(x).block_until_ready()  # compile outside the timed window

        def run_rounds():
            t0 = time.perf_counter()
            for r in range(rounds):
                y = step(x)
                with telemetry.span("device_step", round=r):
                    y.block_until_ready()
                telemetry.counter("fleet.uplink_bits", 8.0 * d * d, round=r)
                telemetry.round_metrics(
                    r, {"loss": y[0, 0], "grad_norm": y[1, 1]})
            return (time.perf_counter() - t0) / rounds

        def timed(active):
            times = []
            for _ in range(reps):
                if active:
                    with tempfile.NamedTemporaryFile(
                            suffix=".telemetry.jsonl") as tf:
                        sink = telemetry.install(
                            telemetry.MetricsSink(tf.name))
                        try:
                            times.append(run_rounds())
                        finally:
                            telemetry.uninstall()
                            sink.close()
                else:
                    times.append(run_rounds())
            return float(np.median(times))

        off_s = timed(False)
        on_s = timed(True)
        overhead = max(0.0, on_s / off_s - 1.0)
        print(f"{name}: off {fmt(off_s)}/round  on {fmt(on_s)}/round  "
              f"overhead {100 * overhead:.2f}%")
        out[name] = {"d": d, "rounds": rounds, "off_s": off_s,
                     "on_s": on_s, "overhead_frac": overhead}
    return out


def check_baseline(results: dict, baseline_path: str) -> bool:
    """CI guard: fail when the Rand-k speedups regress below the committed
    BENCH_compression.json, or the packed wire's byte ratios grow.

    Shapes differ between --quick (CI) and full runs and shared runners are
    noisy, so the timing gates are a generous fraction of the committed
    ratio — tight enough to catch a kernel path silently falling back or
    slowing by integer factors, loose enough not to flake on timer jitter.

    Which timing gates apply depends on what the current run actually
    compiled: pallas-vs-* floors only bind under real Mosaic kernels
    (meta.pallas_mode == "mosaic"); CPU interpret mode executes kernel
    bodies eqn-by-eqn, so its "pallas" timings measure the interpreter, and
    reference-vs-seed is the regression signal there. The wire_packed byte
    ratios are static accounting (census-pinned), not timings, so they gate
    at near-equality.
    """
    with open(baseline_path) as f:
        full_base = json.load(f)
    base = full_base["scales"]["logreg"]
    cur = results["scales"]["logreg"]
    # reference-vs-seed runs systematically lower at --quick shapes than the
    # committed full-run number (~0.4x: the seed path's per-leaf sort is what
    # grows superlinearly), so its floor fraction is looser — it still trips
    # on the integer-factor regressions the gate exists for
    gates = [("randk_speedup_reference_vs_seed", 0.15)]
    if results["meta"]["pallas_mode"] == "mosaic":
        gates += [("randk_speedup_pallas_vs_reference", 0.35),
                  ("randk_speedup_pallas_vs_seed", 0.35)]
    else:
        print("pallas_mode=interpret: pallas-vs-* floors not binding "
              "(interpret timings measure the interpreter, not the kernels)")
    ok = True
    for key, floor_frac in gates:
        if key not in base:
            print(f"baseline has no {key}; skipping that gate")
            continue
        floor = floor_frac * base[key]
        status = "ok" if cur[key] >= floor else "REGRESSED"
        print(f"baseline gate {key}: current {cur[key]:.2f}x vs committed "
              f"{base[key]:.2f}x (floor {floor:.2f}x) -> {status}")
        ok = ok and cur[key] >= floor
    base_wp = full_base.get("wire_packed", {}).get("small")
    cur_wp = results.get("wire_packed", {}).get("small")
    if base_wp and cur_wp:
        for wd in ("bf16", "packed8", "packed4"):
            b = base_wp[wd]["bytes_ratio_vs_f32"]
            c = cur_wp[wd]["bytes_ratio_vs_f32"]
            status = "ok" if c <= b * 1.01 else "REGRESSED"
            print(f"baseline gate wire_packed/{wd} bytes-vs-f32: current "
                  f"{c:.4f} vs committed {b:.4f} -> {status}")
            ok = ok and c <= b * 1.01
    else:
        print("baseline has no wire_packed section; skipping byte-ratio gate")
    # telemetry overhead gates at an ABSOLUTE budget (DESIGN.md §3.14), not
    # a committed ratio: the zero-cost-when-off pipeline must stay under 3%
    # on-vs-off regardless of what any past run measured
    tel = results.get("telemetry")
    if tel:
        for scale, r in sorted(tel.items()):
            status = "ok" if r["overhead_frac"] <= 0.03 else "REGRESSED"
            print(f"baseline gate telemetry/{scale} overhead: "
                  f"{100 * r['overhead_frac']:.2f}% (budget 3.00%) "
                  f"-> {status}")
            ok = ok and r["overhead_frac"] <= 0.03
    else:
        print("no telemetry section; skipping overhead gate")
    return ok


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller shapes + fewer reps (CI smoke)")
    ap.add_argument("--out", default="BENCH_compression.json")
    ap.add_argument("--check-baseline", default=None, metavar="JSON",
                    help="compare speedups against a committed "
                         "BENCH_compression.json and exit nonzero on "
                         "regression (the CI smoke gate)")
    args = ap.parse_args()

    reps = 5 if args.quick else 10
    key = jax.random.key(0)
    results = {
        "meta": {
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "quick": args.quick,
            "pallas_mode": ("interpret" if jax.default_backend() == "cpu"
                            else "mosaic"),
        },
        "scales": {},
    }

    t0 = time.time()
    d = 20_000 if args.quick else 120_000
    m = 8 if args.quick else 32
    results["scales"]["logreg"] = run_scale(
        "logreg", logreg_tree(m, d, key), fraction=0.02, levels=8, reps=reps
    )

    tcfg = dict(layers=2, d_model=128, vocab=2048) if args.quick else \
        dict(layers=4, d_model=256, vocab=8192)
    results["scales"]["transformer"] = run_scale(
        "transformer", transformer_tree(8, key, **tcfg),
        fraction=0.05, levels=8, reps=max(3, reps // 2),
    )

    results["rules"] = run_rules(
        m=8, n_slots=8, d=20_000 if args.quick else 120_000,
        reps=max(3, reps // 2),
    )

    results["pod_wire"] = run_pod_wire(
        d=8_192 if args.quick else 65_536, fraction=0.05,
        reps=max(3, reps // 2),
    )

    results["wire_packed"] = {
        "small": run_wire_packed(d=4_096 if args.quick else 8_192,
                                 fraction=0.05, reps=max(3, reps // 2)),
        "large": run_wire_packed(d=16_384 if args.quick else 65_536,
                                 fraction=0.05, reps=max(3, reps // 2)),
    }

    results["pipeline"] = run_pipeline_bench(quick=args.quick,
                                             reps=max(3, reps // 2))

    results["fleet"] = run_fleet_bench(quick=args.quick,
                                       reps=max(3, reps // 2))

    results["fleet_async"] = run_fleet_async_bench(quick=args.quick,
                                                   reps=max(3, reps // 2))

    results["fleet_paging"] = run_fleet_paging_bench(quick=args.quick,
                                                     reps=max(3, reps // 2))

    results["telemetry"] = run_telemetry_bench(quick=args.quick,
                                               reps=max(3, reps // 2))

    sp = results["scales"]["logreg"]["randk_speedup_pallas_vs_seed"]
    results["meta"]["elapsed_s"] = round(time.time() - t0, 1)
    ok = sp >= 2.0
    print(f"\nlogreg randk speedup (pallas backend vs seed): {sp:.1f}x "
          f"{'(>= 2x target met)' if ok else '(below 2x target!)'}")

    with open(args.out, "w") as f:
        json.dump(results, f, indent=2)
    print(f"wrote {args.out} in {results['meta']['elapsed_s']}s")

    if args.check_baseline and not check_baseline(results,
                                                  args.check_baseline):
        raise SystemExit(2)


if __name__ == "__main__":
    main()
